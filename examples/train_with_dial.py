"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the data pipeline ingesting through the DIAL-tuned simulated PFS and
checkpoints flowing through the tuned write path.

Run:  PYTHONPATH=src python examples/train_with_dial.py [--steps 200]
"""

import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    # demo-100m lives in repro/configs/demo_100m.py (~100M params)
    out = train("demo-100m", steps=args.steps, batch=args.batch,
                seq_len=args.seq_len, ckpt_dir="/tmp/dial_demo_ckpt",
                ckpt_every=50, dial_model_path="models/dial",
                log_every=20)
    n = sum(p.size for p in __import__("jax").tree.leaves(out["params"]))
    print(f"\ntrained {n / 1e6:.0f}M params for {args.steps} steps")
    print(f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}; "
          f"ingest {out['ingest_mbs']:.0f} MB/s (DIAL-tuned)")


if __name__ == "__main__":
    main()
