"""Batched serving example: prefill a prompt batch and decode new tokens
for three different architecture families (dense / hybrid / SSM).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""

from repro.launch.serve import serve

for arch in ("gemma2-2b", "recurrentgemma-9b", "falcon-mamba-7b"):
    out = serve(arch, batch=4, prompt_len=24, gen_tokens=12)
    print(f"{arch:20s}: generated {out['tokens'].shape}, "
          f"prefill {out['prefill_s']:.2f}s, "
          f"{out['tok_per_s']:.1f} tok/s decode (smoke config, CPU)")
