"""Quickstart: DIAL end-to-end in ~a minute on CPU.

1. Build (or load) the learned client-side models.
2. Run a workload on the simulated Lustre cluster from a bad config,
   once static and once with a DIAL agent tuning each OSC interface.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import CollectConfig, collect, train_models
from repro.core.agent import run_with_agents
from repro.core.gbdt import GBDTParams
from repro.core.model import DIALModel
from repro.pfs import PFSSim
from repro.pfs.engine import READ
from repro.pfs.workloads import sequential_stream


def get_model() -> DIALModel:
    try:
        model = DIALModel.load("models/dial")
        print("loaded pretrained forests from models/dial.*")
        return model
    except FileNotFoundError:
        print("collecting a small offline dataset (paper SIV-A recipe)...")
        data = collect(CollectConfig(seconds=40.0, reps=2))
        print(f"  read samples: {len(data['read'][0])}, "
              f"write samples: {len(data['write'][0])}")
        return train_models(data, GBDTParams(n_trees=80, max_depth=6))


def main():
    model = get_model()

    def throughput(tuned: bool) -> float:
        sim = PFSSim(n_clients=1, n_osts=4, seed=7)
        wl = sequential_stream(0, READ, 16 * 2**20, ost=0)
        sim.attach(wl)
        # pathological starting configuration
        sim.set_knobs(sim.client_oscs(0), window_pages=16, rpcs_in_flight=1)
        if tuned:
            run_with_agents(sim, model, clients=[0], seconds=15.0)
        else:
            sim.run(15.0)
        return wl.done_bytes(sim) / 15.0 / 1e6

    static = throughput(False)
    dial = throughput(True)
    print(f"\nsequential 16 MiB reads from (window=16 pages, in-flight=1):")
    print(f"  static : {static:7.1f} MB/s")
    print(f"  DIAL   : {dial:7.1f} MB/s   ({dial / static:.1f}x)")


if __name__ == "__main__":
    main()
