"""Decentralized collective behaviour: four clients share OSTs; each runs
an independent DIAL agent (no communication).  Compare aggregate delivered
bandwidth vs static defaults as the mix of workloads shifts mid-run —
the adaptivity claim of the paper at multi-client scope.

Run:  PYTHONPATH=src python examples/dial_vs_static.py
"""

from repro.core.agent import DIALAgent, SimClientPort
from repro.core.model import DIALModel
from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import Workload


def scenario(tuned: bool, seconds: float = 40.0) -> float:
    model = DIALModel.load("models/dial") if tuned else None
    sim = PFSSim(n_clients=4, n_osts=4, seed=21)
    wls = [
        # phase 1 mix: two seq readers, one random reader, one writer
        Workload(client=0, op=READ, req_size=16 * 2**20, randomness=0.0,
                 n_threads=2, osts=(0, 1)),
        Workload(client=1, op=READ, req_size=8 * 1024, randomness=1.0,
                 n_threads=32, osts=(1,)),
        Workload(client=2, op=WRITE, req_size=1 * 2**20, randomness=0.1,
                 n_threads=4, osts=(2, 3)),
        # late joiner: kicks in mid-run via duty cycling
        Workload(client=3, op=READ, req_size=64 * 1024, randomness=0.9,
                 n_threads=16, osts=(0, 2), duty_cycle=0.5, period=seconds),
    ]
    # heterogeneous starting points: two clients inherit configurations
    # tuned for a PREVIOUS workload phase (the adaptivity scenario)
    starts = {0: (256, 8), 1: (1024, 32), 2: (256, 8), 3: (16, 1)}
    for w in wls:
        sim.attach(w)
        sw, sf = starts[w.client]
        sim.set_knobs(sim.client_oscs(w.client), window_pages=sw,
                      rpcs_in_flight=sf)
    agents = [DIALAgent(SimClientPort(sim, c), model) for c in range(4)] \
        if tuned else []
    steps = int(0.5 / sim.params.tick)
    for _ in range(int(seconds / 0.5)):
        for _ in range(steps):
            sim.step()
        for a in agents:
            a.tick()
    return [w.done_bytes(sim) / seconds / 1e6 for w in wls]


NAMES = ["seq reader (2 OSTs)", "random-8K reader x32",
         "writer (2 OSTs)", "late 64K shuffled x16"]


def main():
    static = scenario(False)
    dial = scenario(True)
    print("per-client delivered bandwidth over a shifting 4-client mix")
    print("(clients 1 and 3 start from configurations tuned for an earlier")
    print(" workload phase — the decentralized-adaptation scenario):\n")
    for name, s, d in zip(NAMES, static, dial):
        print(f"  {name:24s} static={s:7.1f}  DIAL={d:7.1f} MB/s "
              f"({d / max(s, 0.1):5.2f}x)")
    print(f"  {'aggregate':24s} static={sum(static):7.1f}  "
          f"DIAL={sum(dial):7.1f} MB/s ({sum(dial)/sum(static):5.2f}x)")


if __name__ == "__main__":
    main()
