PY ?= python

.PHONY: verify deps bench-fleet bench-train bench-loop bench-weak bench-ragged bench-json bench-compare trace-smoke lab-smoke continual-smoke fuzz-smoke diagnose-smoke ragged-smoke

deps:
	$(PY) -m pip install -r requirements-dev.txt

# tier-1 verify (same command CI runs)
verify:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-fleet:
	PYTHONPATH=src $(PY) benchmarks/fleet_scaling.py --quick

bench-train:
	PYTHONPATH=src $(PY) benchmarks/train_scaling.py --quick

bench-loop:
	PYTHONPATH=src $(PY) benchmarks/loop_scaling.py --quick

# weak-scaling fleet: tuned interfaces/sec vs (forced host) device count
bench-weak:
	PYTHONPATH=src $(PY) benchmarks/fleet_weak_scaling.py

# ragged catalog economics: padded-ragged vs per-structure vs sequential
bench-ragged:
	PYTHONPATH=src $(PY) benchmarks/ragged_scaling.py --quick

# full benchmark sweep + machine-readable perf record
# (repo root on PYTHONPATH: run.py imports its siblings as benchmarks.*)
bench-json:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --json reports/BENCH_latest.json

# regression gate: latest sweep vs the committed reference record
# (BASELINE/CANDIDATE overridable: make bench-compare CANDIDATE=...)
BASELINE ?= BENCH_10.json
CANDIDATE ?= reports/BENCH_latest.json
bench-compare:
	$(PY) benchmarks/compare.py $(BASELINE) $(CANDIDATE)

# CI-sized traced replay: one scenario through the traced fused loop,
# all three sinks into reports/trace/ (resolves models/dial or the
# latest campaign artifact; trains a smoke campaign if neither exists)
trace-smoke:
	PYTHONPATH=src $(PY) -m repro.lab trace vpic_checkpoint --smoke \
	    --seconds 5

# CI-sized scenario-catalog sweep (writes reports/lab/report.{json,md})
lab-smoke:
	PYTHONPATH=src $(PY) -m repro.lab evaluate --smoke

# CI-sized frozen-vs-online continual run (writes reports/lab/continual.json)
continual-smoke:
	PYTHONPATH=src $(PY) -m repro.lab continual --smoke

# CI-sized fuzz sweep: 64 generated scenarios raced vs a static grid,
# auto-triaged (writes reports/fuzz/report.{json,md}); every triaged
# loser is stamped with its counterfactual diagnosis
fuzz-smoke:
	PYTHONPATH=src $(PY) -m repro.lab fuzz --smoke

# CI-sized counterfactual diagnosis: one registry scenario replayed
# under the intervention arms end to end (writes reports/diagnose/)
diagnose-smoke:
	PYTHONPATH=src $(PY) -m repro.lab diagnose degraded_ost --smoke \
	    --seconds 5 --out reports/diagnose

# ragged padding-neutrality tests (the CI ragged-equivalence job)
ragged-smoke:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_ragged.py
