PY ?= python

.PHONY: verify deps bench-fleet

deps:
	$(PY) -m pip install -r requirements-dev.txt

# tier-1 verify (same command CI runs)
verify:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-fleet:
	PYTHONPATH=src $(PY) benchmarks/fleet_scaling.py --quick

# CI-sized scenario-catalog sweep (writes reports/lab/report.{json,md})
lab-smoke:
	PYTHONPATH=src $(PY) -m repro.lab evaluate --smoke
