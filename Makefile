PY ?= python

.PHONY: verify deps bench-fleet

deps:
	$(PY) -m pip install -r requirements-dev.txt

# tier-1 verify (same command CI runs)
verify:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench-fleet:
	PYTHONPATH=src $(PY) benchmarks/fleet_scaling.py --quick
