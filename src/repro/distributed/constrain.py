"""Sharding-constraint hooks usable from inside model code.

``constrain(x, *axes)`` applies ``with_sharding_constraint`` when called
under an active mesh (pjit tracing in the launcher / dry-run) and is a
no-op otherwise (CPU smoke tests, single device).  The special axis name
"dp" expands to the data-parallel axes of the active mesh (('pod',
'data') on the multi-pod mesh), and axes absent from the mesh are
dropped — the same annotation works on any mesh shape.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def _active_mesh():
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        if m.empty:
            return None
        return m
    except Exception:  # pragma: no cover
        return None


def model_axis_size() -> int:
    """Size of the 'model' axis in the active mesh (0 when no mesh)."""
    mesh = _active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 0
    return int(mesh.shape["model"])


def constrain(x, *axes):
    mesh = _active_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    spec = []
    for a in axes:
        if a == "dp":
            dp = tuple(n for n in ("pod", "data") if n in names)
            spec.append(dp if dp else None)
        elif a is None or a in names:
            spec.append(a)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
