"""Distribution: sharding rules, ZeRO-1, gradient compression."""

from repro.distributed.sharding import (
    batch_pspec,
    cache_pspecs,
    dp_axes,
    param_pspecs,
    zero1_pspecs,
)

__all__ = ["batch_pspec", "cache_pspecs", "dp_axes", "param_pspecs",
           "zero1_pspecs"]
