"""Sharding rules: parameters, optimizer state (ZeRO-1), batches, caches —
and the DIAL fleet axis.

Mesh axes: ``('data', 'model')`` single-pod, ``('pod', 'data', 'model')``
multi-pod.  Batch and gradient reduction use (pod, data); tensor
parallelism (heads / ffn / experts / vocab) uses 'model'.

Rules are keyed by parameter *name* (the innermost dict key), matching the
layouts in repro.models.*; stacked (scanned) layers get a leading
replicated dim.  ZeRO-1 additionally shards optimizer moments over the
data axes along the largest replicated-and-divisible dimension.

The **fleet axis** (:data:`FLEET_AXIS`) is the simulator-side counterpart:
the leading batch/interface axis of a stacked scenario batch
(:mod:`repro.lab.batch`) or fused decision loop
(:mod:`repro.pfs.loop_jax`).  Every DIAL decision reads only its own
interface's local counters — the paper's decentralization — so the fleet
axis partitions with **no collectives**: each device shard runs its own
engine ticks, probe differencing, forest scoring, and Algorithm 1
entirely device-local.  The helpers here build the 1-D mesh, the
``P('fleet')`` spec trees, and the pad/unpad used when a batch does not
divide the device count.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    """Data-parallel mesh axes (includes 'pod' when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_pspec(mesh: Mesh) -> P:
    return P(dp_axes(mesh))


# --- per-name rules: trailing-dims spec (stacked leading dim added later)
# 'M' marks the model-sharded dim.
_RULES = {
    # attention
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    "wo": ("model", None),
    # mlp
    "gate": (None, "model"), "up": (None, "model"), "down": ("model", None),
    # moe (leading expert axis -> expert parallel over 'model')
    "router": (None, None),
    "moe_gate": ("model", None, None), "moe_up": ("model", None, None),
    "moe_down": ("model", None, None),
    "shared_gate": (None,),
    # mamba
    "in_proj": (None, "model"), "conv_w": ("model", None),
    "x_proj": ("model", None), "dt_proj": (None, "model"),
    "dt_bias": ("model",), "A_log": ("model", None), "D": ("model",),
    "out_proj": ("model", None),
    # rglru
    "in_gate": (None, "model"), "in_lin": (None, "model"),
    "wa": (None, "model"), "wx": (None, "model"),
    "ba": ("model",), "bx": ("model",), "lam": ("model",),
    # norms
    "scale": (None,), "bias": (None,),
}


def _spec_for(path, leaf) -> tuple:
    names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
    name = names[-1]
    parents = names[:-1]
    ndim = leaf.ndim

    if name == "embed":
        spec = ("model", None, None)[-ndim:] if ndim == 3 \
            else ("model", None)          # vocab-sharded
    elif name == "head":
        spec = (None, None, "model")[-ndim:] if ndim == 3 \
            else (None, "model")
    elif "moe" in parents and name in ("gate", "up", "down"):
        spec = _RULES["moe_" + name]
    elif "shared" in parents:             # qwen2moe shared expert = dense mlp
        spec = _RULES[name]
    else:
        spec = _RULES.get(name)
        if spec is None:
            spec = (None,) * ndim
    # stacked (scanned) leaves carry a leading n_rep dim
    extra = ndim - len(spec)
    assert extra >= 0, (names, leaf.shape, spec)
    return (None,) * extra + tuple(spec)


def param_pspecs(params) -> dict:
    """PartitionSpec pytree matching a params (or abstract params) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: P(*_spec_for(path, leaf)), params)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def validate_pspecs(pspecs, tree, mesh: Mesh):
    """Drop mesh axes from dims they don't divide.

    E.g. qwen2-moe's 60 experts cannot shard 16 ways — the expert axis
    falls back to replication (pure-DP MoE baseline; see EXPERIMENTS.md
    SPerf for the padded-EP variant).
    """
    def axis_size(a):
        if a is None:
            return 1
        if isinstance(a, (tuple, list)):
            n = 1
            for x in a:
                n *= mesh.shape[x]
            return n
        return mesh.shape[a]

    def one(spec, leaf):
        fixed = []
        for dim, a in zip(leaf.shape, tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            fixed.append(a if a is not None and dim % axis_size(a) == 0 else None)
        return P(*fixed)

    return jax.tree.map(one, pspecs, tree,
                        is_leaf=lambda x: isinstance(x, P))


def zero1_pspecs(params, pspecs, mesh: Mesh) -> dict:
    """Optimizer-moment specs: param spec + data-axis sharding (ZeRO-1).

    For each leaf, shard the largest dim that is currently replicated and
    divisible by the data-parallel world size.  Falls back to the param
    spec when nothing divides (small norms/biases stay replicated).
    """
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def one(path, leaf):
        spec = list(_spec_for(path, leaf))
        if dp_size > 1:
            order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
            for i in order:
                if spec[i] is None and leaf.shape[i] % dp_size == 0:
                    spec[i] = dp if len(dp) > 1 else dp[0]
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params)


# ---------------------------------------------------------------------- #
# the DIAL fleet axis: batch/interface sharding for the fused loop
# ---------------------------------------------------------------------- #
FLEET_AXIS = "fleet"


def fleet_mesh(n_devices: int | None = None, *, devices=None) -> Mesh:
    """1-D mesh over local devices for the batch/interface axis.

    Every array the fused loop shards carries the scenario-batch axis
    leading, so one axis name is all the partitioning needs.  Default:
    all local devices (on CPU, force more with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    initializes — the pattern :mod:`repro.launch.dryrun` uses).
    """
    if devices is None:
        devices = jax.devices()
        if n_devices is not None:
            if n_devices > len(devices):
                raise ValueError(
                    f"fleet mesh wants {n_devices} devices but only "
                    f"{len(devices)} are visible (force host devices "
                    f"with --xla_force_host_platform_device_count)")
            devices = devices[:n_devices]
    return Mesh(np.asarray(devices), (FLEET_AXIS,))


def fleet_pspec() -> P:
    """Leading-axis spec of every fleet-sharded array (trailing dims —
    ops, interfaces, workload rows, ticks — stay device-local)."""
    return P(FLEET_AXIS)


def fleet_specs(tree):
    """A ``P('fleet')`` for every leaf of a stacked scenario pytree
    (``SimState`` / ``WorkloadTable`` / ``WorkloadState`` / disturbance
    schedule — all their leaves carry the batch axis leading)."""
    return jax.tree.map(lambda _: fleet_pspec(), tree)


def fleet_sharding(mesh: Mesh) -> NamedSharding:
    """The NamedSharding host arrays are ``device_put`` with before a
    sharded dispatch — placing inputs pre-sharded is what makes
    ``donate_argnums`` donation real (no reshard copy to un-donated
    buffers)."""
    return NamedSharding(mesh, fleet_pspec())


def fleet_batch_size(tree) -> int:
    """Leading-axis extent shared by every leaf of a stacked batch."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        raise ValueError("empty pytree has no batch axis")
    return int(np.asarray(leaves[0]).shape[0])


def pad_fleet(tree, n_shards: int, n_pad: int | None = None):
    """Pad every leaf's leading batch axis up to a multiple of
    ``n_shards`` by repeating element 0.

    Returns ``(padded_tree, n_pad)``.  Pad elements are discarded by
    :func:`unpad_fleet` after the dispatch; callers that carry per-
    element *decision* masks must pad those with ``False`` themselves so
    phantom elements never decide (see ``FusedLoop.run``).
    """
    b = fleet_batch_size(tree)
    if n_pad is None:
        n_pad = (-b) % int(n_shards)
    if n_pad == 0:
        return tree, 0

    def one(a):
        a = np.asarray(a)
        return np.concatenate([a, np.repeat(a[:1], n_pad, axis=0)])
    return jax.tree.map(one, tree), n_pad


def unpad_fleet(tree, n_pad: int):
    """Strip :func:`pad_fleet`'s phantom trailing elements again."""
    if n_pad == 0:
        return tree
    return jax.tree.map(lambda a: np.asarray(a)[:-n_pad], tree)


def cache_pspecs(cfg, cache, mesh: Mesh, shard_seq: bool = False) -> dict:
    """Decode-cache specs.

    Default: batch over data axes, kv-heads (or channels) over 'model'.
    ``shard_seq=True`` (long-context, batch=1): the KV sequence axis
    shards over the data axes instead — sequence parallelism for decode.
    """
    dp = dp_axes(mesh)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    model_size = mesh.shape["model"]
    # kv heads shard over 'model' when divisible, else head_dim does
    # (all assigned archs have head_dim % 16 == 0)
    kv_heads_ok = cfg.n_kv_heads % model_size == 0

    def one(path, leaf):
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1]
        if name in ("k", "v"):              # (B, S, Hkv, Dh)
            kv_model = ("model", None) if kv_heads_ok else (None, "model")
            spec = (None, dp_spec) + kv_model if shard_seq \
                else (dp_spec, None) + kv_model
        elif name == "conv":                # (B, K-1, W)
            spec = (None, None, "model") if shard_seq \
                else (dp_spec, None, "model")
        elif name == "ssm":                 # (B, Di, N)
            spec = (None, "model", None) if shard_seq \
                else (dp_spec, "model", None)
        elif name == "h":                   # (B, W)
            spec = (None, "model") if shard_seq else (dp_spec, "model")
        else:
            spec = ()
        # leaves under cache['stack'] carry a leading n_rep dim
        extra = leaf.ndim - len(spec)
        return P(*((None,) * extra + tuple(spec)))

    return jax.tree_util.tree_map_with_path(one, cache)
