"""Sharding rules: parameters, optimizer state (ZeRO-1), batches, caches.

Mesh axes: ``('data', 'model')`` single-pod, ``('pod', 'data', 'model')``
multi-pod.  Batch and gradient reduction use (pod, data); tensor
parallelism (heads / ffn / experts / vocab) uses 'model'.

Rules are keyed by parameter *name* (the innermost dict key), matching the
layouts in repro.models.*; stacked (scanned) layers get a leading
replicated dim.  ZeRO-1 additionally shards optimizer moments over the
data axes along the largest replicated-and-divisible dimension.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh):
    """Data-parallel mesh axes (includes 'pod' when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_pspec(mesh: Mesh) -> P:
    return P(dp_axes(mesh))


# --- per-name rules: trailing-dims spec (stacked leading dim added later)
# 'M' marks the model-sharded dim.
_RULES = {
    # attention
    "wq": (None, "model"), "wk": (None, "model"), "wv": (None, "model"),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    "wo": ("model", None),
    # mlp
    "gate": (None, "model"), "up": (None, "model"), "down": ("model", None),
    # moe (leading expert axis -> expert parallel over 'model')
    "router": (None, None),
    "moe_gate": ("model", None, None), "moe_up": ("model", None, None),
    "moe_down": ("model", None, None),
    "shared_gate": (None,),
    # mamba
    "in_proj": (None, "model"), "conv_w": ("model", None),
    "x_proj": ("model", None), "dt_proj": (None, "model"),
    "dt_bias": ("model",), "A_log": ("model", None), "D": ("model",),
    "out_proj": ("model", None),
    # rglru
    "in_gate": (None, "model"), "in_lin": (None, "model"),
    "wa": (None, "model"), "wx": (None, "model"),
    "ba": ("model",), "bx": ("model",), "lam": ("model",),
    # norms
    "scale": (None,), "bias": (None,),
}


def _spec_for(path, leaf) -> tuple:
    names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
    name = names[-1]
    parents = names[:-1]
    ndim = leaf.ndim

    if name == "embed":
        spec = ("model", None, None)[-ndim:] if ndim == 3 \
            else ("model", None)          # vocab-sharded
    elif name == "head":
        spec = (None, None, "model")[-ndim:] if ndim == 3 \
            else (None, "model")
    elif "moe" in parents and name in ("gate", "up", "down"):
        spec = _RULES["moe_" + name]
    elif "shared" in parents:             # qwen2moe shared expert = dense mlp
        spec = _RULES[name]
    else:
        spec = _RULES.get(name)
        if spec is None:
            spec = (None,) * ndim
    # stacked (scanned) leaves carry a leading n_rep dim
    extra = ndim - len(spec)
    assert extra >= 0, (names, leaf.shape, spec)
    return (None,) * extra + tuple(spec)


def param_pspecs(params) -> dict:
    """PartitionSpec pytree matching a params (or abstract params) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: P(*_spec_for(path, leaf)), params)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def validate_pspecs(pspecs, tree, mesh: Mesh):
    """Drop mesh axes from dims they don't divide.

    E.g. qwen2-moe's 60 experts cannot shard 16 ways — the expert axis
    falls back to replication (pure-DP MoE baseline; see EXPERIMENTS.md
    SPerf for the padded-EP variant).
    """
    def axis_size(a):
        if a is None:
            return 1
        if isinstance(a, (tuple, list)):
            n = 1
            for x in a:
                n *= mesh.shape[x]
            return n
        return mesh.shape[a]

    def one(spec, leaf):
        fixed = []
        for dim, a in zip(leaf.shape, tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            fixed.append(a if a is not None and dim % axis_size(a) == 0 else None)
        return P(*fixed)

    return jax.tree.map(one, pspecs, tree,
                        is_leaf=lambda x: isinstance(x, P))


def zero1_pspecs(params, pspecs, mesh: Mesh) -> dict:
    """Optimizer-moment specs: param spec + data-axis sharding (ZeRO-1).

    For each leaf, shard the largest dim that is currently replicated and
    divisible by the data-parallel world size.  Falls back to the param
    spec when nothing divides (small norms/biases stay replicated).
    """
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def one(path, leaf):
        spec = list(_spec_for(path, leaf))
        if dp_size > 1:
            order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
            for i in order:
                if spec[i] is None and leaf.shape[i] % dp_size == 0:
                    spec[i] = dp if len(dp) > 1 else dp[0]
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, params)


def cache_pspecs(cfg, cache, mesh: Mesh, shard_seq: bool = False) -> dict:
    """Decode-cache specs.

    Default: batch over data axes, kv-heads (or channels) over 'model'.
    ``shard_seq=True`` (long-context, batch=1): the KV sequence axis
    shards over the data axes instead — sequence parallelism for decode.
    """
    dp = dp_axes(mesh)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    model_size = mesh.shape["model"]
    # kv heads shard over 'model' when divisible, else head_dim does
    # (all assigned archs have head_dim % 16 == 0)
    kv_heads_ok = cfg.n_kv_heads % model_size == 0

    def one(path, leaf):
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1]
        if name in ("k", "v"):              # (B, S, Hkv, Dh)
            kv_model = ("model", None) if kv_heads_ok else (None, "model")
            spec = (None, dp_spec) + kv_model if shard_seq \
                else (dp_spec, None) + kv_model
        elif name == "conv":                # (B, K-1, W)
            spec = (None, None, "model") if shard_seq \
                else (dp_spec, None, "model")
        elif name == "ssm":                 # (B, Di, N)
            spec = (None, "model", None) if shard_seq \
                else (dp_spec, "model", None)
        elif name == "h":                   # (B, W)
            spec = (None, "model") if shard_seq else (dp_spec, "model")
        else:
            spec = ()
        # leaves under cache['stack'] carry a leading n_rep dim
        extra = leaf.ndim - len(spec)
        return P(*((None,) * extra + tuple(spec)))

    return jax.tree_util.tree_map_with_path(one, cache)
