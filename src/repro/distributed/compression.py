"""Gradient compression: int8 quantization with error feedback.

For cross-pod gradient reduction (the slow DCN hop on multi-pod meshes),
gradients quantize to int8 with a per-tensor absmax scale before the
reduction; the quantization residual accumulates in a local error-feedback
buffer added to the next step's gradient (Seide et al. 1-bit SGD / EF-SGD
semantics, which keeps SGD/Adam convergence).

``compressed_psum`` runs inside shard_map over the reduction axis.  The
arithmetic is exact int8 semantics; on CPU/XLA the reduction itself is
carried in int32 (XLA has no int8 ring all-reduce), so the *wire-byte*
saving (4x) is reported analytically via ``wire_bytes`` — on TPU the int8
payload is what crosses the DCN.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(grad, error_buf):
    """Error-feedback compression of one tensor.

    Returns (int8 payload, scale, new_error_buf)."""
    g = grad.astype(jnp.float32) + error_buf
    q, scale = quantize_int8(g)
    new_err = g - dequantize(q, scale)
    return q, scale, new_err


def compressed_psum(grads, error_bufs, axis_name: str):
    """Inside shard_map: EF-int8 compress + reduce over ``axis_name``.

    Returns (reduced_f32_grads, new_error_bufs).
    """
    def one(g, e):
        q, scale, new_e = ef_compress(g, e)
        # int32 carrier for the reduction (int8 payload on real DCN)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_sum = jax.lax.psum(scale, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        # per-shard scales differ; use the mean scale (standard EF-SGD
        # approximation — the residual lands in the error buffer)
        return total.astype(jnp.float32) * (scale_sum / n) / n, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_bufs)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def wire_bytes(grads, compressed: bool) -> float:
    """Analytic per-reduction wire bytes (ring all-reduce, 2x payload)."""
    n = sum(g.size for g in jax.tree.leaves(grads))
    return 2.0 * n * (1 if compressed else 4)


def make_dp_train_grads(loss_fn, mesh, axis_name: str = "data",
                        compress: bool = True):
    """Pure-DP gradient computation with EF-int8 cross-shard reduction.

    Returns grads_fn(params, batch, error_bufs) -> (loss, grads, bufs):
    the batch shards over ``axis_name`` via shard_map, each shard
    backprops its microbatch, and the reduction runs compressed.  Used by
    the multi-pod example and tests; the pjit train path keeps XLA-native
    reductions (this is the explicit-collective alternative for the
    cross-pod DCN hop).
    """
    from jax.experimental.shard_map import shard_map

    def local(params, batch, error_bufs):
        # error buffers carry a leading device axis (sharded over
        # axis_name): strip it inside, restore it on the way out
        ebufs = jax.tree.map(lambda x: x[0], error_bufs)
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress:
            grads, ebufs = compressed_psum(grads, ebufs, axis_name)
        else:
            grads = jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), grads)
        return (jax.lax.pmean(loss, axis_name), grads,
                jax.tree.map(lambda x: x[None], ebufs))

    def apply(params, batch, error_bufs):
        sm = shard_map(
            local, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), params),
                      jax.tree.map(lambda _: P(axis_name), batch),
                      jax.tree.map(lambda _: P(axis_name), error_bufs)),
            out_specs=(P(),
                       jax.tree.map(lambda _: P(), params),
                       jax.tree.map(lambda _: P(axis_name), error_bufs)),
            check_rep=False)
        return sm(params, batch, error_bufs)

    return apply


def init_error_bufs(params, n_shards: int):
    """Per-shard error-feedback buffers, leading axis = n_shards."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_shards,) + p.shape, jnp.float32), params)
