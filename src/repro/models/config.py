"""Model configuration covering all assigned architecture families.

One frozen dataclass drives the composable LM in :mod:`repro.models.lm`:
dense / MoE / SSM / hybrid decoder-only transformers plus the audio
(multi-codebook) and VLM (image-prefix) backbone variants.

Layer heterogeneity (gemma2's local/global alternation, recurrentgemma's
2-recurrent:1-attention pattern) is expressed as ``layer_pattern``: the
layer stack is ``pattern * n_rep + tail``, the repeated pattern is scanned
with stacked parameters (fast compiles at 26-64 layers), and the tail is
unrolled.
"""

from __future__ import annotations

import dataclasses
import math

# layer kinds
ATTN = "attn"              # global (full causal) attention + MLP
ATTN_LOCAL = "attn_local"  # sliding-window attention + MLP
MOE = "moe"                # attention + mixture-of-experts MLP
MAMBA = "mamba"            # mamba-1 block (attention-free)
RECURRENT = "recurrent"    # griffin recurrent block (RG-LRU + conv)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    layer_pattern: tuple = (ATTN,)
    window_size: int = 0        # sliding window for ATTN_LOCAL layers
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    attn_softcap: float = 0.0   # gemma2: 50.0
    final_softcap: float = 0.0  # gemma2: 30.0
    act: str = "silu"           # mlp activation: silu | gelu
    mlp_gated: bool = True      # SwiGLU/GeGLU vs plain 2-matrix MLP
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    use_post_norm: bool = False # gemma2 sandwich norms
    scale_embeddings: bool = False  # gemma-style sqrt(d) embedding scale
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0
    moe_groups: int = 16    # routing groups (align with data-parallel shards)
    n_experts_pad: int = 0  # pad expert arrays to this count for EP divisibility
    n_heads_pad: int = 0    # pad q heads for TP divisibility (zeroed wo rows)
    # --- SSM (mamba-1) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # --- RG-LRU (griffin) ---
    lru_width: int = 0          # 0 -> d_model
    # --- modality stubs ---
    num_codebooks: int = 0      # musicgen: 4 parallel EnCodec streams
    img_tokens: int = 0         # llava: anyres patch-embedding prefix length
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"

    # ------------------------------------------------------------------ #
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    def layer_types(self) -> list[str]:
        """Concrete per-layer kinds, length n_layers."""
        p = self.layer_pattern
        reps = self.n_layers // len(p)
        tail = self.n_layers - reps * len(p)
        return list(p) * reps + list(p[:tail])

    @property
    def n_rep(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def tail_types(self) -> tuple:
        tail = self.n_layers - self.n_rep * len(self.layer_pattern)
        return tuple(self.layer_pattern[:tail])

    def has_attention(self) -> bool:
        return any(t in (ATTN, ATTN_LOCAL, MOE) for t in self.layer_types())

    def is_subquadratic(self) -> bool:
        """True if no layer materializes O(S) KV growth at full scope...

        Used to gate the long_500k shape: SSM and hybrid (bounded-window
        attention) archs qualify; gemma2 qualifies for *decode* because its
        global layers read a KV cache linearly per token while local layers
        are bounded.  Pure full-attention archs do not.
        """
        types = set(self.layer_types())
        if types <= {MAMBA, RECURRENT}:
            return True
        if ATTN in types or MOE in types:
            return False
        return True  # local-attention only (+ recurrent)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dh = self.d_model, self.head_dim_
        n_q, n_kv = self.n_heads, self.n_kv_heads
        total = 0
        emb = self.vocab_size * d
        if self.num_codebooks:
            emb *= self.num_codebooks
        total += emb
        if not self.tie_embeddings:
            total += d * self.vocab_size * max(self.num_codebooks, 1)
        for t in self.layer_types():
            if t in (ATTN, ATTN_LOCAL, MOE):
                attn = d * (n_q * dh) + 2 * d * (n_kv * dh) + (n_q * dh) * d
                if self.qkv_bias:
                    attn += (n_q + 2 * n_kv) * dh
                total += attn
                mlp_mats = 3 if self.mlp_gated else 2
                if t == MOE:
                    total += d * self.n_experts  # router
                    e = self.n_experts + self.n_shared_experts
                    total += e * 3 * d * self.d_expert
                else:
                    total += mlp_mats * d * self.d_ff
                total += 2 * d  # norms
            elif t == MAMBA:
                di, n, dtr = self.d_inner, self.ssm_state, self.dt_rank
                total += d * 2 * di + di * self.ssm_conv + di * (dtr + 2 * n)
                total += dtr * di + di * n + di + di * d + d
            elif t == RECURRENT:
                w = self.lru_width_
                mlp_mats = 3 if self.mlp_gated else 2
                total += 2 * d * w + w * self.ssm_conv + 2 * w * w \
                    + w * d + 2 * d  # in x2, conv, gates, out, norms
                total += mlp_mats * d * self.d_ff  # griffin MLP block
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        dead_experts = self.n_experts - self.top_k
        per_expert = 3 * d * self.d_expert
        return self.param_count() - self.n_layers * dead_experts * per_expert
