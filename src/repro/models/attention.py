"""Attention layers: chunked online-softmax (train/prefill) + cached decode.

The chunked implementation is the production jnp path: it bounds live
memory to O(S * chunk) per head-batch instead of O(S^2), lowers on every
backend (the Pallas kernel in repro.kernels.flash_attention is the TPU
drop-in with identical semantics), and exposes the same GQA / sliding
window / softcap features.

Sharding strategy (explicit constraints; see EXPERIMENTS.md SPerf for the
measurement that motivated them): attention operates on the FLAT q-head
axis, sharded over 'model' when the head count divides the axis;
k/v stay GQA-compressed in memory and repeat per chunk at compute time
(the per-chunk repeat is free when heads are sharded — each shard
materializes only its own groups).  When q-heads don't divide the model
axis (gemma2's 8, qwen's 40, llava's 56 on a 16-way axis), attention
computes replicated over 'model' — the honest fallback; GSPMD's
alternative (sharding head_dim) all-reduces every score chunk, measured
at 100x the traffic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.constrain import constrain, model_axis_size
from repro.models.layers import dtype_of, rope

NEG_INF = -1e30


def init_attention(cfg, key):
    d, dh = cfg.d_model, cfg.head_dim_
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    np_ = max(cfg.n_heads_pad, nq)   # padded q heads (zeroed wo rows)
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    dt = dtype_of(cfg)
    wo = jax.random.normal(ks[3], (nq * dh, d)) * (nq * dh) ** -0.5
    if np_ > nq:
        wo = jnp.concatenate([wo, jnp.zeros(((np_ - nq) * dh, d))], axis=0)
    p = {
        "wq": (jax.random.normal(ks[0], (d, np_ * dh)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, nkv * dh)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, nkv * dh)) * s).astype(dt),
        "wo": wo.astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((np_ * dh,), dt)
        p["bk"] = jnp.zeros((nkv * dh,), dt)
        p["bv"] = jnp.zeros((nkv * dh,), dt)
    return p


def _nq(cfg):
    return max(cfg.n_heads_pad, cfg.n_heads)


def _head_axis(cfg):
    """'model' if the (padded) q-head axis divides the model mesh axis,
    else None (replicated attention fallback)."""
    m = model_axis_size()
    if m and _nq(cfg) % m == 0:
        return "model"
    return None


def _project_qkv(x, p, cfg, positions):
    b, s, _ = x.shape
    dh = cfg.head_dim_
    ha = _head_axis(cfg)
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(b, s, _nq(cfg), dh), "dp", None, ha, None)
    # k/v stay GQA-compressed and replicated over 'model' (small)
    k = constrain(k.reshape(b, s, cfg.n_kv_heads, dh), "dp", None, None, None)
    v = constrain(v.reshape(b, s, cfg.n_kv_heads, dh), "dp", None, None, None)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q, k, v, *, causal: bool, window: int, softcap: float,
                      head_axis=None, q_chunk: int = 1024,
                      kv_chunk: int = 1024):
    """Online-softmax attention, chunked on both sequence axes.

    q: (B, Sq, Hq, Dh); k/v: (B, Skv, Hkv, Dh).  window <= 0 disables the
    sliding-window mask.  Returns (B, Sq, Hq, Dh) in q.dtype.
    """
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = dh ** -0.5
    ha = head_axis

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    pq = -sq % q_chunk
    pkv = -skv % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0))) if pkv else k
    vp = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0))) if pkv else v
    nq, nkv = (sq + pq) // q_chunk, (skv + pkv) // kv_chunk

    qs = jnp.moveaxis(qp.reshape(b, nq, q_chunk, hq, dh), 1, 0)
    ks = jnp.moveaxis(kp.reshape(b, nkv, kv_chunk, hkv, dh), 1, 0)
    vs = jnp.moveaxis(vp.reshape(b, nkv, kv_chunk, hkv, dh), 1, 0)
    offset = skv - sq  # end-aligned positions

    def q_block(qi, q_c):
        q_pos = qi * q_chunk + jnp.arange(q_chunk) + offset
        q_c = constrain(q_c, "dp", None, ha, None)

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_c, v_c = inp
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            # per-chunk GQA expansion: with heads sharded each device
            # materializes only its own groups' keys
            kr = constrain(jnp.repeat(k_c, group, axis=2), "dp", None, ha, None)
            vr = constrain(jnp.repeat(v_c, group, axis=2), "dp", None, ha, None)
            s_blk = jnp.einsum("bqhd,bkhd->bhqk", q_c.astype(jnp.float32),
                               kr.astype(jnp.float32)) * scale
            s_blk = constrain(s_blk, "dp", ha, None, None)
            if softcap > 0:
                s_blk = softcap * jnp.tanh(s_blk / softcap)
            mask = k_pos[None, :] < skv
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window > 0:
                mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
            s_blk = jnp.where(mask[None, None], s_blk, NEG_INF)
            m_cur = jnp.max(s_blk, axis=-1)
            m_new = jnp.maximum(m, m_cur)
            p_blk = jnp.exp(s_blk - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + p_blk.sum(axis=-1)
            acc_new = alpha[..., None] * acc + jnp.einsum(
                "bhqk,bkhd->bhqd", p_blk, vr.astype(jnp.float32))
            acc_new = constrain(acc_new, "dp", ha, None, None)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hq, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hq, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nkv), ks, vs))
        safe = jnp.where(l == 0.0, 1.0, l)
        out = acc / safe[..., None]                     # (B, Hq, C, Dh)
        return jnp.moveaxis(out, 1, 2)                  # (B, C, Hq, Dh)

    outs = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qs))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq + pq, hq, dh)
    return out[:, :sq].astype(q.dtype)


def attention_block(x, p, cfg, positions, *, window: int):
    """Full attention sublayer for train/prefill (no cache)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(x, p, cfg, positions)
    out = chunked_attention(q, k, v, causal=True, window=window,
                            softcap=cfg.attn_softcap, head_axis=_head_axis(cfg))
    return out.reshape(b, s, -1) @ p["wo"]


def attention_prefill(x, p, cfg, positions, *, window: int, cache_len: int):
    """Prefill: returns output and the (padded) KV cache to serve from."""
    b, s, d = x.shape
    q, k, v = _project_qkv(x, p, cfg, positions)
    out = chunked_attention(q, k, v, causal=True, window=window,
                            softcap=cfg.attn_softcap, head_axis=_head_axis(cfg))
    pad = cache_len - s
    assert pad >= 0, (
        f"cache_len {cache_len} must cover the full prompt ({s} tokens, "
        "including any image-prefix embeddings)")
    k_cache = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v_cache = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out.reshape(b, s, -1) @ p["wo"], (k_cache, v_cache)


def attention_decode(x, p, cfg, cache, cur_len, *, window: int):
    """Single-token decode against a static KV cache.

    x: (B, 1, D); cache: (k, v) each (B, Smax, Hkv, Dh); cur_len: scalar.
    The score einsum keeps the kv SEQUENCE axis contracted last so a
    sequence-sharded cache (long-context mode) yields partial softmax
    stats combined by small collectives rather than a cache all-gather.
    """
    b, one, d = x.shape
    dh = cfg.head_dim_
    group = _nq(cfg) // cfg.n_kv_heads
    positions = jnp.full((b, 1), cur_len, jnp.int32)
    q, k_new, v_new = _project_qkv(x, p, cfg, positions)
    k_cache, v_cache = cache
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new.astype(k_cache.dtype),
                                           (0, cur_len, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new.astype(v_cache.dtype),
                                           (0, cur_len, 0, 0))
    smax = k_cache.shape[1]
    # sliding-window layers only ever attend to the trailing `window`
    # positions: slice a static-size view instead of streaming the whole
    # cache (SPerf iteration C — the decode memory-term optimization)
    if window > 0 and window < smax:
        start = jnp.clip(cur_len - window + 1, 0, smax - window)
        k_att = jax.lax.dynamic_slice_in_dim(k_cache, start, window, axis=1)
        v_att = jax.lax.dynamic_slice_in_dim(v_cache, start, window, axis=1)
        k_pos = start + jnp.arange(window)
    else:
        k_att, v_att = k_cache, v_cache
        k_pos = jnp.arange(smax)
    # scores on GQA-compressed heads: (B, Hkv, G, 1, S_att)
    qg = q.reshape(b, 1, cfg.n_kv_heads, group, dh).astype(jnp.float32)
    s_all = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                       k_att.astype(jnp.float32)) * dh ** -0.5
    if cfg.attn_softcap > 0:
        s_all = cfg.attn_softcap * jnp.tanh(s_all / cfg.attn_softcap)
    mask = k_pos <= cur_len
    if window > 0:
        mask = mask & (k_pos > cur_len - window)
    s_all = jnp.where(mask[None, None, None, None, :], s_all, NEG_INF)
    w = jax.nn.softmax(s_all, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v_att.astype(jnp.float32))
    out = out.reshape(b, 1, -1).astype(x.dtype) @ p["wo"]
    return out, (k_cache, v_cache)
