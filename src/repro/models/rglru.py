"""Griffin recurrent block (recurrentgemma): conv + RG-LRU gated recurrence.

Block layout (Griffin, arXiv:2402.19427 fig. 2): two input branches —
GeLU gate branch and a temporal branch (causal conv1d -> RG-LRU) — merged
multiplicatively, then projected out.  The RG-LRU recurrence:

    r_t = sigmoid(W_a y_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x y_t + b_x)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * y_t)

Train/prefill uses the associative scan (repro.kernels.rglru_scan.ref,
TPU drop-in kernel available); decode is the single-step update.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.ref import rglru_ref
from repro.models.layers import causal_conv1d, dtype_of

RGLRU_C = 8.0


def init_recurrent(cfg, key):
    d, w = cfg.d_model, cfg.lru_width_
    kc = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    s = d ** -0.5
    return {
        "in_gate": (jax.random.normal(ks[0], (d, w)) * s).astype(dt),
        "in_lin": (jax.random.normal(ks[1], (d, w)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (w, kc)) * kc ** -0.5).astype(dt),
        "wa": (jax.random.normal(ks[3], (w, w)) * w ** -0.5).astype(dt),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": (jax.random.normal(ks[4], (w, w)) * w ** -0.5).astype(dt),
        "bx": jnp.zeros((w,), jnp.float32),
        # Lambda init so a^c spans ~(0.9, 0.999) (Griffin appendix)
        "lam": jnp.log(jnp.expm1(
            jnp.linspace(0.35, 0.9, w).astype(jnp.float32))),
        "out_proj": (jax.random.normal(ks[5], (w, d)) * w ** -0.5).astype(dt),
    }


def _gates(y, p):
    r = jax.nn.sigmoid((y @ p["wa"]).astype(jnp.float32) + p["ba"])
    i = jax.nn.sigmoid((y @ p["wx"]).astype(jnp.float32) + p["bx"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    return a, i


def recurrent_block(x, p, cfg):
    """Train/prefill forward.  x: (B, S, D) -> (B, S, D)."""
    gate = jax.nn.gelu((x @ p["in_gate"]).astype(jnp.float32)).astype(x.dtype)
    y = x @ p["in_lin"]
    y, _ = causal_conv1d(y, p["conv_w"])
    a, i = _gates(y, p)
    u = i * y.astype(jnp.float32)
    h = rglru_ref(u, a)                                   # (B, S, W) f32
    out = (h.astype(x.dtype) * gate) @ p["out_proj"]
    return out


def init_recurrent_state(cfg, batch, dtype=jnp.float32):
    w = cfg.lru_width_
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def recurrent_decode(x, p, cfg, state):
    """Single-token decode.  x: (B, 1, D) -> (out, new_state)."""
    gate = jax.nn.gelu((x @ p["in_gate"]).astype(jnp.float32)).astype(x.dtype)
    y = x @ p["in_lin"]
    y, conv_state = causal_conv1d(y, p["conv_w"], state["conv"])
    a, i = _gates(y, p)                                   # (B, 1, W)
    u = i[:, 0] * y[:, 0].astype(jnp.float32)
    a0 = a[:, 0]
    h = a0 * state["h"] + jnp.sqrt(jnp.maximum(1.0 - a0 * a0, 0.0)) * u
    out = (h[:, None].astype(x.dtype) * gate) @ p["out_proj"]
    return out, {"conv": conv_state, "h": h}
