"""Mixture-of-experts MLP: top-k token-choice, grouped capacity dispatch.

GShard-style formulation (arXiv:2006.16668): tokens are partitioned into
``n_groups`` independent routing groups, each with its own capacity
``C_g = ceil(cf * k * T_g / E)``.  The position-in-expert cumsum runs
*within* a group, so when groups align with the data-parallel sharding the
routing bookkeeping stays device-local and the only cross-device traffic
is the (groups <-> experts) all-to-all of the dispatch buffers — the
canonical TPU MoE pattern.  (A global-cumsum variant was measured at
~40 s of collective time per step on the 256-chip dry-run — see
EXPERIMENTS.md SPerf — which is why groups are the baseline.)

Tokens overflowing an expert's per-group capacity are dropped (GShard
semantics).  Supports OLMoE (64 routed, top-8, gate renormalization) and
Qwen2-MoE (60 routed top-4 + always-on shared experts).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.constrain import constrain
from repro.models.layers import act_fn, dtype_of, init_mlp, mlp

CAPACITY_FACTOR = 1.25


def init_moe(cfg, key):
    d, de = cfg.d_model, cfg.d_expert
    e = cfg.n_experts
    ep = max(cfg.n_experts_pad, e)  # dummy experts make E divide the EP axis
    ks = jax.random.split(key, 5)
    dt = dtype_of(cfg)
    s_in, s_out = d ** -0.5, de ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "gate": (jax.random.normal(ks[1], (ep, d, de)) * s_in).astype(dt),
        "up": (jax.random.normal(ks[2], (ep, d, de)) * s_in).astype(dt),
        "down": (jax.random.normal(ks[3], (ep, de, d)) * s_out).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], d_ff=cfg.n_shared_experts * cfg.d_expert)
        p["shared_gate"] = jnp.zeros((cfg.d_model,), dt)  # qwen2moe gating proj
    return p


def _n_groups(cfg, t: int) -> int:
    g = max(int(getattr(cfg, "moe_groups", 16) or 16), 1)
    while t % g:
        g //= 2
    return max(g, 1)


def moe_mlp(x, p, cfg, capacity_factor: float = CAPACITY_FACTOR):
    """x: (B, S, D) -> ((B, S, D), aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    ep = max(cfg.n_experts_pad, e)
    t = b * s
    g = _n_groups(cfg, t)
    tl = t // g                                    # tokens per group
    xt = constrain(x.reshape(g, tl, d), "dp", None, None)

    logits = xt.astype(jnp.float32) @ p["router"]  # (g, tl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)           # (g, tl, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    cap = int(capacity_factor * k * tl / e) + 1

    flat_e = idx.reshape(g, tl * k)                # (g, tl*k)
    flat_g = gates.reshape(g, tl * k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tl), k)[None], (g, tl * k))
    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (g, tl*k, E)
    rank = (jnp.cumsum(oh, axis=1) - oh)[
        jnp.arange(g)[:, None], jnp.arange(tl * k)[None, :], flat_e]
    keep = rank < cap
    safe_e = jnp.where(keep, flat_e, 0)
    safe_r = jnp.where(keep, rank, 0)

    # dispatch: (g, E_pad, C, D) — scattered within each group (kept local
    # to the data shard owning the group; the einsum below is the
    # canonical groups<->experts all-to-all boundary).  Router indices
    # never point at dummy experts, so padded rows stay zero.
    gi = jnp.arange(g)[:, None]
    gathered = jnp.where(keep[..., None], xt[gi, flat_tok], 0).astype(x.dtype)
    buf = jnp.zeros((g, ep, cap, d), x.dtype)
    buf = buf.at[gi, safe_e, safe_r].add(gathered)

    a = act_fn(cfg.act)
    h = a(jnp.einsum("gecd,edf->gecf", buf, p["gate"])) \
        * jnp.einsum("gecd,edf->gecf", buf, p["up"])
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["down"])   # (g, E, C, D)

    contrib = out_buf[gi, safe_e, safe_r] * flat_g[..., None].astype(out_buf.dtype) \
        * keep[..., None].astype(out_buf.dtype)
    out = jnp.zeros((g, tl, d), jnp.float32).at[gi, flat_tok].add(
        contrib.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(b, s, d)

    if cfg.n_shared_experts:
        xf = x.reshape(t, d)
        gate_sh = jax.nn.sigmoid((xf @ p["shared_gate"]).astype(jnp.float32))
        out = out + (mlp(xf, p["shared"], cfg)
                     * gate_sh[:, None].astype(x.dtype)).reshape(b, s, d)

    # Switch-style load-balance aux loss
    me = probs.mean(axis=(0, 1))                           # (E,)
    ce = oh.sum(axis=(0, 1)).astype(jnp.float32) / (t * k)
    aux = e * jnp.sum(me * ce)
    return out, aux
