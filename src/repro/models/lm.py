"""The composable decoder-only LM covering all assigned architectures.

Layer stack = ``layer_pattern * n_rep + tail``.  The repeated pattern is a
*super-block* scanned with stacked parameters (lax.scan keeps HLO size and
compile time flat in depth — essential for 64-layer configs on the
dry-run), optionally rematerialized.  The tail (pattern remainder, e.g.
recurrentgemma's trailing recurrent layers) is unrolled.

Entry points:
    init_params / abstract_params      parameters (concrete / eval_shape)
    forward_train                      full-sequence logits-loss path
    prefill                            forward + KV/state cache construction
    decode_step                        single-token cached decode
    loss_fn                            seq-chunked CE (never materializes
                                       the full (B, S, V) logits tensor)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models import rglru as rgl
from repro.models.config import ATTN, ATTN_LOCAL, MAMBA, MOE, RECURRENT, ModelConfig
from repro.models.layers import apply_norm, dtype_of, init_mlp, init_norm, mlp


# --------------------------------------------------------------------- #
# per-layer init / apply
# --------------------------------------------------------------------- #
def init_layer(cfg: ModelConfig, key, kind: str):
    ks = jax.random.split(key, 4)
    if kind in (ATTN, ATTN_LOCAL):
        p = {"norm1": init_norm(cfg, ks[0]),
             "attn": attn.init_attention(cfg, ks[1]),
             "norm2": init_norm(cfg, ks[2]),
             "mlp": init_mlp(cfg, ks[3])}
    elif kind == MOE:
        p = {"norm1": init_norm(cfg, ks[0]),
             "attn": attn.init_attention(cfg, ks[1]),
             "norm2": init_norm(cfg, ks[2]),
             "moe": moe_mod.init_moe(cfg, ks[3])}
    elif kind == MAMBA:
        p = {"norm1": init_norm(cfg, ks[0]),
             "mamba": mam.init_mamba(cfg, ks[1])}
    elif kind == RECURRENT:
        p = {"norm1": init_norm(cfg, ks[0]),
             "rec": rgl.init_recurrent(cfg, ks[1]),
             "norm2": init_norm(cfg, ks[2]),
             "mlp": init_mlp(cfg, ks[3])}
    else:
        raise ValueError(kind)
    if cfg.use_post_norm:
        p["post_norm1"] = init_norm(cfg, jax.random.fold_in(key, 7))
        p["post_norm2"] = init_norm(cfg, jax.random.fold_in(key, 8))
    return p


def _apply_layer(x, p, cfg: ModelConfig, kind: str, positions):
    """Train/prefill sub-layer application (no cache)."""
    window = cfg.window_size if kind == ATTN_LOCAL else 0
    aux = jnp.zeros((), jnp.float32)
    if kind in (ATTN, ATTN_LOCAL, MOE):
        h = attn.attention_block(apply_norm(x, p["norm1"], cfg), p["attn"],
                                 cfg, positions, window=window)
        if cfg.use_post_norm:
            h = apply_norm(h, p["post_norm1"], cfg)
        x = x + h
        y = apply_norm(x, p["norm2"], cfg)
        if kind == MOE:
            h, aux = moe_mod.moe_mlp(y, p["moe"], cfg)
        else:
            h = mlp(y, p["mlp"], cfg)
        if cfg.use_post_norm:
            h = apply_norm(h, p["post_norm2"], cfg)
        x = x + h
    elif kind == MAMBA:
        x = x + mam.mamba_block(apply_norm(x, p["norm1"], cfg), p["mamba"], cfg)
    elif kind == RECURRENT:
        x = x + rgl.recurrent_block(apply_norm(x, p["norm1"], cfg), p["rec"], cfg)
        x = x + mlp(apply_norm(x, p["norm2"], cfg), p["mlp"], cfg)
    return x, aux


# --------------------------------------------------------------------- #
# parameters
# --------------------------------------------------------------------- #
def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    d, v = cfg.d_model, cfg.vocab_size
    ncb = max(cfg.num_codebooks, 1)
    if cfg.num_codebooks:
        embed = (jax.random.normal(ks[0], (ncb, v, d)) * d ** -0.5).astype(dt)
    else:
        embed = (jax.random.normal(ks[0], (v, d)) * d ** -0.5).astype(dt)

    def init_stacked(kind, pos):
        keys = jax.random.split(jax.random.fold_in(ks[1], pos), cfg.n_rep)
        return jax.vmap(lambda k: init_layer(cfg, k, kind))(keys)

    stack = tuple(init_stacked(kind, i)
                  for i, kind in enumerate(cfg.layer_pattern))
    tail = tuple(init_layer(cfg, jax.random.fold_in(ks[2], i), kind)
                 for i, kind in enumerate(cfg.tail_types))
    params = {"embed": embed, "stack": stack, "tail": tail,
              "final_norm": init_norm(cfg, ks[3])}
    if not cfg.tie_embeddings:
        if cfg.num_codebooks:
            params["head"] = (jax.random.normal(ks[4], (ncb, d, v)) * d ** -0.5).astype(dt)
        else:
            params["head"] = (jax.random.normal(ks[4], (d, v)) * d ** -0.5).astype(dt)
    return params


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocating (for the dry-run)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg), jax.random.PRNGKey(0))


# --------------------------------------------------------------------- #
# embedding / head
# --------------------------------------------------------------------- #
def embed_tokens(params, tokens, cfg: ModelConfig, img_embeds=None):
    if cfg.num_codebooks:
        # tokens: (B, S, K) -> sum of per-codebook embeddings
        parts = [jnp.take(params["embed"][k], tokens[..., k], axis=0)
                 for k in range(cfg.num_codebooks)]
        x = sum(parts)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(x.dtype), x], axis=1)
    return x


def _head_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        e = params["embed"]
        return jnp.swapaxes(e, -1, -2) if cfg.num_codebooks else e.T
    return params["head"]


def logits_for(params, x, cfg: ModelConfig):
    """Logits for a (B, S', D) activation slice."""
    h = _head_matrix(params, cfg)
    if cfg.num_codebooks:
        out = jnp.einsum("bsd,kdv->bskv", x, h)
    else:
        out = x @ h
    out = out.astype(jnp.float32)
    if cfg.final_softcap > 0:
        out = cfg.final_softcap * jnp.tanh(out / cfg.final_softcap)
    return out


# --------------------------------------------------------------------- #
# stack runners
# --------------------------------------------------------------------- #
def run_stack(x, params, cfg: ModelConfig, positions, remat: bool = True):
    """Apply all layers (train/prefill, no cache).  Returns (x, aux_sum)."""

    def superblock(carry, block_params):
        x, aux = carry
        for kind, p in zip(cfg.layer_pattern, block_params):
            x, a = _apply_layer(x, p, cfg, kind, positions)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(superblock) if remat else superblock
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["stack"])
    for kind, p in zip(cfg.tail_types, params["tail"]):
        x, a = _apply_layer(x, p, cfg, kind, positions)
        aux = aux + a
    return x, aux


def forward_train(params, tokens, cfg: ModelConfig, img_embeds=None,
                  remat: bool = True):
    """Full-sequence activations (pre-head).  Returns (x, aux)."""
    x = embed_tokens(params, tokens, cfg, img_embeds)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, aux = run_stack(x, params, cfg, positions, remat=remat)
    x = apply_norm(x, params["final_norm"], cfg)
    return x, aux


# --------------------------------------------------------------------- #
# loss (sequence-chunked CE; vocab stays sharded)
# --------------------------------------------------------------------- #
def loss_fn(params, batch, cfg: ModelConfig, seq_chunk: int = 512,
            remat: bool = True):
    tokens = batch["tokens"]
    labels = batch["labels"]
    img = batch.get("img_embeds")
    x, aux = forward_train(params, tokens, cfg, img_embeds=img, remat=remat)
    if img is not None:
        x = x[:, img.shape[1]:]  # loss only over text positions
    # next-token shift
    x = x[:, :-1]
    y = labels[:, 1:] if cfg.num_codebooks == 0 else labels[:, 1:, :]
    b, s = x.shape[:2]
    seq_chunk = min(seq_chunk, s)
    pad = -s % seq_chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        y = jnp.pad(y, ((0, 0), (0, pad)) + ((0, 0),) * (y.ndim - 2),
                    constant_values=-1)
    nc = (s + pad) // seq_chunk
    xc = jnp.moveaxis(x.reshape(b, nc, seq_chunk, -1), 1, 0)
    yc = jnp.moveaxis(y.reshape((b, nc, seq_chunk) + y.shape[2:]), 1, 0)

    def chunk_loss(carry, inp):
        xi, yi = inp
        lg = logits_for(params, xi, cfg)               # (B, C, [K,] V)
        lse = jax.nn.logsumexp(lg, axis=-1)
        valid = yi >= 0
        tgt = jnp.take_along_axis(lg, jnp.maximum(yi, 0)[..., None],
                                  axis=-1)[..., 0]
        nll = jnp.where(valid, lse - tgt, 0.0)
        return (carry[0] + nll.sum(), carry[1] + valid.sum()), None

    body = jax.checkpoint(chunk_loss) if remat else chunk_loss
    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xc, yc))
    loss = tot / jnp.maximum(cnt, 1)
    return loss + 0.01 * aux


# --------------------------------------------------------------------- #
# serving: cache init / prefill / decode
# --------------------------------------------------------------------- #
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Zeroed decode state for every layer, stacked like the params."""
    def one(kind):
        if kind in (ATTN, ATTN_LOCAL, MOE):
            shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim_)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if kind == MAMBA:
            return mam.init_mamba_state(cfg, batch)
        if kind == RECURRENT:
            return rgl.init_recurrent_state(cfg, batch)
        raise ValueError(kind)

    def stacked(kind):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_rep,) + a.shape),
                            one(kind))

    return {"stack": tuple(stacked(k) for k in cfg.layer_pattern),
            "tail": tuple(one(k) for k in cfg.tail_types)}


def _decode_layer(x, p, cfg, kind, cache, cur_len):
    window = cfg.window_size if kind == ATTN_LOCAL else 0
    if kind in (ATTN, ATTN_LOCAL, MOE):
        h, (k, v) = attn.attention_decode(
            apply_norm(x, p["norm1"], cfg), p["attn"], cfg,
            (cache["k"], cache["v"]), cur_len, window=window)
        if cfg.use_post_norm:
            h = apply_norm(h, p["post_norm1"], cfg)
        x = x + h
        y = apply_norm(x, p["norm2"], cfg)
        if kind == MOE:
            h, _ = moe_mod.moe_mlp(y, p["moe"], cfg)
        else:
            h = mlp(y, p["mlp"], cfg)
        if cfg.use_post_norm:
            h = apply_norm(h, p["post_norm2"], cfg)
        x = x + h
        return x, {"k": k, "v": v}
    if kind == MAMBA:
        h, st = mam.mamba_decode(apply_norm(x, p["norm1"], cfg), p["mamba"],
                                 cfg, cache)
        return x + h, st
    if kind == RECURRENT:
        h, st = rgl.recurrent_decode(apply_norm(x, p["norm1"], cfg), p["rec"],
                                     cfg, cache)
        x = x + h
        x = x + mlp(apply_norm(x, p["norm2"], cfg), p["mlp"], cfg)
        return x, st
    raise ValueError(kind)


def decode_step(params, tokens, cache, cur_len, cfg: ModelConfig):
    """One new token for every sequence in the batch.

    tokens: (B, 1) or (B, 1, K); cur_len: scalar int32.
    Returns (logits (B, 1, [K,] V), new_cache).
    """
    x = embed_tokens(params, tokens, cfg)

    def superblock(x, inp):
        block_params, block_cache = inp
        new_cache = []
        for kind, p, c in zip(cfg.layer_pattern, block_params, block_cache):
            x, nc = _decode_layer(x, p, cfg, kind, c, cur_len)
            new_cache.append(nc)
        return x, tuple(new_cache)

    x, new_stack = jax.lax.scan(superblock, x,
                                (params["stack"], cache["stack"]))
    new_tail = []
    for kind, p, c in zip(cfg.tail_types, params["tail"], cache["tail"]):
        x, nc = _decode_layer(x, p, cfg, kind, c, cur_len)
        new_tail.append(nc)
    x = apply_norm(x, params["final_norm"], cfg)
    logits = logits_for(params, x, cfg)
    return logits, {"stack": new_stack, "tail": tuple(new_tail)}


def prefill(params, tokens, cfg: ModelConfig, max_len: int, img_embeds=None):
    """Process the prompt; returns (last-token logits, cache, prompt_len).

    Built on the train-path layers plus per-layer state extraction; the
    attention caches are padded to ``max_len``.
    """
    x = embed_tokens(params, tokens, cfg, img_embeds)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def prefill_layer(x, p, kind):
        window = cfg.window_size if kind == ATTN_LOCAL else 0
        if kind in (ATTN, ATTN_LOCAL, MOE):
            h, (k, v) = attn.attention_prefill(
                apply_norm(x, p["norm1"], cfg), p["attn"], cfg, positions,
                window=window, cache_len=max_len)
            if cfg.use_post_norm:
                h = apply_norm(h, p["post_norm1"], cfg)
            x = x + h
            y = apply_norm(x, p["norm2"], cfg)
            h = moe_mod.moe_mlp(y, p["moe"], cfg)[0] if kind == MOE \
                else mlp(y, p["mlp"], cfg)
            if cfg.use_post_norm:
                h = apply_norm(h, p["post_norm2"], cfg)
            return x + h, {"k": k, "v": v}
        if kind == MAMBA:
            y = apply_norm(x, p["norm1"], cfg)
            h, st = _mamba_prefill(y, p["mamba"], cfg)
            return x + h, st
        if kind == RECURRENT:
            y = apply_norm(x, p["norm1"], cfg)
            h, st = _recurrent_prefill(y, p["rec"], cfg)
            x = x + h
            return x + mlp(apply_norm(x, p["norm2"], cfg), p["mlp"], cfg), st
        raise ValueError(kind)

    def superblock(x, block_params):
        caches = []
        for kind, p in zip(cfg.layer_pattern, block_params):
            x, c = prefill_layer(x, p, kind)
            caches.append(c)
        return x, tuple(caches)

    x, stack_cache = jax.lax.scan(superblock, x, params["stack"])
    tail_cache = []
    for kind, p in zip(cfg.tail_types, params["tail"]):
        x, c = prefill_layer(x, p, kind)
        tail_cache.append(c)
    x = apply_norm(x, params["final_norm"], cfg)
    logits = logits_for(params, x[:, -1:], cfg)
    return logits, {"stack": stack_cache, "tail": tuple(tail_cache)}


def _mamba_prefill(y, p, cfg):
    """Mamba block over the prompt + final state for decode continuation."""
    out = mam.mamba_block(y, p, cfg)
    # final conv state: last K-1 pre-activation conv inputs
    u = (y @ p["in_proj"])[..., :cfg.d_inner]
    conv = u[:, -(cfg.ssm_conv - 1):, :]
    # final ssm state: replay the scan cheaply on the last chunk only is
    # incorrect in general; recompute exactly with a scan that keeps only h.
    from repro.models.layers import causal_conv1d
    uc, _ = causal_conv1d(u, p["conv_w"])
    uc = jax.nn.silu(uc)
    delta, b_in, c_in = mam._ssm_inputs(uc, p, cfg)
    A = -jnp.exp(p["A_log"])

    def step(h, xs):
        u_t, d_t, b_t = xs
        coef = jnp.exp(d_t[..., None] * A[None])
        return coef * h + (d_t * u_t)[..., None] * b_t[:, None, :], None

    h0 = jnp.zeros((y.shape[0], cfg.d_inner, cfg.ssm_state), jnp.float32)
    h, _ = jax.lax.scan(step, h0, (jnp.moveaxis(uc.astype(jnp.float32), 1, 0),
                                   jnp.moveaxis(delta.astype(jnp.float32), 1, 0),
                                   jnp.moveaxis(b_in.astype(jnp.float32), 1, 0)))
    return out, {"conv": conv.astype(jnp.float32), "ssm": h}


def _recurrent_prefill(y, p, cfg):
    from repro.models.layers import causal_conv1d
    gate = jax.nn.gelu((y @ p["in_gate"]).astype(jnp.float32)).astype(y.dtype)
    z = y @ p["in_lin"]
    zc, conv = causal_conv1d(z, p["conv_w"])
    a, i = rgl._gates(zc, p)
    u = i * zc.astype(jnp.float32)
    h = rgl.rglru_ref(u, a)
    out = (h.astype(y.dtype) * gate) @ p["out_proj"]
    return out, {"conv": conv.astype(jnp.float32), "h": h[:, -1]}
