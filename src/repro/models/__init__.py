"""Composable model definitions for all assigned architectures."""

from repro.models.config import (
    ATTN,
    ATTN_LOCAL,
    MAMBA,
    MOE,
    RECURRENT,
    ModelConfig,
)
from repro.models.lm import (
    abstract_params,
    decode_step,
    forward_train,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

__all__ = [
    "ATTN", "ATTN_LOCAL", "MAMBA", "MOE", "RECURRENT", "ModelConfig",
    "abstract_params", "decode_step", "forward_train", "init_cache",
    "init_params", "loss_fn", "prefill",
]
