"""Shared building blocks: norms, activations, RoPE, MLP, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


def rms_norm(x, scale, eps: float):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p, cfg):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def init_norm(cfg, key):
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), dtype_of(cfg)),
                "bias": jnp.zeros((d,), dtype_of(cfg))}
    return {"scale": jnp.zeros((d,), dtype_of(cfg))}


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #
def rope(x, positions, theta: float):
    """Apply rotary embeddings.

    x: (..., S, H, Dh) with Dh even; positions: (..., S) int32.
    """
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# gated MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------- #
def init_mlp(cfg, key, d_ff: int | None = None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    dt = dtype_of(cfg)
    p = {
        "up": (jax.random.normal(k2, (d, f)) * s_in).astype(dt),
        "down": (jax.random.normal(k3, (f, d)) * s_out).astype(dt),
    }
    if cfg.mlp_gated:
        p["gate"] = (jax.random.normal(k1, (d, f)) * s_in).astype(dt)
    return p


def mlp(x, p, cfg):
    a = act_fn(cfg.act)
    if cfg.mlp_gated:
        h = a(x @ p["gate"]) * (x @ p["up"])
    else:
        h = a(x @ p["up"])
    return h @ p["down"]


def causal_conv1d(x, w, state=None):
    """Depthwise causal temporal conv.

    x: (B, S, D); w: (D, K).  If ``state`` is given — (B, K-1, D), the
    trailing inputs of the previous chunk — returns (y, new_state) for
    streaming decode; otherwise zero-history.
    """
    b, s, d = x.shape
    k = w.shape[1]
    if state is None:
        state = jnp.zeros((b, k - 1, d), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)        # (B, S+K-1, D)
    y = jnp.zeros((b, s, d), jnp.float32)
    for i in range(k):
        y = y + xx[:, i:i + s, :].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    new_state = xx[:, -(k - 1):, :] if k > 1 else jnp.zeros((b, 0, d), x.dtype)
    return y.astype(x.dtype), new_state
