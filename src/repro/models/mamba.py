"""Mamba-1 block (falcon-mamba): gated selective-state-space layer.

Train/prefill path: chunked scan — ``lax.scan`` over sequence chunks
carrying the (B, Di, N) state, associative work inside each chunk done by
the sequential reference (CPU lowering) or the Pallas kernel (TPU).
Memory stays O(chunk * Di * N) instead of O(S * Di * N).

Decode path: single-step state update (the SSM recurrence evaluated once),
carrying (conv_state, ssm_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.ref import selective_scan_ref
from repro.models.layers import causal_conv1d, dtype_of


def init_mamba(cfg, key):
    d, di, n, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    kc = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg)
    s = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (di, kc)) * kc ** -0.5).astype(dt),
        "x_proj": (jax.random.normal(ks[2], (di, dtr + 2 * n)) * di ** -0.5).astype(dt),
        "dt_proj": (jax.random.normal(ks[3], (dtr, di)) * dtr ** -0.5).astype(dt),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (di,)) * 0.099 + 0.001, 1e-4)
        )).astype(jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (di, d)) * di ** -0.5).astype(dt),
    }


def _ssm_inputs(u, p, cfg):
    """Project conv output to (delta, B, C)."""
    n, dtr = cfg.ssm_state, cfg.dt_rank
    proj = u @ p["x_proj"]                               # (B, S, dtr+2N)
    dt_in, b_in, c_in = jnp.split(proj, [dtr, dtr + n], axis=-1)
    delta = jax.nn.softplus(dt_in @ p["dt_proj"]
                            + p["dt_bias"].astype(dt_in.dtype))
    return delta, b_in, c_in


def mamba_block(x, p, cfg, chunk: int = 512):
    """Train/prefill forward.  x: (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                     # (B, S, Di) each
    u, _ = causal_conv1d(u, p["conv_w"])
    u = jax.nn.silu(u)
    delta, b_in, c_in = _ssm_inputs(u, p, cfg)
    A = -jnp.exp(p["A_log"])

    chunk = min(chunk, s)
    pad = -s % chunk
    if pad:
        u_, d_, b_, c_ = (jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
                          for t in (u, delta, b_in, c_in))
    else:
        u_, d_, b_, c_ = u, delta, b_in, c_in
    nc = (s + pad) // chunk

    def chunk_step(h, inp):
        uc, dc, bc, cc = inp                             # (B, chunk, ...)
        # run the in-chunk scan with injected initial state via a virtual
        # step: fold h into the first step by augmenting B*x with h/coef —
        # simpler: sequential scan with explicit carry
        def step(hh, xs):
            u_t, dt_t, b_t, c_t = xs
            coef = jnp.exp(dt_t[..., None] * A[None])    # (B, Di, N)
            hh = coef * hh + (dt_t * u_t)[..., None] * b_t[:, None, :]
            y = jnp.einsum("bdn,bn->bd", hh, c_t) + p["D"][None] * u_t
            return hh, y
        xs = (jnp.moveaxis(uc.astype(jnp.float32), 1, 0),
              jnp.moveaxis(dc.astype(jnp.float32), 1, 0),
              jnp.moveaxis(bc.astype(jnp.float32), 1, 0),
              jnp.moveaxis(cc.astype(jnp.float32), 1, 0))
        h_new, ys = jax.lax.scan(step, h, xs)
        return h_new, jnp.moveaxis(ys, 0, 1)             # (B, chunk, Di)

    h0 = jnp.zeros((b, di, n), jnp.float32)
    reshape = lambda t: jnp.moveaxis(
        t.reshape(b, nc, chunk, t.shape[-1]), 1, 0)
    _, ys = jax.lax.scan(chunk_step, h0,
                         (reshape(u_), reshape(d_), reshape(b_), reshape(c_)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s + pad, di)[:, :s]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"]


def init_mamba_state(cfg, batch, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), dtype),
    }


def mamba_decode(x, p, cfg, state):
    """Single-token decode.  x: (B, 1, D); returns (out, new_state)."""
    b = x.shape[0]
    xz = x @ p["in_proj"]
    u, z = jnp.split(xz, 2, axis=-1)                     # (B, 1, Di)
    u, conv_state = causal_conv1d(u, p["conv_w"], state["conv"])
    u = jax.nn.silu(u)
    delta, b_in, c_in = _ssm_inputs(u, p, cfg)
    A = -jnp.exp(p["A_log"])
    dt0 = delta[:, 0].astype(jnp.float32)                # (B, Di)
    coef = jnp.exp(dt0[..., None] * A[None])
    h = coef * state["ssm"] + (dt0 * u[:, 0].astype(jnp.float32))[..., None] \
        * b_in[:, 0].astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, c_in[:, 0].astype(jnp.float32)) \
        + p["D"][None] * u[:, 0].astype(jnp.float32)
    y = y[:, None].astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"], {"conv": conv_state, "ssm": h}
