"""Scenario Lab: batched multi-scenario simulation and the fleet-scale
collect → train → evaluate pipeline.

The DIAL paper argues that decentralized agents trained purely on local
metrics react well to *global* conditions — contention, stragglers,
shifting workload mixes.  Exercising that claim needs many scenarios,
not one hand-built simulator per Python process.  This package turns the
PR-2 pure-pytree engine into a scenario machine:

    scenarios.py   declarative :class:`ScenarioSpec` (topology, workload
                   mix, disturbance schedule, seed) + a registry of named
                   scenarios — the paper setups (vpic / bdcats / dlio /
                   filebench) and beyond-paper ones (noisy neighbours,
                   degraded / failing OSTs, bursty arrivals,
                   heterogeneous client links);
    batch.py       stack N structurally-identical scenarios into one
                   batched pytree and ``vmap`` the fused interval scan —
                   hundreds of independent scenarios/seeds per jitted
                   launch, with in-batch DIAL tuning through the existing
                   batched forest scorer;
    campaign.py    offline data collection rebuilt on the batch path:
                   explore θ′ across the whole cell batch, train the
                   read/write GBDTs, save versioned model artifacts
                   (``core/dataset.collect`` stays the sequential oracle);
    evaluate.py    run every registered scenario under tuned vs default
                   vs best-static policies and emit a JSON + markdown
                   report (Table II / Fig. 3 analogs).

CLI:  ``python -m repro.lab {list,evaluate,campaign}`` (``--smoke`` for
the CI-sized sweep).  Disturbances are per-tick exogenous schedules
(:class:`repro.pfs.state.Disturbance`) consumed identically by the numpy
oracle and the JAX scan, so every lab run stays equivalence-testable.
"""

from repro.lab.scenarios import (SCENARIOS, BuiltScenario, DisturbanceEvent,
                                 ScenarioSpec, build, get_scenario,
                                 make_schedule, scenario_names, variants)

# The declarative scenario layer is pure numpy; the batch executor needs
# jax.  Keep the package importable (catalog listing, numpy-oracle runs)
# on jax-free installs by resolving the batch exports lazily (PEP 562).
_BATCH_EXPORTS = ("ScenarioBatch", "BatchEngine", "BatchPort",
                  "stack_scenarios", "run_batch")

__all__ = [
    "ScenarioSpec", "DisturbanceEvent", "BuiltScenario", "SCENARIOS",
    "build", "get_scenario", "scenario_names", "variants", "make_schedule",
    *_BATCH_EXPORTS,
]


def __getattr__(name):
    if name in _BATCH_EXPORTS:
        from repro.lab import batch as _batch
        return getattr(_batch, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
