"""Scenario fuzzing: seeded property-based generation + triaged sweeps.

The 10-entry hand-built registry is exactly the kind of curated coverage
the paper argues against relying on.  This module generates scenarios —
topology sizes, workload mixes (vpic / bdcats / dlio / random /
sequential rows), disturbance compositions over the full event
vocabulary including the Lustre-grounded fault kinds (``ost_fail`` /
``ost_failover`` / ``client_evict``) — **fully deterministically from
one seed**, then sweeps them at scale:

1. :func:`generate_spec` draws one :class:`~repro.lab.scenarios.ScenarioSpec`
   per ``(seed, index)`` pair via an independent ``SeedSequence`` stream,
   so any scenario of a sweep can be regenerated in isolation;
2. :func:`run_sweep` groups the generated specs by padded shape class
   (:func:`~repro.lab.batch.pad_class`) — mixed structures share a
   bucket and ride the ragged pad-and-mask path, collapsing the old
   one-dispatch-per-structure sweep into fewer padded dispatches — and
   runs each bucket through ``run_batch(fused=True)`` with the static-θ
   arms plus a DIAL-tuned arm per scenario (the best static arm is the
   per-scenario oracle DIAL is judged against); padding is an exact
   arithmetic identity, so rows match the per-structure sweep bit for
   bit (``ragged=False`` restores the per-structure grouping);
3. auto-triage: every scenario where DIAL loses to best-static by more
   than ``loss_threshold`` lands in the report's ``triage`` section,
   deduplicated by spec fingerprint, with the full spec serialized so
   the continual-learning loop can replay the hard cases
   (:func:`load_hard_specs`).

Reports are byte-identical across invocations with the same seed and
model (no timestamps, sorted keys): ``python -m repro.lab fuzz --smoke``
twice must produce the same ``reports/fuzz/report.json``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os

import numpy as np

from repro.core.config_space import SPACE
from repro.lab.batch import (pad_class, run_batch, stack_scenarios,
                             structure_key)
from repro.lab.scenarios import DisturbanceEvent, ScenarioSpec, build
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import (Workload, bdcats_read, dlio_reader,
                                 random_stream, sequential_stream,
                                 vpic_write)


# ---------------------------------------------------------------------- #
# configuration
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class FuzzConfig:
    """One sweep's generation + execution + triage parameters.

    ``thetas`` are the static arms each scenario is raced against
    (empty tuple -> the full 24-point Θ grid, as ``lab evaluate`` uses);
    ``topologies`` bounds the structural diversity (every extra
    (clients, osts) pair is at least one more compiled program);
    ``loss_threshold`` is the triage X: DIAL "loses" a scenario when its
    throughput is below ``(1 - X) * best_static``.
    """

    seed: int = 0
    n_scenarios: int = 512
    seconds: float = 6.0
    interval: float = 0.5
    loss_threshold: float = 0.05
    min_best_static_mbs: float = 1.0   # skip triage of all-dead scenarios
    thetas: tuple = ()                 # () -> full SPACE
    topologies: tuple = ((2, 1), (4, 2), (4, 4), (6, 2))
    event_kinds: tuple = ("ost_slow", "bg_burst", "nic_slow",
                          "ost_fail", "ost_failover", "client_evict")
    min_events: int = 0
    max_events: int = 3
    stripe_all_prob: float = 0.5       # row stripes over all OSTs vs one
    max_batch_elems: int = 256         # chunk buckets beyond this
    seg_backend: str = "jax"


#: CI-sized sweep: 64 scenarios, 3 s each, a 6-point static grid, two
#: topologies (one compiled program family per structure bucket), every
#: scenario carrying at least one event so the fault vocabulary is
#: always exercised.
SMOKE = FuzzConfig(
    n_scenarios=64, seconds=3.0,
    thetas=((16, 1), (64, 2), (256, 8), (1024, 4), (1024, 16), (1024, 32)),
    topologies=((4, 2), (2, 1)),
    min_events=1, max_events=2,
    max_batch_elems=224,
)


# ---------------------------------------------------------------------- #
# seeded generation
# ---------------------------------------------------------------------- #
def _draw_workload(rng, client: int, n_osts: int,
                   stripe_all_prob: float) -> Workload:
    """One workload row for ``client``: preset family + jittered params."""
    all_osts = tuple(range(n_osts))
    one_ost = (int(rng.integers(n_osts)),)
    stripe = all_osts if rng.random() < stripe_all_prob else one_ost
    family = int(rng.integers(6))
    if family == 0:
        w = vpic_write(client, dims=int(rng.integers(1, 4)), osts=stripe)
    elif family == 1:
        mode = ("partial", "strided", "full")[int(rng.integers(3))]
        w = bdcats_read(client, mode, osts=stripe)
    elif family == 2:
        w = dlio_reader(client, "bert", n_threads=int(rng.integers(1, 5)),
                        osts=stripe)
    elif family == 3:
        w = dlio_reader(client, "megatron",
                        n_threads=int(rng.integers(1, 5)), osts=stripe)
    elif family == 4:
        op = READ if rng.random() < 0.5 else WRITE
        w = sequential_stream(client, op,
                              float(2.0 ** rng.integers(17, 25)),
                              ost=one_ost[0],
                              n_threads=int(rng.integers(1, 4)))
    else:
        op = READ if rng.random() < 0.5 else WRITE
        w = random_stream(client, op, float(2.0 ** rng.integers(13, 21)),
                          ost=one_ost[0], n_threads=int(rng.integers(1, 4)))
    # continuous jitter on top of the preset (same knobs variants() turns)
    return dataclasses.replace(
        w,
        req_size=float(w.req_size) * 2.0 ** rng.uniform(-0.7, 0.7),
        thread_rate=float(w.thread_rate) * rng.uniform(0.7, 1.3),
        randomness=float(np.clip(w.randomness + rng.uniform(-0.1, 0.1),
                                 0.0, 1.0)),
        period=float(w.period) * rng.uniform(0.8, 1.25),
    )


def _draw_targets(rng, n: int, k_max: int | None = None) -> tuple:
    k = int(rng.integers(1, (k_max or n) + 1))
    return tuple(int(x) for x in sorted(rng.choice(n, size=k,
                                                   replace=False)))


def _draw_event(rng, kind: str, n_clients: int, n_osts: int,
                horizon: float) -> DisturbanceEvent:
    """One valid event of ``kind`` whose window intersects the run."""
    start = float(rng.uniform(0.0, 0.55 * horizon))
    if kind == "ost_slow":
        end = (math.inf if rng.random() < 0.5
               else start + float(rng.uniform(0.2, 0.8) * horizon))
        periodic = rng.random() < 0.4
        return DisturbanceEvent(
            kind, targets=_draw_targets(rng, n_osts),
            magnitude=float(rng.uniform(0.05, 0.7)), start=start, end=end,
            period=float(rng.uniform(0.5, 2.0)) if periodic else 0.0,
            duty=float(rng.uniform(0.2, 0.9)) if periodic else 1.0)
    if kind == "bg_burst":
        end = (math.inf if rng.random() < 0.5
               else start + float(rng.uniform(0.2, 0.8) * horizon))
        periodic = rng.random() < 0.6
        return DisturbanceEvent(
            kind, targets=_draw_targets(rng, n_osts),
            magnitude=float(rng.uniform(100e6, 600e6)), start=start,
            end=end,
            period=float(rng.uniform(0.5, 3.0)) if periodic else 0.0,
            duty=float(rng.uniform(0.2, 0.8)) if periodic else 1.0)
    if kind == "nic_slow":
        return DisturbanceEvent(
            kind, targets=_draw_targets(rng, n_clients,
                                        k_max=max(1, n_clients - 1)),
            magnitude=float(rng.uniform(0.05, 0.6)), start=start)
    if kind == "ost_fail":
        end = start + float(rng.uniform(0.15, 0.5) * horizon)
        flapping = rng.random() < 0.3
        return DisturbanceEvent(
            kind, targets=_draw_targets(rng, n_osts,
                                        k_max=max(1, n_osts - 1) if n_osts > 1
                                        else 1),
            magnitude=float(rng.choice((0.0, 0.1))), start=start, end=end,
            period=float(rng.uniform(0.4, 1.5)) if flapping else 0.0,
            duty=float(rng.uniform(0.3, 0.7)) if flapping else 1.0)
    if kind == "ost_failover":
        start = float(rng.uniform(0.15, 0.35) * horizon)
        end = start + float(rng.uniform(0.15, 0.3) * horizon)
        return DisturbanceEvent(
            kind, targets=_draw_targets(rng, n_osts,
                                        k_max=max(1, n_osts - 1) if n_osts > 1
                                        else 1),
            magnitude=0.0, start=start, end=end,
            recovery=float(rng.uniform(0.2, 0.5) * horizon))
    if kind == "client_evict":
        end = start + float(rng.uniform(0.2, 0.6) * horizon)
        return DisturbanceEvent(
            kind, targets=_draw_targets(rng, n_clients,
                                        k_max=max(1, n_clients // 2)),
            magnitude=0.0, start=start, end=end)
    raise ValueError(f"unknown event kind {kind!r}")


def generate_spec(cfg: FuzzConfig, index: int) -> ScenarioSpec:
    """Scenario ``index`` of the sweep — a pure function of
    ``(cfg.seed, index)`` via an independent SeedSequence stream."""
    rng = np.random.default_rng(
        np.random.SeedSequence((int(cfg.seed), int(index))))
    n_clients, n_osts = cfg.topologies[int(rng.integers(len(cfg.topologies)))]
    workloads = tuple(_draw_workload(rng, c, n_osts, cfg.stripe_all_prob)
                      for c in range(n_clients))
    n_events = int(rng.integers(cfg.min_events, cfg.max_events + 1))
    events = tuple(
        _draw_event(rng,
                    cfg.event_kinds[int(rng.integers(len(cfg.event_kinds)))],
                    n_clients, n_osts, cfg.seconds)
        for _ in range(n_events))
    configs = SPACE.configs()
    theta = configs[int(rng.integers(len(configs)))]
    return ScenarioSpec(
        name=f"fuzz_{cfg.seed}_{index}",
        n_clients=n_clients, n_osts=n_osts,
        workloads=workloads, events=events,
        initial_theta=(int(theta[0]), int(theta[1])),
        seed=index,
        description=f"generated (seed={cfg.seed}, index={index})",
        tags=("fuzz",) + tuple(sorted({ev.kind for ev in events})),
    )


def generate_specs(cfg: FuzzConfig) -> list[ScenarioSpec]:
    return [generate_spec(cfg, i) for i in range(cfg.n_scenarios)]


# ---------------------------------------------------------------------- #
# spec serialization + fingerprinting
# ---------------------------------------------------------------------- #
def _event_dict(ev: DisturbanceEvent) -> dict:
    d = dataclasses.asdict(ev)
    d["targets"] = list(d["targets"])
    d["end"] = None if math.isinf(ev.end) else ev.end   # JSON-safe inf
    return d


def spec_to_dict(spec: ScenarioSpec) -> dict:
    """JSON-safe serialization of everything that defines the physics
    (name/description/tags excluded — they don't affect the run)."""
    return {
        "n_clients": spec.n_clients,
        "n_osts": spec.n_osts,
        "initial_theta": [int(x) for x in spec.initial_theta],
        "workloads": [
            {**dataclasses.asdict(w), "osts": list(w.osts)}
            for w in spec.workloads],
        "events": [_event_dict(ev) for ev in spec.events],
    }


def spec_from_dict(d: dict, name: str = "replayed") -> ScenarioSpec:
    """Inverse of :func:`spec_to_dict` (for replaying triaged specs)."""
    workloads = tuple(
        Workload(**{**w, "osts": tuple(w["osts"])}) for w in d["workloads"])
    events = tuple(
        DisturbanceEvent(**{**e, "targets": tuple(e["targets"]),
                            "end": math.inf if e["end"] is None else e["end"]})
        for e in d["events"])
    return ScenarioSpec(name=name, n_clients=d["n_clients"],
                        n_osts=d["n_osts"], workloads=workloads,
                        events=events,
                        initial_theta=tuple(d["initial_theta"]),
                        tags=("fuzz", "replayed"))


def fingerprint(spec: ScenarioSpec) -> str:
    """Stable content hash of the physics — the triage dedup key."""
    blob = json.dumps(spec_to_dict(spec), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


# ---------------------------------------------------------------------- #
# the sweep
# ---------------------------------------------------------------------- #
def _run_bucket(specs_ix, thetas, model, cfg: FuzzConfig,
                mesh=None, stats: dict | None = None) -> list[dict]:
    """Race every scenario of one shape bucket: static arms + DIAL.

    ``specs_ix`` is ``[(index, spec), ...]``; buckets beyond
    ``max_batch_elems`` elements run as several equally-shaped chunks
    (chunking never changes a scenario's result — elements are
    independent under vmap).  Mixed structures inside a bucket stack
    ragged (pad-and-mask); ``stats``, when given, accumulates
    ``dispatches`` / ``real`` / ``padded`` interface counts.
    """
    m = len(thetas)
    arms = m + 1
    per_chunk = max(1, cfg.max_batch_elems // arms)
    rows = []
    for lo in range(0, len(specs_ix), per_chunk):
        chunk = specs_ix[lo:lo + per_chunk]
        built = []
        for _, spec in chunk:
            for th in thetas:
                built.append(build(dataclasses.replace(
                    spec, initial_theta=(int(th[0]), int(th[1])))))
            built.append(build(spec))          # the DIAL arm
        batch = stack_scenarios(built)
        n = batch.n_osc
        dial_cols = np.concatenate(
            [(j * arms + m) * n + batch.element_cols(j * arms + m)
             for j in range(len(chunk))])
        result = run_batch(batch, model=model, seconds=cfg.seconds,
                           interval=cfg.interval,
                           seg_backend=cfg.seg_backend,
                           tune_cols=dial_cols, fused=True, mesh=mesh)
        tput = batch.throughput(cfg.seconds)["total_mbs"]
        if stats is not None:
            ps = batch.pad_stats()
            stats["dispatches"] = stats.get("dispatches", 0) + 1
            stats["real"] = (stats.get("real", 0)
                             + ps["real_interfaces"])
            stats["phantom"] = (stats.get("phantom", 0)
                                + ps["phantom_interfaces"])
        changes = np.zeros(len(chunk), dtype=int)
        for r in result.decisions:
            if len(r):
                np.add.at(changes, r.oscs // n // arms,
                          r.decisions.changed.astype(int))
        for j, (index, spec) in enumerate(chunk):
            static = tput[j * arms:j * arms + m]
            best = int(np.argmax(static))
            dial_mbs = float(tput[j * arms + m])
            best_mbs = float(static[best])
            rows.append({
                "index": index,
                "name": spec.name,
                "fingerprint": fingerprint(spec),
                "n_clients": spec.n_clients,
                "n_osts": spec.n_osts,
                "initial_theta": [int(x) for x in spec.initial_theta],
                "event_kinds": sorted({ev.kind for ev in spec.events}),
                "dial_mbs": dial_mbs,
                "best_static_mbs": best_mbs,
                "best_static_theta": [int(x) for x in thetas[best]],
                "dial_frac_of_best_static": dial_mbs / max(best_mbs, 1e-9),
                "changes": int(changes[j]),
            })
    return rows


def run_sweep(cfg: FuzzConfig, model, mesh=None, diagnose: bool = False,
              max_diagnoses: int | None = 32, ragged: bool = True) -> dict:
    """Generate, bucket, race, triage.  Deterministic from ``cfg.seed``
    and the model; the returned report dict serializes byte-identically
    across invocations.

    ``mesh`` spreads each structural bucket's batch across local devices
    through the sharded fused path (``--mesh`` on the CLI).  Kept out of
    the serialized config on purpose: it is an execution knob, and a
    report must stay byte-comparable with its single-device twin.  Note
    the PR-6 caveat still applies across *mesh shapes*: a ~1e-12
    segment-sum reduction drift can flip knife-edge generated scenarios,
    so only byte-compare reports produced with the same mesh.

    ``diagnose=True`` stamps a counterfactual diagnosis
    (:func:`repro.obs.diagnose.diagnose`) into each triaged loss —
    dominant cause + evidence rows, reusing the sweep's recorded race
    figures — worst losers first, at most ``max_diagnoses`` of them
    (``None`` = all; the summary records diagnosed-of-total and the
    per-cause loss counts).

    ``ragged=True`` (default) buckets specs by padded shape class so
    mixed structures share fused dispatches; ``ragged=False`` restores
    the historical one-bucket-per-structure grouping.  Rows are
    bit-identical either way (padding neutrality)."""
    specs = generate_specs(cfg)
    thetas = [tuple(int(x) for x in t)
              for t in (cfg.thetas or SPACE.configs())]

    key_fn = pad_class if ragged else structure_key
    buckets: dict = {}
    for i, spec in enumerate(specs):
        key = key_fn(build(spec))
        buckets.setdefault(key, []).append((i, spec))

    rows, occupancy = [], []
    # params (key[0]) is shared; order buckets by the numeric signature
    for key in sorted(buckets, key=lambda k: tuple(k[1:])):
        stats: dict = {}
        rows.extend(_run_bucket(buckets[key], thetas, model, cfg,
                                mesh=mesh, stats=stats))
        denom = max(stats.get("real", 0) + stats.get("phantom", 0), 1)
        occupancy.append({
            "shape": "x".join(str(int(x)) for x in key[1:]),
            "n_specs": len(buckets[key]),
            "dispatches": stats.get("dispatches", 0),
            "pad_waste": stats.get("phantom", 0) / denom,
        })
    rows.sort(key=lambda r: r["index"])
    n_dispatches = sum(b["dispatches"] for b in occupancy)

    losses, seen = [], set()
    for r in rows:
        losing = (r["best_static_mbs"] >= cfg.min_best_static_mbs
                  and r["dial_mbs"] < (1.0 - cfg.loss_threshold)
                  * r["best_static_mbs"])
        if losing and r["fingerprint"] not in seen:
            seen.add(r["fingerprint"])
            losses.append({**r, "spec": spec_to_dict(specs[r["index"]])})
    losses.sort(key=lambda r: (r["dial_frac_of_best_static"], r["index"]))

    diag_summary = {}
    if diagnose:
        from repro.obs.diagnose import DiagnoseConfig, cause_counts
        from repro.obs.diagnose import diagnose as _diagnose

        dcfg = DiagnoseConfig.from_fuzz(cfg)
        n_diag = (len(losses) if max_diagnoses is None
                  else min(len(losses), int(max_diagnoses)))
        diags = []
        for r in losses[:n_diag]:
            d = _diagnose(specs[r["index"]], model, dcfg,
                          race={k: r[k] for k in
                                ("dial_mbs", "best_static_mbs",
                                 "best_static_theta",
                                 "dial_frac_of_best_static")},
                          mesh=mesh)
            # the loss row already carries name/fingerprint/spec
            r["diagnosis"] = {k: v for k, v in d.items()
                              if k not in ("name", "fingerprint")}
            diags.append(d)
        diag_summary = {"n_diagnosed": n_diag,
                        "loss_causes": cause_counts(diags)}

    fracs = [r["dial_frac_of_best_static"] for r in rows]
    return {
        "config": {
            **{k: v for k, v in dataclasses.asdict(cfg).items()
               if k not in ("thetas", "topologies", "event_kinds")},
            "thetas": [list(t) for t in thetas],
            "topologies": [list(t) for t in cfg.topologies],
            "event_kinds": list(cfg.event_kinds),
        },
        "summary": {
            "n_scenarios": len(rows),
            "n_buckets": len(buckets),
            "n_dispatches": n_dispatches,
            "bucket_occupancy": occupancy,
            "n_unique_specs": len({r["fingerprint"] for r in rows}),
            "n_losses": len(losses),
            "mean_dial_frac_of_best_static": float(np.mean(fracs)),
            "min_dial_frac_of_best_static": float(np.min(fracs)),
            **diag_summary,
        },
        "scenarios": rows,
        "triage": {
            "loss_threshold": cfg.loss_threshold,
            "losses": losses,
        },
    }


# ---------------------------------------------------------------------- #
# report IO + hard-case feed
# ---------------------------------------------------------------------- #
def render_markdown(report: dict) -> str:
    s = report["summary"]
    cfg = report["config"]
    lines = [
        "# Fuzz sweep triage",
        "",
        f"{s['n_scenarios']} generated scenarios "
        f"({s['n_unique_specs']} unique, {s['n_buckets']} shape "
        f"buckets, {s.get('n_dispatches', '?')} fused dispatches), "
        f"seed {cfg['seed']}, {cfg['seconds']:.0f} s each, "
        f"{len(cfg['thetas'])} static arms.",
        "",
        f"DIAL fraction of best-static: mean "
        f"**{100 * s['mean_dial_frac_of_best_static']:.1f}%**, min "
        f"{100 * s['min_dial_frac_of_best_static']:.1f}%.  "
        f"**{s['n_losses']}** scenario(s) lose by more than "
        f"{100 * report['triage']['loss_threshold']:.0f}%.",
        "",
    ]
    occ = s.get("bucket_occupancy")
    if occ:
        lines += [
            "| bucket (padded shape) | specs | dispatches | pad waste |",
            "|---|---|---|---|",
        ]
        lines += [f"| `{b['shape']}` | {b['n_specs']} | "
                  f"{b['dispatches']} | {100 * b['pad_waste']:.1f}% |"
                  for b in occ]
        lines.append("")
    if report["triage"]["losses"]:
        diagnosed = any(r.get("diagnosis")
                        for r in report["triage"]["losses"])
        cause_col = " cause |" if diagnosed else ""
        lines += [
            "| scenario | topo | events | θ₀ | DIAL MB/s | "
            "best static MB/s (θ) | DIAL/best | fingerprint |" + cause_col,
            "|---|---|---|---|---|---|---|---|" + ("---|" if diagnosed
                                                   else ""),
        ]
        for r in report["triage"]["losses"]:
            th = "×".join(str(x) for x in r["best_static_theta"])
            t0 = "×".join(str(x) for x in r["initial_theta"])
            ev = ",".join(r["event_kinds"]) or "—"
            cause = (f" {r['diagnosis']['cause']} |"
                     if diagnosed and r.get("diagnosis") else
                     (" — |" if diagnosed else ""))
            lines.append(
                f"| {r['name']} | {r['n_clients']}c×{r['n_osts']}ost | "
                f"{ev} | {t0} | {r['dial_mbs']:.1f} | "
                f"{r['best_static_mbs']:.1f} ({th}) | "
                f"{100 * r['dial_frac_of_best_static']:.1f}% | "
                f"`{r['fingerprint']}` |" + cause)
        lines.append("")
        if report["triage"]["losses"][0].get("trace_recipe"):
            lines += [
                "Replay any loser with full decision provenance and "
                "per-OST timelines:",
                "",
                f"    {report['triage']['losses'][0]['trace_recipe']}",
                "",
                "(swap the fingerprint for any row above).",
                "",
            ]
    return "\n".join(lines)


def trace_recipe(report_path: str, fp: str) -> str:
    """The replay command for one triaged loss: rebuilds the exact spec
    from the serialized physics in the report and re-runs it traced."""
    return (f"python -m repro.lab trace --from-report {report_path} "
            f"--fingerprint {fp}")


def write_fuzz_report(report: dict, out_dir: str) -> tuple[str, str]:
    os.makedirs(out_dir, exist_ok=True)
    jpath = os.path.join(out_dir, "report.json")
    mpath = os.path.join(out_dir, "report.md")
    # stamp each triaged loss with its replay recipe; paths are derived
    # from out_dir only, so reports stay byte-identical across
    # invocations into the same directory (the CI determinism check)
    report = {**report, "triage": {
        **report["triage"],
        "losses": [{**r, "trace_recipe": trace_recipe(jpath,
                                                      r["fingerprint"])}
                   for r in report["triage"]["losses"]]}}
    with open(jpath, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    with open(mpath, "w") as f:
        f.write(render_markdown(report))
    return jpath, mpath


def load_hard_specs(path: str) -> list[ScenarioSpec]:
    """Triaged losing scenarios from a report.json, rebuilt as specs —
    the hard-case feed for the continual-learning loop."""
    with open(path) as f:
        report = json.load(f)
    return [spec_from_dict(r["spec"], name=r["name"])
            for r in report["triage"]["losses"]]
