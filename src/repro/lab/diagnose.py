"""Counterfactual diagnosis replay: one command from loss to cause.

``python -m repro.lab diagnose <scenario>`` (or a triaged fuzz loser
via ``--from-report/--fingerprint``, or every loser via ``--all``)
re-runs the scenario through the fused loop under the intervention
arms (θ pinned to the best-static oracle, gates forced open, decisions
frozen, optional model swap) and writes the machine-readable diagnosis:

    diagnosis.json    byte-deterministic ``dial-diagnosis-v1`` report
    diagnosis.md      per-scenario cause table

See :mod:`repro.obs.diagnose` for the engine and
``docs/OBSERVABILITY.md`` for the cause taxonomy.
"""

from __future__ import annotations

import json

from repro.lab.scenarios import get_scenario
from repro.obs.diagnose import (DiagnoseConfig, diagnose_many,
                                render_diagnosis_markdown,
                                write_diagnosis_report)


def _losses(path: str) -> list[dict]:
    with open(path) as f:
        report = json.load(f)
    return report.get("triage", {}).get("losses", [])


def specs_from_report(path: str, fp: str | None,
                      all_losses: bool) -> list[tuple]:
    """``(spec, race)`` pairs for the requested triaged losers — the
    recorded race figures skip re-running phase A."""
    from repro.lab.fuzz import spec_from_dict

    losses = _losses(path)
    if not all_losses:
        losses = [r for r in losses if r["fingerprint"] == fp]
        if not losses:
            have = ", ".join(r["fingerprint"]
                             for r in _losses(path)) or "none"
            raise KeyError(f"fingerprint {fp!r} not in {path} "
                           f"(triaged: {have})")
    return [(spec_from_dict(r["spec"], name=r["name"]),
             {"dial_mbs": r["dial_mbs"],
              "best_static_mbs": r["best_static_mbs"],
              "best_static_theta": r["best_static_theta"],
              "dial_frac_of_best_static": r["dial_frac_of_best_static"]})
            for r in losses]


def main(args) -> int:
    """CLI entry (dispatched from ``repro.lab.__main__``)."""
    from repro.core.model import DIALModel
    from repro.lab.evaluate import default_model

    if args.from_report:
        if not (args.fingerprint or args.all):
            raise SystemExit("--from-report needs --fingerprint or --all")
        pairs = specs_from_report(args.from_report, args.fingerprint,
                                  args.all)
    elif args.scenario:
        pairs = [(get_scenario(args.scenario), None)]
    else:
        raise SystemExit("pass a scenario name or --from-report with "
                         "--fingerprint/--all")

    model = (DIALModel.load(args.model) if args.model
             else default_model(smoke=args.smoke))
    alt_model = DIALModel.load(args.alt_model) if args.alt_model else None
    cfg = DiagnoseConfig(seconds=args.seconds, interval=args.interval,
                         loss_threshold=args.threshold,
                         max_evidence=args.max_evidence,
                         seg_backend=args.seg_backend)

    from repro.lab.__main__ import _make_mesh
    mesh = _make_mesh(args.mesh)
    # a mixed loser set (--all) replays ragged: one traced dispatch per
    # padded shape bucket instead of one per loser
    diags = diagnose_many(pairs, model, cfg, mesh=mesh,
                          alt_model=alt_model,
                          alt_model_name=args.alt_model,
                          ragged=not getattr(args, "no_ragged", False))
    jpath, mpath = write_diagnosis_report(diags, args.out)
    report = {"schema": diags[0]["schema"] if diags else "",
              "n_diagnoses": len(diags),
              "causes": {}, "diagnoses": diags}
    from repro.obs.diagnose import cause_counts
    report["causes"] = cause_counts(diags)
    print(render_diagnosis_markdown(report))
    print(f"wrote {jpath} / {mpath}")
    return 0
