"""Continual in-lab retraining: a scenario that refits its model mid-run.

The evaluate path (:mod:`repro.lab.evaluate`) tunes every scenario with
a *frozen* offline model, so scenarios whose storage system drifts
mid-run (``degraded_ost`` / ``failing_ost``) are scored by a model that
has never seen the post-drift regime.  This module closes the loop:

* every tuning interval, the DIAL agent's own decisions are labeled one
  interval later with the paper's improvement criterion
  (``tput_{t+1}/tput_t > 1 + eps``) and pushed into per-op
  :class:`~repro.learn.online.ReplayBuffer` rings;
* an epsilon-greedy sprinkle of random θ keeps the on-policy stream
  from collapsing onto one configuration;
* :class:`~repro.learn.online.OnlineTrainer` watches fleet throughput
  for drift (fast/slow EMA divergence) and periodically refits the
  forests with one jitted :func:`repro.learn.boost.fit_forest_batch`
  launch, swapping them into the live model between intervals.

``run_comparison`` drives the same scenario twice — frozen model vs
online refit — and reports pre/post-failure throughput for both; the
``python -m repro.lab continual`` CLI prints and persists the result.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import math
import os

import numpy as np

from repro.core.config_space import SPACE
from repro.core.dataset import EPS_IMPROVE
from repro.core.fleet import FleetAgent
from repro.core.gbdt import GBDTParams
from repro.core.metrics import fleet_feature_matrix, snapshot_all
from repro.core.model import DIALModel
from repro.core.tuner import TunerParams
from repro.lab.batch import BatchEngine, BatchPort, stack_scenarios
from repro.lab.scenarios import ScenarioSpec, build, get_scenario
from repro.learn.online import OnlinePolicy, OnlineTrainer
from repro.pfs.engine import READ, WRITE


@dataclasses.dataclass
class ContinualResult:
    """One policy's run of one drifting scenario."""

    scenario: str
    online: bool
    seconds: float
    interval: float
    t_fail: float                 # first disturbance onset (inf if none)
    tput_mbs: list                # per-interval fleet MB/s
    theta_trace: list             # per-interval checksum of applied θ
    refits: list                  # OnlineTrainer refit records
    samples: dict                 # labeled rows collected per op
    pre_fail_mbs: float
    post_fail_mbs: float          # mean over every post-onset interval
    post_tail_mbs: float          # mean over the later post-onset half
    changes: int

    def row(self) -> dict:
        return dataclasses.asdict(self)


def _first_onset(spec: ScenarioSpec) -> float:
    starts = [ev.start for ev in spec.events]
    return min(starts) if starts else math.inf


def run_continual(spec: ScenarioSpec, model: DIALModel, *,
                  online: bool = True, seconds: float = 30.0,
                  interval: float = 0.5,
                  policy: OnlinePolicy | None = None,
                  gbdt_params: GBDTParams | None = None,
                  seed_data: dict | None = None,
                  seg_backend: str = "jax",
                  tuner_params: TunerParams | None = None,
                  seed: int = 0) -> ContinualResult:
    """Drive one scenario with DIAL tuning and (optionally) online refit.

    The labeling loop mirrors the campaign's explore/label recipe, but
    on-policy: each interval's *applied* θ (the agent's decision, or an
    epsilon-greedy random θ) becomes a pending sample labeled by the
    next interval's throughput ratio.
    """
    rng = np.random.default_rng(seed)
    policy = policy if policy is not None else OnlinePolicy()
    tuner_params = tuner_params if tuner_params is not None else TunerParams()
    batch = stack_scenarios([build(spec)])
    port = BatchPort(batch)
    fleet = FleetAgent(port, model, tuner_params=tuner_params)
    trainer = None
    if online:
        trainer = OnlineTrainer(model, gbdt_params, policy=policy)
        if seed_data is not None:
            trainer.seed(seed_data)

    steps = max(int(round(interval / batch.params.tick)), 1)
    n_intervals = int(round(seconds / interval))
    engine = BatchEngine(batch.params, batch.topo, steps,
                         seg_backend=seg_backend)
    theta_feats = SPACE.as_features()
    configs = SPACE.configs()
    m = len(configs)

    prev = port.probe_all()
    hist: collections.deque = collections.deque(maxlen=fleet.k + 1)
    pending = None       # (rows, ops, feats, tput) awaiting next label
    series: list[float] = []
    theta_trace: list[float] = []
    n_samples = {READ: 0, WRITE: 0}

    for _ in range(n_intervals):
        t0 = float(np.ravel(np.asarray(batch.state.now))[0])
        sched = batch.schedule(int(round(t0 / batch.params.tick)), steps)
        batch.state, batch.wstate = engine.run_interval(
            batch.table, batch.state, batch.wstate, sched)

        cur = port.probe_all()
        snap = snapshot_all(prev, cur)
        prev = cur
        hist.append(snap)
        series.append(float((snap.read_volume + snap.write_volume).sum()
                            / snap.dt / 1e6))

        # label the previous interval's applied configurations
        if pending is not None and trainer is not None:
            rows, ops_p, feats, tput0 = pending
            op_tput = np.where(ops_p == READ, snap.read[rows, 0],
                               snap.write[rows, 0])
            vol = np.where(ops_p == READ, snap.read_volume[rows],
                           snap.write_volume[rows])
            ok = (tput0 > 0) & (vol >= fleet.min_volume)
            for op in (READ, WRITE):
                sel = ok & (ops_p == op)
                if sel.any():
                    labels = (op_tput[sel] / tput0[sel]
                              > 1.0 + EPS_IMPROVE).astype(float)
                    trainer.observe(op, feats[sel], labels)
                    n_samples[op] += int(sel.sum())
        pending = None

        # the agent's tuning tick (probes the same state again — cheap)
        result = fleet.tick()

        if len(result):
            rows = result.oscs.copy()           # cols == osc ids here
            ops_r = result.ops.copy()
            theta = result.decisions.theta.copy()
            # epsilon-greedy: some rows explore a random θ instead.  The
            # frozen arm runs the identical exploration schedule (same
            # rng stream), so a frozen-vs-online comparison isolates the
            # refits rather than mixing in an exploration tax.
            explore = rng.random(len(rows)) < policy.explore_eps
            if explore.any():
                j = rng.integers(m, size=int(explore.sum()))
                theta[explore] = np.asarray([configs[x] for x in j])
                port.set_knobs_many(rows[explore], theta[explore, 0],
                                    theta[explore, 1])
                # no shadow-state repair needed: the agent derives the
                # applied configuration from its next probe, so this
                # out-of-band flip is seen by construction
            # position-weighted checksum of the applied (row, θ) block —
            # frozen/online traces must agree until the first refit
            w = np.arange(theta.size, dtype=np.float64) + 1.0
            theta_trace.append(float(theta.ravel() @ w + float(rows.sum())))
        else:
            theta_trace.append(0.0)

        if trainer is not None and len(result):
            # feature rows of the *applied* θ, for next-interval labeling
            from repro.core.metrics import feature_dim

            hist_list = list(hist)
            width = max(feature_dim(READ, fleet.k),
                        feature_dim(WRITE, fleet.k))
            feats = np.zeros((len(rows), width), dtype=np.float32)
            fdims = {}
            for op in (READ, WRITE):
                sel = ops_r == op
                if not sel.any():
                    continue
                F = fleet_feature_matrix(hist_list, op, rows[sel],
                                         theta_feats)
                js = np.asarray([SPACE.index_of(tuple(t))
                                 for t in theta[sel]])
                picked = F[np.arange(sel.sum()) * m + js]
                fdims[op] = picked.shape[1]
                feats[sel, :picked.shape[1]] = picked
            tput0 = np.where(ops_r == READ, snap.read[rows, 0],
                             snap.write[rows, 0])
            pending = (rows, ops_r,
                       _RowView(feats, fdims, ops_r), tput0)

        if trainer is not None:
            trainer.step(series[-1])

    t_fail = _first_onset(spec)
    ts = (np.arange(n_intervals) + 1) * interval
    arr = np.asarray(series)
    pre = arr[ts <= t_fail]
    post = arr[ts > t_fail]
    tail = post[len(post) // 2:]
    changes = sum(int(r.decisions.changed.sum()) for r in fleet.decisions)
    return ContinualResult(
        scenario=spec.name,
        online=online,
        seconds=seconds,
        interval=interval,
        t_fail=t_fail,
        tput_mbs=[float(x) for x in series],
        theta_trace=theta_trace,
        refits=list(trainer.refits) if trainer else [],
        samples={"read": n_samples[READ], "write": n_samples[WRITE]},
        pre_fail_mbs=float(pre.mean()) if len(pre) else 0.0,
        post_fail_mbs=float(post.mean()) if len(post) else float(arr.mean()),
        post_tail_mbs=float(tail.mean()) if len(tail) else float(arr.mean()),
        changes=changes,
    )


class _RowView:
    """Op-sliced view over the mixed-op pending feature block: indexing
    with a boolean row mask returns rows trimmed to that op's dim."""

    def __init__(self, feats: np.ndarray, fdims: dict, ops: np.ndarray):
        self._feats = feats
        self._fdims = fdims
        self._ops = ops

    def __getitem__(self, sel):
        op = int(self._ops[np.nonzero(sel)[0][0]])
        return self._feats[sel, :self._fdims[op]]


def run_comparison(name: str = "failing_ost", model: DIALModel | None = None,
                   seconds: float = 45.0, interval: float = 0.5,
                   policy: OnlinePolicy | None = None,
                   gbdt_params: GBDTParams | None = None,
                   seed_data: dict | None = None,
                   seg_backend: str = "jax", smoke: bool = False) -> dict:
    """Frozen-model vs online-refit on one drifting scenario.

    Both runs start from the *same* forests (the online run swaps its
    own copies, never mutating the originals), identical engine state,
    and the identical epsilon-greedy exploration schedule, so the
    throughput difference is attributable to the refits.  Defaults are
    the calibrated failing_ost recovery configuration (10-interval
    refit cadence, 10% exploration, 40x5 refit forests).
    """
    from repro.lab.evaluate import default_model

    spec = get_scenario(name)
    if model is None:
        model = default_model(smoke=smoke)
    policy = policy or OnlinePolicy(refit_every=10, min_samples=32,
                                    cooldown=6, explore_eps=0.10)
    gbdt_params = gbdt_params or GBDTParams(n_trees=40, max_depth=5)

    def fresh():
        return DIALModel(read_forest=model.read_forest,
                         write_forest=model.write_forest,
                         space=model.space, backend=model.backend,
                         k=model.k)

    # the frozen arm gets the same policy: only explore_eps is consulted
    # when online=False, so both arms draw the identical epsilon-greedy
    # exploration schedule from the same rng stream
    frozen = run_continual(spec, fresh(), online=False, seconds=seconds,
                           interval=interval, policy=policy,
                           seg_backend=seg_backend)
    online = run_continual(spec, fresh(), online=True, seconds=seconds,
                           interval=interval, policy=policy,
                           gbdt_params=gbdt_params, seed_data=seed_data,
                           seg_backend=seg_backend)
    gain = online.post_fail_mbs / max(frozen.post_fail_mbs, 1e-9)
    tail_gain = online.post_tail_mbs / max(frozen.post_tail_mbs, 1e-9)
    return {
        "scenario": name,
        "seconds": seconds,
        "interval": interval,
        "t_fail": frozen.t_fail if math.isfinite(frozen.t_fail) else None,
        "frozen": frozen.row(),
        "online": online.row(),
        "post_fail_gain": gain,
        "post_tail_gain": tail_gain,
        "refits": len(online.refits),
    }


def write_report(report: dict, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "continual.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    return path


# ---------------------------------------------------------------------- #
# the hard-case replay curriculum: fuzz triage -> refits -> re-race
# ---------------------------------------------------------------------- #
CURRICULUM_SCHEMA = "dial-curriculum-v1"

#: curriculum replays per diagnosed cause.  Model-attributed losses
#: (the forests ranked wrong, converged late, or cleared no candidate)
#: are replayed hardest — each replay collects on-policy labels and
#: feeds the online refits.  Gate-attributed losses get one pass (the
#: model is not at fault; their diagnosis evidence rows are surfaced as
#: gate-threshold evidence instead).  ``inherent`` and ``none`` losses
#: carry no signal a refit could use.
CAUSE_WEIGHTS = {
    "model_misranked": 3,
    "reaction_lag": 2,
    "candidate_missing": 2,
    "gate_blocked": 1,
    "undiagnosed": 1,
    "inherent": 0,
    "none": 0,
}


def _race_cases(cases: list[dict], model: DIALModel, seconds: float,
                interval: float, seg_backend: str) -> list[dict]:
    """DIAL vs each loser's recorded best-static θ, under the sweep's
    own run length — the before/after measurement both ends share.
    The mixed loser set races ragged: one fused dispatch per padded
    shape bucket, results bit-identical to one race per case."""
    from repro.obs.diagnose import DiagnoseConfig, race_many

    cfg = DiagnoseConfig(seconds=seconds, interval=interval,
                         seg_backend=seg_backend)
    return race_many([(c["spec"], c["row"]["best_static_theta"])
                      for c in cases], model, cfg)


def run_hard_case_curriculum(report_path: str, model: DIALModel, *,
                             seconds: float = 12.0, interval: float = 0.5,
                             policy: OnlinePolicy | None = None,
                             gbdt_params: GBDTParams | None = None,
                             seg_backend: str = "jax",
                             max_cases: int | None = None,
                             seed: int = 0) -> dict:
    """Close the triage loop: replay a fuzz report's losers as a
    continual-learning curriculum and measure the loss-rate delta.

    Every triaged loser is (1) re-raced against its recorded
    best-static θ with the incoming model (*before*), (2) replayed
    ``CAUSE_WEIGHTS[cause]`` times through :func:`run_continual` with
    online refits mutating ``model`` in place — losers the diagnosis
    attributes to the *model* are replayed hardest, gate-attributed
    losers instead contribute their evidence rows to the report's
    ``gate_evidence`` ledger — then (3) re-raced with the refit model
    (*after*).  The report buckets before/after loss rates per
    diagnosed cause.  ``seconds`` / ``interval`` control the curriculum
    replays; the before/after races reuse the fuzz sweep's own run
    length so "losing" means exactly what it meant at triage time.
    """
    with open(report_path) as f:
        fuzz_report = json.load(f)
    from repro.lab.fuzz import spec_from_dict

    losses = fuzz_report["triage"]["losses"]
    if max_cases is not None:
        losses = losses[:max_cases]
    loss_x = float(fuzz_report["triage"]["loss_threshold"])
    min_mbs = float(fuzz_report["config"].get("min_best_static_mbs", 0.0))
    race_seconds = float(fuzz_report["config"]["seconds"])
    race_interval = float(fuzz_report["config"]["interval"])
    policy = policy if policy is not None else OnlinePolicy(
        refit_every=4, min_samples=16, cooldown=2, explore_eps=0.15)
    gbdt_params = gbdt_params or GBDTParams(n_trees=40, max_depth=5)

    def losing(race: dict) -> bool:
        return (race["best_static_mbs"] >= min_mbs
                and race["dial_mbs"] < (1.0 - loss_x)
                * race["best_static_mbs"])

    cases, gate_evidence = [], []
    for r in losses:
        spec = spec_from_dict(r["spec"], name=r["name"])
        cause = r.get("diagnosis", {}).get("cause", "undiagnosed")
        if cause == "gate_blocked":
            gate_evidence.append({
                "name": r["name"], "fingerprint": r["fingerprint"],
                "evidence": r["diagnosis"]["evidence"],
                "n_evidence_total": r["diagnosis"]["n_evidence_total"],
            })
        cases.append({"spec": spec, "row": r, "cause": cause,
                      "weight": CAUSE_WEIGHTS.get(cause, 1)})

    # (1) before: every case, with the incoming forests (ragged)
    for c, race in zip(cases, _race_cases(cases, model, race_seconds,
                                          race_interval, seg_backend)):
        c["before"] = race

    # (2) the curriculum: weighted replays with in-place online refits
    n_replays = n_refits = 0
    for i, c in enumerate(cases):
        for rep in range(c["weight"]):
            res = run_continual(c["spec"], model, online=True,
                                seconds=seconds, interval=interval,
                                policy=policy, gbdt_params=gbdt_params,
                                seg_backend=seg_backend,
                                seed=seed + 1000 * i + rep)
            n_replays += 1
            n_refits += len(res.refits)

    # (3) after: the same races, with the curriculum-refit forests
    for c, race in zip(cases, _race_cases(cases, model, race_seconds,
                                          race_interval, seg_backend)):
        c["after"] = race

    buckets: dict = {}
    for c in cases:
        b = buckets.setdefault(c["cause"], {"n": 0, "before_losses": 0,
                                            "after_losses": 0})
        b["n"] += 1
        b["before_losses"] += int(losing(c["before"]))
        b["after_losses"] += int(losing(c["after"]))
    for b in buckets.values():
        b["before_loss_rate"] = b["before_losses"] / b["n"]
        b["after_loss_rate"] = b["after_losses"] / b["n"]
        b["delta"] = b["after_loss_rate"] - b["before_loss_rate"]
    n = len(cases)
    before = sum(b["before_losses"] for b in buckets.values())
    after = sum(b["after_losses"] for b in buckets.values())

    return {
        "schema": CURRICULUM_SCHEMA,
        "source": os.path.basename(report_path),
        "n_losers": n,
        "n_replays": n_replays,
        "n_refits": n_refits,
        "replay_seconds": seconds,
        "replay_interval": interval,
        "race_seconds": race_seconds,
        "loss_threshold": loss_x,
        "cause_weights": dict(sorted(CAUSE_WEIGHTS.items())),
        "cases": [{
            "name": c["row"]["name"],
            "fingerprint": c["row"]["fingerprint"],
            "cause": c["cause"],
            "weight": c["weight"],
            "before": {**c["before"], "losing": losing(c["before"])},
            "after": {**c["after"], "losing": losing(c["after"])},
        } for c in cases],
        "buckets": dict(sorted(buckets.items())),
        "overall": {
            "before_loss_rate": before / n if n else 0.0,
            "after_loss_rate": after / n if n else 0.0,
            "delta": (after - before) / n if n else 0.0,
        },
        "gate_evidence": gate_evidence,
    }


def write_curriculum_report(report: dict, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "curriculum.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
