"""Offline data collection and training, rebuilt on the batch path.

The paper's SIV-A recipe (reproduced sequentially in
:func:`repro.core.dataset.collect`, which stays the oracle) probes a
grid of filebench-style cells every 0.5 s while exploring random θ′ and
labels each transition with ``1[tput_{t+1}/tput_t > 1 + ε]``.  The
sequential version steps one big mostly-idle simulator tick-by-tick from
Python; a campaign instead builds one tiny *scenario per cell* — 2
clients × 1 OST: a measurement stream plus an optional noisy-neighbour
stream on its own client — stacks the whole grid into a
:class:`~repro.lab.batch.ScenarioBatch`, and advances every cell's
interval in a single vmapped launch.  Exploration, labeling, and feature
assembly then run as array programs over the batch (the same
``fleet_feature_matrix`` the fleet agent uses at inference time).

Campaigns end in **versioned model artifacts**: ``models/lab/vNNN/``
holding the two forests (``dial.read.npz`` / ``dial.write.npz``), a
``manifest.json`` (config, sample counts, label rates), and a ``LATEST``
pointer — anything :meth:`DIALModel.load` (and therefore ``run_fleet``)
can consume directly.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os
import re

import numpy as np

from repro.core.config_space import SPACE, ConfigSpace
from repro.core.dataset import EPS_IMPROVE, train_models
from repro.core.gbdt import GBDTParams
from repro.core.metrics import (feature_dim, fleet_feature_matrix,
                                snapshot_all)
from repro.core.model import DIALModel
from repro.lab.batch import BatchEngine, BatchPort, stack_scenarios
from repro.lab.scenarios import ScenarioSpec, build
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import Workload


@dataclasses.dataclass(frozen=True)
class CellGrid:
    """The measurement-cell grid (paper: single streams, seq/rand ×
    8K/1M/16M; thread counts extend the concurrency axis as in
    ``core/dataset``)."""

    req_sizes: tuple = (8 * 1024, 64 * 1024, 1 * 2**20, 16 * 2**20)
    patterns: tuple = (0.0, 0.9, 1.0)
    threads: tuple = (1, 4, 16, 32)


SMOKE_GRID = CellGrid(req_sizes=(64 * 1024, 4 * 2**20),
                      patterns=(0.0, 1.0), threads=(1, 8))


def smoke_campaign() -> tuple["CampaignConfig", GBDTParams]:
    """The one CI-sized campaign every smoke entry point shares (the
    CLI's ``campaign --smoke`` and ``evaluate``'s auto-trained fallback
    must stay the same model grade)."""
    return (CampaignConfig(seconds=15.0, reps=1, grid=SMOKE_GRID),
            GBDTParams(n_trees=40, max_depth=5))


@dataclasses.dataclass
class CampaignConfig:
    seconds: float = 60.0
    interval: float = 0.5
    reps: int = 2                      # grid replicas (exploration diversity)
    k: int = 1
    min_volume_bytes: float = 64 * 1024
    contention_frac: float = 0.25      # cells that get a live noisy neighbour
    noise_rate: float = 1.2e9          # neighbour per-thread issue rate [B/s]
    seed: int = 0
    grid: CellGrid = dataclasses.field(default_factory=CellGrid)


def _cell_specs(cfg: CampaignConfig):
    """One 2-client × 1-OST ScenarioSpec per (cell, rep); returns the
    specs plus the per-element op codes.

    Every element has the same structure (2 workload rows, 1 stripe
    entry each, disjoint clients → single wave), so the whole grid
    stacks into one batch.  The neighbour row rides on its *own* client
    (fresh id — cf. the `core/dataset.collect` contention fix) and is
    disabled by ``thread_rate=0`` in uncontended cells.
    """
    rng = np.random.default_rng(cfg.seed)
    cells = list(itertools.product((READ, WRITE), cfg.grid.patterns,
                                   cfg.grid.req_sizes, cfg.grid.threads))
    specs, ops = [], []
    for rep in range(cfg.reps):
        for i, (op, rnd, req, thr) in enumerate(cells):
            noisy = rng.random() < cfg.contention_frac
            measure = Workload(client=0, op=op, req_size=float(req),
                               randomness=float(rnd), n_threads=int(thr),
                               osts=(0,), name=f"cell{i}")
            noise = Workload(client=1, op=READ, req_size=1 * 2**20,
                             randomness=0.3, n_threads=4, osts=(0,),
                             thread_rate=cfg.noise_rate if noisy else 0.0,
                             name="noise")
            specs.append(ScenarioSpec(
                name=f"campaign_cell{i}_rep{rep}", n_clients=2, n_osts=1,
                workloads=(measure, noise), seed=cfg.seed * 1000 + rep))
            ops.append(op)
    return specs, np.asarray(ops, dtype=np.int64)


def collect_batch(cfg: CampaignConfig = CampaignConfig(),
                  space: ConfigSpace = SPACE) -> dict:
    """The collection sweep on the batch path.

    Same explore/label alternation as :func:`repro.core.dataset.collect`
    — observe H_t under the held θ, apply a random θ′, label one
    interval later — but every per-cell step is one masked array op over
    the whole batch and every interval is one vmapped engine launch.
    Returns ``{'read': (X, y), 'write': (X, y)}``.
    """
    rng = np.random.default_rng(cfg.seed)
    specs, ops = _cell_specs(cfg)
    batch = stack_scenarios([build(s) for s in specs])
    n_cells = len(batch)
    # measurement interface = (client 0, OST 0) = local OSC 0 per element
    cols = np.arange(n_cells, dtype=np.int64) * batch.n_osc
    port = BatchPort(batch, cols=cols)

    steps = max(int(round(cfg.interval / batch.params.tick)), 1)
    n_intervals = int(round(cfg.seconds / cfg.interval))
    engine = BatchEngine(batch.params, batch.topo, steps)

    theta_feats = space.as_features()
    configs = space.configs()
    m = len(configs)
    is_read = ops == READ

    prev = port.probe_all()
    hist: list = []
    pend_active = np.zeros(n_cells, dtype=bool)
    pend_tput = np.zeros(n_cells)
    pend_feats = {READ: np.zeros((n_cells, feature_dim(READ, cfg.k)),
                                 dtype=np.float32),
                  WRITE: np.zeros((n_cells, feature_dim(WRITE, cfg.k)),
                                  dtype=np.float32)}
    Xs = {READ: [], WRITE: []}
    ys = {READ: [], WRITE: []}

    for it in range(n_intervals):
        sched = batch.schedule(it * steps, steps)
        batch.state, batch.wstate = engine.run_interval(
            batch.table, batch.state, batch.wstate, sched)
        cur = port.probe_all()
        snap = snapshot_all(prev, cur)
        prev = cur
        hist.append(snap)
        hist = hist[-(cfg.k + 1):]

        vol = np.where(is_read, snap.read_volume, snap.write_volume)
        tput = np.where(is_read, snap.read[:, 0], snap.write[:, 0])

        # finalize last interval's exploration with this interval's label
        was_pending = pend_active.copy()
        label_ok = was_pending & (pend_tput > 0) & (vol >= cfg.min_volume_bytes)
        for op in (READ, WRITE):
            sel = label_ok & (ops == op)
            if sel.any():
                Xs[op].append(pend_feats[op][sel].copy())
                ys[op].append((tput[sel] / pend_tput[sel]
                               > 1.0 + EPS_IMPROVE).astype(float))
        pend_active[:] = False

        # explore on alternating intervals (cells that just labeled rest
        # one interval so H_t reflects a steady state under the new θ)
        if len(hist) < cfg.k + 1:
            continue
        ready = (~was_pending) & (vol >= cfg.min_volume_bytes)
        rows = np.nonzero(ready)[0]
        if rows.size == 0:
            continue
        j = rng.integers(m, size=rows.size)
        for op in (READ, WRITE):
            sel = ops[rows] == op
            r_op = rows[sel]
            if r_op.size == 0:
                continue
            F = fleet_feature_matrix(hist, op, r_op, theta_feats)
            pend_feats[op][r_op] = F[np.arange(r_op.size) * m + j[sel]]
        theta = np.asarray([configs[x] for x in j], dtype=np.int64)
        port.set_knobs_many(cols[rows], theta[:, 0], theta[:, 1])
        pend_tput[rows] = tput[rows]
        pend_active[rows] = True

    def _cat(op):
        if not Xs[op]:
            dim = feature_dim(op, cfg.k)
            return (np.zeros((0, dim), dtype=np.float32), np.zeros(0))
        return (np.concatenate(Xs[op]).astype(np.float32),
                np.concatenate(ys[op]))

    return {"read": _cat(READ), "write": _cat(WRITE)}


# ---------------------------------------------------------------------- #
# versioned model artifacts
# ---------------------------------------------------------------------- #
_VERSION_RE = re.compile(r"^v(\d{3,})$")


def latest_version(root: str) -> str | None:
    """Resolve the newest ``vNNN`` directory under ``root`` (the LATEST
    pointer when present, else the highest version on disk)."""
    pointer = os.path.join(root, "LATEST")
    if os.path.exists(pointer):
        with open(pointer) as f:
            v = f.read().strip()
        if os.path.isdir(os.path.join(root, v)):
            return v
    if not os.path.isdir(root):
        return None
    versions = sorted((v for v in os.listdir(root) if _VERSION_RE.match(v)),
                      key=lambda v: int(_VERSION_RE.match(v).group(1)))
    return versions[-1] if versions else None


def save_versioned(model: DIALModel, root: str = "models/lab",
                   meta: dict | None = None) -> str:
    """Persist a campaign's model as the next ``models/lab/vNNN/``.

    Layout: ``dial.read.npz`` / ``dial.write.npz`` (the standard
    :meth:`DIALModel.save` prefix layout, so ``DIALModel.load(dir +
    "/dial")`` — and therefore ``run_fleet`` — consumes it directly),
    plus ``manifest.json`` and an updated ``LATEST`` pointer.
    """
    os.makedirs(root, exist_ok=True)
    prev = latest_version(root)
    nxt = "v%03d" % ((int(_VERSION_RE.match(prev).group(1)) + 1)
                     if prev else 1)
    d = os.path.join(root, nxt)
    os.makedirs(d)
    model.save(os.path.join(d, "dial"))
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"version": nxt, **(meta or {})}, f, indent=2,
                  default=str)
    with open(os.path.join(root, "LATEST"), "w") as f:
        f.write(nxt + "\n")
    return d


def load_versioned(root: str = "models/lab", version: str | None = None,
                   backend: str = "numpy", strict: bool = True) -> DIALModel:
    """Load one versioned artifact, refusing tampered/mismatched ones.

    When both the campaign ``manifest.json`` and the model's own
    ``dial.meta.json`` carry training provenance (trainer backend +
    dataset row counts/hash), they must agree — a mismatch means the
    forests on disk are not the ones this campaign trained (partial
    copy, stale overwrite), which ``strict`` turns into an error.
    """
    v = version or latest_version(root)
    if v is None:
        raise FileNotFoundError(f"no campaign artifacts under {root!r}")
    d = os.path.join(root, v)
    model = DIALModel.load(os.path.join(d, "dial"), backend=backend)
    if strict:
        manifest_meta = None
        manifest_ok = True
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest_meta = json.load(f).get("train_meta")
        except (OSError, ValueError):
            manifest_ok = False
        if not manifest_ok and model.train_meta:
            # the mirror of the missing-dial.meta case below: the model
            # carries provenance but the manifest that should confirm it
            # is gone/unreadable (save_versioned always writes one)
            raise ValueError(
                f"artifact {d!r} is inconsistent: the model carries "
                "train_meta but manifest.json is missing or unreadable "
                "(pass strict=False to override)")
        if manifest_meta is not None and manifest_meta != model.train_meta:
            # DIALModel.load maps a missing/corrupt dial.meta.json to {} —
            # that is exactly the partial-copy/tamper case, not a pass
            if not model.train_meta:
                raise ValueError(
                    f"artifact {d!r} is inconsistent: manifest carries "
                    "train_meta but the model's dial.meta.json is missing "
                    "or unreadable (forests on disk do not match the "
                    "campaign that wrote the manifest; pass strict=False "
                    "to override)")
            raise ValueError(
                f"artifact {d!r} is inconsistent: manifest train_meta "
                f"{manifest_meta} != model meta {model.train_meta} "
                "(forests on disk do not match the campaign that wrote "
                "the manifest; pass strict=False to override)")
    return model


def run_campaign(cfg: CampaignConfig = CampaignConfig(),
                 out_root: str = "models/lab",
                 gbdt_params: GBDTParams | None = None,
                 smoke: bool = False, trainer_backend: str = "numpy"):
    """collect → train → save one versioned artifact.

    ``smoke`` marks the manifest so quality-sensitive consumers
    (:func:`repro.lab.evaluate.default_model`) can refuse to silently
    inherit a CI-sized model; ``trainer_backend`` selects the GBDT
    training path (``"jax"`` = both forests in one vmapped launch) and
    is recorded — with the dataset fingerprint — in both the manifest
    and the model's own metadata.  Returns ``(artifact_dir, model,
    info)``.
    """
    data = collect_batch(cfg)
    info = {
        "smoke": bool(smoke),
        "config": dataclasses.asdict(cfg),
        "samples": {op: int(len(data[op][0])) for op in ("read", "write")},
        "positive_rate": {op: (float(data[op][1].mean())
                               if len(data[op][1]) else 0.0)
                          for op in ("read", "write")},
    }
    model = train_models(data, gbdt_params, backend=trainer_backend)
    info["train_meta"] = model.train_meta
    d = save_versioned(model, out_root, meta=info)
    return d, model, info
