"""Batched scenario execution: N scenarios per jitted launch.

The fused interval scan (:mod:`repro.pfs.engine_jax`) removed the
per-tick Python round trip; this module removes the per-*scenario*
process.  ``stack_scenarios`` stacks B
:class:`~repro.lab.scenarios.BuiltScenario` pytrees along a new leading
batch axis, and :class:`BatchEngine` ``vmap``-s the identical
``demand_step ∘ engine_step`` interval over that axis — hundreds of
independent scenarios advance one tuning interval in a single device
dispatch.

Structurally-identical scenarios (same topology dimensions, same
workload-table shapes) stack directly, exactly as before.  Mismatched
structures stack **ragged**: every element is padded up to a shared
bucket shape class (:func:`pad_class` — OSTs / clients / workload rows /
stripe entries rounded to the next power of two) with phantom OSTs,
clients, and workload rows whose parameters are exact arithmetic
identities (zero demand, neutral disturbance, inert rows) and whose
validity masks are off.  Padded runs pin bit-equal θ trajectories and
≤1e-6 counters against unpadded per-scenario runs (tests/test_ragged.py)
because every phantom contribution is a literal ``+ 0.0``.
:func:`bucket_scenarios` groups a heterogeneous catalog by shape class
so the whole registry executes in one fused dispatch per bucket.

In-batch DIAL tuning reuses the fleet machinery unchanged: a batch of B
scenarios with n interfaces each *is* a fleet of ``B * n`` interfaces
(every row of every fleet matrix is already built purely from one
interface's local counters), so :class:`BatchPort` exposes the stacked
state through the :class:`~repro.core.fleet.FleetPort` protocol and one
:class:`~repro.core.fleet.FleetAgent` tunes every scenario in the batch
with one forest launch per interval.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.fleet import FleetAgent
from repro.core.tuner import TunerParams
from repro.kernels.segment_reduce.ops import make_segment_sum
from repro.lab.scenarios import BuiltScenario, make_schedule
from repro.pfs.engine_jax import engine_step_jax
from repro.pfs.state import (_STATE_FIELDS, Disturbance, SimParams, SimState,
                             SimTopo, init_state)
from repro.pfs.stats import FleetStats
from repro.pfs.workloads import WorkloadState, WorkloadTable


def _tree_stack(trees):
    """Stack a list of identical-structure pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                        *trees)


@dataclasses.dataclass
class ScenarioBatch:
    """B stacked scenarios: one pytree per engine-level piece.

    ``table`` / ``state`` / ``wstate`` arrays carry a leading ``(B, ...)``
    batch axis; ``specs`` keeps the per-element provenance (used to
    rebuild each element's disturbance schedule every interval).

    Ragged (pad-and-mask) batches additionally carry ``osc_cols`` — one
    int array per element listing its *real* interface columns within
    the padded layout, in original interface order.  Empty ``osc_cols``
    means nothing was padded (every column real), the historical layout.
    """

    params: SimParams
    topo: SimTopo
    table: WorkloadTable        # batched arrays
    state: SimState             # batched arrays
    wstate: WorkloadState       # batched arrays
    specs: tuple = ()           # per-element ScenarioSpec (may be empty)
    osc_cols: tuple = ()        # per-element real columns (ragged only)

    def __len__(self) -> int:
        return int(np.asarray(self.state.window_pages).shape[0])

    @property
    def n_osc(self) -> int:
        return self.topo.n_osc

    def element_cols(self, b: int) -> np.ndarray:
        """Element ``b``'s real interface columns, in original order."""
        if self.osc_cols:
            return np.asarray(self.osc_cols[b], dtype=np.int64)
        return np.arange(self.n_osc, dtype=np.int64)

    def real_tune_cols(self) -> np.ndarray:
        """Fleet columns (``b * n + osc``) of every real interface."""
        n = self.n_osc
        return np.concatenate([b * n + self.element_cols(b)
                               for b in range(len(self))])

    def pad_stats(self) -> dict:
        """Padding-waste accounting (the fuzz histogram's raw numbers)."""
        n = self.n_osc
        real = sum(len(self.element_cols(b)) for b in range(len(self)))
        total = len(self) * n
        return {"n_elems": len(self), "n_osc": n,
                "real_interfaces": int(real),
                "phantom_interfaces": int(total - real),
                "total_interfaces": int(total),
                "pad_waste": float(1.0 - real / total) if total else 0.0}

    def schedule(self, t0_tick: int, n_ticks: int) -> Disturbance:
        """Stacked ``(B, n_ticks, ...)`` disturbance schedule for one
        interval (neutral for elements without events / without specs)."""
        if self.specs:
            per = [make_schedule(s.events, self.topo, self.params,
                                 t0_tick, n_ticks) for s in self.specs]
        else:
            per = [Disturbance.neutral(self.topo, n_ticks=n_ticks)
                   for _ in range(len(self))]
        return _tree_stack(per)

    # ------------------------------------------------------------------ #
    def throughput(self, seconds: float) -> dict:
        """Per-element aggregate MB/s from the cumulative counters.

        Ragged batches sum each element's real columns by ordered
        gather, so the float summation order is exactly the unpadded
        run's — per-element figures are bit-equal, not merely close.
        """
        done = np.asarray(self.state.ctr_bytes_done)      # (B, 2, n)
        if self.osc_cols:
            read = np.array([done[b, 0, self.element_cols(b)].sum()
                             for b in range(len(self))]) / seconds / 1e6
            write = np.array([done[b, 1, self.element_cols(b)].sum()
                              for b in range(len(self))]) / seconds / 1e6
        else:
            read = done[:, 0].sum(axis=1) / seconds / 1e6
            write = done[:, 1].sum(axis=1) / seconds / 1e6
        return {"read_mbs": read, "write_mbs": write,
                "total_mbs": read + write}


# the structure fields strict stacking compares, in check order — the
# refusal message names the first mismatching one with both values
_STRUCTURE_FIELDS = ("params", "n_clients", "n_osts", "n_rows", "n_waves",
                     "n_entries")


def structure_key(b: BuiltScenario) -> tuple:
    """The structural signature batch elements must share to stack
    *without padding*.

    Physics constants, topology dimensions, and the workload-table shape
    (rows / waves / flattened stripe entries): two built scenarios with
    equal keys always stack — and hit the same compiled vmapped program
    shape — regardless of how their workload parameters, disturbance
    schedules, or initial knobs differ.  Mismatched keys stack too via
    ragged pad-and-mask bucketing (:func:`pad_class`); this key is the
    strict (``ragged=False``) grouping and the zero-waste fast path.
    """
    return (b.params, b.topo.n_clients, b.topo.n_osts,
            len(b.table), b.table.n_waves, len(b.table.entry_row))


def _structure_mismatch(built: list[BuiltScenario]):
    """First (element index, field name, value, element-0 value) whose
    structure differs from element 0's, or ``None`` if all match."""
    k0 = structure_key(built[0])
    for i, b in enumerate(built[1:], start=1):
        k = structure_key(b)
        if k != k0:
            f = next(j for j in range(len(k)) if k[j] != k0[j])
            return i, _STRUCTURE_FIELDS[f], k[f], k0[f]
    return None


def _p2(x: int) -> int:
    """Next power of two ≥ x (bucket dims quantize to powers of two so a
    heterogeneous catalog lands in a handful of shape classes)."""
    return 1 << max(int(x) - 1, 0).bit_length()


def pad_class(b: BuiltScenario) -> tuple:
    """The padded shape class ``(params, C, O, R, E, W)`` of a scenario.

    Clients / OSTs round up to the next power of two; workload rows and
    stripe entries round up to ``p2(x + 1)`` so every padded table owns
    at least one phantom row — phantom stripe entries must reference an
    inactive row to contribute exact zeros.  ``params`` rides the key
    because physics constants are baked into the compiled program and
    cannot be padded away.
    """
    return (b.params, _p2(b.topo.n_clients), _p2(b.topo.n_osts),
            _p2(len(b.table) + 1), _p2(len(b.table.entry_row) + 1),
            _p2(b.table.n_waves))


def pad_scenario(b: BuiltScenario, cls: tuple) -> BuiltScenario:
    """Pad one built scenario up to a bucket shape class.

    Every addition is an exact arithmetic identity: phantom OSTs and
    clients join the dense topology with validity masks off and
    fresh-idle per-interface state (zero queues, zero demand, neutral
    disturbance — every reduction they join adds a literal ``0.0``);
    phantom workload rows are inert (:meth:`WorkloadTable.padded`).
    Real interfaces keep their original interface *order* under the
    remap ``new = (old // O) * O_pad + old % O_pad``, so ordered
    reductions over real columns regroup nothing.
    """
    params, nc, no, nr, ne, nw = cls
    if params != b.params:
        raise ValueError("pad class params mismatch")
    topo_old = b.topo
    if (nc, no) == (topo_old.n_clients, topo_old.n_osts):
        topo = topo_old
        remap = None
    else:
        base = SimTopo.dense(nc, no)
        ost_valid = np.zeros(no, dtype=bool)
        ost_valid[:topo_old.n_osts] = topo_old.ost_valid_mask()
        client_valid = np.zeros(nc, dtype=bool)
        client_valid[:topo_old.n_clients] = topo_old.client_valid_mask()
        topo = dataclasses.replace(base, ost_valid=ost_valid,
                                   client_valid=client_valid)
        old_osc = np.arange(topo_old.n_osc, dtype=np.int64)
        remap = (old_osc // topo_old.n_osts) * no + old_osc % topo_old.n_osts

    state = init_state(topo)
    for f in _STATE_FIELDS:
        old = getattr(b.state, f)
        if f in ("now", "tick_index"):
            setattr(state, f, old)
        elif f in ("ost_valid", "client_valid"):
            pass    # init_state already took them from the padded topo
        elif remap is None:
            setattr(state, f, np.array(np.asarray(old)))
        else:
            new = getattr(state, f)
            new[..., remap] = np.asarray(old)

    table = b.table.padded(nr, ne, nw, topo.n_osc, osc_remap=remap)
    pr = nr - len(b.table)
    wstate = WorkloadState(
        issued=np.concatenate([np.asarray(b.wstate.issued, dtype=float),
                               np.zeros(pr)]),
        done_base=np.concatenate([np.asarray(b.wstate.done_base,
                                             dtype=float), np.zeros(pr)]))
    return BuiltScenario(spec=b.spec, params=b.params, topo=topo,
                         table=table, state=state, wstate=wstate)


def stack_scenarios(built: list[BuiltScenario],
                    ragged: bool = True) -> ScenarioBatch:
    """Stack built scenarios into one batch.

    Structurally-identical elements stack directly (bit-for-bit the
    historical layout, zero padding).  Mismatched structures are padded
    up to the elementwise-max :func:`pad_class` and stacked ragged —
    unless ``ragged=False``, which restores the strict refusal (the
    error names the first mismatching structure field and both values).
    ``SimParams`` must always match: physics is baked into the compiled
    program and cannot be masked off.
    """
    if not built:
        raise ValueError("empty scenario batch")
    b0 = built[0]
    for b in built[1:]:
        if b.params != b0.params:
            raise ValueError("batch elements must share SimParams "
                             "(the engine closes over element 0's)")
    mm = _structure_mismatch(built)
    if mm is not None and not ragged:
        i, field, v, v0 = mm
        raise ValueError(
            f"batch elements must share workload-table structure to "
            f"stack with ragged=False: element {i} has {field}={v} but "
            f"element 0 has {field}={v0} (drop ragged=False to pad-and-"
            f"mask mismatched structures into one bucket)")
    osc_cols: tuple = ()
    if mm is not None:
        classes = [pad_class(b) for b in built]
        cls = (b0.params,) + tuple(
            max(c[j] for c in classes) for j in range(1, 6))
        built = [pad_scenario(b, cls) for b in built]
        osc_cols = tuple(np.nonzero(b.topo.osc_valid())[0].astype(np.int64)
                         for b in built)
        b0 = built[0]
    # per-element validity masks live on the stacked state; the shared
    # static topology is the all-valid bucket shape
    topo = (b0.topo if mm is None
            else dataclasses.replace(b0.topo, ost_valid=None,
                                     client_valid=None))
    return ScenarioBatch(
        params=b0.params,
        topo=topo,
        table=_tree_stack([b.table for b in built]),
        state=_tree_stack([b.state for b in built]),
        wstate=_tree_stack([b.wstate for b in built]),
        specs=tuple(b.spec for b in built),
        osc_cols=osc_cols,
    )


def bucket_scenarios(built: list[BuiltScenario], ragged: bool = True):
    """Group a heterogeneous catalog into stackable buckets.

    Returns ``[(indices, batch), ...]`` where ``indices`` maps each
    batch element back to its position in ``built``.  With ``ragged``
    (default) scenarios group by :func:`pad_class` — the whole registry
    collapses to a handful of padded buckets, each one fused dispatch.
    With ``ragged=False`` they group by exact :func:`structure_key`
    (the historical per-structure bucketing, more buckets, no padding).
    Bucket order is deterministic: sorted by shape class, ties by first
    element index.
    """
    groups: dict = {}
    for i, b in enumerate(built):
        key = pad_class(b) if ragged else structure_key(b)
        groups.setdefault(key, []).append(i)
    out = []
    for key in sorted(groups, key=lambda k: tuple(k[1:])):
        idxs = groups[key]
        out.append((idxs, stack_scenarios([built[i] for i in idxs],
                                          ragged=ragged)))
    return out


# ---------------------------------------------------------------------- #
# vmapped fused interval
# ---------------------------------------------------------------------- #
class BatchEngine:
    """One tuning interval for the whole batch per jitted call.

    ``vmap`` of the exact :class:`~repro.pfs.engine_jax.FusedEngine`
    interval body over the batch axis (state, workload table, and
    disturbance schedule all batched), jitted once per
    (topology, table-structure, n_ticks) shape.  ``seg_backend``
    defaults to the XLA ``segment_sum`` path, which vmaps cleanly on
    every platform; the Pallas one-hot-matmul kernel remains available
    for unbatched TPU intervals via :class:`FusedEngine`.
    """

    def __init__(self, params: SimParams, topo: SimTopo, n_ticks: int,
                 seg_backend: str = "jax"):
        self.params = params
        self.topo = topo
        self.n_ticks = int(n_ticks)
        segsum = make_segment_sum(seg_backend)

        def interval(table, state, wstate, sched):
            def body(carry, dist):
                st, ws = carry
                demand, ws = table.demand_step(params, ws, st,
                                               xp=jnp, segsum=segsum)
                st = engine_step_jax(params, topo, st, demand, segsum,
                                     disturbance=dist)
                return (st, ws), None

            (state, wstate), _ = jax.lax.scan(
                body, (state, wstate), sched, length=self.n_ticks)
            return state, wstate

        self._run = jax.jit(jax.vmap(interval))

    def run_interval(self, table: WorkloadTable, state: SimState,
                     wstate: WorkloadState, sched: Disturbance):
        """Advance every element one interval; numpy in, numpy out."""
        with enable_x64():
            args = jax.tree.map(jnp.asarray, (table, state, wstate, sched))
            jstate, jws = self._run(*args)
            jstate, jws = jax.tree.map(
                lambda x: x.block_until_ready()
                if hasattr(x, "block_until_ready") else x, (jstate, jws))
        return jax.tree.map(np.array, jstate), jax.tree.map(np.array, jws)


# ---------------------------------------------------------------------- #
# in-batch DIAL tuning: the batch as one fleet
# ---------------------------------------------------------------------- #
class BatchPort:
    """:class:`~repro.core.fleet.FleetPort` over a stacked batch.

    Interface ``(b, osc)`` of the batch is fleet column ``b * n + osc``.
    ``cols`` restricts the exposed interfaces (e.g. only the DIAL-policy
    element of an evaluation batch, or only measurement cells of a
    campaign); default is every interface of every element.
    """

    def __init__(self, batch: ScenarioBatch, cols=None):
        self.batch = batch
        if cols is None:
            # every *real* interface — identical to the historical
            # all-columns default on unpadded batches, and keeps phantom
            # padded interfaces out of probes and knob write-back
            cols = batch.real_tune_cols()
        self._cols = np.asarray(cols, dtype=np.int64)

    def osc_ids(self) -> np.ndarray:
        return self._cols

    def probe_all(self) -> FleetStats:
        s = self.batch.state
        c = self._cols

        def f2(a):  # (B, 2, n) -> (2, len(cols))
            return np.moveaxis(np.asarray(a), 1, 0).reshape(2, -1)[:, c].copy()

        def f1(a):  # (B, n) -> (len(cols),)
            return np.asarray(a).reshape(-1)[c].copy()

        return FleetStats(
            t=float(np.ravel(np.asarray(s.now))[0]),
            oscs=c,
            bytes_done=f2(s.ctr_bytes_done),
            rpcs_sent=f2(s.ctr_rpcs_sent),
            rpc_bytes=f2(s.ctr_rpc_bytes),
            partial_rpcs=f2(s.ctr_partial_rpcs),
            latency_sum=f2(s.ctr_latency_sum),
            rpcs_done=f2(s.ctr_rpcs_done),
            req_count=f2(s.ctr_req_count),
            req_bytes=f2(s.ctr_req_bytes),
            pending_integral=f2(s.ctr_pending_integral),
            active_integral=f2(s.ctr_active_integral),
            cache_hit_bytes=f1(s.ctr_cache_hit_bytes),
            block_time=f1(s.ctr_block_time),
            dirty_integral=f1(s.ctr_dirty_integral),
            grant_integral=f1(s.ctr_grant_integral),
            randomness=f2(s.randomness),
            window_pages=f1(s.window_pages).astype(np.int64),
            rpcs_in_flight=f1(s.rpcs_in_flight).astype(np.int64),
        )

    def set_knobs_many(self, osc_ids, window_pages, rpcs_in_flight) -> None:
        ids = np.atleast_1d(np.asarray(osc_ids, dtype=np.int64))
        b, o = np.divmod(ids, self.batch.n_osc)
        s = self.batch.state
        s.window_pages[b, o] = np.asarray(window_pages, dtype=np.int64)
        s.rpcs_in_flight[b, o] = np.asarray(rpcs_in_flight, dtype=np.int64)


def run_batch(batch: ScenarioBatch, model=None, seconds: float = 10.0,
              interval: float = 0.5, seg_backend: str = "jax",
              tuner_params: TunerParams | None = None,
              tune_cols=None, engine: BatchEngine | None = None,
              fused: bool = False, mesh=None, trace=None,
              intervene=None):
    """Drive a whole batch for ``seconds``, optionally DIAL-tuning.

    The batched counterpart of :func:`repro.core.fleet.run_fleet`: every
    interval is one vmapped engine launch followed (when ``model`` is
    given) by one fleet tuning tick over ``tune_cols`` (default: every
    interface of every element).  Returns the :class:`FleetAgent` (or
    ``None`` when untuned); final state lives on ``batch.state``.

    ``fused=True`` routes the whole run through the device-resident loop
    (:class:`~repro.pfs.loop_jax.FusedLoop` vmapped over the batch): one
    jitted dispatch covers every interval of engine **and** tuning, with
    each element's whole-run disturbance schedule compiled once up front
    instead of rebuilt per interval.  Knob trajectories are identical to
    the host path (tests/test_loop_fused.py); the return value is a
    :class:`~repro.pfs.loop_jax.FusedLoopResult`, whose ``decisions``
    list matches the host agent's interval-aligned records.

    ``mesh`` (fused only) shards the batch axis across a 1-D device mesh
    (:func:`repro.distributed.sharding.fleet_mesh`): each device runs
    its slice of the batch device-local, no collectives — decisions
    identical to the single-device dispatch (tests/test_shard.py).

    ``trace`` (a :class:`~repro.obs.schema.TraceConfig`) opts the run
    into telemetry.  Fused runs accumulate the records in-dispatch and
    return them on ``result.trace`` (normalize with
    :meth:`~repro.obs.schema.RunTrace.from_fused`); on the split
    tuned/untuned path the timeline covers every element while decision
    columns of never-tuned elements carry the inert placeholder record
    (``decided`` false, applied θ, zeroed gate metrics) — the lean
    engine-only program has no decision path to observe.  The host path
    mirrors decision provenance through the fleet agent's
    :class:`~repro.obs.host.HostTracer` (``fleet.trace``; no timeline —
    the interval engine exposes no per-tick state).

    ``intervene`` (fused only) is a per-interface
    :class:`~repro.pfs.loop_jax.Intervention` with ``(B, n)`` leading
    shape — the counterfactual-replay hook used by
    :mod:`repro.obs.diagnose`.  Rows of never-tuned elements are
    dropped with the element (the lean program has no decision path to
    intervene on).
    """
    steps = max(int(round(interval / batch.params.tick)), 1)
    n_intervals = int(round(seconds / interval))

    if fused:
        if model is None:
            raise ValueError("fused=True requires a model (untuned runs "
                             "gain nothing from fusing the decision loop)")
        if engine is not None:
            raise ValueError("`engine` configures the per-interval host "
                             "path; the fused path compiles its own "
                             "whole-run programs (pass seg_backend "
                             "instead)")
        return _run_batch_fused(batch, model, steps, n_intervals,
                                tuner_params, seg_backend, tune_cols,
                                mesh=mesh, trace=trace,
                                intervene=intervene)
    if intervene is not None:
        raise ValueError("intervene= rides the fused batch path — "
                         "pass fused=True")
    if mesh is not None:
        raise ValueError("mesh sharding rides the fused batch path — "
                         "pass fused=True with mesh")
    if trace is not None and model is None:
        raise ValueError("host-path tracing records decision provenance "
                         "through the fleet agent — untuned host batches "
                         "have neither (use fused=True for timelines)")

    engine = engine or BatchEngine(batch.params, batch.topo, steps,
                                   seg_backend=seg_backend)
    fleet = None
    if model is not None:
        tracer = None
        if trace is not None:
            from repro.obs.host import HostTracer
            tracer = HostTracer(trace, batch.params, batch.topo)
        fleet = FleetAgent(BatchPort(batch, cols=tune_cols), model,
                           tuner_params=tuner_params, tracer=tracer)
        fleet.trace = None
    # precompile the whole run's disturbance schedule once and slice per
    # interval — make_schedule is a pure function of the absolute tick
    # index, so slicing the full-run arrays is exactly the per-interval
    # rebuild without B Python rebuilds per interval
    full_sched = batch.schedule(0, n_intervals * steps)
    for i in range(n_intervals):
        sched = jax.tree.map(
            lambda a: a[:, i * steps:(i + 1) * steps], full_sched)
        batch.state, batch.wstate = engine.run_interval(
            batch.table, batch.state, batch.wstate, sched)
        if fleet is not None:
            fleet.tick()
    if fleet is not None and fleet.tracer is not None:
        fleet.trace = fleet.tracer.run_trace(
            fleet.oscs, interval, batch.params.tick)
    return fleet


# compiled fused loops, reused across run_batch calls: scenarios that
# share (model, physics, topology dims, cadence) hit the same FusedLoop
# instance, and jax.jit then caches per (table/state) *structure*, so an
# evaluate sweep compiles a handful of programs instead of one per call.
# Ragged bucketing strengthens this: every scenario in a bucket shares
# the padded topology, so the key is effectively (bucket shape, mesh).
_FUSED_LOOPS: dict = {}

# hit/miss counters for the compiled-loop cache, exposed through bench
# provenance (benchmarks/ragged_scaling.py) so padding waste and
# recompiles are observable rather than inferred
_CACHE_STATS = {"hits": 0, "misses": 0}


def loop_cache_stats() -> dict:
    """Compiled-loop cache counters: ``hits`` / ``misses`` / ``size``."""
    return {**_CACHE_STATS, "size": len(_FUSED_LOOPS)}


def reset_loop_cache_stats() -> None:
    _CACHE_STATS["hits"] = _CACHE_STATS["misses"] = 0


def _cached_loop(params, topo, steps, model, tuner_params, seg_backend,
                 tuned: bool, mesh=None, trace=None):
    from repro.pfs.loop_jax import FusedLoop

    key = (None if model is None else id(model),
           0 if model is None else model._version,
           params, topo.n_clients, topo.n_osts,
           # same-sized topologies can differ in wiring (osc -> client /
           # OST maps); the compiled program bakes the wiring in
           np.asarray(topo.osc_client).tobytes(),
           np.asarray(topo.osc_ost).tobytes(),
           int(steps), tuner_params, seg_backend, tuned,
           mesh,    # jax Mesh hashes by (devices, axis_names)
           trace)   # TraceConfig is frozen/hashable; traced programs
    #                 have different outputs and must not alias untraced
    if key not in _FUSED_LOOPS:
        _CACHE_STATS["misses"] += 1
        if len(_FUSED_LOOPS) >= 32:          # bound the cache: evict the
            _FUSED_LOOPS.pop(next(iter(_FUSED_LOOPS)))   # oldest (FIFO)
        # the model is kept alive alongside its loop: the key uses
        # id(model), which is only unique while the object lives — a
        # cached entry must therefore pin the model so a recycled id can
        # never alias someone else's forests to this compiled program
        _FUSED_LOOPS[key] = (FusedLoop(
            params, topo, steps, model, tuner_params=tuner_params,
            seg_backend=seg_backend, batched=True, tuned=tuned,
            mesh=mesh, trace=trace), model)
    else:
        _CACHE_STATS["hits"] += 1
        _FUSED_LOOPS[key][0].timers.add("loop_cache_hit", 0.0)
    return _FUSED_LOOPS[key][0]


def _run_batch_fused(batch: ScenarioBatch, model, steps: int,
                     n_intervals: int, tuner_params, seg_backend: str,
                     tune_cols, mesh=None, trace=None, intervene=None):
    """One (or two) jitted dispatches for the whole batched run.

    Elements with at least one tuned interface go through the
    device-resident decision loop; the rest (e.g. the static-θ arms of
    an evaluate comparison) run a lean engine-only fused program — no
    featurize/forest/Algorithm-1 work for elements that can never
    decide.  Final states are scattered back in element order.
    """
    import dataclasses as _dc

    b, n = len(batch), batch.n_osc
    mask = np.zeros((b, n), dtype=bool)
    cols = (batch.real_tune_cols() if tune_cols is None
            else np.asarray(tune_cols, dtype=np.int64))
    mask[cols // n, cols % n] = True
    # the whole run's schedule, compiled once (pure function of the
    # absolute tick index -> identical to the per-interval rebuild)
    sched = batch.schedule(0, n_intervals * steps)

    t_idx = np.nonzero(mask.any(axis=1))[0]
    u_idx = np.nonzero(~mask.any(axis=1))[0]
    take = lambda tree, idx: jax.tree.map(lambda a: np.asarray(a)[idx],
                                          tree)

    loop_t = _cached_loop(batch.params, batch.topo, steps, model,
                          tuner_params, seg_backend, tuned=True, mesh=mesh,
                          trace=trace)
    if len(u_idx) == 0:
        result = loop_t.run(batch.table, batch.state, batch.wstate,
                            n_intervals, schedule=sched, tune_mask=mask,
                            intervene=intervene)
        batch.state, batch.wstate = result.state, result.wstate
        return result

    res_t = loop_t.run(take(batch.table, t_idx), take(batch.state, t_idx),
                       take(batch.wstate, t_idx), n_intervals,
                       schedule=take(sched, t_idx), tune_mask=mask[t_idx],
                       intervene=(None if intervene is None
                                  else take(intervene, t_idx)))
    loop_u = _cached_loop(batch.params, batch.topo, steps, None,
                          tuner_params, seg_backend, tuned=False, mesh=mesh,
                          trace=trace)
    res_u = loop_u.run(take(batch.table, u_idx), take(batch.state, u_idx),
                       take(batch.wstate, u_idx), n_intervals,
                       schedule=take(sched, u_idx))

    def merge(a_t, a_u):
        out = np.empty((b,) + a_t.shape[1:], dtype=a_t.dtype)
        out[t_idx] = a_t
        out[u_idx] = a_u
        return out

    state = jax.tree.map(merge, res_t.state, res_u.state)
    wstate = jax.tree.map(merge, res_t.wstate, res_u.wstate)
    # decision columns come back indexed within the tuned sub-batch;
    # remap to the caller's element order (b * n + osc fleet columns) —
    # and merge the trace to the same order so both views agree.
    for r in res_t.decisions:
        r.oscs = t_idx[r.oscs // n] * n + r.oscs % n
    merged_trace = _merge_split_trace(res_t.trace, res_u.trace, b, t_idx,
                                      u_idx, state)
    batch.state, batch.wstate = state, wstate
    return _dc.replace(res_t, state=state, wstate=wstate,
                       trace=merged_trace, hist=None)


def _merge_split_trace(tr_t, tr_u, b, t_idx, u_idx, state):
    """Reassemble the two sub-batches' traces in caller element order.

    The timeline exists on both programs and merges losslessly.
    Decision columns only exist on the tuned program: never-tuned
    elements get the inert placeholder record — ``decided`` false
    everywhere, θ = the element's applied knobs (their knobs never
    change, so the final state is the whole-run value), warmup flags
    copied from the tuned sub-batch (pure functions of the interval
    index), and zeros for the gate metrics the lean program never
    computes.
    """
    # untraced runs still carry the decisions-only ys dict (it feeds
    # result.decisions); only opt-in traces (marked by "t") merge —
    # anything else stays dropped, as before, since its leaves index
    # the tuned sub-batch
    if tr_t is None or "t" not in tr_t:
        return None

    def merge(a_t, a_u=None, fill=None):
        a_t = np.asarray(a_t)
        out = np.zeros((b,) + a_t.shape[1:], dtype=a_t.dtype)
        out[t_idx] = a_t
        if a_u is not None:
            out[u_idx] = np.asarray(a_u)
        elif fill is not None:
            out[u_idx] = fill
        return out

    theta_u = np.stack([np.asarray(state.window_pages)[u_idx],
                        np.asarray(state.rpcs_in_flight)[u_idx]],
                       axis=-1).astype(np.int64)[:, None]   # (B_u,1,n,2)
    fills = {"t": np.asarray(tr_t["t"])[0],
             "warm": np.asarray(tr_t["warm"])[0],
             "theta": theta_u, "cur_theta": theta_u}
    out = {}
    for key, v in tr_t.items():
        if key == "timeline":
            out[key] = jax.tree.map(lambda at, au: merge(at, a_u=au),
                                    v, tr_u["timeline"])
        elif key == "t":
            out[key] = merge(v, a_u=tr_u["t"])
        else:
            out[key] = merge(v, fill=fills.get(key))
    return out
