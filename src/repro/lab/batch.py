"""Batched scenario execution: N scenarios per jitted launch.

The fused interval scan (:mod:`repro.pfs.engine_jax`) removed the
per-tick Python round trip; this module removes the per-*scenario*
process.  ``stack_scenarios`` stacks B structurally-identical
:class:`~repro.lab.scenarios.BuiltScenario` pytrees (same topology
dimensions, same workload-table shapes — e.g. variants/seeds of one
spec, or a grid of homogeneous campaign cells) along a new leading batch
axis, and :class:`BatchEngine` ``vmap``-s the identical
``demand_step ∘ engine_step`` interval over that axis — hundreds of
independent scenarios advance one tuning interval in a single device
dispatch.

In-batch DIAL tuning reuses the fleet machinery unchanged: a batch of B
scenarios with n interfaces each *is* a fleet of ``B * n`` interfaces
(every row of every fleet matrix is already built purely from one
interface's local counters), so :class:`BatchPort` exposes the stacked
state through the :class:`~repro.core.fleet.FleetPort` protocol and one
:class:`~repro.core.fleet.FleetAgent` tunes every scenario in the batch
with one forest launch per interval.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.fleet import FleetAgent
from repro.core.tuner import TunerParams
from repro.kernels.segment_reduce.ops import make_segment_sum
from repro.lab.scenarios import BuiltScenario, make_schedule
from repro.pfs.engine_jax import engine_step_jax
from repro.pfs.state import Disturbance, SimParams, SimState, SimTopo
from repro.pfs.stats import FleetStats
from repro.pfs.workloads import WorkloadState, WorkloadTable


def _tree_stack(trees):
    """Stack a list of identical-structure pytrees along a new axis 0."""
    return jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                        *trees)


@dataclasses.dataclass
class ScenarioBatch:
    """B stacked scenarios: one pytree per engine-level piece.

    ``table`` / ``state`` / ``wstate`` arrays carry a leading ``(B, ...)``
    batch axis; ``specs`` keeps the per-element provenance (used to
    rebuild each element's disturbance schedule every interval).
    """

    params: SimParams
    topo: SimTopo
    table: WorkloadTable        # batched arrays
    state: SimState             # batched arrays
    wstate: WorkloadState       # batched arrays
    specs: tuple = ()           # per-element ScenarioSpec (may be empty)

    def __len__(self) -> int:
        return int(np.asarray(self.state.window_pages).shape[0])

    @property
    def n_osc(self) -> int:
        return self.topo.n_osc

    def schedule(self, t0_tick: int, n_ticks: int) -> Disturbance:
        """Stacked ``(B, n_ticks, ...)`` disturbance schedule for one
        interval (neutral for elements without events / without specs)."""
        if self.specs:
            per = [make_schedule(s.events, self.topo, self.params,
                                 t0_tick, n_ticks) for s in self.specs]
        else:
            per = [Disturbance.neutral(self.topo, n_ticks=n_ticks)
                   for _ in range(len(self))]
        return _tree_stack(per)

    # ------------------------------------------------------------------ #
    def throughput(self, seconds: float) -> dict:
        """Per-element aggregate MB/s from the cumulative counters."""
        done = np.asarray(self.state.ctr_bytes_done)      # (B, 2, n)
        read = done[:, 0].sum(axis=1) / seconds / 1e6
        write = done[:, 1].sum(axis=1) / seconds / 1e6
        return {"read_mbs": read, "write_mbs": write,
                "total_mbs": read + write}


def stack_scenarios(built: list[BuiltScenario]) -> ScenarioBatch:
    """Stack structurally-identical built scenarios into one batch."""
    if not built:
        raise ValueError("empty scenario batch")
    b0 = built[0]
    for b in built[1:]:
        if b.params != b0.params:
            raise ValueError("batch elements must share SimParams "
                             "(the engine closes over element 0's)")
        if (b.topo.n_clients, b.topo.n_osts) != (b0.topo.n_clients,
                                                 b0.topo.n_osts):
            raise ValueError("batch elements must share topology dims")
        if (len(b.table), b.table.n_waves,
                len(b.table.entry_row)) != (len(b0.table), b0.table.n_waves,
                                            len(b0.table.entry_row)):
            raise ValueError("batch elements must share workload-table "
                             "structure (rows, waves, stripe entries)")
    return ScenarioBatch(
        params=b0.params,
        topo=b0.topo,
        table=_tree_stack([b.table for b in built]),
        state=_tree_stack([b.state for b in built]),
        wstate=_tree_stack([b.wstate for b in built]),
        specs=tuple(b.spec for b in built),
    )


# ---------------------------------------------------------------------- #
# vmapped fused interval
# ---------------------------------------------------------------------- #
class BatchEngine:
    """One tuning interval for the whole batch per jitted call.

    ``vmap`` of the exact :class:`~repro.pfs.engine_jax.FusedEngine`
    interval body over the batch axis (state, workload table, and
    disturbance schedule all batched), jitted once per
    (topology, table-structure, n_ticks) shape.  ``seg_backend``
    defaults to the XLA ``segment_sum`` path, which vmaps cleanly on
    every platform; the Pallas one-hot-matmul kernel remains available
    for unbatched TPU intervals via :class:`FusedEngine`.
    """

    def __init__(self, params: SimParams, topo: SimTopo, n_ticks: int,
                 seg_backend: str = "jax"):
        self.params = params
        self.topo = topo
        self.n_ticks = int(n_ticks)
        segsum = make_segment_sum(seg_backend)

        def interval(table, state, wstate, sched):
            def body(carry, dist):
                st, ws = carry
                demand, ws = table.demand_step(params, ws, st,
                                               xp=jnp, segsum=segsum)
                st = engine_step_jax(params, topo, st, demand, segsum,
                                     disturbance=dist)
                return (st, ws), None

            (state, wstate), _ = jax.lax.scan(
                body, (state, wstate), sched, length=self.n_ticks)
            return state, wstate

        self._run = jax.jit(jax.vmap(interval))

    def run_interval(self, table: WorkloadTable, state: SimState,
                     wstate: WorkloadState, sched: Disturbance):
        """Advance every element one interval; numpy in, numpy out."""
        with enable_x64():
            args = jax.tree.map(jnp.asarray, (table, state, wstate, sched))
            jstate, jws = self._run(*args)
            jstate, jws = jax.tree.map(
                lambda x: x.block_until_ready()
                if hasattr(x, "block_until_ready") else x, (jstate, jws))
        return jax.tree.map(np.array, jstate), jax.tree.map(np.array, jws)


# ---------------------------------------------------------------------- #
# in-batch DIAL tuning: the batch as one fleet
# ---------------------------------------------------------------------- #
class BatchPort:
    """:class:`~repro.core.fleet.FleetPort` over a stacked batch.

    Interface ``(b, osc)`` of the batch is fleet column ``b * n + osc``.
    ``cols`` restricts the exposed interfaces (e.g. only the DIAL-policy
    element of an evaluation batch, or only measurement cells of a
    campaign); default is every interface of every element.
    """

    def __init__(self, batch: ScenarioBatch, cols=None):
        self.batch = batch
        n = batch.n_osc
        if cols is None:
            cols = np.arange(len(batch) * n, dtype=np.int64)
        self._cols = np.asarray(cols, dtype=np.int64)

    def osc_ids(self) -> np.ndarray:
        return self._cols

    def probe_all(self) -> FleetStats:
        s = self.batch.state
        c = self._cols

        def f2(a):  # (B, 2, n) -> (2, len(cols))
            return np.moveaxis(np.asarray(a), 1, 0).reshape(2, -1)[:, c].copy()

        def f1(a):  # (B, n) -> (len(cols),)
            return np.asarray(a).reshape(-1)[c].copy()

        return FleetStats(
            t=float(np.ravel(np.asarray(s.now))[0]),
            oscs=c,
            bytes_done=f2(s.ctr_bytes_done),
            rpcs_sent=f2(s.ctr_rpcs_sent),
            rpc_bytes=f2(s.ctr_rpc_bytes),
            partial_rpcs=f2(s.ctr_partial_rpcs),
            latency_sum=f2(s.ctr_latency_sum),
            rpcs_done=f2(s.ctr_rpcs_done),
            req_count=f2(s.ctr_req_count),
            req_bytes=f2(s.ctr_req_bytes),
            pending_integral=f2(s.ctr_pending_integral),
            active_integral=f2(s.ctr_active_integral),
            cache_hit_bytes=f1(s.ctr_cache_hit_bytes),
            block_time=f1(s.ctr_block_time),
            dirty_integral=f1(s.ctr_dirty_integral),
            grant_integral=f1(s.ctr_grant_integral),
            randomness=f2(s.randomness),
            window_pages=f1(s.window_pages).astype(np.int64),
            rpcs_in_flight=f1(s.rpcs_in_flight).astype(np.int64),
        )

    def set_knobs_many(self, osc_ids, window_pages, rpcs_in_flight) -> None:
        ids = np.atleast_1d(np.asarray(osc_ids, dtype=np.int64))
        b, o = np.divmod(ids, self.batch.n_osc)
        s = self.batch.state
        s.window_pages[b, o] = np.asarray(window_pages, dtype=np.int64)
        s.rpcs_in_flight[b, o] = np.asarray(rpcs_in_flight, dtype=np.int64)


def run_batch(batch: ScenarioBatch, model=None, seconds: float = 10.0,
              interval: float = 0.5, seg_backend: str = "jax",
              tuner_params: TunerParams = TunerParams(),
              tune_cols=None, engine: BatchEngine | None = None):
    """Drive a whole batch for ``seconds``, optionally DIAL-tuning.

    The batched counterpart of :func:`repro.core.fleet.run_fleet`: every
    interval is one vmapped engine launch followed (when ``model`` is
    given) by one fleet tuning tick over ``tune_cols`` (default: every
    interface of every element).  Returns the :class:`FleetAgent` (or
    ``None`` when untuned); final state lives on ``batch.state``.
    """
    steps = max(int(round(interval / batch.params.tick)), 1)
    n_intervals = int(round(seconds / interval))
    engine = engine or BatchEngine(batch.params, batch.topo, steps,
                                   seg_backend=seg_backend)
    fleet = None
    if model is not None:
        fleet = FleetAgent(BatchPort(batch, cols=tune_cols), model,
                           tuner_params=tuner_params)
    for i in range(n_intervals):
        sched = batch.schedule(i * steps, steps)
        batch.state, batch.wstate = engine.run_interval(
            batch.table, batch.state, batch.wstate, sched)
        if fleet is not None:
            fleet.tick()
    return fleet
