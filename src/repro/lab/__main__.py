"""Scenario Lab CLI.

    python -m repro.lab list
    python -m repro.lab evaluate [--smoke] [--scenarios A B ...]
                                 [--model PREFIX] [--out reports/lab]
    python -m repro.lab campaign [--smoke] [--out models/lab]
    python -m repro.lab continual [--smoke] [--scenario failing_ost]
    python -m repro.lab fuzz [--smoke] [--seed 0] [--mesh N]
                             [--out reports/fuzz]
    python -m repro.lab trace <scenario> [--stride 20] [--out reports/trace]
    python -m repro.lab trace --from-report reports/fuzz/report.json \
                              --fingerprint <fp>
    python -m repro.lab diagnose <scenario> [--out reports/diagnose]
    python -m repro.lab diagnose --from-report reports/fuzz/report.json \
                                 [--fingerprint <fp> | --all]

``evaluate`` runs every registered scenario (or the named subset) under
every static θ plus DIAL and writes ``report.json`` / ``report.md``;
``campaign`` runs batched offline collection + training and saves a
versioned model artifact; ``continual`` runs one drifting scenario
twice — frozen model vs online refit (replay buffer + drift trigger +
jitted retraining) — and reports the post-failure recovery.
``fuzz`` generates scenarios deterministically from a seed (topologies,
workload mixes, disturbance/fault compositions), races DIAL against a
static-θ grid through the fused batch path, and writes an auto-triaged
``reports/fuzz/`` of every scenario DIAL loses.
``trace`` replays one scenario (catalog name, or a triaged fuzz loser
by fingerprint) through the traced fused loop and writes decision
provenance + per-OST timelines as JSONL, Chrome ``trace_event``
(Perfetto-ready), and a markdown digest.
``diagnose`` replays a scenario under the counterfactual intervention
arms (θ pinned to best-static, gates forced open, decisions frozen)
and writes a dominant-cause diagnosis with per-interval evidence;
``fuzz`` runs it automatically over every triaged loser unless
``--no-diagnose`` is given.
``--smoke`` shrinks each to CI size.
"""

from __future__ import annotations

import argparse


def _cmd_list(args) -> None:
    from repro.lab.scenarios import SCENARIOS

    w = max(len(n) for n in SCENARIOS)
    for name, spec in SCENARIOS.items():
        tags = ",".join(spec.tags)
        print(f"{name:<{w}}  {spec.n_clients}c x {spec.n_osts}ost  "
              f"[{tags}]  {spec.description}")


def _make_mesh(n):
    """``--mesh`` value -> fleet mesh (None off, 0 = all local devices)."""
    if n is None:
        return None
    from repro.distributed.sharding import fleet_mesh

    return fleet_mesh(n or None)


def _cmd_evaluate(args) -> None:
    from repro.core.model import DIALModel
    from repro.lab.evaluate import default_model, evaluate, write_report

    model = (DIALModel.load(args.model) if args.model
             else default_model(smoke=args.smoke, root=args.models_root))
    seconds = 3.0 if args.smoke else args.seconds
    report = evaluate(names=args.scenarios or None, model=model,
                      seconds=seconds, interval=args.interval,
                      seg_backend=args.seg_backend,
                      fused=not args.no_fused,
                      mesh=_make_mesh(args.mesh),
                      ragged=not args.no_ragged)
    jpath, mpath = write_report(report, args.out)
    s = report["summary"]
    print(f"{s['n_scenarios']} scenarios -> {jpath} / {mpath}")
    if "n_buckets" in s:
        print(f"ragged catalog: {s['n_buckets']} buckets, "
              f"{s['n_dispatches']} fused dispatches")
    print(f"mean DIAL vs default {s['mean_dial_vs_default']:.2f}x, "
          f"mean frac of best static "
          f"{100 * s['mean_dial_frac_of_best_static']:.1f}%")


def _cmd_campaign(args) -> None:
    import dataclasses

    from repro.lab.campaign import CampaignConfig, run_campaign, smoke_campaign

    if args.smoke:
        cfg, gbdt = smoke_campaign()
        cfg = dataclasses.replace(cfg, contention_frac=args.contention_frac,
                                  seed=args.seed)
    else:
        cfg = CampaignConfig(seconds=args.seconds, reps=args.reps,
                             contention_frac=args.contention_frac,
                             seed=args.seed)
        gbdt = None
    d, _, info = run_campaign(cfg, out_root=args.out, gbdt_params=gbdt,
                              smoke=args.smoke,
                              trainer_backend=args.trainer_backend)
    print(f"saved {d}: {info['samples']} samples, "
          f"positive rates {info['positive_rate']}, "
          f"trainer {info['train_meta']['trainer_backend']}")


def _cmd_continual(args) -> None:
    from repro.core.gbdt import GBDTParams
    from repro.core.model import DIALModel
    from repro.lab.continual import run_comparison, write_report
    from repro.learn.online import OnlinePolicy

    if args.hard_from:
        _cmd_hard_cases(args)
        return
    model = DIALModel.load(args.model) if args.model else None
    seconds = 10.0 if args.smoke else args.seconds
    gbdt = (GBDTParams(n_trees=20, max_depth=4) if args.smoke
            else GBDTParams(n_trees=40, max_depth=5))
    policy = OnlinePolicy(refit_every=args.refit_every,
                          min_samples=16 if args.smoke else 32,
                          explore_eps=args.explore_eps)
    report = run_comparison(args.scenario, model=model, seconds=seconds,
                            interval=args.interval, policy=policy,
                            gbdt_params=gbdt, smoke=args.smoke)
    path = write_report(report, args.out)
    fr, on = report["frozen"], report["online"]
    print(f"{args.scenario}: failure at t={report['t_fail']}s, "
          f"{report['refits']} refit(s), "
          f"{on['samples']} online samples -> {path}")
    print(f"post-failure MB/s: frozen {fr['post_fail_mbs']:.1f}, "
          f"online {on['post_fail_mbs']:.1f} "
          f"({report['post_fail_gain']:.2f}x; tail "
          f"{report['post_tail_gain']:.2f}x)")


def _cmd_hard_cases(args) -> None:
    """``continual --hard-from``: the fuzz-triage replay curriculum."""
    from repro.core.gbdt import GBDTParams
    from repro.core.model import DIALModel
    from repro.lab.continual import (run_hard_case_curriculum,
                                     write_curriculum_report)
    from repro.lab.evaluate import default_model
    from repro.learn.online import OnlinePolicy

    model = (DIALModel.load(args.model) if args.model
             else default_model(smoke=args.smoke))
    gbdt = (GBDTParams(n_trees=20, max_depth=4) if args.smoke
            else GBDTParams(n_trees=40, max_depth=5))
    policy = OnlinePolicy(refit_every=args.refit_every,
                          min_samples=16 if args.smoke else 32,
                          cooldown=2 if args.smoke else 4,
                          explore_eps=args.explore_eps)
    max_cases = args.max_cases if args.max_cases is not None else (
        6 if args.smoke else None)
    report = run_hard_case_curriculum(
        args.hard_from, model, seconds=6.0 if args.smoke else args.seconds,
        interval=args.interval, policy=policy, gbdt_params=gbdt,
        max_cases=max_cases)
    path = write_curriculum_report(report, args.out)
    o = report["overall"]
    print(f"{report['n_losers']} triaged loser(s), "
          f"{report['n_replays']} curriculum replay(s), "
          f"{report['n_refits']} refit(s) -> {path}")
    print(f"loss rate {100 * o['before_loss_rate']:.0f}% -> "
          f"{100 * o['after_loss_rate']:.0f}% "
          f"(delta {100 * o['delta']:+.0f}%)")
    for cause, row in report["buckets"].items():
        print(f"  {cause}: {row['n']} case(s), loss rate "
              f"{100 * row['before_loss_rate']:.0f}% -> "
              f"{100 * row['after_loss_rate']:.0f}%")


def _cmd_fuzz(args) -> None:
    import dataclasses

    from repro.core.model import DIALModel
    from repro.lab.evaluate import default_model
    from repro.lab.fuzz import SMOKE, FuzzConfig, run_sweep, write_fuzz_report

    cfg = SMOKE if args.smoke else FuzzConfig()
    over = {"seed": args.seed}
    if args.n is not None:
        over["n_scenarios"] = args.n
    if args.seconds is not None:
        over["seconds"] = args.seconds
    if args.threshold is not None:
        over["loss_threshold"] = args.threshold
    cfg = dataclasses.replace(cfg, **over)
    model = (DIALModel.load(args.model) if args.model
             else default_model(smoke=args.smoke, root=args.models_root))
    report = run_sweep(cfg, model, mesh=_make_mesh(args.mesh),
                       diagnose=not args.no_diagnose,
                       max_diagnoses=args.max_diagnoses,
                       ragged=not args.no_ragged)
    jpath, mpath = write_fuzz_report(report, args.out)
    s = report["summary"]
    print(f"{s['n_scenarios']} scenarios, {s['n_buckets']} buckets, "
          f"{s['n_dispatches']} fused dispatches -> {jpath} / {mpath}")
    for b in s["bucket_occupancy"]:
        print(f"  bucket {b['shape']}: {b['n_specs']} specs, "
              f"{b['dispatches']} dispatch(es), "
              f"pad waste {100 * b['pad_waste']:.1f}%")
    causes = s.get("loss_causes")
    by_cause = ("" if causes is None else " [" + (
        ", ".join(f"{c}: {n}" for c, n in causes.items()) or "no causes")
        + "]")
    print(f"mean DIAL frac of best static "
          f"{100 * s['mean_dial_frac_of_best_static']:.1f}%, "
          f"{s['n_losses']} loss(es) beyond "
          f"{100 * cfg.loss_threshold:.0f}%" + by_cause)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.lab",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="print the scenario catalog")

    ev = sub.add_parser("evaluate", help="tuned vs default vs best-static "
                                         "sweep over the catalog")
    ev.add_argument("--scenarios", nargs="*", default=None)
    ev.add_argument("--model", default=None,
                    help="DIALModel prefix (default: latest campaign "
                         "artifact under --models-root, else models/dial, "
                         "else a fresh campaign)")
    ev.add_argument("--models-root", default="models/lab",
                    help="campaign artifact root to resolve models from")
    ev.add_argument("--seconds", type=float, default=10.0)
    ev.add_argument("--interval", type=float, default=0.5)
    ev.add_argument("--seg-backend", default="jax")
    ev.add_argument("--no-fused", action="store_true",
                    help="use the per-interval host loop instead of the "
                         "single-dispatch device-resident loop")
    ev.add_argument("--mesh", type=int, default=None, nargs="?", const=0,
                    help="shard each policy batch over N local devices "
                         "(0 or bare flag: all; needs the fused path)")
    ev.add_argument("--no-ragged", action="store_true",
                    help="one batch per scenario instead of pooling the "
                         "mixed catalog into padded shape buckets")
    ev.add_argument("--out", default="reports/lab")
    ev.add_argument("--smoke", action="store_true",
                    help="CI-sized run (3 s per scenario, smoke model)")

    cp = sub.add_parser("campaign", help="batched collect -> train -> "
                                         "versioned artifact")
    cp.add_argument("--seconds", type=float, default=60.0)
    cp.add_argument("--reps", type=int, default=2)
    cp.add_argument("--contention-frac", type=float, default=0.25)
    cp.add_argument("--seed", type=int, default=0)
    cp.add_argument("--out", default="models/lab")
    cp.add_argument("--smoke", action="store_true")
    cp.add_argument("--trainer-backend", default="numpy",
                    choices=("numpy", "jax"),
                    help="GBDT training path (jax = one vmapped launch "
                         "for the read+write pair)")

    ct = sub.add_parser("continual", help="frozen vs online-refit run of "
                                          "a drifting scenario")
    ct.add_argument("--scenario", default="failing_ost")
    ct.add_argument("--seconds", type=float, default=45.0)
    ct.add_argument("--interval", type=float, default=0.5)
    ct.add_argument("--refit-every", type=int, default=10)
    ct.add_argument("--explore-eps", type=float, default=0.10)
    ct.add_argument("--model", default=None,
                    help="DIALModel prefix (default: evaluate's model "
                         "resolution order)")
    ct.add_argument("--out", default="reports/lab")
    ct.add_argument("--smoke", action="store_true",
                    help="CI-sized run (10 s, small refits)")
    ct.add_argument("--hard-from", default=None,
                    help="fuzz report.json: instead of the frozen-vs-"
                         "online comparison, replay its triaged losers "
                         "as a hard-case curriculum (weighted by "
                         "diagnosed cause) and report the loss-rate "
                         "delta per cause bucket")
    ct.add_argument("--max-cases", type=int, default=None,
                    help="with --hard-from: cap the losers replayed "
                         "(worst-first; --smoke caps at 6)")

    fz = sub.add_parser("fuzz", help="seeded scenario fuzzing: generate, "
                                     "race vs static grid, auto-triage")
    fz.add_argument("--seed", type=int, default=0)
    fz.add_argument("--n", type=int, default=None,
                    help="number of scenarios (default: config's)")
    fz.add_argument("--seconds", type=float, default=None)
    fz.add_argument("--threshold", type=float, default=None,
                    help="triage loss threshold X: flag scenarios where "
                         "DIAL < (1-X) * best static")
    fz.add_argument("--model", default=None,
                    help="DIALModel prefix (default: evaluate's model "
                         "resolution order)")
    fz.add_argument("--models-root", default="models/lab")
    fz.add_argument("--mesh", type=int, default=None, nargs="?", const=0,
                    help="spread each structure bucket over N local "
                         "devices via the sharded fused path (0 or bare "
                         "flag: all local devices); cuts sweep "
                         "wall-clock on multi-device hosts — force CPU "
                         "devices with "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N")
    fz.add_argument("--no-ragged", action="store_true",
                    help="bucket by exact structure instead of padded "
                         "shape class (more dispatches, no padding)")
    fz.add_argument("--out", default="reports/fuzz")
    fz.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep (64 scenarios, 3 s, 6 static "
                         "arms, two topologies)")
    fz.add_argument("--no-diagnose", action="store_true",
                    help="skip stamping a counterfactual diagnosis into "
                         "each triaged loser")
    fz.add_argument("--max-diagnoses", type=int, default=None,
                    help="diagnose at most N losers (worst first; the "
                         "report records diagnosed-of-total; default: "
                         "every triaged loser)")

    tr = sub.add_parser("trace", help="replay one scenario traced; write "
                                      "JSONL + Chrome trace + summary")
    tr.add_argument("scenario", nargs="?", default=None,
                    help="catalog scenario name (see `list`)")
    tr.add_argument("--from-report", default=None,
                    help="fuzz report.json to pull a triaged loser from")
    tr.add_argument("--fingerprint", default=None,
                    help="which triaged loss to replay (with "
                         "--from-report)")
    tr.add_argument("--stride", type=int, default=20,
                    help="timeline downsampling: one sample every N "
                         "engine ticks")
    tr.add_argument("--no-timeline", action="store_true",
                    help="decision provenance only (no per-tick records)")
    tr.add_argument("--diagnose", action="store_true",
                    help="also run the counterfactual diagnosis and "
                         "stamp its verdict into every sink (JSONL "
                         "record, Perfetto marker track, md section)")
    tr.add_argument("--seconds", type=float, default=10.0)
    tr.add_argument("--interval", type=float, default=0.5)
    tr.add_argument("--seg-backend", default="jax")
    tr.add_argument("--model", default=None,
                    help="DIALModel prefix (default: evaluate's model "
                         "resolution order)")
    tr.add_argument("--out", default="reports/trace")
    tr.add_argument("--smoke", action="store_true",
                    help="allow the smoke-grade campaign model")

    dg = sub.add_parser("diagnose", help="counterfactual replay: "
                                         "attribute a loss to a cause "
                                         "with per-interval evidence")
    dg.add_argument("scenario", nargs="?", default=None,
                    help="catalog scenario name (see `list`)")
    dg.add_argument("--from-report", default=None,
                    help="fuzz report.json to pull triaged loser(s) from")
    dg.add_argument("--fingerprint", default=None,
                    help="which triaged loss to diagnose (with "
                         "--from-report)")
    dg.add_argument("--all", action="store_true",
                    help="diagnose every triaged loss of --from-report")
    dg.add_argument("--seconds", type=float, default=3.0)
    dg.add_argument("--interval", type=float, default=0.5)
    dg.add_argument("--threshold", type=float, default=0.05,
                    help="loss threshold X for the cause cascade")
    dg.add_argument("--max-evidence", type=int, default=8,
                    help="evidence rows kept per diagnosis (total is "
                         "always recorded)")
    dg.add_argument("--seg-backend", default="jax")
    dg.add_argument("--model", default=None,
                    help="DIALModel prefix (default: evaluate's model "
                         "resolution order)")
    dg.add_argument("--alt-model", default=None,
                    help="second DIALModel prefix for the model_swap "
                         "arm (was the artifact version the loss?)")
    dg.add_argument("--mesh", type=int, default=None, nargs="?", const=0,
                    help="run the replay arms through the sharded fused "
                         "path over N local devices (0 or bare: all)")
    dg.add_argument("--no-ragged", action="store_true",
                    help="replay losers one at a time instead of one "
                         "traced dispatch per padded shape bucket")
    dg.add_argument("--out", default="reports/diagnose")
    dg.add_argument("--smoke", action="store_true",
                    help="allow the smoke-grade campaign model")

    args = ap.parse_args(argv)
    if args.cmd == "trace":
        from repro.lab.trace import main as trace_main

        trace_main(args)
        return
    if args.cmd == "diagnose":
        from repro.lab.diagnose import main as diagnose_main

        diagnose_main(args)
        return
    {"list": _cmd_list, "evaluate": _cmd_evaluate,
     "campaign": _cmd_campaign, "continual": _cmd_continual,
     "fuzz": _cmd_fuzz}[args.cmd](args)


if __name__ == "__main__":
    main()
