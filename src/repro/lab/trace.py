"""Traced scenario replay: one command from scenario to Perfetto.

``python -m repro.lab trace <scenario>`` runs one catalog scenario (or
a triaged fuzz loser, via ``--from-report/--fingerprint``) through the
device-resident fused loop with telemetry on, then writes the three
sinks side by side:

    trace.jsonl          lossless ``dial-trace-v2`` records
    trace.chrome.json    Chrome ``trace_event`` — open in Perfetto or
                         ``chrome://tracing``
    trace.md             human-readable digest (gate outcomes, θ
                         changes, per-OST throughput)

The records accumulate as scan outputs *inside* the jitted dispatch —
tracing a run never changes what the run decides (tests/test_obs.py).
"""

from __future__ import annotations

import json
import os

from repro.lab.batch import run_batch, stack_scenarios
from repro.lab.scenarios import ScenarioSpec, build, get_scenario
from repro.obs.schema import RunTrace, TraceConfig


def load_spec_from_report(path: str, fp: str) -> ScenarioSpec:
    """Rebuild one triaged loss from a fuzz ``report.json`` by its
    fingerprint — the replay half of the report's ``trace_recipe``."""
    from repro.lab.fuzz import spec_from_dict

    with open(path) as f:
        report = json.load(f)
    losses = report.get("triage", {}).get("losses", [])
    for r in losses:
        if r["fingerprint"] == fp:
            return spec_from_dict(r["spec"], name=r["name"])
    have = ", ".join(r["fingerprint"] for r in losses) or "none"
    raise KeyError(f"fingerprint {fp!r} not in {path} (triaged: {have})")


def trace_scenario(spec: ScenarioSpec, model, seconds: float = 10.0,
                   interval: float = 0.5, config: TraceConfig | None = None,
                   seg_backend: str = "jax") -> RunTrace:
    """Run ``spec`` DIAL-tuned through the traced fused loop and return
    the normalized :class:`RunTrace` (fleet columns = the scenario's
    interfaces, one OST track each)."""
    config = config if config is not None else TraceConfig()
    batch = stack_scenarios([build(spec)])
    result = run_batch(batch, model=model, seconds=seconds,
                       interval=interval, seg_backend=seg_backend,
                       fused=True, trace=config)
    trace = RunTrace.from_fused(result, config, batch.params.tick)
    trace.validate()
    return trace


def write_trace(trace: RunTrace, out_dir: str,
                title: str = "trace", diagnosis: dict | None = None) -> dict:
    """All three sinks into ``out_dir``; returns their paths.  With
    ``diagnosis`` (a :mod:`repro.obs.diagnose` report), the verdict is
    stamped into every sink: a ``diagnosis`` JSONL record, a Perfetto
    marker track with per-evidence-row instants, a markdown section."""
    from repro.obs.sinks import render_summary, write_chrome, write_jsonl

    os.makedirs(out_dir, exist_ok=True)
    paths = {
        "jsonl": write_jsonl(trace, os.path.join(out_dir, "trace.jsonl"),
                             diagnosis=diagnosis),
        "chrome": write_chrome(trace,
                               os.path.join(out_dir, "trace.chrome.json"),
                               diagnosis=diagnosis),
        "md": os.path.join(out_dir, "trace.md"),
    }
    with open(paths["md"], "w") as f:
        f.write(render_summary(trace, title=title, diagnosis=diagnosis))
    return paths


def main(args) -> int:
    """CLI entry (dispatched from ``repro.lab.__main__``)."""
    from repro.lab.evaluate import default_model
    from repro.obs.sinks import render_summary
    from repro.core.model import DIALModel

    if args.from_report:
        if not args.fingerprint:
            raise SystemExit("--from-report needs --fingerprint "
                             "(see the report's trace_recipe fields)")
        spec = load_spec_from_report(args.from_report, args.fingerprint)
    elif args.scenario:
        spec = get_scenario(args.scenario)
    else:
        raise SystemExit("pass a scenario name or --from-report/"
                         "--fingerprint")

    model = (DIALModel.load(args.model) if args.model
             else default_model(smoke=args.smoke))
    cfg = TraceConfig(stride=args.stride,
                      timeline=not args.no_timeline)
    trace = trace_scenario(spec, model, seconds=args.seconds,
                           interval=args.interval, config=cfg,
                           seg_backend=args.seg_backend)
    diagnosis = None
    if getattr(args, "diagnose", False):
        from repro.obs.diagnose import DiagnoseConfig, diagnose
        dcfg = DiagnoseConfig(seconds=args.seconds,
                              interval=args.interval,
                              seg_backend=args.seg_backend)
        diagnosis = diagnose(spec, model, dcfg)
    paths = write_trace(trace, args.out, title=spec.name,
                        diagnosis=diagnosis)
    print(render_summary(trace, title=spec.name, diagnosis=diagnosis))
    print(f"wrote {paths['jsonl']}, {paths['chrome']} "
          f"(open in Perfetto), {paths['md']}")
    return 0
