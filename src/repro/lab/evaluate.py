"""Catalog evaluation: tuned vs default vs best-static, per scenario.

For every registered scenario this runs a single batch of
``|Θ| + 1`` elements — one frozen element per static configuration
(the Lustre default ``(256, 8)`` is row ``SPACE.index_of(DEFAULT)``)
plus one DIAL-tuned element — through the vmapped engine, so the whole
policy comparison for a scenario is one compiled launch per interval.
The static sweep *is* the "best static" oracle the paper compares
against in Table II; the DIAL element reuses the production
:class:`~repro.core.fleet.FleetAgent` restricted to its own columns.

Output is a JSON report plus a markdown table (Table II / Fig. 3
analogs over the whole catalog), written by :func:`write_report` and
the ``python -m repro.lab evaluate`` CLI.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core.config_space import DEFAULT, SPACE
from repro.core.model import DIALModel
from repro.core.tuner import TunerParams
from repro.lab.batch import (BatchEngine, bucket_scenarios, run_batch,
                             stack_scenarios)
from repro.lab.scenarios import SCENARIOS, ScenarioSpec, build, get_scenario


@dataclasses.dataclass
class ScenarioResult:
    """One scenario's policy comparison (MB/s aggregated over the run)."""

    scenario: str
    tags: tuple
    n_clients: int
    n_osts: int
    default_mbs: float
    initial_mbs: float                # static θ₀ (what DIAL started from)
    best_static_mbs: float
    best_static_theta: tuple
    dial_mbs: float
    dial_vs_default: float
    dial_vs_initial: float            # the recovery story
    dial_frac_of_best_static: float
    changes: int                      # knob changes DIAL applied

    def row(self) -> dict:
        d = dataclasses.asdict(self)
        d["tags"] = list(self.tags)
        d["best_static_theta"] = list(self.best_static_theta)
        return d


def evaluate_scenario(spec: ScenarioSpec, model: DIALModel,
                      seconds: float = 10.0, interval: float = 0.5,
                      seg_backend: str = "jax",
                      tuner_params: TunerParams | None = None,
                      fused: bool = True, mesh=None,
                      trace=None) -> ScenarioResult:
    """One scenario under every static θ plus DIAL, in one batch.

    ``fused=True`` (default) runs the whole comparison through the
    device-resident loop — every interval of engine + tuning in a single
    jitted dispatch per scenario (knob trajectories identical to the
    host loop; see tests/test_loop_fused.py).  ``fused=False`` keeps the
    per-interval host loop.  ``mesh`` shards the |Θ|+1 policy arms
    across local devices (fused only).

    ``trace`` (a :class:`~repro.obs.schema.TraceConfig`, fused only)
    records the comparison in-dispatch; the returned result then carries
    a :class:`~repro.obs.schema.RunTrace` as ``result.trace`` — fleet
    columns ``e * n + osc`` over the |Θ|+1 elements, decision provenance
    on the DIAL element's columns, timelines for every arm.
    """
    if trace is not None and not fused:
        raise ValueError("evaluate tracing rides the fused batch path")
    configs = SPACE.configs()
    m = len(configs)
    built = []
    for theta in configs + [spec.initial_theta]:
        b = build(dataclasses.replace(spec, initial_theta=tuple(theta)))
        built.append(b)
    batch = stack_scenarios(built)
    n = batch.n_osc
    dial_cols = m * n + np.arange(n)       # last element is the tuned one
    fleet = run_batch(batch, model=model, seconds=seconds,
                      interval=interval, seg_backend=seg_backend,
                      tuner_params=tuner_params, tune_cols=dial_cols,
                      fused=fused, mesh=mesh, trace=trace)
    run_trace = None
    if trace is not None:
        from repro.obs.schema import RunTrace
        run_trace = RunTrace.from_fused(fleet, trace, batch.params.tick)

    tput = batch.throughput(seconds)["total_mbs"]
    changes = sum(int(r.decisions.changed.sum()) for r in fleet.decisions)
    result = _make_result(spec, tput, changes, configs)
    result.trace = run_trace        # plain attribute; row() stays JSON
    return result


def _make_result(spec: ScenarioSpec, tput, changes: int,
                 configs) -> ScenarioResult:
    """Assemble one scenario's result from its |Θ|+1 arm throughputs.

    Shared by the per-scenario and the ragged whole-catalog paths so
    both produce identical rows from identical figures.
    """
    m = len(configs)
    static = tput[:m]
    best = int(np.argmax(static))
    default_mbs = float(static[SPACE.index_of(DEFAULT)])
    theta0 = (int(spec.initial_theta[0]), int(spec.initial_theta[1]))
    initial_mbs = (float(static[SPACE.index_of(theta0)])
                   if theta0 in configs else default_mbs)
    dial_mbs = float(tput[m])
    return ScenarioResult(
        scenario=spec.name,
        tags=spec.tags,
        n_clients=spec.n_clients,
        n_osts=spec.n_osts,
        default_mbs=default_mbs,
        initial_mbs=initial_mbs,
        best_static_mbs=float(static[best]),
        best_static_theta=configs[best],
        dial_mbs=dial_mbs,
        dial_vs_default=dial_mbs / max(default_mbs, 1e-9),
        dial_vs_initial=dial_mbs / max(initial_mbs, 1e-9),
        dial_frac_of_best_static=dial_mbs / max(float(static[best]), 1e-9),
        changes=changes,
    )


def _evaluate_catalog_ragged(specs, model: DIALModel, seconds: float,
                             interval: float, seg_backend: str, mesh,
                             tuner_params: TunerParams | None = None):
    """The whole heterogeneous catalog in one ``run_batch`` per bucket.

    Every spec contributes its |Θ|+1 policy arms to a flat pool; the
    pool is grouped by padded shape class (:func:`bucket_scenarios`) —
    vpic next to dlio next to hetero_links — and each bucket runs
    ragged in a single fused ``run_batch``.  Per-arm figures are
    bit-equal to the per-scenario path (padding neutrality + ordered
    real-column gathers), so the assembled rows are identical; the
    catalog just stops paying one dispatch per scenario.

    Returns ``(results_in_spec_order, n_buckets, n_dispatches)``.
    """
    configs = SPACE.configs()
    m = len(configs)
    built, owners = [], []
    for si, spec in enumerate(specs):
        for ai, theta in enumerate(configs + [spec.initial_theta]):
            built.append(build(dataclasses.replace(
                spec, initial_theta=tuple(theta))))
            owners.append((si, ai))
    buckets = bucket_scenarios(built)
    tputs = {}
    changes = dict.fromkeys(range(len(specs)), 0)
    n_dispatches = 0
    for idxs, batch in buckets:
        n = batch.n_osc
        dial_elems = [e for e, gi in enumerate(idxs) if owners[gi][1] == m]
        tune_cols = np.concatenate(
            [e * n + batch.element_cols(e) for e in dial_elems])
        res = run_batch(batch, model=model, seconds=seconds,
                        interval=interval, seg_backend=seg_backend,
                        tuner_params=tuner_params, tune_cols=tune_cols,
                        fused=True, mesh=mesh)
        n_dispatches += 1
        tp = batch.throughput(seconds)["total_mbs"]
        for e, gi in enumerate(idxs):
            tputs[owners[gi]] = float(tp[e])
        for r in res.decisions:
            elems = np.asarray(r.oscs) // n
            ch = np.asarray(r.decisions.changed)
            for e in np.unique(elems):
                si = owners[idxs[int(e)]][0]
                changes[si] += int(ch[elems == e].sum())
    results = []
    for si, spec in enumerate(specs):
        tput = np.array([tputs[(si, ai)] for ai in range(m + 1)])
        results.append(_make_result(spec, tput, changes[si], configs))
    return results, len(buckets), n_dispatches


def evaluate(names=None, model: DIALModel | None = None,
             seconds: float = 10.0, interval: float = 0.5,
             seg_backend: str = "jax", fused: bool = True,
             mesh=None, ragged: bool = True) -> dict:
    """Run the catalog (default: every registered scenario) and return
    the report dict (rows + summary).

    ``ragged=True`` (default, fused only) pools every scenario's policy
    arms and runs the mixed catalog in one fused ``run_batch`` per
    padded shape bucket; the summary gains ``n_buckets`` /
    ``n_dispatches``.  ``ragged=False`` runs one batch per scenario
    (the historical path) — rows are identical either way.
    """
    if model is None:
        model = default_model()
    names = list(names) if names else list(SCENARIOS)
    stats = None
    if ragged and fused and len(names) > 1:
        specs = [get_scenario(n) for n in names]
        results, n_buckets, n_dispatches = _evaluate_catalog_ragged(
            specs, model, seconds, interval, seg_backend, mesh)
        rows = [r.row() for r in results]
        stats = {"n_buckets": n_buckets, "n_dispatches": n_dispatches}
    else:
        rows = []
        for name in names:
            res = evaluate_scenario(get_scenario(name), model,
                                    seconds=seconds, interval=interval,
                                    seg_backend=seg_backend, fused=fused,
                                    mesh=mesh)
            rows.append(res.row())
    speedups = [r["dial_vs_default"] for r in rows]
    fracs = [r["dial_frac_of_best_static"] for r in rows]
    report = {
        "seconds": seconds,
        "interval": interval,
        "scenarios": rows,
        "summary": {
            "n_scenarios": len(rows),
            "mean_dial_vs_default": float(np.mean(speedups)),
            "min_dial_vs_default": float(np.min(speedups)),
            "mean_dial_frac_of_best_static": float(np.mean(fracs)),
            "min_dial_frac_of_best_static": float(np.min(fracs)),
        },
    }
    if stats is not None:
        report["summary"].update(stats)
    return report


def render_markdown(report: dict) -> str:
    """The report as a markdown table (Table II analog over the catalog)."""
    lines = [
        "# Scenario Lab report",
        "",
        f"{report['summary']['n_scenarios']} scenarios, "
        f"{report['seconds']:.0f} s each, tuning every "
        f"{report['interval']} s.",
        "",
        "| scenario | default MB/s | θ₀ MB/s | best static MB/s (θ) | "
        "DIAL MB/s | DIAL/default | DIAL/θ₀ | DIAL/best | changes |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in report["scenarios"]:
        th = "×".join(str(int(x)) for x in r["best_static_theta"])
        lines.append(
            f"| {r['scenario']} | {r['default_mbs']:.1f} | "
            f"{r['initial_mbs']:.1f} | "
            f"{r['best_static_mbs']:.1f} ({th}) | {r['dial_mbs']:.1f} | "
            f"{r['dial_vs_default']:.2f}x | {r['dial_vs_initial']:.2f}x | "
            f"{100 * r['dial_frac_of_best_static']:.1f}% | "
            f"{r['changes']} |")
    s = report["summary"]
    lines += [
        "",
        f"Mean DIAL vs default: **{s['mean_dial_vs_default']:.2f}x** "
        f"(min {s['min_dial_vs_default']:.2f}x); mean fraction of best "
        f"static: **{100 * s['mean_dial_frac_of_best_static']:.1f}%** "
        f"(min {100 * s['min_dial_frac_of_best_static']:.1f}%).",
        "",
    ]
    return "\n".join(lines)


def write_report(report: dict, out_dir: str) -> tuple[str, str]:
    os.makedirs(out_dir, exist_ok=True)
    jpath = os.path.join(out_dir, "report.json")
    mpath = os.path.join(out_dir, "report.md")
    with open(jpath, "w") as f:
        json.dump(report, f, indent=2)
    with open(mpath, "w") as f:
        f.write(render_markdown(report))
    return jpath, mpath


def default_model(smoke: bool = False,
                  root: str = "models/lab") -> DIALModel:
    """Best available model: campaign artifact under ``root`` → trained
    ``models/dial`` prefix → a fresh campaign (which also leaves a
    versioned artifact behind).

    A non-smoke caller never silently inherits a smoke-grade campaign
    artifact: versions whose manifest carries ``smoke: true`` are only
    eligible when ``smoke`` is requested (pin one explicitly with
    ``--model <root>/vNNN/dial`` to override).
    """
    from repro.lab.campaign import (CampaignConfig, latest_version,
                                    load_versioned, run_campaign,
                                    smoke_campaign)
    v = latest_version(root)
    if v is not None:
        try:
            with open(os.path.join(root, v, "manifest.json")) as f:
                is_smoke = bool(json.load(f).get("smoke", False))
        except (OSError, ValueError):
            is_smoke = False
        if smoke or not is_smoke:
            return load_versioned(root, version=v)
    try:
        return DIALModel.load("models/dial")
    except FileNotFoundError:
        pass
    if smoke:
        cfg, gbdt = smoke_campaign()
    else:
        cfg, gbdt = CampaignConfig(reps=2), None
    _, model, _ = run_campaign(cfg, out_root=root, gbdt_params=gbdt,
                               smoke=smoke)
    return model
