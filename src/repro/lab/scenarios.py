"""Declarative scenarios: topology + workload mix + disturbance schedule.

A :class:`ScenarioSpec` is a pure-data description of one simulated
cluster run.  Building it produces the engine-level pieces
(``SimParams`` / ``SimTopo`` / ``WorkloadTable`` / ``SimState``) plus a
deterministic per-tick :class:`~repro.pfs.state.Disturbance` schedule,
so the same spec runs bit-equivalently on the numpy oracle
(:func:`repro.pfs.workloads.run_interval`), the fused JAX scan
(:class:`repro.pfs.engine_jax.FusedEngine`), and the vmapped batch path
(:mod:`repro.lab.batch`).

Disturbances are *exogenous*: conditions no client controls or observes
directly.  They are expressed as piecewise/periodic events compiled into
per-tick arrays (a pure function of the absolute tick index, so interval
boundaries and backends cannot disagree):

    ``ost_slow``      scale an OST's bandwidth *and* setup/IOPS capacity
                      (a sick or failing disk is slow at both);
    ``bg_burst``      background bytes/s arriving at an OST from clients
                      outside the simulated fleet (noisy neighbours) —
                      they are served first and inflate the congestion
                      queue;
    ``nic_slow``      scale a client's NIC ceiling (heterogeneous links).

Plus the Lustre-grounded fault vocabulary (shine's client/OST state
machine: MOUNTED -> OFFLINE / CLIENT_ERROR, failover and recovery):

    ``ost_fail``      hard OST outage: bandwidth and IOPS scale to
                      ``magnitude`` (default 0 — OFFLINE) inside the
                      window, snapping back when it closes.  Periodic
                      windows model a flapping target;
    ``ost_failover``  fail, then ramp linearly back to full capacity
                      over a ``recovery``-second window after ``end``
                      (failback onto a cold target is never instant:
                      cache warmup, recovery windows, resync);
    ``client_evict``  the OST view of a client eviction: the client's
                      NIC scale drops to ``magnitude`` (default 0), so
                      its queued demand stalls until reconnection.

All kinds compile through :func:`make_schedule` into the same three
:class:`~repro.pfs.state.Disturbance` fields (``bw_scale`` /
``iops_scale`` / ``nic_scale`` / ``bg_bytes``), so the numpy oracle, the
fused scan, and the device-resident loop consume them with zero engine
changes.

The registry at the bottom names the paper evaluation setups
(vpic / bdcats / dlio / filebench) and beyond-paper stress scenarios;
``python -m repro.lab list`` prints the catalog.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.config_space import DEFAULT
from repro.pfs.state import (Disturbance, SimParams, SimState, SimTopo,
                             init_state)
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import (Workload, WorkloadState, WorkloadTable,
                                 bdcats_read, dlio_reader, random_stream,
                                 sequential_stream, vpic_write)


# ---------------------------------------------------------------------- #
# disturbance events -> per-tick schedules
# ---------------------------------------------------------------------- #
# kinds whose targets index OSTs vs clients, and kinds that express a
# capacity *outage* (scale drops toward 0 inside the window) vs a
# steady-state degradation
EVENT_KINDS = ("ost_slow", "bg_burst", "nic_slow",
               "ost_fail", "ost_failover", "client_evict")
CLIENT_KINDS = ("nic_slow", "client_evict")
FAULT_KINDS = ("ost_fail", "ost_failover", "client_evict")


@dataclasses.dataclass(frozen=True)
class DisturbanceEvent:
    """One piecewise/periodic exogenous condition.

    Active on ticks whose time ``t`` satisfies ``start <= t < end`` and,
    when ``period > 0``, ``(t - start) mod period < duty * period``
    (square-wave bursting).  ``magnitude`` is a scale factor for the
    ``*_slow`` kinds, background bytes/second for ``bg_burst``, and the
    residual capacity fraction during the outage for the fault kinds
    (``ost_fail`` / ``ost_failover`` / ``client_evict``, default 0 —
    hard offline).  ``recovery`` (``ost_failover`` only) is the number
    of seconds after ``end`` the target takes to ramp linearly from
    ``magnitude`` back to full capacity.

    Construction validates every field — a malformed event raises
    ``ValueError`` here, at the event/spec boundary, instead of passing
    silently into :func:`make_schedule` or crashing deep inside it.
    """

    kind: str                 # one of EVENT_KINDS
    targets: tuple            # OST ids, or client ids for CLIENT_KINDS
    magnitude: float = 0.0
    start: float = 0.0        # seconds
    end: float = math.inf
    period: float = 0.0       # 0 -> constant while inside [start, end)
    duty: float = 1.0
    recovery: float = 0.0     # seconds; ost_failover ramp-back window

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown disturbance kind {self.kind!r}; "
                             f"known: {', '.join(EVENT_KINDS)}")
        tgts = tuple(self.targets)
        if not tgts:
            raise ValueError(f"{self.kind}: empty targets — an event must "
                             "name at least one OST/client id")
        if any((not float(x).is_integer()) or x < 0 for x in tgts):
            raise ValueError(f"{self.kind}: targets must be non-negative "
                             f"integer ids, got {tgts!r}")
        if not (math.isfinite(self.magnitude) and self.magnitude >= 0):
            raise ValueError(f"{self.kind}: magnitude must be finite and "
                             f">= 0, got {self.magnitude!r}")
        if self.kind in ("ost_slow", "nic_slow") and self.magnitude == 0:
            raise ValueError(f"{self.kind}: magnitude must be > 0 (use "
                             "ost_fail/client_evict for a hard outage)")
        if self.kind in FAULT_KINDS and self.magnitude >= 1.0:
            raise ValueError(f"{self.kind}: residual capacity magnitude "
                             f"must be < 1, got {self.magnitude!r}")
        if not (math.isfinite(self.start) and self.start >= 0):
            raise ValueError(f"{self.kind}: start must be finite and >= 0, "
                             f"got {self.start!r}")
        if not self.end > self.start:
            raise ValueError(f"{self.kind}: end ({self.end!r}) must be > "
                             f"start ({self.start!r})")
        if not (math.isfinite(self.period) and self.period >= 0):
            raise ValueError(f"{self.kind}: period must be finite and "
                             f">= 0, got {self.period!r}")
        if not 0.0 < self.duty <= 1.0:
            raise ValueError(f"{self.kind}: duty must be in (0, 1], got "
                             f"{self.duty!r}")
        if not (math.isfinite(self.recovery) and self.recovery >= 0):
            raise ValueError(f"{self.kind}: recovery must be finite and "
                             f">= 0, got {self.recovery!r}")
        if self.kind == "ost_failover":
            if self.recovery <= 0:
                raise ValueError("ost_failover: recovery must be > 0 — a "
                                 "zero-length ramp is ost_fail")
            if not math.isfinite(self.end):
                raise ValueError("ost_failover: end must be finite (the "
                                 "ramp starts when the outage ends)")
            if self.period > 0:
                raise ValueError("ost_failover: period must be 0 (a ramp "
                                 "after a square wave is ill-defined; "
                                 "use periodic ost_fail for flapping)")
        elif self.recovery != 0:
            raise ValueError(f"{self.kind}: recovery only applies to "
                             "ost_failover")

    def active(self, t: np.ndarray) -> np.ndarray:
        act = (t >= self.start) & (t < self.end)
        if self.period > 0:
            act &= np.mod(t - self.start, self.period) < self.duty * self.period
        return act

    def capacity_scale(self, t: np.ndarray) -> np.ndarray:
        """Per-tick capacity multiplier for the fault kinds.

        ``magnitude`` inside the active window, 1 outside; ost_failover
        additionally ramps linearly from ``magnitude`` at ``end`` to 1
        at ``end + recovery`` instead of snapping back.
        """
        scale = np.where(self.active(t), self.magnitude, 1.0)
        if self.kind == "ost_failover":
            frac = (t - self.end) / self.recovery
            in_ramp = (t >= self.end) & (frac < 1.0)
            scale = np.where(
                in_ramp, self.magnitude + (1.0 - self.magnitude) * frac,
                scale)
        return scale


def validate_events(events, topo: SimTopo) -> None:
    """Check every event's target ids against a topology.

    Field-level validation happens at event construction; this is the
    spec-level half — an OST id >= ``n_osts`` (or client id >=
    ``n_clients``) would otherwise scatter out of bounds inside
    :func:`make_schedule`.
    """
    for ev in events:
        n = (topo.n_clients if ev.kind in CLIENT_KINDS else topo.n_osts)
        what = "client" if ev.kind in CLIENT_KINDS else "OST"
        bad = [x for x in ev.targets if not 0 <= int(x) < n]
        if bad:
            raise ValueError(
                f"{ev.kind}: {what} target ids {bad} out of range for a "
                f"{topo.n_clients}-client x {topo.n_osts}-OST topology")


def make_schedule(events, topo: SimTopo, params: SimParams,
                  t0_tick: int, n_ticks: int) -> Disturbance:
    """Compile events into one interval's per-tick Disturbance schedule.

    Pure function of the absolute tick index ``t0_tick + i``, so
    consecutive intervals tile seamlessly and every backend sees the
    identical exogenous world.
    """
    validate_events(events, topo)
    t = (t0_tick + np.arange(n_ticks)) * params.tick
    sched = Disturbance.neutral(topo, n_ticks=n_ticks)
    for ev in events:
        cols = np.asarray(ev.targets, dtype=np.int64)
        if ev.kind == "ost_slow":
            scale = np.where(ev.active(t), ev.magnitude, 1.0)[:, None]
            sched.bw_scale[:, cols] *= scale
            sched.iops_scale[:, cols] *= scale
        elif ev.kind in ("ost_fail", "ost_failover"):
            scale = ev.capacity_scale(t)[:, None]
            sched.bw_scale[:, cols] *= scale
            sched.iops_scale[:, cols] *= scale
        elif ev.kind == "bg_burst":
            sched.bg_bytes[:, cols] += (ev.active(t) * ev.magnitude
                                        * params.tick)[:, None]
        elif ev.kind == "nic_slow":
            sched.nic_scale[:, cols] *= np.where(ev.active(t), ev.magnitude,
                                                 1.0)[:, None]
        else:                            # client_evict (kinds are closed
            scale = ev.capacity_scale(t)[:, None]        # at construction)
            sched.nic_scale[:, cols] *= scale
    return sched


# ---------------------------------------------------------------------- #
# scenario spec + build
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Pure-data description of one simulated run.

    ``workloads`` holds unbound :class:`~repro.pfs.workloads.Workload`
    rows (the presets stay the row constructors); ``events`` the
    exogenous disturbance schedule; ``initial_theta`` the knob setting
    every OSC starts from (the Lustre default unless the scenario is
    meant to demonstrate recovery from a pathological config).

    The engine itself is deterministic: two builds of the same spec run
    bit-identically.  ``seed`` seeds the *structure-preserving jitter*
    :func:`variants` derives fan-out populations from — diversity across
    a batch comes from jittered parameters and disturbance phases, not
    from engine noise.
    """

    name: str
    n_clients: int
    n_osts: int
    workloads: tuple = ()
    events: tuple = ()
    initial_theta: tuple = DEFAULT      # (window_pages, rpcs_in_flight)
    seed: int = 0
    description: str = ""
    tags: tuple = ()

    def make_workloads(self) -> list:
        """Fresh (unshared) Workload row instances for attaching to sims."""
        return [dataclasses.replace(w) for w in self.workloads]


@dataclasses.dataclass
class BuiltScenario:
    """Engine-level pieces of one spec, ready to run or stack."""

    spec: ScenarioSpec
    params: SimParams
    topo: SimTopo
    table: WorkloadTable
    state: SimState
    wstate: WorkloadState

    def schedule(self, t0_tick: int, n_ticks: int) -> Disturbance:
        return make_schedule(self.spec.events, self.topo, self.params,
                             t0_tick, n_ticks)


def build(spec: ScenarioSpec, params: SimParams | None = None) -> BuiltScenario:
    """Materialize a spec: topology, frozen workload table, fresh state."""
    params = params or SimParams()
    topo = SimTopo.dense(spec.n_clients, spec.n_osts)
    validate_events(spec.events, topo)
    state = init_state(topo)
    w, f = spec.initial_theta
    state.window_pages[:] = int(w)
    state.rpcs_in_flight[:] = int(f)
    table = WorkloadTable.from_workloads(spec.make_workloads(), topo)
    wstate = table.init_wstate(state)
    return BuiltScenario(spec=spec, params=params, topo=topo, table=table,
                         state=state, wstate=wstate)


def _jitter_event(ev: DisturbanceEvent, rng) -> DisturbanceEvent:
    """One structure-preserving event jitter (same rng draw order as the
    historical inline version: one magnitude draw, one phase draw)."""
    if ev.kind == "bg_burst":
        mag = ev.magnitude * rng.uniform(0.6, 1.4)
    elif ev.kind in FAULT_KINDS:
        # residual capacity stays a valid outage fraction (< 1)
        mag = float(np.clip(ev.magnitude * rng.uniform(0.7, 1.3), 0.0, 0.9))
    else:
        mag = float(np.clip(ev.magnitude * rng.uniform(0.7, 1.3), 0.01, 1.0))
    shift = rng.uniform(0.0, 0.5)
    # shift the whole window so finite-end events keep their duration
    # (start-only jitter could cross `end` and fail validation)
    end = ev.end if math.isinf(ev.end) else ev.end + shift
    return dataclasses.replace(ev, magnitude=mag, start=ev.start + shift,
                               end=end)


def variants(spec: ScenarioSpec, n: int, seed: int = 0) -> list[ScenarioSpec]:
    """``n`` structure-preserving jitters of a spec (for batch fan-out).

    Continuous workload parameters (request size, thread rate,
    randomness, duty cycling) and event magnitudes/phases are perturbed;
    topology, row count, stripe layout, ops — everything that defines
    the batchable *structure* — stay fixed, so any set of variants of
    one spec stacks into a single vmapped launch.
    """
    out = []
    for i in range(n):
        rng = np.random.default_rng((seed << 16) ^ (spec.seed << 8) ^ i)
        wls = tuple(dataclasses.replace(
            w,
            req_size=float(w.req_size) * 2.0 ** rng.uniform(-0.7, 0.7),
            thread_rate=float(w.thread_rate) * rng.uniform(0.7, 1.3),
            randomness=float(np.clip(w.randomness + rng.uniform(-0.1, 0.1),
                                     0.0, 1.0)),
            period=float(w.period) * rng.uniform(0.8, 1.25),
        ) for w in spec.workloads)
        evs = tuple(_jitter_event(ev, rng) for ev in spec.events)
        out.append(dataclasses.replace(
            spec, name=f"{spec.name}#{i}", workloads=wls, events=evs,
            seed=spec.seed + 1 + i))
    return out


# ---------------------------------------------------------------------- #
# the catalog
# ---------------------------------------------------------------------- #
SCENARIOS: dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec) -> ScenarioSpec:
    SCENARIOS[spec.name] = spec
    return spec


def scenario_names() -> list[str]:
    return list(SCENARIOS)


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {', '.join(SCENARIOS)}") from None


register(ScenarioSpec(
    name="vpic_checkpoint",
    n_clients=4, n_osts=4,
    workloads=tuple(vpic_write(c, dims=1 + c % 3, osts=(0, 1, 2, 3))
                    for c in range(4)),
    description="H5bench VPIC-IO checkpoint: 4 clients write contiguous "
                "particle arrays striped over all OSTs (Table II).",
    tags=("paper", "write"),
))

register(ScenarioSpec(
    name="bdcats_analysis",
    n_clients=4, n_osts=4,
    workloads=tuple(bdcats_read(c, mode, osts=(0, 1, 2, 3))
                    for c, mode in enumerate(("partial", "strided",
                                              "full", "partial"))),
    description="H5bench BDCATS-IO analysis: partial/strided/full reads "
                "of the VPIC output (Table II).",
    tags=("paper", "read"),
))

register(ScenarioSpec(
    name="dlio_bert",
    n_clients=6, n_osts=2,
    workloads=tuple(dlio_reader(c, "bert", n_threads=2 + c % 3,
                                osts=(c % 2,)) for c in range(6)),
    description="DLIO BERT input pipeline: shuffled smallish TFRecord "
                "reads in epoch bursts (Fig. 3).",
    tags=("paper", "read", "bursty"),
))

register(ScenarioSpec(
    name="dlio_megatron",
    n_clients=6, n_osts=2,
    workloads=tuple(dlio_reader(c, "megatron", n_threads=2 + c % 4,
                                osts=(c % 2,)) for c in range(6)),
    description="DLIO Megatron input pipeline: larger sequential-ish "
                "sample reads from indexed .bin files (Fig. 3).",
    tags=("paper", "read", "bursty"),
))

register(ScenarioSpec(
    name="filebench_mix",
    n_clients=8, n_osts=2,
    workloads=tuple(
        (sequential_stream(c, READ, 4 * 2**20, ost=c % 2) if c % 2 else
         random_stream(c, WRITE, 256 * 1024, ost=c % 2, n_threads=2))
        for c in range(8)),
    initial_theta=(64, 2),
    description="Filebench-style mixed streams from a pathological "
                "(64-page, 2-in-flight) start — the run_fleet recovery "
                "scenario and the disturbance-free lab anchor.",
    tags=("paper", "mixed"),
))

register(ScenarioSpec(
    name="noisy_neighbor",
    n_clients=4, n_osts=2,
    workloads=tuple(
        (sequential_stream(c, READ, 4 * 2**20, ost=c % 2) if c < 2 else
         bdcats_read(c, "strided", osts=(0, 1))) for c in range(4)),
    events=(
        DisturbanceEvent("bg_burst", targets=(0,), magnitude=450e6,
                         start=1.0, period=4.0, duty=0.5),
        DisturbanceEvent("bg_burst", targets=(1,), magnitude=450e6,
                         start=3.0, period=4.0, duty=0.5),
    ),
    description="Contention bursts: un-modeled tenants slam alternating "
                "OSTs with 450 MB/s background traffic on a 4 s square "
                "wave; local RPC latency is the only visible symptom.",
    tags=("beyond-paper", "contention-burst"),
))

register(ScenarioSpec(
    name="degraded_ost",
    n_clients=4, n_osts=4,
    workloads=tuple(
        (vpic_write(c, dims=2, osts=(0, 1, 2, 3)) if c < 2 else
         bdcats_read(c, "full", osts=(0, 1, 2, 3))) for c in range(4)),
    events=(
        DisturbanceEvent("ost_slow", targets=(1,), magnitude=0.3,
                         start=2.0),
    ),
    description="Degraded OST: one of four stripe targets drops to 30% "
                "bandwidth and IOPS mid-run (sick disk), turning every "
                "full-stripe op into a straggler problem.",
    tags=("beyond-paper", "degraded-ost"),
))

register(ScenarioSpec(
    name="failing_ost",
    n_clients=4, n_osts=4,
    workloads=tuple(bdcats_read(c, ("partial", "strided")[c % 2],
                                osts=(0, 1, 2, 3)) for c in range(4)),
    events=(
        DisturbanceEvent("ost_slow", targets=(0,), magnitude=0.05,
                         start=3.0),
    ),
    description="Failing OST: stripe target 0 collapses to 5% capacity "
                "at t=3 s and never recovers.",
    tags=("beyond-paper", "degraded-ost"),
))

register(ScenarioSpec(
    name="failover_ost",
    n_clients=4, n_osts=4,
    workloads=tuple(bdcats_read(c, ("partial", "strided")[c % 2],
                                osts=(0, 1, 2, 3)) for c in range(4)),
    events=(
        DisturbanceEvent("ost_failover", targets=(0,), start=2.0, end=4.0,
                         recovery=3.0),
    ),
    description="OST failover: stripe target 0 goes OFFLINE at t=2 s "
                "(shine MOUNTED->OFFLINE), fails back at t=4 s and ramps "
                "to full capacity over 3 s — failback onto a cold target "
                "is never instant.",
    tags=("beyond-paper", "fault", "failover"),
))

register(ScenarioSpec(
    name="client_eviction",
    n_clients=6, n_osts=2,
    workloads=tuple(dlio_reader(c, "bert", n_threads=2 + c % 3,
                                osts=(c % 2,)) for c in range(6)),
    events=(
        DisturbanceEvent("client_evict", targets=(1, 4), start=2.0,
                         end=5.0),
    ),
    description="Client eviction: clients 1 and 4 hit CLIENT_ERROR at "
                "t=2 s — NIC scale 0, queued demand stalls — and "
                "reconnect at t=5 s; survivors inherit the freed "
                "capacity and their optima shift twice.",
    tags=("beyond-paper", "fault", "eviction"),
))

register(ScenarioSpec(
    name="hetero_links",
    n_clients=8, n_osts=2,
    workloads=tuple(sequential_stream(c, READ, 8 * 2**20, ost=c % 2,
                                      n_threads=2) for c in range(8)),
    events=(
        DisturbanceEvent("nic_slow", targets=(4, 5, 6, 7), magnitude=0.12),
    ),
    description="Heterogeneous client links: half the clients sit behind "
                "a 12% NIC (edge boxes on the slow fabric); per-client "
                "optima diverge.",
    tags=("beyond-paper", "hetero-links"),
))

register(ScenarioSpec(
    name="bursty_arrivals",
    n_clients=6, n_osts=2,
    workloads=tuple(
        dataclasses.replace(
            dlio_reader(c, "bert" if c % 2 else "megatron",
                        n_threads=2 + c % 3, osts=(c % 2,)),
            duty_cycle=0.4 if c % 2 else 0.5,
            period=2.0 if c % 2 else 3.0)
        for c in range(6)),
    events=(
        DisturbanceEvent("bg_burst", targets=(0, 1), magnitude=300e6,
                         start=0.5, period=2.0, duty=0.25),
    ),
    description="Bursty arrivals: short-duty DLIO epochs plus 300 MB/s "
                "background spikes every 2 s — steady state never lasts "
                "a full tuning interval.",
    tags=("beyond-paper", "contention-burst", "bursty"),
))
