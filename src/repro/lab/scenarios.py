"""Declarative scenarios: topology + workload mix + disturbance schedule.

A :class:`ScenarioSpec` is a pure-data description of one simulated
cluster run.  Building it produces the engine-level pieces
(``SimParams`` / ``SimTopo`` / ``WorkloadTable`` / ``SimState``) plus a
deterministic per-tick :class:`~repro.pfs.state.Disturbance` schedule,
so the same spec runs bit-equivalently on the numpy oracle
(:func:`repro.pfs.workloads.run_interval`), the fused JAX scan
(:class:`repro.pfs.engine_jax.FusedEngine`), and the vmapped batch path
(:mod:`repro.lab.batch`).

Disturbances are *exogenous*: conditions no client controls or observes
directly.  They are expressed as piecewise/periodic events compiled into
per-tick arrays (a pure function of the absolute tick index, so interval
boundaries and backends cannot disagree):

    ``ost_slow``   scale an OST's bandwidth *and* setup/IOPS capacity
                   (a sick or failing disk is slow at both);
    ``bg_burst``   background bytes/s arriving at an OST from clients
                   outside the simulated fleet (noisy neighbours) — they
                   are served first and inflate the congestion queue;
    ``nic_slow``   scale a client's NIC ceiling (heterogeneous links).

The registry at the bottom names the paper evaluation setups
(vpic / bdcats / dlio / filebench) and beyond-paper stress scenarios;
``python -m repro.lab list`` prints the catalog.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.config_space import DEFAULT
from repro.pfs.state import (Disturbance, SimParams, SimState, SimTopo,
                             init_state)
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import (Workload, WorkloadState, WorkloadTable,
                                 bdcats_read, dlio_reader, random_stream,
                                 sequential_stream, vpic_write)


# ---------------------------------------------------------------------- #
# disturbance events -> per-tick schedules
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class DisturbanceEvent:
    """One piecewise/periodic exogenous condition.

    Active on ticks whose time ``t`` satisfies ``start <= t < end`` and,
    when ``period > 0``, ``(t - start) mod period < duty * period``
    (square-wave bursting).  ``magnitude`` is a scale factor for the
    ``*_slow`` kinds and background bytes/second for ``bg_burst``.
    """

    kind: str                 # "ost_slow" | "bg_burst" | "nic_slow"
    targets: tuple            # OST ids (ost_*/bg_*) or client ids (nic_*)
    magnitude: float
    start: float = 0.0        # seconds
    end: float = math.inf
    period: float = 0.0       # 0 -> constant while inside [start, end)
    duty: float = 1.0

    def active(self, t: np.ndarray) -> np.ndarray:
        act = (t >= self.start) & (t < self.end)
        if self.period > 0:
            act &= np.mod(t - self.start, self.period) < self.duty * self.period
        return act


def make_schedule(events, topo: SimTopo, params: SimParams,
                  t0_tick: int, n_ticks: int) -> Disturbance:
    """Compile events into one interval's per-tick Disturbance schedule.

    Pure function of the absolute tick index ``t0_tick + i``, so
    consecutive intervals tile seamlessly and every backend sees the
    identical exogenous world.
    """
    t = (t0_tick + np.arange(n_ticks)) * params.tick
    sched = Disturbance.neutral(topo, n_ticks=n_ticks)
    for ev in events:
        act = ev.active(t)
        cols = np.asarray(ev.targets, dtype=np.int64)
        if ev.kind == "ost_slow":
            scale = np.where(act, ev.magnitude, 1.0)[:, None]
            sched.bw_scale[:, cols] *= scale
            sched.iops_scale[:, cols] *= scale
        elif ev.kind == "bg_burst":
            sched.bg_bytes[:, cols] += (act * ev.magnitude
                                        * params.tick)[:, None]
        elif ev.kind == "nic_slow":
            sched.nic_scale[:, cols] *= np.where(act, ev.magnitude,
                                                 1.0)[:, None]
        else:
            raise ValueError(f"unknown disturbance kind {ev.kind!r}")
    return sched


# ---------------------------------------------------------------------- #
# scenario spec + build
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Pure-data description of one simulated run.

    ``workloads`` holds unbound :class:`~repro.pfs.workloads.Workload`
    rows (the presets stay the row constructors); ``events`` the
    exogenous disturbance schedule; ``initial_theta`` the knob setting
    every OSC starts from (the Lustre default unless the scenario is
    meant to demonstrate recovery from a pathological config).

    The engine itself is deterministic: two builds of the same spec run
    bit-identically.  ``seed`` seeds the *structure-preserving jitter*
    :func:`variants` derives fan-out populations from — diversity across
    a batch comes from jittered parameters and disturbance phases, not
    from engine noise.
    """

    name: str
    n_clients: int
    n_osts: int
    workloads: tuple = ()
    events: tuple = ()
    initial_theta: tuple = DEFAULT      # (window_pages, rpcs_in_flight)
    seed: int = 0
    description: str = ""
    tags: tuple = ()

    def make_workloads(self) -> list:
        """Fresh (unshared) Workload row instances for attaching to sims."""
        return [dataclasses.replace(w) for w in self.workloads]


@dataclasses.dataclass
class BuiltScenario:
    """Engine-level pieces of one spec, ready to run or stack."""

    spec: ScenarioSpec
    params: SimParams
    topo: SimTopo
    table: WorkloadTable
    state: SimState
    wstate: WorkloadState

    def schedule(self, t0_tick: int, n_ticks: int) -> Disturbance:
        return make_schedule(self.spec.events, self.topo, self.params,
                             t0_tick, n_ticks)


def build(spec: ScenarioSpec, params: SimParams | None = None) -> BuiltScenario:
    """Materialize a spec: topology, frozen workload table, fresh state."""
    params = params or SimParams()
    topo = SimTopo.dense(spec.n_clients, spec.n_osts)
    state = init_state(topo)
    w, f = spec.initial_theta
    state.window_pages[:] = int(w)
    state.rpcs_in_flight[:] = int(f)
    table = WorkloadTable.from_workloads(spec.make_workloads(), topo)
    wstate = table.init_wstate(state)
    return BuiltScenario(spec=spec, params=params, topo=topo, table=table,
                         state=state, wstate=wstate)


def variants(spec: ScenarioSpec, n: int, seed: int = 0) -> list[ScenarioSpec]:
    """``n`` structure-preserving jitters of a spec (for batch fan-out).

    Continuous workload parameters (request size, thread rate,
    randomness, duty cycling) and event magnitudes/phases are perturbed;
    topology, row count, stripe layout, ops — everything that defines
    the batchable *structure* — stay fixed, so any set of variants of
    one spec stacks into a single vmapped launch.
    """
    out = []
    for i in range(n):
        rng = np.random.default_rng((seed << 16) ^ (spec.seed << 8) ^ i)
        wls = tuple(dataclasses.replace(
            w,
            req_size=float(w.req_size) * 2.0 ** rng.uniform(-0.7, 0.7),
            thread_rate=float(w.thread_rate) * rng.uniform(0.7, 1.3),
            randomness=float(np.clip(w.randomness + rng.uniform(-0.1, 0.1),
                                     0.0, 1.0)),
            period=float(w.period) * rng.uniform(0.8, 1.25),
        ) for w in spec.workloads)
        evs = tuple(dataclasses.replace(
            ev,
            magnitude=(ev.magnitude * rng.uniform(0.6, 1.4)
                       if ev.kind == "bg_burst"
                       else float(np.clip(ev.magnitude * rng.uniform(0.7, 1.3),
                                          0.01, 1.0))),
            start=ev.start + rng.uniform(0.0, 0.5),
        ) for ev in spec.events)
        out.append(dataclasses.replace(
            spec, name=f"{spec.name}#{i}", workloads=wls, events=evs,
            seed=spec.seed + 1 + i))
    return out


# ---------------------------------------------------------------------- #
# the catalog
# ---------------------------------------------------------------------- #
SCENARIOS: dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec) -> ScenarioSpec:
    SCENARIOS[spec.name] = spec
    return spec


def scenario_names() -> list[str]:
    return list(SCENARIOS)


def get_scenario(name: str) -> ScenarioSpec:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {', '.join(SCENARIOS)}") from None


register(ScenarioSpec(
    name="vpic_checkpoint",
    n_clients=4, n_osts=4,
    workloads=tuple(vpic_write(c, dims=1 + c % 3, osts=(0, 1, 2, 3))
                    for c in range(4)),
    description="H5bench VPIC-IO checkpoint: 4 clients write contiguous "
                "particle arrays striped over all OSTs (Table II).",
    tags=("paper", "write"),
))

register(ScenarioSpec(
    name="bdcats_analysis",
    n_clients=4, n_osts=4,
    workloads=tuple(bdcats_read(c, mode, osts=(0, 1, 2, 3))
                    for c, mode in enumerate(("partial", "strided",
                                              "full", "partial"))),
    description="H5bench BDCATS-IO analysis: partial/strided/full reads "
                "of the VPIC output (Table II).",
    tags=("paper", "read"),
))

register(ScenarioSpec(
    name="dlio_bert",
    n_clients=6, n_osts=2,
    workloads=tuple(dlio_reader(c, "bert", n_threads=2 + c % 3,
                                osts=(c % 2,)) for c in range(6)),
    description="DLIO BERT input pipeline: shuffled smallish TFRecord "
                "reads in epoch bursts (Fig. 3).",
    tags=("paper", "read", "bursty"),
))

register(ScenarioSpec(
    name="dlio_megatron",
    n_clients=6, n_osts=2,
    workloads=tuple(dlio_reader(c, "megatron", n_threads=2 + c % 4,
                                osts=(c % 2,)) for c in range(6)),
    description="DLIO Megatron input pipeline: larger sequential-ish "
                "sample reads from indexed .bin files (Fig. 3).",
    tags=("paper", "read", "bursty"),
))

register(ScenarioSpec(
    name="filebench_mix",
    n_clients=8, n_osts=2,
    workloads=tuple(
        (sequential_stream(c, READ, 4 * 2**20, ost=c % 2) if c % 2 else
         random_stream(c, WRITE, 256 * 1024, ost=c % 2, n_threads=2))
        for c in range(8)),
    initial_theta=(64, 2),
    description="Filebench-style mixed streams from a pathological "
                "(64-page, 2-in-flight) start — the run_fleet recovery "
                "scenario and the disturbance-free lab anchor.",
    tags=("paper", "mixed"),
))

register(ScenarioSpec(
    name="noisy_neighbor",
    n_clients=4, n_osts=2,
    workloads=tuple(
        (sequential_stream(c, READ, 4 * 2**20, ost=c % 2) if c < 2 else
         bdcats_read(c, "strided", osts=(0, 1))) for c in range(4)),
    events=(
        DisturbanceEvent("bg_burst", targets=(0,), magnitude=450e6,
                         start=1.0, period=4.0, duty=0.5),
        DisturbanceEvent("bg_burst", targets=(1,), magnitude=450e6,
                         start=3.0, period=4.0, duty=0.5),
    ),
    description="Contention bursts: un-modeled tenants slam alternating "
                "OSTs with 450 MB/s background traffic on a 4 s square "
                "wave; local RPC latency is the only visible symptom.",
    tags=("beyond-paper", "contention-burst"),
))

register(ScenarioSpec(
    name="degraded_ost",
    n_clients=4, n_osts=4,
    workloads=tuple(
        (vpic_write(c, dims=2, osts=(0, 1, 2, 3)) if c < 2 else
         bdcats_read(c, "full", osts=(0, 1, 2, 3))) for c in range(4)),
    events=(
        DisturbanceEvent("ost_slow", targets=(1,), magnitude=0.3,
                         start=2.0),
    ),
    description="Degraded OST: one of four stripe targets drops to 30% "
                "bandwidth and IOPS mid-run (sick disk), turning every "
                "full-stripe op into a straggler problem.",
    tags=("beyond-paper", "degraded-ost"),
))

register(ScenarioSpec(
    name="failing_ost",
    n_clients=4, n_osts=4,
    workloads=tuple(bdcats_read(c, ("partial", "strided")[c % 2],
                                osts=(0, 1, 2, 3)) for c in range(4)),
    events=(
        DisturbanceEvent("ost_slow", targets=(0,), magnitude=0.05,
                         start=3.0),
    ),
    description="Failing OST: stripe target 0 collapses to 5% capacity "
                "at t=3 s and never recovers.",
    tags=("beyond-paper", "degraded-ost"),
))

register(ScenarioSpec(
    name="hetero_links",
    n_clients=8, n_osts=2,
    workloads=tuple(sequential_stream(c, READ, 8 * 2**20, ost=c % 2,
                                      n_threads=2) for c in range(8)),
    events=(
        DisturbanceEvent("nic_slow", targets=(4, 5, 6, 7), magnitude=0.12),
    ),
    description="Heterogeneous client links: half the clients sit behind "
                "a 12% NIC (edge boxes on the slow fabric); per-client "
                "optima diverge.",
    tags=("beyond-paper", "hetero-links"),
))

register(ScenarioSpec(
    name="bursty_arrivals",
    n_clients=6, n_osts=2,
    workloads=tuple(
        dataclasses.replace(
            dlio_reader(c, "bert" if c % 2 else "megatron",
                        n_threads=2 + c % 3, osts=(c % 2,)),
            duty_cycle=0.4 if c % 2 else 0.5,
            period=2.0 if c % 2 else 3.0)
        for c in range(6)),
    events=(
        DisturbanceEvent("bg_burst", targets=(0, 1), magnitude=300e6,
                         start=0.5, period=2.0, duty=0.25),
    ),
    description="Bursty arrivals: short-duty DLIO epochs plus 300 MB/s "
                "background spikes every 2 s — steady state never lasts "
                "a full tuning interval.",
    tags=("beyond-paper", "contention-burst", "bursty"),
))
