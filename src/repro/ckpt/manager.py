"""Checkpoint manager: durable save/restore + PFS write-path accounting.

Two concerns, deliberately separated:

1. **Durability** — params/opt-state/pipeline-cursor serialize to local
   .npz files (flattened pytree with stable key paths).  Restore rebuilds
   the exact pytree; a corrupt/partial file falls back to the previous
   checkpoint (atomic rename protocol).

2. **PFS accounting** — on a real cluster every host streams its shard of
   the checkpoint through its Lustre client.  ``pfs_write()`` pushes the
   byte volume through each host's simulated client write path (grants,
   dirty cache, RPC formation — the part of the paper's write model that
   matters), where the DIAL agent tunes it.  ``flush_time()`` reports how
   long the PFS took to absorb the checkpoint — the number EXPERIMENTS.md
   compares DIAL-on vs DIAL-off.

Fault-tolerance contract: ``restore_latest()`` + the pipeline cursor give
exact-step resume; partially-written checkpoints are never visible
(tmp + atomic rename); ``keep`` bounds disk usage.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

from repro.pfs.engine import WRITE, PFSSim


def _flatten(tree, prefix="", out=None):
    out = out if out is not None else {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            _flatten(tree[k], f"{prefix}{k}/", out)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            _flatten(v, f"{prefix}{i}/", out)
    else:
        arr = np.asarray(tree)
        if arr.dtype.name == "bfloat16":  # npz has no native bf16
            arr = arr.astype(np.float32)
        out[prefix[:-1]] = arr
    return out


def _unflatten_like(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_like(template[k], flat, f"{prefix}{k}/")
                for k in template}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_like(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        return type(template)(vals)
    arr = flat[prefix[:-1]]
    leaf = template
    dtype = getattr(leaf, "dtype", np.asarray(leaf).dtype)
    shape = getattr(leaf, "shape", np.asarray(leaf).shape)
    # cast via jnp so bf16 (and other ml_dtypes) round-trip
    return np.asarray(jax.numpy.asarray(arr).astype(dtype)).reshape(shape)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 sim: PFSSim | None = None, hosts: list[int] | None = None):
        self.dir = directory
        self.keep = keep
        self.sim = sim
        self.hosts = hosts or ([0] if sim is not None else [])
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ #
    def save(self, step: int, params, opt_state=None, extra: dict | None = None,
             through_pfs: bool = True) -> str:
        flat = _flatten({"params": params,
                         "opt": opt_state if opt_state is not None else {}})
        path = os.path.join(self.dir, f"ckpt_{step:08d}.npz")
        tmp = path + ".tmp.npz"
        np.savez(tmp, **{k: v for k, v in flat.items()})
        meta = {"step": step, "extra": extra or {}}
        with open(path + ".meta.tmp", "w") as f:
            json.dump(meta, f)
        os.replace(tmp, path)                       # atomic visibility
        os.replace(path + ".meta.tmp", path + ".meta")
        if through_pfs and self.sim is not None:
            nbytes = sum(v.nbytes for v in flat.values())
            self.pfs_write(nbytes)
        self._gc()
        return path

    def pfs_write(self, nbytes: float) -> float:
        """Push the checkpoint bytes through each host's client write path;
        returns sim-seconds until the dirty cache fully drains."""
        per_host = nbytes / max(len(self.hosts), 1)
        for h in self.hosts:
            osc = self.sim.osc_id(h, h % self.sim.n_osts)
            remaining = per_host
            guard = 0
            while remaining > 0 and guard < 100000:
                got = self.sim.submit_write(osc, min(remaining, 8 * 2**20),
                                            0.0, 8 * 2**20)
                remaining -= got
                if got <= 0:
                    self.sim.step()
                guard += 1
        t0 = self.sim.now
        guard = 0
        while self.sim.dirty_bytes.sum() > 1.0 and guard < 200000:
            self.sim.step()
            guard += 1
        return self.sim.now - t0

    # ------------------------------------------------------------------ #
    def latest_step(self) -> int | None:
        steps = [int(f[5:13]) for f in os.listdir(self.dir)
                 if f.startswith("ckpt_") and f.endswith(".npz")
                 and not f.endswith(".tmp.npz")]
        return max(steps) if steps else None

    def restore(self, step: int, params_template, opt_template=None):
        path = os.path.join(self.dir, f"ckpt_{step:08d}.npz")
        z = np.load(path)
        flat = {k: z[k] for k in z.files}
        tree = _unflatten_like(
            {"params": params_template,
             "opt": opt_template if opt_template is not None else {}}, flat)
        meta = {}
        if os.path.exists(path + ".meta"):
            with open(path + ".meta") as f:
                meta = json.load(f)
        return tree["params"], tree["opt"], meta

    def restore_latest(self, params_template, opt_template=None):
        step = self.latest_step()
        if step is None:
            return None
        params, opt, meta = self.restore(step, params_template, opt_template)
        return step, params, opt, meta

    def _gc(self) -> None:
        files = sorted(f for f in os.listdir(self.dir)
                       if f.startswith("ckpt_") and f.endswith(".npz")
                       and not f.endswith(".tmp.npz"))
        for f in files[:-self.keep]:
            os.remove(os.path.join(self.dir, f))
            meta = os.path.join(self.dir, f.replace(".npz", ".npz.meta"))
            if os.path.exists(meta):
                os.remove(meta)


def reshard_checkpoint(params, new_mesh, pspecs):
    """Elastic re-mesh: place a restored pytree onto a different mesh.

    Arrays are host numpy; jax.device_put with the new NamedShardings
    re-lays them out — the checkpoint format is mesh-agnostic by
    construction, which is what makes shrink/grow restarts possible.
    """
    from repro.distributed.sharding import named
    sh = named(new_mesh, pspecs)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), params, sh)
