"""Checkpointing through the PFS write path."""

from repro.ckpt.manager import CheckpointManager

__all__ = ["CheckpointManager"]
