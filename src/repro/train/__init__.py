"""Training: optimizer, step builders, trainer loop."""
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.steps import make_decode_step, make_prefill_step, make_train_step
