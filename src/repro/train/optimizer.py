"""AdamW in pure JAX (no optax offline), with cosine schedule + clipping.

Moments are f32; with ZeRO-1 the moment pytrees carry data-axis sharding
(see repro.distributed.sharding.zero1_pspecs) so optimizer memory scales
down with the data-parallel world size.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}
