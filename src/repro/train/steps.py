"""Train / prefill / decode step builders (the functions the launcher jits).

``make_train_step`` supports gradient-accumulation microbatching: the
global batch reshapes to (n_micro, micro, ...) and a lax.scan accumulates
f32 gradients — live activation memory scales with the microbatch while
arithmetic stays identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    grad_accum: int = 1, remat: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss(p, mb):
        return lm.loss_fn(p, mb, cfg, remat=remat)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
        else:
            def micro(i, b):
                return jax.tree.map(
                    lambda x: x.reshape((grad_accum, -1) + x.shape[1:])[i], b)

            def acc_step(carry, i):
                tot_l, g_acc = carry
                l, g = jax.value_and_grad(loss)(params, micro(i, batch))
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (tot_l + l, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (tot_l, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), g0),
                jnp.arange(grad_accum))
            l = tot_l / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        metrics["loss"] = l
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, tokens, img_embeds=None):
        return lm.prefill(params, tokens, cfg, max_len=max_len,
                          img_embeds=img_embeds)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, tokens, cache, cur_len):
        return lm.decode_step(params, tokens, cache, cur_len, cfg)
    return decode_step
