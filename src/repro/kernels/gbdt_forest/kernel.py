"""Pallas TPU kernel: batched GBDT forest inference.

The paper's per-tick hot spot is scoring the whole configuration space for
every OSC interface (Table III: 10-13.5 ms per interface on a 16-core
host CPU).  On a TPU-hosted training cluster we batch all
(interface x config) rows into one launch.

TPU adaptation (vs GPU warp-per-tree traversal, which relies on per-lane
divergent control flow): the forest lives wholly in VMEM as dense arrays
(a 160-tree depth-5 forest is ~60 KiB) and descent is *level-synchronous
predicated* — every (sample, tree) lane advances exactly one level per
step via vectorized gathers + selects, no data-dependent branches.  The
sample axis is tiled by BlockSpec so each grid step streams one block of
samples HBM->VMEM while the forest stays resident.

This kernel is VPU/latency-bound by design (no MXU work) — the win is
batching and memory locality, not FLOPs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 512


def _forest_kernel(x_ref, feat_ref, thr_ref, leaf_ref, out_ref, *,
                   depth: int, base_score: float):
    """One grid step: margins for a (BLOCK_N, F) tile of samples."""
    x = x_ref[...]                      # (BN, F)  VMEM tile
    feat = feat_ref[...]                # (T, I)   resident forest
    thr = thr_ref[...]
    leaf = leaf_ref[...]
    bn = x.shape[0]
    t, n_internal = feat.shape

    feat_flat = feat.reshape(-1)
    thr_flat = thr.reshape(-1)
    leaf_flat = leaf.reshape(-1)
    tree_off = jnp.arange(t, dtype=jnp.int32) * n_internal

    idx = jnp.zeros((bn, t), dtype=jnp.int32)
    # static unrolled descent: depth is small (4-6); each step is pure
    # vector ops — gather, compare, predicated advance
    for _ in range(depth):
        node = idx + tree_off[None, :]
        f = feat_flat[node]
        th = thr_flat[node]
        xv = jnp.take_along_axis(x, f, axis=1)
        idx = 2 * idx + 1 + (xv > th).astype(jnp.int32)

    leaf_off = jnp.arange(t, dtype=jnp.int32) * leaf.shape[1]
    vals = leaf_flat[(idx - n_internal) + leaf_off[None, :]]
    out_ref[...] = vals.sum(axis=1).astype(jnp.float32) + jnp.float32(base_score)


def forest_margin(x, feature, threshold, leaf, base_score: float, depth: int,
                  block_n: int = DEFAULT_BLOCK_N, interpret: bool = True):
    """Batched forest margins via pl.pallas_call.

    Args match :func:`repro.kernels.gbdt_forest.ref.forest_margin_ref`.
    ``interpret=True`` executes on CPU (validation); on TPU pass False.
    """
    n, f = x.shape
    t, n_internal = feature.shape
    n_pad = -n % block_n
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
    grid = ((n + n_pad) // block_n,)

    out = pl.pallas_call(
        functools.partial(_forest_kernel, depth=depth,
                          base_score=float(base_score)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),       # sample tile
            pl.BlockSpec((t, n_internal), lambda i: (0, 0)),    # forest stays
            pl.BlockSpec((t, n_internal), lambda i: (0, 0)),    #   resident in
            pl.BlockSpec((t, leaf.shape[1]), lambda i: (0, 0)), #   VMEM
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad,), jnp.float32),
        interpret=interpret,
        name="gbdt_forest_margin",
    )(x, feature, threshold, leaf)
    return out[:n]


# ---------------------------------------------------------------------- #
# paired forests: one launch scores mixed read/write rows for the fleet
# ---------------------------------------------------------------------- #
def _paired_forest_kernel(x_ref, op_ref, feat_ref, thr_ref, leaf_ref,
                          base_ref, out_ref, *, depth: int):
    """Margins for a (BLOCK_N, F) tile with per-row forest selection.

    Both forests (stacked on the leading axis) stay VMEM-resident; each
    row adds ``op * T * nodes`` to its gather indices, so selecting a
    forest costs one vector add — no divergence, no second traversal.
    """
    x = x_ref[...]                      # (BN, F)  VMEM tile
    opv = op_ref[...]                   # (BN,)    0 = read, 1 = write
    feat = feat_ref[...]                # (2, T, I) resident forests
    thr = thr_ref[...]
    leaf = leaf_ref[...]
    base = base_ref[...]                # (2,)
    bn = x.shape[0]
    _, t, n_internal = feat.shape
    n_leaves = leaf.shape[2]

    feat_flat = feat.reshape(-1)
    thr_flat = thr.reshape(-1)
    leaf_flat = leaf.reshape(-1)
    tree_off = jnp.arange(t, dtype=jnp.int32) * n_internal
    forest_off = opv * (t * n_internal)                 # (BN,)

    idx = jnp.zeros((bn, t), dtype=jnp.int32)
    for _ in range(depth):
        node = idx + tree_off[None, :] + forest_off[:, None]
        f = feat_flat[node]
        th = thr_flat[node]
        xv = jnp.take_along_axis(x, f, axis=1)
        idx = 2 * idx + 1 + (xv > th).astype(jnp.int32)

    leaf_off = jnp.arange(t, dtype=jnp.int32) * n_leaves
    vals = leaf_flat[(idx - n_internal) + leaf_off[None, :]
                     + (opv * (t * n_leaves))[:, None]]
    out_ref[...] = vals.sum(axis=1).astype(jnp.float32) + base[opv]


def paired_forest_margin(x, op, feature, threshold, leaf, base, depth: int,
                         block_n: int = DEFAULT_BLOCK_N,
                         interpret: bool = True):
    """Batched margins over two stacked forests with per-row selection.

    Args match :func:`repro.kernels.gbdt_forest.ref.paired_forest_margin_ref`.
    This is the fleet agent's single launch per tuning tick: all
    (interface x config) rows for both ops at once.
    """
    n, f = x.shape
    _, t, n_internal = feature.shape
    n_pad = -n % block_n
    if n_pad:
        x = jnp.pad(x, ((0, n_pad), (0, 0)))
        op = jnp.pad(op, (0, n_pad))
    grid = ((n + n_pad) // block_n,)

    out = pl.pallas_call(
        functools.partial(_paired_forest_kernel, depth=depth),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, f), lambda i: (i, 0)),          # sample tile
            pl.BlockSpec((block_n,), lambda i: (i,)),              # row ops
            pl.BlockSpec((2, t, n_internal), lambda i: (0, 0, 0)), # forests
            pl.BlockSpec((2, t, n_internal), lambda i: (0, 0, 0)), #   stay
            pl.BlockSpec((2, t, leaf.shape[2]), lambda i: (0, 0, 0)),  # in VMEM
            pl.BlockSpec((2,), lambda i: (0,)),                    # base margins
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad,), jnp.float32),
        interpret=interpret,
        name="gbdt_paired_forest_margin",
    )(x, op.astype(jnp.int32), feature, threshold, leaf, base)
    return out[:n]
