"""Pure-jnp oracle for dense-forest GBDT inference.

Matches :meth:`repro.core.gbdt.DenseForest.predict_margin` bit-for-bit on
float32 inputs: a static ``depth``-step level-synchronous descent through
complete binary trees laid out in dense arrays.
"""

from __future__ import annotations

import jax.numpy as jnp


def forest_margin_ref(x, feature, threshold, leaf, base_score: float,
                      depth: int):
    """Reference forest margins.

    Args:
        x:         (N, F) float32 samples.
        feature:   (T, 2^D - 1) int32 split features.
        threshold: (T, 2^D - 1) float32 split thresholds (+inf = pass left).
        leaf:      (T, 2^D) float32 leaf values.
        base_score: scalar initial margin.
        depth:     D, static.

    Returns:
        (N,) float32 margins (pre-sigmoid).
    """
    n = x.shape[0]
    t = feature.shape[0]
    n_internal = feature.shape[1]
    # flatten forests for (sample, tree) gathers
    feat_flat = feature.reshape(-1)
    thr_flat = threshold.reshape(-1)
    leaf_flat = leaf.reshape(-1)
    tree_off = jnp.arange(t, dtype=jnp.int32) * n_internal

    idx = jnp.zeros((n, t), dtype=jnp.int32)
    for _ in range(depth):
        node = idx + tree_off[None, :]
        f = feat_flat[node]                      # (N, T)
        thr = thr_flat[node]                     # (N, T)
        xv = jnp.take_along_axis(x, f, axis=1)   # (N, T)
        go_right = (xv > thr).astype(jnp.int32)
        idx = 2 * idx + 1 + go_right
    leaf_idx = idx - n_internal
    vals = leaf_flat[leaf_idx + jnp.arange(t, dtype=jnp.int32)[None, :] * leaf.shape[1]]
    return vals.sum(axis=1).astype(jnp.float32) + jnp.float32(base_score)


def forest_proba_ref(x, feature, threshold, leaf, base_score: float, depth: int):
    m = forest_margin_ref(x, feature, threshold, leaf, base_score, depth)
    return 1.0 / (1.0 + jnp.exp(-jnp.clip(m, -30.0, 30.0)))


def paired_forest_margin_ref(x, op, feature, threshold, leaf, base,
                             depth: int):
    """Margins with per-row forest selection (the fleet inference oracle).

    Two forests (read / write) are stacked on a leading axis; each row of
    ``x`` traverses the forest named by ``op``.  Selection is just an
    extra per-row offset into the flattened forest arrays — no extra
    traversal work for the unselected forest.

    Args:
        x:         (N, F) float32 samples (F = max of both forests' dims).
        op:        (N,) int32 forest selector, 0 or 1.
        feature:   (2, T, 2^D - 1) int32.
        threshold: (2, T, 2^D - 1) float32 (+inf = pass left).
        leaf:      (2, T, 2^D) float32.
        base:      (2,) float32 per-forest base margin.
        depth:     D, static.

    Returns:
        (N,) float32 margins (pre-sigmoid).
    """
    n = x.shape[0]
    _, t, n_internal = feature.shape
    n_leaves = leaf.shape[2]
    feat_flat = feature.reshape(-1)
    thr_flat = threshold.reshape(-1)
    leaf_flat = leaf.reshape(-1)
    tree_off = jnp.arange(t, dtype=jnp.int32) * n_internal
    forest_off = op.astype(jnp.int32) * (t * n_internal)     # (N,)

    idx = jnp.zeros((n, t), dtype=jnp.int32)
    for _ in range(depth):
        node = idx + tree_off[None, :] + forest_off[:, None]
        f = feat_flat[node]
        thr = thr_flat[node]
        xv = jnp.take_along_axis(x, f, axis=1)
        idx = 2 * idx + 1 + (xv > thr).astype(jnp.int32)
    leaf_off = jnp.arange(t, dtype=jnp.int32) * n_leaves
    leaf_forest_off = op.astype(jnp.int32) * (t * n_leaves)
    vals = leaf_flat[(idx - n_internal) + leaf_off[None, :]
                     + leaf_forest_off[:, None]]
    return vals.sum(axis=1).astype(jnp.float32) + base[op]
