"""Jitted public wrappers for GBDT forest inference."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gbdt_forest import kernel as _kernel
from repro.kernels.gbdt_forest import ref as _ref


def make_predictor(forest, use_pallas: bool = False, interpret: bool = True):
    """Build a jitted ``X -> probabilities`` closure for a DenseForest.

    The forest arrays are closed over (donated to the device once);
    only the sample matrix streams per call.
    """
    feature = jnp.asarray(forest.feature, dtype=jnp.int32)
    threshold = jnp.asarray(forest.threshold, dtype=jnp.float32)
    leaf = jnp.asarray(forest.leaf, dtype=jnp.float32)
    base = float(forest.base_score)
    depth = int(forest.depth)

    if use_pallas:
        def margin_fn(x):
            return _kernel.forest_margin(x, feature, threshold, leaf, base,
                                         depth, interpret=interpret)
    else:
        def margin_fn(x):
            return _ref.forest_margin_ref(x, feature, threshold, leaf, base,
                                          depth)

    @jax.jit
    def predict(x):
        m = margin_fn(x.astype(jnp.float32))
        return 1.0 / (1.0 + jnp.exp(-jnp.clip(m, -30.0, 30.0)))

    return predict
