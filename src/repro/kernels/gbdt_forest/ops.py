"""Jitted public wrappers for GBDT forest inference."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gbdt_forest import kernel as _kernel
from repro.kernels.gbdt_forest import ref as _ref


def make_predictor(forest, use_pallas: bool = False, interpret: bool = True):
    """Build a jitted ``X -> probabilities`` closure for a DenseForest.

    The forest arrays are closed over (donated to the device once);
    only the sample matrix streams per call.
    """
    feature = jnp.asarray(forest.feature, dtype=jnp.int32)
    threshold = jnp.asarray(forest.threshold, dtype=jnp.float32)
    leaf = jnp.asarray(forest.leaf, dtype=jnp.float32)
    base = float(forest.base_score)
    depth = int(forest.depth)

    if use_pallas:
        def margin_fn(x):
            return _kernel.forest_margin(x, feature, threshold, leaf, base,
                                         depth, interpret=interpret)
    else:
        def margin_fn(x):
            return _ref.forest_margin_ref(x, feature, threshold, leaf, base,
                                          depth)

    @jax.jit
    def predict(x):
        m = margin_fn(x.astype(jnp.float32))
        return 1.0 / (1.0 + jnp.exp(-jnp.clip(m, -30.0, 30.0)))

    return predict


# ---------------------------------------------------------------------- #
# fleet inference: both forests, mixed-op row batch, one launch
# ---------------------------------------------------------------------- #
def _pad_forest(feature, threshold, leaf, depth: int, to_depth: int,
                to_trees: int):
    """Pad one dense forest to ``(to_trees, to_depth)`` without changing
    its predictions.

    Depth grows by turning every leaf into a pass-through internal node
    (threshold ``+inf`` descends left) whose left child carries the old
    leaf value; extra trees are all-pass-through with 0-valued leaves.
    """
    feature = np.asarray(feature, dtype=np.int32)
    threshold = np.asarray(threshold, dtype=np.float32)
    leaf = np.asarray(leaf, dtype=np.float32)
    t = feature.shape[0]
    for _ in range(to_depth - depth):
        n_leaves = leaf.shape[1]
        feature = np.concatenate(
            [feature, np.zeros((t, n_leaves), dtype=np.int32)], axis=1)
        threshold = np.concatenate(
            [threshold, np.full((t, n_leaves), np.inf, dtype=np.float32)],
            axis=1)
        new_leaf = np.zeros((t, 2 * n_leaves), dtype=np.float32)
        new_leaf[:, 0::2] = leaf            # left child of each pass-through
        leaf = new_leaf
    if to_trees > t:
        n_internal, n_leaves = feature.shape[1], leaf.shape[1]
        pad = to_trees - t
        feature = np.concatenate(
            [feature, np.zeros((pad, n_internal), dtype=np.int32)], axis=0)
        threshold = np.concatenate(
            [threshold, np.full((pad, n_internal), np.inf, dtype=np.float32)],
            axis=0)
        leaf = np.concatenate(
            [leaf, np.zeros((pad, n_leaves), dtype=np.float32)], axis=0)
    return feature, threshold, leaf


def pair_forests(read_forest, write_forest):
    """Stack the read and write DenseForests into one paired tensor set.

    Returns ``(feature, threshold, leaf, base, depth, n_features)`` with
    forest axis 0 = read, 1 = write, both padded to the larger depth /
    tree count.  Sample matrices must be zero-padded to ``n_features``
    columns (the larger of the two models' input dims); padding never
    changes a prediction because pass-through trees and spines carry the
    original leaf values and inert trees contribute exactly 0.
    """
    depth = max(read_forest.depth, write_forest.depth)
    t = max(read_forest.n_trees, write_forest.n_trees)
    fr = _pad_forest(read_forest.feature, read_forest.threshold,
                     read_forest.leaf, read_forest.depth, depth, t)
    fw = _pad_forest(write_forest.feature, write_forest.threshold,
                     write_forest.leaf, write_forest.depth, depth, t)
    feature = np.stack([fr[0], fw[0]])          # (2, T, 2^D - 1)
    threshold = np.stack([fr[1], fw[1]])
    leaf = np.stack([fr[2], fw[2]])             # (2, T, 2^D)
    base = np.array([read_forest.base_score, write_forest.base_score],
                    dtype=np.float32)
    n_features = max(read_forest.n_features, write_forest.n_features)
    return feature, threshold, leaf, base, depth, n_features


def _round_up_pow2(n: int, floor: int = 32) -> int:
    cap = floor
    while cap < n:
        cap *= 2
    return cap


def make_fleet_predictor(read_forest, write_forest, use_pallas: bool = False,
                         interpret: bool = True):
    """Build the fleet scorer: ``(X_read, X_write) -> (p_read, p_write)``.

    Both ops' (interface x config) rows are fused into one padded batch
    with a per-row forest selector and scored in a **single** launch —
    the per-tick inference cost no longer scales with the number of
    Python-level agents or with having two models.  Row counts are
    bucketed to powers of two so jit traces a handful of shapes total.
    """
    feature, threshold, leaf, base, depth, n_features = pair_forests(
        read_forest, write_forest)
    feature = jnp.asarray(feature)
    threshold = jnp.asarray(threshold)
    leaf = jnp.asarray(leaf)
    base = jnp.asarray(base)

    if use_pallas:
        def margin_fn(x, op):
            return _kernel.paired_forest_margin(
                x, op, feature, threshold, leaf, base, depth,
                interpret=interpret)
    else:
        def margin_fn(x, op):
            return _ref.paired_forest_margin_ref(
                x, op, feature, threshold, leaf, base, depth)

    @jax.jit
    def _predict(x, op):
        m = margin_fn(x.astype(jnp.float32), op)
        return 1.0 / (1.0 + jnp.exp(-jnp.clip(m, -30.0, 30.0)))

    def predict(x_read: np.ndarray, x_write: np.ndarray):
        nr = 0 if x_read is None else x_read.shape[0]
        nw = 0 if x_write is None else x_write.shape[0]
        n = nr + nw
        if n == 0:
            return np.zeros(0), np.zeros(0)
        cap = _round_up_pow2(n)
        x = np.zeros((cap, n_features), dtype=np.float32)
        op = np.zeros(cap, dtype=np.int32)
        if nr:
            x[:nr, :x_read.shape[1]] = x_read
        if nw:
            x[nr:n, :x_write.shape[1]] = x_write
            op[nr:n] = 1
        p = np.asarray(_predict(x, op))
        return p[:nr], p[nr:n]

    return predict
