"""Pure-jnp oracle for the Mamba-1 selective scan.

Recurrence (per batch, channel d, state n):
    h_t = exp(delta_t * A) * h_{t-1} + (delta_t * u_t) * B_t
    y_t = (h_t . C_t) + D * u_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(u, delta, A, B, C, D):
    """Sequential-scan reference.

    Args:
        u:     (Bt, S, Dm) gated input.
        delta: (Bt, S, Dm) positive timestep (post-softplus).
        A:     (Dm, N) negative-real state matrix.
        B:     (Bt, S, N) input projection.
        C:     (Bt, S, N) output projection.
        D:     (Dm,) skip gain.

    Returns:
        y: (Bt, S, Dm) float32.
    """
    u = u.astype(jnp.float32)
    delta = delta.astype(jnp.float32)
    A = A.astype(jnp.float32)
    B = B.astype(jnp.float32)
    C = C.astype(jnp.float32)
    D = D.astype(jnp.float32)
    bt, s, dm = u.shape
    n = A.shape[1]

    def step(h, xs):
        u_t, d_t, b_t, c_t = xs
        a = jnp.exp(d_t[:, :, None] * A[None])            # (Bt, Dm, N)
        h = a * h + (d_t * u_t)[:, :, None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + D[None] * u_t
        return h, y

    h0 = jnp.zeros((bt, dm, n), jnp.float32)
    xs = (jnp.moveaxis(u, 1, 0), jnp.moveaxis(delta, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)
