"""Pallas TPU kernel for the Mamba-1 selective scan.

TPU adaptation: the CUDA implementation fuses a warp-parallel prefix scan
over shared memory.  TPUs have no cross-lane scan primitive, but the
recurrence is *diagonal* per (channel, state) pair, so we tile the channel
axis into VMEM blocks (grid = (batch, channel_blocks)) and run the time
loop sequentially *inside* the kernel with the (block_d, N) state held in
registers/VMEM.  Each grid step touches HBM once for its (S, block_d)
slab — the scan itself is entirely on-chip, which is the whole point on
the HBM->VMEM hierarchy.

The sequence axis is unblocked (one slab per grid step).  For very long
sequences the surrounding layer chunks S before calling (see
repro.models.mamba); kernel-side S-chunking with state handoff would use
input_output_aliases and is left as a documented production extension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 256


def _mamba_kernel(u_ref, delta_ref, a_ref, b_ref, c_ref, d_ref, o_ref):
    u = u_ref[0].astype(jnp.float32)          # (S, bd)
    delta = delta_ref[0].astype(jnp.float32)  # (S, bd)
    A = a_ref[...].astype(jnp.float32)        # (bd, N)
    B = b_ref[0].astype(jnp.float32)          # (S, N)
    C = c_ref[0].astype(jnp.float32)          # (S, N)
    D = d_ref[...].astype(jnp.float32)        # (bd,)

    bd, n = A.shape

    def step(h, xs):
        u_t, d_t, b_t, c_t = xs               # (bd,), (bd,), (N,), (N,)
        coef = jnp.exp(d_t[:, None] * A)      # (bd, N)
        h = coef * h + (d_t * u_t)[:, None] * b_t[None, :]
        y = (h * c_t[None, :]).sum(axis=1) + D * u_t
        return h, y

    h0 = jnp.zeros((bd, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (u, delta, B, C))
    o_ref[0] = ys.astype(o_ref.dtype)


def selective_scan(u, delta, A, B, C, D, *, block_d: int = DEFAULT_BLOCK_D,
                   interpret: bool = True):
    """Selective scan via pl.pallas_call; args as in ref.selective_scan_ref."""
    bt, s, dm = u.shape
    n = A.shape[1]
    block_d = min(block_d, dm)
    assert dm % block_d == 0, (dm, block_d)
    grid = (bt, dm // block_d)

    return pl.pallas_call(
        _mamba_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, block_d), lambda b, i: (b, 0, i)),   # u
            pl.BlockSpec((1, s, block_d), lambda b, i: (b, 0, i)),   # delta
            pl.BlockSpec((block_d, n), lambda b, i: (i, 0)),         # A
            pl.BlockSpec((1, s, n), lambda b, i: (b, 0, 0)),         # B
            pl.BlockSpec((1, s, n), lambda b, i: (b, 0, 0)),         # C
            pl.BlockSpec((block_d,), lambda b, i: (i,)),             # D
        ],
        out_specs=pl.BlockSpec((1, s, block_d), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((bt, s, dm), jnp.float32),
        interpret=interpret,
        name="mamba_selective_scan",
    )(u, delta, A, B, C, D)
