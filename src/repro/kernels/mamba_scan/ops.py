"""Jitted public wrapper for the Mamba selective scan."""

from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan import kernel as _kernel
from repro.kernels.mamba_scan import ref as _ref


@functools.partial(jax.jit, static_argnames=("backend", "block_d"))
def selective_scan(u, delta, A, B, C, D, *, backend: str = "ref",
                   block_d: int = _kernel.DEFAULT_BLOCK_D):
    if backend == "ref":
        return _ref.selective_scan_ref(u, delta, A, B, C, D)
    return _kernel.selective_scan(
        u, delta, A, B, C, D, block_d=block_d,
        interpret=(backend == "pallas_interpret"))
