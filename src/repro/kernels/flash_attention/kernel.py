"""Pallas TPU flash attention (forward).

Canonical TPU tiling: grid = (batch*q_heads, q_blocks, kv_blocks) with the
kv axis innermost.  Each (bh, qi) output tile is revisited across kv steps
while online-softmax statistics (running max m, normalizer l) and the
accumulator live in VMEM scratch; the final kv step rescales and writes.

Block shapes are MXU-aligned (q_block x d and kv_block x d tiles with d a
multiple of 128 ideally; q/kv blocks multiples of the 8-sublane tile).
GQA is expressed through the k/v BlockSpec index maps (q-head h reads kv
head h // group) — no materialized head repetition, which is the memory
win vs the naive einsum on TPU.

Supports: causal masking (end-aligned), sliding window, Gemma-2 logit
softcap.  Sliding-window + causal skips fully-masked kv blocks by clamping
work to the masked band (the index maps still visit them; the @pl.when
guard makes them cheap).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  softcap: float, sq: int, skv: int, block_q: int,
                  block_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_kv = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this tile's queries/keys, end-aligned on the
    # ORIGINAL (unpadded) lengths: real query i sits at i + (skv - sq);
    # padded queries land past the end (harmless, sliced off), padded keys
    # are masked by the validity test below.
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0) \
        + (skv - sq)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)

    needed = jnp.bool_(True)
    if causal:
        # tile participates iff some key <= some query
        needed &= (ki * block_kv) <= (qi * block_q + (skv - sq) + block_q - 1)
    if window is not None:
        first_valid = qi * block_q + (skv - sq) - window + 1
        needed &= (ki + 1) * block_kv - 1 >= first_valid
    needed &= (ki * block_kv) < skv  # tile of pure padding keys

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (block_kv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_pos < skv  # padded keys are never valid
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                        # (block_q, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)        # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float = 0.0, scale: float | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True):
    """Flash attention forward.

    Args:
        q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D), Hq % Hkv == 0.
        block_q / block_kv: VMEM tile sizes (MXU-aligned multiples of 8/128).
        interpret: run the kernel body in Python on CPU (validation mode).

    Returns:
        (B, Hq, Sq, D), dtype of q.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = d ** -0.5 if scale is None else scale

    # pad sequence dims to block multiples (end-aligned causal stays valid
    # because padding keys are masked by position comparisons)
    pq = -sq % block_q
    pkv = -skv % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pkv), (0, 0)))

    grid = (b * hq, (sq + pq) // block_q, (skv + pkv) // block_kv)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, sq=sq, skv=skv,
        block_q=block_q, block_kv=block_kv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bh, qi, ki: (bh // hq, bh % hq, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bh, qi, ki: (bh // hq, (bh % hq) // group, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bh, qi, ki: (bh // hq, (bh % hq) // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bh, qi, ki: (bh // hq, bh % hq, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq + pq, d), q.dtype),
        scratch_shapes=[
            _vmem((block_q, 1)),
            _vmem((block_q, 1)),
            _vmem((block_q, d)),
        ],
        interpret=interpret,
        name="flash_attention_fwd",
    )(q, k, v)
    return out[:, :, :sq, :]


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
