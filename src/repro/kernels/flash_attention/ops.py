"""Jitted public wrapper for flash attention with backend dispatch."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.flash_attention import ref as _ref


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "backend", "block_q",
                     "block_kv"))
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              softcap: float = 0.0, backend: str = "ref",
              block_q: int = 128, block_kv: int = 128):
    """Attention entry point.

    backend:
        'ref'              -- materialized jnp oracle (small shapes/tests)
        'pallas_interpret' -- TPU kernel executed in interpret mode (CPU)
        'pallas'           -- TPU kernel compiled for TPU
    """
    if backend == "ref":
        return _ref.mha_ref(q, k, v, causal=causal, window=window,
                            softcap=softcap).astype(q.dtype)
    return _kernel.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv,
        interpret=(backend == "pallas_interpret"))
