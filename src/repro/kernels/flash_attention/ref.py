"""Pure-jnp oracle for flash attention (GQA + causal + window + softcap).

The O(S^2) materialized form is the ground truth for the Pallas kernel
and for the chunked online-softmax production path in
:mod:`repro.models.attention` (used when lowering on non-TPU backends).
"""

from __future__ import annotations

import jax.numpy as jnp


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def mha_ref(q, k, v, *, causal: bool = True, window: int | None = None,
            softcap: float = 0.0, scale: float | None = None):
    """Materialized attention.

    Args:
        q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.
        causal: apply causal mask aligned to the sequence end
            (query i attends to keys j <= i + (Skv - Sq)).
        window: additionally mask keys more than `window` positions behind
            the query (sliding-window / local attention).
        softcap: if > 0, logits = softcap * tanh(logits / softcap)
            (Gemma-2 logit soft-capping).
        scale: defaults to D ** -0.5.

    Returns:
        (B, Hq, Sq, D) float32.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    scale = d ** -0.5 if scale is None else scale

    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    qi = jnp.arange(sq)[:, None] + (skv - sq)
    kj = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    logits = jnp.where(mask[None, None], logits, -1e30)
    return jnp.einsum("bhqk,bhkd->bhqd", _softmax(logits), vr.astype(jnp.float32))
