"""Shared dispatch for the GBDT training histogram primitive.

Every per-level reduction in the :mod:`repro.learn` trainer routes
through :func:`tree_histogram`, so one dispatch decides the execution
strategy for the whole boosting pass (mirroring
:mod:`repro.kernels.segment_reduce.ops`):

==========  ============================================================
backend      implementation
==========  ============================================================
``numpy``    ``np.bincount`` per channel (the oracle)
``jax``      ``jax.ops.segment_sum`` (XLA scatter-add)
``matmul``   dense factorized one-hot contraction (CPU/GPU default —
             XLA's CPU scatter runs tens of ns per element, while the
             same reduction as two one-hot products is BLAS work)
``pallas``   one-hot-matmul Pallas kernel (TPU default; MXU, no scatter)
``auto``     pallas on TPU, matmul elsewhere
==========  ============================================================

Every backend drops samples whose ``node`` id falls outside
``[0, n_nodes)`` — the sibling-subtraction trick addresses only left
children and parks right-child samples on id ``n_nodes``.
"""

from __future__ import annotations

import functools

# NOTE: jax/pallas implementations import lazily so the numpy oracle
# stays importable without jax (same contract as segment_reduce.ops).


@functools.lru_cache(maxsize=1)
def _default_jax_backend() -> str:
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "matmul"


def _tree_histogram_segsum(values, bins, node, n_nodes: int, n_bins: int):
    """XLA scatter-add fallback: one flat segment_sum over (sample, feature)
    pairs, all channels riding the trailing data axis."""
    import jax
    import jax.numpy as jnp

    values = jnp.asarray(values)
    bins = jnp.asarray(bins)
    node = jnp.asarray(node)
    c, n = values.shape
    f = bins.shape[1]
    flat = ((node[:, None] * f + jnp.arange(f)[None, :]) * n_bins
            + bins).ravel()                                   # (n*F,)
    data = jnp.broadcast_to(values.T[:, None, :], (n, f, c)).reshape(-1, c)
    out = jax.ops.segment_sum(data, flat,
                              num_segments=n_nodes * f * n_bins)
    return jnp.transpose(out.reshape(n_nodes, f, n_bins, c), (3, 0, 1, 2))


def bin_onehot(bins, n_bins: int, dtype=None):
    """Static per-feature bin one-hot ``(n, F * n_bins)`` — hoistable
    (bin codes never change across levels or trees of one training run)."""
    import jax.numpy as jnp

    bins = jnp.asarray(bins)
    n, f = bins.shape
    oh = (bins[:, :, None] == jnp.arange(n_bins)[None, None, :])
    return oh.reshape(n, f * n_bins).astype(dtype or jnp.float64)


def matmul_histogram(values, onehot, node, n_nodes: int, n_bins: int):
    """The factorized dense contraction given a prebuilt bin one-hot.

    ``out[c,j,f,b] = sum_i [node_i = j] values[c,i] onehot[i, f*NB+b]``
    as (node one-hot * values) @ onehot — two dense products, no
    scatter.  Out-of-range node ids match no one-hot row and drop.
    """
    import jax.numpy as jnp

    c, n = values.shape
    sel = (node[None, :] == jnp.arange(n_nodes)[:, None]
           ).astype(values.dtype)                      # (n_nodes, n)
    u = (values[:, None, :] * sel[None]).reshape(c * n_nodes, n)
    out = u @ onehot                                   # (C*nodes, F*NB)
    return out.reshape(c, n_nodes, -1, n_bins)


def _tree_histogram_matmul(values, bins, node, n_nodes: int, n_bins: int):
    """Self-contained matmul backend (builds the bin one-hot per call;
    hoist it with :func:`bin_onehot` + :func:`matmul_histogram` when
    calling repeatedly over static bins, as the trainer does)."""
    import jax.numpy as jnp

    values = jnp.asarray(values)
    return matmul_histogram(values, bin_onehot(bins, n_bins, values.dtype),
                            jnp.asarray(node), n_nodes, n_bins)


def make_tree_histogram(backend: str = "auto"):
    """Return ``tree_histogram(values, bins, node, n_nodes, n_bins)`` for
    a backend name; the returned callable is safe to close over under
    jit (and under ``vmap`` for the ``jax`` path)."""
    if backend == "numpy":
        from repro.kernels.tree_histogram import ref as _ref
        return _ref.tree_histogram_np
    if backend == "auto":
        backend = _default_jax_backend()
    if backend == "jax":
        return _tree_histogram_segsum
    if backend == "matmul":
        return _tree_histogram_matmul
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels.tree_histogram import kernel as _kernel
        interpret = backend == "pallas_interpret"
        return functools.partial(_kernel.tree_histogram, interpret=interpret)
    raise ValueError(f"unknown tree_histogram backend {backend!r}")


def tree_histogram(values, bins, node, n_nodes: int, n_bins: int,
                   backend: str = "auto"):
    """One-call convenience over :func:`make_tree_histogram`."""
    return make_tree_histogram(backend)(values, bins, node, n_nodes, n_bins)
