"""Pallas TPU kernel: GBDT training histograms as one-hot matmuls.

One boosting level needs, for every (tree node, feature, bin) cell, the
sum of each sample's channel statistics (gradient / hessian / count).
XLA lowers the obvious ``segment_sum`` formulation to scatter-add, which
serializes on TPU.  But the cell count per feature is static and small
(``n_nodes * n_bins``), so — exactly like
:mod:`repro.kernels.segment_reduce` — the reduction is a dense matmul
against a one-hot matrix built on the fly in VMEM:

    combined = node * n_bins + bin            (BE,)    per feature tile
    onehot   = combined[:, None] == iota      (BE, S)  S = n_nodes*n_bins
    partial  = values @ onehot                (C, S)   MXU work

The grid walks (feature, sample-tile); the per-feature (C, S) output
block stays VMEM-resident across all sample tiles, and all C channels
ride one matmul, so a whole level's gradient+hessian+count histograms
are a single launch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_E = 1024


def _tree_histogram_kernel(values_ref, bins_ref, node_ref, out_ref, *,
                           n_nodes: int, n_bins: int):
    """One grid step: fold a (C, BE) value tile of one feature into the
    feature's resident (C, S) histogram block."""
    s = n_nodes * n_bins
    values = values_ref[...].astype(jnp.float32)       # (C, BE)
    bins = bins_ref[...][:, 0]                         # (BE,) int32
    node = node_ref[...]                               # (BE,) int32

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    combined = node * n_bins + bins                    # (BE,)
    onehot = (combined[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, s), 1)
              ).astype(jnp.float32)                    # (BE, S)
    partial = jnp.dot(values, onehot,
                      preferred_element_type=jnp.float32)  # (C, S)
    out_ref[...] += partial[:, None, :]


def tree_histogram(values, bins, node, n_nodes: int, n_bins: int,
                   block_e: int = DEFAULT_BLOCK_E, interpret: bool = True):
    """Multi-channel (node, feature, bin) histograms via one-hot matmuls.

    Args/shapes as :func:`repro.kernels.tree_histogram.ref
    .tree_histogram_np`; returns ``(C, n_nodes, F, n_bins)`` float32.
    ``interpret=True`` executes on CPU (validation); on TPU pass False.
    Sample padding uses node id ``n_nodes`` so its one-hot row is all
    zeros and contributes nothing.
    """
    values = jnp.asarray(values, dtype=jnp.float32)
    bins = jnp.asarray(bins, dtype=jnp.int32)
    node = jnp.asarray(node, dtype=jnp.int32)
    c, e = values.shape
    f = bins.shape[1]
    s = n_nodes * n_bins
    e_pad = -e % block_e
    if e_pad:
        values = jnp.pad(values, ((0, 0), (0, e_pad)))
        bins = jnp.pad(bins, ((0, e_pad), (0, 0)))
        node = jnp.pad(node, (0, e_pad), constant_values=n_nodes)
    grid = (f, (e + e_pad) // block_e)

    out = pl.pallas_call(
        functools.partial(_tree_histogram_kernel,
                          n_nodes=n_nodes, n_bins=n_bins),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, block_e), lambda fi, i: (0, i)),   # values
            pl.BlockSpec((block_e, 1), lambda fi, i: (i, fi)),  # bin codes
            pl.BlockSpec((block_e,), lambda fi, i: (i,)),       # node ids
        ],
        out_specs=pl.BlockSpec((c, 1, s), lambda fi, i: (0, fi, 0)),
        out_shape=jax.ShapeDtypeStruct((c, f, s), jnp.float32),
        interpret=interpret,
        name="tree_histogram_onehot",
    )(values, bins, node)
    # (C, F, n_nodes * n_bins) -> (C, n_nodes, F, n_bins)
    return jnp.transpose(out.reshape(c, f, n_nodes, n_bins), (0, 2, 1, 3))
