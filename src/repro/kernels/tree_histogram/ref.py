"""Numpy oracle for per-(node, feature, bin) histogram accumulation.

Histogram GBDT training reduces each boosting level to one multi-channel
scatter-add: for every sample, add its per-channel statistics (gradient,
hessian, sample count, ...) into the cell addressed by (its current tree
node, a feature, that feature's bin code).  This module is the
``np.add.at`` oracle the jax/pallas implementations are pinned against.
"""

from __future__ import annotations

import numpy as np


def tree_histogram_np(values, bins, node, n_nodes: int, n_bins: int):
    """``out[c, j, f, b] = sum(values[c, i] : node[i]==j, bins[i,f]==b)``.

    Args:
        values: ``(C, n)`` per-sample channel statistics (g, h, count...).
        bins:   ``(n, F)`` integer bin codes in ``[0, n_bins)``.
        node:   ``(n,)`` level-local node assignment in ``[0, n_nodes)``.
        n_nodes, n_bins: static output extents.

    Samples whose ``node`` id falls outside ``[0, n_nodes)`` are dropped
    (the sibling-subtraction trick addresses only left children and
    parks right-child samples on id ``n_nodes``).

    Returns ``(C, n_nodes, F, n_bins)`` float64.
    """
    values = np.asarray(values, dtype=np.float64)
    bins = np.asarray(bins)
    node = np.asarray(node)
    keep = (node >= 0) & (node < n_nodes)
    values, bins, node = values[:, keep], bins[keep], node[keep]
    c, n = values.shape
    f = bins.shape[1]
    out = np.zeros((c, n_nodes, f, n_bins), dtype=np.float64)
    # flat (node, bin) cell per (sample, feature); one bincount per channel
    flat = (node[:, None] * f + np.arange(f)[None, :]) * n_bins + bins
    flat = flat.ravel()
    size = n_nodes * f * n_bins
    for ch in range(c):
        w = np.repeat(values[ch], f)
        out[ch] = np.bincount(flat, weights=w,
                              minlength=size).reshape(n_nodes, f, n_bins)
    return out
