"""Pure-jnp oracle for the RG-LRU gated linear recurrence (Griffin).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t

with a_t in (0, 1) the per-channel recurrent gate.  The sqrt(1-a^2)
input normalization is Griffin's (arXiv:2402.19427 eq. 4).

The reference uses an associative scan (the composition
(a1,b1)*(a2,b2) = (a1*a2, a2*b1 + b2) is associative), which is also the
production jnp path for training on long sequences.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(x, a):
    """Associative-scan reference.

    Args:
        x: (B, S, D) input.
        a: (B, S, D) recurrent gate in (0, 1).

    Returns:
        h: (B, S, D) float32.
    """
    x = x.astype(jnp.float32)
    a = a.astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * x

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
