"""Pallas TPU kernel for the RG-LRU gated linear recurrence.

Same TPU adaptation story as the Mamba scan: the recurrence is diagonal
per channel, so we tile channels into VMEM blocks (grid =
(batch, channel_blocks)) and scan time on-chip.  State is a (block_d,)
vector — trivially resident.  This is the decode-path workhorse for
recurrentgemma where the sequential scan (not the parallel one) is what
runs per new token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 512


def _rglru_kernel(x_ref, a_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)   # (S, bd)
    a = a_ref[0].astype(jnp.float32)   # (S, bd)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * x

    def step(h, xs):
        a_t, b_t = xs
        h = a_t * h + b_t
        return h, h

    h0 = jnp.zeros((x.shape[1],), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (a, b))
    o_ref[0] = hs.astype(o_ref.dtype)


def rglru(x, a, *, block_d: int = DEFAULT_BLOCK_D, interpret: bool = True):
    """RG-LRU scan via pl.pallas_call; args as in ref.rglru_ref."""
    bt, s, dm = x.shape
    block_d = min(block_d, dm)
    assert dm % block_d == 0, (dm, block_d)
    grid = (bt, dm // block_d)

    return pl.pallas_call(
        _rglru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, s, block_d), lambda b, i: (b, 0, i)),
            pl.BlockSpec((1, s, block_d), lambda b, i: (b, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, s, block_d), lambda b, i: (b, 0, i)),
        out_shape=jax.ShapeDtypeStruct((bt, s, dm), jnp.float32),
        interpret=interpret,
        name="rglru_scan",
    )(x, a)
