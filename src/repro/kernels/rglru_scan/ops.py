"""Jitted public wrapper for the RG-LRU scan."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rglru_scan import kernel as _kernel
from repro.kernels.rglru_scan import ref as _ref


@functools.partial(jax.jit, static_argnames=("backend", "block_d"))
def rglru(x, a, *, backend: str = "ref",
          block_d: int = _kernel.DEFAULT_BLOCK_D):
    if backend == "ref":
        return _ref.rglru_ref(x, a)
    return _kernel.rglru(x, a, block_d=block_d,
                         interpret=(backend == "pallas_interpret"))
