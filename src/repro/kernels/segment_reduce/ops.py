"""Shared segment-sum helper: the engine's one reduction primitive.

Every per-tick aggregation in the PFS engine (six historical
``np.bincount`` call sites across formation/drain/bandwidth plus the
workload stripe scatter) routes through :func:`segment_sum`, so one
dispatch decides the execution strategy for the whole tick:

==========  ============================================================
backend      implementation
==========  ============================================================
``numpy``    ``np.bincount(..., weights=...)`` (the oracle)
``jax``      ``jax.ops.segment_sum`` (XLA scatter-add; CPU/GPU default)
``pallas``   one-hot-matmul Pallas kernel (TPU default; MXU, no scatter)
``auto``     pallas on TPU, jax elsewhere
==========  ============================================================
"""

from __future__ import annotations

import functools

import numpy as np

# NOTE: the jax/pallas implementations are imported lazily inside
# make_segment_sum so that the numpy oracle (and everything that imports
# it, e.g. repro.pfs.workloads) stays importable without jax.


def segment_sum_np(values, segment_ids, num_segments: int):
    """Numpy oracle: ``np.bincount`` with weights."""
    return np.bincount(segment_ids, weights=np.asarray(values, dtype=float),
                       minlength=num_segments)


@functools.lru_cache(maxsize=1)
def _default_jax_backend() -> str:
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "jax"


def make_segment_sum(backend: str = "auto"):
    """Return ``segment_sum(values, segment_ids, num_segments)`` for a
    backend name; the returned callable is safe to close over under jit."""
    if backend == "numpy":
        return segment_sum_np
    if backend == "auto":
        backend = _default_jax_backend()
    if backend == "jax":
        from repro.kernels.segment_reduce import ref as _ref
        return _ref.segment_sum_ref
    if backend in ("pallas", "pallas_interpret"):
        from repro.kernels.segment_reduce import kernel as _kernel
        interpret = backend == "pallas_interpret"
        return functools.partial(_kernel.segment_sum, interpret=interpret)
    raise ValueError(f"unknown segment_sum backend {backend!r}")


def segment_sum(values, segment_ids, num_segments: int,
                backend: str = "auto"):
    """One-call convenience over :func:`make_segment_sum`."""
    return make_segment_sum(backend)(values, segment_ids, num_segments)
