"""Pallas TPU kernel: segment sum as a one-hot matmul.

The engine's per-tick reductions (OST setup-work/IOPS/bandwidth
aggregation over OSCs, NIC aggregation over clients, workload stripe
scatter/gather) are all segment sums over a *static* segment mapping.
XLA lowers ``jax.ops.segment_sum`` to scatter-add, which serializes on
TPU; with a static, small segment count the same reduction is one
``(1, E) @ (E, S)`` one-hot matmul — dense MXU work, no scatter at all.

The values axis is tiled by BlockSpec; each grid step builds the one-hot
block on the fly from the resident segment-id tile (iota compare — never
materialized in HBM) and accumulates its partial product into the single
(S,)-block output, which stays VMEM-resident across the whole grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_E = 1024


def _segment_sum_kernel(x_ref, seg_ref, out_ref, *, num_segments: int):
    """One grid step: accumulate a (BLOCK_E,) tile into the (S,) output."""
    x = x_ref[...].astype(jnp.float32)          # (BE,)
    seg = seg_ref[...]                          # (BE,) int32

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # one-hot built in VMEM from an iota compare; (1, BE) @ (BE, S) on MXU
    onehot = (seg[:, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, num_segments), 1)
              ).astype(jnp.float32)
    partial = jnp.dot(x[None, :], onehot,
                      preferred_element_type=jnp.float32)[0]
    out_ref[...] += partial


def segment_sum(values, segment_ids, num_segments: int,
                block_e: int = DEFAULT_BLOCK_E, interpret: bool = True):
    """Segment sum of 1-D ``values`` via one-hot matmul tiles.

    ``interpret=True`` executes on CPU (validation); on TPU pass False.
    Out-of-range padding ids are handled by padding with ``num_segments``
    (their one-hot row is all zeros, so they contribute nothing).
    """
    e = values.shape[0]
    e_pad = -e % block_e
    if e_pad:
        values = jnp.pad(values, (0, e_pad))
        segment_ids = jnp.pad(segment_ids, (0, e_pad),
                              constant_values=num_segments)
    grid = ((e + e_pad) // block_e,)

    out = pl.pallas_call(
        functools.partial(_segment_sum_kernel, num_segments=num_segments),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e,), lambda i: (i,)),    # values tile
            pl.BlockSpec((block_e,), lambda i: (i,)),    # segment-id tile
        ],
        out_specs=pl.BlockSpec((num_segments,), lambda i: (0,)),  # resident
        out_shape=jax.ShapeDtypeStruct((num_segments,), jnp.float32),
        interpret=interpret,
        name="segment_sum_onehot",
    )(values, segment_ids.astype(jnp.int32))
    return out
