"""Reference segment sum: jax.ops.segment_sum (XLA scatter-add)."""

from __future__ import annotations

import jax


def segment_sum_ref(values, segment_ids, num_segments: int):
    """``out[s] = sum(values[segment_ids == s])`` over 1-D values."""
    return jax.ops.segment_sum(values, segment_ids,
                               num_segments=num_segments)
