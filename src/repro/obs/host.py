"""Host-path tracer: the fused trace schema, emitted by the host loop.

:class:`HostTracer` collects the identical records the traced
:class:`~repro.pfs.loop_jax.FusedLoop` emits as scan outputs —
per-interval decision provenance from :class:`~repro.core.fleet.FleetAgent`
(which calls :meth:`record_interval` every tick, gated or not) and
per-tick timeline samples from the ``run_fleet`` numpy loop (which
calls :meth:`sample` at the fused path's exact sample offsets).  The
result is a :class:`~repro.obs.schema.RunTrace` diffable row-for-row
against a traced fused run of the same scenario
(tests/test_obs.py).
"""

from __future__ import annotations

import numpy as np

from repro.obs.schema import RunTrace, TraceConfig, normalize_decisions, \
    timeline_tap


class HostTracer:
    """Accumulates host-loop records; one instance per traced run."""

    def __init__(self, config: TraceConfig | None = None,
                 params=None, topo=None):
        self.config = config if config is not None else TraceConfig()
        self.params = params
        self.topo = topo
        self._dec: list[dict] = []
        self._tl: list[dict] = []

    # ------------------------------------------------------------------ #
    # decision mirror (called by FleetAgent.tick, every interval)
    # ------------------------------------------------------------------ #
    def record_interval(self, t, decided, ops, theta, changed,
                        n_candidates, score, probs, vol_r, vol_w, active,
                        steady, warm, ratio, cur_theta) -> None:
        """One interval's full-fleet record (pre-masking raw values —
        the same masking as the fused path applies in normalization)."""
        self._dec.append({
            "t": float(t), "decided": np.asarray(decided, dtype=bool),
            "ops": np.asarray(ops), "theta": np.asarray(theta),
            "changed": np.asarray(changed),
            "n_candidates": np.asarray(n_candidates),
            "score": np.asarray(score), "probs": np.asarray(probs),
            "vol_r": np.asarray(vol_r), "vol_w": np.asarray(vol_w),
            "active": np.asarray(active), "steady": np.asarray(steady),
            "warm": bool(warm), "ratio": np.asarray(ratio),
            "cur_theta": np.asarray(cur_theta)})

    def wants_sample(self, tick_in_interval: int,
                     steps_per_interval: int) -> bool:
        """Sample offsets matching the fused chunked scan: within each
        interval, ticks ``stride-1, 2*stride-1, ...`` (remainder ticks
        past the last full stride are not sampled)."""
        if not self.config.timeline:
            return False
        s = self.config.stride
        n_chunks = steps_per_interval // s
        return (tick_in_interval + 1) % s == 0 and \
            tick_in_interval < n_chunks * s

    def sample(self, state, dist=None) -> None:
        """One timeline sample off the live (numpy) ``SimState``."""
        self._tl.append(timeline_tap(self.params, self.topo, state,
                                     dist, xp=np))

    # ------------------------------------------------------------------ #
    def run_trace(self, oscs, interval_seconds: float,
                  tick_seconds: float) -> RunTrace:
        """Normalize everything recorded so far to a :class:`RunTrace`."""
        if not self._dec:
            raise ValueError("no intervals recorded")
        stack = lambda k: np.stack([d[k] for d in self._dec])
        decisions = normalize_decisions(
            np.asarray([d["t"] for d in self._dec]),
            stack("decided"), stack("ops"), stack("theta"),
            stack("changed"), stack("n_candidates"), stack("score"),
            stack("probs"), stack("vol_r"), stack("vol_w"),
            stack("active"), stack("steady"),
            np.asarray([d["warm"] for d in self._dec]),
            stack("ratio"), stack("cur_theta"))
        timeline = None
        if self._tl:
            timeline = {k: np.stack([np.asarray(s[k]) for s in self._tl])
                        for k in self._tl[0]}
            timeline["t"] = timeline["t"].astype(np.float64)
        return RunTrace(decisions=decisions, timeline=timeline,
                        oscs=np.asarray(oscs, dtype=np.int64),
                        config=self.config,
                        interval_seconds=float(interval_seconds),
                        tick_seconds=float(tick_seconds))
