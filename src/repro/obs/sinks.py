"""Host-side trace sinks: JSONL, Chrome ``trace_event``, markdown.

The JSONL sink is the lossless interchange format (one record per line,
first line a ``meta`` header; round-trips through :func:`read_jsonl`).
The Chrome sink renders the same records as a ``trace_event`` JSON that
opens directly in Perfetto / ``chrome://tracing``: one counter track
per OST (throughput, queue, dirty-cache room, disturbance scales) and
one thread per interface carrying its decisions as instant events —
applied θ changes stand out as named markers with the full Algorithm 1
provenance in ``args``.  Timestamps are simulated microseconds.
"""

from __future__ import annotations

import json

import numpy as np

from repro.obs.schema import (TRACE_SCHEMA, TRACE_SCHEMAS, RunTrace,
                              TraceConfig)


# ---------------------------------------------------------------------- #
# JSONL
# ---------------------------------------------------------------------- #
def write_jsonl(trace: RunTrace, path: str,
                diagnosis: dict | None = None) -> str:
    meta = {
        "kind": "meta",
        "schema": TRACE_SCHEMA,
        "stride": trace.config.stride,
        "timeline": trace.config.timeline,
        "interval_seconds": trace.interval_seconds,
        "tick_seconds": trace.tick_seconds,
        "oscs": [int(x) for x in trace.oscs],
        "n_intervals": trace.n_intervals,
    }
    with open(path, "w") as f:
        f.write(json.dumps(meta) + "\n")
        for row in trace.decision_rows():
            f.write(json.dumps(row) + "\n")
        for row in trace.timeline_rows():
            f.write(json.dumps(row) + "\n")
        if diagnosis is not None:
            f.write(json.dumps({"kind": "diagnosis", **diagnosis},
                               sort_keys=True) + "\n")
    return path


def read_jsonl(path: str) -> RunTrace:
    """Rebuild a :class:`RunTrace` from its JSONL serialization.

    Accepts any schema in :data:`~repro.obs.schema.TRACE_SCHEMAS`.
    Dispatch is by explicit ``kind`` — a ``diagnosis`` record (or any
    future kind) is surfaced via :func:`read_jsonl_diagnosis`, never
    misfiled as a timeline row.
    """
    with open(path) as f:
        meta = json.loads(f.readline())
        if meta.get("schema") not in TRACE_SCHEMAS:
            raise ValueError(f"not a {'/'.join(TRACE_SCHEMAS)} file: {path}")
        dec_rows, tl_rows = [], []
        for line in f:
            row = json.loads(line)
            if row["kind"] == "decision":
                dec_rows.append(row)
            elif row["kind"] == "timeline":
                tl_rows.append(row)

    oscs = np.asarray(meta["oscs"], dtype=np.int64)
    n, m = meta["n_intervals"], len(oscs)
    col = {j: idx for idx, j in enumerate(meta["oscs"])}
    n_probs = len(dec_rows[0]["probs"]) if dec_rows else 0
    dec = {
        "t": np.zeros(n),
        "decided": np.zeros((n, m), dtype=bool),
        "ops": np.zeros((n, m), dtype=np.int64),
        "theta": np.zeros((n, m, 2), dtype=np.int64),
        "changed": np.zeros((n, m), dtype=bool),
        "n_candidates": np.zeros((n, m), dtype=np.int64),
        "score": np.zeros((n, m)),
        "probs": np.zeros((n, m, n_probs)),
        "vol_r": np.zeros((n, m)), "vol_w": np.zeros((n, m)),
        "active": np.zeros((n, m), dtype=bool),
        "steady": np.zeros((n, m), dtype=bool),
        "warm": np.zeros((n, m), dtype=bool),
        "ratio": np.zeros((n, m)),
    }
    for r in dec_rows:
        i, j = r["interval"], col[r["osc"]]
        dec["t"][i] = r["t"]
        dec["decided"][i, j] = r["decided"]
        dec["ops"][i, j] = r["op"]
        dec["theta"][i, j] = r["theta"]
        dec["changed"][i, j] = r["changed"]
        dec["n_candidates"][i, j] = r["n_candidates"]
        dec["score"][i, j] = r["score"]
        dec["probs"][i, j] = r["probs"]
        for k in ("vol_r", "vol_w", "active", "steady", "warm", "ratio"):
            dec[k][i, j] = r[k]

    timeline = None
    if tl_rows:
        tl_rows.sort(key=lambda r: r["sample"])
        timeline = {"t": np.asarray([r["t"] for r in tl_rows])}
        from repro.obs.schema import TIMELINE_FIELDS
        for k in TIMELINE_FIELDS[1:]:
            timeline[k] = np.asarray([r[k] for r in tl_rows])
    cfg = TraceConfig(stride=meta["stride"], timeline=meta["timeline"])
    return RunTrace(decisions=dec, timeline=timeline, oscs=oscs,
                    config=cfg,
                    interval_seconds=meta["interval_seconds"],
                    tick_seconds=meta["tick_seconds"])


def read_jsonl_diagnosis(path: str) -> dict | None:
    """The file's ``diagnosis`` record, if one was stamped."""
    with open(path) as f:
        meta = json.loads(f.readline())
        if meta.get("schema") not in TRACE_SCHEMAS:
            raise ValueError(f"not a {'/'.join(TRACE_SCHEMAS)} file: {path}")
        for line in f:
            row = json.loads(line)
            if row["kind"] == "diagnosis":
                return {k: v for k, v in row.items() if k != "kind"}
    return None


# ---------------------------------------------------------------------- #
# Chrome trace_event (Perfetto)
# ---------------------------------------------------------------------- #
_OST_PID = 1          # process grouping the per-OST counter tracks
_IF_PID = 2           # process grouping the per-interface decision rows
_DIAG_PID = 3         # process carrying diagnosis verdict markers


def chrome_trace(trace: RunTrace, diagnosis: dict | None = None) -> dict:
    """The run as a Chrome ``trace_event`` object (JSON-serializable).

    Counter events (``ph: "C"``) per OST — throughput derived from the
    cumulative byte counters between samples — and instant events
    (``ph: "i"``) per interface decision.  ``ts`` is simulated time in
    microseconds; events are emitted time-sorted.

    With ``diagnosis`` (a :mod:`repro.obs.diagnose` report), a third
    process carries the verdict: one process-scoped instant at t=0
    naming the dominant cause (arm throughputs in ``args``) plus one
    instant per evidence row, landed on the *same* interval timestamps
    as the decision rows they explain, so cause markers line up with
    the decisions they indict in Perfetto.
    """
    events = [
        {"ph": "M", "pid": _OST_PID, "name": "process_name",
         "args": {"name": "osts"}},
        {"ph": "M", "pid": _IF_PID, "name": "process_name",
         "args": {"name": "interfaces"}},
    ]
    timed = []
    if trace.timeline is not None:
        tl = trace.timeline
        n_s, n_o = tl["read_bytes"].shape
        for o in range(n_o):
            events.append({"ph": "M", "pid": _OST_PID, "tid": o,
                           "name": "thread_name",
                           "args": {"name": f"ost{o}"}})
        t = tl["t"]
        for i in range(n_s):
            ts = t[i] * 1e6
            dt = (t[i] - t[i - 1]) if i else max(float(t[i]), 1e-9)
            for o in range(n_o):
                read_mbs = ((tl["read_bytes"][i, o]
                             - (tl["read_bytes"][i - 1, o] if i else 0.0))
                            / dt / 1e6)
                write_mbs = ((tl["write_bytes"][i, o]
                              - (tl["write_bytes"][i - 1, o] if i else 0.0))
                             / dt / 1e6)
                timed.append({"ph": "C", "pid": _OST_PID, "tid": o,
                              "name": f"ost{o}.throughput_mbs", "ts": ts,
                              "args": {"read": round(read_mbs, 3),
                                       "write": round(write_mbs, 3)}})
                timed.append({"ph": "C", "pid": _OST_PID, "tid": o,
                              "name": f"ost{o}.queue", "ts": ts,
                              "args": {"queue_mb":
                                       round(tl["queue_bytes"][i, o] / 1e6,
                                             3),
                                       "active_rpcs":
                                       round(float(tl["active_rpcs"][i, o]),
                                             2)}})
                timed.append({"ph": "C", "pid": _OST_PID, "tid": o,
                              "name": f"ost{o}.dirty_room_mb", "ts": ts,
                              "args": {"room":
                                       round(tl["dirty_room"][i, o] / 1e6,
                                             3)}})
                timed.append({"ph": "C", "pid": _OST_PID, "tid": o,
                              "name": f"ost{o}.disturbance", "ts": ts,
                              "args": {"bw": round(float(tl["bw_scale"][i, o]), 3),
                                       "iops": round(float(tl["iops_scale"][i, o]), 3),
                                       "bg_mb": round(tl["bg_bytes"][i, o] / 1e6, 3)}})

    d = trace.decisions
    for j in range(trace.n_interfaces):
        events.append({"ph": "M", "pid": _IF_PID, "tid": int(trace.oscs[j]),
                       "name": "thread_name",
                       "args": {"name": f"if{int(trace.oscs[j])}"}})
    for i in range(trace.n_intervals):
        ts = float(d["t"][i]) * 1e6
        for j in range(trace.n_interfaces):
            if not d["decided"][i, j]:
                continue
            th = d["theta"][i, j]
            name = (f"θ→{int(th[0])}x{int(th[1])}" if d["changed"][i, j]
                    else "hold")
            timed.append({
                "ph": "i", "s": "t", "pid": _IF_PID,
                "tid": int(trace.oscs[j]), "ts": ts, "name": name,
                "args": {
                    "op": "read" if int(d["ops"][i, j]) == 0 else "write",
                    "theta": [int(th[0]), int(th[1])],
                    "changed": bool(d["changed"][i, j]),
                    "n_candidates": int(d["n_candidates"][i, j]),
                    "score": round(float(d["score"][i, j]), 4),
                    "p_max": round(float(d["probs"][i, j].max())
                                   if d["probs"].shape[2] else 0.0, 4),
                }})
    if diagnosis is not None:
        cause = diagnosis.get("cause", "unknown")
        events.append({"ph": "M", "pid": _DIAG_PID, "name": "process_name",
                       "args": {"name": "diagnosis"}})
        events.append({"ph": "M", "pid": _DIAG_PID, "tid": 0,
                       "name": "thread_name",
                       "args": {"name": f"cause:{cause}"}})
        timed.append({"ph": "i", "s": "p", "pid": _DIAG_PID, "tid": 0,
                      "ts": 0.0, "name": f"verdict:{cause}",
                      "args": {"losing": diagnosis.get("losing"),
                               "arms": diagnosis.get("arms", {}),
                               "n_evidence_total":
                               diagnosis.get("n_evidence_total")}})
        for row in diagnosis.get("evidence", []):
            if "t" not in row:       # arm-summary rows carry no timestamp
                continue
            # land on the trace's own interval timestamp (the evidence
            # rounds t for the report; the raw floats must match the
            # decision instants exactly to line up in Perfetto)
            i = row.get("interval", -1)
            ts = (float(d["t"][i]) * 1e6 if 0 <= i < len(d["t"])
                  else float(row["t"]) * 1e6)
            timed.append({"ph": "i", "s": "p", "pid": _DIAG_PID, "tid": 0,
                          "ts": ts, "name": cause,
                          "args": {k: v for k, v in row.items()
                                   if k != "t"}})
    timed.sort(key=lambda e: e["ts"])
    return {"traceEvents": events + timed,
            "displayTimeUnit": "ms",
            "otherData": {"schema": TRACE_SCHEMA}}


def write_chrome(trace: RunTrace, path: str,
                 diagnosis: dict | None = None) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(trace, diagnosis=diagnosis), f)
    return path


# ---------------------------------------------------------------------- #
# markdown summary
# ---------------------------------------------------------------------- #
def render_summary(trace: RunTrace, title: str = "trace",
                   diagnosis: dict | None = None) -> str:
    """Human-readable digest: gate outcomes, θ trajectory, hot OSTs —
    plus the counterfactual verdict when a diagnosis rides along."""
    d = trace.decisions
    n, m = trace.n_intervals, trace.n_interfaces
    lines = [f"# Trace summary — {title}", ""]
    lines.append(f"{n} intervals × {m} interfaces "
                 f"(interval {trace.interval_seconds:.3g} s, timeline "
                 f"stride {trace.config.stride} ticks).")
    lines.append("")
    if m:
        total = n * m
        gates = {
            "decided": int(d["decided"].sum()),
            "cold (warmup)": int((~d["warm"]).sum()),
            "idle (volume gate)": int((d["warm"] & ~d["active"]).sum()),
            "bursty (steadiness gate)": int(
                (d["warm"] & d["active"] & ~d["steady"]).sum()),
        }
        lines.append("| gate outcome | rows | share |")
        lines.append("|---|---|---|")
        for k, v in gates.items():
            lines.append(f"| {k} | {v} | {100 * v / total:.1f}% |")
        lines.append("")
        changes = int(d["changed"].sum())
        lines.append(f"Algorithm 1 applied **{changes}** θ change(s); "
                     f"mean candidates past τ on decided rows: "
                     f"{float(d['n_candidates'][d['decided']].mean()) if d['decided'].any() else 0:.1f}.")
        lines.append("")
        lines.append("## θ changes")
        lines.append("")
        any_change = False
        for i in range(n):
            for j in np.nonzero(d["changed"][i])[0]:
                any_change = True
                th = d["theta"][i, j]
                lines.append(
                    f"- t={d['t'][i]:.2f}s if{int(trace.oscs[j])}: "
                    f"θ→({int(th[0])}, {int(th[1])}) "
                    f"[{'read' if int(d['ops'][i, j]) == 0 else 'write'} "
                    f"model, {int(d['n_candidates'][i, j])} candidates, "
                    f"score {float(d['score'][i, j]):.3f}]")
        if not any_change:
            lines.append("- none")
        lines.append("")
    if trace.timeline is not None and len(trace.timeline["t"]):
        tl = trace.timeline
        span = max(float(tl["t"][-1]) - float(tl["t"][0]), 1e-9)
        lines.append("## OST timeline")
        lines.append("")
        lines.append("| OST | read MB/s | write MB/s | peak queue MB | "
                     "min dirty room MB |")
        lines.append("|---|---|---|---|---|")
        for o in range(tl["read_bytes"].shape[1]):
            rd = (tl["read_bytes"][-1, o] - tl["read_bytes"][0, o]) / span
            wr = (tl["write_bytes"][-1, o] - tl["write_bytes"][0, o]) / span
            lines.append(f"| {o} | {rd / 1e6:.1f} | {wr / 1e6:.1f} | "
                         f"{tl['queue_bytes'][:, o].max() / 1e6:.1f} | "
                         f"{tl['dirty_room'][:, o].min() / 1e6:.1f} |")
        lines.append("")
    if diagnosis is not None:
        lines.append("## Diagnosis")
        lines.append("")
        lines.append(f"Dominant cause: **{diagnosis.get('cause', '?')}** "
                     f"(losing: {diagnosis.get('losing')}).")
        arms = diagnosis.get("arms", {})
        if arms:
            lines.append("")
            lines.append("| arm | MB/s |")
            lines.append("|---|---|")
            for arm, mbs in arms.items():
                lines.append(f"| {arm} | {float(mbs):.1f} |")
        n_ev = diagnosis.get("n_evidence_total", 0)
        shown = len(diagnosis.get("evidence", []))
        lines.append("")
        lines.append(f"{n_ev} evidence row(s) ({shown} in report); see "
                     f"the JSONL `diagnosis` record for the full rows.")
        lines.append("")
    return "\n".join(lines)
