"""Trace schema: the one record layout both execution paths emit.

DIAL's observability claim is that everything the tuner needs is already
in cheap client-local counters; this module makes those counters — plus
the tuner's own rationale — first-class observables with a schema that
is *identical* across the host loop and the device-resident fused loop,
so a traced host run and a traced fused run are diffable row for row.

Record kinds (``dial-trace-v2``; ``v1`` lacked ``diagnosis``):

``decision``  one row per (tuning interval, interface): the full
              provenance of that interface's Algorithm 1 pass — chosen
              θ, per-config probabilities, how many configs cleared τ,
              the winning score, and every gate the row had to clear
              (volume, steadiness, warmup, tune mask) with the measured
              quantities behind them (``vol_r``/``vol_w``/``ratio``).
``timeline``  one row per (sampled tick, OST): cumulative read/write
              bytes, queued + in-pipeline bytes, active RPCs, remaining
              dirty-cache room of the attached OSCs, and the disturbance
              scales in effect — sampled every ``stride`` ticks.
``diagnosis`` at most one per file: the counterfactual replay verdict
              for the traced run (:mod:`repro.obs.diagnose`) — dominant
              cause, intervention-arm throughputs, and evidence rows
              keyed to the same intervals as the ``decision`` records.

Masking convention (what makes the two paths diffable): rows that did
not reach Algorithm 1 (``decided`` false) carry the *applied* θ and
zeros for probs / score / n_candidates; ``ratio`` and ``steady`` are
only recorded once the snapshot history is warm (the fused ring buffer
holds zero placeholders during warmup where the host deque simply holds
fewer entries — masking by ``warm`` removes the representational
difference without touching a single decision).
"""

from __future__ import annotations

import dataclasses

import numpy as np


TRACE_SCHEMA = "dial-trace-v2"
#: schemas read_jsonl accepts (v1 files simply carry no diagnosis record)
TRACE_SCHEMAS = ("dial-trace-v1", "dial-trace-v2")

#: per-(interval, interface) decision provenance, canonical field order
DECISION_FIELDS = ("t", "decided", "ops", "theta", "changed",
                   "n_candidates", "score", "probs",
                   "vol_r", "vol_w", "active", "steady", "warm", "ratio")

#: per-(sampled tick, OST) fleet timeline, canonical field order
TIMELINE_FIELDS = ("t", "read_bytes", "write_bytes", "queue_bytes",
                   "active_rpcs", "dirty_room",
                   "bw_scale", "iops_scale", "bg_bytes", "nic_scale")


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Opt-in tracing knobs (hashable — it keys compiled-loop caches).

    ``stride`` downsamples the per-tick timeline: one sample every
    ``stride`` engine ticks (within each interval, at tick offsets
    ``stride-1, 2*stride-1, ...``; a remainder shorter than ``stride``
    is not sampled).  The default of 20 keeps the traced fused dispatch
    within a few percent of the untraced wall clock
    (benchmarks/obs_overhead.py guards <= 10%).  Decision records are
    per interval and never downsampled — there are few intervals and
    they are the point.  ``timeline=False`` keeps only the decision
    provenance, which adds no per-tick work at all.
    """

    stride: int = 20
    timeline: bool = True

    def __post_init__(self):
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1, got {self.stride}")


# ---------------------------------------------------------------------- #
# the timeline tap — backend-agnostic, used verbatim by both paths
# ---------------------------------------------------------------------- #
def timeline_tap(params, topo, state, dist=None, xp=np, segsum=None):
    """One timeline sample off a (possibly traced) ``SimState``.

    The same function body runs inside the fused scan (``xp=jnp``,
    ``segsum`` the loop's segment-sum) and on the host sampler
    (``xp=np``) — the record arithmetic cannot drift between paths.
    Returns a dict of ``TIMELINE_FIELDS`` minus nothing: per-OST arrays
    ``(n_osts,)`` except ``t`` (scalar) and ``nic_scale``
    ``(n_clients,)``.
    """
    if segsum is None:
        from repro.kernels.segment_reduce.ops import segment_sum_np
        segsum = segment_sum_np
    from repro.pfs.state import READ, WRITE

    ids, n_osts = topo.osc_ost, topo.n_osts
    s = state
    queued = (s.queue_bytes[READ] + s.queue_bytes[WRITE]
              + s.unready_bytes[READ] + s.unready_bytes[WRITE]
              + s.ready_bytes[READ] + s.ready_bytes[WRITE])
    room = xp.minimum(params.max_dirty_bytes - s.dirty_bytes,
                      params.grant_bytes - s.grant_used)
    if dist is None:
        from repro.pfs.state import Disturbance, SimTopo  # noqa: F401
        bw = xp.ones(n_osts)
        iops = xp.ones(n_osts)
        bg = xp.zeros(n_osts)
        nic = xp.ones(topo.n_clients)
    else:
        bw, iops = dist.bw_scale, dist.iops_scale
        bg, nic = dist.bg_bytes, dist.nic_scale
    return {
        "t": s.now,
        "read_bytes": segsum(s.ctr_bytes_done[READ], ids, n_osts),
        "write_bytes": segsum(s.ctr_bytes_done[WRITE], ids, n_osts),
        "queue_bytes": segsum(queued, ids, n_osts),
        "active_rpcs": segsum(s.active_rpcs[READ] + s.active_rpcs[WRITE],
                              ids, n_osts),
        "dirty_room": segsum(room, ids, n_osts),
        "bw_scale": bw, "iops_scale": iops, "bg_bytes": bg,
        "nic_scale": nic,
    }


# ---------------------------------------------------------------------- #
# normalization: raw per-path output -> the canonical masked record
# ---------------------------------------------------------------------- #
def normalize_decisions(t, decided, ops, theta, changed, n_candidates,
                        score, probs, vol_r, vol_w, active, steady, warm,
                        ratio, cur_theta) -> dict:
    """Apply the masking convention; every input has an ``(N, n, ...)``
    or broadcastable shape.  ``cur_theta`` is the θ applied at probe
    time — what a row that never reached Algorithm 1 is actually
    running."""
    decided = np.asarray(decided, dtype=bool)
    warm = np.broadcast_to(np.asarray(warm, dtype=bool)[..., None]
                           if np.asarray(warm).ndim < decided.ndim
                           else np.asarray(warm, dtype=bool),
                           decided.shape)
    d2 = decided[..., None]
    return {
        "t": np.asarray(t, dtype=np.float64),
        "decided": decided,
        "ops": np.asarray(ops, dtype=np.int64),
        "theta": np.where(d2, np.asarray(theta, dtype=np.int64),
                          np.asarray(cur_theta, dtype=np.int64)),
        "changed": np.asarray(changed, dtype=bool) & decided,
        "n_candidates": np.asarray(n_candidates, dtype=np.int64) * decided,
        "score": np.asarray(score, dtype=np.float64) * decided,
        "probs": np.asarray(probs, dtype=np.float64) * d2,
        "vol_r": np.asarray(vol_r, dtype=np.float64),
        "vol_w": np.asarray(vol_w, dtype=np.float64),
        "active": np.asarray(active, dtype=bool),
        "steady": np.asarray(steady, dtype=bool) & warm,
        "warm": warm,
        "ratio": np.asarray(ratio, dtype=np.float64) * warm,
    }


@dataclasses.dataclass
class RunTrace:
    """One traced run, already normalized to the canonical schema.

    ``decisions`` maps ``DECISION_FIELDS`` to arrays with a leading
    ``(n_intervals, n_interfaces)`` layout (``theta`` adds a trailing 2,
    ``probs`` a trailing |Θ|; ``t`` is ``(n_intervals,)``).
    ``timeline`` maps ``TIMELINE_FIELDS`` to ``(n_samples, n_osts)``
    arrays (``t`` is ``(n_samples,)``, ``nic_scale``
    ``(n_samples, n_clients)``); ``None`` when timeline tracing was off.
    """

    decisions: dict
    timeline: dict | None
    oscs: np.ndarray
    config: TraceConfig
    interval_seconds: float
    tick_seconds: float

    @property
    def n_intervals(self) -> int:
        return int(self.decisions["decided"].shape[0])

    @property
    def n_interfaces(self) -> int:
        return int(self.decisions["decided"].shape[1])

    # ------------------------------------------------------------------ #
    @classmethod
    def from_fused(cls, result, config: TraceConfig,
                   tick_seconds: float) -> "RunTrace":
        """Normalize a traced :class:`~repro.pfs.loop_jax.FusedLoopResult`.

        Batched traces (leaves ``(B, N, ...)``) flatten the batch axis
        into fleet columns ``b * n + osc`` (and OST tracks
        ``b * n_osts + ost``) — the same convention
        :func:`~repro.pfs.loop_jax.decisions_from_trace` uses.
        """
        raw = result.trace
        if raw is None or "t" not in raw:
            raise ValueError("result carries no trace — was the loop "
                             "built with trace=TraceConfig(...)?")
        batched = np.asarray(raw["t"]).ndim == 2

        def flat(x):            # (B, N, ...) -> (N, B*n, ...)
            x = np.asarray(x)
            if not batched:
                return x
            x = np.moveaxis(x, 0, 1)
            return x.reshape(x.shape[0], -1, *x.shape[3:])

        t = (np.asarray(raw["t"])[0] if batched
             else np.asarray(raw["t"]))
        if "decided" in raw:
            decisions = normalize_decisions(
                t, flat(raw["decided"]), flat(raw["ops"]),
                flat(raw["theta"]), flat(raw["changed"]),
                flat(raw["n_candidates"]), flat(raw["score"]),
                flat(raw["probs"]), flat(raw["vol_r"]), flat(raw["vol_w"]),
                flat(raw["active"]), flat(raw["steady"]),
                (np.asarray(raw["warm"])[0] if batched
                 else np.asarray(raw["warm"])),
                flat(raw["ratio"]), flat(raw["cur_theta"]))
            n_if = decisions["decided"].shape[1]
        else:                   # untuned run: timeline only
            decisions = {f: np.zeros((len(t), 0) if f != "t" else len(t))
                         for f in DECISION_FIELDS}
            decisions["t"] = t
            n_if = 0

        timeline = None
        if "timeline" in raw:
            def tl(x):          # (B?, N, C, tracks) -> (N*C, B*tracks)
                x = np.asarray(x)
                if batched:
                    x = np.moveaxis(x, 0, 2)        # (N, C, B, tracks)
                    x = x.reshape(x.shape[0], x.shape[1], -1)
                return x.reshape(-1, *x.shape[2:])
            timeline = {k: tl(v) for k, v in raw["timeline"].items()}
            timeline["t"] = (timeline["t"][:, 0] if batched
                             else timeline["t"])
        return cls(decisions=decisions, timeline=timeline,
                   oscs=np.arange(n_if, dtype=np.int64),
                   config=config,
                   interval_seconds=float(result.interval_seconds),
                   tick_seconds=float(tick_seconds))

    # ------------------------------------------------------------------ #
    def decision_rows(self):
        """Yield one JSON-safe dict per (interval, interface) row."""
        d = self.decisions
        for i in range(self.n_intervals):
            for j in range(self.n_interfaces):
                yield {
                    "kind": "decision",
                    "interval": i,
                    "osc": int(self.oscs[j]),
                    "t": float(d["t"][i]),
                    "decided": bool(d["decided"][i, j]),
                    "op": int(d["ops"][i, j]),
                    "theta": [int(x) for x in d["theta"][i, j]],
                    "changed": bool(d["changed"][i, j]),
                    "n_candidates": int(d["n_candidates"][i, j]),
                    "score": float(d["score"][i, j]),
                    "probs": [round(float(p), 9)
                              for p in d["probs"][i, j]],
                    "vol_r": float(d["vol_r"][i, j]),
                    "vol_w": float(d["vol_w"][i, j]),
                    "active": bool(d["active"][i, j]),
                    "steady": bool(d["steady"][i, j]),
                    "warm": bool(d["warm"][i, j]),
                    "ratio": float(d["ratio"][i, j]),
                }

    def timeline_rows(self):
        """Yield one JSON-safe dict per sample (per-OST values as lists,
        ``nic_scale`` per client)."""
        if self.timeline is None:
            return
        tl = self.timeline
        n_samples = tl["read_bytes"].shape[0]
        for i in range(n_samples):
            row = {"kind": "timeline", "sample": i,
                   "t": float(tl["t"][i])}
            for k in TIMELINE_FIELDS[1:]:
                row[k] = [float(x) for x in tl[k][i]]
            yield row

    # ------------------------------------------------------------------ #
    def validate(self) -> None:
        """Schema sanity: field coverage, shapes, monotone time axes."""
        missing = set(DECISION_FIELDS) - set(self.decisions)
        if missing:
            raise ValueError(f"decision trace missing fields {missing}")
        n, m = self.n_intervals, self.n_interfaces
        assert self.decisions["t"].shape == (n,)
        assert self.decisions["theta"].shape[:2] == (n, m)
        t = self.decisions["t"]
        if n > 1 and not np.all(np.diff(t) > 0):
            raise ValueError("decision timestamps not strictly increasing")
        if self.timeline is not None:
            missing = set(TIMELINE_FIELDS) - set(self.timeline)
            if missing:
                raise ValueError(f"timeline trace missing fields {missing}")
            tt = self.timeline["t"]
            if len(tt) > 1 and not np.all(np.diff(tt) > 0):
                raise ValueError("timeline timestamps not strictly "
                                 "increasing")
