"""Lightweight host-phase timers and run provenance.

The fused loop is a single dispatch — a 131k-interface run is one
opaque ``jit`` call from the host's point of view.  :class:`PhaseTimers`
gives the host side back its phase breakdown at near-zero cost
(``perf_counter`` pairs around device_put / dispatch / host transfer),
and :func:`compile_execute_split` separates compile from execute for a
jitted callable via AOT lowering — the number an operator actually
wants when a "slow run" might just be a cold cache.

:func:`collect_provenance` stamps bench records with what produced
them (git SHA, platform, device kind/count, jax version) so checked-in
baselines like ``BENCH_8.json`` stay attributable.
"""

from __future__ import annotations

import collections
import contextlib
import platform
import subprocess
import time


class PhaseTimers:
    """Accumulate named wall-clock phases; ~100 ns per measurement."""

    def __init__(self):
        self.seconds = collections.defaultdict(float)
        self.calls = collections.defaultdict(int)

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.seconds[name] += time.perf_counter() - t0
            self.calls[name] += 1

    def add(self, name: str, seconds: float) -> None:
        self.seconds[name] += seconds
        self.calls[name] += 1

    def reset(self) -> None:
        self.seconds.clear()
        self.calls.clear()

    def summary(self) -> dict:
        """``{phase: {"seconds": total, "calls": n}}``, insertion order."""
        return {k: {"seconds": self.seconds[k], "calls": self.calls[k]}
                for k in self.seconds}


def compile_execute_split(jit_fn, *args, **kwargs) -> dict:
    """Compile-vs-execute wall split for one jitted callable.

    AOT-lowers and compiles ``jit_fn`` for ``args``, then times one
    execution of the compiled object (blocking on the result).  Returns
    ``{"compile_s", "execute_s", "out"}``.  Falls back to timing a
    single traced call as pure execute when the callable does not
    support ``.lower`` (e.g. a plain function).
    """
    lower = getattr(jit_fn, "lower", None)
    if lower is None:
        t0 = time.perf_counter()
        out = jit_fn(*args, **kwargs)
        return {"compile_s": 0.0,
                "execute_s": time.perf_counter() - t0, "out": out}
    t0 = time.perf_counter()
    compiled = lower(*args, **kwargs).compile()
    t1 = time.perf_counter()
    out = compiled(*args, **kwargs)
    import jax
    out = jax.block_until_ready(out)
    t2 = time.perf_counter()
    return {"compile_s": t1 - t0, "execute_s": t2 - t1, "out": out}


def collect_provenance() -> dict:
    """Git/platform/device metadata for bench records (best effort —
    every field degrades to a placeholder rather than raising)."""
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    try:
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True,
            text=True, timeout=10).stdout.strip())
    except (OSError, subprocess.SubprocessError):
        dirty = False
    prov = {
        "git_sha": sha,
        "git_dirty": dirty,
        "platform": platform.platform(),
        "python": platform.python_version(),
    }
    try:
        import jax
        prov["jax_version"] = jax.__version__
        devs = jax.devices()
        prov["device_count"] = len(devs)
        prov["device_kind"] = devs[0].device_kind if devs else "none"
        prov["default_backend"] = jax.default_backend()
    except Exception:   # jax may be absent or fail to init headless
        prov["jax_version"] = "unavailable"
        prov["device_count"] = 0
        prov["device_kind"] = "none"
        prov["default_backend"] = "none"
    try:
        from repro.lab.batch import loop_cache_stats
        prov["loop_cache"] = loop_cache_stats()
    except Exception:
        prov["loop_cache"] = {"hits": 0, "misses": 0, "size": 0}
    return prov
