"""Device-resident telemetry for the DIAL reproduction.

Opt-in tracing threaded through both execution paths — decision
provenance and per-OST timelines accumulated as scan outputs inside the
fused loop (no host callbacks), mirrored record-for-record by the host
agent path — plus host-side sinks (JSONL, Chrome ``trace_event``,
markdown), phase timers, and bench provenance.  See
``docs/OBSERVABILITY.md``.
"""

from repro.obs.diagnose import (ARMS, CAUSES, DIAGNOSIS_SCHEMA,
                                DiagnoseConfig, cause_counts, diagnose,
                                render_diagnosis_markdown,
                                write_diagnosis_report)
from repro.obs.host import HostTracer
from repro.obs.schema import (DECISION_FIELDS, TIMELINE_FIELDS,
                              TRACE_SCHEMA, RunTrace, TraceConfig,
                              timeline_tap)
from repro.obs.sinks import (chrome_trace, read_jsonl,
                             read_jsonl_diagnosis, render_summary,
                             write_chrome, write_jsonl)
from repro.obs.timers import (PhaseTimers, collect_provenance,
                              compile_execute_split)

__all__ = [
    "TRACE_SCHEMA", "DECISION_FIELDS", "TIMELINE_FIELDS",
    "TraceConfig", "RunTrace", "timeline_tap", "HostTracer",
    "write_jsonl", "read_jsonl", "read_jsonl_diagnosis", "chrome_trace",
    "write_chrome", "render_summary",
    "DIAGNOSIS_SCHEMA", "CAUSES", "ARMS", "DiagnoseConfig", "diagnose",
    "cause_counts", "write_diagnosis_report", "render_diagnosis_markdown",
    "PhaseTimers", "compile_execute_split", "collect_provenance",
]
