"""Counterfactual loss diagnosis: from "DIAL lost" to "here is why".

A fuzz triage entry or a traced replay says *what* DIAL decided and by
how much it lost — not *why*.  This module turns any scenario (catalog
entry or triaged fuzz loser) into a machine-readable explanation by
re-running it through the fused loop under a small set of
**interventions** and diffing the outcomes against the factual run:

``factual``          the neutral intervention — bit-identical to the
                     unintervened run (arithmetic-identity masks);
``pin_best_static``  θ pinned to the best-static oracle every interval
                     (and started there) — reproduces the oracle arm
                     inside the replay program, calibrating the gap;
``gates_open``       the volume + steadiness gates forced open — what
                     DIAL would have done had the gates never blocked;
``freeze_theta``     decisions never applied — θ stays at the initial
                     configuration, isolating DIAL's knob churn
                     (the fused loop's analogue of "exploration
                     zeroed" — the only θ motion it has);
``model_swap``       optional: the same scenario tuned by a different
                     model artifact (is the *model version* the loss?).

Interventions ride the same mechanism as the PR-8 trace taps: an extra
scan-input pytree on :class:`~repro.pfs.loop_jax.FusedLoop`
(:class:`~repro.pfs.loop_jax.Intervention`), with ``iv=None`` compiling
the exact unintervened graph — so diagnosis works on every backend
including the sharded path and the reports are byte-deterministic like
the fuzz report (no timestamps, sorted keys).

The dominant-cause taxonomy (attribution cascade, in order):

``none``              the scenario is not a loss at the configured
                      threshold;
``inherent``          the loss does not reproduce under the pinned
                      oracle — best-static is no better in replay
                      (noise-floor or non-θ-attributable gap);
``gate_blocked``      warm intervals where the volume/steadiness gates
                      blocked decisions dominate, or forcing the gates
                      open recovers most of the gap;
``candidate_missing`` θ* is outside the tuner's candidate grid, or
                      decided intervals mostly had **zero** candidates
                      clear the confidence threshold τ;
``reaction_lag``      DIAL does converge to θ* but only in the second
                      half of the run — the loss is the transient;
``model_misranked``   the forests ranked some other configuration above
                      θ* while it was available (the residual cause).

Every diagnosis carries per-interval evidence rows supporting its
label, capped at ``max_evidence`` with the uncapped total recorded —
no silent truncation.  See ``docs/OBSERVABILITY.md``.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core.config_space import SPACE

DIAGNOSIS_SCHEMA = "dial-diagnosis-v1"

#: attribution labels, in cascade order
CAUSES = ("none", "inherent", "gate_blocked", "candidate_missing",
          "reaction_lag", "model_misranked")

#: the counterfactual arms every diagnosis replays
ARMS = ("factual", "pin_best_static", "gates_open", "freeze_theta")


@dataclasses.dataclass(frozen=True)
class DiagnoseConfig:
    """One diagnosis run's execution + attribution parameters.

    ``thetas`` are the static arms of the (re-)race that defines the
    best-static oracle θ* (empty -> the full Θ grid, as fuzz uses);
    ``reproduce_frac`` is the inherent-loss floor: if pinning θ* beats
    the factual replay by less than this fraction of the pinned arm,
    the loss is not θ-attributable; ``recover_frac`` is the share of
    the pinned gap an intervention arm must recover to claim the cause.
    """

    seconds: float = 3.0
    interval: float = 0.5
    thetas: tuple = ()                 # () -> full SPACE
    loss_threshold: float = 0.05
    min_best_static_mbs: float = 1.0
    max_evidence: int = 8
    seg_backend: str = "jax"
    reproduce_frac: float = 0.02
    recover_frac: float = 0.5

    @classmethod
    def from_fuzz(cls, fuzz_cfg, max_evidence: int = 8) -> "DiagnoseConfig":
        """Mirror a sweep's execution knobs so the diagnosis replays a
        triaged loser under the exact conditions that triaged it."""
        return cls(seconds=fuzz_cfg.seconds, interval=fuzz_cfg.interval,
                   thetas=tuple(fuzz_cfg.thetas),
                   loss_threshold=fuzz_cfg.loss_threshold,
                   min_best_static_mbs=fuzz_cfg.min_best_static_mbs,
                   max_evidence=max_evidence,
                   seg_backend=fuzz_cfg.seg_backend)


# ---------------------------------------------------------------------- #
# phase A: the race (static arms + DIAL) — defines θ* and the loss
# ---------------------------------------------------------------------- #
def race_scenario(spec, model, cfg: DiagnoseConfig, mesh=None) -> dict:
    """Race ``spec`` DIAL-tuned against the static arms; the fuzz
    sweep's per-scenario measurement, for one scenario."""
    from repro.lab.batch import run_batch, stack_scenarios
    from repro.lab.scenarios import build

    thetas = [tuple(int(x) for x in t)
              for t in (cfg.thetas or SPACE.configs())]
    built = [build(dataclasses.replace(spec, initial_theta=th))
             for th in thetas]
    built.append(build(spec))                        # the DIAL arm
    batch = stack_scenarios(built)
    n, m = batch.n_osc, len(thetas)
    run_batch(batch, model=model, seconds=cfg.seconds,
              interval=cfg.interval, seg_backend=cfg.seg_backend,
              tune_cols=m * n + np.arange(n), fused=True, mesh=mesh)
    tput = batch.throughput(cfg.seconds)["total_mbs"]
    best = int(np.argmax(tput[:m]))
    dial_mbs, best_mbs = float(tput[m]), float(tput[best])
    return {
        "dial_mbs": dial_mbs,
        "best_static_mbs": best_mbs,
        "best_static_theta": [int(x) for x in thetas[best]],
        "dial_frac_of_best_static": dial_mbs / max(best_mbs, 1e-9),
    }


def race_many(cases, model, cfg: DiagnoseConfig, mesh=None) -> list[dict]:
    """Ragged phase A for many scenarios, each against its *own* static
    oracle θ: one fused dispatch per padded shape bucket.

    ``cases`` is ``[(spec, best_theta), ...]``; each case contributes a
    pinned-static arm plus a DIAL arm.  Elements are independent under
    vmap and padding is an exact identity, so the returned dicts are
    bit-identical to per-case ``race_scenario`` with
    ``thetas=(best_theta,)`` — the mixed set just shares dispatches.
    """
    from repro.lab.batch import pad_class, run_batch, stack_scenarios
    from repro.lab.scenarios import build

    groups: dict = {}
    for i, (spec, _) in enumerate(cases):
        groups.setdefault(pad_class(build(spec)), []).append(i)
    out: list = [None] * len(cases)
    for key in sorted(groups, key=lambda k: tuple(k[1:])):
        idxs = groups[key]
        built = []
        for i in idxs:
            spec, theta = cases[i]
            built.append(build(dataclasses.replace(
                spec, initial_theta=tuple(int(x) for x in theta))))
            built.append(build(spec))                # the DIAL arm
        batch = stack_scenarios(built)
        n = batch.n_osc
        tune_cols = np.concatenate(
            [(2 * j + 1) * n + batch.element_cols(2 * j + 1)
             for j in range(len(idxs))])
        run_batch(batch, model=model, seconds=cfg.seconds,
                  interval=cfg.interval, seg_backend=cfg.seg_backend,
                  tune_cols=tune_cols, fused=True, mesh=mesh)
        tput = batch.throughput(cfg.seconds)["total_mbs"]
        for j, i in enumerate(idxs):
            best_mbs = float(tput[2 * j])
            dial_mbs = float(tput[2 * j + 1])
            out[i] = {
                "dial_mbs": dial_mbs,
                "best_static_mbs": best_mbs,
                "best_static_theta": [int(x) for x in cases[i][1]],
                "dial_frac_of_best_static":
                    dial_mbs / max(best_mbs, 1e-9),
            }
    return out


# ---------------------------------------------------------------------- #
# phase B: the counterfactual arms — one traced intervened dispatch
# ---------------------------------------------------------------------- #
def replay_arms(spec, model, cfg: DiagnoseConfig, theta_star,
                mesh=None) -> tuple[dict, dict]:
    """One traced 4-element batch: factual + the three interventions.

    Element 0 carries the neutral intervention (bit-identical to the
    unintervened run); element 1 starts at θ* and pins it every
    interval; element 2 forces the volume/steadiness gates open;
    element 3 freezes θ at the scenario's initial configuration.
    Returns ``(arms MB/s by name, factual decision arrays (N, n, ...))``.
    """
    return replay_arms_many([(spec, theta_star)], model, cfg,
                            mesh=mesh)[0]


def replay_arms_many(cases, model, cfg: DiagnoseConfig,
                     mesh=None) -> list[tuple[dict, dict]]:
    """Ragged phase B: every case's four intervention arms, grouped by
    padded shape class into one traced dispatch per bucket.

    ``cases`` is ``[(spec, theta_star), ...]``.  Per case the batch
    carries factual / pin-θ* / gates-open / freeze-θ elements
    contiguously; interventions and the factual decision slice address
    only the case's real interface columns, so mixed-structure loser
    sets replay bit-identically to one-case-at-a-time ``replay_arms``.
    """
    from repro.lab.batch import pad_class, run_batch, stack_scenarios
    from repro.lab.scenarios import build
    from repro.obs.schema import RunTrace, TraceConfig
    from repro.pfs.loop_jax import Intervention

    groups: dict = {}
    for i, (spec, _) in enumerate(cases):
        groups.setdefault(pad_class(build(spec)), []).append(i)
    out: list = [None] * len(cases)
    for key in sorted(groups, key=lambda k: tuple(k[1:])):
        idxs = groups[key]
        built, stars = [], []
        for i in idxs:
            spec, theta_star = cases[i]
            star = tuple(int(x) for x in theta_star)
            stars.append(star)
            built += [build(spec),
                      build(dataclasses.replace(spec, initial_theta=star)),
                      build(spec), build(spec)]
        batch = stack_scenarios(built)
        n = batch.n_osc

        iv = Intervention.neutral(n, batch=4 * len(idxs))
        pin_mask = iv.pin_mask.copy()
        pin_theta = iv.pin_theta.copy()
        force_gates = iv.force_gates.copy()
        freeze = iv.freeze.copy()
        for j, star in enumerate(stars):
            pin_mask[4 * j + 1] = True
            pin_theta[4 * j + 1] = np.asarray(star, dtype=np.int64)
            force_gates[4 * j + 2] = True
            freeze[4 * j + 3] = True
        iv = Intervention(pin_mask=pin_mask, pin_theta=pin_theta,
                          force_gates=force_gates, freeze=freeze)

        tcfg = TraceConfig(timeline=False)  # decision provenance suffices
        result = run_batch(batch, model=model, seconds=cfg.seconds,
                           interval=cfg.interval,
                           seg_backend=cfg.seg_backend,
                           fused=True, mesh=mesh, trace=tcfg,
                           intervene=iv)
        tput = batch.throughput(cfg.seconds)["total_mbs"]
        trace = RunTrace.from_fused(result, tcfg, batch.params.tick)
        for j, i in enumerate(idxs):
            # fleet columns are b * n + osc: the factual element's real
            # interface columns, in original order
            cols = 4 * j * n + batch.element_cols(4 * j)
            factual = {k: (np.asarray(v)[:, cols]
                           if np.asarray(v).ndim >= 2 else np.asarray(v))
                       for k, v in trace.decisions.items()}
            out[i] = ({"factual": float(tput[4 * j]),
                       "pin_best_static": float(tput[4 * j + 1]),
                       "gates_open": float(tput[4 * j + 2]),
                       "freeze_theta": float(tput[4 * j + 3])}, factual)
    return out


# ---------------------------------------------------------------------- #
# signals + attribution
# ---------------------------------------------------------------------- #
def _signals(factual: dict, theta_star) -> dict:
    """Structural evidence off the factual trace alone."""
    decided = factual["decided"]
    warm = factual["warm"]
    star = np.asarray(theta_star, dtype=np.int64)
    match = (factual["theta"] == star).all(axis=-1)      # (N, n)

    n_dec = int(decided.sum())
    blocked_share = float((warm & ~decided).sum() / max(int(warm.sum()), 1))
    nocand_share = float((decided & (factual["n_candidates"] == 0)).sum()
                         / max(n_dec, 1))
    mismatch_share = float((decided & ~match).sum() / max(n_dec, 1))

    frac_match = match.mean(axis=1) if match.size else np.zeros(0)
    ok = frac_match >= 0.5
    suffix_ok = (np.logical_and.accumulate(ok[::-1])[::-1] if len(ok)
                 else ok)
    idx = np.nonzero(suffix_ok)[0]
    converged_interval = int(idx[0]) if len(idx) else None

    grid = {tuple(int(x) for x in t) for t in SPACE.configs()}
    return {
        "blocked_share": blocked_share,
        "nocand_share": nocand_share,
        "mismatch_share": mismatch_share,
        "converged_interval": converged_interval,
        "theta_star_in_grid": tuple(int(x) for x in theta_star) in grid,
        "n_decided": n_dec,
        "frac_at_best_static": [round(float(x), 6) for x in frac_match],
    }


def attribute(losing: bool, arms: dict, signals: dict,
              cfg: DiagnoseConfig, n_intervals: int) -> str:
    """The deterministic attribution cascade (docs/OBSERVABILITY.md)."""
    if not losing:
        return "none"
    gap = arms["pin_best_static"] - arms["factual"]
    if gap <= cfg.reproduce_frac * max(arms["pin_best_static"], 1e-9):
        return "inherent"
    if (signals["blocked_share"] >= 0.5
            or (arms["gates_open"] - arms["factual"]) / gap
            >= cfg.recover_frac):
        return "gate_blocked"
    if not signals["theta_star_in_grid"] or signals["nocand_share"] >= 0.5:
        return "candidate_missing"
    ci = signals["converged_interval"]
    if ci is not None and ci > n_intervals // 2:
        return "reaction_lag"
    return "model_misranked"


def _evidence(cause: str, factual: dict, theta_star, arms: dict,
              max_evidence: int) -> tuple[list, int]:
    """Per-interval rows supporting ``cause`` (row-major order, capped
    at ``max_evidence``; the uncapped total rides the diagnosis)."""
    star = np.asarray(theta_star, dtype=np.int64)
    match = (factual["theta"] == star).all(axis=-1)
    decided = factual["decided"]
    star_idx = None
    grid = [tuple(int(x) for x in t) for t in SPACE.configs()]
    if tuple(int(x) for x in theta_star) in grid:
        star_idx = grid.index(tuple(int(x) for x in theta_star))

    def base(i, j):
        return {"interval": int(i), "osc": int(j),
                "t": round(float(factual["t"][i]), 9)}

    rows: list = []
    if cause == "gate_blocked":
        for i, j in zip(*np.nonzero(factual["warm"] & ~decided)):
            rows.append({**base(i, j),
                         "active": bool(factual["active"][i, j]),
                         "steady": bool(factual["steady"][i, j]),
                         "vol_r": round(float(factual["vol_r"][i, j]), 3),
                         "vol_w": round(float(factual["vol_w"][i, j]), 3),
                         "ratio": round(float(factual["ratio"][i, j]), 6)})
    elif cause == "candidate_missing":
        sel = (decided & (factual["n_candidates"] == 0)
               if star_idx is not None else decided)
        for i, j in zip(*np.nonzero(sel)):
            rows.append({**base(i, j),
                         "n_candidates":
                         int(factual["n_candidates"][i, j]),
                         "score": round(float(factual["score"][i, j]), 6),
                         "theta_star_in_grid": star_idx is not None})
    elif cause == "reaction_lag":
        frac = match.mean(axis=1)
        for i in range(len(frac)):
            if frac[i] >= 0.5 and i and frac[i - 1] >= 0.5:
                break
            rows.append({"interval": int(i),
                         "t": round(float(factual["t"][i]), 9),
                         "frac_at_best_static": round(float(frac[i]), 6),
                         "decided": int(decided[i].sum())})
    elif cause == "model_misranked":
        for i, j in zip(*np.nonzero(decided & ~match)):
            row = {**base(i, j),
                   "theta": [int(x) for x in factual["theta"][i, j]],
                   "theta_star": [int(x) for x in star],
                   "score": round(float(factual["score"][i, j]), 6)}
            if star_idx is not None:
                row["prob_best_static"] = round(
                    float(factual["probs"][i, j, star_idx]), 6)
            rows.append(row)
    elif cause == "inherent":
        rows.append({"pin_best_static_mbs": round(
            arms["pin_best_static"], 6),
            "factual_mbs": round(arms["factual"], 6),
            "gap_mbs": round(arms["pin_best_static"]
                             - arms["factual"], 6)})
    # a losing diagnosis must never ship without evidence: fall back to
    # the per-interval decision/convergence digest
    if cause not in ("none",) and not rows:
        frac = match.mean(axis=1)
        for i in range(decided.shape[0]):
            rows.append({"interval": int(i),
                         "t": round(float(factual["t"][i]), 9),
                         "decided": int(decided[i].sum()),
                         "frac_at_best_static": round(float(frac[i]), 6)})
    return rows[:max_evidence], len(rows)


# ---------------------------------------------------------------------- #
# the engine
# ---------------------------------------------------------------------- #
def diagnose(spec, model, cfg: DiagnoseConfig | None = None, *,
             race: dict | None = None, mesh=None, alt_model=None,
             alt_model_name: str | None = None) -> dict:
    """Full counterfactual diagnosis of one scenario.

    ``race`` short-circuits phase A with an already-measured
    ``{dial_mbs, best_static_mbs, best_static_theta, ...}`` (e.g. a
    triaged fuzz row); otherwise the race is re-run here.
    ``alt_model`` adds the optional ``model_swap`` arm — the same
    scenario tuned by a different artifact.  Deterministic: the same
    (spec, model, cfg) produce a byte-identical diagnosis dict.
    """
    cfg = cfg if cfg is not None else DiagnoseConfig()
    if race is None:
        race = race_scenario(spec, model, cfg, mesh=mesh)
    theta_star = [int(x) for x in race["best_static_theta"]]

    arms, factual = replay_arms(spec, model, cfg, theta_star, mesh=mesh)
    if alt_model is not None:
        arms["model_swap"] = _swap_many([spec], alt_model, cfg,
                                        mesh=mesh)[0]
    return _finish_diagnosis(spec, race, arms, factual, cfg,
                             alt_model_name=alt_model_name)


def diagnose_many(pairs, model, cfg: DiagnoseConfig | None = None, *,
                  mesh=None, alt_model=None,
                  alt_model_name: str | None = None,
                  ragged: bool = True) -> list[dict]:
    """Diagnose a whole loser set — ``[(spec, race-or-None), ...]``.

    ``ragged=True`` groups the missing phase-A races, the intervention
    replays, and any model-swap arms by padded shape class and runs
    each group in one fused dispatch — diagnosis dicts are
    bit-identical to calling :func:`diagnose` per pair, which
    ``ragged=False`` does literally.
    """
    cfg = cfg if cfg is not None else DiagnoseConfig()
    pairs = list(pairs)
    if not ragged:
        return [diagnose(spec, model, cfg, race=race, mesh=mesh,
                         alt_model=alt_model,
                         alt_model_name=alt_model_name)
                for spec, race in pairs]
    races = [race for _, race in pairs]
    for i, r in enumerate(races):
        if r is None:   # rare: catalog entries without recorded races —
            # the full-grid phase A defines θ*, so it can't ride
            # race_many's per-case-θ batching
            races[i] = race_scenario(pairs[i][0], model, cfg, mesh=mesh)
    replays = replay_arms_many(
        [(spec, races[i]["best_static_theta"])
         for i, (spec, _) in enumerate(pairs)], model, cfg, mesh=mesh)
    swaps = (None if alt_model is None
             else _swap_many([spec for spec, _ in pairs], alt_model, cfg,
                             mesh=mesh))
    out = []
    for i, (spec, _) in enumerate(pairs):
        arms, factual = replays[i]
        if swaps is not None:
            arms["model_swap"] = swaps[i]
        out.append(_finish_diagnosis(spec, races[i], arms, factual, cfg,
                                     alt_model_name=alt_model_name))
    return out


def _swap_many(specs, alt_model, cfg: DiagnoseConfig,
               mesh=None) -> list[float]:
    """The optional ``model_swap`` arm for many specs: the same
    scenarios tuned by a different artifact, one ragged dispatch per
    padded shape bucket."""
    from repro.lab.batch import bucket_scenarios, run_batch
    from repro.lab.scenarios import build

    built = [build(s) for s in specs]
    out = [0.0] * len(specs)
    for idxs, batch in bucket_scenarios(built):
        run_batch(batch, model=alt_model, seconds=cfg.seconds,
                  interval=cfg.interval, seg_backend=cfg.seg_backend,
                  fused=True, mesh=mesh)
        tp = batch.throughput(cfg.seconds)["total_mbs"]
        for e, i in enumerate(idxs):
            out[i] = float(tp[e])
    return out


def _finish_diagnosis(spec, race: dict, arms: dict, factual: dict,
                      cfg: DiagnoseConfig,
                      alt_model_name: str | None = None) -> dict:
    """Post-replay assembly: signals, attribution, evidence, report
    dict — shared by the per-scenario and ragged many-scenario paths."""
    from repro.lab.fuzz import fingerprint

    theta_star = [int(x) for x in race["best_static_theta"]]
    losing = (race["best_static_mbs"] >= cfg.min_best_static_mbs
              and race["dial_mbs"] < (1.0 - cfg.loss_threshold)
              * race["best_static_mbs"])

    n_intervals = int(factual["decided"].shape[0])
    signals = _signals(factual, theta_star)
    cause = attribute(losing, arms, signals, cfg, n_intervals)
    evidence, n_total = _evidence(cause, factual, theta_star, arms,
                                  cfg.max_evidence)

    gap = arms["pin_best_static"] - arms["factual"]
    recovery = {"gap_mbs": round(gap, 6)}
    for name in ("gates_open", "freeze_theta", "model_swap"):
        if name in arms:
            recovery[name] = round(
                (arms[name] - arms["factual"]) / gap if gap > 0 else 0.0,
                6)

    out = {
        "schema": DIAGNOSIS_SCHEMA,
        "name": spec.name,
        "fingerprint": fingerprint(spec),
        "cause": cause,
        "losing": losing,
        "race": {
            "dial_mbs": race["dial_mbs"],
            "best_static_mbs": race["best_static_mbs"],
            "best_static_theta": theta_star,
            "dial_frac_of_best_static":
                race["dial_frac_of_best_static"],
        },
        "arms": {k: round(v, 6) for k, v in arms.items()},
        "recovery": recovery,
        "signals": signals,
        "evidence": evidence,
        "n_evidence_total": n_total,
        "n_intervals": n_intervals,
        "config": {
            "seconds": cfg.seconds, "interval": cfg.interval,
            "loss_threshold": cfg.loss_threshold,
            "min_best_static_mbs": cfg.min_best_static_mbs,
            "reproduce_frac": cfg.reproduce_frac,
            "recover_frac": cfg.recover_frac,
            "seg_backend": cfg.seg_backend,
        },
    }
    if alt_model_name is not None:
        out["alt_model"] = alt_model_name
    return out


def cause_counts(diagnoses: list[dict]) -> dict:
    """``{cause: count}`` over a list of diagnoses, key-sorted."""
    counts: dict = {}
    for d in diagnoses:
        counts[d["cause"]] = counts.get(d["cause"], 0) + 1
    return dict(sorted(counts.items()))


# ---------------------------------------------------------------------- #
# report IO
# ---------------------------------------------------------------------- #
def render_diagnosis_markdown(report: dict) -> str:
    lines = ["# Counterfactual diagnosis", ""]
    lines.append(f"{report['n_diagnoses']} scenario(s) diagnosed; "
                 "dominant causes: "
                 + (", ".join(f"{c} x{n}" for c, n in
                              report["causes"].items()) or "none")
                 + ".")
    lines.append("")
    if report["diagnoses"]:
        lines += [
            "| scenario | cause | DIAL/best | factual | pin θ* | "
            "gates open | freeze | evidence |",
            "|---|---|---|---|---|---|---|---|",
        ]
        for d in report["diagnoses"]:
            a = d["arms"]
            lines.append(
                f"| {d['name']} | **{d['cause']}** | "
                f"{100 * d['race']['dial_frac_of_best_static']:.1f}% | "
                f"{a['factual']:.1f} | {a['pin_best_static']:.1f} | "
                f"{a['gates_open']:.1f} | {a['freeze_theta']:.1f} | "
                f"{d['n_evidence_total']} row(s) |")
        lines.append("")
        lines.append("Arms are MB/s under each intervention; `pin θ*` "
                     "replays with θ pinned to the best-static oracle, "
                     "`gates open` forces the volume/steadiness gates, "
                     "`freeze` never applies a decision.  See "
                     "docs/OBSERVABILITY.md for the cause taxonomy.")
        lines.append("")
    return "\n".join(lines)


def write_diagnosis_report(diagnoses: list[dict],
                           out_dir: str) -> tuple[str, str]:
    """``diagnosis.json`` + ``diagnosis.md``; byte-identical across
    invocations (sorted keys, no timestamps, content-only)."""
    os.makedirs(out_dir, exist_ok=True)
    report = {
        "schema": DIAGNOSIS_SCHEMA,
        "n_diagnoses": len(diagnoses),
        "causes": cause_counts(diagnoses),
        "diagnoses": diagnoses,
    }
    jpath = os.path.join(out_dir, "diagnosis.json")
    mpath = os.path.join(out_dir, "diagnosis.md")
    with open(jpath, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    with open(mpath, "w") as f:
        f.write(render_diagnosis_markdown(report))
    return jpath, mpath
