"""Analytic FLOPs / HBM-traffic model per (arch x shape) cell.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (verified in
tests/test_roofline.py), so any scanned program — layer stacks, grad
accumulation, chunked attention — is undercounted by the trip counts.
Since every loop in this framework is structural and known, we compute
exact math FLOPs analytically and validate the formulas against
``cost_analysis`` on small *unscanned* configs (see the test).

Conventions:
  * matmul flops = 2*m*n*k; backward = 2x forward matmul flops;
    remat adds ~1x forward recompute -> train multiplier 3 + 1(remat).
  * causal attention context: S/2 average (full), min(w, ~S) (windowed).
  * MoE compute includes the capacity-factor padding overhead (the padded
    (E, C) buffer is what the MXU actually runs).

The HBM-traffic model (per chip, per step):
  * parameters stream once per microbatch fwd + once bwd (+1x remat fwd),
    optimizer touches param + 2 moments read/write in f32;
  * activations: ~A_LAYER * d bytes per token per layer through the
    residual stream (reads+writes, bf16), KV cache reads for decode.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ATTN, ATTN_LOCAL, MAMBA, MOE, RECURRENT

BF16 = 2
F32 = 4
A_LAYER = 16  # residual-stream activation bytes/token/layer factor (bf16 rw)


# --------------------------------------------------------------------- #
# forward flops per token, per layer kind
# --------------------------------------------------------------------- #
def _attn_flops_per_token(cfg, ctx: float) -> float:
    d, dh = cfg.d_model, cfg.head_dim_
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    proj = 2 * d * (nq + 2 * nkv) * dh + 2 * d * nq * dh
    scores = 4 * nq * dh * ctx          # qk^T + av
    return proj + scores


def _mlp_flops_per_token(cfg, d_ff: int) -> float:
    mats = 3 if cfg.mlp_gated else 2
    return 2 * mats * cfg.d_model * d_ff


def _moe_flops_per_token(cfg, capacity_factor: float = 1.25) -> float:
    d = cfg.d_model
    router = 2 * d * cfg.n_experts
    routed = cfg.top_k * capacity_factor * 6 * d * cfg.d_expert
    shared = 6 * d * (cfg.n_shared_experts * cfg.d_expert)
    return router + routed + shared


def _mamba_flops_per_token(cfg) -> float:
    d, di, n, dtr, k = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                        cfg.dt_rank, cfg.ssm_conv)
    return (2 * d * 2 * di + 2 * di * k + 2 * di * (dtr + 2 * n)
            + 2 * dtr * di + 8 * di * n + 2 * di * d)


def _recurrent_flops_per_token(cfg) -> float:
    d, w, k = cfg.d_model, cfg.lru_width_, cfg.ssm_conv
    return (4 * d * w + 2 * w * k + 4 * w * w + 10 * w + 2 * w * d
            + _mlp_flops_per_token(cfg, cfg.d_ff))


def layer_flops_per_token(cfg, kind: str, ctx: float) -> float:
    if kind == ATTN:
        return _attn_flops_per_token(cfg, ctx) + _mlp_flops_per_token(cfg, cfg.d_ff)
    if kind == ATTN_LOCAL:
        return _attn_flops_per_token(cfg, ctx) + _mlp_flops_per_token(cfg, cfg.d_ff)
    if kind == MOE:
        return _attn_flops_per_token(cfg, ctx) + _moe_flops_per_token(cfg)
    if kind == MAMBA:
        return _mamba_flops_per_token(cfg)
    if kind == RECURRENT:
        return _recurrent_flops_per_token(cfg)
    raise ValueError(kind)


def fwd_flops_per_token(cfg, seq_len: int, decode_ctx: int | None = None) -> float:
    """Average forward flops per token at the given sequence length.

    decode_ctx: if set, attention context is the (fixed) cache length
    (single-token decode) rather than the causal average.
    """
    total = 0.0
    for kind in cfg.layer_types():
        if decode_ctx is not None:
            ctx = min(cfg.window_size, decode_ctx) if kind == ATTN_LOCAL \
                else decode_ctx
        else:
            ctx = min(cfg.window_size, seq_len) if kind == ATTN_LOCAL \
                else seq_len / 2
        total += layer_flops_per_token(cfg, kind, ctx)
    ncb = max(cfg.num_codebooks, 1)
    total += 2 * cfg.d_model * cfg.vocab_size * ncb  # head
    return total


@dataclasses.dataclass
class CellCost:
    flops_per_chip: float
    hbm_bytes_per_chip: float


def cell_cost(cfg, shape, chips: int, model_shards: int, grad_accum: int = 1,
              remat: bool = True, window_cache: bool = False) -> CellCost:
    """Analytic per-chip flops + HBM traffic for one (arch, shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    n_params = cfg.param_count()
    params_local = n_params * BF16 / model_shards

    if shape.kind == "train":
        tokens = b * s
        mult = 3.0 + (1.0 if remat else 0.0)
        flops = fwd_flops_per_token(cfg, s) * tokens * mult / chips
        # params stream fwd+bwd(+remat fwd) per microbatch; AdamW touches
        # p (bf16 rw) + m,v (f32 rw) once per step
        param_traffic = grad_accum * (2.0 + (1.0 if remat else 0.0)) * params_local
        opt_traffic = 2 * params_local + 4 * (n_params * F32 / chips)
        act_traffic = (A_LAYER * cfg.d_model * cfg.n_layers
                       * (tokens / chips) * (2.0 if remat else 1.0))
        return CellCost(flops, param_traffic + opt_traffic + act_traffic)

    if shape.kind == "prefill":
        tokens = b * s
        flops = fwd_flops_per_token(cfg, s) * tokens / chips
        act = A_LAYER * cfg.d_model * cfg.n_layers * tokens / chips
        cache = _cache_bytes(cfg, b, s, window_cache) / chips  # cache write
        return CellCost(flops, params_local + act + cache)

    # decode: one token per sequence against a cache of length s
    flops = fwd_flops_per_token(cfg, s, decode_ctx=s) * b / chips
    cache = _cache_bytes(cfg, b, s, window_cache) / chips  # cache read (the wall)
    act = A_LAYER * cfg.d_model * cfg.n_layers * b / chips
    return CellCost(flops, params_local + cache + act)


def _cache_bytes(cfg, b: int, s: int, window_cache: bool = False) -> float:
    """Decode-cache bytes.  The BASELINE implementation keeps (and reads)
    full-length caches even for sliding-window layers; ``window_cache``
    models the rolling-buffer optimization (SPerf hillclimb)."""
    total = 0.0
    for kind in cfg.layer_types():
        if kind in (ATTN, MOE):
            total += 2 * b * s * cfg.n_kv_heads * cfg.head_dim_ * BF16
        elif kind == ATTN_LOCAL:
            eff = min(cfg.window_size or s, s) if window_cache else s
            total += 2 * b * eff * cfg.n_kv_heads * cfg.head_dim_ * BF16
        elif kind == MAMBA:
            total += b * cfg.d_inner * (cfg.ssm_state + cfg.ssm_conv - 1) * F32
        elif kind == RECURRENT:
            total += b * cfg.lru_width_ * cfg.ssm_conv * F32
    return total
