"""Shared utilities: HLO analysis, analytic roofline model."""
