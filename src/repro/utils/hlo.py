"""HLO post-processing: collective-byte accounting + roofline terms.

``compiled.cost_analysis()`` provides per-device FLOPs and HBM bytes;
collective traffic is NOT included, so we parse the partitioned HLO text
and sum the bytes of every cross-device collective, with per-op wire
multipliers (ring algorithms):

    all-reduce        2x result bytes   (reduce-scatter + all-gather)
    all-gather        1x result bytes   (each chip receives the full result)
    reduce-scatter    1x operand ~= result * n ... accounted as result * 1
                      (bytes leaving/entering one chip ~ operand/n * (n-1))
    all-to-all        1x result bytes
    collective-permute 1x result bytes

These are per-chip wire-byte approximations, adequate for comparing
roofline terms across shardings (the quantity we hillclimb).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    f32_activation_bytes: float = 0.0  # see tpu_adjusted_wire_bytes

    @property
    def total_wire_bytes(self) -> float:
        return sum(_COLLECTIVES[k] * v for k, v in self.bytes_by_kind.items())

    @property
    def tpu_adjusted_wire_bytes(self) -> float:
        """XLA:CPU upcasts all bf16 compute to f32 (verified in
        tests/test_roofline.py), so matmul-partial / activation
        all-reduces appear at 2x their TPU width.  This adjustment
        halves the f32 collectives attributed to fwd/bwd dot_generals
        (gradient accumulators legitimately stay f32 and are not
        adjusted)."""
        return self.total_wire_bytes - 0.5 * 2.0 * self.f32_activation_bytes


# a computation header is an UNINDENTED "name (signature) -> type {" line;
# signatures may contain nested tuple parens, so match loosely to the
# trailing "{" instead of balancing parens
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$", re.M)
# the while operand may be printed bare (`while(%tuple.2)`) or with its
# full tuple type (`while((s32[], f32[8,16]{1,0}) %tuple.2)`) depending on
# the XLA version; greedy `.*` spans nested parens within the line
_WHILE_RE = re.compile(
    r"while\(.*\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|branch_computations)="
                      r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _segment_computations(text: str) -> dict:
    """Split HLO text into {computation_name: body_text}."""
    comps = {}
    matches = list(_COMP_RE.finditer(text))
    for i, m in enumerate(matches):
        start = m.start()
        end = matches[i + 1].start() if i + 1 < len(matches) else len(text)
        comps[m.group(1)] = text[start:end]
    return comps


def _entry_name(text: str) -> str | None:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    return m.group(1) if m else None


def _trip_count(cond_body: str) -> int:
    """Trip count of a scan-lowered while: the bound constant in the
    condition computation (max s32 constant; 1 if none found)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    return max(consts) if consts else 1


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Collective bytes with while-loop trip multipliers.

    XLA prints each while body once; we walk the computation graph from
    ENTRY, multiplying collective bytes inside loop bodies by the parsed
    trip counts (verified against scan-lowered HLO in tests).
    """
    comps = _segment_computations(hlo_text)
    entry = _entry_name(hlo_text)
    counts: dict = {}
    byts: dict = {}
    f32_act = [0.0]

    def local_collectives(body: str):
        out = []
        for m in _OP_RE.finditer(body):
            type_str, kind = m.group(1), m.group(2)
            line = body[m.start():body.find("\n", m.start())]
            if f"{kind}-done" in line:
                continue
            b = _shape_bytes(type_str)
            if f"{kind}-start" in line:
                b = b // 2 or b  # start result tuple = (operand, result)
            is_f32_act = ("f32[" in type_str and kind == "all-reduce"
                          and ("dot_general" in line or "reshape" in line))
            out.append((kind, b, is_f32_act))
        return out

    def visit(name: str, mult: float, depth: int = 0):
        body = comps.get(name)
        if body is None or depth > 32:
            return
        for kind, b, is_f32_act in local_collectives(body):
            counts[kind] = counts.get(kind, 0) + mult
            byts[kind] = byts.get(kind, 0) + mult * b
            if is_f32_act:
                f32_act[0] += mult * b
        for m in _WHILE_RE.finditer(body):
            cond, wbody = m.group(1), m.group(2)
            trips = _trip_count(comps.get(cond, ""))
            visit(wbody, mult * trips, depth + 1)
        for m in _CALL_RE.finditer(body):
            for callee in re.split(r",\s*%?", m.group(1)):
                if callee != name:
                    visit(callee, mult, depth + 1)

    if entry:
        visit(entry, 1.0)
    else:  # fallback: flat, no multipliers
        for m in _OP_RE.finditer(hlo_text):
            kind = m.group(2)
            counts[kind] = counts.get(kind, 0) + 1
            byts[kind] = byts.get(kind, 0) + _shape_bytes(m.group(1))
    return CollectiveStats(counts=counts, bytes_by_kind=byts,
                           f32_activation_bytes=f32_act[0])


# ---------------------------------------------------------------------- #
# roofline
# ---------------------------------------------------------------------- #
PEAK_FLOPS = 197e12      # TPU v5e bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device HLO bytes accessed
    wire_bytes: float            # per-device collective bytes
    model_flops: float           # 6 * N_active * tokens (whole step, global)
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (HLO flops summed over chips)."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Achievable MFU if the step runs exactly at the dominant bound."""
        if self.bound_s == 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * self.bound_s)

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "wire_bytes_per_chip": self.wire_bytes,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_frac": self.useful_flops_frac,
            "mfu_bound": self.mfu_bound,
        }
