"""Workload generators driving the PFS simulator.

Each workload models one application process group on one client:
closed-loop reader threads (issue -> wait -> issue, like POSIX sync reads)
or rate-capped writer threads (writes complete into the dirty cache until
it fills, after which the engine blocks them — Lustre's grant/dirty rule).

Generators mirror the paper's evaluation workloads:

* filebench-like single streams (SIV-A): sequential/random x 8K/1MB/16MB,
  one process, one OST — the offline training distribution;
* H5bench VPIC-IO (contiguous 1/2/3-D array writes) and BDCATS-IO
  (partial/strided/full reads) — Table II;
* DLIO BERT / Megatron read kernels with variable thread counts and OST
  spans — Fig. 3.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.pfs.engine import READ, WRITE


@dataclasses.dataclass
class Workload:
    """A closed/open-loop I/O stream bound to one (client, op) pair.

    Attributes:
        client: client index in the sim.
        op: READ or WRITE.
        req_size: application request size in bytes.
        randomness: 0.0 = perfectly sequential offsets, 1.0 = uniform random.
        n_threads: concurrent application threads (closed-loop depth).
        osts: OST indices the file stripes over (stripe_count = len(osts)).
        thread_rate: per-thread issue ceiling [B/s] (CPU-side cost; writers).
        duty_cycle / period: optional on/off bursting (DLIO epochs).
    """

    client: int
    op: int
    req_size: float
    randomness: float
    n_threads: int = 1
    osts: tuple = (0,)
    thread_rate: float = 1.2e9
    duty_cycle: float = 1.0
    period: float = 10.0
    name: str = "workload"

    def bind(self, sim) -> None:
        self._osc_ids = np.array([sim.osc_id(self.client, t) for t in self.osts])
        self._issued = 0.0
        self._done_base = float(sim.ctr_bytes_done[self.op, self._osc_ids].sum())
        self._rr = 0

    # ------------------------------------------------------------------ #
    def _active(self, sim) -> bool:
        if self.duty_cycle >= 1.0:
            return True
        return (sim.now % self.period) < self.duty_cycle * self.period

    def done_bytes(self, sim) -> float:
        return float(sim.ctr_bytes_done[self.op, self._osc_ids].sum()) - self._done_base

    def tick(self, sim, dt: float) -> None:
        if not self._active(sim):
            return
        if self.op == READ:
            # closed loop: keep n_threads * req_size bytes outstanding.
            # Sequential streams get extra depth from client readahead,
            # which pipelines ahead of the application threads.
            seq = 1.0 - self.randomness
            depth = (self.n_threads * self.req_size
                     + seq * sim.params.readahead_bytes * len(self._osc_ids))
            outstanding = self._issued - self.done_bytes(sim)
            want = depth - outstanding
            # a thread can re-issue at most thread_rate anyway
            want = min(max(want, 0.0), self.n_threads * self.thread_rate * dt)
            if want <= 0:
                return
            self._issue(sim, want)
        else:
            # writers throttle while the dirty cache / grants are exhausted
            if sim.write_blocked[self._osc_ids].any():
                return
            want = self.n_threads * self.thread_rate * dt
            self._issue(sim, want)

    def _issue(self, sim, nbytes: float) -> None:
        self._issued += nbytes
        per = nbytes / len(self._osc_ids)
        for osc in self._osc_ids:
            if self.op == READ:
                sim.submit_read(int(osc), per, self.randomness, self.req_size)
            else:
                got = sim.submit_write(int(osc), per, self.randomness, self.req_size)
                # blocked bytes are retried by the engine; stop counting them
                self._issued -= per - got


# ---------------------------------------------------------------------- #
# paper workload presets
# ---------------------------------------------------------------------- #
def sequential_stream(client: int, op: int, req_size: float, ost: int = 0,
                      n_threads: int = 1) -> Workload:
    """Filebench single-stream sequential pattern (training distribution)."""
    return Workload(client=client, op=op, req_size=req_size, randomness=0.0,
                    n_threads=n_threads, osts=(ost,),
                    name=f"seq_{'r' if op == READ else 'w'}_{int(req_size)}")


def random_stream(client: int, op: int, req_size: float, ost: int = 0,
                  n_threads: int = 1) -> Workload:
    """Filebench single-stream random pattern (training distribution)."""
    return Workload(client=client, op=op, req_size=req_size, randomness=1.0,
                    n_threads=n_threads, osts=(ost,),
                    name=f"rand_{'r' if op == READ else 'w'}_{int(req_size)}")


def strided_stream(client: int, op: int, req_size: float, ost: int = 0,
                   n_threads: int = 1) -> Workload:
    return Workload(client=client, op=op, req_size=req_size, randomness=0.5,
                    n_threads=n_threads, osts=(ost,), name="strided")


def vpic_write(client: int, dims: int, osts=(0, 1, 2, 3)) -> Workload:
    """H5bench VPIC-IO: contiguous particle array writes.

    Higher dimensionality fragments the contiguous runs slightly (HDF5
    chunking), which we model as mild randomness growth.
    """
    req = {1: 16 * 2**20, 2: 8 * 2**20, 3: 4 * 2**20}[dims]
    rnd = {1: 0.0, 2: 0.06, 3: 0.12}[dims]
    return Workload(client=client, op=WRITE, req_size=req, randomness=rnd,
                    n_threads=4, osts=tuple(osts), name=f"vpic_{dims}d")


def bdcats_read(client: int, mode: str, osts=(0, 1, 2, 3)) -> Workload:
    """H5bench BDCATS-IO: reads the VPIC output back (partial/strided/full)."""
    cfg = {
        "partial": dict(req_size=1 * 2**20, randomness=0.55, n_threads=4),
        "strided": dict(req_size=2 * 2**20, randomness=0.35, n_threads=4),
        "full": dict(req_size=16 * 2**20, randomness=0.0, n_threads=4),
    }[mode]
    return Workload(client=client, op=READ, osts=tuple(osts),
                    name=f"bdcats_{mode}", **cfg)


def dlio_reader(client: int, model: str, n_threads: int, osts=(0,)) -> Workload:
    """DLIO deep-learning read kernels (Fig. 3).

    BERT: many smallish TFRecord-style reads, shuffled access (random-ish).
    Megatron: larger sequential-ish sample reads from indexed .bin files.
    Both run in epoch bursts (read batch, compute step, repeat).
    """
    if model == "bert":
        # BERT TFRecord shards: many small records, shuffled access
        return Workload(client=client, op=READ, req_size=64 * 2**10,
                        randomness=0.9, n_threads=n_threads, osts=tuple(osts),
                        duty_cycle=0.85, period=4.0, name=f"dlio_bert_t{n_threads}")
    if model == "megatron":
        return Workload(client=client, op=READ, req_size=2 * 2**20,
                        randomness=0.25, n_threads=n_threads, osts=tuple(osts),
                        duty_cycle=0.9, period=6.0, name=f"dlio_megatron_t{n_threads}")
    raise ValueError(f"unknown DLIO model {model!r}")
