"""Workload generators driving the PFS simulator.

Each workload models one application process group on one client:
closed-loop reader threads (issue -> wait -> issue, like POSIX sync reads)
or rate-capped writer threads (writes complete into the dirty cache until
it fills, after which the engine blocks them — Lustre's grant/dirty rule).

Generators mirror the paper's evaluation workloads:

* filebench-like single streams (SIV-A): sequential/random x 8K/1MB/16MB,
  one process, one OST — the offline training distribution;
* H5bench VPIC-IO (contiguous 1/2/3-D array writes) and BDCATS-IO
  (partial/strided/full reads) — Table II;
* DLIO BERT / Megatron read kernels with variable thread counts and OST
  spans — Fig. 3.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.kernels.segment_reduce.ops import segment_sum_np as _np_segment_sum
from repro.pfs.engine import READ, WRITE
from repro.pfs.state import Demand, SimParams, SimState, SimTopo


@dataclasses.dataclass
class Workload:
    """A closed/open-loop I/O stream bound to one (client, op) pair.

    Attributes:
        client: client index in the sim.
        op: READ or WRITE.
        req_size: application request size in bytes.
        randomness: 0.0 = perfectly sequential offsets, 1.0 = uniform random.
        n_threads: concurrent application threads (closed-loop depth).
        osts: OST indices the file stripes over (stripe_count = len(osts)).
        thread_rate: per-thread issue ceiling [B/s] (CPU-side cost; writers).
        duty_cycle / period: optional on/off bursting (DLIO epochs).
    """

    client: int
    op: int
    req_size: float
    randomness: float
    n_threads: int = 1
    osts: tuple = (0,)
    thread_rate: float = 1.2e9
    duty_cycle: float = 1.0
    period: float = 10.0
    name: str = "workload"

    def bind(self, sim) -> None:
        self._osc_ids = np.array([sim.osc_id(self.client, t) for t in self.osts])
        self._issued = 0.0
        self._done_base = float(sim.ctr_bytes_done[self.op, self._osc_ids].sum())
        self._rr = 0

    # ------------------------------------------------------------------ #
    def _active(self, sim) -> bool:
        if self.duty_cycle >= 1.0:
            return True
        return (sim.now % self.period) < self.duty_cycle * self.period

    def done_bytes(self, sim) -> float:
        return float(sim.ctr_bytes_done[self.op, self._osc_ids].sum()) - self._done_base

    def tick(self, sim, dt: float) -> None:
        if not self._active(sim):
            return
        if self.op == READ:
            # closed loop: keep n_threads * req_size bytes outstanding.
            # Sequential streams get extra depth from client readahead,
            # which pipelines ahead of the application threads.
            seq = 1.0 - self.randomness
            depth = (self.n_threads * self.req_size
                     + seq * sim.params.readahead_bytes * len(self._osc_ids))
            outstanding = self._issued - self.done_bytes(sim)
            want = depth - outstanding
            # a thread can re-issue at most thread_rate anyway
            want = min(max(want, 0.0), self.n_threads * self.thread_rate * dt)
            if want <= 0:
                return
            self._issue(sim, want)
        else:
            # writers throttle while the dirty cache / grants are exhausted
            if sim.write_blocked[self._osc_ids].any():
                return
            want = self.n_threads * self.thread_rate * dt
            self._issue(sim, want)

    def _issue(self, sim, nbytes: float) -> None:
        self._issued += nbytes
        per = nbytes / len(self._osc_ids)
        if self.op == READ:
            for osc in self._osc_ids:
                sim.submit_read(int(osc), per, self.randomness, self.req_size)
        else:
            got = 0.0
            for osc in self._osc_ids:
                got += sim.submit_write(int(osc), per, self.randomness,
                                        self.req_size)
            # blocked bytes are retried by the engine; settle the closed-loop
            # accounting once for the whole stripe, so a partially blocked
            # stripe can't distort the depth seen while the rest of the same
            # call is still issuing
            self._issued -= nbytes - got


# ---------------------------------------------------------------------- #
# paper workload presets
# ---------------------------------------------------------------------- #
def sequential_stream(client: int, op: int, req_size: float, ost: int = 0,
                      n_threads: int = 1) -> Workload:
    """Filebench single-stream sequential pattern (training distribution)."""
    return Workload(client=client, op=op, req_size=req_size, randomness=0.0,
                    n_threads=n_threads, osts=(ost,),
                    name=f"seq_{'r' if op == READ else 'w'}_{int(req_size)}")


def random_stream(client: int, op: int, req_size: float, ost: int = 0,
                  n_threads: int = 1) -> Workload:
    """Filebench single-stream random pattern (training distribution)."""
    return Workload(client=client, op=op, req_size=req_size, randomness=1.0,
                    n_threads=n_threads, osts=(ost,),
                    name=f"rand_{'r' if op == READ else 'w'}_{int(req_size)}")


def strided_stream(client: int, op: int, req_size: float, ost: int = 0,
                   n_threads: int = 1) -> Workload:
    return Workload(client=client, op=op, req_size=req_size, randomness=0.5,
                    n_threads=n_threads, osts=(ost,), name="strided")


def vpic_write(client: int, dims: int, osts=(0, 1, 2, 3)) -> Workload:
    """H5bench VPIC-IO: contiguous particle array writes.

    Higher dimensionality fragments the contiguous runs slightly (HDF5
    chunking), which we model as mild randomness growth.
    """
    req = {1: 16 * 2**20, 2: 8 * 2**20, 3: 4 * 2**20}[dims]
    rnd = {1: 0.0, 2: 0.06, 3: 0.12}[dims]
    return Workload(client=client, op=WRITE, req_size=req, randomness=rnd,
                    n_threads=4, osts=tuple(osts), name=f"vpic_{dims}d")


def bdcats_read(client: int, mode: str, osts=(0, 1, 2, 3)) -> Workload:
    """H5bench BDCATS-IO: reads the VPIC output back (partial/strided/full)."""
    cfg = {
        "partial": dict(req_size=1 * 2**20, randomness=0.55, n_threads=4),
        "strided": dict(req_size=2 * 2**20, randomness=0.35, n_threads=4),
        "full": dict(req_size=16 * 2**20, randomness=0.0, n_threads=4),
    }[mode]
    return Workload(client=client, op=READ, osts=tuple(osts),
                    name=f"bdcats_{mode}", **cfg)


def dlio_reader(client: int, model: str, n_threads: int, osts=(0,)) -> Workload:
    """DLIO deep-learning read kernels (Fig. 3).

    BERT: many smallish TFRecord-style reads, shuffled access (random-ish).
    Megatron: larger sequential-ish sample reads from indexed .bin files.
    Both run in epoch bursts (read batch, compute step, repeat).
    """
    if model == "bert":
        # BERT TFRecord shards: many small records, shuffled access
        return Workload(client=client, op=READ, req_size=64 * 2**10,
                        randomness=0.9, n_threads=n_threads, osts=tuple(osts),
                        duty_cycle=0.85, period=4.0, name=f"dlio_bert_t{n_threads}")
    if model == "megatron":
        return Workload(client=client, op=READ, req_size=2 * 2**20,
                        randomness=0.25, n_threads=n_threads, osts=tuple(osts),
                        duty_cycle=0.9, period=6.0, name=f"dlio_megatron_t{n_threads}")
    raise ValueError(f"unknown DLIO model {model!r}")


# ---------------------------------------------------------------------- #
# vectorized workload layer: struct-of-arrays table + fleet demand_step
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class WorkloadState:
    """The per-row mutable workload state threaded through the scan."""

    issued: np.ndarray      # (R,) closed-loop bytes issued so far
    done_base: np.ndarray   # (R,) ctr_bytes_done stripe-sum at bind time


try:  # thread WorkloadState through jit / lax.scan when jax is present
    import jax as _jax

    _jax.tree_util.register_pytree_node(
        WorkloadState,
        lambda s: ((s.issued, s.done_base), None),
        lambda aux, c: WorkloadState(issued=c[0], done_base=c[1]),
    )
except ImportError:  # pragma: no cover
    pass


@dataclasses.dataclass
class WorkloadTable:
    """Struct-of-arrays over every attached workload row.

    The per-object ``Workload.tick`` loop issues per-interface
    ``submit_read``/``submit_write`` calls, which scales linearly with
    Python-level workload count.  This table holds the same information
    as flat arrays — one row per workload, plus a flattened
    (row -> OSC) stripe scatter — so the whole fleet's demand for one
    tick is a single vectorized :meth:`demand_step`.

    Rows that can interact (same op, overlapping stripes: sequential
    randomness-EMA mixing, shared dirty-cache room, blocked-flag reads)
    are partitioned into *waves* preserving attach order; rows within a
    wave are independent and vectorize exactly.  Almost all practical
    scenarios are single-wave.

    Build with :meth:`from_workloads` (the presets above stay the row
    constructors) and pair with :meth:`init_wstate`.
    """

    # per-row static arrays (R,)
    client: np.ndarray       # int64
    op: np.ndarray           # int64, READ/WRITE
    req_size: np.ndarray     # float
    randomness: np.ndarray   # float
    n_threads: np.ndarray    # float
    thread_rate: np.ndarray  # float
    duty_cycle: np.ndarray   # float
    period: np.ndarray       # float
    stripe_len: np.ndarray   # float (len(osts) per row)
    wave: np.ndarray         # int64 conflict-free execution wave
    # flattened stripe scatter (E,) — entry e maps row entry_row[e] to
    # interface entry_osc[e]
    entry_row: np.ndarray    # int64
    entry_osc: np.ndarray    # int64
    # (R,) bool — False rows are phantom padding added by :meth:`padded`
    # (ragged-batch bucketing); they never go active and every per-entry
    # contribution they make is an exact zero
    row_valid: np.ndarray
    n_osc: int
    n_waves: int
    names: tuple = ()

    def __len__(self) -> int:
        return len(self.op)

    @classmethod
    def from_workloads(cls, workloads, topo: SimTopo) -> "WorkloadTable":
        """Append one row per :class:`Workload` (presets stay constructors)."""
        rows = list(workloads)
        r = len(rows)
        osc_sets = []
        entry_row, entry_osc = [], []
        for i, w in enumerate(rows):
            oscs = [topo.osc_id(w.client, t) for t in w.osts]
            osc_sets.append((int(w.op), frozenset(oscs)))
            entry_row.extend([i] * len(oscs))
            entry_osc.extend(oscs)
        # wave partition: a row lands one wave after the latest earlier row
        # it conflicts with (same op, stripe overlap), preserving order
        wave = np.zeros(r, dtype=np.int64)
        for i in range(r):
            for j in range(i):
                if (osc_sets[i][0] == osc_sets[j][0]
                        and osc_sets[i][1] & osc_sets[j][1]):
                    wave[i] = max(wave[i], wave[j] + 1)
        return cls(
            client=np.array([w.client for w in rows], dtype=np.int64),
            op=np.array([w.op for w in rows], dtype=np.int64),
            req_size=np.array([w.req_size for w in rows], dtype=float),
            randomness=np.array([w.randomness for w in rows], dtype=float),
            n_threads=np.array([w.n_threads for w in rows], dtype=float),
            thread_rate=np.array([w.thread_rate for w in rows], dtype=float),
            duty_cycle=np.array([w.duty_cycle for w in rows], dtype=float),
            period=np.array([w.period for w in rows], dtype=float),
            stripe_len=np.array([len(w.osts) for w in rows], dtype=float),
            wave=wave,
            entry_row=np.array(entry_row, dtype=np.int64),
            entry_osc=np.array(entry_osc, dtype=np.int64),
            row_valid=np.ones(r, dtype=bool),
            n_osc=topo.n_osc,
            n_waves=int(wave.max()) + 1 if r else 1,
            names=tuple(w.name for w in rows),
        )

    def padded(self, n_rows: int, n_entries: int, n_waves: int,
               new_n_osc: int, osc_remap=None) -> "WorkloadTable":
        """Pad to a ragged-batch bucket shape with inert phantom rows.

        Phantom rows carry exact arithmetic identities: ``duty_cycle=0``
        (never active), ``n_threads=0`` (zero issue cap), ``row_valid``
        off.  Phantom stripe entries point at the first phantom row, so
        their per-entry shares are exactly ``0.0`` and every segment-sum
        they join is unchanged bitwise.  ``osc_remap`` (old ``n_osc`` ->
        new interface id) rewires the stripe scatter when the topology
        itself was padded; extra waves beyond ``self.n_waves`` run as
        empty (exact-identity) wave iterations.
        """
        r, e = len(self), len(self.entry_row)
        if n_rows < r or n_entries < e or n_waves < self.n_waves:
            raise ValueError("padded shape must cover the existing table")
        if n_entries > e and n_rows == r:
            raise ValueError("phantom entries need at least one phantom row")
        pr = n_rows - r

        def pad_row(a, fill, dtype=None):
            return np.concatenate(
                [np.asarray(a), np.full(pr, fill, dtype=dtype or a.dtype)])

        entry_osc = np.asarray(self.entry_osc)
        if osc_remap is not None:
            entry_osc = np.asarray(osc_remap, dtype=np.int64)[entry_osc]
        pe = n_entries - e
        return WorkloadTable(
            client=pad_row(self.client, 0),
            op=pad_row(self.op, READ),
            req_size=pad_row(self.req_size, 1.0),
            randomness=pad_row(self.randomness, 0.0),
            n_threads=pad_row(self.n_threads, 0.0),
            thread_rate=pad_row(self.thread_rate, 0.0),
            duty_cycle=pad_row(self.duty_cycle, 0.0),
            period=pad_row(self.period, 1.0),
            stripe_len=pad_row(self.stripe_len, 1.0),
            wave=pad_row(self.wave, 0),
            entry_row=np.concatenate(
                [np.asarray(self.entry_row),
                 np.full(pe, r, dtype=np.int64)]),
            entry_osc=np.concatenate(
                [entry_osc, np.zeros(pe, dtype=np.int64)]),
            row_valid=np.concatenate(
                [np.asarray(self.row_valid, dtype=bool),
                 np.zeros(pr, dtype=bool)]),
            n_osc=int(new_n_osc),
            n_waves=int(n_waves),
            names=self.names,
        )

    # ------------------------------------------------------------------ #
    def _row_done(self, state, wstate, xp, segsum):
        """Per-row app-visible completed bytes (stripe sum, net of base)."""
        done_e = state.ctr_bytes_done[self.op[self.entry_row], self.entry_osc]
        return segsum(done_e, self.entry_row, len(self)) - wstate.done_base

    def init_wstate(self, state: SimState) -> WorkloadState:
        """Bind the table to a state (captures the done_bytes baseline)."""
        r = len(self)
        base = np.zeros(r)
        if r:
            done_e = np.asarray(
                state.ctr_bytes_done)[self.op[self.entry_row], self.entry_osc]
            base = _np_segment_sum(done_e, self.entry_row, r)
        return WorkloadState(issued=np.zeros(r), done_base=base)

    def done_bytes(self, state, wstate) -> np.ndarray:
        """Per-row delivered bytes — the vectorized ``Workload.done_bytes``."""
        return self._row_done(state, wstate, np, _np_segment_sum)

    # ------------------------------------------------------------------ #
    def demand_step(self, params: SimParams, wstate: WorkloadState,
                    state: SimState, xp=np, segsum=_np_segment_sum):
        """One tick of demand for the whole fleet, fully vectorized.

        Runs the exact closed-loop reader / grant-throttled writer
        semantics of ``Workload.tick`` for every row at once and resolves
        them to per-OSC deltas.  ``xp``/``segsum`` select the backend
        (numpy by default; :mod:`repro.pfs.engine_jax` passes jnp and the
        shared segment-sum helper), so the same code is the oracle and
        the jitted path.

        Returns ``(demand, wstate')`` — the caller feeds ``demand`` to
        :func:`repro.pfs.state.engine_step`.
        """
        n, r = self.n_osc, len(self)
        dt = params.tick
        now = state.now
        e_row, e_osc = self.entry_row, self.entry_osc
        slen_e = self.stripe_len[e_row]
        rand_row_e = self.randomness[e_row]
        req_floor_e = xp.maximum(self.req_size, 1.0)[e_row]

        # threaded (functional) copies of the sequentially-mixed fields
        rand_r = state.randomness[READ]
        rand_w = state.randomness[WRITE]
        blocked = state.write_blocked
        dirty = state.dirty_bytes
        grant = state.grant_used

        zero_n = xp.zeros(n)
        pend_read_add = zero_n
        dirty_add = zero_n
        cache_add = zero_n
        req_cnt_add = [zero_n, zero_n]
        req_bytes_add = [zero_n, zero_n]
        issued = wstate.issued

        active = xp.logical_and(
            xp.logical_or(
                self.duty_cycle >= 1.0,
                xp.mod(now, self.period) < self.duty_cycle * self.period),
            self.row_valid)
        cap_row = self.n_threads * self.thread_rate * dt
        # wave-invariant reader inputs: reads never observe intra-tick
        # counter changes, so the stripe-summed done_bytes uses the
        # tick-start counters, and depth is static per tick
        done_e = state.ctr_bytes_done[self.op[e_row], e_osc]
        done_row = segsum(done_e, e_row, r) - wstate.done_base
        seq = 1.0 - self.randomness
        depth = (self.n_threads * self.req_size
                 + seq * params.readahead_bytes * self.stripe_len)

        for k in range(self.n_waves):
            in_wave = self.wave == k           # static mask
            # ---- closed-loop readers -------------------------------- #
            is_r = xp.logical_and(xp.logical_and(in_wave, self.op == READ),
                                  active)
            want_r = xp.clip(depth - (issued - done_row), 0.0, cap_row)
            want_r = xp.where(xp.logical_and(is_r, want_r > 0), want_r, 0.0)
            issued = issued + want_r
            per_e = want_r[e_row] / slen_e
            pend_read_add = pend_read_add + segsum(per_e, e_osc, n)
            # randomness EMA: stripes within a wave are disjoint per op,
            # so the scatter has at most one contributor per interface
            w_e = xp.minimum(per_e / (4 * 2**20), 1.0)
            factor = 1.0 - segsum(0.2 * w_e, e_osc, n)
            contrib = segsum((0.2 * w_e) * rand_row_e, e_osc, n)
            rand_r = factor * rand_r + contrib
            inc_e = xp.where(want_r[e_row] > 0,
                             xp.maximum(per_e / req_floor_e, 1.0), 0.0)
            req_cnt_add[READ] = req_cnt_add[READ] + segsum(inc_e, e_osc, n)
            req_bytes_add[READ] = req_bytes_add[READ] + segsum(per_e, e_osc, n)
            cache_add = cache_add + segsum((1.0 - rand_row_e) * per_e,
                                           e_osc, n)

            # ---- grant-throttled writers ---------------------------- #
            blocked_any = segsum(xp.where(blocked[e_osc], 1.0, 0.0),
                                 e_row, r) > 0
            goes = xp.logical_and(
                xp.logical_and(in_wave, self.op == WRITE),
                xp.logical_and(active, xp.logical_not(blocked_any)))
            want_w = xp.where(goes, cap_row, 0.0)
            per_we = want_w[e_row] / slen_e
            want_osc = segsum(per_we, e_osc, n)
            room = xp.minimum(params.max_dirty_bytes - dirty,
                              params.grant_bytes - grant)
            accepted = xp.clip(want_osc, 0.0, xp.maximum(room, 0.0))
            dirty = dirty + accepted
            grant = grant + accepted
            dirty_add = dirty_add + accepted
            w_osc = xp.minimum(accepted / (4 * 2**20), 1.0)
            rr_osc = segsum(xp.where(per_we > 0, rand_row_e, 0.0), e_osc, n)
            rand_w = (1.0 - 0.2 * w_osc) * rand_w + (0.2 * w_osc) * rr_osc
            inc_we = xp.where(per_we > 0,
                              xp.maximum(per_we / req_floor_e, 1.0), 0.0)
            req_cnt_add[WRITE] = req_cnt_add[WRITE] + segsum(inc_we, e_osc, n)
            req_bytes_add[WRITE] = req_bytes_add[WRITE] + accepted
            submitted = want_osc > 0
            blocked = xp.where(submitted, accepted < want_osc, blocked)
            # whole-stripe closed-loop settlement (see Workload._issue):
            # only the accepted bytes count as issued, in one correction
            acc_row = segsum(xp.where(per_we > 0, accepted[e_osc], 0.0),
                             e_row, r)
            issued = issued + acc_row

        demand = Demand(
            pending_read_add=pend_read_add,
            dirty_add=dirty_add,
            req_count_add=xp.stack(req_cnt_add),
            req_bytes_add=xp.stack(req_bytes_add),
            cache_hit_add=cache_add,
            randomness_new=xp.stack([rand_r, rand_w]),
            write_blocked_new=blocked,
        )
        return demand, WorkloadState(issued=issued, done_base=wstate.done_base)


# The table is itself a pytree (arrays as children; n_osc / n_waves as
# static aux data) so the scenario lab can stack B structurally-identical
# tables and vmap demand_step over the batch axis.  ``names`` is display
# metadata only and deliberately not round-tripped through tree ops.
_TABLE_ARRAY_FIELDS = (
    "client", "op", "req_size", "randomness", "n_threads", "thread_rate",
    "duty_cycle", "period", "stripe_len", "wave", "entry_row", "entry_osc",
    "row_valid",
)

try:  # pragma: no cover - exercised implicitly by the lab batch tests
    import jax as _jax2

    _jax2.tree_util.register_pytree_node(
        WorkloadTable,
        lambda t: (tuple(getattr(t, f) for f in _TABLE_ARRAY_FIELDS),
                   (t.n_osc, t.n_waves)),
        lambda aux, children: WorkloadTable(
            **dict(zip(_TABLE_ARRAY_FIELDS, children)),
            n_osc=aux[0], n_waves=aux[1]),
    )
except ImportError:  # pragma: no cover
    pass


def table_from_sim(sim):
    """Freeze a live sim's attached workloads into (table, wstate).

    Captures each legacy :class:`Workload`'s closed-loop runtime state
    (``_issued`` / ``_done_base``) so the vectorized path continues the
    exact same trajectories mid-run.
    """
    wls = sim._workloads
    table = WorkloadTable.from_workloads(wls, sim.topo)
    wstate = WorkloadState(
        issued=np.array([w._issued for w in wls], dtype=float),
        done_base=np.array([w._done_base for w in wls], dtype=float))
    return table, wstate


def sync_workloads_from_table(sim, wstate: WorkloadState) -> None:
    """Write the table's closed-loop state back into the legacy objects,
    so ``Workload.done_bytes`` / further ``sim.step()`` keep working."""
    for i, w in enumerate(sim._workloads):
        w._issued = float(wstate.issued[i])


def run_interval(params: SimParams, topo: SimTopo, table: WorkloadTable,
                 state: SimState, wstate: WorkloadState, n_ticks: int,
                 schedule=None):
    """Numpy reference interval runner over the vectorized workload table.

    Steps ``n_ticks`` of ``demand_step`` + :func:`engine_step` — the same
    schedule the fused JAX scan executes, on the oracle backend.
    ``schedule`` is an optional :class:`~repro.pfs.state.Disturbance`
    with a leading ``(n_ticks, ...)`` time axis; tick ``i`` consumes row
    ``i``, mirroring the scan's ``xs`` consumption exactly.
    """
    from repro.pfs.state import engine_step
    for i in range(n_ticks):
        demand, wstate = table.demand_step(params, wstate, state)
        dist = None if schedule is None else schedule.at_tick(i)
        state = engine_step(params, topo, state, demand, disturbance=dist)
    return state, wstate
