"""JAX execution layer: one full tuning interval as a single fused scan.

The numpy oracle (:func:`repro.pfs.state.engine_step`) is driven
tick-by-tick from Python — ~100 interpreter round trips per 0.5 s tuning
interval.  This module compiles the *whole interval* into one jitted
``lax.scan`` over the identical transition:

    (SimState, WorkloadState) --[demand_step ∘ engine_step]*n_ticks-->
    (SimState', WorkloadState')

with every per-OST / per-client / per-stripe reduction routed through
the shared :mod:`repro.kernels.segment_reduce` helper — on TPU a Pallas
one-hot-matmul kernel, elsewhere ``jax.ops.segment_sum``.

Numerics: the scan is traced under ``enable_x64`` so arithmetic matches
the float64 numpy oracle (the equivalence tests hold both paths to
≤1e-6 relative error on all probe counters; in practice they agree to
~1e-12).  The TPU Pallas segment kernel accumulates in f32 — it is only
selected on TPU, where the oracle comparison does not run.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.kernels.segment_reduce.ops import make_segment_sum
from repro.pfs.state import (PAGE_SIZE, READ, WRITE, Demand, Disturbance,
                             SimParams, SimState, SimTopo)
from repro.pfs.workloads import WorkloadState, WorkloadTable


def _div_where(num, den, cond, fallback):
    """``np.divide(num, den, out=fallback, where=cond)`` in functional jnp."""
    safe = jnp.where(cond, den, 1.0)
    return jnp.where(cond, num / safe, fallback)


def engine_step_jax(params: SimParams, topo: SimTopo, state: SimState,
                    demand: Demand | None, segsum,
                    disturbance: Disturbance | None = None) -> SimState:
    """Pure-jnp mirror of :func:`repro.pfs.state.engine_step`.

    Same phase structure and same arithmetic, with the bincount call
    sites replaced by ``segsum`` and the in-place updates rewritten as
    functional rebinding.  Tested element-for-element against the numpy
    oracle (tests/test_engine_equivalence.py).
    """
    p = params
    dt = p.tick
    n_osts, n_clients = topo.n_osts, topo.n_clients
    osc_ost, osc_client = topo.osc_ost, topo.osc_client
    dist = (disturbance if disturbance is not None
            else Disturbance.neutral(topo))

    # unpack per-op rows as locals (functional SSA instead of mutation)
    pending = [state.pending[READ], state.pending[WRITE]]
    hold_age = [state.hold_age[READ], state.hold_age[WRITE]]
    queue_rpcs = [state.queue_rpcs[READ], state.queue_rpcs[WRITE]]
    queue_bytes = [state.queue_bytes[READ], state.queue_bytes[WRITE]]
    active_rpcs = [state.active_rpcs[READ], state.active_rpcs[WRITE]]
    setup_work = [state.setup_work[READ], state.setup_work[WRITE]]
    unready = [state.unready_bytes[READ], state.unready_bytes[WRITE]]
    ready_b = [state.ready_bytes[READ], state.ready_bytes[WRITE]]
    avg_size = [state.active_avg_size[READ], state.active_avg_size[WRITE]]
    disp_num = [state.dispatch_time_num[READ], state.dispatch_time_num[WRITE]]
    randomness = [state.randomness[READ], state.randomness[WRITE]]
    ctr_bytes_done = [state.ctr_bytes_done[READ], state.ctr_bytes_done[WRITE]]
    ctr_rpcs_sent = [state.ctr_rpcs_sent[READ], state.ctr_rpcs_sent[WRITE]]
    ctr_rpc_bytes = [state.ctr_rpc_bytes[READ], state.ctr_rpc_bytes[WRITE]]
    ctr_partial = [state.ctr_partial_rpcs[READ], state.ctr_partial_rpcs[WRITE]]
    ctr_lat = [state.ctr_latency_sum[READ], state.ctr_latency_sum[WRITE]]
    ctr_rpcs_done = [state.ctr_rpcs_done[READ], state.ctr_rpcs_done[WRITE]]
    ctr_req_count = state.ctr_req_count
    ctr_req_bytes = state.ctr_req_bytes
    ctr_cache_hit = state.ctr_cache_hit_bytes
    ctr_pend_int = [state.ctr_pending_integral[READ],
                    state.ctr_pending_integral[WRITE]]
    ctr_act_int = [state.ctr_active_integral[READ],
                   state.ctr_active_integral[WRITE]]
    dirty = state.dirty_bytes
    grant = state.grant_used
    blocked = state.write_blocked
    now = state.now

    # (1) workloads deposit demand
    if demand is not None:
        pending[READ] = pending[READ] + demand.pending_read_add
        dirty = dirty + demand.dirty_add
        grant = grant + demand.dirty_add
        ctr_req_count = ctr_req_count + demand.req_count_add
        ctr_req_bytes = ctr_req_bytes + demand.req_bytes_add
        ctr_cache_hit = ctr_cache_hit + demand.cache_hit_add
        ctr_bytes_done[WRITE] = ctr_bytes_done[WRITE] + demand.dirty_add
        randomness = [demand.randomness_new[READ], demand.randomness_new[WRITE]]
        blocked = demand.write_blocked_new

    # write path: dirty cache continuously feeds the pending queue
    in_pipe = (pending[WRITE] + queue_bytes[WRITE]
               + unready[WRITE] + ready_b[WRITE])
    pending[WRITE] = pending[WRITE] + jnp.maximum(dirty - in_pipe, 0.0)

    # (2) RPC formation: full windows pack immediately; partials wait
    win_bytes = (state.window_pages * PAGE_SIZE).astype(jnp.float64)
    for op in (READ, WRITE):
        pend = pending[op]
        room = jnp.maximum(p.max_rpc_queue - queue_rpcs[op], 0.0)
        n_full = jnp.minimum(jnp.floor(pend / win_bytes), room)
        full_bytes = n_full * win_bytes
        queue_rpcs[op] = queue_rpcs[op] + n_full
        queue_bytes[op] = queue_bytes[op] + full_bytes
        pend = pend - full_bytes
        hold_age[op] = jnp.where(pend > 0, hold_age[op] + dt, 0.0)
        expire = (pend > 0) & (hold_age[op] >= p.hold_time(op)) & (room > n_full)
        queue_rpcs[op] = queue_rpcs[op] + expire
        queue_bytes[op] = queue_bytes[op] + jnp.where(expire, pend, 0.0)
        ctr_partial[op] = ctr_partial[op] + expire
        pending[op] = jnp.where(expire, 0.0, pend)
        hold_age[op] = jnp.where(expire, 0.0, hold_age[op])

    # (3) dispatch up to rpcs_in_flight (reads first: sync-read bias)
    slots = jnp.maximum(
        state.rpcs_in_flight - (active_rpcs[READ] + active_rpcs[WRITE]), 0.0)
    for op in (READ, WRITE):
        take = jnp.minimum(queue_rpcs[op], slots)
        frac = _div_where(take, queue_rpcs[op], queue_rpcs[op] > 0, 0.0)
        bytes_out = queue_bytes[op] * frac
        queue_rpcs[op] = queue_rpcs[op] - take
        queue_bytes[op] = queue_bytes[op] - bytes_out
        slots = slots - take
        active_rpcs[op] = active_rpcs[op] + take
        per_rpc = p.setup_time(randomness[op]) + p.rtt
        setup_work[op] = setup_work[op] + take * per_rpc
        unready[op] = unready[op] + bytes_out
        tot_bytes = unready[op] + ready_b[op]
        avg_size[op] = jnp.where(
            active_rpcs[op] > 0,
            tot_bytes / jnp.maximum(active_rpcs[op], 1e-9),
            avg_size[op])
        ctr_rpcs_sent[op] = ctr_rpcs_sent[op] + take
        ctr_rpc_bytes[op] = ctr_rpc_bytes[op] + bytes_out
        disp_num[op] = disp_num[op] + take * now

    # (4) OST setup service + IOPS ceiling
    total_work = setup_work[READ] + setup_work[WRITE]
    ost_work = segsum(total_work, osc_ost, n_osts)
    cap = dt * p.ost_setup_parallel * dist.iops_scale
    drain_frac_ost = _div_where(cap, ost_work, ost_work > cap, 1.0)
    for op in (READ, WRITE):
        work = setup_work[op]
        drained = work * drain_frac_ost[osc_ost]
        per_rpc = p.setup_time(randomness[op]) + p.rtt
        setups_done = _div_where(drained, per_rpc, per_rpc > 0, 0.0)
        ost_setups = segsum(setups_done, osc_ost, n_osts)
        iops_cap = p.ost_iops * dt * dist.iops_scale
        iops_frac = _div_where(iops_cap, ost_setups, ost_setups > iops_cap, 1.0)
        effective = drained * iops_frac[osc_ost]
        setup_work[op] = work - effective
        ready = jnp.minimum(
            _div_where(effective, per_rpc, per_rpc > 0, 0.0) * avg_size[op],
            unready[op])
        ready = jnp.where(setup_work[op] <= 1e-12, unready[op], ready)
        unready[op] = unready[op] - ready
        ready_b[op] = ready_b[op] + ready

    # (5) bandwidth: OST fair share + congestion decay + NIC cap
    want = ready_b[READ] + ready_b[WRITE]
    queued = unready[READ] + unready[WRITE] + ready_b[READ] + ready_b[WRITE]
    ost_queued = segsum(queued, osc_ost, n_osts) + dist.bg_bytes
    eff = jnp.where(
        ost_queued > p.ost_buffer_bytes,
        jnp.power(p.ost_buffer_bytes / jnp.maximum(ost_queued, 1.0),
                  p.congestion_exp),
        1.0)
    active_transfer = jnp.where(want > 0,
                                active_rpcs[READ] + active_rpcs[WRITE], 0.0)
    ost_shares = segsum(active_transfer, osc_ost, n_osts)
    share = _div_where(active_transfer, ost_shares[osc_ost],
                       ost_shares[osc_ost] > 0, 0.0)
    ost_bw_eff = p.ost_bandwidth * dist.bw_scale * eff
    # background traffic is served first, shrinking the foreground
    # budget; same subtraction form as the numpy oracle so the
    # zero-background case keeps the historical multiplication order
    bg_served = jnp.minimum(dist.bg_bytes, ost_bw_eff * dt)
    alloc = jnp.minimum(
        share * ost_bw_eff[osc_ost] * dt - share * bg_served[osc_ost], want)
    leftover = (ost_bw_eff * dt - bg_served) - segsum(alloc, osc_ost, n_osts)
    hungry = want - alloc
    ost_hungry = segsum(hungry, osc_ost, n_osts)
    bonus_frac = _div_where(leftover, ost_hungry, ost_hungry > 0, 0.0)
    alloc = alloc + hungry * jnp.minimum(bonus_frac[osc_ost], 1.0)
    nic_cap = p.nic_bandwidth * dist.nic_scale * dt
    client_alloc = segsum(alloc, osc_client, n_clients)
    nic_frac = _div_where(nic_cap, client_alloc,
                          client_alloc > nic_cap, 1.0)
    alloc = alloc * nic_frac[osc_client]

    # (6) completions
    for op in (READ, WRITE):
        frac = _div_where(ready_b[op], want, want > 0, 0.0)
        drained = alloc * frac
        ready_b[op] = ready_b[op] - drained
        avg = jnp.maximum(avg_size[op], 1.0)
        done_rpcs = jnp.minimum(drained / avg, active_rpcs[op])
        inflight_bytes = unready[op] + ready_b[op]
        done_rpcs = jnp.where(inflight_bytes <= 1e-9, active_rpcs[op],
                              done_rpcs)
        prev_active = active_rpcs[op]
        active_rpcs[op] = active_rpcs[op] - done_rpcs
        ctr_rpcs_done[op] = ctr_rpcs_done[op] + done_rpcs
        if op == READ:
            ctr_bytes_done[READ] = ctr_bytes_done[READ] + drained
        else:
            dirty = jnp.maximum(dirty - drained, 0.0)
            grant = jnp.maximum(grant - drained, 0.0)
        avg_disp = disp_num[op] / jnp.maximum(prev_active, 1e-9)
        lat = jnp.maximum(now + dt - avg_disp, dt)
        ctr_lat[op] = ctr_lat[op] + done_rpcs * lat
        keep = active_rpcs[op] / jnp.maximum(prev_active, 1e-9)
        disp_num[op] = disp_num[op] * keep

    # blocked-writer accounting
    ctr_block_time = state.ctr_block_time + blocked * dt
    room = jnp.minimum(p.max_dirty_bytes - dirty, p.grant_bytes - grant)
    blocked = jnp.logical_and(blocked, room < PAGE_SIZE)

    # time-integrals for interval averages
    for op in (READ, WRITE):
        ctr_pend_int[op] = ctr_pend_int[op] + (pending[op] + queue_bytes[op]) * dt
        ctr_act_int[op] = ctr_act_int[op] + active_rpcs[op] * dt
    ctr_dirty_int = state.ctr_dirty_integral + dirty * dt
    ctr_grant_int = state.ctr_grant_integral + grant * dt

    stack = jnp.stack
    return SimState(
        now=now + dt,
        tick_index=state.tick_index + 1,
        window_pages=state.window_pages,
        rpcs_in_flight=state.rpcs_in_flight,
        pending=stack(pending),
        hold_age=stack(hold_age),
        queue_rpcs=stack(queue_rpcs),
        queue_bytes=stack(queue_bytes),
        active_rpcs=stack(active_rpcs),
        setup_work=stack(setup_work),
        unready_bytes=stack(unready),
        ready_bytes=stack(ready_b),
        active_avg_size=stack(avg_size),
        dispatch_time_num=stack(disp_num),
        randomness=stack(randomness),
        dirty_bytes=dirty,
        grant_used=grant,
        write_blocked=blocked,
        ctr_bytes_done=stack(ctr_bytes_done),
        ctr_rpcs_sent=stack(ctr_rpcs_sent),
        ctr_rpc_bytes=stack(ctr_rpc_bytes),
        ctr_partial_rpcs=stack(ctr_partial),
        ctr_latency_sum=stack(ctr_lat),
        ctr_rpcs_done=stack(ctr_rpcs_done),
        ctr_req_count=ctr_req_count,
        ctr_req_bytes=ctr_req_bytes,
        ctr_cache_hit_bytes=ctr_cache_hit,
        ctr_block_time=ctr_block_time,
        ctr_pending_integral=stack(ctr_pend_int),
        ctr_active_integral=stack(ctr_act_int),
        ctr_dirty_integral=ctr_dirty_int,
        ctr_grant_integral=ctr_grant_int,
        ost_valid=state.ost_valid,
        client_valid=state.client_valid,
    )


# ---------------------------------------------------------------------- #
# fused interval runner
# ---------------------------------------------------------------------- #
def _to_numpy_state(state: SimState) -> SimState:
    # np.array (not asarray): device buffers convert to read-only views,
    # and the stateful wrapper mutates these in place (set_knobs, submit_*)
    out = jax.tree.map(np.array, state)
    out.now = float(out.now)
    out.tick_index = int(out.tick_index)
    return out


class FusedEngine:
    """One tuning interval (``n_ticks`` engine ticks) per jitted call.

    Compiles ``lax.scan`` over ``demand_step ∘ engine_step`` once at
    construction scope (first call), then every interval is a single
    device dispatch.  Inputs/outputs are numpy ``SimState`` /
    ``WorkloadState`` so the stateful :class:`~repro.pfs.engine.PFSSim`
    wrapper and the probe/tuning layers never see jax arrays.
    """

    def __init__(self, params: SimParams, topo: SimTopo,
                 table: WorkloadTable, n_ticks: int,
                 seg_backend: str = "auto"):
        self.params = params
        self.topo = topo
        self.table = table
        self.n_ticks = int(n_ticks)
        segsum = make_segment_sum(seg_backend)
        # every interval scans over a per-tick Disturbance schedule; an
        # undisturbed run scans the (exact-identity) neutral schedule so
        # jit sees a single signature either way
        self._neutral_sched = Disturbance.neutral(topo, n_ticks=self.n_ticks)

        def body(carry, dist):
            state, wstate = carry
            demand, wstate = table.demand_step(params, wstate, state,
                                               xp=jnp, segsum=segsum)
            state = engine_step_jax(params, topo, state, demand, segsum,
                                    disturbance=dist)
            return (state, wstate), None

        @jax.jit
        def run(state, wstate, sched):
            (state, wstate), _ = jax.lax.scan(
                body, (state, wstate), sched, length=self.n_ticks)
            return state, wstate

        self._run = run

    def run_interval(self, state: SimState, wstate: WorkloadState,
                     schedule: Disturbance | None = None):
        """Advance one interval; numpy in, numpy out (float64 end to end).

        ``schedule`` is a :class:`Disturbance` whose arrays carry a
        leading ``(n_ticks, ...)`` time axis — tick ``i`` of the scan
        consumes row ``i`` (scan ``xs``), exactly as the numpy reference
        :func:`repro.pfs.workloads.run_interval` indexes it.
        """
        if schedule is None:
            schedule = self._neutral_sched
        with enable_x64():
            jstate = jax.tree.map(jnp.asarray, state)
            jws = jax.tree.map(jnp.asarray, wstate)
            jsched = jax.tree.map(jnp.asarray, schedule)
            jstate, jws = self._run(jstate, jws, jsched)
            jstate, jws = jax.tree.map(lambda x: x.block_until_ready()
                                       if hasattr(x, "block_until_ready")
                                       else x, (jstate, jws))
        return _to_numpy_state(jstate), jax.tree.map(np.array, jws)


def fused_engine_for(sim, table: WorkloadTable, n_ticks: int,
                     seg_backend: str = "auto") -> FusedEngine:
    """Build a :class:`FusedEngine` for a live :class:`PFSSim`."""
    return FusedEngine(sim.params, sim.topo, table, n_ticks,
                       seg_backend=seg_backend)
