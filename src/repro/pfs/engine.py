"""Stateful wrapper over the pure PFS engine core.

The engine itself lives in :mod:`repro.pfs.state` as a flat
:class:`~repro.pfs.state.SimState` dataclass plus the pure transition
:func:`~repro.pfs.state.engine_step` — see that module for the model
documentation (RPC formation, dispatch, OST drain, bandwidth sharing,
grant/dirty write-back).  :class:`PFSSim` keeps the historical mutable
interface every caller knows:

* attribute access (``sim.ctr_bytes_done`` …) transparently reads the
  current ``SimState`` arrays, so :mod:`repro.pfs.stats` probing and all
  tests/benchmarks work unchanged;
* legacy :class:`~repro.pfs.workloads.Workload` objects still deposit
  demand through :meth:`submit_read` / :meth:`submit_write` (in-place on
  the state arrays), after which :meth:`step` advances via the pure
  function;
* the vectorized/fused paths (:class:`~repro.pfs.workloads.WorkloadTable`
  + :mod:`repro.pfs.engine_jax`) operate on the same ``SimState`` and
  sync back through :attr:`state`.

The two DIAL-tunable knobs are per-OSC arrays: ``window_pages``
(= Lustre ``osc.*.max_pages_per_rpc``) and ``rpcs_in_flight``
(= ``osc.*.max_rpcs_in_flight``).  Both take effect on the next tick,
mirroring Lustre's near-real-time application of these parameters (SII-B).
"""

from __future__ import annotations

import numpy as np

from repro.pfs.state import (PAGE_SIZE, READ, WRITE, Disturbance, SimParams,
                             SimState, SimTopo, engine_step, init_state)

__all__ = ["PFSSim", "SimParams", "SimTopo", "SimState", "Disturbance",
           "engine_step", "init_state", "PAGE_SIZE", "READ", "WRITE"]


class PFSSim:
    """Discrete-time simulator of clients -> OSC -> RPC -> OST.

    Construction wires a static topology; workloads attach to clients and
    drive demand each tick.  All mutable state is numpy arrays in one
    :class:`SimState`, so a tick is a handful of vectorized ops regardless
    of OSC count.
    """

    def __init__(
        self,
        n_clients: int,
        n_osts: int,
        params: SimParams | None = None,
        seed: int = 0,
    ):
        self.params = params or SimParams()
        self.topo = SimTopo.dense(n_clients, n_osts)
        self.rng = np.random.default_rng(seed)
        self.state = init_state(self.topo)
        self._workloads: list = []

    # ------------------------------------------------------------------ #
    # state delegation: sim.<field> reads the current SimState array
    # ------------------------------------------------------------------ #
    def __getattr__(self, name: str):
        # only called when normal attribute lookup fails
        state = self.__dict__.get("state")
        if state is not None and hasattr(state, name):
            return getattr(state, name)
        raise AttributeError(
            f"{type(self).__name__!s} object has no attribute {name!r}")

    @property
    def n_clients(self) -> int:
        return self.topo.n_clients

    @property
    def n_osts(self) -> int:
        return self.topo.n_osts

    @property
    def n_osc(self) -> int:
        return self.topo.n_osc

    @property
    def osc_client(self) -> np.ndarray:
        return self.topo.osc_client

    @property
    def osc_ost(self) -> np.ndarray:
        return self.topo.osc_ost

    # ------------------------------------------------------------------ #
    # topology / knob helpers
    # ------------------------------------------------------------------ #
    def osc_id(self, client: int, ost: int) -> int:
        return self.topo.osc_id(client, ost)

    def client_oscs(self, client: int) -> np.ndarray:
        return self.topo.client_oscs(client)

    def set_knobs(self, osc_ids, window_pages=None, rpcs_in_flight=None) -> None:
        """Apply DIAL's theta to one or more OSC interfaces (takes effect
        next tick, mirroring ``lctl set_param`` latency).

        Either knob may be a scalar (broadcast over ``osc_ids``) or an
        array aligned with ``osc_ids`` — the fleet agent applies a whole
        tick's decisions in one fancy-indexed assignment.
        """
        if window_pages is not None:
            self.state.window_pages[osc_ids] = np.asarray(window_pages,
                                                          dtype=np.int64)
        if rpcs_in_flight is not None:
            self.state.rpcs_in_flight[osc_ids] = np.asarray(rpcs_in_flight,
                                                            dtype=np.int64)

    def attach(self, workload) -> None:
        workload.bind(self)
        self._workloads.append(workload)

    # ------------------------------------------------------------------ #
    # demand entry points used by legacy Workload objects
    # ------------------------------------------------------------------ #
    def submit_read(self, osc: int, nbytes: float, randomness: float,
                    req_size: float) -> float:
        """App issues read requests totalling ``nbytes``.  All bytes flow
        through the RPC pipeline (readahead hides latency in the workload's
        closed loop, it does not conjure bandwidth)."""
        s = self.state
        s.pending[READ, osc] += nbytes
        self._mix_randomness(READ, osc, nbytes, randomness)
        s.ctr_req_count[READ, osc] += max(nbytes / max(req_size, 1.0), 1.0)
        s.ctr_req_bytes[READ, osc] += nbytes
        # observable proxy for llite readahead hit counters
        s.ctr_cache_hit_bytes[osc] += (1.0 - randomness) * nbytes
        return nbytes

    def submit_write(self, osc: int, nbytes: float, randomness: float,
                     req_size: float) -> float:
        """App writes ``nbytes``; bytes land in the dirty cache if grant and
        dirty limits allow, else the writer blocks (accepted < nbytes)."""
        p = self.params
        s = self.state
        room = min(
            p.max_dirty_bytes - s.dirty_bytes[osc],
            p.grant_bytes - s.grant_used[osc],
        )
        accepted = float(np.clip(nbytes, 0.0, max(room, 0.0)))
        s.dirty_bytes[osc] += accepted
        s.grant_used[osc] += accepted
        self._mix_randomness(WRITE, osc, accepted, randomness)
        s.ctr_req_count[WRITE, osc] += max(nbytes / max(req_size, 1.0), 1.0)
        s.ctr_req_bytes[WRITE, osc] += accepted
        # app-visible write completion == acceptance into the cache
        s.ctr_bytes_done[WRITE, osc] += accepted
        s.write_blocked[osc] = accepted < nbytes
        return accepted

    def _mix_randomness(self, op: int, osc: int, nbytes: float, r: float) -> None:
        s = self.state
        w = min(nbytes / (4 * 2**20), 1.0)
        s.randomness[op, osc] = (1 - 0.2 * w) * s.randomness[op, osc] + 0.2 * w * r

    # ------------------------------------------------------------------ #
    # the tick
    # ------------------------------------------------------------------ #
    def step(self, disturbance: Disturbance | None = None) -> None:
        # (1) workloads deposit demand (mutates state arrays in place) …
        for w in self._workloads:
            w.tick(self, self.params.tick)
        # … then the pure core advances every other phase
        self.state = engine_step(self.params, self.topo, self.state, None,
                                 disturbance=disturbance)

    def run(self, seconds: float) -> None:
        n = int(round(seconds / self.params.tick))
        for _ in range(n):
            self.step()
