"""Vectorized fluid engine for the simulated Lustre client I/O path.

State lives in flat numpy arrays indexed by *OSC id* (one OSC per
(client, OST) pair, exactly like Lustre's per-target Object Storage
Client interfaces).  Every tick advances all OSCs at once:

    1. workloads deposit demand           (closed-loop readers / writers)
    2. RPC formation                      (window batching + partial hold)
    3. dispatch                           (bounded by rpcs_in_flight)
    4. OST setup-server drain             (per-RPC fixed overhead + IOPS cap)
    5. bandwidth allocation               (OST bw fair share, NIC cap)
    6. completion + stats accounting

The two DIAL-tunable knobs are per-OSC arrays: ``window_pages``
(= Lustre ``osc.*.max_pages_per_rpc``) and ``rpcs_in_flight``
(= ``osc.*.max_rpcs_in_flight``).  Both take effect on the next tick,
mirroring Lustre's near-real-time application of these parameters (SII-B).

Model regimes (why the tuner has something to learn):

* throughput of one OSC pipeline  ~ in_flight * rpc_size / rpc_latency,
  capped by its fair share of OST bandwidth and by the OST IOPS ceiling;
* rpc_latency = setup(randomness) + rtt + transfer + (hold if the window
  was not filled) -- so a too-large window starves channels under sparse
  demand (the paper's SII-B motivation) while a too-small window wastes
  the IOPS budget under heavy demand;
* writes absorb into a dirty cache until grant/dirty limits bind, then the
  application throttles to the flush rate (Lustre grant mechanics, SIII-B).
"""

from __future__ import annotations

import dataclasses

import numpy as np

PAGE_SIZE = 4096  # bytes, Linux page

# Operation codes.
READ = 0
WRITE = 1


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Physical constants of the simulated cluster.

    Defaults are calibrated against the paper's CloudLab c6525-25g testbed
    (SIV-A): 4 OSS x 2 OST on SATA SSDs behind 25 GbE, which delivers
    single-client streams in the 300-460 MB/s range (paper Table II).
    """

    tick: float = 0.005                # simulation step [s]
    ost_bandwidth: float = 520e6       # per-OST service bandwidth [B/s]
    ost_setup_parallel: float = 4.0    # concurrent setup contexts per OST
    ost_iops: float = 2600.0           # per-OST RPC completions per second
    setup_time_seq: float = 300e-6     # fixed overhead per sequential RPC [s]
    setup_time_rand: float = 3.5e-3    # extra overhead for fully random RPC [s]
    rtt: float = 120e-6                # client<->OSS network round trip [s]
    nic_bandwidth: float = 2.9e9       # per-client NIC cap [B/s]
    hold_time_read: float = 0.012      # OSC holds a partial read RPC [s]
    hold_time_write: float = 0.025     # writes plug longer (write-behind)
    ost_buffer_bytes: float = 64 * 2**20  # OST service-queue comfort zone
    congestion_exp: float = 0.35       # service efficiency decay past buffer
    max_dirty_bytes: float = 64 * 2**20   # per-OSC dirty cache limit
    grant_bytes: float = 96 * 2**20       # per-OSC server grant
    readahead_bytes: float = 8 * 2**20 # client readahead pipeline depth
    max_rpc_queue: int = 4096          # formed-but-unsent RPC cap per OSC

    def setup_time(self, randomness: np.ndarray) -> np.ndarray:
        """Per-RPC fixed overhead as a function of access randomness in [0,1]."""
        return self.setup_time_seq + randomness * self.setup_time_rand

    def hold_time(self, op: int) -> float:
        return self.hold_time_read if op == READ else self.hold_time_write


class PFSSim:
    """Discrete-time simulator of clients -> OSC -> RPC -> OST.

    Construction wires a static topology; workloads attach to clients and
    drive demand each tick.  All mutable state is numpy arrays so a tick is
    a handful of vectorized ops regardless of OSC count.
    """

    def __init__(
        self,
        n_clients: int,
        n_osts: int,
        params: SimParams | None = None,
        seed: int = 0,
    ):
        self.params = params or SimParams()
        self.n_clients = n_clients
        self.n_osts = n_osts
        self.rng = np.random.default_rng(seed)
        self.now = 0.0
        self.tick_index = 0

        n = n_clients * n_osts  # one OSC per (client, ost), like Lustre LOV
        self.n_osc = n
        self.osc_client = np.repeat(np.arange(n_clients), n_osts)
        self.osc_ost = np.tile(np.arange(n_osts), n_clients)

        # --- tunable knobs (DIAL's theta), per OSC ------------------------
        self.window_pages = np.full(n, 256, dtype=np.int64)   # Lustre default 1 MiB
        self.rpcs_in_flight = np.full(n, 8, dtype=np.int64)   # Lustre default

        # --- per-OSC, per-op fluid state ----------------------------------
        self.pending = np.zeros((2, n))      # bytes not yet packed into RPCs
        self.hold_age = np.zeros((2, n))
        self.queue_rpcs = np.zeros((2, n))   # formed, waiting for a slot
        self.queue_bytes = np.zeros((2, n))
        self.active_rpcs = np.zeros((2, n))  # dispatched, in the pipeline
        self.setup_work = np.zeros((2, n))   # seconds of setup left (aggregate)
        self.unready_bytes = np.zeros((2, n))
        self.ready_bytes = np.zeros((2, n))  # setup done, transferring
        self.active_avg_size = np.full((2, n), float(PAGE_SIZE))
        self.dispatch_time_num = np.zeros((2, n))
        self.randomness = np.zeros((2, n))   # EMA of workload offset jumps
        # --- write path extras --------------------------------------------
        self.dirty_bytes = np.zeros(n)
        self.grant_used = np.zeros(n)
        self.write_blocked = np.zeros(n, dtype=bool)  # cache full last tick
        # --- cumulative counters (the "/proc" the client can probe) -------
        zeros2 = lambda: np.zeros((2, n))
        self.ctr_bytes_done = zeros2()
        self.ctr_rpcs_sent = zeros2()
        self.ctr_rpc_bytes = zeros2()
        self.ctr_partial_rpcs = zeros2()
        self.ctr_latency_sum = zeros2()
        self.ctr_rpcs_done = zeros2()
        self.ctr_req_count = zeros2()
        self.ctr_req_bytes = zeros2()
        self.ctr_cache_hit_bytes = np.zeros(n)
        self.ctr_block_time = np.zeros(n)
        self.ctr_pending_integral = zeros2()
        self.ctr_active_integral = zeros2()
        self.ctr_dirty_integral = np.zeros(n)
        self.ctr_grant_integral = np.zeros(n)

        self._workloads: list = []

    # ------------------------------------------------------------------ #
    # topology / knob helpers
    # ------------------------------------------------------------------ #
    def osc_id(self, client: int, ost: int) -> int:
        return client * self.n_osts + ost

    def client_oscs(self, client: int) -> np.ndarray:
        return np.arange(client * self.n_osts, (client + 1) * self.n_osts)

    def set_knobs(self, osc_ids, window_pages=None, rpcs_in_flight=None) -> None:
        """Apply DIAL's theta to one or more OSC interfaces (takes effect
        next tick, mirroring ``lctl set_param`` latency).

        Either knob may be a scalar (broadcast over ``osc_ids``) or an
        array aligned with ``osc_ids`` — the fleet agent applies a whole
        tick's decisions in one fancy-indexed assignment.
        """
        if window_pages is not None:
            self.window_pages[osc_ids] = np.asarray(window_pages, dtype=np.int64)
        if rpcs_in_flight is not None:
            self.rpcs_in_flight[osc_ids] = np.asarray(rpcs_in_flight, dtype=np.int64)

    def attach(self, workload) -> None:
        workload.bind(self)
        self._workloads.append(workload)

    # ------------------------------------------------------------------ #
    # demand entry points used by workloads
    # ------------------------------------------------------------------ #
    def submit_read(self, osc: int, nbytes: float, randomness: float,
                    req_size: float) -> float:
        """App issues read requests totalling ``nbytes``.  All bytes flow
        through the RPC pipeline (readahead hides latency in the workload's
        closed loop, it does not conjure bandwidth)."""
        self.pending[READ, osc] += nbytes
        self._mix_randomness(READ, osc, nbytes, randomness)
        self.ctr_req_count[READ, osc] += max(nbytes / max(req_size, 1.0), 1.0)
        self.ctr_req_bytes[READ, osc] += nbytes
        # observable proxy for llite readahead hit counters
        self.ctr_cache_hit_bytes[osc] += (1.0 - randomness) * nbytes
        return nbytes

    def submit_write(self, osc: int, nbytes: float, randomness: float,
                     req_size: float) -> float:
        """App writes ``nbytes``; bytes land in the dirty cache if grant and
        dirty limits allow, else the writer blocks (accepted < nbytes)."""
        p = self.params
        room = min(
            p.max_dirty_bytes - self.dirty_bytes[osc],
            p.grant_bytes - self.grant_used[osc],
        )
        accepted = float(np.clip(nbytes, 0.0, max(room, 0.0)))
        self.dirty_bytes[osc] += accepted
        self.grant_used[osc] += accepted
        self._mix_randomness(WRITE, osc, accepted, randomness)
        self.ctr_req_count[WRITE, osc] += max(nbytes / max(req_size, 1.0), 1.0)
        self.ctr_req_bytes[WRITE, osc] += accepted
        # app-visible write completion == acceptance into the cache
        self.ctr_bytes_done[WRITE, osc] += accepted
        self.write_blocked[osc] = accepted < nbytes
        return accepted

    def _mix_randomness(self, op: int, osc: int, nbytes: float, r: float) -> None:
        w = min(nbytes / (4 * 2**20), 1.0)
        self.randomness[op, osc] = (1 - 0.2 * w) * self.randomness[op, osc] + 0.2 * w * r

    # ------------------------------------------------------------------ #
    # the tick
    # ------------------------------------------------------------------ #
    def step(self) -> None:
        p = self.params
        dt = p.tick

        # (1) workloads deposit demand
        for w in self._workloads:
            w.tick(self, dt)

        # write path: dirty cache continuously feeds the pending queue
        in_pipe = (self.pending[WRITE] + self.queue_bytes[WRITE]
                   + self.unready_bytes[WRITE] + self.ready_bytes[WRITE])
        self.pending[WRITE] += np.maximum(self.dirty_bytes - in_pipe, 0.0)

        # (2) RPC formation: full windows pack immediately; partials wait
        # up to hold_time hoping more data shows up (Lustre plugging).
        win_bytes = (self.window_pages * PAGE_SIZE).astype(float)
        for op in (READ, WRITE):
            pend = self.pending[op]
            room = np.maximum(p.max_rpc_queue - self.queue_rpcs[op], 0.0)
            n_full = np.minimum(np.floor(pend / win_bytes), room)
            full_bytes = n_full * win_bytes
            self.queue_rpcs[op] += n_full
            self.queue_bytes[op] += full_bytes
            pend = pend - full_bytes
            self.hold_age[op] = np.where(pend > 0, self.hold_age[op] + dt, 0.0)
            expire = (pend > 0) & (self.hold_age[op] >= p.hold_time(op)) & (room > n_full)
            self.queue_rpcs[op] += expire
            self.queue_bytes[op] += np.where(expire, pend, 0.0)
            self.ctr_partial_rpcs[op] += expire
            self.pending[op] = np.where(expire, 0.0, pend)
            self.hold_age[op] = np.where(expire, 0.0, self.hold_age[op])

        # (3) dispatch up to rpcs_in_flight (reads first: sync-read bias)
        slots = np.maximum(
            self.rpcs_in_flight - (self.active_rpcs[READ] + self.active_rpcs[WRITE]),
            0.0,
        )
        for op in (READ, WRITE):
            take = np.minimum(self.queue_rpcs[op], slots)
            frac = np.divide(take, self.queue_rpcs[op],
                             out=np.zeros_like(take), where=self.queue_rpcs[op] > 0)
            bytes_out = self.queue_bytes[op] * frac
            self.queue_rpcs[op] -= take
            self.queue_bytes[op] -= bytes_out
            slots = slots - take
            self.active_rpcs[op] += take
            per_rpc = p.setup_time(self.randomness[op]) + p.rtt
            self.setup_work[op] += take * per_rpc
            self.unready_bytes[op] += bytes_out
            tot_bytes = self.unready_bytes[op] + self.ready_bytes[op]
            self.active_avg_size[op] = np.where(
                self.active_rpcs[op] > 0,
                tot_bytes / np.maximum(self.active_rpcs[op], 1e-9),
                self.active_avg_size[op])
            self.ctr_rpcs_sent[op] += take
            self.ctr_rpc_bytes[op] += bytes_out
            self.dispatch_time_num[op] += take * self.now

        # (4) OST setup service: `ost_setup_parallel` concurrent contexts
        # drain setup work; a separate IOPS ceiling caps completed setups.
        total_work = self.setup_work[READ] + self.setup_work[WRITE]
        ost_work = np.bincount(self.osc_ost, weights=total_work, minlength=self.n_osts)
        cap = dt * p.ost_setup_parallel
        drain_frac_ost = np.divide(cap, ost_work,
                                   out=np.ones(self.n_osts), where=ost_work > cap)
        # IOPS ceiling, applied on setups completed this tick per OST
        for op in (READ, WRITE):
            work = self.setup_work[op]
            drained = work * drain_frac_ost[self.osc_ost]
            per_rpc = p.setup_time(self.randomness[op]) + p.rtt
            setups_done = np.divide(drained, per_rpc,
                                    out=np.zeros_like(drained), where=per_rpc > 0)
            ost_setups = np.bincount(self.osc_ost, weights=setups_done,
                                     minlength=self.n_osts)
            iops_cap = p.ost_iops * dt
            iops_frac = np.divide(iops_cap, ost_setups, out=np.ones(self.n_osts),
                                  where=ost_setups > iops_cap)
            effective = drained * iops_frac[self.osc_ost]
            self.setup_work[op] = work - effective
            ready = np.minimum(
                np.divide(effective, per_rpc, out=np.zeros_like(effective),
                          where=per_rpc > 0) * self.active_avg_size[op],
                self.unready_bytes[op])
            ready = np.where(self.setup_work[op] <= 1e-12, self.unready_bytes[op], ready)
            self.unready_bytes[op] -= ready
            self.ready_bytes[op] += ready

        # (5) bandwidth: OST bw fair-shared over transfer-phase RPC counts,
        # then per-client NIC cap rescales.  An OST whose service queue
        # holds far more bytes than its buffer comfort zone degrades
        # (cache thrash / request-queue overhead) -- this is the cost of
        # everyone maxing rpcs_in_flight x window at once, and the reason
        # decentralized agents must moderate under contention.
        want = self.ready_bytes[READ] + self.ready_bytes[WRITE]
        queued = (self.unready_bytes[READ] + self.unready_bytes[WRITE]
                  + self.ready_bytes[READ] + self.ready_bytes[WRITE])
        ost_queued = np.bincount(self.osc_ost, weights=queued, minlength=self.n_osts)
        over = ost_queued > p.ost_buffer_bytes
        eff = np.where(
            over,
            np.power(p.ost_buffer_bytes / np.maximum(ost_queued, 1.0),
                     p.congestion_exp),
            1.0,
        )
        active_transfer = np.where(want > 0,
                                   self.active_rpcs[READ] + self.active_rpcs[WRITE], 0.0)
        ost_shares = np.bincount(self.osc_ost, weights=active_transfer,
                                 minlength=self.n_osts)
        share = np.divide(active_transfer, ost_shares[self.osc_ost],
                          out=np.zeros_like(active_transfer),
                          where=ost_shares[self.osc_ost] > 0)
        ost_bw_eff = p.ost_bandwidth * eff
        alloc = np.minimum(share * ost_bw_eff[self.osc_ost] * dt, want)
        # redistribute leftover OST bandwidth to still-hungry OSCs
        leftover = ost_bw_eff * dt - np.bincount(
            self.osc_ost, weights=alloc, minlength=self.n_osts)
        hungry = want - alloc
        ost_hungry = np.bincount(self.osc_ost, weights=hungry, minlength=self.n_osts)
        bonus_frac = np.divide(leftover, ost_hungry, out=np.zeros(self.n_osts),
                               where=ost_hungry > 0)
        alloc = alloc + hungry * np.minimum(bonus_frac[self.osc_ost], 1.0)
        # NIC cap per client
        client_alloc = np.bincount(self.osc_client, weights=alloc,
                                   minlength=self.n_clients)
        nic_frac = np.divide(p.nic_bandwidth * dt, client_alloc,
                             out=np.ones(self.n_clients),
                             where=client_alloc > p.nic_bandwidth * dt)
        alloc = alloc * nic_frac[self.osc_client]

        # (6) completions
        for op in (READ, WRITE):
            frac = np.divide(self.ready_bytes[op], want,
                             out=np.zeros_like(want), where=want > 0)
            drained = alloc * frac
            self.ready_bytes[op] -= drained
            avg = np.maximum(self.active_avg_size[op], 1.0)
            done_rpcs = np.minimum(np.divide(drained, avg), self.active_rpcs[op])
            inflight_bytes = self.unready_bytes[op] + self.ready_bytes[op]
            done_rpcs = np.where(inflight_bytes <= 1e-9, self.active_rpcs[op], done_rpcs)
            prev_active = self.active_rpcs[op].copy()
            self.active_rpcs[op] -= done_rpcs
            self.ctr_rpcs_done[op] += done_rpcs
            if op == READ:
                self.ctr_bytes_done[READ] += drained
            else:
                # flushed bytes leave the dirty cache and release grant
                self.dirty_bytes = np.maximum(self.dirty_bytes - drained, 0.0)
                self.grant_used = np.maximum(self.grant_used - drained, 0.0)
            avg_disp = np.divide(self.dispatch_time_num[op], np.maximum(prev_active, 1e-9))
            lat = np.maximum(self.now + dt - avg_disp, dt)
            self.ctr_latency_sum[op] += done_rpcs * lat
            keep = np.divide(self.active_rpcs[op], np.maximum(prev_active, 1e-9))
            self.dispatch_time_num[op] *= keep

        # blocked-writer accounting (workloads stop issuing while blocked)
        self.ctr_block_time += self.write_blocked * dt
        room = np.minimum(p.max_dirty_bytes - self.dirty_bytes,
                          p.grant_bytes - self.grant_used)
        self.write_blocked &= room < PAGE_SIZE

        # time-integrals for interval averages
        for op in (READ, WRITE):
            self.ctr_pending_integral[op] += (self.pending[op] + self.queue_bytes[op]) * dt
            self.ctr_active_integral[op] += self.active_rpcs[op] * dt
        self.ctr_dirty_integral += self.dirty_bytes * dt
        self.ctr_grant_integral += self.grant_used * dt

        self.now += dt
        self.tick_index += 1

    def run(self, seconds: float) -> None:
        n = int(round(seconds / self.params.tick))
        for _ in range(n):
            self.step()
