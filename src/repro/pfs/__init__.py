"""Simulated Lustre-like parallel file system substrate.

This package provides a deterministic, seedable, discrete-time queueing
model of the Lustre client I/O path described in the DIAL paper (SII):

    application --> LLITE --> LOV --> OSC (per OST) --> RPC --> OST

The model is intentionally *fluid* (aggregate counters per tick rather than
per-RPC event objects) so that offline training-data collection — thousands
of simulated seconds across many workload x contention scenarios — runs in
seconds on one CPU core while still reproducing the qualitative regimes the
paper's tuner exploits:

* per-RPC fixed overhead (setup + RTT) makes *large* RPC windows win for
  large sequential streams (bandwidth-bound);
* the OSC *holds* partially-filled RPCs hoping to fill the window, so an
  oversized window under small/random I/O starves the RPC channels
  (the paper's SII-B motivation);
* an OST-side setup server (IOPS ceiling) makes many tiny RPCs waste
  service capacity;
* shared OST bandwidth + per-client NIC caps create cross-client
  contention, so the optimum (window, in-flight) shifts with global load —
  the signal DIAL senses through purely local metrics;
* the write path adds grants and a dirty-page cache: writes complete into
  the cache until it fills, then the app throttles to the flush rate.

The engine is layered (see docs/ARCHITECTURE.md):

    state layer      repro.pfs.state      SimState pytree + pure engine_step
    workload layer   repro.pfs.workloads  Workload objects + WorkloadTable
    execution layer  repro.pfs.engine     stateful numpy wrapper (PFSSim)
                     repro.pfs.engine_jax fused lax.scan interval path

Public API:
    SimParams, PFSSim          -- stateful wrapper (repro.pfs.engine)
    SimTopo, SimState, engine_step -- pure core (repro.pfs.state)
    Workload + generators      -- repro.pfs.workloads
    WorkloadTable              -- vectorized fleet demand (same module)
    OSCStats snapshots         -- repro.pfs.stats
    TUNABLE knobs              -- window_pages / rpcs_in_flight per OSC
"""

from repro.pfs.engine import PFSSim, SimParams, PAGE_SIZE
from repro.pfs.state import (Disturbance, SimState, SimTopo, engine_step,
                             init_state)
from repro.pfs.workloads import (
    Workload,
    WorkloadTable,
    sequential_stream,
    random_stream,
    strided_stream,
    vpic_write,
    bdcats_read,
    dlio_reader,
    table_from_sim,
)
from repro.pfs.stats import OSCStats

__all__ = [
    "PFSSim",
    "SimParams",
    "SimTopo",
    "SimState",
    "Disturbance",
    "engine_step",
    "init_state",
    "PAGE_SIZE",
    "Workload",
    "WorkloadTable",
    "table_from_sim",
    "sequential_stream",
    "random_stream",
    "strided_stream",
    "vpic_write",
    "bdcats_read",
    "dlio_reader",
    "OSCStats",
]
