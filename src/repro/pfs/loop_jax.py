"""Device-resident DIAL decision loop: one ``jit`` per tuning *run*.

The host loop (:func:`repro.core.fleet.run_fleet`) pays one device
round trip per tuning interval: the jitted engine scan stops, the whole
``SimState`` converts to numpy, the fleet agent differences/featurizes
on the host, scores with one more jitted launch, runs Algorithm 1 in
numpy, and re-uploads the knobs.  Per paper Table III the decision path
itself budgets 10-13.5 ms per interface — cheap — so at fleet scale the
loop is dominated by dispatch and transfer, not compute.

This module folds the *entire* closed loop into one compiled program:

    lax.scan over intervals
      └─ lax.scan over ticks      demand_step ∘ engine_step_jax
      └─ probe                    counters read straight off SimState
      └─ snapshot                 :func:`repro.core.metrics.snapshot_arrays`
                                  (the literal oracle arithmetic, xp=jnp)
      └─ features                 history ‖ θ ‖ Δθ, float64 → float32
                                  (same rounding as the host matrix)
      └─ forest scoring           :func:`paired_forest_margin_ref` — both
                                  ops, all interfaces × configs, one pass
      └─ Algorithm 1              :func:`repro.core.tuner.score_greedy_arrays`
                                  (the literal oracle reductions, xp=jnp)
      └─ gating + write-back      volume/steadiness masks, knob update on
                                  the in-scan ``SimState``

so ``N`` intervals of engine + tuning execute as a single jitted
dispatch (:class:`FusedLoop`), and a whole batch of scenarios vmaps over
it (``batched=True``), each element carrying its own precompiled
disturbance schedule — no per-interval ``make_schedule`` rebuild.

Equivalence: the loop is pinned against the (bug-fixed)
:class:`~repro.core.fleet.FleetAgent` oracle — identical knob
trajectories (θ exact) and probe counters (≤1e-6 relative, observed
~1e-15) over multi-interval mixed-workload scenarios on both engine
backends (tests/test_loop_fused.py).

Scale-out: ``mesh=`` shards the batch axis of a ``batched=True`` loop
over a 1-D device mesh with ``shard_map`` (axis
:data:`repro.distributed.sharding.FLEET_AXIS`).  Because every DIAL
decision reads only its own interface's local counters, the per-shard
programs are fully independent — no collectives anywhere in the scanned
body — so the sharded program is the vmapped program split across
devices, and θ trajectories stay *exactly* equal to the single-device
run (tests/test_shard.py).  ``SimState``/``WorkloadState`` buffers are
donated into the dispatch (``donate_argnums``) so a fleet's state is
held once, not twice, at peak.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.config_space import SPACE, ConfigSpace
from repro.distributed.sharding import pad_fleet, unpad_fleet
from repro.obs.schema import TraceConfig, timeline_tap
from repro.obs.timers import PhaseTimers
from repro.core.metrics import (N_READ, N_WRITE, READ_KNOB_IDX,
                                WRITE_KNOB_IDX, snapshot_arrays)
from repro.core.model import DIALModel
from repro.core.tuner import TunerParams, score_greedy_arrays
from repro.kernels.gbdt_forest.ref import paired_forest_margin_ref
from repro.kernels.segment_reduce.ops import make_segment_sum
from repro.pfs.engine_jax import engine_step_jax
from repro.pfs.state import (READ, WRITE, Disturbance, SimParams, SimState,
                             SimTopo)
from repro.pfs.workloads import WorkloadState, WorkloadTable


class Intervention(NamedTuple):
    """Per-interface counterfactual knobs for one fused run.

    The diagnosis engine (:mod:`repro.obs.diagnose`) re-runs a scenario
    under these interventions and diffs the resulting traces against
    the factual run.  They ride the same mechanism as the trace taps:
    one extra run-constant input pytree on :meth:`FusedLoop.run`, with
    the unintervened graph (``intervene=None``) literally unchanged.
    Every field is built from ``jnp.where``/boolean masks whose neutral
    values are exact identities, so an *all-neutral* intervention
    reproduces the factual run bit-for-bit (θ exact, counters ≤1e-6 —
    tests/test_diagnose.py pins this on the fused, batched, and
    sharded paths).

    ``pin_mask``/``pin_theta``  after every interval's write-back the
                                interface's knobs are forced to
                                ``pin_theta`` — the best-static-oracle
                                replay (pin from t=0 by also building
                                the scenario with ``initial_theta`` =
                                the pin).  The decision graph still
                                runs, so the trace shows what DIAL
                                *would* have chosen on the pinned
                                trajectory.
    ``force_gates``             the volume and steadiness gates are
                                treated as open (warmup and the tune
                                mask still apply) — decisions that were
                                gate-blocked in the factual run fire.
    ``freeze``                  decisions are never applied (θ holds at
                                its initial value) — DIAL's own knob
                                churn, its in-loop exploration, zeroed.

    Shapes: ``(n,)`` bool masks and ``(n, 2)`` pinned knobs per
    interface; batched loops take a leading batch axis like every other
    per-element input.
    """

    pin_mask: np.ndarray
    pin_theta: np.ndarray
    force_gates: np.ndarray
    freeze: np.ndarray

    @classmethod
    def neutral(cls, n: int, batch: int | None = None) -> "Intervention":
        """The do-nothing intervention (the bit-neutrality arm)."""
        lead = (n,) if batch is None else (int(batch), n)
        return cls(pin_mask=np.zeros(lead, dtype=bool),
                   pin_theta=np.zeros(lead + (2,), dtype=np.int64),
                   force_gates=np.zeros(lead, dtype=bool),
                   freeze=np.zeros(lead, dtype=bool))

    @classmethod
    def pin(cls, n: int, theta, batch: int | None = None) -> "Intervention":
        """Pin every interface to ``theta = (window_pages, rpcs)``."""
        iv = cls.neutral(n, batch=batch)
        return iv._replace(
            pin_mask=np.ones_like(iv.pin_mask),
            pin_theta=np.broadcast_to(
                np.asarray(theta, dtype=np.int64),
                iv.pin_theta.shape).copy())

    @classmethod
    def gates_open(cls, n: int, batch: int | None = None) -> "Intervention":
        iv = cls.neutral(n, batch=batch)
        return iv._replace(force_gates=np.ones_like(iv.force_gates))

    @classmethod
    def freeze_theta(cls, n: int,
                     batch: int | None = None) -> "Intervention":
        iv = cls.neutral(n, batch=batch)
        return iv._replace(freeze=np.ones_like(iv.freeze))


class Probe(NamedTuple):
    """Cumulative counters the decision loop reads off ``SimState``.

    Field names mirror :class:`repro.pfs.stats.FleetStats` so
    :func:`repro.core.metrics.snapshot_arrays` consumes either — a probe
    here is zero-copy views of the in-scan state, not a host transfer.
    """

    t: jnp.ndarray
    bytes_done: jnp.ndarray
    rpcs_sent: jnp.ndarray
    rpc_bytes: jnp.ndarray
    partial_rpcs: jnp.ndarray
    latency_sum: jnp.ndarray
    rpcs_done: jnp.ndarray
    req_count: jnp.ndarray
    req_bytes: jnp.ndarray
    pending_integral: jnp.ndarray
    active_integral: jnp.ndarray
    cache_hit_bytes: jnp.ndarray
    block_time: jnp.ndarray
    dirty_integral: jnp.ndarray
    grant_integral: jnp.ndarray
    randomness: jnp.ndarray
    window_pages: jnp.ndarray
    rpcs_in_flight: jnp.ndarray


def probe_state(state: SimState) -> Probe:
    """The fleet probe as views of the (possibly traced) state arrays."""
    return Probe(
        t=state.now,
        bytes_done=state.ctr_bytes_done,
        rpcs_sent=state.ctr_rpcs_sent,
        rpc_bytes=state.ctr_rpc_bytes,
        partial_rpcs=state.ctr_partial_rpcs,
        latency_sum=state.ctr_latency_sum,
        rpcs_done=state.ctr_rpcs_done,
        req_count=state.ctr_req_count,
        req_bytes=state.ctr_req_bytes,
        pending_integral=state.ctr_pending_integral,
        active_integral=state.ctr_active_integral,
        cache_hit_bytes=state.ctr_cache_hit_bytes,
        block_time=state.ctr_block_time,
        dirty_integral=state.ctr_dirty_integral,
        grant_integral=state.ctr_grant_integral,
        randomness=state.randomness,
        window_pages=state.window_pages,
        rpcs_in_flight=state.rpcs_in_flight,
    )


def conditional_score_greedy_jnp(probs, ops, current,
                                 space: ConfigSpace = SPACE,
                                 params: TunerParams | None = None):
    """Batched Algorithm 1 on the JAX backend (the fused-loop tuner).

    Same signature shape as
    :func:`repro.core.tuner.conditional_score_greedy_batch`; returns
    numpy ``(theta, changed, n_candidates, score)``.  Exists mainly so
    the property tests can pin the in-``jit`` Algorithm 1 against both
    the scalar and the batched numpy oracles on adversarial rows.
    """
    params = params if params is not None else TunerParams()
    with enable_x64():
        out = score_greedy_arrays(
            jnp.asarray(probs, dtype=jnp.float64),
            jnp.asarray(ops),
            jnp.asarray(current),
            jnp.asarray(space.as_array()),
            params, xp=jnp)
        return tuple(np.asarray(x) for x in out)


@dataclasses.dataclass
class FusedLoopResult:
    """Everything one fused run produced, already back on the host.

    ``decisions`` carries one :class:`~repro.core.fleet.FleetTickResult`
    per interval (empty results for gated intervals), aligned with
    interval indices exactly like the bug-fixed
    :attr:`FleetAgent.decisions` — so every trajectory consumer works on
    either path unchanged.
    """

    state: SimState
    wstate: WorkloadState
    trace: dict | None
    decisions: list
    # final (k+1)-deep snapshot history (read/write matrices + volumes)
    # and the interval length — what a host agent needs to continue
    # ticking after the fused run without re-warming (None when untuned)
    hist: tuple | None = None
    interval_seconds: float = 0.0
    n_run: int = 0

    @property
    def n_intervals(self) -> int:
        return len(self.decisions) if self.decisions else self.n_run


def decisions_from_trace(trace: dict) -> list:
    """Host-side per-interval decision records from a fused trace.

    Batched traces (leaves ``(B, N, ...)``) flatten the batch axis into
    fleet columns ``b * n + osc`` — the same layout
    :class:`~repro.lab.batch.BatchPort` exposes to the host agent.
    """
    from repro.core.fleet import FleetTickResult
    from repro.core.tuner import FleetDecisions

    if np.asarray(trace["decided"]).ndim == 3:  # (B, N, n) -> (N, B*n)
        def flat(x):
            x = np.moveaxis(np.asarray(x), 0, 1)
            return x.reshape(x.shape[0], -1, *x.shape[3:])
    else:
        flat = np.asarray
    decided = flat(trace["decided"])
    ops = flat(trace["ops"])
    theta = flat(trace["theta"])
    changed = flat(trace["changed"])
    n_cand = flat(trace["n_candidates"])
    score = flat(trace["score"])
    probs = flat(trace["probs"])

    out = []
    for i in range(decided.shape[0]):
        rows = np.nonzero(decided[i])[0]
        out.append(FleetTickResult(
            oscs=rows.astype(np.int64),
            ops=ops[i][rows].astype(np.int64),
            decisions=FleetDecisions(
                theta=theta[i][rows].astype(np.int64),
                changed=changed[i][rows].astype(bool),
                n_candidates=n_cand[i][rows].astype(np.int64),
                score=score[i][rows].astype(np.float64),
                probs=probs[i][rows].astype(np.float64))))
    return out


class FusedLoop:
    """N intervals of engine + DIAL tuning per jitted dispatch.

    One instance compiles one ``(topology, table structure, steps,
    n_intervals)`` signature; repeated :meth:`run` calls with the same
    shapes reuse the compiled program.  ``batched=True`` vmaps the whole
    loop over a leading batch axis on table/state/wstate/schedule/mask
    (the scenario-lab fan-out); the forests and tuner constants are
    closed over unbatched.  ``mesh=`` additionally shards that batch
    axis across a 1-D device mesh — the forests stay closed-over
    (replicated to every device by jit), each shard runs its slice of
    the fleet with zero cross-device communication, and :meth:`run`
    pads a non-divisible batch with masked phantom elements it strips
    from every output.

    Decentralization is untouched: every interface's decision still
    reads only that interface's local counters — the fusion is an
    execution strategy, exactly like :class:`~repro.core.fleet.FleetAgent`.
    """

    def __init__(self, params: SimParams, topo: SimTopo,
                 steps_per_interval: int, model: DIALModel | None,
                 space: ConfigSpace = SPACE,
                 tuner_params: TunerParams | None = None,
                 k: int = 1,
                 min_volume_bytes: float = 256 * 1024,
                 warmup_intervals: int = 2,
                 seg_backend: str = "auto",
                 batched: bool = False,
                 tuned: bool = True,
                 mesh: Mesh | None = None,
                 trace: TraceConfig | None = None):
        self.params = params
        self.topo = topo
        self.steps = int(steps_per_interval)
        self.space = space
        self.tuner_params = (tuner_params if tuner_params is not None
                             else TunerParams())
        self.k = int(k)
        self.min_volume = float(min_volume_bytes)
        self.warmup = int(warmup_intervals)
        self.batched = bool(batched)
        self.mesh = mesh
        # opt-in telemetry: None compiles the exact untraced graph (the
        # branch below is taken at trace time, so an untraced loop pays
        # literally nothing); a TraceConfig adds scan *outputs* only —
        # the decision arithmetic is shared, never forked
        self.trace_config = trace
        self.timers = PhaseTimers()
        if mesh is not None and not self.batched:
            raise ValueError("mesh sharding needs batched=True — the "
                             "fleet axis being sharded *is* the batch "
                             "axis")
        if mesh is not None and len(mesh.axis_names) != 1:
            raise ValueError(f"FusedLoop shards one batch axis; got a "
                             f"{len(mesh.axis_names)}-D mesh "
                             f"{mesh.axis_names} (want fleet_mesh())")
        # tuned=False compiles the lean engine-only run (no decision
        # graph at all) — used for the untuned elements of a split batch,
        # where paying featurize/forest/Algorithm-1 per element would
        # waste most of the dispatch (e.g. the 24 static arms of an
        # evaluate comparison)
        self.tuned = bool(tuned)
        if self.tuned and model is None:
            raise ValueError("a tuned FusedLoop needs a model")
        segsum = make_segment_sum(seg_backend)

        n = topo.n_osc
        m = len(space)
        if self.tuned:
            feature, threshold, leaf, base, depth, n_features = \
                model.paired_arrays()
            with enable_x64():   # constants must keep f64 (oracle parity)
                feature = jnp.asarray(feature)
                threshold = jnp.asarray(threshold)
                leaf = jnp.asarray(leaf)
                base = jnp.asarray(base)
                theta_raw = jnp.asarray(space.as_array())        # f64
                theta_feats = jnp.asarray(space.as_features())   # log2
            kp1 = self.k + 1
            dim_r = N_READ * kp1 + 4
            dim_w = N_WRITE * kp1 + 4
            if n_features < max(dim_r, dim_w):
                raise ValueError(
                    f"model expects {n_features} features but k={self.k} "
                    f"histories need {max(dim_r, dim_w)} — model trained "
                    f"with a different history length?")
        else:
            kp1 = self.k + 1
        tp = self.tuner_params
        warm_from = self.warmup + self.k + 1   # first deciding interval
        pfsp, pfst = params, topo

        def features(hist, n_feat, knob_idx):
            """(k+1, n, N) history -> (n*M, dim) float32, host layout."""
            h2 = jnp.moveaxis(hist, 0, 1).reshape(n, kp1 * n_feat)
            cur = h2[:, [self.k * n_feat + knob_idx[0],
                         self.k * n_feat + knob_idx[1]]]      # (n, 2)
            x64 = jnp.concatenate([
                jnp.broadcast_to(h2[:, None, :], (n, m, h2.shape[1])),
                jnp.broadcast_to(theta_feats[None], (n, m, 2)),
                theta_feats[None] - cur[:, None, :],
            ], axis=2)
            # float64 -> float32 exactly where the host path stores into
            # its float32 matrix (same rounding, same bits)
            return x64.astype(jnp.float32).reshape(n * m, -1)

        tcfg = self.trace_config
        tap_timeline = tcfg is not None and tcfg.timeline \
            and self.steps >= tcfg.stride

        def tick_body(table):
            def body(carry, dist):
                st, ws = carry
                demand, ws = table.demand_step(pfsp, ws, st,
                                               xp=jnp, segsum=segsum)
                st = engine_step_jax(pfsp, pfst, st, demand, segsum,
                                     disturbance=dist)
                return (st, ws), None
            return body

        def run_ticks(table, state, wstate, dist):
            """One interval of engine ticks -> (state, wstate, taps).

            Untraced: the original single scan over ``steps`` ticks —
            byte-identical graph.  Traced with timeline: the same tick
            body scanned in ``stride``-tick chunks, one
            :func:`timeline_tap` per chunk boundary as scan output (so
            the tap compute is paid once per ``stride`` ticks, not per
            tick, and vmap/shard_map stack it like any other ys).
            """
            body = tick_body(table)
            if not tap_timeline:
                (state, wstate), _ = jax.lax.scan(
                    body, (state, wstate), dist, length=self.steps)
                return state, wstate, None
            stride = tcfg.stride
            n_chunks = self.steps // stride

            def chunk(carry, dch):
                carry, _ = jax.lax.scan(body, carry, dch, length=stride)
                st, _ = carry
                tap = timeline_tap(pfsp, pfst, st,
                                   jax.tree.map(lambda a: a[-1], dch),
                                   xp=jnp, segsum=segsum)
                return carry, tap

            dmain = jax.tree.map(
                lambda a: a[:n_chunks * stride].reshape(
                    (n_chunks, stride) + a.shape[1:]), dist)
            (state, wstate), taps = jax.lax.scan(
                chunk, (state, wstate), dmain, length=n_chunks)
            rem = self.steps - n_chunks * stride
            if rem:
                drem = jax.tree.map(lambda a: a[n_chunks * stride:], dist)
                (state, wstate), _ = jax.lax.scan(
                    body, (state, wstate), drem, length=rem)
            return state, wstate, taps

        def run_untuned(table, state, wstate, sched):
            def interval(carry, dist):
                st, ws = carry
                st, ws, taps = run_ticks(table, st, ws, dist)
                if tcfg is None:
                    return (st, ws), None
                ys = {"t": st.now}
                if taps is not None:
                    ys["timeline"] = taps
                return (st, ws), ys
            (state, wstate), trace = jax.lax.scan(
                interval, (state, wstate), sched)
            if tcfg is None:
                return state, wstate
            return state, wstate, trace

        def run(table, state, wstate, sched, tune_mask, iv=None):
            hist0 = (jnp.zeros((kp1, n, N_READ)),
                     jnp.zeros((kp1, n, N_WRITE)),
                     jnp.zeros((kp1, n)), jnp.zeros((kp1, n)))

            def interval(carry, dist):
                state, wstate, prev, hist, tick = carry
                state, wstate, taps = run_ticks(table, state, wstate, dist)

                # probe + snapshot: the oracle arithmetic, on device
                cur = probe_state(state)
                _, snap_r, snap_w, vol_r, vol_w = snapshot_arrays(
                    prev, cur, xp=jnp)
                hr, hw, hrv, hwv = hist
                hist = (jnp.concatenate([hr[1:], snap_r[None]]),
                        jnp.concatenate([hw[1:], snap_w[None]]),
                        jnp.concatenate([hrv[1:], vol_r[None]]),
                        jnp.concatenate([hwv[1:], vol_w[None]]))
                hr, hw, hrv, hwv = hist
                tick = tick + 1

                # gating masks (same predicates as FleetAgent.tick)
                ops = jnp.where(vol_r >= vol_w, READ, WRITE)
                active = jnp.maximum(vol_r, vol_w) >= self.min_volume
                v0 = jnp.where(ops == READ, hrv[0], hwv[0])
                v1 = jnp.where(ops == READ, vol_r, vol_w)
                ratio = v1 / jnp.maximum(v0, 1.0)
                steady = (ratio >= 0.5) & (ratio <= 2.0)
                warm = tick >= warm_from
                # interventions (iv) are a trace-time branch: iv=None
                # compiles the exact unintervened graph, and the
                # neutral intervention is an arithmetic identity (all
                # masks False) — counterfactual replays stay diffable
                # row-for-row against the factual run
                gate_ok = active & steady
                if iv is not None:
                    gate_ok = gate_ok | iv.force_gates
                decide = gate_ok & warm & tune_mask

                # features + one fused paired-forest pass for all rows
                x_r = features(hr, N_READ, READ_KNOB_IDX)
                x_w = features(hw, N_WRITE, WRITE_KNOB_IDX)
                x_r = jnp.pad(x_r, ((0, 0), (0, n_features - dim_r)))
                x_w = jnp.pad(x_w, ((0, 0), (0, n_features - dim_w)))
                op_rows = jnp.repeat(ops, m)
                x = jnp.where((op_rows == READ)[:, None], x_r, x_w)
                margin = paired_forest_margin_ref(
                    x, op_rows, feature, threshold, leaf, base, depth)
                p32 = 1.0 / (1.0 + jnp.exp(-jnp.clip(margin, -30.0, 30.0)))
                probs = p32.astype(jnp.float64).reshape(n, m)

                # Algorithm 1 (the oracle reductions) + knob write-back;
                # `current` comes from the probe itself, never a shadow
                cur_theta = jnp.stack([state.window_pages,
                                       state.rpcs_in_flight], axis=1)
                theta, changed, n_cand, score = score_greedy_arrays(
                    probs, ops, cur_theta, theta_raw, tp, xp=jnp)
                apply = decide & changed
                if iv is not None:
                    apply = apply & ~iv.freeze
                new_wp = jnp.where(apply, theta[:, 0], state.window_pages)
                new_rf = jnp.where(apply, theta[:, 1],
                                   state.rpcs_in_flight)
                if iv is not None:
                    new_wp = jnp.where(iv.pin_mask, iv.pin_theta[:, 0],
                                       new_wp)
                    new_rf = jnp.where(iv.pin_mask, iv.pin_theta[:, 1],
                                       new_rf)
                state = dataclasses.replace(
                    state, window_pages=new_wp, rpcs_in_flight=new_rf)

                ys = {"decided": decide, "ops": ops, "theta": theta,
                      "changed": changed, "n_candidates": n_cand,
                      "score": score, "probs": probs}
                if tcfg is not None:
                    # provenance extras: every value already exists in
                    # the decision graph — tracing adds outputs, never
                    # arithmetic (bit-neutrality, tests/test_obs.py)
                    ys.update({"t": state.now, "vol_r": vol_r,
                               "vol_w": vol_w, "active": active,
                               "steady": steady, "warm": warm,
                               "ratio": ratio, "cur_theta": cur_theta})
                    if taps is not None:
                        ys["timeline"] = taps
                return (state, wstate, cur, hist, tick), ys

            carry0 = (state, wstate, probe_state(state), hist0,
                      jnp.asarray(0, dtype=jnp.int64))
            (state, wstate, _, hist, _), trace = jax.lax.scan(
                interval, carry0, sched)
            return state, wstate, trace, hist

        fn = run if self.tuned else run_untuned
        if self.batched:
            fn = jax.vmap(fn)
        self._fn = fn
        # per-arity jitted programs: the tuned loop optionally takes an
        # Intervention pytree as a sixth argument (counterfactual
        # replays, repro.obs.diagnose); shard_map needs one in_spec per
        # call-time argument, so the wrapped callable is built per arity
        # and cached.  donate state + wstate: the engine consumes its
        # own previous state, so at fleet scale keeping the input alive
        # across the dispatch would double peak device memory for no
        # reader.
        self._jits: dict = {}
        self._run = self._get_run(5 if self.tuned else 4)

    def _get_run(self, n_args: int):
        if n_args not in self._jits:
            fn = self._fn
            if self.mesh is not None:
                # one spec per argument pytree, prefix-broadcast to
                # every leaf: the leading batch axis shards, everything
                # trailing (interfaces, workload rows, ticks) stays
                # device-local.  The scanned body has no collectives,
                # so each shard is an independent fleet slice — the
                # paper's decentralization, literal in the partitioning.
                spec = PartitionSpec(self.mesh.axis_names[0])
                fn = shard_map(fn, mesh=self.mesh,
                               in_specs=(spec,) * n_args, out_specs=spec)
            self._jits[n_args] = jax.jit(fn, donate_argnums=(1, 2))
        return self._jits[n_args]

    # ------------------------------------------------------------------ #
    def run_trace(self, result: "FusedLoopResult"):
        """Normalize a traced result to a :class:`~repro.obs.schema.RunTrace`."""
        from repro.obs.schema import RunTrace
        if self.trace_config is None:
            raise ValueError("loop was built without trace=TraceConfig(...)")
        return RunTrace.from_fused(result, self.trace_config,
                                   self.params.tick)

    # ------------------------------------------------------------------ #
    def neutral_schedule(self, n_intervals: int) -> Disturbance:
        """Whole-run identity schedule with a flat leading time axis."""
        return Disturbance.neutral(self.topo,
                                   n_ticks=n_intervals * self.steps)

    def _shape_schedule(self, sched: Disturbance,
                        n_intervals: int) -> Disturbance:
        """Flat ``(…, total_ticks, …)`` -> per-interval scan ``xs``."""
        t_ax = 1 if self.batched else 0

        def reshape(a):
            a = np.asarray(a)
            lead = a.shape[:t_ax]
            return a.reshape(lead + (n_intervals, self.steps)
                             + a.shape[t_ax + 1:])
        return jax.tree.map(reshape, sched)

    def run(self, table: WorkloadTable, state: SimState,
            wstate: WorkloadState, n_intervals: int,
            schedule: Disturbance | None = None,
            tune_mask: np.ndarray | None = None,
            intervene: "Intervention | None" = None) -> FusedLoopResult:
        """Advance ``n_intervals`` of engine + tuning in one dispatch.

        ``schedule`` is a whole-run :class:`Disturbance` with a flat
        leading ``(n_intervals * steps, ...)`` time axis (batched: a
        ``(B, total_ticks, ...)`` stack) — compiled **once** by the
        caller, not rebuilt per interval.  ``tune_mask`` restricts which
        interfaces may decide (default: all interfaces the state's
        ragged-batch validity masks mark real).  Numpy in, numpy out.

        ``intervene`` (tuned loops only) applies a per-interface
        :class:`Intervention` counterfactual — ``None`` leaves the
        compiled program literally unchanged.

        With ``mesh=``, a batch that does not divide the device count is
        padded with copies of element 0 whose ``tune_mask`` is forced
        ``False`` (phantom elements never decide); every output is
        sliced back to the caller's batch before returning.
        """
        n_intervals = int(n_intervals)
        if intervene is not None and not self.tuned:
            raise ValueError("intervene= requires a tuned loop")
        if schedule is None:
            schedule = self.neutral_schedule(n_intervals)
            if self.batched:
                b = np.asarray(state.window_pages).shape[0]
                schedule = jax.tree.map(
                    lambda a: np.broadcast_to(a, (b,) + a.shape), schedule)
        sched = self._shape_schedule(schedule, n_intervals)
        args = (table, state, wstate, sched)
        n_pad = 0
        if self.mesh is not None:
            args, n_pad = pad_fleet(args, self.mesh.devices.size)
        if self.tuned:
            if tune_mask is None:
                # default: every *valid* interface decides.  The state's
                # ragged-batch masks (all-true for unpadded runs, so this
                # is the historical all-ones mask) keep phantom padded
                # interfaces out of Algorithm 1, gating, and write-back
                # — they get zero trace weight because they never decide.
                cv = np.asarray(state.client_valid, dtype=bool)
                ov = np.asarray(state.ost_valid, dtype=bool)
                tune_mask = (cv[..., self.topo.osc_client]
                             & ov[..., self.topo.osc_ost])
            tune_mask = np.asarray(tune_mask, dtype=bool)
            if n_pad:
                tune_mask = np.concatenate(
                    [tune_mask,
                     np.zeros((n_pad,) + tune_mask.shape[1:], dtype=bool)])
            args = args + (tune_mask,)
            if intervene is not None:
                if n_pad:
                    # phantom rows get the neutral intervention: they
                    # never decide, and neutral masks are arithmetic
                    # identities, so padding cannot perturb anything.
                    intervene = jax.tree.map(
                        lambda a: np.concatenate(
                            [np.asarray(a),
                             np.zeros((n_pad,) + np.asarray(a).shape[1:],
                                      dtype=np.asarray(a).dtype)]),
                        intervene)
                args = args + (intervene,)

        with enable_x64():
            if self.mesh is not None:
                # place inputs *pre-sharded*: jit then donates the
                # caller's buffers directly instead of donating a
                # resharding copy (which would leave the originals
                # alive and defeat donate_argnums)
                sharding = NamedSharding(
                    self.mesh, PartitionSpec(self.mesh.axis_names[0]))
                with self.timers.phase("device_put"):
                    jargs = jax.tree.map(
                        lambda a: jax.device_put(np.asarray(a), sharding),
                        args)
            else:
                with self.timers.phase("device_put"):
                    jargs = jax.tree.map(jnp.asarray, args)
            with self.timers.phase("dispatch"):
                run_fn = (self._get_run(6) if intervene is not None
                          else self._run)
                out = run_fn(*jargs)
                out = jax.tree.map(
                    lambda x: x.block_until_ready()
                    if hasattr(x, "block_until_ready") else x, out)
        if self.tuned:
            jstate, jws, jtrace, jhist = out
        elif self.trace_config is not None:
            (jstate, jws, jtrace), jhist = out, None
        else:
            (jstate, jws), jtrace, jhist = out, None, None
        with self.timers.phase("to_host"):
            state = jax.tree.map(np.array, jstate)
            if not self.batched:
                state.now = float(state.now)
                state.tick_index = int(state.tick_index)
            wstate = jax.tree.map(np.array, jws)
            trace = (jax.tree.map(np.array, jtrace)
                     if jtrace is not None else None)
            hist = (jax.tree.map(np.array, jhist)
                    if jhist is not None else None)
        if n_pad:
            state = unpad_fleet(state, n_pad)
            wstate = unpad_fleet(wstate, n_pad)
            trace = unpad_fleet(trace, n_pad) if trace is not None else None
            hist = unpad_fleet(hist, n_pad) if hist is not None else None
        return FusedLoopResult(
            state=state, wstate=wstate, trace=trace,
            decisions=(decisions_from_trace(trace)
                       if trace is not None and "decided" in trace
                       else []),
            hist=hist,
            interval_seconds=self.steps * self.params.tick,
            n_run=n_intervals)
