"""State layer of the PFS engine: flat tick state + the pure step function.

This module is the backend-agnostic core the simulator is built on:

* :class:`SimParams` — physical constants of the simulated cluster;
* :class:`SimTopo`   — the static (client, OST) -> OSC wiring;
* :class:`SimState`  — every mutable per-tick array in one flat dataclass
  (registered as a JAX pytree when jax is importable, so the same object
  threads through ``lax.scan``);
* :class:`Demand`    — one tick's workload submissions, already resolved
  to per-OSC deltas (see :meth:`repro.pfs.workloads.WorkloadTable.demand_step`);
* :func:`engine_step` — the pure numpy transition
  ``(params, topo, state, demand) -> state'``, a verbatim extraction of
  the historical ``PFSSim.step`` phases.  This is the oracle the JAX
  execution layer (:mod:`repro.pfs.engine_jax`) is tested against.

:class:`~repro.pfs.engine.PFSSim` remains the stateful convenience
wrapper: it owns one ``SimState`` and calls :func:`engine_step` per tick,
so every existing caller (stats probing, fleet ports, benchmarks) keeps
working unchanged.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

PAGE_SIZE = 4096  # bytes, Linux page

# Operation codes.
READ = 0
WRITE = 1


@dataclasses.dataclass(frozen=True)
class SimParams:
    """Physical constants of the simulated cluster.

    Defaults are calibrated against the paper's CloudLab c6525-25g testbed
    (SIV-A): 4 OSS x 2 OST on SATA SSDs behind 25 GbE, which delivers
    single-client streams in the 300-460 MB/s range (paper Table II).
    """

    tick: float = 0.005                # simulation step [s]
    ost_bandwidth: float = 520e6       # per-OST service bandwidth [B/s]
    ost_setup_parallel: float = 4.0    # concurrent setup contexts per OST
    ost_iops: float = 2600.0           # per-OST RPC completions per second
    setup_time_seq: float = 300e-6     # fixed overhead per sequential RPC [s]
    setup_time_rand: float = 3.5e-3    # extra overhead for fully random RPC [s]
    rtt: float = 120e-6                # client<->OSS network round trip [s]
    nic_bandwidth: float = 2.9e9       # per-client NIC cap [B/s]
    hold_time_read: float = 0.012      # OSC holds a partial read RPC [s]
    hold_time_write: float = 0.025     # writes plug longer (write-behind)
    ost_buffer_bytes: float = 64 * 2**20  # OST service-queue comfort zone
    congestion_exp: float = 0.35       # service efficiency decay past buffer
    max_dirty_bytes: float = 64 * 2**20   # per-OSC dirty cache limit
    grant_bytes: float = 96 * 2**20       # per-OSC server grant
    readahead_bytes: float = 8 * 2**20 # client readahead pipeline depth
    max_rpc_queue: int = 4096          # formed-but-unsent RPC cap per OSC

    def setup_time(self, randomness):
        """Per-RPC fixed overhead as a function of access randomness in [0,1]."""
        return self.setup_time_seq + randomness * self.setup_time_rand

    def hold_time(self, op: int) -> float:
        return self.hold_time_read if op == READ else self.hold_time_write


@dataclasses.dataclass(frozen=True)
class SimTopo:
    """Static topology: one OSC per (client, OST) pair, like Lustre LOV.

    ``ost_valid`` / ``client_valid`` mark which slots are real when the
    topology has been padded up to a ragged-batch bucket shape
    (:mod:`repro.lab.batch`).  ``None`` means all-valid — the default for
    every directly-built topology, so unpadded runs are untouched.
    Phantom slots carry exact arithmetic identities everywhere (zero
    demand, neutral disturbance), so the masks are bookkeeping for the
    tuning/probing layers, not an engine input.
    """

    n_clients: int
    n_osts: int
    osc_client: np.ndarray   # (n_osc,) owning client of each OSC
    osc_ost: np.ndarray      # (n_osc,) backing OST of each OSC
    ost_valid: np.ndarray | None = None      # (n_osts,) bool; None = all
    client_valid: np.ndarray | None = None   # (n_clients,) bool; None = all

    @property
    def n_osc(self) -> int:
        return self.n_clients * self.n_osts

    @classmethod
    def dense(cls, n_clients: int, n_osts: int) -> "SimTopo":
        return cls(
            n_clients=n_clients,
            n_osts=n_osts,
            osc_client=np.repeat(np.arange(n_clients), n_osts),
            osc_ost=np.tile(np.arange(n_osts), n_clients),
        )

    def osc_id(self, client: int, ost: int) -> int:
        return client * self.n_osts + ost

    def client_oscs(self, client: int) -> np.ndarray:
        return np.arange(client * self.n_osts, (client + 1) * self.n_osts)

    def ost_valid_mask(self) -> np.ndarray:
        if self.ost_valid is None:
            return np.ones(self.n_osts, dtype=bool)
        return np.asarray(self.ost_valid, dtype=bool)

    def client_valid_mask(self) -> np.ndarray:
        if self.client_valid is None:
            return np.ones(self.n_clients, dtype=bool)
        return np.asarray(self.client_valid, dtype=bool)

    def osc_valid(self) -> np.ndarray:
        """(n_osc,) bool — an interface is real iff both endpoints are."""
        return (self.client_valid_mask()[self.osc_client]
                & self.ost_valid_mask()[self.osc_ost])


# The SimState fields, in pytree flattening order.  Everything mutable in
# a tick lives here; per-op arrays are (2, n), per-OSC arrays (n,).
_STATE_FIELDS = (
    "now", "tick_index",
    "window_pages", "rpcs_in_flight",
    "pending", "hold_age", "queue_rpcs", "queue_bytes", "active_rpcs",
    "setup_work", "unready_bytes", "ready_bytes", "active_avg_size",
    "dispatch_time_num", "randomness",
    "dirty_bytes", "grant_used", "write_blocked",
    "ctr_bytes_done", "ctr_rpcs_sent", "ctr_rpc_bytes", "ctr_partial_rpcs",
    "ctr_latency_sum", "ctr_rpcs_done", "ctr_req_count", "ctr_req_bytes",
    "ctr_cache_hit_bytes", "ctr_block_time", "ctr_pending_integral",
    "ctr_active_integral", "ctr_dirty_integral", "ctr_grant_integral",
    "ost_valid", "client_valid",
)


@dataclasses.dataclass
class SimState:
    """All mutable engine state as one flat bag of arrays (a pytree).

    ``engine_step`` consumes and returns these; the arrays may be numpy
    (the oracle path) or jax (the fused-interval path) — the dataclass is
    agnostic.  Counters are the simulated ``/proc/fs/lustre`` surface that
    :mod:`repro.pfs.stats` probes.
    """

    now: float
    tick_index: int
    # --- tunable knobs (DIAL's theta), per OSC ------------------------
    window_pages: np.ndarray     # (n,) int64
    rpcs_in_flight: np.ndarray   # (n,) int64
    # --- per-OSC, per-op fluid state ----------------------------------
    pending: np.ndarray          # (2, n) bytes not yet packed into RPCs
    hold_age: np.ndarray
    queue_rpcs: np.ndarray       # formed, waiting for a slot
    queue_bytes: np.ndarray
    active_rpcs: np.ndarray      # dispatched, in the pipeline
    setup_work: np.ndarray       # seconds of setup left (aggregate)
    unready_bytes: np.ndarray
    ready_bytes: np.ndarray      # setup done, transferring
    active_avg_size: np.ndarray
    dispatch_time_num: np.ndarray
    randomness: np.ndarray       # EMA of workload offset jumps
    # --- write path extras --------------------------------------------
    dirty_bytes: np.ndarray      # (n,)
    grant_used: np.ndarray
    write_blocked: np.ndarray    # (n,) bool; cache full last tick
    # --- cumulative counters (the "/proc" the client can probe) -------
    ctr_bytes_done: np.ndarray
    ctr_rpcs_sent: np.ndarray
    ctr_rpc_bytes: np.ndarray
    ctr_partial_rpcs: np.ndarray
    ctr_latency_sum: np.ndarray
    ctr_rpcs_done: np.ndarray
    ctr_req_count: np.ndarray
    ctr_req_bytes: np.ndarray
    ctr_cache_hit_bytes: np.ndarray
    ctr_block_time: np.ndarray
    ctr_pending_integral: np.ndarray
    ctr_active_integral: np.ndarray
    ctr_dirty_integral: np.ndarray
    ctr_grant_integral: np.ndarray
    # --- ragged-batch validity masks (pass-through; engine never reads) ---
    ost_valid: np.ndarray       # (n_osts,) bool; phantom padded OSTs False
    client_valid: np.ndarray    # (n_clients,) bool; phantom clients False

    def copy(self) -> "SimState":
        """Deep copy (fresh numpy arrays) — engine_step mutates the copy."""
        out = {}
        for f in _STATE_FIELDS:
            v = getattr(self, f)
            out[f] = np.array(v) if isinstance(v, np.ndarray) else v
        return SimState(**out)


def init_state(topo: SimTopo) -> SimState:
    """Fresh state for a topology: Lustre-default knobs, everything idle."""
    n = topo.n_osc
    zeros2 = lambda: np.zeros((2, n))
    return SimState(
        now=0.0,
        tick_index=0,
        window_pages=np.full(n, 256, dtype=np.int64),   # Lustre default 1 MiB
        rpcs_in_flight=np.full(n, 8, dtype=np.int64),   # Lustre default
        pending=zeros2(),
        hold_age=zeros2(),
        queue_rpcs=zeros2(),
        queue_bytes=zeros2(),
        active_rpcs=zeros2(),
        setup_work=zeros2(),
        unready_bytes=zeros2(),
        ready_bytes=zeros2(),
        active_avg_size=np.full((2, n), float(PAGE_SIZE)),
        dispatch_time_num=zeros2(),
        randomness=zeros2(),
        dirty_bytes=np.zeros(n),
        grant_used=np.zeros(n),
        write_blocked=np.zeros(n, dtype=bool),
        ctr_bytes_done=zeros2(),
        ctr_rpcs_sent=zeros2(),
        ctr_rpc_bytes=zeros2(),
        ctr_partial_rpcs=zeros2(),
        ctr_latency_sum=zeros2(),
        ctr_rpcs_done=zeros2(),
        ctr_req_count=zeros2(),
        ctr_req_bytes=zeros2(),
        ctr_cache_hit_bytes=np.zeros(n),
        ctr_block_time=np.zeros(n),
        ctr_pending_integral=zeros2(),
        ctr_active_integral=zeros2(),
        ctr_dirty_integral=np.zeros(n),
        ctr_grant_integral=np.zeros(n),
        ost_valid=topo.ost_valid_mask(),
        client_valid=topo.client_valid_mask(),
    )


@dataclasses.dataclass
class Demand:
    """One tick of workload submissions, resolved to per-OSC deltas.

    Produced by :meth:`repro.pfs.workloads.WorkloadTable.demand_step`,
    which runs the closed-loop / grant-acceptance workload semantics and
    leaves only trivially-appliable updates: additive counter deltas plus
    the post-submission absolute values of the two sequentially-mixed
    fields (randomness EMA, write-blocked flags).
    """

    pending_read_add: np.ndarray    # (n,) bytes entering the read pipeline
    dirty_add: np.ndarray           # (n,) write bytes accepted into cache
    req_count_add: np.ndarray       # (2, n)
    req_bytes_add: np.ndarray       # (2, n)
    cache_hit_add: np.ndarray       # (n,)
    randomness_new: np.ndarray      # (2, n) absolute (EMA already applied)
    write_blocked_new: np.ndarray   # (n,) bool, absolute
    # |Demand| == app-visible write completions: bytes_done[WRITE] += dirty_add


_DISTURBANCE_FIELDS = ("bw_scale", "iops_scale", "bg_bytes", "nic_scale")


@dataclasses.dataclass
class Disturbance:
    """One tick of exogenous conditions the simulated cluster is under.

    These are the environment inputs no client controls or observes
    directly — the scenario lab uses them to express noisy neighbours,
    degraded or failing OSTs, and heterogeneous client links as per-tick
    schedules (leading time axis) threaded through the numpy oracle and
    the fused JAX scan identically (scan ``xs``).  The neutral values
    (scales of 1, zero background bytes) are exact arithmetic identities,
    so an undisturbed run is bit-equal to the historical engine.
    """

    bw_scale: np.ndarray    # (n_osts,) multiplier on OST service bandwidth
    iops_scale: np.ndarray  # (n_osts,) multiplier on setup/IOPS capacity
    bg_bytes: np.ndarray    # (n_osts,) background bytes arriving this tick
    nic_scale: np.ndarray   # (n_clients,) multiplier on client NIC cap

    @classmethod
    def neutral(cls, topo: "SimTopo", n_ticks: int | None = None) -> "Disturbance":
        """Identity disturbance; with ``n_ticks`` a whole neutral schedule."""
        shape = (lambda n: (n,)) if n_ticks is None else (lambda n: (n_ticks, n))
        return cls(
            bw_scale=np.ones(shape(topo.n_osts)),
            iops_scale=np.ones(shape(topo.n_osts)),
            bg_bytes=np.zeros(shape(topo.n_osts)),
            nic_scale=np.ones(shape(topo.n_clients)),
        )

    def at_tick(self, i: int) -> "Disturbance":
        """Tick ``i`` of a schedule (arrays carry a leading time axis)."""
        return Disturbance(bw_scale=self.bw_scale[i],
                           iops_scale=self.iops_scale[i],
                           bg_bytes=self.bg_bytes[i],
                           nic_scale=self.nic_scale[i])


@functools.lru_cache(maxsize=64)
def _neutral_cached(n_osts: int, n_clients: int) -> Disturbance:
    """Shared identity Disturbance per topology size — the undisturbed
    per-tick oracle path must not pay four allocations per call.  The
    cached arrays are frozen (``writeable=False``): an in-place edit by
    any caller would silently corrupt every later tick that reuses the
    cache, so mutation raises instead."""
    d = Disturbance(bw_scale=np.ones(n_osts), iops_scale=np.ones(n_osts),
                    bg_bytes=np.zeros(n_osts), nic_scale=np.ones(n_clients))
    for f in _DISTURBANCE_FIELDS:
        getattr(d, f).flags.writeable = False
    return d


# Register the state dataclasses as JAX pytrees when jax is importable so
# they thread through jit / lax.scan; numpy-only deployments skip this.
try:  # pragma: no cover - exercised implicitly by engine_jax tests
    import jax as _jax

    for _cls, _fields in ((SimState, _STATE_FIELDS),
                          (Demand, tuple(f.name for f in
                                         dataclasses.fields(Demand))),
                          (Disturbance, _DISTURBANCE_FIELDS)):
        _jax.tree_util.register_pytree_node(
            _cls,
            (lambda s, _f=_fields: (tuple(getattr(s, n) for n in _f), None)),
            (lambda aux, children, _c=_cls, _f=_fields:
             _c(**dict(zip(_f, children)))),
        )
except ImportError:  # pragma: no cover
    pass


def apply_demand(state: SimState, demand: Demand) -> None:
    """Fold one tick's workload submissions into ``state`` (in place).

    Mirrors what a sequence of ``PFSSim.submit_read`` / ``submit_write``
    calls does, given that ``demand_step`` already resolved acceptance
    and the sequential EMA / blocked-flag mixing.
    """
    state.pending[READ] += demand.pending_read_add
    state.dirty_bytes += demand.dirty_add
    state.grant_used += demand.dirty_add
    state.ctr_req_count += demand.req_count_add
    state.ctr_req_bytes += demand.req_bytes_add
    state.ctr_cache_hit_bytes += demand.cache_hit_add
    state.ctr_bytes_done[WRITE] += demand.dirty_add
    state.randomness[...] = demand.randomness_new
    state.write_blocked[...] = demand.write_blocked_new


def engine_step(params: SimParams, topo: SimTopo, state: SimState,
                demand: Demand | None = None,
                disturbance: Disturbance | None = None) -> SimState:
    """One pure engine tick: ``state' = engine_step(params, topo, state)``.

    A verbatim extraction of the historical ``PFSSim.step`` phases
    (formation -> dispatch -> OST drain -> bandwidth -> completion ->
    accounting) operating on a :class:`SimState`.  ``demand`` carries the
    tick's workload submissions; pass ``None`` when submissions were
    already folded in by the stateful wrapper (legacy ``Workload``
    objects calling ``submit_*`` on the sim).  ``disturbance`` carries
    the tick's exogenous conditions (OST degradation, background
    traffic, NIC heterogeneity); ``None`` means the neutral identity.

    The input state is never mutated; a fresh numpy state is returned.
    This function is the semantic oracle for the fused JAX path.
    """
    p = params
    dt = p.tick
    s = state.copy()
    n_osts = topo.n_osts
    osc_ost = topo.osc_ost
    osc_client = topo.osc_client
    dist = (disturbance if disturbance is not None
            else _neutral_cached(topo.n_osts, topo.n_clients))

    # (1) workloads deposit demand
    if demand is not None:
        apply_demand(s, demand)

    # write path: dirty cache continuously feeds the pending queue
    in_pipe = (s.pending[WRITE] + s.queue_bytes[WRITE]
               + s.unready_bytes[WRITE] + s.ready_bytes[WRITE])
    s.pending[WRITE] += np.maximum(s.dirty_bytes - in_pipe, 0.0)

    # (2) RPC formation: full windows pack immediately; partials wait
    # up to hold_time hoping more data shows up (Lustre plugging).
    win_bytes = (s.window_pages * PAGE_SIZE).astype(float)
    for op in (READ, WRITE):
        pend = s.pending[op]
        room = np.maximum(p.max_rpc_queue - s.queue_rpcs[op], 0.0)
        n_full = np.minimum(np.floor(pend / win_bytes), room)
        full_bytes = n_full * win_bytes
        s.queue_rpcs[op] += n_full
        s.queue_bytes[op] += full_bytes
        pend = pend - full_bytes
        s.hold_age[op] = np.where(pend > 0, s.hold_age[op] + dt, 0.0)
        expire = (pend > 0) & (s.hold_age[op] >= p.hold_time(op)) & (room > n_full)
        s.queue_rpcs[op] += expire
        s.queue_bytes[op] += np.where(expire, pend, 0.0)
        s.ctr_partial_rpcs[op] += expire
        s.pending[op] = np.where(expire, 0.0, pend)
        s.hold_age[op] = np.where(expire, 0.0, s.hold_age[op])

    # (3) dispatch up to rpcs_in_flight (reads first: sync-read bias)
    slots = np.maximum(
        s.rpcs_in_flight - (s.active_rpcs[READ] + s.active_rpcs[WRITE]),
        0.0,
    )
    for op in (READ, WRITE):
        take = np.minimum(s.queue_rpcs[op], slots)
        frac = np.divide(take, s.queue_rpcs[op],
                         out=np.zeros_like(take), where=s.queue_rpcs[op] > 0)
        bytes_out = s.queue_bytes[op] * frac
        s.queue_rpcs[op] -= take
        s.queue_bytes[op] -= bytes_out
        slots = slots - take
        s.active_rpcs[op] += take
        per_rpc = p.setup_time(s.randomness[op]) + p.rtt
        s.setup_work[op] += take * per_rpc
        s.unready_bytes[op] += bytes_out
        tot_bytes = s.unready_bytes[op] + s.ready_bytes[op]
        s.active_avg_size[op] = np.where(
            s.active_rpcs[op] > 0,
            tot_bytes / np.maximum(s.active_rpcs[op], 1e-9),
            s.active_avg_size[op])
        s.ctr_rpcs_sent[op] += take
        s.ctr_rpc_bytes[op] += bytes_out
        s.dispatch_time_num[op] += take * s.now

    # (4) OST setup service: `ost_setup_parallel` concurrent contexts
    # drain setup work; a separate IOPS ceiling caps completed setups.
    total_work = s.setup_work[READ] + s.setup_work[WRITE]
    ost_work = np.bincount(osc_ost, weights=total_work, minlength=n_osts)
    cap = dt * p.ost_setup_parallel * dist.iops_scale
    drain_frac_ost = np.divide(cap, ost_work,
                               out=np.ones(n_osts), where=ost_work > cap)
    # IOPS ceiling, applied on setups completed this tick per OST
    for op in (READ, WRITE):
        work = s.setup_work[op]
        drained = work * drain_frac_ost[osc_ost]
        per_rpc = p.setup_time(s.randomness[op]) + p.rtt
        setups_done = np.divide(drained, per_rpc,
                                out=np.zeros_like(drained), where=per_rpc > 0)
        ost_setups = np.bincount(osc_ost, weights=setups_done,
                                 minlength=n_osts)
        iops_cap = p.ost_iops * dt * dist.iops_scale
        iops_frac = np.divide(iops_cap, ost_setups, out=np.ones(n_osts),
                              where=ost_setups > iops_cap)
        effective = drained * iops_frac[osc_ost]
        s.setup_work[op] = work - effective
        ready = np.minimum(
            np.divide(effective, per_rpc, out=np.zeros_like(effective),
                      where=per_rpc > 0) * s.active_avg_size[op],
            s.unready_bytes[op])
        ready = np.where(s.setup_work[op] <= 1e-12, s.unready_bytes[op], ready)
        s.unready_bytes[op] -= ready
        s.ready_bytes[op] += ready

    # (5) bandwidth: OST bw fair-shared over transfer-phase RPC counts,
    # then per-client NIC cap rescales.  An OST whose service queue
    # holds far more bytes than its buffer comfort zone degrades
    # (cache thrash / request-queue overhead) -- this is the cost of
    # everyone maxing rpcs_in_flight x window at once, and the reason
    # decentralized agents must moderate under contention.
    want = s.ready_bytes[READ] + s.ready_bytes[WRITE]
    queued = (s.unready_bytes[READ] + s.unready_bytes[WRITE]
              + s.ready_bytes[READ] + s.ready_bytes[WRITE])
    ost_queued = np.bincount(osc_ost, weights=queued,
                             minlength=n_osts) + dist.bg_bytes
    over = ost_queued > p.ost_buffer_bytes
    eff = np.where(
        over,
        np.power(p.ost_buffer_bytes / np.maximum(ost_queued, 1.0),
                 p.congestion_exp),
        1.0,
    )
    active_transfer = np.where(want > 0,
                               s.active_rpcs[READ] + s.active_rpcs[WRITE], 0.0)
    ost_shares = np.bincount(osc_ost, weights=active_transfer,
                             minlength=n_osts)
    share = np.divide(active_transfer, ost_shares[osc_ost],
                      out=np.zeros_like(active_transfer),
                      where=ost_shares[osc_ost] > 0)
    ost_bw_eff = p.ost_bandwidth * dist.bw_scale * eff
    # background traffic is served first (it belongs to clients outside
    # the fleet; the server cannot tell it apart), shrinking this tick's
    # foreground budget.  Written as a subtraction of the background
    # share so the zero-background case keeps the historical
    # multiplication order bit for bit.
    bg_served = np.minimum(dist.bg_bytes, ost_bw_eff * dt)
    alloc = np.minimum(
        share * ost_bw_eff[osc_ost] * dt - share * bg_served[osc_ost], want)
    # redistribute leftover OST bandwidth to still-hungry OSCs
    leftover = (ost_bw_eff * dt - bg_served) - np.bincount(
        osc_ost, weights=alloc, minlength=n_osts)
    hungry = want - alloc
    ost_hungry = np.bincount(osc_ost, weights=hungry, minlength=n_osts)
    bonus_frac = np.divide(leftover, ost_hungry, out=np.zeros(n_osts),
                           where=ost_hungry > 0)
    alloc = alloc + hungry * np.minimum(bonus_frac[osc_ost], 1.0)
    # NIC cap per client
    nic_cap = p.nic_bandwidth * dist.nic_scale * dt
    client_alloc = np.bincount(osc_client, weights=alloc,
                               minlength=topo.n_clients)
    nic_frac = np.divide(nic_cap, client_alloc,
                         out=np.ones(topo.n_clients),
                         where=client_alloc > nic_cap)
    alloc = alloc * nic_frac[osc_client]

    # (6) completions
    for op in (READ, WRITE):
        frac = np.divide(s.ready_bytes[op], want,
                         out=np.zeros_like(want), where=want > 0)
        drained = alloc * frac
        s.ready_bytes[op] -= drained
        avg = np.maximum(s.active_avg_size[op], 1.0)
        done_rpcs = np.minimum(np.divide(drained, avg), s.active_rpcs[op])
        inflight_bytes = s.unready_bytes[op] + s.ready_bytes[op]
        done_rpcs = np.where(inflight_bytes <= 1e-9, s.active_rpcs[op], done_rpcs)
        prev_active = s.active_rpcs[op].copy()
        s.active_rpcs[op] -= done_rpcs
        s.ctr_rpcs_done[op] += done_rpcs
        if op == READ:
            s.ctr_bytes_done[READ] += drained
        else:
            # flushed bytes leave the dirty cache and release grant
            s.dirty_bytes = np.maximum(s.dirty_bytes - drained, 0.0)
            s.grant_used = np.maximum(s.grant_used - drained, 0.0)
        avg_disp = np.divide(s.dispatch_time_num[op], np.maximum(prev_active, 1e-9))
        lat = np.maximum(s.now + dt - avg_disp, dt)
        s.ctr_latency_sum[op] += done_rpcs * lat
        keep = np.divide(s.active_rpcs[op], np.maximum(prev_active, 1e-9))
        s.dispatch_time_num[op] *= keep

    # blocked-writer accounting (workloads stop issuing while blocked)
    s.ctr_block_time += s.write_blocked * dt
    room = np.minimum(p.max_dirty_bytes - s.dirty_bytes,
                      p.grant_bytes - s.grant_used)
    s.write_blocked &= room < PAGE_SIZE

    # time-integrals for interval averages
    for op in (READ, WRITE):
        s.ctr_pending_integral[op] += (s.pending[op] + s.queue_bytes[op]) * dt
        s.ctr_active_integral[op] += s.active_rpcs[op] * dt
    s.ctr_dirty_integral += s.dirty_bytes * dt
    s.ctr_grant_integral += s.grant_used * dt

    s.now += dt
    s.tick_index += 1
    return s
