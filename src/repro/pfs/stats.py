"""Raw client-local statistics probing — the simulated ``/proc/fs/lustre``.

``probe()`` copies the cumulative counters one Lustre client can read for
one of its OSC interfaces *without touching the shared file system* (the
paper's core constraint, SIII-A/SIV-C).  DIAL's preprocessor
(:mod:`repro.core.metrics`) turns two consecutive probes into the designed
interval metrics.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class OSCStats:
    """Cumulative counters for one OSC interface at one instant.

    Mirrors Lustre's ``osc.*.rpc_stats`` / ``osc.*.stats`` /
    ``llite.*.read_ahead_stats`` surface, per operation where relevant.
    Arrays indexed by op: 0=read, 1=write.
    """

    t: float
    bytes_done: np.ndarray          # app-visible completed bytes
    rpcs_sent: np.ndarray
    rpc_bytes: np.ndarray
    partial_rpcs: np.ndarray
    latency_sum: np.ndarray
    rpcs_done: np.ndarray
    req_count: np.ndarray
    req_bytes: np.ndarray
    pending_integral: np.ndarray
    active_integral: np.ndarray
    cache_hit_bytes: float
    block_time: float
    dirty_integral: float
    grant_integral: float
    randomness: np.ndarray          # client-side offset-jump estimate
    window_pages: int               # knob currently applied
    rpcs_in_flight: int


def probe(sim, osc: int) -> OSCStats:
    """Snapshot the cumulative counters of one OSC (cheap, local-only)."""
    return OSCStats(
        t=sim.now,
        bytes_done=sim.ctr_bytes_done[:, osc].copy(),
        rpcs_sent=sim.ctr_rpcs_sent[:, osc].copy(),
        rpc_bytes=sim.ctr_rpc_bytes[:, osc].copy(),
        partial_rpcs=sim.ctr_partial_rpcs[:, osc].copy(),
        latency_sum=sim.ctr_latency_sum[:, osc].copy(),
        rpcs_done=sim.ctr_rpcs_done[:, osc].copy(),
        req_count=sim.ctr_req_count[:, osc].copy(),
        req_bytes=sim.ctr_req_bytes[:, osc].copy(),
        pending_integral=sim.ctr_pending_integral[:, osc].copy(),
        active_integral=sim.ctr_active_integral[:, osc].copy(),
        cache_hit_bytes=float(sim.ctr_cache_hit_bytes[osc]),
        block_time=float(sim.ctr_block_time[osc]),
        dirty_integral=float(sim.ctr_dirty_integral[osc]),
        grant_integral=float(sim.ctr_grant_integral[osc]),
        randomness=sim.randomness[:, osc].copy(),
        window_pages=int(sim.window_pages[osc]),
        rpcs_in_flight=int(sim.rpcs_in_flight[osc]),
    )


def probe_client(sim, client: int) -> dict:
    """Probe every OSC interface of one client (what a DIAL agent sees)."""
    return {int(osc): probe(sim, int(osc)) for osc in sim.client_oscs(client)}


# ---------------------------------------------------------------------- #
# fleet probing: stacked counters for many OSC interfaces at once
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class FleetStats:
    """Cumulative counters for *many* OSC interfaces at one instant.

    Column ``i`` of every array is the interface ``oscs[i]`` — the same
    fields as :class:`OSCStats`, stacked so one probe of the whole fleet
    is a handful of fancy-indexed array copies instead of a Python loop.
    Per-op arrays are shaped ``(2, n)``, per-OSC scalars ``(n,)``.
    """

    t: float
    oscs: np.ndarray                # (n,) interface ids
    bytes_done: np.ndarray          # (2, n)
    rpcs_sent: np.ndarray
    rpc_bytes: np.ndarray
    partial_rpcs: np.ndarray
    latency_sum: np.ndarray
    rpcs_done: np.ndarray
    req_count: np.ndarray
    req_bytes: np.ndarray
    pending_integral: np.ndarray
    active_integral: np.ndarray
    cache_hit_bytes: np.ndarray     # (n,)
    block_time: np.ndarray
    dirty_integral: np.ndarray
    grant_integral: np.ndarray
    randomness: np.ndarray          # (2, n)
    window_pages: np.ndarray        # (n,) int64
    rpcs_in_flight: np.ndarray      # (n,) int64

    def __len__(self) -> int:
        return len(self.oscs)

    def one(self, i: int) -> OSCStats:
        """Column ``i`` as a scalar :class:`OSCStats` (compat / debugging)."""
        return OSCStats(
            t=self.t,
            bytes_done=self.bytes_done[:, i].copy(),
            rpcs_sent=self.rpcs_sent[:, i].copy(),
            rpc_bytes=self.rpc_bytes[:, i].copy(),
            partial_rpcs=self.partial_rpcs[:, i].copy(),
            latency_sum=self.latency_sum[:, i].copy(),
            rpcs_done=self.rpcs_done[:, i].copy(),
            req_count=self.req_count[:, i].copy(),
            req_bytes=self.req_bytes[:, i].copy(),
            pending_integral=self.pending_integral[:, i].copy(),
            active_integral=self.active_integral[:, i].copy(),
            cache_hit_bytes=float(self.cache_hit_bytes[i]),
            block_time=float(self.block_time[i]),
            dirty_integral=float(self.dirty_integral[i]),
            grant_integral=float(self.grant_integral[i]),
            randomness=self.randomness[:, i].copy(),
            window_pages=int(self.window_pages[i]),
            rpcs_in_flight=int(self.rpcs_in_flight[i]),
        )


def probe_all(sim, oscs=None) -> FleetStats:
    """Snapshot the counters of many OSC interfaces in one shot.

    Reads the simulator's flat counter arrays directly (one fancy-indexed
    copy per field), so the cost is independent of how many Python-level
    agents exist — this is the fleet agent's probe path.
    """
    oscs = (np.arange(sim.n_osc) if oscs is None
            else np.asarray(oscs, dtype=np.int64))
    return FleetStats(
        t=sim.now,
        oscs=oscs,
        bytes_done=sim.ctr_bytes_done[:, oscs].copy(),
        rpcs_sent=sim.ctr_rpcs_sent[:, oscs].copy(),
        rpc_bytes=sim.ctr_rpc_bytes[:, oscs].copy(),
        partial_rpcs=sim.ctr_partial_rpcs[:, oscs].copy(),
        latency_sum=sim.ctr_latency_sum[:, oscs].copy(),
        rpcs_done=sim.ctr_rpcs_done[:, oscs].copy(),
        req_count=sim.ctr_req_count[:, oscs].copy(),
        req_bytes=sim.ctr_req_bytes[:, oscs].copy(),
        pending_integral=sim.ctr_pending_integral[:, oscs].copy(),
        active_integral=sim.ctr_active_integral[:, oscs].copy(),
        cache_hit_bytes=sim.ctr_cache_hit_bytes[oscs].copy(),
        block_time=sim.ctr_block_time[oscs].copy(),
        dirty_integral=sim.ctr_dirty_integral[oscs].copy(),
        grant_integral=sim.ctr_grant_integral[oscs].copy(),
        randomness=sim.randomness[:, oscs].copy(),
        window_pages=sim.window_pages[oscs].copy(),
        rpcs_in_flight=sim.rpcs_in_flight[oscs].copy(),
    )


def stack_stats(stats: list[OSCStats], oscs) -> FleetStats:
    """Stack per-interface :class:`OSCStats` into one :class:`FleetStats`.

    Fallback for :class:`~repro.core.fleet.FleetPort` adapters over systems
    that only expose per-interface probes; the simulator fast path is
    :func:`probe_all`.
    """
    col = (lambda name: np.stack([getattr(s, name) for s in stats], axis=-1)
           ) if stats else (lambda name: np.zeros((2, 0)))
    vec = lambda name: np.array([getattr(s, name) for s in stats])
    return FleetStats(
        t=stats[0].t if stats else 0.0,
        oscs=np.asarray(oscs, dtype=np.int64),
        bytes_done=col("bytes_done"),
        rpcs_sent=col("rpcs_sent"),
        rpc_bytes=col("rpc_bytes"),
        partial_rpcs=col("partial_rpcs"),
        latency_sum=col("latency_sum"),
        rpcs_done=col("rpcs_done"),
        req_count=col("req_count"),
        req_bytes=col("req_bytes"),
        pending_integral=col("pending_integral"),
        active_integral=col("active_integral"),
        cache_hit_bytes=vec("cache_hit_bytes"),
        block_time=vec("block_time"),
        dirty_integral=vec("dirty_integral"),
        grant_integral=vec("grant_integral"),
        randomness=col("randomness"),
        window_pages=vec("window_pages").astype(np.int64),
        rpcs_in_flight=vec("rpcs_in_flight").astype(np.int64),
    )
