"""Raw client-local statistics probing — the simulated ``/proc/fs/lustre``.

``probe()`` copies the cumulative counters one Lustre client can read for
one of its OSC interfaces *without touching the shared file system* (the
paper's core constraint, SIII-A/SIV-C).  DIAL's preprocessor
(:mod:`repro.core.metrics`) turns two consecutive probes into the designed
interval metrics.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class OSCStats:
    """Cumulative counters for one OSC interface at one instant.

    Mirrors Lustre's ``osc.*.rpc_stats`` / ``osc.*.stats`` /
    ``llite.*.read_ahead_stats`` surface, per operation where relevant.
    Arrays indexed by op: 0=read, 1=write.
    """

    t: float
    bytes_done: np.ndarray          # app-visible completed bytes
    rpcs_sent: np.ndarray
    rpc_bytes: np.ndarray
    partial_rpcs: np.ndarray
    latency_sum: np.ndarray
    rpcs_done: np.ndarray
    req_count: np.ndarray
    req_bytes: np.ndarray
    pending_integral: np.ndarray
    active_integral: np.ndarray
    cache_hit_bytes: float
    block_time: float
    dirty_integral: float
    grant_integral: float
    randomness: np.ndarray          # client-side offset-jump estimate
    window_pages: int               # knob currently applied
    rpcs_in_flight: int


def probe(sim, osc: int) -> OSCStats:
    """Snapshot the cumulative counters of one OSC (cheap, local-only)."""
    return OSCStats(
        t=sim.now,
        bytes_done=sim.ctr_bytes_done[:, osc].copy(),
        rpcs_sent=sim.ctr_rpcs_sent[:, osc].copy(),
        rpc_bytes=sim.ctr_rpc_bytes[:, osc].copy(),
        partial_rpcs=sim.ctr_partial_rpcs[:, osc].copy(),
        latency_sum=sim.ctr_latency_sum[:, osc].copy(),
        rpcs_done=sim.ctr_rpcs_done[:, osc].copy(),
        req_count=sim.ctr_req_count[:, osc].copy(),
        req_bytes=sim.ctr_req_bytes[:, osc].copy(),
        pending_integral=sim.ctr_pending_integral[:, osc].copy(),
        active_integral=sim.ctr_active_integral[:, osc].copy(),
        cache_hit_bytes=float(sim.ctr_cache_hit_bytes[osc]),
        block_time=float(sim.ctr_block_time[osc]),
        dirty_integral=float(sim.ctr_dirty_integral[osc]),
        grant_integral=float(sim.ctr_grant_integral[osc]),
        randomness=sim.randomness[:, osc].copy(),
        window_pages=int(sim.window_pages[osc]),
        rpcs_in_flight=int(sim.rpcs_in_flight[osc]),
    )


def probe_client(sim, client: int) -> dict:
    """Probe every OSC interface of one client (what a DIAL agent sees)."""
    return {int(osc): probe(sim, int(osc)) for osc in sim.client_oscs(client)}
