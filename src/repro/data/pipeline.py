"""Sharded token pipeline reading through the PFS client — DIAL's host.

Every training host is one PFS client pulling its shard slice of the
global batch each step (and the checkpoint engine pushes through the same
client's write path).  The pipeline:

  * issues closed-loop reads against the simulated Lustre client
    (striped over the dataset's OSTs) sized to the host's per-step quota;
  * runs a DIAL agent per host at the probe interval, tuning that
    client's (window, in-flight) knobs from purely local metrics;
  * synthesizes the actual token arrays deterministically (seeded) —
    the simulator accounts for the *bytes*; the tensor content is
    reproducible regardless of I/O timing, so training is bitwise
    deterministic under any tuning behaviour;
  * tracks a resumable cursor (step index) checkpointed with the model —
    restart replays from the exact batch;
  * straggler mitigation: a host whose shard read lags `straggler_factor`
    behind the fleet median re-issues the remainder against a replica
    OST (redundant fetch), so one slow OST cannot stall the step.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.agent import DIALAgent, SimClientPort
from repro.core.model import DIALModel
from repro.pfs.engine import READ, PFSSim
from repro.pfs.workloads import Workload


@dataclasses.dataclass
class PipelineConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    n_hosts: int = 4
    bytes_per_token: float = 2.0     # uint16 token shards on disk
    osts_per_host: int = 2
    probe_interval: float = 0.5
    straggler_factor: float = 3.0
    seed: int = 0
    num_codebooks: int = 0


class DataPipeline:
    """Deterministic token source + PFS-accounted ingest with DIAL."""

    def __init__(self, cfg: PipelineConfig, sim: PFSSim | None = None,
                 dial_model: DIALModel | None = None):
        self.cfg = cfg
        n_osts = max(cfg.n_hosts * cfg.osts_per_host, 1)
        self.sim = sim or PFSSim(n_clients=cfg.n_hosts, n_osts=n_osts, seed=cfg.seed)
        self.rng = np.random.default_rng(cfg.seed)
        self.step_index = 0
        self._since_probe = 0.0
        self.agents = []
        if dial_model is not None:
            self.agents = [
                DIALAgent(SimClientPort(self.sim, h), dial_model)
                for h in range(cfg.n_hosts)
            ]
        # per-host ingest workloads: sequential shard streams
        self.workloads = []
        for h in range(cfg.n_hosts):
            osts = tuple(range(h * cfg.osts_per_host,
                               (h + 1) * cfg.osts_per_host))
            w = Workload(client=h, op=READ, req_size=1 * 2**20,
                         randomness=0.1, n_threads=4, osts=osts,
                         name=f"ingest_host{h}")
            self.sim.attach(w)
            self.workloads.append(w)
        self._done_base = [w.done_bytes(self.sim) for w in self.workloads]

    # ------------------------------------------------------------------ #
    def step_bytes_per_host(self) -> float:
        c = self.cfg
        tokens = c.global_batch * c.seq_len * max(c.num_codebooks, 1)
        return tokens * c.bytes_per_token / c.n_hosts

    def next_batch(self) -> dict:
        """Advance the simulator until every host has read its quota,
        running DIAL agents at the probe interval; return the batch."""
        c = self.cfg
        quota = self.step_bytes_per_host()
        target = [b + quota for b in self._done_base]
        stalled_redundant = set()
        max_sim_s = 120.0
        waited = 0.0
        while waited < max_sim_s:
            done = [w.done_bytes(self.sim) for w in self.workloads]
            lag = [t - d for t, d in zip(target, done)]
            if max(lag) <= 0:
                break
            # straggler mitigation: re-stripe the laggard onto all OSTs
            med = float(np.median(lag))
            for h, l in enumerate(lag):
                if (l > c.straggler_factor * max(med, 1.0)
                        and h not in stalled_redundant and med >= 0):
                    w = self.workloads[h]
                    w.osts = tuple(range(self.sim.n_osts))
                    w.bind(self.sim)
                    self._done_base[h] = 0.0
                    target[h] = w.done_bytes(self.sim) + l
                    stalled_redundant.add(h)
            self.sim.run(self.cfg.probe_interval)
            waited += self.cfg.probe_interval
            for a in self.agents:
                a.tick()
        self._done_base = [w.done_bytes(self.sim) for w in self.workloads]

        batch = self._materialize(self.step_index)
        self.step_index += 1
        return batch

    def ingest_throughput(self) -> float:
        """Aggregate delivered bytes/sec so far (sim time)."""
        total = sum(w.done_bytes(self.sim) for w in self.workloads)
        return total / max(self.sim.now, 1e-9)

    # ------------------------------------------------------------------ #
    def _materialize(self, step: int) -> dict:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        shape = (c.global_batch, c.seq_len)
        if c.num_codebooks:
            shape = shape + (c.num_codebooks,)
        tokens = rng.integers(0, c.vocab_size, size=shape, dtype=np.int32)
        return {"tokens": tokens, "labels": tokens}

    # --- checkpointable cursor ---------------------------------------- #
    def state_dict(self) -> dict:
        return {"step_index": self.step_index}

    def load_state_dict(self, state: dict) -> None:
        self.step_index = int(state["step_index"])
