"""Training-data pipeline that ingests through the simulated PFS."""

from repro.data.pipeline import DataPipeline, PipelineConfig

__all__ = ["DataPipeline", "PipelineConfig"]
