"""gemma2-2b [dense]: local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, head_dim 256,
window 4096, attn softcap 50, final softcap 30, GeGLU, sandwich norms,
tied embeddings with sqrt(d) scaling [arXiv:2408.00118; hf].
"""

from repro.models.config import ATTN, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    layer_pattern=(ATTN_LOCAL, ATTN),
    window_size=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    use_post_norm=True,
    scale_embeddings=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch_id="gemma2-2b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    layer_pattern=(ATTN_LOCAL, ATTN),
    window_size=16,
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    use_post_norm=True,
    scale_embeddings=True,
    tie_embeddings=True,
)
