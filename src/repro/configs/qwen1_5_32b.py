"""qwen1.5-32b [dense]: 64L d_model=5120 40H (kv=40, i.e. MHA) d_ff=27392
vocab=152064 — QKV bias [hf:Qwen/Qwen1.5-32B; hf].  SwiGLU, RMSNorm."""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152_064,
    layer_pattern=(ATTN,),
    qkv_bias=True,
)

SMOKE = ModelConfig(
    arch_id="qwen1.5-32b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab_size=256,
    layer_pattern=(ATTN,),
    qkv_bias=True,
)
