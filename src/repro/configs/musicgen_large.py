"""musicgen-large [audio]: decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048, 4 codebooks
[arXiv:2306.05284; hf].  The EnCodec frontend is a stub: input_specs()
provides the precomputed 4-stream token grid; the backbone embeds each
codebook, sums, and predicts all 4 streams in parallel (delay-pattern
scheduling happens in the tokenizer, outside the backbone).
"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    layer_pattern=(ATTN,),
    act="gelu",
    norm="layernorm",
    mlp_gated=False,
    num_codebooks=4,
)

SMOKE = ModelConfig(
    arch_id="musicgen-large-smoke",
    family="audio",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    layer_pattern=(ATTN,),
    act="gelu",
    norm="layernorm",
    mlp_gated=False,
    num_codebooks=4,
)
