"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) expert d_ff=1024
vocab=50304, MoE 64 experts top-8 [arXiv:2409.02060; hf]."""

from repro.models.config import MOE, ModelConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50_304,
    layer_pattern=(MOE,),
    n_experts=64,
    top_k=8,
    d_expert=1024,
)

SMOKE = ModelConfig(
    arch_id="olmoe-1b-7b-smoke",
    family="moe",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=256,
    layer_pattern=(MOE,),
    n_experts=8,
    top_k=2,
    d_expert=64,
)
