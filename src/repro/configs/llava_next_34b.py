"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling [hf:llava-hf/llava-v1.6-34b-hf; unverified].

The vision tower + anyres tiling is a STUB: ``input_specs()`` supplies
precomputed patch embeddings (B, img_tokens, d_model) prepended to the
text sequence; img_tokens=2880 covers the 672x672 anyres grid
(5 tiles x 24x24 patches).
"""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64_000,
    layer_pattern=(ATTN,),
    img_tokens=2880,
    rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    arch_id="llava-next-34b-smoke",
    family="vlm",
    n_layers=4,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    layer_pattern=(ATTN,),
    img_tokens=16,
    rope_theta=5_000_000.0,
)
