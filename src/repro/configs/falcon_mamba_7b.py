"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free mamba-1 blocks,
ssm_state=16, vocab=65024 [arXiv:2410.05355; unverified]."""

from repro.models.config import MAMBA, ModelConfig

CONFIG = ModelConfig(
    arch_id="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65_024,
    layer_pattern=(MAMBA,),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
)

SMOKE = ModelConfig(
    arch_id="falcon-mamba-7b-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=256,
    layer_pattern=(MAMBA,),
    ssm_state=8,
    ssm_conv=4,
    ssm_expand=2,
)
