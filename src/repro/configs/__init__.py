"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the full published config;
``get_smoke_config(arch_id)`` returns the reduced same-family config used
by CPU smoke tests (small widths/depths, tiny vocab, few experts).
"""

from __future__ import annotations

import importlib

ARCHS = (
    "musicgen-large",
    "gemma2-2b",
    "stablelm-12b",
    "starcoder2-15b",
    "qwen1.5-32b",
    "recurrentgemma-9b",
    "olmoe-1b-7b",
    "qwen2-moe-a2.7b",
    "falcon-mamba-7b",
    "llava-next-34b",
)


def _module(arch_id: str):
    name = arch_id.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _module(arch_id).SMOKE


def all_configs():
    return {a: get_config(a) for a in ARCHS}
