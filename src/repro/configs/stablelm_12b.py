"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 [hf:stabilityai/stablelm-2-12b; hf].  LayerNorm, SwiGLU,
RoPE."""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100_352,
    layer_pattern=(ATTN,),
    norm="layernorm",
)

SMOKE = ModelConfig(
    arch_id="stablelm-12b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=256,
    layer_pattern=(ATTN,),
    norm="layernorm",
)
