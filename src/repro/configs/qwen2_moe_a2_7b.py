"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].  QKV bias like the Qwen dense family.
"""

from repro.models.config import MOE, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151_936,
    layer_pattern=(MOE,),
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_expert=1408,
    qkv_bias=True,
)

SMOKE = ModelConfig(
    arch_id="qwen2-moe-a2.7b-smoke",
    family="moe",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=256,
    layer_pattern=(MOE,),
    n_experts=6,
    top_k=2,
    n_shared_experts=2,
    d_expert=64,
    qkv_bias=True,
)
