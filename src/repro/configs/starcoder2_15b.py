"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf].  GELU MLP, LayerNorm,
learned attention biases (qkv_bias=True per released config)."""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49_152,
    layer_pattern=(ATTN,),
    act="gelu",
    norm="layernorm",
    mlp_gated=False,
    qkv_bias=True,
)

SMOKE = ModelConfig(
    arch_id="starcoder2-15b-smoke",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=256,
    layer_pattern=(ATTN,),
    act="gelu",
    norm="layernorm",
    mlp_gated=False,
    qkv_bias=True,
)
