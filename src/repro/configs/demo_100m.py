"""demo-100m: ~100M-parameter decoder-only LM for the end-to-end training
example (not an assigned architecture)."""

from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    arch_id="demo-100m",
    family="dense",
    n_layers=8,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=32_000,
)

SMOKE = CONFIG
