"""Assigned input-shape sets and their step kinds.

LM-transformer shapes (applies to every assigned arch):
    train_4k     seq 4,096   x global_batch 256   -> train_step
    prefill_32k  seq 32,768  x global_batch 32    -> prefill_step
    decode_32k   seq 32,768  x global_batch 128   -> serve_step (1 token,
                                                     KV cache of 32k)
    long_500k    seq 524,288 x global_batch 1     -> serve_step; only for
                 sub-quadratic archs (ssm / hybrid / local+global decode)
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs for which long_500k applies (sub-quadratic sequence handling, per
# DESIGN.md S5): SSM, hybrid, and gemma2's alternating local/global whose
# decode step is O(window) local + O(S) memory-bound global reads.
LONG_CONTEXT_ARCHS = {"falcon-mamba-7b", "recurrentgemma-9b", "gemma2-2b"}


def applicable_shapes(arch_id: str) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch_id in LONG_CONTEXT_ARCHS:
        out.append("long_500k")
    return out
