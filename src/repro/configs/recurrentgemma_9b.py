"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 2:1 pattern.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, head_dim 256,
lru_width 4096, local window 2048 [arXiv:2402.19427; unverified].
Pattern = (recurrent, recurrent, local attention) x 12 + 2 recurrent tail.
"""

from repro.models.config import ATTN_LOCAL, RECURRENT, ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    layer_pattern=(RECURRENT, RECURRENT, ATTN_LOCAL),
    window_size=2048,
    lru_width=4096,
    act="gelu",
    scale_embeddings=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    arch_id="recurrentgemma-9b-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    layer_pattern=(RECURRENT, RECURRENT, ATTN_LOCAL),
    window_size=16,
    lru_width=64,
    act="gelu",
    scale_embeddings=True,
    tie_embeddings=True,
)
