"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the host-device override before ANY jax import (jax locks the
device count on first init).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, get_config  # noqa: E402
from repro.configs.shapes import SHAPES, applicable_shapes  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.train.optimizer import AdamWConfig, init_opt_state  # noqa: E402
from repro.train.steps import (  # noqa: E402
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.utils import hlo as hlo_util  # noqa: E402
from repro.utils import flops as flops_util  # noqa: E402


def _abstract(tree, shardings):
    """ShapeDtypeStructs with shardings attached (no allocation)."""
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        tree, shardings)


def input_specs(cfg, shape, mesh):
    """Abstract model inputs for one shape — the dry-run's stand-ins."""
    b, s = shape.global_batch, shape.seq_len
    dp_size = 1
    for a in shd.dp_axes(mesh):
        dp_size *= mesh.shape[a]
    # batch=1 long-context decode cannot shard the batch axis
    dp = shd.batch_pspec(mesh) if b % dp_size == 0 else P(None)
    out = {}
    if shape.kind in ("train", "prefill"):
        s_text = s - (cfg.img_tokens if cfg.family == "vlm" else 0)
        tshape = (b, s_text, cfg.num_codebooks) if cfg.num_codebooks \
            else (b, s_text)
        tsh = NamedSharding(mesh, P(*dp, *([None] * (len(tshape) - 1))))
        out["tokens"] = jax.ShapeDtypeStruct(tshape, jnp.int32, sharding=tsh)
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct(tshape, jnp.int32, sharding=tsh)
        if cfg.family == "vlm":
            ish = NamedSharding(mesh, P(*dp, None, None))
            out["img_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.img_tokens, cfg.d_model), jnp.bfloat16, sharding=ish)
    else:  # decode
        tshape = (b, 1, cfg.num_codebooks) if cfg.num_codebooks else (b, 1)
        tsh = NamedSharding(mesh, P(*dp, *([None] * (len(tshape) - 1))))
        out["tokens"] = jax.ShapeDtypeStruct(tshape, jnp.int32, sharding=tsh)
    return out


def baseline_grad_accum(shape, mesh) -> int:
    dp = 1
    for a in shd.dp_axes(mesh):
        dp *= mesh.shape[a]
    per_dev = shape.global_batch // dp
    return max(per_dev // 2, 1)  # microbatch of 2 sequences per device


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               grad_accum: int | None = None, donate: bool = True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size

    aparams = lm.abstract_params(cfg)
    pspecs = shd.param_pspecs(aparams)
    pspecs = shd.validate_pspecs(pspecs, aparams, mesh)
    p_sh = shd.named(mesh, pspecs)
    aparams = _abstract(aparams, p_sh)
    inputs = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        accum = grad_accum or baseline_grad_accum(shape, mesh)
        aopt = jax.eval_shape(init_opt_state, aparams)
        ospecs = {"step": P(),
                  "m": shd.zero1_pspecs(aparams, pspecs, mesh),
                  "v": shd.zero1_pspecs(aparams, pspecs, mesh)}
        ospecs = {"step": P(),
                  "m": shd.validate_pspecs(ospecs["m"], aopt["m"], mesh),
                  "v": shd.validate_pspecs(ospecs["v"], aopt["v"], mesh)}
        o_sh = shd.named(mesh, ospecs)
        aopt = _abstract(aopt, o_sh)
        step = make_train_step(cfg, AdamWConfig(), grad_accum=accum)
        jitted = jax.jit(step, donate_argnums=(0, 1) if donate else ())
        with mesh:
            lowered = jitted.lower(aparams, aopt, inputs)
        extra = {"grad_accum": accum}
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, max_len=shape.seq_len)
        jitted = jax.jit(step)
        with mesh:
            if cfg.family == "vlm":
                lowered = jitted.lower(aparams, inputs["tokens"],
                                       inputs["img_embeds"])
            else:
                lowered = jitted.lower(aparams, inputs["tokens"])
        extra = {}
    else:  # decode
        shard_seq = shape.global_batch == 1
        acache = jax.eval_shape(
            lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len))
        cspecs = shd.cache_pspecs(cfg, acache, mesh, shard_seq=shard_seq)
        c_sh = shd.named(mesh, cspecs)
        acache = _abstract(acache, c_sh)
        step = make_decode_step(cfg)
        jitted = jax.jit(step, donate_argnums=(2,) if donate else ())
        cur = jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
        with mesh:
            lowered = jitted.lower(aparams, inputs["tokens"], acache, cur)
        extra = {"shard_seq": shard_seq}
    return cfg, shape, mesh, lowered, extra


def analyze(cfg, shape, mesh, lowered, extra) -> dict:
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    rec = {"arch": cfg.arch_id, "shape": shape.name,
           "mesh": list(mesh.devices.shape), "chips": mesh.size,
           "kind": shape.kind, "compile_s": round(compile_s, 1), **extra}

    # raw XLA numbers kept for reference; NOTE they count while (scan)
    # bodies once (verified in tests/test_roofline.py) so the roofline
    # terms below use the analytic model + loop-aware collective parsing.
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["xla_flops_raw"] = float(ca.get("flops", 0.0))
        rec["xla_bytes_raw"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = str(e)

    model_shards = mesh.shape["model"]
    cost = flops_util.cell_cost(
        cfg, shape, chips=mesh.size, model_shards=model_shards,
        grad_accum=extra.get("grad_accum", 1), remat=True,
        window_cache=extra.get("window_cache", False))
    rec["flops_per_chip"] = cost.flops_per_chip
    rec["hbm_bytes_per_chip"] = cost.hbm_bytes_per_chip

    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                rec[attr] = int(v)
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = str(e)

    text = compiled.as_text()
    stats = hlo_util.collective_stats(text)
    rec["collective_counts"] = stats.counts
    rec["collective_bytes_by_kind"] = {k: int(v)
                                       for k, v in stats.bytes_by_kind.items()}
    rec["wire_bytes_raw"] = float(stats.total_wire_bytes)
    # TPU-width adjustment for XLA:CPU's bf16->f32 upcast artifact
    rec["wire_bytes_per_chip"] = float(stats.tpu_adjusted_wire_bytes)

    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * shape.global_batch
    roof = hlo_util.Roofline(
        flops=rec["flops_per_chip"], hbm_bytes=rec["hbm_bytes_per_chip"],
        wire_bytes=rec["wire_bytes_per_chip"], model_flops=model_flops,
        chips=mesh.size)
    rec["roofline"] = roof.to_dict()
    return rec


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             grad_accum: int | None = None) -> dict:
    cfg, shape, mesh, lowered, extra = lower_cell(
        arch, shape_name, multi_pod, grad_accum=grad_accum)
    rec = analyze(cfg, shape, mesh, lowered, extra)
    os.makedirs(out_dir, exist_ok=True)
    tag = "multipod" if multi_pod else "pod"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[dryrun] {arch} x {shape_name} x {tag}: "
          f"dominant={rec['roofline']['dominant']} "
          f"compute={rec['roofline']['compute_s']:.4f}s "
          f"memory={rec['roofline']['memory_s']:.4f}s "
          f"collective={rec['roofline']['collective_s']:.4f}s "
          f"(compile {rec['compile_s']}s)")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=list(ARCHS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)

    cells = []
    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    for a in archs:
        shapes = applicable_shapes(a) if (args.all or args.shape is None) \
            else [args.shape]
        for s in shapes:
            meshes = [False, True] if (args.all or args.both_meshes) \
                else [args.multi_pod]
            for mp in meshes:
                cells.append((a, s, mp))

    failures = []
    for a, s, mp in cells:
        try:
            run_cell(a, s, mp, args.out, grad_accum=args.grad_accum)
        except Exception as e:
            failures.append((a, s, mp, repr(e)))
            print(f"[dryrun] FAILED {a} x {s} x {'multipod' if mp else 'pod'}: {e}")
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")


if __name__ == "__main__":
    main()
