"""Batched serving driver: prefill a batch of prompts, decode new tokens.

Runs the smoke configs for real on CPU; the full configs lower under the
production mesh via the dry-run (decode_32k / long_500k shapes).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import lm
from repro.train.steps import make_decode_step, make_prefill_step


def serve(arch: str, batch: int = 4, prompt_len: int = 32,
          gen_tokens: int = 16, smoke: bool = True, seed: int = 0,
          greedy: bool = True) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    key = jax.random.PRNGKey(seed)
    params = lm.init_params(cfg, key)
    max_len = prompt_len + gen_tokens + (cfg.img_tokens or 0)

    tshape = (batch, prompt_len, cfg.num_codebooks) if cfg.num_codebooks \
        else (batch, prompt_len)
    prompts = jax.random.randint(key, tshape, 0, cfg.vocab_size)
    img = None
    if cfg.family == "vlm":
        img = jax.random.normal(key, (batch, cfg.img_tokens, cfg.d_model),
                                jnp.bfloat16)

    prefill_fn = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode_fn = jax.jit(make_decode_step(cfg))

    t0 = time.time()
    if img is not None:
        logits, cache = prefill_fn(params, prompts, img)
    else:
        logits, cache = prefill_fn(params, prompts)
    prefill_s = time.time() - t0

    def sample(lg):
        tok = jnp.argmax(lg, axis=-1)
        return tok.astype(jnp.int32)

    cur = prompt_len + (cfg.img_tokens or 0)
    tok = sample(logits)                      # (B, 1[, K])
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(gen_tokens - 1):
        logits, cache = decode_fn(params, tok, cache, jnp.int32(cur + i))
        tok = sample(logits)
        out_tokens.append(np.asarray(tok))
    decode_s = time.time() - t0
    toks = np.concatenate(out_tokens, axis=1)
    return {"tokens": toks, "prefill_s": prefill_s, "decode_s": decode_s,
            "tok_per_s": batch * (gen_tokens - 1) / max(decode_s, 1e-9)}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma2-2b", choices=list(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen_tokens=args.gen_tokens, smoke=not args.full)
    print(f"[serve] generated {out['tokens'].shape} tokens; "
          f"prefill {out['prefill_s']:.2f}s, "
          f"{out['tok_per_s']:.1f} tok/s decode")


if __name__ == "__main__":
    main()
