"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4, *, multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (requires >= n devices)."""
    if multi_pod:
        return jax.make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_fleet_mesh(n_devices: int | None = None):
    """1-D mesh over the DIAL fleet (scenario-batch) axis.

    Thin launch-side alias of
    :func:`repro.distributed.sharding.fleet_mesh`: all local devices by
    default, the first ``n_devices`` otherwise.  On CPU, force visible
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    before jax initializes (see the module docstring).
    """
    from repro.distributed.sharding import fleet_mesh

    return fleet_mesh(n_devices)
