"""End-to-end training driver.

Wires together: model (any --arch, full or smoke config), AdamW,
DIAL-tuned data pipeline through the simulated PFS, checkpoint manager
(save/restore through the PFS write path), and fault-tolerant resume.

On this CPU container it runs the *smoke* configs for real (the examples
train a ~100M-param model for a few hundred steps); on a TPU cluster the
same driver takes the full configs under the production mesh (the
lowering is what the dry-run certifies).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core.model import DIALModel
from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataPipeline, PipelineConfig
from repro.models import lm
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.steps import make_train_step


def train(arch: str, steps: int = 50, smoke: bool = True,
          batch: int = 8, seq_len: int = 128, ckpt_dir: str | None = None,
          ckpt_every: int = 25, dial_model_path: str | None = "models/dial",
          n_hosts: int = 4, grad_accum: int = 1, seed: int = 0,
          resume: bool = True, log_every: int = 10,
          peak_lr: float | None = None) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)

    dial = None
    if dial_model_path:
        try:
            dial = DIALModel.load(dial_model_path)
        except FileNotFoundError:
            print("[train] no DIAL model found; pipeline runs untuned")

    pipe = DataPipeline(PipelineConfig(
        global_batch=batch, seq_len=seq_len, vocab_size=cfg.vocab_size,
        n_hosts=n_hosts, num_codebooks=cfg.num_codebooks, seed=seed),
        dial_model=dial)

    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(params)
    # Short smoke runs need a schedule that can actually move the weights:
    # at the production peak (3e-4) a 15-step run travels ~4.5e-3 in
    # parameter space and the loss sits flat.  Scale the peak up for smoke
    # runs under ~200 steps (capped at 1e-2); production (smoke=False)
    # always trains at the paper's 3e-4 unless peak_lr is passed.
    # Resuming a checkpoint replays identical LRs as long as the resumed
    # run uses the same `steps` (the schedule is a function of steps).
    if peak_lr is None:
        peak_lr = 3e-4
        if smoke:
            peak_lr = float(min(1e-2, 3e-4 * max(1.0, 200.0 / max(steps, 1))))
    opt_cfg = AdamWConfig(peak_lr=peak_lr, min_lr=peak_lr / 10.0,
                          total_steps=steps,
                          warmup_steps=max(steps // 20, 5))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=grad_accum))

    mgr = None
    start = 0
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, sim=pipe.sim,
                                hosts=list(range(n_hosts)))
        if resume:
            restored = mgr.restore_latest(params, opt_state)
            if restored is not None:
                start, params, opt_state, meta = restored
                pipe.load_state_dict(meta.get("extra", {}).get(
                    "pipeline", {"step_index": start}))
                print(f"[train] resumed from step {start}")

    losses = []
    t0 = time.time()
    img = None
    if cfg.family == "vlm":
        img = jnp.zeros((batch, cfg.img_tokens, cfg.d_model), jnp.bfloat16)
    for step in range(start, steps):
        np_batch = pipe.next_batch()
        jbatch = {k: jnp.asarray(v) for k, v in np_batch.items()}
        if img is not None:
            jbatch["img_embeds"] = img
        params, opt_state, metrics = step_fn(params, opt_state, jbatch)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"ingest {pipe.ingest_throughput() / 1e6:.0f} MB/s")
        if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, params, opt_state,
                     extra={"pipeline": pipe.state_dict()})

    return {"losses": losses, "params": params, "opt_state": opt_state,
            "pipeline": pipe, "wall_s": time.time() - t0,
            "ingest_mbs": pipe.ingest_throughput() / 1e6}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="gemma2-2b", choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (TPU-scale)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--dial-model", default="models/dial")
    ap.add_argument("--no-dial", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = train(args.arch, steps=args.steps, smoke=not args.full,
                batch=args.batch, seq_len=args.seq_len,
                ckpt_dir=args.ckpt_dir, grad_accum=args.grad_accum,
                dial_model_path=None if args.no_dial else args.dial_model,
                seed=args.seed)
    print(f"[train] done: final loss {out['losses'][-1]:.4f}, "
          f"{out['wall_s']:.1f}s wall, ingest {out['ingest_mbs']:.0f} MB/s")


if __name__ == "__main__":
    main()
