"""Continual learning: replay buffers, drift detection, periodic refit.

DIAL's offline model is trained once against healthy-cluster campaign
data, so scenarios whose storage system *drifts* mid-run (a degraded or
failing OST, new tenants) tune with a stale model.  This module holds
the pieces that let a running lab scenario retrain in place:

``ReplayBuffer``
    fixed-capacity ring buffer of (feature row, label) pairs per op —
    bounded memory, recency-biased, numpy end to end;
``DriftDetector``
    a fast/slow throughput EMA pair; when the fast estimate falls below
    ``drop_frac`` of the slow one the world has shifted under the model;
``OnlineTrainer``
    owns the buffers + detector + refit schedule and swaps freshly
    trained forests (one vmapped :func:`repro.learn.boost.fit_forest
    _batch` launch) into a live :class:`~repro.core.model.DIALModel`.

The lab wiring (label collection on the tuning loop, the frozen-vs-
online comparison) lives in :mod:`repro.lab.continual`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.gbdt import GBDTParams
from repro.pfs.engine import READ, WRITE


class ReplayBuffer:
    """Fixed-capacity FIFO ring of (feature row, label) samples."""

    def __init__(self, capacity: int, dim: int):
        self.capacity = int(capacity)
        self.X = np.zeros((self.capacity, dim), dtype=np.float32)
        self.y = np.zeros(self.capacity)
        self._pos = 0
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def add(self, X: np.ndarray, y: np.ndarray) -> None:
        """Append rows, overwriting the oldest once full."""
        X = np.atleast_2d(np.asarray(X, dtype=np.float32))
        y = np.atleast_1d(np.asarray(y, dtype=np.float64))
        if len(X) >= self.capacity:           # keep the newest tail
            X, y = X[-self.capacity:], y[-self.capacity:]
        n = len(X)
        end = min(self._pos + n, self.capacity)
        k = end - self._pos
        self.X[self._pos:end] = X[:k]
        self.y[self._pos:end] = y[:k]
        if k < n:                              # wrap around
            self.X[:n - k] = X[k:]
            self.y[:n - k] = y[k:]
        self._pos = (self._pos + n) % self.capacity
        self._size = min(self._size + n, self.capacity)

    def dataset(self) -> tuple[np.ndarray, np.ndarray]:
        """Copy of the live contents (order is immaterial to the GBDT)."""
        return self.X[:self._size].copy(), self.y[:self._size].copy()


class DriftDetector:
    """Throughput drift as a fast/slow EMA divergence.

    ``update`` folds one interval's throughput into both EMAs and
    returns True when the fast estimate sits below ``drop_frac`` of the
    slow one (after ``warmup`` intervals) — i.e. recent throughput fell
    off the long-run trend the current model was coping with.
    """

    def __init__(self, fast: float = 0.5, slow: float = 0.08,
                 drop_frac: float = 0.75, warmup: int = 6):
        self.alpha_fast = fast
        self.alpha_slow = slow
        self.drop_frac = drop_frac
        self.warmup = warmup
        self.reset()

    def reset(self, level: float | None = None) -> None:
        self._fast = self._slow = level
        self._n = 0

    def update(self, tput: float) -> bool:
        tput = float(tput)
        if self._fast is None:
            self._fast = self._slow = tput
        else:
            self._fast += self.alpha_fast * (tput - self._fast)
            self._slow += self.alpha_slow * (tput - self._slow)
        self._n += 1
        return (self._n > self.warmup and self._slow > 0
                and self._fast < self.drop_frac * self._slow)


@dataclasses.dataclass
class OnlinePolicy:
    """When and how the online trainer refits."""

    refit_every: int = 0        # periodic refit cadence in intervals; 0 = off
    min_samples: int = 48       # per-op floor before an op's forest refits
    capacity: int = 4096        # replay-buffer rows per op
    cooldown: int = 6           # min intervals between refits
    explore_eps: float = 0.15   # lab-side epsilon-greedy exploration rate
    drift_drop_frac: float = 0.75
    drift_fast: float = 0.5
    drift_slow: float = 0.08
    drift_warmup: int = 6


class OnlineTrainer:
    """Buffers + drift trigger + refit schedule around a live model.

    Call :meth:`observe` with labeled rows as they materialize and
    :meth:`step` once per tuning interval with that interval's
    throughput; ``step`` returns a refit record (or None) after swapping
    retrained forests into the model in place — every open reference to
    the :class:`DIALModel` (e.g. a running ``FleetAgent``) scores with
    the new forests from the next interval on.
    """

    def __init__(self, model, gbdt_params: GBDTParams | None = None,
                 policy: OnlinePolicy | None = None,
                 hist_backend: str = "matmul", precision: str = "fast"):
        from repro.core.metrics import feature_dim

        self.model = model
        self.params = gbdt_params or GBDTParams(n_trees=40, max_depth=5)
        self.policy = policy if policy is not None else OnlinePolicy()
        self.hist_backend = hist_backend
        # float32 training is the production refit configuration: a live
        # run needs refit latency, not bit-parity with the numpy loop
        self.precision = precision
        self.buffers = {op: ReplayBuffer(self.policy.capacity,
                                         feature_dim(op, model.k))
                        for op in (READ, WRITE)}
        self.detector = DriftDetector(fast=self.policy.drift_fast,
                                      slow=self.policy.drift_slow,
                                      drop_frac=self.policy.drift_drop_frac,
                                      warmup=self.policy.drift_warmup)
        self._interval = 0
        # periodic cadence and cooldown both count from the run start, so
        # the first refit cannot fire on a handful of warmup samples
        self._last_refit = 0
        self.refits: list[dict] = []

    # ------------------------------------------------------------------ #
    def observe(self, op: int, X: np.ndarray, y: np.ndarray) -> None:
        if len(np.atleast_1d(y)):
            self.buffers[op].add(X, y)

    def seed(self, data: dict) -> None:
        """Warm-start the buffers from campaign data
        (``{'read': (X, y), 'write': (X, y)}``)."""
        for name, op in (("read", READ), ("write", WRITE)):
            X, y = data[name]
            if len(X):
                self.buffers[op].add(X, y)

    # ------------------------------------------------------------------ #
    def step(self, tput: float) -> dict | None:
        """One interval heartbeat: update drift, maybe refit."""
        self._interval += 1
        drifted = self.detector.update(tput)
        due = (self.policy.refit_every > 0
               and self._interval - self._last_refit
               >= self.policy.refit_every)
        cooled = self._interval - self._last_refit >= self.policy.cooldown
        if not ((drifted or due) and cooled):
            return None
        ops = [op for op in (READ, WRITE)
               if len(self.buffers[op]) >= self.policy.min_samples]
        if not ops:
            return None
        return self._refit(ops, "drift" if drifted else "periodic", tput)

    def _refit(self, ops: list[int], reason: str, tput: float) -> dict:
        from repro.learn.boost import fit_forest_batch

        datasets = [self.buffers[op].dataset() for op in ops]
        forests = fit_forest_batch(datasets, self.params,
                                   hist_backend=self.hist_backend,
                                   precision=self.precision)
        kw = {("read_forest" if op == READ else "write_forest"): f
              for op, f in zip(ops, forests)}
        self.model.update_forests(**kw)
        self._last_refit = self._interval
        self.detector.reset(tput)   # the regime the new model trained on
        rec = {"interval": self._interval, "reason": reason,
               "ops": ["read" if op == READ else "write" for op in ops],
               "samples": {("read" if op == READ else "write"):
                           len(self.buffers[op]) for op in ops}}
        self.refits.append(rec)
        return rec
