"""On-device GBDT training (the learn layer).

The offline trainer in :mod:`repro.core.gbdt` is a sequential numpy
loop — fine for one overnight campaign, useless for continual in-lab
retraining.  This package re-expresses the identical histogram-boosting
algorithm as a fixed-shape array program:

``boost``
    grows a whole :class:`~repro.core.gbdt.DenseForest` under ``jit``
    (``lax.scan`` over trees, level-synchronous ``lax.fori_loop`` over
    depths, per-level reductions on
    :mod:`repro.kernels.tree_histogram`) and ``vmap``-s over forests so
    the read+write pair — or a whole hyperparameter sweep — trains in
    one launch;
``online``
    fixed-capacity replay buffers, a throughput-drift trigger, and the
    periodic-refit policy that lets a running lab scenario retrain its
    model mid-flight (``python -m repro.lab continual``).
"""

from repro.learn.boost import (fit_forest, fit_forest_batch,
                               train_models_jax)
from repro.learn.online import (DriftDetector, OnlinePolicy, OnlineTrainer,
                                ReplayBuffer)

__all__ = [
    "fit_forest",
    "fit_forest_batch",
    "train_models_jax",
    "ReplayBuffer",
    "DriftDetector",
    "OnlinePolicy",
    "OnlineTrainer",
]
