"""Jitted GBDT training: the whole forest as one fixed-shape program.

:class:`~repro.core.gbdt.GBDTClassifier` grows trees with Python loops
over trees x depths x features — the one stage of the collect -> train
-> evaluate pipeline that could not ride the fused JAX engine.  Because
the exported :class:`~repro.core.gbdt.DenseForest` is a *complete*
binary tree of static depth D, growth itself is level-synchronous and
fixed-shape:

* every boosting level is one multi-channel histogram reduction
  (:mod:`repro.kernels.tree_histogram`: gradient / hessian per
  (node, feature, bin) cell) followed by dense cumsum/argmax gain math;
  the default ``matmul`` strategy hoists the static bin one-hot out of
  the whole forest, and every strategy halves its work with the
  sibling-subtraction trick (left children reduced from samples,
  right = parent - left);
* the depth loop is unrolled level-synchronously over the static D
  levels, each with its exact ``2^d`` node count;
* the tree loop is a ``lax.scan`` carrying the margin vector;
* a whole *batch* of forests (the read+write pair, or a campaign
  hyperparameter sweep) trains in one ``vmap``-ed launch — datasets are
  padded to a common shape with zero-weight rows and inert features.

Split selection replicates the numpy trainer decision-for-decision:
identical quantile binning (:func:`repro.core.gbdt.quantile_edges` /
:func:`~repro.core.gbdt.bin_codes` — the same code path), identical
XGBoost gain, identical first-occurrence tie-breaking (lowest feature,
then lowest bin), identical pass-through / empty-leaf inheritance, and
the identical subsample mask stream, so ``fit_forest`` reproduces
``GBDTClassifier.fit`` splits and leaves to float tolerance
(``tests/test_learn.py`` pins <= 1e-5).
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.gbdt import (GAIN_DECIMALS, DenseForest, GBDTParams,
                             bin_codes, quantile_edges)

_INT32_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------- #
# numpy-side preparation: binning, padding, subsample masks
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class BinnedDataset:
    """One dataset in the fixed-shape layout the jitted trainer consumes.

    ``edges_pad`` is the per-feature edge table padded to ``n_bins - 1``
    columns with ``+inf``; ``bin_count[f]`` is the real number of bins
    (``len(edges[f]) + 1``), so valid split bins are ``b < bin_count - 1``.
    ``valid`` marks real rows (padding rows carry zero weight and zero
    count everywhere).
    """

    Xb: np.ndarray          # (n, F) int32 bin codes
    edges_pad: np.ndarray   # (F, n_bins - 1) float64
    bin_count: np.ndarray   # (F,) int32
    y: np.ndarray           # (n,) float64
    valid: np.ndarray       # (n,) float64 1/0
    base: float             # log-odds base score
    n_features: int         # pre-padding feature count
    n_rows: int             # pre-padding row count


def bin_dataset(X: np.ndarray, y: np.ndarray, n_bins: int) -> BinnedDataset:
    """Quantile-bin one dataset (the numpy trainer's exact binning)."""
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    n, n_feat = X.shape
    edges = quantile_edges(X, n_bins)
    Xb = bin_codes(X, edges).astype(np.int32)
    edges_pad = np.full((n_feat, n_bins - 1), np.inf)
    for f, e in enumerate(edges):
        edges_pad[f, :len(e)] = e
    bin_count = np.array([len(e) + 1 for e in edges], dtype=np.int32)
    pos = y.mean()
    base = float(np.log(max(pos, 1e-6) / max(1 - pos, 1e-6)))
    return BinnedDataset(Xb=Xb, edges_pad=edges_pad, bin_count=bin_count,
                         y=y, valid=np.ones(n), base=base,
                         n_features=n_feat, n_rows=n)


def pad_dataset(ds: BinnedDataset, n: int, n_feat: int) -> BinnedDataset:
    """Pad to ``(n, n_feat)``: extra rows are zero-weight, extra features
    are single-bin (never splittable), so padding changes nothing."""
    dn, dF = ds.Xb.shape
    if (dn, dF) == (n, n_feat):
        return ds
    Xb = np.zeros((n, n_feat), dtype=np.int32)
    Xb[:dn, :dF] = ds.Xb
    edges_pad = np.full((n_feat, ds.edges_pad.shape[1]), np.inf)
    edges_pad[:dF] = ds.edges_pad
    bin_count = np.ones(n_feat, dtype=np.int32)
    bin_count[:dF] = ds.bin_count
    y = np.zeros(n)
    y[:dn] = ds.y
    valid = np.zeros(n)
    valid[:dn] = ds.valid
    return dataclasses.replace(ds, Xb=Xb, edges_pad=edges_pad,
                               bin_count=bin_count, y=y, valid=valid)


def sort_structs(Xb: np.ndarray,
                 n_bins: int) -> tuple[np.ndarray, np.ndarray]:
    """Static per-feature sample ordering for the ``cumsum`` histogram
    strategy: ``perm[f]`` sorts samples by feature f's bin code, and
    ``bnd[f, b]`` is the offset of bin b's first sample in that order —
    both fixed for a whole training run (bin codes never change)."""
    perm = np.argsort(Xb, axis=0, kind="stable").astype(np.int32).T
    sorted_codes = np.take_along_axis(Xb, perm.T.astype(np.int64), axis=0)
    bnd = np.stack([np.searchsorted(sorted_codes[:, f],
                                    np.arange(n_bins + 1))
                    for f in range(Xb.shape[1])]).astype(np.int32)
    return perm, bnd                   # (F, n), (F, n_bins + 1)


def subsample_masks(params: GBDTParams, n_rows: int, n: int) -> np.ndarray:
    """The numpy trainer's per-tree subsample stream, padded to ``n``
    columns (padding rows always masked out)."""
    masks = np.zeros((params.n_trees, n))
    if params.subsample < 1.0:
        rng = np.random.default_rng(params.seed)
        masks[:, :n_rows] = (rng.random((params.n_trees, n_rows))
                             < params.subsample)
    else:
        masks[:, :n_rows] = 1.0
    return masks


# ---------------------------------------------------------------------- #
# the jitted trainer
# ---------------------------------------------------------------------- #
def _grow_forest(Xb, edges_pad, bin_count, y, valid, masks, perm, bnd,
                 base, lr, lam, min_gain, min_child_hess, *,
                 max_depth: int, hist_backend: str, precision: str):
    """Grow one forest; pure and traceable (vmap over every array arg).

    Shapes: ``Xb (n, F)``, ``edges_pad (F, NB-1)``, ``bin_count (F,)``,
    ``y/valid (n,)``, ``masks (T, n)``, ``perm (F, n)`` / ``bnd
    (F, NB+1)`` (the :func:`sort_structs` orderings, used by the
    ``cumsum`` strategy); scalars are traced (sweepable under vmap).
    Returns ``(feature (T, 2^D-1) int32, threshold (T, 2^D-1) f32,
    leaf (T, 2^D) f32)``.

    The depth loop is unrolled (D is tiny and static) so every level
    carries its exact ``2^d`` node count, and levels d >= 1 use the
    sibling-subtraction trick: only *left*-child histograms are reduced
    from samples (right-child samples park on the drop id), the right
    halves come free as ``parent - left``.

    Histogram strategies (``hist_backend``): ``matmul`` (default) is
    the one-hot GEMM with the bin one-hot hoisted across the forest —
    the fastest option under XLA CPU, whose scatter-add runs tens of
    ns per element; ``cumsum`` masks each node's samples in the
    per-feature bin-sorted order, prefix-sums them, and reads bin
    totals off the static boundary offsets — O(nodes * F * n), for
    accelerators with fast associative scans; anything else resolves
    through :func:`make_tree_histogram` (``jax`` scatter-add,
    ``pallas`` kernel, ...).

    ``precision="exact"`` (float64 under ``enable_x64``) replicates the
    numpy trainer split for split, including its quantized tie-breaking
    and float32-threshold partition quirks; ``"fast"`` runs everything
    in float32 and skips the quirk emulation — statistically equivalent
    forests (AUC-parity tested) at half the memory traffic, the
    production choice for online refits.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.tree_histogram.ops import (bin_onehot,
                                                  make_tree_histogram,
                                                  matmul_histogram)

    fast = precision == "fast"
    if fast:
        edges_pad = edges_pad.astype(jnp.float32)
        y = y.astype(jnp.float32)
        valid = valid.astype(jnp.float32)
        masks = masks.astype(jnp.float32)
    n, n_feat = Xb.shape
    n_bins = edges_pad.shape[1] + 1
    n_internal = 2 ** max_depth - 1
    n_leaves = 2 ** max_depth
    dt = edges_pad.dtype

    Xb = Xb.astype(jnp.int32)
    split_ok = (jnp.arange(n_bins - 1)[None, :]
                < (bin_count[:, None] - 1))          # (F, NB-1)
    leaf_j = jnp.arange(n_leaves)

    if hist_backend == "cumsum":
        perm = perm.astype(jnp.int32)

        def make_hist(gh2):
            vperm = gh2[:, perm]                     # (C, F, n) per tree
            c = vperm.shape[0]

            def hist_fn(node_ids, n_rows):
                idsp = node_ids[perm]                # (F, n) sorted order
                sel = (idsp[None, :, :]
                       == jnp.arange(n_rows)[:, None, None])
                cs = jnp.cumsum(vperm[:, None] * sel[None], axis=-1)
                cs0 = jnp.concatenate(
                    [jnp.zeros_like(cs[..., :1]), cs], axis=-1)
                idx = jnp.broadcast_to(bnd[None, None],
                                       (c, n_rows) + bnd.shape)
                pref = jnp.take_along_axis(cs0, idx, axis=-1)
                return pref[..., 1:] - pref[..., :-1]

            return hist_fn
    elif hist_backend == "matmul":
        # hoist the static bin one-hot out of the whole forest: bin codes
        # never change across levels or trees, only node ids do
        onehot = bin_onehot(Xb, n_bins, dt)

        def make_hist(gh2):
            def hist_fn(node_ids, n_rows):
                return matmul_histogram(gh2, onehot, node_ids, n_rows,
                                        n_bins)

            return hist_fn
    else:
        generic = make_tree_histogram(hist_backend)

        def make_hist(gh2):
            def hist_fn(node_ids, n_rows):
                return generic(gh2, Xb, node_ids, n_rows,
                               n_bins).astype(dt)

            return hist_fn

    def tree_body(margin, mask):
        prob = 1.0 / (1.0 + jnp.exp(-jnp.clip(margin, -30.0, 30.0)))
        g = (prob - y) * mask
        h = jnp.maximum(prob * (1.0 - prob), 1e-6) * mask
        gh2 = jnp.stack([g, h])                      # (2, n)
        hist_fn = make_hist(gh2)

        node = jnp.zeros(n, dtype=jnp.int32)         # build partition
        mnode = jnp.zeros(n, dtype=jnp.int32)        # margin partition
        feat_parts, thr_parts = [], []
        hist = vals = None
        for d in range(max_depth):
            n_here = 1 << d
            level_start = n_here - 1
            loc = node - level_start                 # in [0, n_here)
            if d == 0:
                hist = hist_fn(jnp.zeros(n, dtype=jnp.int32), 1)
            else:
                half = n_here // 2
                left_ids = jnp.where(loc % 2 == 0, loc // 2, half
                                     ).astype(jnp.int32)
                left = hist_fn(left_ids, half)
                hist = jnp.stack([left, hist - left], axis=2
                                 ).reshape(2, n_here, n_feat, n_bins)
            gh, hh = hist[0], hist[1]                # (n_here, F, NB)
            GL = jnp.cumsum(gh, axis=-1)[..., :-1]
            HL = jnp.cumsum(hh, axis=-1)[..., :-1]
            G = gh.sum(-1)[..., None]
            H = hh.sum(-1)[..., None]
            GR, HR = G - GL, H - HL
            gain = 0.5 * (GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam)
                          - G ** 2 / (H + lam))
            ok = (split_ok[None] & (HL >= min_child_hess)
                  & (HR >= min_child_hess))
            gain = jnp.where(ok, gain, -jnp.inf)
            if not fast:
                gain = jnp.round(gain, GAIN_DECIMALS)  # backend-stable ties

            # first-occurrence argmax over the flattened (F, NB-1) grid ==
            # the numpy trainer's lowest-feature-then-lowest-bin tie-break
            flat = gain.reshape(n_here, -1)
            best = jnp.argmax(flat, axis=1)
            best_gain = jnp.take_along_axis(flat, best[:, None], 1)[:, 0]
            f_best = (best // (n_bins - 1)).astype(jnp.int32)
            b_best = (best % (n_bins - 1)).astype(jnp.int32)
            has_split = best_gain > min_gain         # -inf never passes

            # Newton value of every level node (pass-through spine values)
            g_sum = gh[:, 0, :].sum(-1)              # any feature's bins
            h_sum = hh[:, 0, :].sum(-1)              # sum to the node total
            vals = -lr * g_sum / (h_sum + lam)       # (n_here,)

            feat_level = jnp.where(has_split, f_best, 0)
            edge_val = edges_pad[f_best, b_best]
            thr_level = jnp.where(has_split, edge_val, jnp.inf)

            feat_parts.append(feat_level)
            thr_parts.append(thr_level)

            def descend(ptr, tb_level):
                lc = ptr - level_start
                f_node = feat_level[lc]
                tb_node = tb_level[lc]
                xb = jnp.take_along_axis(Xb, f_node[:, None], axis=1)[:, 0]
                return 2 * ptr + 1 + (xb > tb_node).astype(ptr.dtype)

            tb_margin = jnp.where(has_split, b_best, _INT32_MAX)
            if fast:
                # one partition: code > b  <=>  raw x > threshold
                node = mnode = descend(node, tb_margin)
            else:
                # The numpy trainer keeps thresholds in float32 and
                # recovers the partition bin with searchsorted(edges,
                # float32(thr)): when float32 rounds the edge *up*, the
                # build-time descend routes bin b+1 left, while the
                # margin-update descend (raw x > float32 thr) still
                # routes it right.  Replicate both: `node` follows the
                # build partition (histograms, leaves), `mnode` the
                # raw-threshold partition (margin updates).
                up = edge_val.astype(jnp.float32).astype(dt) > edge_val
                tb_build = jnp.where(has_split,
                                     b_best + up.astype(jnp.int32),
                                     _INT32_MAX)
                node = descend(node, tb_build)
                mnode = descend(mnode, tb_margin)

        # level-order concatenation == global node ids 0, 1-2, 3-6, ...
        feature = jnp.concatenate(feat_parts)
        threshold = jnp.concatenate(thr_parts)

        # leaves: Newton where occupied, direct-parent value where empty;
        # per-leaf sums are a dense (2^D, n) one-hot matvec — no bins
        loc = node - n_internal
        sel = (loc[None, :] == leaf_j[:, None]).astype(dt)   # (2^D, n)
        g_leaf = sel @ g
        h_leaf = sel @ h
        cnt = sel @ valid
        newton = -lr * g_leaf / (h_leaf + lam)
        leaf = jnp.where(cnt > 0, newton, vals[leaf_j // 2])
        return margin + leaf[mnode - n_internal], (feature, threshold, leaf)

    margin0 = jnp.full(n, base, dtype=dt)
    _, (features, thresholds, leaves) = jax.lax.scan(
        tree_body, margin0, masks)
    return (features, thresholds.astype(jnp.float32),
            leaves.astype(jnp.float32))


@functools.lru_cache(maxsize=None)
def _make_grow_fn(max_depth: int, hist_backend: str, batched: bool,
                  precision: str):
    """Jitted grower per (depth, histogram backend, batched, precision)
    signature; array shapes key jit's own cache."""
    import jax

    fn = functools.partial(_grow_forest, max_depth=max_depth,
                           hist_backend=hist_backend, precision=precision)
    if batched:
        fn = jax.vmap(fn)
    return jax.jit(fn)


def _x64_ctx(precision: str):
    import contextlib

    from jax.experimental import enable_x64

    return enable_x64() if precision == "exact" else contextlib.nullcontext()


def _check_hist_backend(hist_backend: str, precision: str) -> str:
    """Resolve ``auto`` and refuse combinations that cannot honor the
    exact-parity contract: the Pallas kernel accumulates in float32, so
    its histograms cannot back ``precision="exact"`` float64 gains."""
    if hist_backend == "auto":
        from repro.kernels.tree_histogram.ops import _default_jax_backend

        hist_backend = _default_jax_backend()
    if precision == "exact" and hist_backend.startswith("pallas"):
        raise ValueError(
            "hist_backend='pallas' accumulates histograms in float32 and "
            "cannot provide precision='exact' split parity; use "
            "precision='fast' or hist_backend='matmul'/'jax'")
    return hist_backend


# ---------------------------------------------------------------------- #
# public entry points
# ---------------------------------------------------------------------- #
def _scalar_args(p: GBDTParams):
    return (float(p.learning_rate), float(p.reg_lambda),
            float(p.min_gain), float(p.min_child_hess))


def fit_forest(X: np.ndarray, y: np.ndarray,
               params: GBDTParams | None = None,
               hist_backend: str = "matmul",
               precision: str = "exact") -> DenseForest:
    """Train one :class:`DenseForest` under jit — the drop-in counterpart
    of ``GBDTClassifier(params).fit(X, y).forest``."""
    import jax

    p = params or GBDTParams()
    hist_backend = _check_hist_backend(hist_backend, precision)
    ds = bin_dataset(X, y, p.n_bins)
    masks = subsample_masks(p, ds.n_rows, ds.n_rows)
    if hist_backend == "cumsum":
        perm, bnd = sort_structs(ds.Xb, p.n_bins)
    else:
        # unused traced args on the other strategies — don't pay the
        # O(n F log n) argsort (this runs on every online refit)
        perm = bnd = np.zeros((1, 1), dtype=np.int32)
    grow = _make_grow_fn(p.max_depth, hist_backend, False, precision)
    with _x64_ctx(precision):
        out = grow(ds.Xb, ds.edges_pad, ds.bin_count, ds.y, ds.valid,
                   masks, perm, bnd, ds.base, *_scalar_args(p))
        out = jax.tree.map(lambda a: a.block_until_ready(), out)
    feature, threshold, leaf = (np.asarray(a) for a in out)
    return DenseForest(feature=feature, threshold=threshold, leaf=leaf,
                       base_score=ds.base, depth=p.max_depth,
                       n_features=ds.n_features)


def fit_forest_batch(datasets, params: GBDTParams | list | None = None,
                     hist_backend: str = "matmul",
                     precision: str = "exact") -> list[DenseForest]:
    """Train B forests in one vmapped launch.

    ``datasets`` is a list of ``(X, y)`` pairs (row/feature counts may
    differ — they are padded to a common shape with inert rows and
    features).  ``params`` is one :class:`GBDTParams` for all forests or
    a per-forest list; continuous hyperparameters (``learning_rate``,
    ``reg_lambda``, ``min_gain``, ``min_child_hess``) may vary per
    forest and ride the vmap, while the structural ones (``n_trees``,
    ``max_depth``, ``n_bins``) must be shared.
    """
    import jax

    hist_backend = _check_hist_backend(hist_backend, precision)
    if params is None:
        params = GBDTParams()
    plist = (list(params) if isinstance(params, (list, tuple))
             else [params] * len(datasets))
    if len(plist) != len(datasets):
        raise ValueError("one GBDTParams per dataset (or a single shared)")
    p0 = plist[0]
    for p in plist[1:]:
        if (p.n_trees, p.max_depth, p.n_bins) != (p0.n_trees, p0.max_depth,
                                                  p0.n_bins):
            raise ValueError("structural params (n_trees, max_depth, "
                             "n_bins) must be shared across a batch")

    binned = [bin_dataset(X, y, p.n_bins)
              for (X, y), p in zip(datasets, plist)]
    n = max(ds.n_rows for ds in binned)
    n_feat = max(ds.n_features for ds in binned)
    padded = [pad_dataset(ds, n, n_feat) for ds in binned]
    masks = np.stack([subsample_masks(p, ds.n_rows, n)
                      for ds, p in zip(binned, plist)])
    if hist_backend == "cumsum":
        sorts = [sort_structs(ds.Xb, p0.n_bins) for ds in padded]
        perm = np.stack([s[0] for s in sorts])
        bnd = np.stack([s[1] for s in sorts])
    else:
        # unused traced args on the other strategies (vmap only needs
        # the leading batch axis) — skip the per-forest argsorts
        perm = bnd = np.zeros((len(padded), 1, 1), dtype=np.int32)

    def stack(attr):
        return np.stack([getattr(ds, attr) for ds in padded])

    scal = np.array([_scalar_args(p) for p in plist])   # (B, 4)
    base = np.array([ds.base for ds in binned])
    grow = _make_grow_fn(p0.max_depth, hist_backend, True, precision)
    with _x64_ctx(precision):
        out = grow(stack("Xb"), stack("edges_pad"), stack("bin_count"),
                   stack("y"), stack("valid"), masks, perm, bnd, base,
                   scal[:, 0], scal[:, 1], scal[:, 2], scal[:, 3])
        out = jax.tree.map(lambda a: a.block_until_ready(), out)
    features, thresholds, leaves = (np.asarray(a) for a in out)
    return [DenseForest(feature=features[i], threshold=thresholds[i],
                        leaf=leaves[i], base_score=binned[i].base,
                        depth=p0.max_depth,
                        n_features=binned[i].n_features)
            for i in range(len(binned))]


def train_models_jax(data: dict, gbdt_params: GBDTParams | None = None,
                     space=None, hist_backend: str = "matmul",
                     precision: str = "exact"):
    """The jax counterpart of :func:`repro.core.dataset.train_models`:
    the read and write forests train together in one vmapped launch."""
    from repro.core.config_space import SPACE
    from repro.core.model import DIALModel

    for op_name in ("read", "write"):
        if len(data[op_name][0]) == 0:
            raise ValueError(f"no {op_name} samples collected")
    fr, fw = fit_forest_batch([data["read"], data["write"]], gbdt_params,
                              hist_backend=hist_backend,
                              precision=precision)
    return DIALModel(read_forest=fr, write_forest=fw,
                     space=space or SPACE)
