"""Parameter tuning strategy — *Conditional Score Greedy* (paper Alg. 1).

Given the probability distribution the model assigns over Theta, the tuner

  1. keeps only configurations whose predicted probability of >=15%
     improvement exceeds tau (0.8 in the paper);
  2. MinMax-normalizes the surviving configurations;
  3. breaks ties *away from* greedy-safe choices with a regularizer that
     prefers larger theta values (larger RPCs utilize channels better,
     more RPCs in flight move more data in parallel — SIII-C), weighted
     by alpha (read) / beta (write):

         WriteScore(theta) = f(theta, H_t) * (1 + beta * sum(theta_norm))
         ReadScore(theta)  = f(theta, H_t) * (1 + alpha * theta1_norm)
                             + theta2_norm

If no configuration clears tau, the current configuration is kept — the
model sees no sufficiently-likely win, so DIAL does not thrash.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.config_space import ConfigSpace, SPACE
from repro.pfs.engine import READ, WRITE


@dataclasses.dataclass(frozen=True)
class TunerParams:
    tau: float = 0.8      # probability threshold (paper SIII-C)
    alpha: float = 0.3    # read regularizer weight on theta^1 (window)
    beta: float = 0.25    # write regularizer weight on sum(theta)


@dataclasses.dataclass
class TuneDecision:
    theta: tuple[int, int]
    changed: bool
    n_candidates: int
    probs: np.ndarray     # f(theta, H_t) over the whole space
    score: float


def conditional_score_greedy(
    probs: np.ndarray,
    op: int,
    current: tuple[int, int],
    space: ConfigSpace = SPACE,
    params: TunerParams | None = None,
) -> TuneDecision:
    """Algorithm 1.  ``probs`` is f(theta, H_t) for every theta in
    ``space.configs()`` order."""
    params = params if params is not None else TunerParams()
    thetas = space.as_array()                      # (|Theta|, 2) raw values
    keep = probs > params.tau                      # line 4
    if not keep.any():                             # no candidate clears tau
        return TuneDecision(theta=current, changed=False, n_candidates=0,
                            probs=probs, score=0.0)

    S = thetas[keep]
    pS = probs[keep]
    norm = space.minmax_normalize(S)               # line 6

    if op == WRITE:                                # lines 7-8, 11-12
        scores = pS * (1.0 + params.beta * norm.sum(axis=1))
    else:                                          # lines 9-10, 13-14
        scores = pS * (1.0 + params.alpha * norm[:, 0]) + norm[:, 1]

    j = int(np.argmax(scores))
    theta = (int(S[j, 0]), int(S[j, 1]))
    return TuneDecision(theta=theta, changed=theta != tuple(current),
                        n_candidates=int(keep.sum()), probs=probs,
                        score=float(scores[j]))


# ---------------------------------------------------------------------- #
# batched Algorithm 1: every interface's decision in one pass
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class FleetDecisions:
    """Algorithm 1 outcomes for a batch of interfaces (row-aligned)."""

    theta: np.ndarray         # (m, 2) chosen configuration per row
    changed: np.ndarray       # (m,) bool
    n_candidates: np.ndarray  # (m,) how many configs cleared tau
    score: np.ndarray         # (m,) winning score (0 when nothing cleared)
    probs: np.ndarray         # (m, |Theta|) f(theta, H_t) per row

    def __len__(self) -> int:
        return self.theta.shape[0]

    def one(self, i: int) -> TuneDecision:
        """Row ``i`` as a scalar :class:`TuneDecision` (compat surface)."""
        return TuneDecision(
            theta=(int(self.theta[i, 0]), int(self.theta[i, 1])),
            changed=bool(self.changed[i]),
            n_candidates=int(self.n_candidates[i]),
            probs=self.probs[i],
            score=float(self.score[i]))

    def provenance(self, oscs, ops) -> list:
        """JSON-safe per-row Algorithm 1 provenance — the decision plus
        the evidence behind it (per-config probabilities, how many
        cleared τ, the winning score), keyed by interface and op model.
        ``oscs``/``ops`` are the row-aligned arrays the caller batched
        by (:class:`~repro.core.fleet.FleetTickResult` carries both).
        """
        return [{
            "osc": int(oscs[i]),
            "op": "read" if int(ops[i]) == READ else "write",
            "theta": [int(self.theta[i, 0]), int(self.theta[i, 1])],
            "changed": bool(self.changed[i]),
            "n_candidates": int(self.n_candidates[i]),
            "score": float(self.score[i]),
            "probs": [round(float(p), 9) for p in self.probs[i]],
        } for i in range(len(self))]


def score_greedy_arrays(probs, ops, current, thetas, params: TunerParams,
                        xp=np):
    """Backend-agnostic core of the batched Algorithm 1.

    ``probs`` is ``(m, M)`` float64, ``ops`` ``(m,)`` op codes,
    ``current`` ``(m, 2)`` integer thetas, ``thetas`` the ``(M, 2)``
    float64 grid.  ``xp`` selects the array namespace: ``np`` is the
    oracle path; :mod:`repro.pfs.loop_jax` passes ``jnp`` so the
    device-resident loop runs the *literal same* reductions (masked
    extrema MinMax, op-selected scores, first-max argmax) under ``jit``.

    Returns ``(theta, changed, n_candidates, score)``.
    """
    m = probs.shape[0]
    keep = probs > params.tau                          # (m, M)   line 4
    any_keep = keep.any(axis=1)

    # MinMax over each row's surviving subset (line 6), via masked extrema
    t3 = thetas[None, :, :]                            # (1, M, 2)
    lo = xp.min(xp.where(keep[:, :, None], t3, xp.inf), axis=1)
    hi = xp.max(xp.where(keep[:, :, None], t3, -xp.inf), axis=1)
    span = xp.where(hi - lo > 0, hi - lo, 1.0)
    norm = (t3 - lo[:, None, :]) / span[:, None, :]    # (m, M, 2)

    w_scores = probs * (1.0 + params.beta * norm.sum(axis=2))
    r_scores = probs * (1.0 + params.alpha * norm[:, :, 0]) + norm[:, :, 1]
    scores = xp.where((ops == WRITE)[:, None], w_scores, r_scores)
    scores = xp.where(keep, scores, -xp.inf)

    j = xp.argmax(scores, axis=1)                      # first max, like scalar
    cur64 = current.astype(xp.int64)
    theta = thetas[j].astype(xp.int64)                 # (m, 2)
    theta = xp.where(any_keep[:, None], theta, cur64)
    changed = any_keep & (theta != cur64).any(axis=1)
    score = xp.where(any_keep, scores[xp.arange(m), j], 0.0)
    n_candidates = keep.sum(axis=1) * any_keep
    return theta, changed, n_candidates, score


def conditional_score_greedy_batch(
    probs: np.ndarray,
    ops: np.ndarray,
    current: np.ndarray,
    space: ConfigSpace = SPACE,
    params: TunerParams | None = None,
) -> FleetDecisions:
    """Vectorized Algorithm 1 over ``m`` interfaces at once.

    ``probs`` is ``(m, |Theta|)`` in ``space.configs()`` order, ``ops`` is
    ``(m,)`` op codes and ``current`` the ``(m, 2)`` currently-applied
    thetas.  Row ``i`` equals
    ``conditional_score_greedy(probs[i], ops[i], current[i])`` exactly —
    same MinMax-over-survivors normalization, same first-max tie break —
    just computed with masked reductions instead of a Python loop.
    """
    params = params if params is not None else TunerParams()
    probs = np.asarray(probs, dtype=np.float64)
    ops = np.asarray(ops)
    current = np.asarray(current)
    thetas = space.as_array()                          # (M, 2)
    # rows with no survivor produce inf/nan in the masked-out lanes
    # (0 * inf); they are discarded by the keep mask before use
    with np.errstate(invalid="ignore"):
        theta, changed, n_candidates, score = score_greedy_arrays(
            probs, ops, current, thetas, params)
    return FleetDecisions(theta=theta, changed=changed,
                          n_candidates=n_candidates,
                          score=score, probs=probs)
