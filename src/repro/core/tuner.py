"""Parameter tuning strategy — *Conditional Score Greedy* (paper Alg. 1).

Given the probability distribution the model assigns over Theta, the tuner

  1. keeps only configurations whose predicted probability of >=15%
     improvement exceeds tau (0.8 in the paper);
  2. MinMax-normalizes the surviving configurations;
  3. breaks ties *away from* greedy-safe choices with a regularizer that
     prefers larger theta values (larger RPCs utilize channels better,
     more RPCs in flight move more data in parallel — SIII-C), weighted
     by alpha (read) / beta (write):

         WriteScore(theta) = f(theta, H_t) * (1 + beta * sum(theta_norm))
         ReadScore(theta)  = f(theta, H_t) * (1 + alpha * theta1_norm)
                             + theta2_norm

If no configuration clears tau, the current configuration is kept — the
model sees no sufficiently-likely win, so DIAL does not thrash.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.config_space import ConfigSpace, SPACE
from repro.pfs.engine import READ, WRITE


@dataclasses.dataclass(frozen=True)
class TunerParams:
    tau: float = 0.8      # probability threshold (paper SIII-C)
    alpha: float = 0.3    # read regularizer weight on theta^1 (window)
    beta: float = 0.25    # write regularizer weight on sum(theta)


@dataclasses.dataclass
class TuneDecision:
    theta: tuple[int, int]
    changed: bool
    n_candidates: int
    probs: np.ndarray     # f(theta, H_t) over the whole space
    score: float


def conditional_score_greedy(
    probs: np.ndarray,
    op: int,
    current: tuple[int, int],
    space: ConfigSpace = SPACE,
    params: TunerParams = TunerParams(),
) -> TuneDecision:
    """Algorithm 1.  ``probs`` is f(theta, H_t) for every theta in
    ``space.configs()`` order."""
    thetas = space.as_array()                      # (|Theta|, 2) raw values
    keep = probs > params.tau                      # line 4
    if not keep.any():                             # no candidate clears tau
        return TuneDecision(theta=current, changed=False, n_candidates=0,
                            probs=probs, score=0.0)

    S = thetas[keep]
    pS = probs[keep]
    norm = space.minmax_normalize(S)               # line 6

    if op == WRITE:                                # lines 7-8, 11-12
        scores = pS * (1.0 + params.beta * norm.sum(axis=1))
    else:                                          # lines 9-10, 13-14
        scores = pS * (1.0 + params.alpha * norm[:, 0]) + norm[:, 1]

    j = int(np.argmax(scores))
    theta = (int(S[j, 0]), int(S[j, 1]))
    return TuneDecision(theta=theta, changed=theta != tuple(current),
                        n_candidates=int(keep.sum()), probs=probs,
                        score=float(scores[j]))
