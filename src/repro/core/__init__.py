"""DIAL core — the paper's contribution.

Decentralized I/O autotuning from learned client-side local metrics:
per-client agents probe local PFS statistics, score the configuration
space with GBDT models, and apply the Conditional-Score-Greedy winner
to each OSC interface, every interval, with no global coordination.
"""

from repro.core.agent import DIALAgent, SimClientPort, run_with_agents
from repro.core.config_space import DEFAULT, SPACE, ConfigSpace
from repro.core.dataset import CollectConfig, collect, train_models
from repro.core.gbdt import DenseForest, GBDTClassifier, GBDTParams
from repro.core.metrics import Snapshot, feature_vector, snapshot
from repro.core.model import DIALModel
from repro.core.tuner import TuneDecision, TunerParams, conditional_score_greedy

__all__ = [
    "DIALAgent",
    "SimClientPort",
    "run_with_agents",
    "DEFAULT",
    "SPACE",
    "ConfigSpace",
    "CollectConfig",
    "collect",
    "train_models",
    "DenseForest",
    "GBDTClassifier",
    "GBDTParams",
    "Snapshot",
    "feature_vector",
    "snapshot",
    "DIALModel",
    "TuneDecision",
    "TunerParams",
    "conditional_score_greedy",
]
