"""DIAL core — the paper's contribution.

Decentralized I/O autotuning from learned client-side local metrics:
per-client agents probe local PFS statistics, score the configuration
space with GBDT models, and apply the Conditional-Score-Greedy winner
to each OSC interface, every interval, with no global coordination.
"""

from repro.core.agent import (DIALAgent, ReferenceLoopAgent, SimClientPort,
                              run_with_agents, run_with_loop_agents)
from repro.core.config_space import DEFAULT, SPACE, ConfigSpace
from repro.core.dataset import CollectConfig, collect, train_models
from repro.core.fleet import (FleetAgent, LoopFleetPort, SimFleetPort,
                              as_fleet_port, run_fleet)
from repro.core.gbdt import DenseForest, GBDTClassifier, GBDTParams
from repro.core.metrics import (FleetSnapshot, Snapshot, feature_vector,
                                fleet_feature_matrix, snapshot, snapshot_all)
from repro.core.model import DIALModel
from repro.core.tuner import (FleetDecisions, TuneDecision, TunerParams,
                              conditional_score_greedy,
                              conditional_score_greedy_batch)

__all__ = [
    "DIALAgent",
    "ReferenceLoopAgent",
    "SimClientPort",
    "run_with_agents",
    "run_with_loop_agents",
    "FleetAgent",
    "SimFleetPort",
    "LoopFleetPort",
    "as_fleet_port",
    "run_fleet",
    "FleetSnapshot",
    "snapshot_all",
    "fleet_feature_matrix",
    "FleetDecisions",
    "conditional_score_greedy_batch",
    "DEFAULT",
    "SPACE",
    "ConfigSpace",
    "CollectConfig",
    "collect",
    "train_models",
    "DenseForest",
    "GBDTClassifier",
    "GBDTParams",
    "Snapshot",
    "feature_vector",
    "snapshot",
    "DIALModel",
    "TuneDecision",
    "TunerParams",
    "conditional_score_greedy",
]
