"""Offline training-data collection and model training (paper SIV-A).

The paper collects ~100k read and ~98k write non-zero samples by running
the *simplest* Filebench workloads — single-stream I/O with sequential or
random access at 8 KB / 1 MB / 16 MB request sizes — for 300 s x 30 reps,
probing every 0.5 s while exploring configurations.

We reproduce that recipe against the simulator.  Each probe interval:

    1. observe H_t = [s_{t-k} .. s_t] under the current theta,
    2. sample a random theta' from the space and apply it,
    3. at the next probe, label the transition with
       1[ tput_{t+1} / tput_t > 1 + eps ]   (eps = 0.15).

Zero-throughput intervals are dropped (paper keeps "non-zero samples").
Cells run concurrently in one simulator instance on disjoint
(client, OST) pairs so the whole sweep vectorizes.  ``n_threads`` extends
the paper's single-process streams with 4/16-way streams — our closed-loop
clients are more starkly concurrency-limited than real Filebench
processes, so single-thread-only data would under-express the
rpcs_in_flight axis; flagged as a (documented) deviation in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.config_space import SPACE, ConfigSpace
from repro.core.gbdt import GBDTClassifier, GBDTParams
from repro.core.metrics import feature_vector, snapshot
from repro.core.model import DIALModel
from repro.pfs.engine import READ, WRITE, PFSSim, SimParams
from repro.pfs.stats import probe
from repro.pfs.workloads import Workload

EPS_IMPROVE = 0.15  # the paper's epsilon

REQ_SIZES = (8 * 1024, 64 * 1024, 1 * 2**20, 16 * 2**20)  # 8K/64K/1M/16M
PATTERNS = (0.0, 0.9, 1.0)                        # seq, shuffled, random
THREADS = (1, 4, 16, 32)


@dataclasses.dataclass
class CollectConfig:
    seconds: float = 60.0
    interval: float = 0.5
    reps: int = 4
    k: int = 1
    min_volume_bytes: float = 64 * 1024
    include_contention: bool = False   # beyond-paper enrichment
    seed: int = 0


def _cells() -> list[dict]:
    cells = []
    for op, rnd, req, thr in itertools.product(
            (READ, WRITE), PATTERNS, REQ_SIZES, THREADS):
        cells.append(dict(op=op, randomness=rnd, req_size=req, n_threads=thr))
    return cells


def collect(cfg: CollectConfig = CollectConfig(),
            space: ConfigSpace = SPACE) -> dict:
    """Run the collection sweep; returns {'read': (X, y), 'write': (X, y)}."""
    rng = np.random.default_rng(cfg.seed)
    Xr, yr, Xw, yw = [], [], [], []
    theta_feats = space.as_features()
    configs = space.configs()

    for rep in range(cfg.reps):
        cells = _cells()
        n = len(cells)
        n_noise = 4 if cfg.include_contention else 0
        # one isolated OST per cell; optional contention cells share OSTs
        sim = PFSSim(n_clients=n + n_noise, n_osts=n,
                     seed=cfg.seed * 1000 + rep)
        for i, cell in enumerate(cells):
            wl = Workload(client=i, op=cell["op"], req_size=cell["req_size"],
                          randomness=cell["randomness"],
                          n_threads=cell["n_threads"], osts=(i,),
                          name=f"cell{i}")
            sim.attach(wl)
        # extra clients pile onto the first few OSTs (congested cells).
        # Noise traffic rides on *fresh* client ids so it shares only the
        # cell's OST — never the measurement OSC itself (sharing an OSC
        # would pollute the probed counters instead of modeling
        # independent background contention).
        for j in range(n_noise):
            wl = Workload(client=n + j, op=READ, req_size=1 * 2**20,
                          randomness=0.3, n_threads=4,
                          osts=((j + 1) % n,), name=f"noise{j}")
            sim.attach(wl)

        oscs = [sim.osc_id(i, i) for i in range(n)]
        prev = {o: probe(sim, o) for o in oscs}
        hist = {o: [] for o in oscs}
        pending = {o: None for o in oscs}  # (features, tput_t, op)

        steps = max(int(round(cfg.interval / sim.params.tick)), 1)
        n_intervals = int(round(cfg.seconds / cfg.interval))
        for it in range(n_intervals):
            for _ in range(steps):
                sim.step()
            for o, cell in zip(oscs, cells):
                cur = probe(sim, o)
                snap = snapshot(prev[o], cur)
                prev[o] = cur
                hist[o].append(snap)
                hist[o] = hist[o][-(cfg.k + 1):]
                op = cell["op"]
                vol = snap.read_volume if op == READ else snap.write_volume
                tput = (snap.read if op == READ else snap.write)[0]
                # finalize the previous interval's sample with this label
                if pending[o] is not None:
                    feats, tput_prev = pending[o]
                    if tput_prev > 0 and vol >= cfg.min_volume_bytes:
                        label = float(tput / tput_prev > 1.0 + EPS_IMPROVE)
                        (Xr if op == READ else Xw).append(feats)
                        (yr if op == READ else yw).append(label)
                    pending[o] = None
                    continue  # let the new theta settle before re-observing
                # explore on alternating intervals so H_t reflects a steady
                # state under the old theta — matching what the agent sees
                # at inference time (it holds a config between decisions)
                if len(hist[o]) >= cfg.k + 1 and vol >= cfg.min_volume_bytes:
                    j = int(rng.integers(len(configs)))
                    w, f = configs[j]
                    feats = feature_vector(hist[o], op, theta_feats[j])
                    sim.set_knobs([o], window_pages=w, rpcs_in_flight=f)
                    pending[o] = (feats, tput)

    return {
        "read": (np.array(Xr, dtype=np.float32), np.array(yr)),
        "write": (np.array(Xw, dtype=np.float32), np.array(yw)),
    }


def train_models(data: dict, gbdt_params: GBDTParams | None = None,
                 space: ConfigSpace = SPACE,
                 backend: str = "numpy") -> DIALModel:
    """Fit the separate read/write GBDTs and bundle them.

    ``backend="numpy"`` is the sequential oracle loop; ``"jax"`` trains
    both forests in one vmapped jitted launch
    (:func:`repro.learn.boost.train_models_jax`) with split-for-split
    parity.  Either way the returned model carries ``train_meta``
    (trainer backend + dataset fingerprint) for artifact validation.
    """
    from repro.core.model import dataset_fingerprint

    params = gbdt_params or GBDTParams()
    if backend == "jax":
        from repro.learn.boost import train_models_jax  # lazy: needs jax

        model = train_models_jax(data, params, space)
    elif backend == "numpy":
        forests = {}
        for op_name in ("read", "write"):
            X, y = data[op_name]
            if len(X) == 0:
                raise ValueError(f"no {op_name} samples collected")
            clf = GBDTClassifier(params).fit(X, y)
            forests[op_name] = clf.forest
        model = DIALModel(read_forest=forests["read"],
                          write_forest=forests["write"], space=space)
    else:
        raise ValueError(f"unknown trainer backend {backend!r}")
    model.train_meta = {"trainer_backend": backend,
                        "dataset": dataset_fingerprint(data)}
    return model


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description="DIAL offline data collection + training")
    ap.add_argument("--out", default="models/dial")
    ap.add_argument("--seconds", type=float, default=60.0)
    ap.add_argument("--reps", type=int, default=4)
    ap.add_argument("--contention", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = CollectConfig(seconds=args.seconds, reps=args.reps,
                        include_contention=args.contention, seed=args.seed)
    data = collect(cfg)
    for op_name in ("read", "write"):
        X, y = data[op_name]
        print(f"{op_name}: {len(X)} samples, positive rate {y.mean():.3f}")
    model = train_models(data)
    import os

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    model.save(args.out)
    print(f"saved forests to {args.out}.{{read,write}}.npz")


if __name__ == "__main__":
    main()
