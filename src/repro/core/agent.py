"""The per-client DIAL agent (paper SIII-A, components 1-4).

One agent runs on one PFS client, fully autonomously: it probes that
client's OSC interfaces at a fixed interval, derives the designed metrics,
scores the configuration space with the learned model, and applies the
Conditional-Score-Greedy winner to each interface.  Agents never
communicate — the decentralization thesis — yet collectively respond to
global congestion through its locally-visible symptoms (RPC latency,
queue depth, slot starvation).

The agent talks to its client through the tiny :class:`ClientPort`
protocol so the same code drives (a) the PFS simulator directly and
(b) the training-framework data pipeline / checkpoint writer
(:mod:`repro.data.pipeline`), which is how the paper's technique embeds
into the training system as a first-class feature.

Execution is delegated to the batched fleet path
(:mod:`repro.core.fleet`): a :class:`DIALAgent` is a thin adapter that
lifts its port to the fleet surface and runs a one-client
:class:`~repro.core.fleet.FleetAgent`, so even a single client scores all
of its interfaces in one model launch per tick instead of one per
interface.  The original per-interface Python loop is preserved verbatim
as :class:`ReferenceLoopAgent` — the oracle the fleet/loop equivalence
tests compare against, and the baseline `benchmarks/fleet_scaling.py`
amortizes away.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Protocol

import numpy as np

from repro.core.config_space import DEFAULT, SPACE, ConfigSpace
from repro.core.metrics import Snapshot, snapshot
from repro.core.model import DIALModel
from repro.core.tuner import TunerParams, conditional_score_greedy
from repro.pfs.engine import READ, WRITE
from repro.pfs.stats import OSCStats, probe


class ClientPort(Protocol):
    """What a DIAL agent needs from the system it tunes."""

    def osc_ids(self) -> list[int]: ...
    def probe(self, osc: int) -> OSCStats: ...
    def set_knobs(self, osc: int, window_pages: int, rpcs_in_flight: int) -> None: ...


@dataclasses.dataclass
class SimClientPort:
    """Adapter: one client of the PFS simulator."""

    sim: object
    client: int

    def osc_ids(self) -> list[int]:
        return [int(x) for x in self.sim.client_oscs(self.client)]

    def probe(self, osc: int) -> OSCStats:
        return probe(self.sim, osc)

    def set_knobs(self, osc: int, window_pages: int, rpcs_in_flight: int) -> None:
        self.sim.set_knobs([osc], window_pages=window_pages,
                           rpcs_in_flight=rpcs_in_flight)


@dataclasses.dataclass
class AgentTimings:
    """Wall-clock overheads per operation (reproduces paper Table III).

    Loop agents append each interface's own latency; fleet agents append
    batch cost amortized per covered interface — the honest per-interface
    figure either way.
    """

    snapshot_ms: list = dataclasses.field(default_factory=list)
    inference_ms: list = dataclasses.field(default_factory=list)
    end_to_end_ms: list = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        f = lambda xs: float(np.mean(xs)) if xs else 0.0
        return {"snapshot_ms": f(self.snapshot_ms),
                "inference_ms": f(self.inference_ms),
                "end_to_end_ms": f(self.end_to_end_ms)}


class DIALAgent:
    """Decentralized tuner for one client; call :meth:`tick` every interval.

    Thin adapter over the batched fleet path: decisions, knob updates and
    memory behaviour are identical to the historical per-interface loop
    (see :class:`ReferenceLoopAgent`), but every tick runs the metrics,
    inference and Algorithm 1 stages once for all of the client's
    interfaces together.
    """

    def __init__(
        self,
        port: ClientPort,
        model: DIALModel,
        space: ConfigSpace = SPACE,
        tuner_params: TunerParams | None = None,
        k: int = 1,
        min_volume_bytes: float = 256 * 1024,
        warmup_intervals: int = 2,
        measure_overhead: bool = False,
    ):
        from repro.core.fleet import FleetAgent, as_fleet_port

        self.port = port
        self.model = model
        self.space = space
        self.tuner_params = (tuner_params if tuner_params is not None
                             else TunerParams())
        self.k = k
        self.min_volume = min_volume_bytes
        self.warmup = warmup_intervals
        self.measure_overhead = measure_overhead
        self._fleet = FleetAgent(
            as_fleet_port(port), model, space=space,
            tuner_params=tuner_params, k=k,
            min_volume_bytes=min_volume_bytes,
            warmup_intervals=warmup_intervals,
            measure_overhead=measure_overhead)
        self.decisions: list = []

    # ------------------------------------------------------------------ #
    def tick(self) -> list:
        """One tuning round across all of this client's OSC interfaces.

        Returns the historical ``[(osc, op, TuneDecision), ...]`` shape.
        """
        decisions = self._fleet.tick().as_list()
        self.decisions.extend(decisions)
        return decisions

    # --- compat surface over the fleet state --------------------------- #
    @property
    def timings(self) -> dict:
        return self._fleet.timings

    @property
    def _ticks(self) -> int:
        return self._fleet._ticks

    @property
    def _current(self) -> dict:
        cur = self._fleet._current
        return {int(o): (int(cur[i, 0]), int(cur[i, 1]))
                for i, o in enumerate(self._fleet.oscs)}

    @property
    def _hist(self) -> dict:
        """Per-interface snapshot views (paper SIV-C: at most k+1 kept)."""
        fleet_hist = list(self._fleet._hist)
        return {int(o): tuple(s.one(i) for s in fleet_hist)
                for i, o in enumerate(self._fleet.oscs)}


class ReferenceLoopAgent:
    """The original per-interface tuning loop, kept verbatim as an oracle.

    One Python iteration — probe, snapshot, model launch, Algorithm 1,
    knob write — per OSC interface per tick.  This is the paper's
    measured client implementation (Table III per-interface overheads)
    and the semantic reference the batched :class:`FleetAgent` must match
    decision-for-decision (see ``tests/test_fleet.py``); it is also the
    baseline that `benchmarks/fleet_scaling.py` compares against.  Use
    :class:`DIALAgent` everywhere else.
    """

    def __init__(
        self,
        port: ClientPort,
        model: DIALModel,
        space: ConfigSpace = SPACE,
        tuner_params: TunerParams | None = None,
        k: int = 1,
        min_volume_bytes: float = 256 * 1024,
        warmup_intervals: int = 2,
        measure_overhead: bool = False,
    ):
        self.port = port
        self.model = model
        self.space = space
        self.tuner_params = (tuner_params if tuner_params is not None
                             else TunerParams())
        self.k = k
        self.min_volume = min_volume_bytes
        # skip decisions until the workload's startup transient has passed:
        # H_t must reflect steady metrics under the current theta, matching
        # the training distribution (alternating-interval exploration)
        self.warmup = warmup_intervals
        self._ticks = 0
        self.measure_overhead = measure_overhead
        self.timings = {READ: AgentTimings(), WRITE: AgentTimings()}
        # DIAL keeps only two snapshots per interface in memory (SIV-C)
        self._prev: dict[int, OSCStats] = {}
        self._hist: dict[int, collections.deque] = {}
        self._current: dict[int, tuple[int, int]] = {}
        self.decisions: list = []
        for osc in self.port.osc_ids():
            st = self.port.probe(osc)
            self._prev[osc] = st
            self._hist[osc] = collections.deque(maxlen=k + 1)
            self._current[osc] = (st.window_pages, st.rpcs_in_flight)

    # ------------------------------------------------------------------ #
    def tick(self) -> list:
        """One tuning round across all of this client's OSC interfaces."""
        self._ticks += 1
        decisions = []
        for osc in self.port.osc_ids():
            t0 = time.perf_counter()
            cur = self.port.probe(osc)
            snap = snapshot(self._prev[osc], cur)
            self._prev[osc] = cur
            self._hist[osc].append(snap)
            # the applied configuration comes from the probe, never a
            # shadow copy — same contract as FleetAgent (knobs can be
            # flipped out-of-band between ticks)
            self._current[osc] = (cur.window_pages, cur.rpcs_in_flight)
            t1 = time.perf_counter()
            if len(self._hist[osc]) < self.k + 1 or self._ticks <= self.warmup + self.k:
                continue
            # pick the op model by observed data-transfer volume (SIII-C)
            vol_r, vol_w = snap.read_volume, snap.write_volume
            if max(vol_r, vol_w) < self.min_volume:
                continue  # idle interface: nothing to tune
            op = READ if vol_r >= vol_w else WRITE
            history = list(self._hist[osc])
            # steady-state guard: bursty applications (epoch duty cycles)
            # produce intervals straddling on/off boundaries whose metrics
            # alias unrelated states; only decide when consecutive
            # snapshots saw comparable volume
            v0 = (history[0].read_volume if op == READ
                  else history[0].write_volume)
            v1 = vol_r if op == READ else vol_w
            if not (0.5 <= (v1 / max(v0, 1.0)) <= 2.0):
                continue
            probs = self.model.score_space(history, op)
            t2 = time.perf_counter()
            decision = conditional_score_greedy(
                probs, op, self._current[osc], self.space, self.tuner_params)
            if decision.changed:
                self.port.set_knobs(osc, *decision.theta)
                self._current[osc] = decision.theta
            t3 = time.perf_counter()
            if self.measure_overhead:
                tm = self.timings[op]
                tm.snapshot_ms.append((t1 - t0) * 1e3)
                tm.inference_ms.append((t2 - t1) * 1e3)
                tm.end_to_end_ms.append((t3 - t0) * 1e3)
            decisions.append((osc, op, decision))
        self.decisions.extend(decisions)
        return decisions


def run_with_agents(sim, model: DIALModel, clients: list[int],
                    seconds: float, interval: float = 0.5,
                    measure_overhead: bool = False,
                    tuner_params: TunerParams | None = None):
    """Drive the simulator with autonomous DIAL tuning on ``clients``.

    Delegates to the fleet path: all listed clients' interfaces tick as
    one batch — one probe, one model launch, one Algorithm 1 pass per
    interval for the whole fleet (decisions remain per-interface and
    client-local, exactly as with one agent object per client).  Returns
    the :class:`~repro.core.fleet.FleetAgent`.
    """
    from repro.core.fleet import run_fleet

    oscs = np.concatenate([sim.client_oscs(c) for c in clients])
    return run_fleet(sim, model, oscs=oscs, seconds=seconds,
                     interval=interval, measure_overhead=measure_overhead,
                     tuner_params=tuner_params)


def run_with_loop_agents(sim, model: DIALModel, clients: list[int],
                         seconds: float, interval: float = 0.5,
                         measure_overhead: bool = False,
                         tuner_params: TunerParams | None = None) -> list:
    """Reference driver: one :class:`ReferenceLoopAgent` per client.

    Kept for the fleet/loop equivalence tests and scaling benchmarks;
    production callers want :func:`run_with_agents`.
    """
    agents = [ReferenceLoopAgent(SimClientPort(sim, c), model,
                                 tuner_params=tuner_params,
                                 measure_overhead=measure_overhead)
              for c in clients]
    steps_per_interval = max(int(round(interval / sim.params.tick)), 1)
    n_intervals = int(round(seconds / interval))
    for _ in range(n_intervals):
        for _ in range(steps_per_interval):
            sim.step()
        for a in agents:
            a.tick()
    return agents
