"""Gradient-boosted decision trees (the paper's ML architecture, SIII-B).

Training is histogram-based boosting with logistic loss, implemented in
numpy (no sklearn offline).  The fitted forest is exported in a *dense
complete-binary-tree layout* designed for the TPU inference kernel
(:mod:`repro.kernels.gbdt_forest`):

    feature   : (T, 2^D - 1) int32    -- split feature per internal node
    threshold : (T, 2^D - 1) float32  -- split threshold (+inf = pass left)
    leaf      : (T, 2^D)     float32  -- leaf values (lr baked in)

Every tree is padded to full depth D: a node that stops early becomes a
pass-through (threshold=+inf so traversal always descends left) and its
leaf value is replicated down the left spine.  Traversal is therefore a
*static* D-step loop with no data-dependent control flow — exactly what a
TPU wants (level-synchronous predicated descent) and what GPU
warp-per-tree implementations cannot map onto the MXU/VPU model.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def _sigmoid(x: np.ndarray) -> np.ndarray:
    return 1.0 / (1.0 + np.exp(-np.clip(x, -30, 30)))


# Split gains are rounded to this many decimals before argmax so that
# mathematically-equal candidates stay tied under any float summation
# order; ties then break on (feature, bin) order in every trainer
# backend (numpy loop and repro.learn.boost must agree split for split).
GAIN_DECIMALS = 9


# ---------------------------------------------------------------------- #
# quantile binning, shared by this trainer and repro.learn.boost (the
# jitted trainer reproduces this trainer's splits only because both bin
# through the exact same code path)
# ---------------------------------------------------------------------- #
def quantile_edges(X: np.ndarray, n_bins: int) -> list[np.ndarray]:
    """Per-feature quantile bin edges (deduplicated, possibly empty)."""
    X = np.asarray(X, dtype=np.float64)
    qs_grid = np.linspace(0, 1, n_bins + 1)[1:-1]
    return [np.unique(np.quantile(X[:, f], qs_grid))
            for f in range(X.shape[1])]


def bin_codes(X: np.ndarray, edges: list[np.ndarray]) -> np.ndarray:
    """Integer bin codes: ``code > b  <=>  x > edges[f][b]`` (side-right
    searchsorted, the raw-threshold-compatible binning semantics)."""
    X = np.asarray(X, dtype=np.float64)
    Xb = np.empty(X.shape, dtype=np.int16)
    for f, e in enumerate(edges):
        Xb[:, f] = np.searchsorted(e, X[:, f], side="right")
    return Xb


@dataclasses.dataclass
class DenseForest:
    """Inference-ready forest in dense layout (see module docstring)."""

    feature: np.ndarray    # (T, 2^D - 1) int32
    threshold: np.ndarray  # (T, 2^D - 1) float32
    leaf: np.ndarray       # (T, 2^D) float32
    base_score: float
    depth: int
    n_features: int

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    def predict_margin(self, X: np.ndarray) -> np.ndarray:
        """Reference numpy traversal (the oracle for the Pallas kernel)."""
        X = np.asarray(X, dtype=np.float32)
        n, _ = X.shape
        out = np.full(n, self.base_score, dtype=np.float64)
        n_internal = self.feature.shape[1]
        for t in range(self.n_trees):
            idx = np.zeros(n, dtype=np.int64)
            for _ in range(self.depth):
                f = self.feature[t, idx]
                thr = self.threshold[t, idx]
                go_right = X[np.arange(n), f] > thr
                idx = 2 * idx + 1 + go_right
            out += self.leaf[t, idx - n_internal]
        return out

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        return _sigmoid(self.predict_margin(X))

    def save(self, path: str) -> None:
        np.savez_compressed(
            path, feature=self.feature, threshold=self.threshold,
            leaf=self.leaf, base_score=self.base_score, depth=self.depth,
            n_features=self.n_features)

    @staticmethod
    def load(path: str) -> "DenseForest":
        z = np.load(path)
        return DenseForest(
            feature=z["feature"], threshold=z["threshold"], leaf=z["leaf"],
            base_score=float(z["base_score"]), depth=int(z["depth"]),
            n_features=int(z["n_features"]))


@dataclasses.dataclass
class GBDTParams:
    n_trees: int = 160
    max_depth: int = 5
    learning_rate: float = 0.1
    reg_lambda: float = 1.0
    min_gain: float = 1e-4
    min_child_hess: float = 1.0
    n_bins: int = 48
    subsample: float = 0.85
    seed: int = 0


class GBDTClassifier:
    """Binary GBDT with histogram splits; produces a :class:`DenseForest`."""

    def __init__(self, params: GBDTParams | None = None):
        self.params = params or GBDTParams()
        self.forest: DenseForest | None = None

    # ------------------------------------------------------------------ #
    def fit(self, X: np.ndarray, y: np.ndarray) -> "GBDTClassifier":
        p = self.params
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n, n_feat = X.shape
        rng = np.random.default_rng(p.seed)

        # quantile binning: per-feature edges; small-int binned codes
        edges = quantile_edges(X, p.n_bins)
        Xb = bin_codes(X, edges)
        self._edges = edges

        pos = y.mean()
        base = float(np.log(max(pos, 1e-6) / max(1 - pos, 1e-6)))
        F = np.full(n, base)

        n_internal = 2 ** p.max_depth - 1
        n_leaves = 2 ** p.max_depth
        feats = np.zeros((p.n_trees, n_internal), dtype=np.int32)
        thrs = np.full((p.n_trees, n_internal), np.inf, dtype=np.float32)
        leaves = np.zeros((p.n_trees, n_leaves), dtype=np.float32)

        for t in range(p.n_trees):
            prob = _sigmoid(F)
            g = prob - y
            h = np.maximum(prob * (1 - prob), 1e-6)
            if p.subsample < 1.0:
                mask = rng.random(n) < p.subsample
                g_t = np.where(mask, g, 0.0)
                h_t = np.where(mask, h, 0.0)
            else:
                g_t, h_t = g, h
            tf, tt, tl = self._build_tree(Xb, g_t, h_t, edges)
            feats[t], thrs[t], leaves[t] = tf, tt, tl
            # update margins with the new tree only
            idx = np.zeros(n, dtype=np.int64)
            for _ in range(p.max_depth):
                f = tf[idx]
                go_right = X[np.arange(n), f] > tt[idx]
                idx = 2 * idx + 1 + go_right
            F += tl[idx - n_internal]

        self.forest = DenseForest(
            feature=feats, threshold=thrs, leaf=leaves, base_score=base,
            depth=p.max_depth, n_features=n_feat)
        return self

    # ------------------------------------------------------------------ #
    def _build_tree(self, Xb, g, h, edges):
        """Grow one depth-wise tree over binned features (XGBoost gains)."""
        p = self.params
        n, n_feat = Xb.shape
        n_internal = 2 ** p.max_depth - 1
        n_leaves = 2 ** p.max_depth
        feature = np.zeros(n_internal, dtype=np.int32)
        threshold = np.full(n_internal, np.inf, dtype=np.float32)
        leaf = np.zeros(n_leaves, dtype=np.float32)

        # node assignment per sample, in *level-order global* node ids
        node = np.zeros(n, dtype=np.int64)
        # value carried by pass-through spines
        node_value = {0: 0.0}

        for depth in range(p.max_depth):
            level_start = 2 ** depth - 1
            level_nodes = np.arange(level_start, 2 ** (depth + 1) - 1)
            local = node - level_start
            active = (local >= 0) & (local < len(level_nodes))
            loc = np.where(active, local, 0)

            best = {}
            n_level = len(level_nodes)
            for f in range(n_feat):
                nb = len(edges[f]) + 1
                if nb <= 1:
                    continue
                gh = np.zeros((n_level, nb))
                hh = np.zeros((n_level, nb))
                flat = loc * nb + Xb[:, f]
                gh_f = np.bincount(flat[active], weights=g[active],
                                   minlength=n_level * nb)
                hh_f = np.bincount(flat[active], weights=h[active],
                                   minlength=n_level * nb)
                gh = gh_f.reshape(n_level, nb)
                hh = hh_f.reshape(n_level, nb)
                GL = np.cumsum(gh, axis=1)[:, :-1]
                HL = np.cumsum(hh, axis=1)[:, :-1]
                G = GL[:, -1:] + gh[:, -1:]
                H = HL[:, -1:] + hh[:, -1:]
                GR = G - GL
                HR = H - HL
                lam = p.reg_lambda
                gain = 0.5 * (GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam)
                              - G ** 2 / (H + lam))
                gain = np.where((HL >= p.min_child_hess)
                                & (HR >= p.min_child_hess), gain, -np.inf)
                # quantize so mathematically-tied candidates (e.g. two
                # features isolating the same sample set) compare equal
                # regardless of float summation order, and the (feature,
                # bin) tie-break below is stable across trainer backends
                gain = np.round(gain, GAIN_DECIMALS)
                for j in range(n_level):
                    b = int(np.argmax(gain[j]))
                    gj = gain[j, b]
                    if np.isfinite(gj) and gj > best.get(j, (p.min_gain, 0, 0))[0]:
                        best[j] = (gj, f, b)

            # compute node values (Newton leaf) for every level node, used
            # by pass-through spines and final leaves
            g_sum = np.bincount(loc[active], weights=g[active], minlength=n_level)
            h_sum = np.bincount(loc[active], weights=h[active], minlength=n_level)
            for j in range(n_level):
                node_value[level_start + j] = float(
                    -p.learning_rate * g_sum[j] / (h_sum[j] + p.reg_lambda))

            for j in range(n_level):
                gid = level_start + j
                if j in best:
                    _, f, b = best[j]
                    feature[gid] = f
                    threshold[gid] = edges[f][b] if b < len(edges[f]) else np.inf
                # else: stays (feature=0, threshold=+inf) = pass-through left

            # descend samples on raw-threshold semantics via binned compare:
            # x > thr  <=>  bin(x) > bin_index(thr). threshold is the upper
            # edge of bin b, i.e. edges[f][b]; bin codes <= b go left.
            f_arr = feature[node]
            thr_bin = np.empty(n, dtype=np.int64)
            for j in range(n_level):
                gid = level_start + j
                sel = node == gid
                if not sel.any():
                    continue
                if np.isinf(threshold[gid]):
                    thr_bin[sel] = np.iinfo(np.int32).max
                else:
                    f = feature[gid]
                    b = int(np.searchsorted(edges[f], threshold[gid]))
                    thr_bin[sel] = b
            go_right = Xb[np.arange(n), f_arr] > thr_bin
            node = 2 * node + 1 + go_right

        # finalize leaves
        leaf_start = n_internal
        loc = node - leaf_start
        g_sum = np.bincount(loc, weights=g, minlength=n_leaves)
        h_sum = np.bincount(loc, weights=h, minlength=n_leaves)
        counts = np.bincount(loc, minlength=n_leaves)
        for j in range(n_leaves):
            if counts[j] > 0:
                leaf[j] = -p.learning_rate * g_sum[j] / (h_sum[j] + p.reg_lambda)
            else:
                # empty leaf: inherit nearest ancestor value (pass-through)
                anc = (leaf_start + j - 1) // 2
                while anc > 0 and anc not in node_value:
                    anc = (anc - 1) // 2
                leaf[j] = node_value.get(anc, 0.0)
        return feature, threshold, leaf

    # ------------------------------------------------------------------ #
    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        assert self.forest is not None, "fit first"
        return self.forest.predict_proba(X)
