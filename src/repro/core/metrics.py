"""Designed client-side local metrics (paper SIII-A step 1 and SIII-B).

Two consecutive raw probes of one OSC interface (simulated
``/proc/fs/lustre`` counters, :mod:`repro.pfs.stats`) are differenced into
one *interval snapshot* ``s_t`` — the "designed metrics ... extracted from
raw system statistics".  All metrics are strictly client-local.

Read and write snapshots are separate vectors with op-specific members
(grant/dirty/block for writes, readahead hits for reads), because Lustre
handles the two paths differently (SIII-B) and DIAL trains separate
models per op.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.pfs.engine import PAGE_SIZE, READ, WRITE
from repro.pfs.stats import FleetStats, OSCStats

# Ordered feature names for each op's snapshot vector.  Keep stable: the
# GBDT models and the Pallas inference kernel index by position.
READ_FEATURES = (
    "throughput_mbs",      # app-visible read MB/s this interval
    "rpc_rate",            # RPCs sent per second
    "avg_pages_per_rpc",   # mean formed-RPC size in pages
    "partial_rpc_frac",    # fraction of RPCs dispatched below the window
    "avg_rpc_latency_ms",  # mean sojourn of completed RPCs
    "avg_pending_mb",      # time-avg bytes waiting for a slot
    "avg_active_rpcs",     # time-avg RPCs in flight
    "slot_utilization",    # avg_active / rpcs_in_flight knob
    "req_rate",            # app requests per second
    "avg_req_kb",          # mean app request size
    "randomness",          # client-side offset-jump estimate [0,1]
    "cache_hit_rate",      # readahead-covered fraction of request bytes
    "window_pages_log2",   # knob in effect during the interval
    "rpcs_in_flight_log2",
)

WRITE_FEATURES = (
    "throughput_mbs",
    "rpc_rate",
    "avg_pages_per_rpc",
    "partial_rpc_frac",
    "avg_rpc_latency_ms",
    "avg_pending_mb",
    "avg_active_rpcs",
    "slot_utilization",
    "req_rate",
    "avg_req_kb",
    "randomness",
    "block_frac",          # fraction of interval the app sat grant-blocked
    "avg_dirty_mb",        # time-avg dirty cache occupancy
    "avg_grant_mb",        # time-avg grant consumption
    "window_pages_log2",
    "rpcs_in_flight_log2",
)

N_READ = len(READ_FEATURES)
N_WRITE = len(WRITE_FEATURES)


@dataclasses.dataclass
class Snapshot:
    """One interval's designed metrics for one OSC interface."""

    t: float
    dt: float
    read: np.ndarray          # (N_READ,)
    write: np.ndarray         # (N_WRITE,)
    read_volume: float        # bytes moved (model-selection signal)
    write_volume: float


def _safe_div(a: float, b: float) -> float:
    return a / b if b > 0 else 0.0


def snapshot(prev: OSCStats, cur: OSCStats) -> Snapshot:
    """Difference two consecutive probes into the designed metrics."""
    dt = max(cur.t - prev.t, 1e-9)

    def common(op: int) -> list[float]:
        d_bytes = float(cur.bytes_done[op] - prev.bytes_done[op])
        d_rpcs = float(cur.rpcs_sent[op] - prev.rpcs_sent[op])
        d_rpc_bytes = float(cur.rpc_bytes[op] - prev.rpc_bytes[op])
        d_partial = float(cur.partial_rpcs[op] - prev.partial_rpcs[op])
        d_done = float(cur.rpcs_done[op] - prev.rpcs_done[op])
        d_lat = float(cur.latency_sum[op] - prev.latency_sum[op])
        d_reqs = float(cur.req_count[op] - prev.req_count[op])
        d_req_bytes = float(cur.req_bytes[op] - prev.req_bytes[op])
        d_pend = float(cur.pending_integral[op] - prev.pending_integral[op])
        d_act = float(cur.active_integral[op] - prev.active_integral[op])
        return [
            d_bytes / dt / 1e6,
            d_rpcs / dt,
            _safe_div(d_rpc_bytes, d_rpcs) / PAGE_SIZE,
            _safe_div(d_partial, d_rpcs),
            _safe_div(d_lat, d_done) * 1e3,
            d_pend / dt / 2**20,
            d_act / dt,
            _safe_div(d_act / dt, cur.rpcs_in_flight),
            d_reqs / dt,
            _safe_div(d_req_bytes, d_reqs) / 1024.0,
            float(cur.randomness[op]),
        ]

    knobs = [np.log2(cur.window_pages), np.log2(cur.rpcs_in_flight)]

    r = common(READ)
    d_req_bytes_r = float(cur.req_bytes[READ] - prev.req_bytes[READ])
    d_hit = float(cur.cache_hit_bytes - prev.cache_hit_bytes)
    r.append(_safe_div(d_hit, d_req_bytes_r))
    read_vec = np.array(r + knobs)

    w = common(WRITE)
    w.append(float(cur.block_time - prev.block_time) / dt)
    w.append(float(cur.dirty_integral - prev.dirty_integral) / dt / 2**20)
    w.append(float(cur.grant_integral - prev.grant_integral) / dt / 2**20)
    write_vec = np.array(w + knobs)

    return Snapshot(
        t=cur.t,
        dt=dt,
        read=read_vec,
        write=write_vec,
        read_volume=float(cur.bytes_done[READ] - prev.bytes_done[READ]),
        write_volume=float(cur.bytes_done[WRITE] - prev.bytes_done[WRITE]),
    )


# positions of the knob features inside each op's snapshot vector
READ_KNOB_IDX = (READ_FEATURES.index("window_pages_log2"),
                 READ_FEATURES.index("rpcs_in_flight_log2"))
WRITE_KNOB_IDX = (WRITE_FEATURES.index("window_pages_log2"),
                  WRITE_FEATURES.index("rpcs_in_flight_log2"))


def feature_vector(history: list[Snapshot], op: int,
                   theta_feat: np.ndarray) -> np.ndarray:
    """Assemble the model input ``(theta, H_t)`` (paper SIII-B, k=1).

    ``history`` is ``[s_{t-k}, ..., s_t]``; vectors concatenate oldest to
    newest, then the candidate theta's log2 features, then the *delta*
    between candidate and currently-applied theta.  The deltas are part of
    the "designed metrics": whether a configuration improves performance
    depends on how it *differs* from the one producing H_t, a relation
    axis-aligned tree splits cannot synthesize from absolute values alone.
    """
    vecs = [(h.read if op == READ else h.write) for h in history]
    th = np.asarray(theta_feat, dtype=np.float64)
    knobs = READ_KNOB_IDX if op == READ else WRITE_KNOB_IDX
    last = vecs[-1]
    delta = np.array([th[0] - last[knobs[0]], th[1] - last[knobs[1]]])
    return np.concatenate(vecs + [th, delta])


def feature_dim(op: int, k: int = 1) -> int:
    base = N_READ if op == READ else N_WRITE
    return base * (k + 1) + 4


# ---------------------------------------------------------------------- #
# fleet snapshots: the same designed metrics for every interface at once
# ---------------------------------------------------------------------- #
@dataclasses.dataclass
class FleetSnapshot:
    """One interval's designed metrics for *all* probed interfaces.

    Row ``i`` of ``read`` / ``write`` is exactly the vector
    :func:`snapshot` would produce for interface ``oscs[i]`` — the fleet
    path differences the whole stacked probe in a few array ops instead
    of one Python loop iteration per interface.
    """

    t: float
    dt: float
    oscs: np.ndarray          # (n,)
    read: np.ndarray          # (n, N_READ)
    write: np.ndarray         # (n, N_WRITE)
    read_volume: np.ndarray   # (n,) bytes moved (model-selection signal)
    write_volume: np.ndarray

    def one(self, i: int) -> Snapshot:
        """Row ``i`` as a scalar :class:`Snapshot` (compat / debugging)."""
        return Snapshot(t=self.t, dt=self.dt,
                        read=self.read[i], write=self.write[i],
                        read_volume=float(self.read_volume[i]),
                        write_volume=float(self.write_volume[i]))


def _log2_knob(x, xp):
    """``log2`` of a knob column, bit-stable across backends.

    XLA's ``log2`` can land 1 ulp off libm even on exact powers of two;
    that error survives the float32 feature cast through the θ-delta
    subtraction (6.0 - 5.999…e0 ≈ 9e-16 instead of exactly 0.0) and can
    flip GBDT splits whose threshold sits at 0.  Knob values are powers
    of two (the Θ grid), where ``frexp`` recovers the exponent exactly;
    non-power-of-two values (only reachable by writing knobs outside Θ)
    fall back to the backend ``log2``.
    """
    if xp is np:
        return np.log2(x)
    m, e = xp.frexp(x.astype(np.float64))
    return xp.where(m == 0.5, (e - 1).astype(np.float64),
                    xp.log2(x.astype(np.float64)))


def snapshot_arrays(prev, cur, xp=np):
    """Backend-agnostic core of :func:`snapshot_all`.

    ``prev`` / ``cur`` expose the :class:`FleetStats` field surface
    (stacked cumulative counters); ``xp`` is the array namespace.  With
    ``xp=np`` this is the oracle; :mod:`repro.pfs.loop_jax` calls it with
    ``xp=jnp`` so the device-resident loop differences probes with the
    *literal same* arithmetic, in the same op order, on float64.

    Returns ``(dt, read_mat, write_mat, read_volume, write_volume)``.
    """
    dt = xp.maximum(cur.t - prev.t, 1e-9)

    def safe_div(a, b):
        """Elementwise ``a/b`` where ``b > 0`` else 0 (no divide-by-0)."""
        ok = b > 0
        return xp.where(ok, a / xp.where(ok, b, 1.0), 0.0)

    def common(op: int) -> list:
        d_bytes = (cur.bytes_done[op] - prev.bytes_done[op]).astype(np.float64)
        d_rpcs = (cur.rpcs_sent[op] - prev.rpcs_sent[op]).astype(np.float64)
        d_rpc_bytes = (cur.rpc_bytes[op] - prev.rpc_bytes[op]).astype(np.float64)
        d_partial = (cur.partial_rpcs[op] - prev.partial_rpcs[op]).astype(np.float64)
        d_done = (cur.rpcs_done[op] - prev.rpcs_done[op]).astype(np.float64)
        d_lat = (cur.latency_sum[op] - prev.latency_sum[op]).astype(np.float64)
        d_reqs = (cur.req_count[op] - prev.req_count[op]).astype(np.float64)
        d_req_bytes = (cur.req_bytes[op] - prev.req_bytes[op]).astype(np.float64)
        d_pend = (cur.pending_integral[op] - prev.pending_integral[op]).astype(np.float64)
        d_act = (cur.active_integral[op] - prev.active_integral[op]).astype(np.float64)
        return [
            d_bytes / dt / 1e6,
            d_rpcs / dt,
            safe_div(d_rpc_bytes, d_rpcs) / PAGE_SIZE,
            safe_div(d_partial, d_rpcs),
            safe_div(d_lat, d_done) * 1e3,
            d_pend / dt / 2**20,
            d_act / dt,
            safe_div(d_act / dt, cur.rpcs_in_flight),
            d_reqs / dt,
            safe_div(d_req_bytes, d_reqs) / 1024.0,
            cur.randomness[op].astype(np.float64),
        ]

    knobs = [_log2_knob(cur.window_pages, xp),
             _log2_knob(cur.rpcs_in_flight, xp)]

    r = common(READ)
    d_req_bytes_r = (cur.req_bytes[READ] - prev.req_bytes[READ]).astype(np.float64)
    d_hit = (cur.cache_hit_bytes - prev.cache_hit_bytes).astype(np.float64)
    r.append(safe_div(d_hit, d_req_bytes_r))
    read_mat = xp.stack(r + knobs, axis=1)

    w = common(WRITE)
    w.append((cur.block_time - prev.block_time).astype(np.float64) / dt)
    w.append((cur.dirty_integral - prev.dirty_integral).astype(np.float64) / dt / 2**20)
    w.append((cur.grant_integral - prev.grant_integral).astype(np.float64) / dt / 2**20)
    write_mat = xp.stack(w + knobs, axis=1)

    read_vol = (cur.bytes_done[READ] - prev.bytes_done[READ]).astype(np.float64)
    write_vol = (cur.bytes_done[WRITE] - prev.bytes_done[WRITE]).astype(np.float64)
    return dt, read_mat, write_mat, read_vol, write_vol


def snapshot_all(prev: FleetStats, cur: FleetStats) -> FleetSnapshot:
    """Vectorized :func:`snapshot` over two consecutive fleet probes.

    Arithmetic is elementwise-identical to the scalar path (same ops in
    the same order on float64), so fleet rows match per-interface
    snapshots bit for bit — the fleet/loop equivalence tests rely on it.
    """
    dt, read_mat, write_mat, read_vol, write_vol = snapshot_arrays(prev, cur)
    return FleetSnapshot(
        t=cur.t,
        dt=float(dt),
        oscs=cur.oscs,
        read=read_mat,
        write=write_mat,
        read_volume=read_vol,
        write_volume=write_vol,
    )


def fleet_feature_matrix(history: list[FleetSnapshot], op: int,
                         rows: np.ndarray,
                         theta_feats: np.ndarray) -> np.ndarray:
    """Model inputs for selected interfaces against every candidate theta.

    ``history`` is ``[s_{t-k}, ..., s_t]`` of fleet snapshots, ``rows``
    indexes the interfaces to score, ``theta_feats`` is the ``(M, 2)``
    log2 grid from :meth:`ConfigSpace.as_features`.  Returns a
    ``(len(rows) * M, dim)`` float32 matrix: interface-major, so row
    ``i * M + j`` is (theta_j, H_t of interface rows[i]) — identical
    row-for-row to stacking :meth:`DIALModel.features_for_space` outputs.
    """
    rows = np.asarray(rows)
    mats = [(h.read if op == READ else h.write)[rows] for h in history]
    hist = np.concatenate(mats, axis=1)            # (r, N*(k+1)) float64
    knobs = READ_KNOB_IDX if op == READ else WRITE_KNOB_IDX
    cur = mats[-1][:, list(knobs)]                 # (r, 2) currently applied
    r, m = hist.shape[0], theta_feats.shape[0]
    theta_tiled = np.tile(theta_feats, (r, 1))     # (r*M, 2) float64
    out = np.empty((r * m, hist.shape[1] + 4), dtype=np.float32)
    out[:, :-4] = np.repeat(hist, m, axis=0)
    out[:, -4:-2] = theta_tiled
    out[:, -2:] = theta_tiled - np.repeat(cur, m, axis=0)
    return out
