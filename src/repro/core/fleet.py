"""Fleet-scale DIAL: every interface's tuning tick in one batched pass.

The per-client :class:`~repro.core.agent.DIALAgent` walks its OSC
interfaces one at a time in Python and re-enters the model once per
interface — exactly the per-interface 10-13.5 ms hot spot the paper's
Table III measures.  The decentralization thesis only pays off at scale
(many clients tuning every interval), so the hot path must not scale
with Python-level agent count.

:class:`FleetAgent` runs the identical DIAL algorithm for the whole
fleet with array programs end to end:

    probe      one fancy-indexed copy of the simulator's flat counters
               (:func:`repro.pfs.stats.probe_all`) instead of a probe
               call per interface;
    metrics    one :func:`repro.core.metrics.snapshot_all` differencing
               into an ``(n_osc, F)`` matrix;
    inference  all decidable (interface x config) rows for *both* ops
               fused into a single batched forest launch
               (:meth:`DIALModel.score_fleet` — on the jax/pallas
               backends literally one kernel launch with a per-row
               forest selector);
    tuning     :func:`conditional_score_greedy_batch`, Algorithm 1 as
               masked reductions;
    actuation  one fancy-indexed :meth:`set_knobs` for every changed
               interface.

Decisions are bit-for-bit identical to the per-interface loop (kept as
:class:`~repro.core.agent.ReferenceLoopAgent`, the oracle for the
fleet/loop equivalence tests) — only the schedule changes.

Decentralization is preserved: each row of every matrix is built purely
from that interface's client-local counters, and no decision reads
another interface's state.  Batching is an *execution* strategy on a
host that happens to run many clients (or a simulator that models them);
the algorithm remains per-client autonomous.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Protocol

import numpy as np

from repro.core.config_space import SPACE, ConfigSpace
from repro.core.metrics import fleet_feature_matrix, snapshot_all
from repro.core.model import DIALModel
from repro.core.tuner import (FleetDecisions, TunerParams,
                              conditional_score_greedy_batch)
from repro.pfs.engine import READ, WRITE
from repro.pfs.stats import FleetStats, probe_all, stack_stats


class FleetPort(Protocol):
    """What a fleet agent needs from the system it tunes — the batched
    counterpart of :class:`~repro.core.agent.ClientPort`."""

    def osc_ids(self) -> np.ndarray: ...
    def probe_all(self) -> FleetStats: ...
    def set_knobs_many(self, osc_ids, window_pages, rpcs_in_flight) -> None: ...


@dataclasses.dataclass
class SimFleetPort:
    """Adapter: a set of the PFS simulator's OSC interfaces (default all).

    Probing reads the simulator's flat counter arrays directly, so one
    fleet probe costs the same handful of array copies whether it covers
    4 interfaces or 4096.
    """

    sim: object
    oscs: np.ndarray | None = None

    def osc_ids(self) -> np.ndarray:
        if self.oscs is None:
            return np.arange(self.sim.n_osc)
        return np.asarray(self.oscs, dtype=np.int64)

    def probe_all(self) -> FleetStats:
        return probe_all(self.sim, self.osc_ids())

    def set_knobs_many(self, osc_ids, window_pages, rpcs_in_flight) -> None:
        self.sim.set_knobs(osc_ids, window_pages=window_pages,
                           rpcs_in_flight=rpcs_in_flight)


@dataclasses.dataclass
class LoopFleetPort:
    """Adapter lifting any per-interface :class:`ClientPort` to the fleet
    surface.  Probing loops in Python (the port gives us no better), but
    everything downstream — metrics, inference, Algorithm 1 — still runs
    batched, which is where the per-interface milliseconds live."""

    port: object  # ClientPort

    def osc_ids(self) -> np.ndarray:
        return np.asarray(self.port.osc_ids(), dtype=np.int64)

    def probe_all(self) -> FleetStats:
        ids = self.osc_ids()
        return stack_stats([self.port.probe(int(o)) for o in ids], ids)

    def set_knobs_many(self, osc_ids, window_pages, rpcs_in_flight) -> None:
        ids = np.atleast_1d(np.asarray(osc_ids))
        ws = np.broadcast_to(np.asarray(window_pages), ids.shape)
        rs = np.broadcast_to(np.asarray(rpcs_in_flight), ids.shape)
        for o, w, r in zip(ids, ws, rs):
            self.port.set_knobs(int(o), int(w), int(r))


def as_fleet_port(port) -> "FleetPort":
    """Lift a port to the fleet surface (no-op if it already is one)."""
    if hasattr(port, "probe_all"):
        return port
    if hasattr(port, "sim") and hasattr(port, "client"):
        # SimClientPort: take the direct array path for its client's OSCs
        return SimFleetPort(port.sim,
                            np.asarray(port.osc_ids(), dtype=np.int64))
    return LoopFleetPort(port)


@dataclasses.dataclass
class FleetTickResult:
    """Everything one fleet tick decided, row-aligned over decided rows."""

    oscs: np.ndarray          # (m,) interface ids that reached Algorithm 1
    ops: np.ndarray           # (m,) op model used per interface
    decisions: FleetDecisions # batched Algorithm 1 outcomes

    def __len__(self) -> int:
        return len(self.oscs)

    def as_list(self) -> list:
        """Per-agent compat: ``[(osc, op, TuneDecision), ...]``."""
        return [(int(self.oscs[i]), int(self.ops[i]), self.decisions.one(i))
                for i in range(len(self.oscs))]


def empty_tick_result(n_configs: int = len(SPACE)) -> FleetTickResult:
    """A fresh gated-tick result.  Never share one module-level instance:
    result arrays are reachable by every caller (and mutable), so a
    shared empty would alias state across agents — e.g. between the
    frozen and online arms of a continual comparison."""
    return FleetTickResult(
        oscs=np.zeros(0, dtype=np.int64), ops=np.zeros(0, dtype=np.int64),
        decisions=FleetDecisions(theta=np.zeros((0, 2), dtype=np.int64),
                                 changed=np.zeros(0, dtype=bool),
                                 n_candidates=np.zeros(0, dtype=np.int64),
                                 score=np.zeros(0),
                                 probs=np.zeros((0, n_configs))))


class FleetAgent:
    """DIAL for a whole fleet of interfaces; call :meth:`tick` every
    interval.  Constructor arguments mirror :class:`DIALAgent`; the
    semantics per interface are identical."""

    def __init__(
        self,
        port: FleetPort,
        model: DIALModel,
        space: ConfigSpace = SPACE,
        tuner_params: TunerParams | None = None,
        k: int = 1,
        min_volume_bytes: float = 256 * 1024,
        warmup_intervals: int = 2,
        measure_overhead: bool = False,
        tracer=None,
    ):
        from repro.core.agent import AgentTimings  # avoid import cycle

        self.port = port
        self.model = model
        self.space = space
        self.tracer = tracer  # repro.obs.host.HostTracer | None
        self.tuner_params = (tuner_params if tuner_params is not None
                             else TunerParams())
        self.k = k
        self.min_volume = min_volume_bytes
        self.warmup = warmup_intervals
        self._ticks = 0
        self.measure_overhead = measure_overhead
        self.timings = {READ: AgentTimings(), WRITE: AgentTimings()}
        self.oscs = np.asarray(port.osc_ids(), dtype=np.int64)
        self.n = len(self.oscs)
        self._theta_feats = space.as_features()
        st = port.probe_all()
        self._prev = st
        # DIAL keeps only two snapshots per interface in memory (SIV-C);
        # the fleet holds them as two stacked matrices, not 2 x n objects
        self._hist: collections.deque = collections.deque(maxlen=k + 1)
        self._current = np.stack(
            [st.window_pages, st.rpcs_in_flight], axis=1).astype(np.int64)
        self.decisions: list = []

    # ------------------------------------------------------------------ #
    def _gated(self) -> FleetTickResult:
        """Record and return a fresh empty result for a no-decision tick,
        keeping ``decisions[i]`` aligned with interval index ``i``."""
        result = empty_tick_result(len(self.space))
        self.decisions.append(result)
        return result

    def tick(self) -> FleetTickResult:
        """One tuning round across every interface — one batch per stage."""
        self._ticks += 1
        t0 = time.perf_counter()
        cur = self.port.probe_all()
        snap = snapshot_all(self._prev, cur)
        self._prev = cur
        self._hist.append(snap)
        # the *applied* configuration comes from the probe itself, never
        # from a shadow copy: knobs may have changed out-of-band since
        # our last write (ε-greedy exploration, campaign explore/label
        # alternation), and Algorithm 1's `current` / `changed` must see
        # what is actually in effect
        self._current = np.stack(
            [cur.window_pages, cur.rpcs_in_flight], axis=1).astype(np.int64)
        t1 = time.perf_counter()
        vol_r, vol_w = snap.read_volume, snap.write_volume
        ops = np.where(vol_r >= vol_w, READ, WRITE)       # op model (SIII-C)
        active = np.maximum(vol_r, vol_w) >= self.min_volume
        if len(self._hist) < self.k + 1 or self._ticks <= self.warmup + self.k:
            if self.tracer is not None:
                self._trace_gated(cur.t, ops, vol_r, vol_w, active,
                                  warm=False,
                                  steady=np.zeros(self.n, dtype=bool),
                                  ratio=np.zeros(self.n))
            return self._gated()

        # per-interface gating, all as masks (same predicates as the loop)
        oldest = self._hist[0]
        v0 = np.where(ops == READ, oldest.read_volume, oldest.write_volume)
        v1 = np.where(ops == READ, vol_r, vol_w)
        ratio = v1 / np.maximum(v0, 1.0)
        steady = (ratio >= 0.5) & (ratio <= 2.0)          # burst guard
        rows = np.nonzero(active & steady)[0]
        if rows.size == 0:
            if self.tracer is not None:
                self._trace_gated(cur.t, ops, vol_r, vol_w, active,
                                  warm=True, steady=steady, ratio=ratio)
            return self._gated()

        # one feature matrix per op group, one fused model launch
        history = list(self._hist)
        read_rows = rows[ops[rows] == READ]
        write_rows = rows[ops[rows] == WRITE]
        X_read = fleet_feature_matrix(history, READ, read_rows,
                                      self._theta_feats)
        X_write = fleet_feature_matrix(history, WRITE, write_rows,
                                       self._theta_feats)
        p_read, p_write = self.model.score_fleet(X_read, X_write)
        m = len(self.space)
        probs = np.empty((rows.size, m))
        is_read = ops[rows] == READ
        probs[is_read] = p_read.reshape(read_rows.size, m)
        probs[~is_read] = p_write.reshape(write_rows.size, m)
        t2 = time.perf_counter()

        # batched Algorithm 1, then one fancy-indexed knob application
        cur_theta = (self._current.copy() if self.tracer is not None
                     else None)
        dec = conditional_score_greedy_batch(
            probs, ops[rows], self._current[rows], self.space,
            self.tuner_params)
        ch = dec.changed
        if ch.any():
            self.port.set_knobs_many(self.oscs[rows[ch]],
                                     dec.theta[ch, 0], dec.theta[ch, 1])
            self._current[rows[ch]] = dec.theta[ch]
        t3 = time.perf_counter()
        if self.tracer is not None:
            self._trace_decided(cur.t, rows, dec, ops, vol_r, vol_w,
                                active, steady, ratio, cur_theta)

        result = FleetTickResult(oscs=self.oscs[rows], ops=ops[rows],
                                 decisions=dec)
        if self.measure_overhead:
            self._record_timings(rows, is_read, t0, t1, t2, t3)
        self.decisions.append(result)
        return result

    # ------------------------------------------------------------------ #
    def _record_timings(self, rows, is_read, t0, t1, t2, t3) -> None:
        """Amortized per-interface wall-clock (fleet Table III semantics).

        The loop agent attributes each interface its own full probe /
        inference / apply latency; the fleet pays those costs once for
        the whole batch, so the honest per-interface figure is the batch
        cost divided by the interfaces it covered.
        """
        snap_ms = (t1 - t0) / max(self.n, 1) * 1e3
        inf_ms = (t2 - t1) / max(rows.size, 1) * 1e3
        e2e_ms = (t3 - t0) / max(rows.size, 1) * 1e3
        for op, mask in ((READ, is_read), (WRITE, ~is_read)):
            if mask.any():
                tm = self.timings[op]
                tm.snapshot_ms.append(snap_ms)
                tm.inference_ms.append(inf_ms)
                tm.end_to_end_ms.append(e2e_ms)

    # ------------------------------------------------------------------ #
    def _trace_gated(self, t, ops, vol_r, vol_w, active, warm, steady,
                     ratio) -> None:
        """Mirror a no-decision interval into the tracer (raw values;
        the shared normalization applies the masking convention)."""
        zb = np.zeros(self.n, dtype=bool)
        cur = self._current.copy()
        self.tracer.record_interval(
            t, zb, ops, cur, zb, np.zeros(self.n, dtype=np.int64),
            np.zeros(self.n), np.zeros((self.n, len(self.space))),
            vol_r, vol_w, active, steady, warm, ratio, cur)

    def _trace_decided(self, t, rows, dec, ops, vol_r, vol_w, active,
                       steady, ratio, cur_theta) -> None:
        """Mirror a decided interval: scatter the Algorithm 1 outcome
        back to full-fleet arrays.  ``self._current`` post-update is the
        Algorithm 1 θ for every decided row (changed rows were written,
        unchanged rows already matched), so it serves as the dense
        ``theta`` column directly."""
        decided = np.zeros(self.n, dtype=bool)
        decided[rows] = True
        changed = np.zeros(self.n, dtype=bool)
        changed[rows] = dec.changed
        ncand = np.zeros(self.n, dtype=np.int64)
        ncand[rows] = dec.n_candidates
        score = np.zeros(self.n)
        score[rows] = dec.score
        probs = np.zeros((self.n, len(self.space)))
        probs[rows] = dec.probs
        self.tracer.record_interval(
            t, decided, ops, self._current.copy(), changed, ncand,
            score, probs, vol_r, vol_w, active, steady, True, ratio,
            cur_theta)

    # ------------------------------------------------------------------ #
    def ingest_fused(self, result) -> None:
        """Adopt a :class:`~repro.pfs.loop_jax.FusedLoopResult` as this
        agent's history: ``decisions`` gets one record per interval
        (same alignment as :meth:`tick`), the probe/current state
        re-syncs from the post-run port, and the snapshot history deque
        refills from the run's final in-scan ring — so further host
        ticks decide exactly as if every interval had run on the host.
        """
        from repro.core.metrics import FleetSnapshot

        self.decisions.extend(result.decisions)
        self._ticks += result.n_intervals
        st = self.port.probe_all()
        self._prev = st
        self._current = np.stack(
            [st.window_pages, st.rpcs_in_flight], axis=1).astype(np.int64)
        if result.hist is None or np.asarray(result.hist[0]).ndim != 3:
            return                              # untuned or batched run
        hr, hw, hrv, hwv = result.hist          # (k+1, n_all, F) rings
        rows = self.oscs                        # this agent's subset
        kp1 = hr.shape[0]
        # ring slots older than the run's interval count are still the
        # zero-initialized placeholders — only adopt real snapshots
        valid = min(result.n_intervals, kp1)
        for j in range(kp1 - valid, kp1):
            age = kp1 - 1 - j                   # intervals before "now"
            self._hist.append(FleetSnapshot(
                t=st.t - age * result.interval_seconds,
                dt=result.interval_seconds,
                oscs=rows,
                read=hr[j][rows], write=hw[j][rows],
                read_volume=hrv[j][rows], write_volume=hwv[j][rows]))


def run_fleet(sim, model: DIALModel, oscs=None, seconds: float = 10.0,
              interval: float = 0.5, measure_overhead: bool = False,
              tuner_params: TunerParams | None = None,
              backend: str = "numpy", seg_backend: str = "auto",
              mesh=None, trace=None) -> FleetAgent:
    """Drive the simulator with one fleet agent over ``oscs`` (default
    all interfaces) — the batched counterpart of ``run_with_agents``.

    ``backend`` selects the execution layer:

    * ``"numpy"`` — the historical Python tick loop (``sim.step()`` per
      tick, legacy Workload objects depositing demand), tuning on host;
    * ``"jax"``   — the fused interval path: the attached workloads are
      frozen into a :class:`~repro.pfs.workloads.WorkloadTable` and each
      whole interval advances through one jitted ``lax.scan``
      (:class:`~repro.pfs.engine_jax.FusedEngine`), with per-OST/client
      reductions on the shared segment-sum kernel (``seg_backend``);
      tuning still runs per interval on the host;
    * ``"jax-fused"`` — the device-resident loop
      (:class:`~repro.pfs.loop_jax.FusedLoop`): engine **and** the whole
      decision path (snapshot differencing, featurization, forest
      scoring, Algorithm 1, knob write-back) execute as one jitted
      dispatch covering every interval of the run.
    * ``"jax-sharded"`` — the fused loop dispatched through a 1-D device
      mesh (``mesh``, default :func:`repro.distributed.sharding.fleet_mesh`
      over all local devices): the sim is lifted to a one-element batch
      and run through the ``shard_map``-partitioned program.  One sim's
      interfaces share OSTs (coupled inside the engine), so a single sim
      still lands on one device — this backend exists to exercise and
      pin the sharded dispatch end to end; real scale-out shards *many*
      sims/fleet-slices via ``run_batch(fused=True, mesh=...)``
      (benchmarks/fleet_weak_scaling.py).

    Decisions and knob trajectories are identical on every backend —
    only the execution schedule changes (tests/test_loop_fused.py,
    tests/test_shard.py).

    ``trace`` (a :class:`~repro.obs.schema.TraceConfig`) opts the run
    into telemetry: the returned agent carries a normalized
    :class:`~repro.obs.schema.RunTrace` as ``fleet.trace``.  On the
    fused backends the records accumulate as scan outputs inside the
    dispatch; on ``"numpy"`` a :class:`~repro.obs.host.HostTracer`
    mirrors the identical schema (``"jax"`` records decision provenance
    only — the interval engine exposes no per-tick state to sample).
    Tracing never perturbs a decision (tests/test_obs.py).
    """
    if mesh is not None and backend != "jax-sharded":
        raise ValueError("mesh only applies to backend='jax-sharded'")
    tracer = None
    if trace is not None and backend in ("numpy", "jax"):
        from repro.obs.host import HostTracer
        tracer = HostTracer(trace, sim.params, sim.topo)
    fleet = FleetAgent(SimFleetPort(sim, oscs), model,
                       tuner_params=tuner_params,
                       measure_overhead=measure_overhead, tracer=tracer)
    fleet.trace = None
    steps_per_interval = max(int(round(interval / sim.params.tick)), 1)
    n_intervals = int(round(seconds / interval))
    if backend == "numpy":
        for _ in range(n_intervals):
            for j in range(steps_per_interval):
                sim.step()
                if tracer is not None and \
                        tracer.wants_sample(j, steps_per_interval):
                    tracer.sample(sim.state)
            fleet.tick()
    elif backend == "jax":
        from repro.pfs.engine_jax import FusedEngine
        from repro.pfs.workloads import (sync_workloads_from_table,
                                         table_from_sim)

        table, wstate = table_from_sim(sim)
        engine = FusedEngine(sim.params, sim.topo, table,
                             steps_per_interval, seg_backend=seg_backend)
        for _ in range(n_intervals):
            sim.state, wstate = engine.run_interval(sim.state, wstate)
            fleet.tick()
        sync_workloads_from_table(sim, wstate)
    elif backend == "jax-fused":
        from repro.pfs.loop_jax import FusedLoop
        from repro.pfs.workloads import (sync_workloads_from_table,
                                         table_from_sim)

        if measure_overhead:
            raise ValueError(
                "measure_overhead requires per-interval host timing; "
                "inside the single fused dispatch there is nothing to "
                "time per stage — use backend='numpy' or 'jax' "
                "(benchmarks/loop_scaling.py measures the fused path "
                "end to end)")
        table, wstate = table_from_sim(sim)
        loop = FusedLoop(sim.params, sim.topo, steps_per_interval, model,
                         space=fleet.space, tuner_params=fleet.tuner_params,
                         k=fleet.k, min_volume_bytes=fleet.min_volume,
                         warmup_intervals=fleet.warmup,
                         seg_backend=seg_backend, trace=trace)
        tune_mask = np.zeros(sim.n_osc, dtype=bool)
        tune_mask[fleet.oscs] = True
        result = loop.run(table, sim.state, wstate, n_intervals,
                          tune_mask=tune_mask)
        sim.state = result.state
        sync_workloads_from_table(sim, result.wstate)
        fleet.ingest_fused(result)
        if trace is not None:
            fleet.trace = loop.run_trace(result)
    elif backend == "jax-sharded":
        import jax

        from repro.distributed.sharding import fleet_mesh
        from repro.pfs.loop_jax import FusedLoop
        from repro.pfs.workloads import (sync_workloads_from_table,
                                         table_from_sim)

        if measure_overhead:
            raise ValueError(
                "measure_overhead requires per-interval host timing; "
                "inside the single fused dispatch there is nothing to "
                "time per stage — use backend='numpy' or 'jax'")
        if mesh is None:
            mesh = fleet_mesh()
        table, wstate = table_from_sim(sim)
        loop = FusedLoop(sim.params, sim.topo, steps_per_interval, model,
                         space=fleet.space, tuner_params=fleet.tuner_params,
                         k=fleet.k, min_volume_bytes=fleet.min_volume,
                         warmup_intervals=fleet.warmup,
                         seg_backend=seg_backend, batched=True, mesh=mesh,
                         trace=trace)
        # lift to a one-element batch (scalars -> (1,) leaves), run the
        # sharded program, drop the batch axis again
        lift = lambda tree: jax.tree.map(
            lambda a: np.stack([np.asarray(a)]), tree)
        tune_mask = np.zeros((1, sim.n_osc), dtype=bool)
        tune_mask[0, fleet.oscs] = True
        result = loop.run(lift(table), lift(sim.state), lift(wstate),
                          n_intervals, tune_mask=tune_mask)
        drop = lambda tree: jax.tree.map(lambda a: np.asarray(a)[0], tree)
        state = drop(result.state)
        state.now = float(state.now)
        state.tick_index = int(state.tick_index)
        result = dataclasses.replace(
            result, state=state, wstate=drop(result.wstate),
            trace=(drop(result.trace) if result.trace is not None
                   else None),
            hist=(drop(result.hist) if result.hist is not None else None))
        sim.state = result.state
        sync_workloads_from_table(sim, result.wstate)
        fleet.ingest_fused(result)
        if trace is not None:
            fleet.trace = loop.run_trace(result)
    else:
        raise ValueError(f"unknown engine backend {backend!r}")
    if tracer is not None:
        fleet.trace = tracer.run_trace(fleet.oscs, interval,
                                       sim.params.tick)
    return fleet
