"""The tunable configuration space Theta (paper SII-B / SIII-C).

DIAL tunes two per-OSC Lustre client knobs that (a) are runtime-tunable
with near-immediate effect and (b) have workload-entangled optima:

    theta^1 = RPC window size   (osc.*.max_pages_per_rpc)
    theta^2 = RPCs in flight    (osc.*.max_rpcs_in_flight)

The discrete space below spans Lustre's practical range (64 KiB .. 4 MiB
windows, 1 .. 32 concurrent RPCs); the Lustre defaults (256 pages, 8) sit
mid-grid.  |Theta| = 24, which the tuner scores exhaustively each interval
— this full scan is what the batched GBDT inference kernel accelerates.
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

WINDOW_PAGES = (16, 64, 256, 1024)
RPCS_IN_FLIGHT = (1, 2, 4, 8, 16, 32)

DEFAULT = (256, 8)  # Lustre defaults


@dataclasses.dataclass(frozen=True)
class ConfigSpace:
    """Discrete Theta with helpers for normalization and enumeration."""

    window_pages: tuple = WINDOW_PAGES
    rpcs_in_flight: tuple = RPCS_IN_FLIGHT

    def __len__(self) -> int:
        return len(self.window_pages) * len(self.rpcs_in_flight)

    def configs(self) -> list[tuple[int, int]]:
        """All theta = (window_pages, rpcs_in_flight), row-major."""
        return list(itertools.product(self.window_pages, self.rpcs_in_flight))

    def as_array(self) -> np.ndarray:
        """(|Theta|, 2) array of raw theta values."""
        return np.array(self.configs(), dtype=np.float64)

    def as_features(self) -> np.ndarray:
        """(|Theta|, 2) log2-scaled theta features fed to the GBDT.

        Both knobs are power-of-two grids; log scaling gives the trees
        evenly spaced split candidates.
        """
        return np.log2(self.as_array())

    def minmax_normalize(self, thetas: np.ndarray) -> np.ndarray:
        """MinMax-normalize a subset S of configurations (Algorithm 1 l.6).

        Normalization is over the *subset* S, exactly as in the paper: the
        regularizer then ranks surviving configs relative to one another.
        Degenerate spans (single distinct value) normalize to 0.
        """
        t = np.asarray(thetas, dtype=np.float64)
        lo = t.min(axis=0, keepdims=True)
        hi = t.max(axis=0, keepdims=True)
        span = np.where(hi - lo > 0, hi - lo, 1.0)
        return (t - lo) / span

    def index_of(self, theta: tuple[int, int]) -> int:
        return self.configs().index((int(theta[0]), int(theta[1])))


SPACE = ConfigSpace()
