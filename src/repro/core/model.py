"""DIAL's learned models: f(theta, H_t) -> P(improvement > 1+eps).

One :class:`DIALModel` bundles the two GBDT forests (read / write —
separate models per paper SIII-B) and the batched scorer that evaluates
the *entire* configuration space against the current history in one shot.

Backends:
    'numpy'  -- DenseForest.predict_proba (always available; the oracle)
    'jax'    -- jitted gather-based traversal (repro.kernels.gbdt_forest.ops)
    'pallas' -- the TPU kernel in interpret mode on CPU, compiled on TPU

The batched evaluation (n_oscs x |Theta| rows per tick) is the paper's
inference hot spot (Table III: ~10-13.5 ms per interface); the TPU
formulation evaluates all interfaces x configs in a single launch.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core.config_space import ConfigSpace, SPACE
from repro.core.gbdt import DenseForest
from repro.core.metrics import Snapshot, feature_vector
from repro.pfs.engine import READ, WRITE


def dataset_fingerprint(data: dict) -> dict:
    """Row counts + a cheap content hash of a ``{'read': (X, y), 'write':
    (X, y)}`` training dict — persisted with trained artifacts so
    evaluations can refuse models trained on a different dataset."""
    import hashlib

    h = hashlib.sha256()
    counts = {}
    for op_name in ("read", "write"):
        X, y = data[op_name]
        counts[op_name] = int(len(X))
        h.update(np.ascontiguousarray(np.asarray(X, dtype=np.float32)))
        h.update(np.ascontiguousarray(np.asarray(y, dtype=np.float64)))
    return {"rows": counts, "sha256": h.hexdigest()[:16]}


@dataclasses.dataclass
class DIALModel:
    read_forest: DenseForest
    write_forest: DenseForest
    space: ConfigSpace = SPACE
    backend: str = "numpy"
    k: int = 1  # history length (paper uses k=1)
    # provenance: trainer backend + dataset fingerprint, persisted by
    # save/load so artifact consumers can detect mismatched models
    train_meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self._theta_feats = self.space.as_features()  # (|Theta|, 2) log2
        self._jax_fns = {}
        # bumped by update_forests: consumers that baked forest copies
        # onto the device (e.g. the fused-loop cache) key on it so a
        # refit can never serve stale trees
        self._version = getattr(self, "_version", 0)

    def update_forests(self, read_forest: DenseForest | None = None,
                       write_forest: DenseForest | None = None) -> None:
        """Swap retrained forests in place (the online-refit path).

        Invalidates every cached jitted predictor — the old closures
        hold the stale forests on device — so the next score builds
        fresh ones from the new arrays.
        """
        if read_forest is not None:
            self.read_forest = read_forest
        if write_forest is not None:
            self.write_forest = write_forest
        self._jax_fns.clear()
        self._version += 1

    def forest(self, op: int) -> DenseForest:
        return self.read_forest if op == READ else self.write_forest

    # ------------------------------------------------------------------ #
    def features_for_space(self, history: list[Snapshot], op: int) -> np.ndarray:
        """(|Theta|, dim) feature matrix: H_t broadcast against every theta."""
        from repro.core.metrics import READ_KNOB_IDX, WRITE_KNOB_IDX

        hist = feature_vector(history, op, self._theta_feats[0])[:-4]
        knobs = READ_KNOB_IDX if op == READ else WRITE_KNOB_IDX
        last = (history[-1].read if op == READ else history[-1].write)
        cur = np.array([last[knobs[0]], last[knobs[1]]])
        m = len(self.space)
        out = np.empty((m, hist.shape[0] + 4), dtype=np.float32)
        out[:, :-4] = hist
        out[:, -4:-2] = self._theta_feats
        out[:, -2:] = self._theta_feats - cur[None, :]
        return out

    def score_space(self, history: list[Snapshot], op: int) -> np.ndarray:
        """f(theta, H_t) for every theta in space order."""
        X = self.features_for_space(history, op)
        return self.predict_proba(op, X)

    def score_space_batch(self, histories: list[list[Snapshot]],
                          op: int) -> np.ndarray:
        """(n_oscs, |Theta|) probabilities — one launch for all interfaces."""
        X = np.concatenate([self.features_for_space(h, op) for h in histories])
        p = self.predict_proba(op, X)
        return p.reshape(len(histories), len(self.space))

    def score_fleet(self, X_read: np.ndarray,
                    X_write: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Probabilities for mixed read/write row batches — the fleet path.

        ``X_read`` / ``X_write`` are the stacked (interface x config)
        feature rows from :func:`repro.core.metrics.fleet_feature_matrix`.
        On the jax/pallas backends both ops are fused into **one** launch
        with a per-row forest selector (the two forests live stacked on
        device); the numpy backend scores each forest once — still one
        batched traversal per op, never one call per interface.
        """
        if self.backend == "numpy":
            p_read = (self.read_forest.predict_proba(X_read)
                      if len(X_read) else np.zeros(0))
            p_write = (self.write_forest.predict_proba(X_write)
                       if len(X_write) else np.zeros(0))
            return p_read, p_write
        from repro.kernels.gbdt_forest import ops as kops  # lazy import
        key = ("fleet", self.backend)
        if key not in self._jax_fns:
            self._jax_fns[key] = kops.make_fleet_predictor(
                self.read_forest, self.write_forest,
                use_pallas=(self.backend == "pallas"))
        return self._jax_fns[key](X_read, X_write)

    def paired_arrays(self):
        """Both forests stacked into one paired tensor set (numpy).

        ``(feature, threshold, leaf, base, depth, n_features)`` with
        forest axis 0 = read, 1 = write — the arrays the fused fleet
        predictor and the device-resident loop
        (:mod:`repro.pfs.loop_jax`) traverse with a per-row op selector.
        Cached until :meth:`update_forests` swaps the forests.
        """
        from repro.kernels.gbdt_forest import ops as kops  # lazy import
        key = ("paired",)
        if key not in self._jax_fns:
            self._jax_fns[key] = kops.pair_forests(self.read_forest,
                                                   self.write_forest)
        return self._jax_fns[key]

    # ------------------------------------------------------------------ #
    def predict_proba(self, op: int, X: np.ndarray) -> np.ndarray:
        f = self.forest(op)
        if self.backend == "numpy":
            return f.predict_proba(X)
        from repro.kernels.gbdt_forest import ops as kops  # lazy import
        key = (op, self.backend)
        if key not in self._jax_fns:
            self._jax_fns[key] = kops.make_predictor(
                f, use_pallas=(self.backend == "pallas"))
        return np.asarray(self._jax_fns[key](np.asarray(X, dtype=np.float32)))

    # ------------------------------------------------------------------ #
    def save(self, prefix: str) -> None:
        self.read_forest.save(prefix + ".read.npz")
        self.write_forest.save(prefix + ".write.npz")
        meta_path = prefix + ".meta.json"
        if self.train_meta:
            with open(meta_path, "w") as f:
                json.dump(self.train_meta, f, indent=2, default=str)
        elif os.path.exists(meta_path):
            # never leave another model's provenance attached to these
            # forests — a stale meta.json would defeat the artifact guard
            os.remove(meta_path)

    @staticmethod
    def load(prefix: str, backend: str = "numpy") -> "DIALModel":
        meta = {}
        meta_path = prefix + ".meta.json"
        if os.path.exists(meta_path):
            try:
                with open(meta_path) as f:
                    meta = json.load(f)
            except (OSError, ValueError):
                meta = {}
        return DIALModel(
            read_forest=DenseForest.load(prefix + ".read.npz"),
            write_forest=DenseForest.load(prefix + ".write.npz"),
            backend=backend, train_meta=meta)
