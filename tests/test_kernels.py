"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.gbdt import GBDTClassifier, GBDTParams

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------- #
# gbdt_forest
# ---------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def forest():
    X = RNG.normal(size=(2000, 34)).astype(np.float32)
    y = ((X[:, 0] + X[:, 1] * X[:, 2]) > 0).astype(float)
    return GBDTClassifier(GBDTParams(n_trees=24, max_depth=5)).fit(X, y).forest


@pytest.mark.parametrize("n,block", [(64, 64), (100, 64), (513, 128), (24, 512)])
def test_gbdt_forest_kernel_matches_refs(forest, n, block):
    from repro.kernels.gbdt_forest.kernel import forest_margin
    from repro.kernels.gbdt_forest.ref import forest_margin_ref

    X = jnp.asarray(RNG.normal(size=(n, forest.n_features)), jnp.float32)
    args = (jnp.asarray(forest.feature), jnp.asarray(forest.threshold),
            jnp.asarray(forest.leaf), forest.base_score, forest.depth)
    ref = forest_margin_ref(X, *args)
    pal = forest_margin(X, *args, block_n=block)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=1e-5, atol=1e-5)
    # and against the numpy oracle
    np.testing.assert_allclose(np.asarray(ref),
                               forest.predict_margin(np.asarray(X)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------- #
# flash attention
# ---------------------------------------------------------------------- #
CASES = [
    dict(b=1, hq=4, hkv=4, sq=64, skv=64, d=32),
    dict(b=2, hq=8, hkv=2, sq=64, skv=64, d=32),                 # GQA
    dict(b=1, hq=4, hkv=1, sq=48, skv=48, d=64),                 # MQA + pad
    dict(b=1, hq=4, hkv=2, sq=64, skv=64, d=32, window=16),
    dict(b=1, hq=4, hkv=4, sq=64, skv=64, d=32, softcap=50.0),
    dict(b=1, hq=4, hkv=2, sq=1, skv=100, d=32),                 # decode
    dict(b=1, hq=2, hkv=2, sq=40, skv=104, d=64, window=32),
    dict(b=1, hq=2, hkv=2, sq=64, skv=64, d=32, causal=False),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_flash_attention_matches_ref(case, dtype, tol):
    from repro.kernels.flash_attention.ops import attention

    c = dict(case)
    b, hq, hkv = c.pop("b"), c.pop("hq"), c.pop("hkv")
    sq, skv, d = c.pop("sq"), c.pop("skv"), c.pop("d")
    q = jnp.asarray(RNG.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, skv, d)), dtype)
    o_ref = attention(q, k, v, backend="ref", **c)
    o_pal = attention(q, k, v, backend="pallas_interpret",
                      block_q=32, block_kv=32, **c)
    err = float(jnp.abs(o_ref.astype(jnp.float32)
                        - o_pal.astype(jnp.float32)).max())
    assert err < tol, (case, dtype, err)


def test_flash_attention_matches_chunked_production_path():
    """The chunked jnp attention (production lowering path) and the Pallas
    kernel implement identical semantics."""
    from repro.kernels.flash_attention.ops import attention
    from repro.models.attention import chunked_attention

    b, hq, hkv, s, d = 2, 8, 2, 96, 32
    q = jnp.asarray(RNG.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, d)), jnp.float32)
    out_chunked = chunked_attention(q, k, v, causal=True, window=0,
                                    softcap=0.0, q_chunk=32, kv_chunk=32)
    out_kernel = attention(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                           jnp.moveaxis(v, 1, 2),
                           backend="pallas_interpret", block_q=32, block_kv=32)
    err = float(jnp.abs(jnp.moveaxis(out_kernel, 1, 2) - out_chunked).max())
    assert err < 2e-5, err


# ---------------------------------------------------------------------- #
# mamba selective scan
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("bt,s,dm,n,bd", [
    (2, 64, 128, 16, 64), (1, 33, 256, 8, 256), (3, 128, 64, 16, 64),
])
def test_mamba_scan_matches_ref(bt, s, dm, n, bd):
    from repro.kernels.mamba_scan.ops import selective_scan

    u = jnp.asarray(RNG.normal(size=(bt, s, dm)), jnp.float32)
    delta = jnp.asarray(np.abs(RNG.normal(size=(bt, s, dm))) * 0.1, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.normal(size=(dm, n))) - 0.1, jnp.float32)
    B = jnp.asarray(RNG.normal(size=(bt, s, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(bt, s, n)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(dm,)), jnp.float32)
    ref = selective_scan(u, delta, A, B, C, D, backend="ref")
    pal = selective_scan(u, delta, A, B, C, D, backend="pallas_interpret",
                         block_d=bd)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------- #
# rglru scan
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("bt,s,dm,bd", [
    (2, 64, 128, 64), (1, 100, 256, 128), (4, 17, 64, 64),
])
def test_rglru_matches_ref(bt, s, dm, bd):
    from repro.kernels.rglru_scan.ops import rglru

    x = jnp.asarray(RNG.normal(size=(bt, s, dm)), jnp.float32)
    a = jnp.asarray(1 / (1 + np.exp(-RNG.normal(size=(bt, s, dm)))), jnp.float32)
    ref = rglru(x, a, backend="ref")
    pal = rglru(x, a, backend="pallas_interpret", block_d=bd)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal),
                               rtol=1e-4, atol=1e-4)


def test_rglru_sequential_equals_associative():
    """The associative-scan ref equals a plain sequential recurrence."""
    from repro.kernels.rglru_scan.ref import rglru_ref

    x = RNG.normal(size=(1, 50, 8)).astype(np.float32)
    a = (1 / (1 + np.exp(-RNG.normal(size=(1, 50, 8))))).astype(np.float32)
    h = np.zeros((1, 8), np.float32)
    seq = []
    for t in range(50):
        h = a[:, t] * h + np.sqrt(1 - a[:, t] ** 2) * x[:, t]
        seq.append(h.copy())
    seq = np.stack(seq, axis=1)
    np.testing.assert_allclose(np.asarray(rglru_ref(jnp.asarray(x),
                                                    jnp.asarray(a))),
                               seq, rtol=1e-5, atol=1e-5)
