"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; only the dry-run (subprocess) forces 512."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def dial_model():
    """Production DIAL model if trained, else a quick small one."""
    from repro.core.model import DIALModel
    try:
        return DIALModel.load("models/dial")
    except FileNotFoundError:
        from repro.core.dataset import collect, train_models, CollectConfig
        from repro.core.gbdt import GBDTParams
        data = collect(CollectConfig(seconds=30.0, reps=1))
        return train_models(data, GBDTParams(n_trees=40, max_depth=5))
