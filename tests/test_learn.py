"""The learn layer: histogram kernel oracle, trainer parity, online refit.

Pins the three contracts the on-device training subsystem stands on:

* every ``tree_histogram`` backend matches the ``np.bincount`` oracle
  (<= 1e-6 relative), including the drop-id convention the
  sibling-subtraction trick relies on;
* the jitted trainer (``precision="exact"``) reproduces
  ``GBDTClassifier`` split for split — identical features, thresholds
  and leaves to <= 1e-5 — on fresh data, through the vmapped batch
  path, and on a real (CI-sized) SIV-A campaign dataset, where the
  ``fast`` float32 mode must also hold held-out AUC parity;
* the online machinery (replay ring, drift detector, refit swap)
  behaves, and a continual run collects labeled samples and refits a
  live model mid-flight.
"""

import numpy as np
import pytest

from repro.core.gbdt import DenseForest, GBDTClassifier, GBDTParams
from repro.kernels.tree_histogram.ops import tree_histogram
from repro.kernels.tree_histogram.ref import tree_histogram_np
from repro.learn.boost import fit_forest, fit_forest_batch
from repro.learn.online import DriftDetector, OnlinePolicy, ReplayBuffer


def _assert_forests_match(f1: DenseForest, f2: DenseForest,
                          tol: float = 1e-5) -> None:
    np.testing.assert_array_equal(f1.feature, f2.feature)
    thr_ok = (np.isclose(f1.threshold, f2.threshold, atol=tol)
              | (np.isinf(f1.threshold) & np.isinf(f2.threshold)))
    assert thr_ok.all(), "thresholds diverge beyond tolerance"
    np.testing.assert_allclose(f1.leaf, f2.leaf, atol=tol)
    assert f1.base_score == pytest.approx(f2.base_score, abs=tol)
    assert (f1.depth, f1.n_features) == (f2.depth, f2.n_features)


def _toy(n=2500, d=10, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    y = ((X[:, 0] > 0.3) & (X[:, 1] < 0.5)
         | (X[:, 2] * X[:, 3] > 1.0)).astype(float)
    return X, y


def _auc(scores, labels):
    order = np.argsort(scores)
    r = np.empty(len(scores))
    r[order] = np.arange(1, len(scores) + 1)
    pos = labels == 1
    n_pos, n_neg = pos.sum(), (~pos).sum()
    return (r[pos].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg)


# ---------------------------------------------------------------------- #
# tree_histogram kernel vs oracle
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["jax", "matmul", "pallas_interpret"])
def test_tree_histogram_matches_oracle(backend):
    rng = np.random.default_rng(0)
    n, F, n_nodes, n_bins, C = 2500, 7, 8, 12, 3
    values = rng.normal(size=(C, n))
    bins = rng.integers(0, n_bins, size=(n, F))
    node = rng.integers(0, n_nodes, size=n)
    oracle = tree_histogram_np(values, bins, node, n_nodes, n_bins)
    got = np.asarray(tree_histogram(
        values.astype(np.float32), bins, node, n_nodes, n_bins,
        backend=backend))
    scale = np.abs(oracle).max()
    assert np.abs(got - oracle).max() / scale < 1e-6
    # conservation: cells of any one feature sum to the channel totals
    np.testing.assert_allclose(got[:, :, 0, :].sum(axis=(1, 2)),
                               values.sum(axis=1), rtol=1e-6)


@pytest.mark.parametrize("backend", ["jax", "matmul", "pallas_interpret"])
def test_tree_histogram_drops_out_of_range_nodes(backend):
    """The sibling-subtraction trick parks right-child samples on id
    ``n_nodes``; every backend must drop them."""
    rng = np.random.default_rng(1)
    n, F, n_nodes, n_bins = 600, 3, 4, 8
    values = rng.normal(size=(2, n))
    bins = rng.integers(0, n_bins, size=(n, F))
    node = rng.integers(0, n_nodes + 1, size=n)     # some on the drop id
    oracle = tree_histogram_np(values, bins, node, n_nodes, n_bins)
    got = np.asarray(tree_histogram(values, bins, node, n_nodes, n_bins,
                                    backend=backend))
    np.testing.assert_allclose(got, oracle, atol=1e-5)


# ---------------------------------------------------------------------- #
# trainer parity: jitted learn/boost vs the numpy loop
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 3])
def test_fit_forest_reproduces_numpy_trainer(seed):
    X, y = _toy(seed=seed)
    p = GBDTParams(n_trees=25, max_depth=5, seed=seed)
    f_np = GBDTClassifier(p).fit(X, y).forest
    f_jx = fit_forest(X, y, p)
    _assert_forests_match(f_np, f_jx)
    np.testing.assert_allclose(f_np.predict_margin(X[:256]),
                               f_jx.predict_margin(X[:256]), atol=1e-5)


def test_fit_forest_batch_pads_and_matches():
    """Read/write-shaped pair (different n and F) in one vmapped launch."""
    rng = np.random.default_rng(42)
    Xa = rng.normal(size=(900, 8))
    ya = (Xa[:, 0] > 0).astype(float)
    Xb = rng.normal(size=(1300, 12))
    yb = (Xb[:, 1] + Xb[:, 2] > 0.5).astype(float)
    p = GBDTParams(n_trees=15, max_depth=4)
    fa, fb = fit_forest_batch([(Xa, ya), (Xb, yb)], p)
    _assert_forests_match(GBDTClassifier(p).fit(Xa, ya).forest, fa)
    _assert_forests_match(GBDTClassifier(p).fit(Xb, yb).forest, fb)


def test_fit_forest_batch_sweeps_continuous_hyperparams():
    """Per-forest learning rates ride the vmap; each element matches its
    own sequential fit."""
    X, y = _toy(n=1200, seed=5)
    plist = [GBDTParams(n_trees=10, max_depth=4, learning_rate=lr)
             for lr in (0.05, 0.2)]
    out = fit_forest_batch([(X, y), (X, y)], plist)
    for p, f in zip(plist, out):
        _assert_forests_match(GBDTClassifier(p).fit(X, y).forest, f)


def test_campaign_dataset_parity_and_fast_auc():
    """On a real SIV-A campaign dataset: exact mode matches the numpy
    trainer split for split; fast (float32) mode holds held-out AUC."""
    from repro.lab.campaign import CampaignConfig, SMOKE_GRID, collect_batch

    cfg = CampaignConfig(seconds=10.0, reps=1, grid=SMOKE_GRID,
                         contention_frac=0.5, seed=3)
    data = collect_batch(cfg)
    X, y = data["read"]
    assert len(X) >= 40, "campaign produced too few read samples"
    cut = int(0.7 * len(X))
    Xtr, ytr, Xte, yte = X[:cut], y[:cut], X[cut:], y[cut:]
    p = GBDTParams(n_trees=30, max_depth=4)
    f_np = GBDTClassifier(p).fit(Xtr, ytr).forest
    _assert_forests_match(f_np, fit_forest(Xtr, ytr, p))
    if len(set(yte)) == 2:
        f_fast = fit_forest(Xtr, ytr, p, precision="fast")
        a_np = _auc(f_np.predict_margin(Xte), yte)
        a_fast = _auc(f_fast.predict_margin(Xte), yte)
        assert abs(a_np - a_fast) < 0.1


def test_fast_mode_statistical_parity():
    X, y = _toy(n=3000, seed=9)
    p = GBDTParams(n_trees=30, max_depth=5)
    f_np = GBDTClassifier(p).fit(X[:2000], y[:2000]).forest
    f_fast = fit_forest(X[:2000], y[:2000], p, precision="fast")
    a_np = _auc(f_np.predict_margin(X[2000:]), y[2000:])
    a_fast = _auc(f_fast.predict_margin(X[2000:]), y[2000:])
    assert a_fast > 0.9
    assert abs(a_np - a_fast) < 0.05


# ---------------------------------------------------------------------- #
# online machinery
# ---------------------------------------------------------------------- #
def test_replay_buffer_ring_semantics():
    buf = ReplayBuffer(capacity=8, dim=3)
    buf.add(np.ones((5, 3)), np.arange(5))
    assert len(buf) == 5
    buf.add(2 * np.ones((6, 3)), np.arange(5, 11))   # wraps
    assert len(buf) == 8
    X, y = buf.dataset()
    assert X.shape == (8, 3)
    assert set(y) == set(range(3, 11))               # oldest 3 evicted
    # oversized insert keeps only the newest capacity rows
    buf.add(np.arange(30).reshape(10, 3), np.arange(100, 110))
    X, y = buf.dataset()
    assert len(buf) == 8 and set(y) == set(range(102, 110))


def test_drift_detector_fires_on_collapse():
    det = DriftDetector(fast=0.5, slow=0.08, drop_frac=0.75, warmup=4)
    assert not any(det.update(100.0) for _ in range(10))
    fired = [det.update(10.0) for _ in range(4)]
    assert any(fired)
    det.reset(10.0)
    assert not any(det.update(10.0) for _ in range(10))


def test_online_trainer_refits_and_swaps_forests():
    from repro.core.metrics import feature_dim
    from repro.core.model import DIALModel
    from repro.learn.online import OnlineTrainer
    from repro.pfs.engine import READ, WRITE

    rng = np.random.default_rng(0)

    def forest(op):
        dim = feature_dim(op, 1)
        X = rng.normal(size=(300, dim))
        y = (X[:, 0] > 0).astype(float)
        return GBDTClassifier(GBDTParams(n_trees=5, max_depth=3)
                              ).fit(X, y).forest

    model = DIALModel(read_forest=forest(READ), write_forest=forest(WRITE))
    old_read = model.read_forest
    trainer = OnlineTrainer(model,
                            GBDTParams(n_trees=6, max_depth=3),
                            policy=OnlinePolicy(refit_every=3,
                                                min_samples=32,
                                                cooldown=1))
    dim = feature_dim(READ, 1)
    X = rng.normal(size=(64, dim))
    y = (X[:, 1] > 0).astype(float)
    trainer.observe(READ, X, y)
    recs = [trainer.step(100.0) for _ in range(4)]
    fired = [r for r in recs if r]
    assert len(fired) == 1 and fired[0]["ops"] == ["read"]
    assert model.read_forest is not old_read       # swapped in place
    assert model._jax_fns == {}                    # predictor cache cleared
    # write buffer was empty -> write forest untouched
    assert trainer.buffers[WRITE].dataset()[0].shape[0] == 0


def test_continual_run_collects_and_refits():
    """A short failing_ost run labels its own decisions and refits the
    live model; the frozen twin runs the identical loop untouched."""
    from repro.core.metrics import feature_dim
    from repro.core.model import DIALModel
    from repro.lab.continual import run_continual
    from repro.lab.scenarios import get_scenario
    from repro.pfs.engine import READ, WRITE

    rng = np.random.default_rng(1)

    def forest(op):
        dim = feature_dim(op, 1)
        X = rng.normal(size=(400, dim))
        y = (X[:, 0] + 0.2 * rng.normal(size=400) > 0).astype(float)
        return GBDTClassifier(GBDTParams(n_trees=8, max_depth=3)
                              ).fit(X, y).forest

    spec = get_scenario("failing_ost")
    model = DIALModel(read_forest=forest(READ), write_forest=forest(WRITE))
    res = run_continual(
        spec, model, online=True, seconds=6.0, interval=0.5,
        policy=OnlinePolicy(refit_every=6, min_samples=8, cooldown=2,
                            explore_eps=0.3),
        gbdt_params=GBDTParams(n_trees=5, max_depth=3), seed=0)
    assert len(res.tput_mbs) == 12
    assert res.samples["read"] > 0            # labeled its own decisions
    assert res.refits, "no refit fired in the continual run"
    assert res.t_fail == 3.0
    assert res.pre_fail_mbs > res.post_fail_mbs   # the OST did fail


def test_cumsum_hist_backend_matches_matmul():
    """The opt-in cumsum histogram strategy (the only consumer of the
    sort_structs orderings) grows the same forest as the default."""
    X, y = _toy(n=300, d=5, seed=3)
    p = GBDTParams(n_trees=3, max_depth=3)
    _assert_forests_match(fit_forest(X, y, p, hist_backend="matmul"),
                          fit_forest(X, y, p, hist_backend="cumsum"))


def test_comparison_arms_share_schedule_pre_refit():
    """Frozen and online arms must apply the identical θ sequence (and
    see identical throughput) until the first refit — the comparison
    isolates the refit effect, not an exploration-rate difference."""
    from repro.core.metrics import feature_dim
    from repro.core.model import DIALModel
    from repro.lab.continual import run_comparison
    from repro.pfs.engine import READ, WRITE

    rng = np.random.default_rng(2)

    def forest(op):
        dim = feature_dim(op, 1)
        X = rng.normal(size=(400, dim))
        y = (X[:, 0] + 0.2 * rng.normal(size=400) > 0).astype(float)
        return GBDTClassifier(GBDTParams(n_trees=8, max_depth=3)
                              ).fit(X, y).forest

    model = DIALModel(read_forest=forest(READ), write_forest=forest(WRITE))
    rep = run_comparison(
        "failing_ost", model=model, seconds=6.0, interval=0.5,
        policy=OnlinePolicy(refit_every=6, min_samples=8, cooldown=2,
                            explore_eps=0.3),
        gbdt_params=GBDTParams(n_trees=5, max_depth=3))
    online, frozen = rep["online"], rep["frozen"]
    assert online["refits"], "no refit fired; the parity check is vacuous"
    # a refit at interval r swaps forests after interval r's decisions,
    # so the first r trace entries must match exactly
    r0 = online["refits"][0]["interval"]
    assert r0 >= 2
    assert frozen["theta_trace"][:r0] == online["theta_trace"][:r0]
    assert frozen["tput_mbs"][:r0] == online["tput_mbs"][:r0]
