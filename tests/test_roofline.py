"""Roofline machinery: the XLA loop-undercount fact, the loop-aware
collective parser, and validation of the analytic FLOPs model."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.utils import hlo as H


def test_xla_cost_analysis_counts_scan_body_once():
    """The documented fact that motivates the analytic model."""
    w = jnp.zeros((8, 128, 128), jnp.float32)
    x = jnp.zeros((64, 128), jnp.float32)

    def body(c, wi):
        return jnp.tanh(c @ wi), None

    def scanned(x, w):
        return jax.lax.scan(body, x, w)[0]

    def unrolled(x, w):
        for i in range(8):
            x, _ = body(x, w[i])
        return x

    f_scan = jax.jit(scanned).lower(x, w).compile().cost_analysis()
    f_unroll = jax.jit(unrolled).lower(x, w).compile().cost_analysis()
    if isinstance(f_scan, (list, tuple)):
        f_scan, f_unroll = f_scan[0], f_unroll[0]
    assert f_unroll["flops"] >= 7.5 * f_scan["flops"]


def test_collective_parser_multiplies_loop_trips():
    """psum inside a scan counts once per trip in our parser."""
    import os
    import subprocess
    import sys
    code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.utils import hlo as H
mesh = jax.make_mesh((4,), ("data",))
x = jax.ShapeDtypeStruct((8, 64), jnp.float32,
                         sharding=NamedSharding(mesh, P(None, "data")))
def f(x):
    def body(c, _):
        # contraction over the sharded dim -> all-reduce inside the loop
        y = jnp.einsum("bd,bd->b", c, c)
        return c * 0.99 + y[:, None] * 1e-6, None
    return jax.lax.scan(body, x, None, length=5)[0]
text = jax.jit(f).lower(x).compile().as_text()
stats = H.collective_stats(text)
n_ar = stats.counts.get("all-reduce", 0)
assert 5 <= n_ar <= 10, (stats.counts, text.count("all-reduce"))
print("OK", stats.counts)
"""
    import os
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_shape_bytes_parser():
    assert H._shape_bytes("f32[16,4096,2304]") == 16 * 4096 * 2304 * 4
    assert H._shape_bytes("(bf16[8,8], f32[4])") == 8 * 8 * 2 + 4 * 4
    assert H._shape_bytes("pred[]") == 1


def test_analytic_flops_match_xla_on_unscanned_config():
    """For a config with NO structural loops (1-layer pattern, no remat,
    accum=1, single chunks) the analytic forward flops agree with XLA's
    cost analysis within 20%."""
    from repro.models import lm
    from repro.models.config import ModelConfig
    from repro.utils.flops import fwd_flops_per_token

    cfg = ModelConfig(arch_id="tiny", family="dense", n_layers=1,
                      d_model=256, n_heads=4, n_kv_heads=4, d_ff=1024,
                      vocab_size=512, param_dtype="float32")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.zeros((2, 128), jnp.int32)

    def fwd(p, t):
        x, _ = lm.forward_train(p, t, cfg, remat=False)
        return lm.logits_for(p, x, cfg).sum()

    ca = jax.jit(fwd).lower(params, tokens).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    xla = float(ca["flops"])
    analytic = fwd_flops_per_token(cfg, 128) * 2 * 128
    assert abs(analytic - xla) / xla < 0.20, (analytic, xla)


def test_roofline_terms_and_dominance():
    r = H.Roofline(flops=197e12, hbm_bytes=819e9 / 2, wire_bytes=50e9 * 2,
                   model_flops=197e12 * 256 * 0.5, chips=256)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 0.5) < 1e-9
    assert abs(r.collective_s - 2.0) < 1e-9
    assert r.dominant == "collective"
    assert 0 < r.mfu_bound <= 1.0


def test_dryrun_cells_on_ci_mesh():
    """End-to-end dry-run lowering on a small forced-device mesh: one cell
    per step kind compiles and produces a full record."""
    import os
    import subprocess
    import sys
    code = """
import repro.launch.dryrun as dr
# shrink the production mesh for the CI device budget
import repro.launch.mesh as mesh_mod, jax
mesh_mod.make_production_mesh = lambda multi_pod=False: (
    jax.make_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
    else jax.make_mesh((2, 4), ("data", "model")))
import repro.configs.shapes as shp
shp.SHAPES = {k: shp.ShapeSpec(v.name, 512 if v.seq_len > 512 else v.seq_len,
                               8 if v.global_batch > 8 else v.global_batch,
                               v.kind) for k, v in shp.SHAPES.items()}
for shape in ("train_4k", "decode_32k"):
    for mp in (False, True):
        rec = dr.run_cell("gemma2-2b", shape, mp, "/tmp/dryrun_ci")
        assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")
        assert rec["flops_per_chip"] > 0
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
