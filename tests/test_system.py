"""End-to-end behaviour: training with DIAL-tuned ingest, fault-tolerant
resume, checkpoint write-path accounting, serving."""

import shutil

import numpy as np
import pytest

from repro.launch.serve import serve
from repro.launch.train import train


def test_train_loss_decreases():
    out = train("stablelm-12b", steps=15, batch=4, seq_len=64,
                dial_model_path=None, seed=0, log_every=100)
    first = np.mean(out["losses"][:3])
    last = np.mean(out["losses"][-3:])
    assert last < first + 0.01, (first, last)


def test_resume_reproduces_trajectory(tmp_path):
    d = str(tmp_path / "ckpt")
    full = train("qwen1.5-32b", steps=6, batch=4, seq_len=64,
                 dial_model_path=None, seed=3, log_every=100)
    train("qwen1.5-32b", steps=3, batch=4, seq_len=64, ckpt_dir=d,
          ckpt_every=3, dial_model_path=None, seed=3, log_every=100)
    resumed = train("qwen1.5-32b", steps=6, batch=4, seq_len=64, ckpt_dir=d,
                    ckpt_every=3, dial_model_path=None, seed=3, log_every=100)
    assert len(resumed["losses"]) == 3  # only steps 3..5 re-run
    np.testing.assert_allclose(full["losses"][3:], resumed["losses"],
                               atol=2e-3)


def test_ckpt_pfs_write_accounting():
    """Checkpoint bytes flow through the client write path and drain at a
    finite, positive rate."""
    from repro.ckpt.manager import CheckpointManager
    from repro.pfs import PFSSim

    sim = PFSSim(n_clients=2, n_osts=4, seed=0)
    mgr = CheckpointManager("/tmp/_ckpt_acct", sim=sim, hosts=[0, 1])
    t = mgr.pfs_write(256 * 2**20)
    assert 0.05 < t < 60.0, t
    shutil.rmtree("/tmp/_ckpt_acct", ignore_errors=True)


def test_serve_batched_decode():
    out = serve("stablelm-12b", batch=3, prompt_len=16, gen_tokens=8)
    assert out["tokens"].shape == (3, 8)
    assert out["tok_per_s"] > 0


def test_serve_musicgen_multistream():
    out = serve("musicgen-large", batch=2, prompt_len=8, gen_tokens=4)
    assert out["tokens"].shape == (2, 4, 4)  # (B, T, codebooks)


def test_dial_improves_training_ingest(dial_model):
    """The framework integration claim: with DIAL agents tuning the data
    pipeline's PFS clients from a bad initial config, delivered ingest
    bandwidth improves materially."""
    from repro.data.pipeline import DataPipeline, PipelineConfig

    def ingest(dial):
        cfg = PipelineConfig(global_batch=64, seq_len=2048, vocab_size=1000,
                             n_hosts=2, seed=1)
        pipe = DataPipeline(cfg, dial_model=dial)
        # bad initial knobs on every host client
        for h in range(cfg.n_hosts):
            pipe.sim.set_knobs(pipe.sim.client_oscs(h), window_pages=16,
                               rpcs_in_flight=1)
        for _ in range(6):
            pipe.next_batch()
        return pipe.ingest_throughput()

    untuned = ingest(None)
    tuned = ingest(dial_model)
    assert tuned > 1.5 * untuned, (untuned, tuned)
