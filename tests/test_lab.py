"""Scenario Lab equivalence and end-to-end tests.

The two load-bearing properties:

* a vmapped batch of K scenarios matches K independent numpy
  ``run_interval`` calls on every probe counter (the batch axis changes
  the schedule, never the physics);
* a disturbance-free ScenarioSpec driven through the lab reproduces
  today's ``run_fleet`` knob trajectory exactly on both engine backends
  (the lab is a superset, not a fork).

Plus: neutral disturbances are exact identities, schedules actually
bite (degraded OST / background bursts lower delivered bytes), the
catalog is well-formed, and a tiny campaign trains a versioned model
that ``run_fleet`` loads and uses.
"""

import dataclasses

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.lab.batch import run_batch, stack_scenarios
from repro.lab.scenarios import (SCENARIOS, build, get_scenario,
                                 make_schedule, variants)
from repro.pfs import PFSSim
from repro.pfs.state import Disturbance, engine_step
from repro.pfs.workloads import run_interval as np_run_interval

TICKS = 50
PROBE_COUNTERS = (
    "ctr_bytes_done", "ctr_rpcs_sent", "ctr_rpc_bytes", "ctr_partial_rpcs",
    "ctr_latency_sum", "ctr_rpcs_done", "ctr_req_count", "ctr_req_bytes",
    "ctr_cache_hit_bytes", "ctr_block_time", "ctr_pending_integral",
    "ctr_active_integral", "ctr_dirty_integral", "ctr_grant_integral",
    "randomness", "dirty_bytes", "grant_used", "write_blocked",
)


def assert_counters_close(a_state, b_state, rtol):
    for f in PROBE_COUNTERS:
        a = np.asarray(getattr(a_state, f), dtype=float)
        b = np.asarray(getattr(b_state, f), dtype=float)
        err = np.max(np.abs(a - b) / np.maximum(np.abs(a), 1.0))
        assert err <= rtol, (f, err)


# ---------------------------------------------------------------------- #
# disturbance plumbing
# ---------------------------------------------------------------------- #
def test_neutral_disturbance_is_exact_identity():
    """engine_step with the neutral Disturbance == engine_step without,
    bit for bit — undisturbed runs cannot drift from the historical
    engine."""
    b = build(get_scenario("filebench_mix"))
    state, wstate = b.state, b.wstate
    neutral = Disturbance.neutral(b.topo)
    for _ in range(20):
        demand, wstate = b.table.demand_step(b.params, wstate, state)
        plain = engine_step(b.params, b.topo, state, demand)
        dist = engine_step(b.params, b.topo, state, demand,
                           disturbance=neutral)
        for f in PROBE_COUNTERS + ("pending", "ready_bytes", "setup_work"):
            np.testing.assert_array_equal(
                np.asarray(getattr(plain, f)), np.asarray(getattr(dist, f)),
                err_msg=f)
        state = plain


def test_disturbances_bite():
    """Degraded-OST and background-burst schedules reduce delivered
    bytes vs the same scenario undisturbed, on the numpy oracle."""
    for name in ("degraded_ost", "noisy_neighbor"):
        spec = get_scenario(name)
        quiet = dataclasses.replace(spec, events=())
        done = {}
        for label, s in (("disturbed", spec), ("quiet", quiet)):
            b = build(s)
            st, ws = b.state, b.wstate
            for i in range(10):
                sched = b.schedule(i * TICKS, TICKS)
                st, ws = np_run_interval(b.params, b.topo, b.table, st, ws,
                                         TICKS, schedule=sched)
            done[label] = float(np.asarray(st.ctr_bytes_done).sum())
        assert done["disturbed"] < 0.97 * done["quiet"], (name, done)


def test_schedule_tiles_across_intervals():
    """make_schedule is a pure function of the absolute tick index: two
    50-tick intervals concatenate to one 100-tick schedule exactly."""
    b = build(get_scenario("noisy_neighbor"))
    whole = b.schedule(0, 100)
    first, second = b.schedule(0, 50), b.schedule(50, 50)
    for f in ("bw_scale", "iops_scale", "bg_bytes", "nic_scale"):
        np.testing.assert_array_equal(
            np.asarray(getattr(whole, f)),
            np.concatenate([np.asarray(getattr(first, f)),
                            np.asarray(getattr(second, f))]), err_msg=f)


# ---------------------------------------------------------------------- #
# batch equivalence (satellite)
# ---------------------------------------------------------------------- #
def test_batch_matches_independent_runs():
    """A vmapped batch of K disturbed scenario variants matches K
    independent numpy run_interval calls on all probe counters."""
    specs = variants(get_scenario("noisy_neighbor"), 3, seed=7)
    batch = stack_scenarios([build(s) for s in specs])
    run_batch(batch, model=None, seconds=1.0, interval=0.25)

    steps = int(round(0.25 / 0.005))
    for k, spec in enumerate(specs):
        b = build(spec)
        st, ws = b.state, b.wstate
        for i in range(4):
            sched = b.schedule(i * steps, steps)
            st, ws = np_run_interval(b.params, b.topo, b.table, st, ws,
                                     steps, schedule=sched)

        class _Row:
            pass

        row = _Row()
        for f in PROBE_COUNTERS:
            setattr(row, f, np.asarray(getattr(batch.state, f))[k])
        assert_counters_close(st, row, 1e-6)


def test_disturbance_free_spec_reproduces_run_fleet(dial_model):
    """The lab path with no disturbances == today's run_fleet: identical
    decisions and knob trajectories on both engine backends."""
    from repro.core.fleet import run_fleet

    spec = get_scenario("filebench_mix")

    def fleet_run(backend):
        sim = PFSSim(n_clients=spec.n_clients, n_osts=spec.n_osts)
        for w in spec.make_workloads():
            sim.attach(w)
        w0, f0 = spec.initial_theta
        sim.set_knobs(np.arange(sim.n_osc), window_pages=w0,
                      rpcs_in_flight=f0)
        fleet = run_fleet(sim, dial_model, seconds=3.0, interval=0.5,
                          backend=backend)
        return fleet, sim.window_pages.copy(), sim.rpcs_in_flight.copy()

    def traj(fleet):
        return [(r.oscs.tolist(), r.ops.tolist(),
                 r.decisions.theta.tolist(), r.decisions.changed.tolist())
                for r in fleet.decisions]

    f_np, win_np, rif_np = fleet_run("numpy")
    f_jax, win_jax, rif_jax = fleet_run("jax")

    batch = stack_scenarios([build(spec)])
    f_lab = run_batch(batch, model=dial_model, seconds=3.0, interval=0.5)

    assert traj(f_np) == traj(f_jax) == traj(f_lab)
    for win, rif in ((win_np, rif_np), (win_jax, rif_jax)):
        np.testing.assert_array_equal(win,
                                      np.asarray(batch.state.window_pages)[0])
        np.testing.assert_array_equal(rif,
                                      np.asarray(batch.state.rpcs_in_flight)[0])


# ---------------------------------------------------------------------- #
# catalog + campaign + evaluate
# ---------------------------------------------------------------------- #
def test_catalog_well_formed():
    assert len(SCENARIOS) >= 6
    tags = [t for s in SCENARIOS.values() for t in s.tags]
    assert "contention-burst" in tags
    assert "degraded-ost" in tags
    for name, spec in SCENARIOS.items():
        b = build(spec)      # every spec materializes
        assert b.topo.n_osc == spec.n_clients * spec.n_osts
        for ev in spec.events:
            sched = make_schedule([ev], b.topo, b.params, 0, 10)
            assert np.asarray(sched.bw_scale).shape == (10, spec.n_osts)


def test_variants_preserve_structure():
    spec = get_scenario("degraded_ost")
    vs = variants(spec, 4, seed=3)
    assert len({v.name for v in vs}) == 4
    batch = stack_scenarios([build(v) for v in vs])   # raises on mismatch
    assert len(batch) == 4


def test_campaign_model_loads_into_run_fleet(tmp_path):
    """Acceptance: a lab campaign trains a model that run_fleet can load
    and use."""
    from repro.core.fleet import run_fleet
    from repro.core.gbdt import GBDTParams
    from repro.core.model import DIALModel
    from repro.lab.campaign import (CampaignConfig, SMOKE_GRID,
                                    latest_version, run_campaign)
    from repro.pfs.workloads import random_stream, sequential_stream

    root = str(tmp_path / "models")
    cfg = CampaignConfig(seconds=10.0, reps=1, grid=SMOKE_GRID, seed=2)
    d, model, info = run_campaign(
        cfg, out_root=root, gbdt_params=GBDTParams(n_trees=15, max_depth=3))
    assert info["samples"]["read"] > 0 and info["samples"]["write"] > 0
    assert latest_version(root) is not None

    loaded = DIALModel.load(d + "/dial")
    sim = PFSSim(n_clients=4, n_osts=2)
    from repro.pfs.engine import READ, WRITE
    for c in range(4):
        if c % 2:
            sim.attach(sequential_stream(c, READ, 4 * 2**20, ost=c % 2))
        else:
            sim.attach(random_stream(c, WRITE, 256 * 1024, ost=c % 2))
    fleet = run_fleet(sim, loaded, seconds=2.0, interval=0.5)
    assert fleet is not None      # ran end to end with the loaded model


def test_evaluate_scenario_reports_policies(dial_model, tmp_path):
    import json
    import os

    from repro.lab.evaluate import evaluate, render_markdown, write_report

    report = evaluate(names=["noisy_neighbor", "degraded_ost"],
                      model=dial_model, seconds=1.5, interval=0.5)
    assert report["summary"]["n_scenarios"] == 2
    for row in report["scenarios"]:
        assert row["best_static_mbs"] >= row["default_mbs"] - 1e-9
        assert row["dial_mbs"] > 0
    md = render_markdown(report)
    assert "noisy_neighbor" in md and "DIAL/default" in md
    jpath, mpath = write_report(report, str(tmp_path / "report"))
    with open(jpath) as f:
        assert json.load(f)["summary"]["n_scenarios"] == 2
    assert os.path.exists(mpath)
