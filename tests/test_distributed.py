"""Sharding rules, ZeRO-1 specs, gradient compression — on a small
multi-device mesh (spawned subprocess with forced host device count)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_param_pspecs_cover_all_archs():
    """Every leaf of every arch gets a valid, divisibility-checked spec."""
    out = run_py("""
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import ARCHS, get_config
from repro.models import lm
from repro.distributed import sharding as shd
mesh = jax.make_mesh((2, 4), ("data", "model"))
for arch in ARCHS:
    cfg = get_config(arch)
    ap = lm.abstract_params(cfg)
    specs = shd.validate_pspecs(shd.param_pspecs(ap), ap, mesh)
    n_model_sharded = 0
    for leaf, spec in zip(jax.tree.leaves(ap),
                          jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,)*(leaf.ndim-len(spec))):
            if ax is not None:
                size = np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))])
                assert dim % size == 0, (arch, leaf.shape, spec)
                n_model_sharded += 1
    assert n_model_sharded > 0, arch
print("OK")
""")
    assert "OK" in out


def test_zero1_shards_moments_over_data():
    out = run_py("""
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.models import lm
from repro.distributed import sharding as shd
mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("stablelm-12b")
ap = lm.abstract_params(cfg)
pspecs = shd.param_pspecs(ap)
z = shd.validate_pspecs(shd.zero1_pspecs(ap, pspecs, mesh), ap, mesh)
n_data = sum(1 for s in jax.tree.leaves(z, is_leaf=lambda x: isinstance(x, P))
             if any(a == 'data' or (isinstance(a, tuple) and 'data' in a) for a in s))
assert n_data > 10, n_data
print("OK", n_data)
""")
    assert "OK" in out


def test_small_mesh_train_step_runs_sharded():
    """A real (tiny) sharded train step executes on an 8-device mesh and
    matches the single-device loss."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models import lm
from repro.distributed import sharding as shd
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.steps import make_train_step

cfg = get_smoke_config("stablelm-12b")
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = lm.init_params(cfg, jax.random.PRNGKey(0))
specs = shd.validate_pspecs(shd.param_pspecs(params), params, mesh)
params = jax.device_put(params, shd.named(mesh, specs))
opt = init_opt_state(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
batch = {"tokens": tokens, "labels": tokens}
batch_sh = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
step = jax.jit(make_train_step(cfg, AdamWConfig(), grad_accum=2))
with mesh:
    p2, o2, m = step(params, opt, batch_sh)
sharded_loss = float(m["loss"])
# single-device reference
params1 = lm.init_params(cfg, jax.random.PRNGKey(0))
ref = float(lm.loss_fn(params1, batch, cfg))
assert abs(sharded_loss - ref) < 5e-2, (sharded_loss, ref)
print("OK", sharded_loss, ref)
""")
    assert "OK" in out


def test_compressed_allreduce_error_feedback():
    """EF-int8 DP training tracks uncompressed gradients over steps."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.compression import (init_error_bufs,
                                           make_dp_train_grads)
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
W = jnp.asarray(rng.normal(size=(16, 4)), jnp.float32)
X = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
Y = jnp.asarray(rng.normal(size=(64, 4)), jnp.float32)

def loss_fn(w, batch):
    x, y = batch
    return jnp.mean((x @ w - y) ** 2)

fn_c = make_dp_train_grads(loss_fn, mesh, compress=True)
fn_u = make_dp_train_grads(loss_fn, mesh, compress=False)
bufs = init_error_bufs(W, 8)
w_c = w_u = W
for i in range(30):
    batch = (X, Y)
    with mesh:
        _, g_c, bufs = fn_c(w_c, batch, bufs)
        _, g_u = fn_u(w_u, batch, init_error_bufs(W, 8))[:2]
    w_c = w_c - 0.05 * g_c
    w_u = w_u - 0.05 * g_u
final_gap = float(jnp.abs(w_c - w_u).max())
l_c = float(loss_fn(w_c, (X, Y))); l_u = float(loss_fn(w_u, (X, Y)))
assert l_c < 1.05 * l_u + 1e-3, (l_c, l_u)
print("OK", final_gap, l_c, l_u)
""")
    assert "OK" in out


def test_elastic_remesh_roundtrip():
    """A checkpoint saved under one mesh restores onto a different mesh."""
    out = run_py("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.configs import get_smoke_config
from repro.models import lm
from repro.distributed import sharding as shd
from repro.ckpt.manager import CheckpointManager, reshard_checkpoint

cfg = get_smoke_config("gemma2-2b")
params = lm.init_params(cfg, jax.random.PRNGKey(0))
mesh1 = jax.make_mesh((2, 4), ("data", "model"))
specs1 = shd.validate_pspecs(shd.param_pspecs(params), params, mesh1)
p1 = jax.device_put(params, shd.named(mesh1, specs1))
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(1, p1, through_pfs=False)
    step, restored, _, _ = mgr.restore_latest(params)
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    specs2 = shd.validate_pspecs(shd.param_pspecs(params), params, mesh2)
    p2 = reshard_checkpoint(restored, mesh2, specs2)
    a = np.asarray(jax.tree.leaves(p1)[0], dtype=np.float32)
    b = np.asarray(jax.tree.leaves(p2)[0], dtype=np.float32)
    np.testing.assert_allclose(a, b)
print("OK")
""")
    assert "OK" in out
