"""Ragged pad-and-mask batching: padding must be an exact arithmetic
identity.

Property tests for the bucketed ragged stacking layer
(:mod:`repro.lab.batch`): phantom OSTs / clients / workload rows added
by :func:`pad_scenario` may change *shapes* only — θ trajectories pin
bit-equal and counters within 1e-6 against unpadded per-scenario runs
on the numpy oracle, the fused jax loop, the traced + intervened replay
path, and (tests below via subprocess) the 8-forced-device sharded
path.  The generated-scenario cases come from the PR-6 fuzz generator,
whose periodic duty-cycle disturbance schedules are exactly the
knife-edge population where any non-identity padding would flip a
decision.
"""

import copy
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core.gbdt import GBDTClassifier, GBDTParams
from repro.core.metrics import feature_dim
from repro.core.model import DIALModel
from repro.lab.batch import (bucket_scenarios, loop_cache_stats, pad_class,
                             pad_scenario, reset_loop_cache_stats, run_batch,
                             stack_scenarios, structure_key)
from repro.lab.fuzz import SMOKE, generate_spec
from repro.lab.scenarios import SCENARIOS, build, make_schedule
from repro.pfs.state import _STATE_FIELDS, READ, WRITE, engine_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

rng = np.random.default_rng(0)


def _forest(dim):
    x = rng.normal(size=(400, dim)).astype(np.float32)
    y = (x[:, 0] + x[:, -1] > -1.0).astype(np.int64)
    return GBDTClassifier(GBDTParams(n_trees=8, max_depth=3)).fit(x, y).forest


K = 1
MODEL = DIALModel(read_forest=_forest(feature_dim(READ, K)),
                  write_forest=_forest(feature_dim(WRITE, K)),
                  backend="jax", k=K)

#: fuzz-generated specs with periodic (duty-cycled) events — SMOKE draws
#: at least one event per scenario, two topology classes
_GEN = [generate_spec(SMOKE, i) for i in range(6)]


def _mixed_specs():
    """Three registry specs spanning three distinct structures."""
    return [SCENARIOS["dlio_bert"], SCENARIOS["vpic_checkpoint"],
            SCENARIOS["noisy_neighbor"]]


# ---------------------------------------------------------------------- #
# bucketing + strict mode
# ---------------------------------------------------------------------- #
def test_registry_buckets_partition_and_collapse():
    built = [build(s) for s in SCENARIOS.values()]
    ragged = bucket_scenarios(built)
    strict = bucket_scenarios(built, ragged=False)
    for buckets in (ragged, strict):
        seen = sorted(i for idxs, _ in buckets for i in idxs)
        assert seen == list(range(len(built)))
    assert len(ragged) <= len(strict)
    assert len(ragged) == len({pad_class(b) for b in built})
    for idxs, batch in ragged:
        assert len(batch) == len(idxs)


def test_strict_refusal_names_field_and_values():
    a, b = build(SCENARIOS["noisy_neighbor"]), build(SCENARIOS["dlio_bert"])
    k_a, k_b = structure_key(a), structure_key(b)
    assert k_a != k_b
    with pytest.raises(ValueError) as ei:
        stack_scenarios([a, b], ragged=False)
    msg = str(ei.value)
    # the first mismatching structure field, with both values
    field = next(f for f, va, vb in zip(
        ("params", "n_clients", "n_osts", "n_rows", "n_waves", "n_entries"),
        k_a, k_b) if va != vb)
    assert f"element 1 has {field}=" in msg
    assert f"element 0 has {field}=" in msg
    assert "ragged=False" in msg
    # the same pair stacks fine ragged
    batch = stack_scenarios([a, b])
    assert len(batch) == 2 and batch.osc_cols


def test_params_mismatch_always_refused():
    a = build(SCENARIOS["noisy_neighbor"])
    b = build(SCENARIOS["noisy_neighbor"])
    b = dataclasses.replace(b, params=dataclasses.replace(
        b.params, tick=b.params.tick * 2))
    with pytest.raises(ValueError, match="SimParams"):
        stack_scenarios([a, b])


# ---------------------------------------------------------------------- #
# padding neutrality: numpy oracle, bit-equal
# ---------------------------------------------------------------------- #
def _forced_class(b):
    """A strictly larger shape class: padding fires on every axis."""
    c = pad_class(b)
    return (c[0],) + tuple(2 * x for x in c[1:])


def _numpy_ticks(b, n_ticks):
    st, ws = copy.deepcopy(b.state), copy.deepcopy(b.wstate)
    sched = make_schedule(b.spec.events, b.topo, b.params, 0, n_ticks)
    for t in range(n_ticks):
        dist = jax.tree.map(lambda a: np.asarray(a)[t], sched)
        demand, ws = b.table.demand_step(b.params, ws, st)
        st = engine_step(b.params, b.topo, st, demand, disturbance=dist)
    return st, ws


@pytest.mark.parametrize("name", ["dlio_bert", "vpic_checkpoint",
                                  "noisy_neighbor"])
def test_padding_neutral_numpy_registry(name):
    _assert_numpy_neutral(build(SCENARIOS[name]), n_ticks=200)


@pytest.mark.parametrize("idx", [0, 1, 2, 3])
def test_padding_neutral_numpy_generated_knife_edge(idx):
    # generated specs carry periodic duty-cycled events (PR-6 knife edge)
    _assert_numpy_neutral(build(_GEN[idx]), n_ticks=150)


def _assert_numpy_neutral(b, n_ticks):
    p = pad_scenario(build(b.spec) if b.spec else b, _forced_class(b))
    o_old, o_new = b.topo.n_osts, p.topo.n_osts
    osc = np.arange(b.topo.n_osc)
    remap = (osc // o_old) * o_new + osc % o_old

    st_u, ws_u = _numpy_ticks(b, n_ticks)
    st_p, ws_p = _numpy_ticks(p, n_ticks)

    for f in _STATE_FIELDS:
        if f in ("ost_valid", "client_valid"):
            continue
        u, v = np.asarray(getattr(st_u, f)), np.asarray(getattr(st_p, f))
        if u.ndim == 0:
            assert u == v, f
        else:
            np.testing.assert_array_equal(
                np.take(v, remap, axis=-1), u,
                err_msg=f"{f} not bit-equal under padding")
    r = len(b.table)
    np.testing.assert_array_equal(np.asarray(ws_p.issued)[:r], ws_u.issued)
    np.testing.assert_array_equal(np.asarray(ws_p.done_base)[:r],
                                  ws_u.done_base)
    # phantom rows never issued anything
    assert not np.asarray(ws_p.issued)[r:].any()


# ---------------------------------------------------------------------- #
# padding neutrality: fused ragged batch vs per-scenario unpadded
# ---------------------------------------------------------------------- #
def _theta(batch, b):
    cols = batch.element_cols(b)
    return (np.asarray(batch.state.window_pages)[b, cols],
            np.asarray(batch.state.rpcs_in_flight)[b, cols])


def test_ragged_fused_matches_unpadded_per_scenario():
    specs = _mixed_specs()
    ragged = stack_scenarios([build(s) for s in specs])
    assert ragged.osc_cols, "mixed structures must have taken the pad path"
    run_batch(ragged, MODEL, seconds=3.0, interval=0.5, fused=True)
    tput_r = ragged.throughput(3.0)["total_mbs"]

    for b, spec in enumerate(specs):
        solo = stack_scenarios([build(spec)])
        run_batch(solo, MODEL, seconds=3.0, interval=0.5, fused=True)
        wp_r, rif_r = _theta(ragged, b)
        wp_s, rif_s = _theta(solo, 0)
        np.testing.assert_array_equal(wp_r, wp_s, err_msg=spec.name)
        np.testing.assert_array_equal(rif_r, rif_s, err_msg=spec.name)
        for f in ("ctr_bytes_done", "ctr_rpcs_sent", "ctr_latency_sum",
                  "ctr_block_time", "ctr_pending_integral"):
            u = np.asarray(getattr(solo.state, f))[0]
            v = np.take(np.asarray(getattr(ragged.state, f))[b],
                        ragged.element_cols(b), axis=-1)
            np.testing.assert_allclose(v, u, rtol=1e-6, atol=1e-9,
                                       err_msg=f"{spec.name}:{f}")
        np.testing.assert_allclose(
            tput_r[b], float(solo.throughput(3.0)["total_mbs"][0]),
            rtol=1e-6)


def test_ragged_traced_intervened_matches_per_case():
    """The diagnose replay (traced + intervention arms) is bit-identical
    ragged vs one-case-at-a-time across mixed structures."""
    from repro.obs.diagnose import DiagnoseConfig, replay_arms, \
        replay_arms_many

    cfg = DiagnoseConfig(seconds=2.0, interval=0.5)
    cases = [(_GEN[0], (64, 2)), (_GEN[1], (256, 8))]
    if pad_class(build(cases[0][0])) == pad_class(build(cases[1][0])):
        cases = [(_GEN[0], (64, 2)), (_mixed_specs()[0], (256, 8))]
    many = replay_arms_many(cases, MODEL, cfg)
    for (spec, star), (arms_m, fact_m) in zip(cases, many):
        arms_1, fact_1 = replay_arms(spec, MODEL, cfg, star)
        assert arms_m == arms_1, spec.name
        assert set(fact_m) == set(fact_1)
        for k in fact_1:
            np.testing.assert_array_equal(fact_m[k], fact_1[k],
                                          err_msg=f"{spec.name}:{k}")


# ---------------------------------------------------------------------- #
# compiled-loop cache counters
# ---------------------------------------------------------------------- #
def test_loop_cache_stats_count_hits_and_misses():
    reset_loop_cache_stats()
    base = loop_cache_stats()
    assert base["hits"] == 0 and base["misses"] == 0
    batch = stack_scenarios([build(SCENARIOS["noisy_neighbor"])])
    run_batch(batch, MODEL, seconds=1.0, interval=0.5, fused=True)
    after_first = loop_cache_stats()
    batch2 = stack_scenarios([build(SCENARIOS["noisy_neighbor"])])
    run_batch(batch2, MODEL, seconds=1.0, interval=0.5, fused=True)
    after_second = loop_cache_stats()
    # first run either compiled (miss) or reused a loop compiled by an
    # earlier test (hit) — the counters must see it either way
    assert after_first["hits"] + after_first["misses"] >= 1
    # the structurally-identical rerun must be a pure cache hit
    assert after_second["hits"] >= after_first["hits"] + 1
    assert after_second["misses"] == after_first["misses"]
    assert after_second["size"] >= 1


# ---------------------------------------------------------------------- #
# sharded path: 8 forced host devices (subprocess)
# ---------------------------------------------------------------------- #
def _run_py(code, devices=8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_ragged_sharded_matches_unpadded_8dev():
    """A mixed ragged batch on an 8-device mesh pins θ bit-equal and
    counters ≤1e-6 against per-scenario unpadded single-device runs."""
    out = _run_py("""
import numpy as np
from repro.core.gbdt import GBDTClassifier, GBDTParams
from repro.core.metrics import feature_dim
from repro.core.model import DIALModel
from repro.pfs.state import READ, WRITE
from repro.distributed.sharding import fleet_mesh
from repro.lab.batch import run_batch, stack_scenarios
from repro.lab.scenarios import SCENARIOS, build

rng = np.random.default_rng(0)
def _forest(dim):
    x = rng.normal(size=(400, dim)).astype(np.float32)
    y = (x[:, 0] + x[:, -1] > -1.0).astype(np.int64)
    return GBDTClassifier(GBDTParams(n_trees=8, max_depth=3)).fit(x, y).forest
model = DIALModel(read_forest=_forest(feature_dim(READ, 1)),
                  write_forest=_forest(feature_dim(WRITE, 1)),
                  backend="jax", k=1)

specs = [SCENARIOS[n] for n in
         ("dlio_bert", "vpic_checkpoint", "noisy_neighbor")]
ragged = stack_scenarios([build(s) for s in specs])
assert ragged.osc_cols
run_batch(ragged, model, seconds=2.0, interval=0.5, fused=True,
          mesh=fleet_mesh(8))
for b, spec in enumerate(specs):
    solo = stack_scenarios([build(spec)])
    run_batch(solo, model, seconds=2.0, interval=0.5, fused=True)
    cols = ragged.element_cols(b)
    for f, exact in (("window_pages", True), ("rpcs_in_flight", True),
                     ("ctr_bytes_done", False), ("ctr_rpcs_sent", False)):
        u = np.asarray(getattr(solo.state, f))[0]
        v = np.take(np.asarray(getattr(ragged.state, f))[b], cols, axis=-1)
        if exact:
            np.testing.assert_array_equal(v, u, err_msg=f"{spec.name}:{f}")
        else:
            np.testing.assert_allclose(v, u, rtol=1e-6,
                                       err_msg=f"{spec.name}:{f}")
print("OK")
""")
    assert "OK" in out
