"""DIAL core: metrics extraction, Algorithm 1, agent behaviour."""

import numpy as np
import pytest

from repro.core.config_space import SPACE
from repro.core.metrics import READ_FEATURES, WRITE_FEATURES, snapshot
from repro.core.tuner import TunerParams, conditional_score_greedy
from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.stats import probe
from repro.pfs.workloads import random_stream, sequential_stream


def test_snapshot_features_finite_and_shaped():
    sim = PFSSim(n_clients=1, n_osts=2, seed=0)
    sim.attach(sequential_stream(0, READ, 2**20, ost=0))
    sim.attach(random_stream(0, WRITE, 8192, ost=1, n_threads=2))
    osc = sim.osc_id(0, 0)
    prev = probe(sim, osc)
    sim.run(0.5)
    cur = probe(sim, osc)
    s = snapshot(prev, cur)
    assert s.read.shape == (len(READ_FEATURES),)
    assert s.write.shape == (len(WRITE_FEATURES),)
    assert np.isfinite(s.read).all() and np.isfinite(s.write).all()
    assert s.read[0] > 0  # read throughput flowing
    assert s.read_volume > 0


# ---------------------------------------------------------------------- #
# Algorithm 1 semantics
# ---------------------------------------------------------------------- #
def test_tuner_keeps_current_when_no_candidate_clears_tau():
    probs = np.full(len(SPACE), 0.5)
    d = conditional_score_greedy(probs, READ, current=(256, 8))
    assert d.theta == (256, 8) and not d.changed and d.n_candidates == 0


def test_tuner_write_score_prefers_larger_theta_on_ties():
    """WriteScore = f * (1 + beta * sum(theta_norm)): with uniform
    probabilities above tau, the largest config wins (SIII-C)."""
    probs = np.full(len(SPACE), 0.9)
    d = conditional_score_greedy(probs, WRITE, current=(16, 1))
    assert d.theta == (1024, 32)


def test_tuner_read_score_structure():
    """ReadScore = f*(1 + alpha*theta1_norm) + theta2_norm: theta2 adds
    outside the product, so max in-flight dominates ties."""
    probs = np.full(len(SPACE), 0.9)
    d = conditional_score_greedy(probs, READ, current=(16, 1))
    assert d.theta[1] == 32  # max rpcs-in-flight among survivors


def test_tuner_model_veto_beats_regularizer():
    """A high-probability small config must beat a below-tau large one —
    the regularizer only ranks configurations that cleared tau."""
    probs = np.zeros(len(SPACE))
    i_small = SPACE.index_of((64, 4))
    probs[i_small] = 0.95
    i_big = SPACE.index_of((1024, 32))
    probs[i_big] = 0.5           # model predicts no improvement
    d = conditional_score_greedy(probs, WRITE, current=(256, 8))
    assert d.theta == (64, 4)


def test_minmax_normalization_over_subset():
    t = np.array([[64, 4], [256, 8], [1024, 16]], dtype=float)
    n = SPACE.minmax_normalize(t)
    assert n.min() == 0.0 and n.max() == 1.0
    assert n[0, 0] == 0.0 and n[2, 0] == 1.0


# ---------------------------------------------------------------------- #
# end-to-end agent behaviour
# ---------------------------------------------------------------------- #
def test_agent_recovers_bad_seq_config(dial_model):
    """From a pathologically small (window, inflight), DIAL must recover
    most of the sequential-stream bandwidth (paper SIV-B behaviour)."""
    from repro.core.agent import run_with_agents

    def tput(tuned):
        sim = PFSSim(n_clients=1, n_osts=4, seed=7)
        wl = sequential_stream(0, READ, 16 * 2**20, ost=0)
        sim.attach(wl)
        sim.set_knobs(sim.client_oscs(0), window_pages=16, rpcs_in_flight=1)
        if tuned:
            run_with_agents(sim, dial_model, [0], seconds=15.0)
        else:
            sim.run(15.0)
        return wl.done_bytes(sim) / 15.0 / 1e6

    static, dial = tput(False), tput(True)
    assert dial > 5 * static, (static, dial)


def test_agent_does_not_wreck_saturated_workload(dial_model):
    """On an already-optimal config the agent must not lose throughput
    (tau-gated decisions; paper Table II 'on par with optimal')."""
    from repro.core.agent import run_with_agents

    def tput(tuned):
        sim = PFSSim(n_clients=1, n_osts=4, seed=9)
        wl = sequential_stream(0, READ, 16 * 2**20, ost=0)
        sim.attach(wl)
        sim.set_knobs(sim.client_oscs(0), window_pages=1024, rpcs_in_flight=16)
        if tuned:
            run_with_agents(sim, dial_model, [0], seconds=12.0)
        else:
            sim.run(12.0)
        return wl.done_bytes(sim) / 12.0 / 1e6

    static, dial = tput(False), tput(True)
    assert dial > 0.9 * static, (static, dial)


def test_agent_only_two_snapshots_in_memory(dial_model):
    """Paper SIV-C: DIAL keeps only two snapshots per interface."""
    from repro.core.agent import DIALAgent, SimClientPort

    sim = PFSSim(n_clients=1, n_osts=4, seed=0)
    sim.attach(sequential_stream(0, READ, 2**20, ost=0))
    agent = DIALAgent(SimClientPort(sim, 0), dial_model, k=1)
    for _ in range(6):
        sim.run(0.5)
        agent.tick()
    for osc, hist in agent._hist.items():
        assert len(hist) <= 2
