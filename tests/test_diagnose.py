"""Counterfactual diagnosis: the neutral intervention must reproduce
the factual run bit-for-bit (fused, batched, and 8-device sharded),
each intervention arm must bend exactly the trajectory it claims to
bend on a scenario constructed to trigger it, and the diagnosis report
must be byte-identical across invocations.
"""

import dataclasses
import filecmp
import os
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.lab.batch import run_batch, stack_scenarios  # noqa: E402
from repro.lab.scenarios import (ScenarioSpec, build, get_scenario,
                                 variants)  # noqa: E402
from repro.obs.schema import RunTrace, TraceConfig  # noqa: E402
from repro.pfs.engine import WRITE  # noqa: E402
from repro.pfs.loop_jax import Intervention  # noqa: E402
from repro.pfs.workloads import sequential_stream  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CTRS = ("ctr_bytes_done", "ctr_rpcs_sent", "ctr_latency_sum",
         "ctr_pending_integral", "ctr_block_time")


def _run(specs, model, iv=None, seconds=4.0, trace=None):
    batch = stack_scenarios([build(s) for s in specs])
    result = run_batch(batch, model=model, seconds=seconds, interval=0.5,
                       fused=True, intervene=iv, trace=trace)
    return batch, result


def _knobs(batch):
    return (np.asarray(batch.state.window_pages),
            np.asarray(batch.state.rpcs_in_flight))


# ---------------------------------------------------------------------- #
# tentpole: the neutral intervention is an exact identity
# ---------------------------------------------------------------------- #
def test_neutral_intervention_bit_neutral_fused(dial_model):
    """iv=neutral runs the *intervened* compiled graph; every masked
    write-back is an arithmetic identity, so θ is bit-equal and the
    counters exactly match the unintervened dispatch."""
    spec = get_scenario("filebench_mix")
    b0, _ = _run([spec], dial_model)
    n = b0.n_osc
    b1, _ = _run([spec], dial_model, iv=Intervention.neutral(n, batch=1))
    for a, b in zip(_knobs(b0), _knobs(b1)):
        np.testing.assert_array_equal(a, b)
    for f in _CTRS:
        np.testing.assert_array_equal(np.asarray(getattr(b0.state, f)),
                                      np.asarray(getattr(b1.state, f)),
                                      err_msg=f)


def test_neutral_intervention_bit_neutral_batched(dial_model):
    specs = variants(get_scenario("vpic_checkpoint"), 3, seed=7)
    b0, _ = _run(specs, dial_model, seconds=3.0)
    n = b0.n_osc
    b1, _ = _run(specs, dial_model, seconds=3.0,
                 iv=Intervention.neutral(n, batch=len(specs)))
    for a, b in zip(_knobs(b0), _knobs(b1)):
        np.testing.assert_array_equal(a, b)
    for f in _CTRS:
        np.testing.assert_array_equal(np.asarray(getattr(b0.state, f)),
                                      np.asarray(getattr(b1.state, f)),
                                      err_msg=f)


def test_neutral_intervention_bit_neutral_sharded_8dev():
    """Same identity under an 8-forced-host-device mesh: phantom pad
    rows get the zero (neutral) intervention, real rows reproduce the
    unmeshed-unintervened run exactly."""
    code = """
import numpy as np
from repro.core.gbdt import GBDTClassifier, GBDTParams
from repro.core.metrics import feature_dim
from repro.core.model import DIALModel
from repro.pfs.state import READ, WRITE

rng = np.random.default_rng(0)
def _forest(dim):
    x = rng.normal(size=(400, dim)).astype(np.float32)
    y = (x[:, 0] + x[:, -1] > -1.0).astype(np.int64)
    return GBDTClassifier(GBDTParams(n_trees=8, max_depth=3)).fit(x, y).forest
k = 1
model = DIALModel(read_forest=_forest(feature_dim(READ, k)),
                  write_forest=_forest(feature_dim(WRITE, k)),
                  backend="jax", k=k)

from repro.distributed.sharding import fleet_mesh
from repro.lab.batch import run_batch, stack_scenarios
from repro.lab.scenarios import build, get_scenario, variants
from repro.pfs.loop_jax import Intervention

specs = variants(get_scenario("vpic_checkpoint"), 5, seed=3)
mesh = fleet_mesh(8)

b0 = stack_scenarios([build(s) for s in specs])
run_batch(b0, model=model, seconds=3.0, interval=0.5, fused=True)

b1 = stack_scenarios([build(s) for s in specs])
iv = Intervention.neutral(b1.n_osc, batch=len(specs))
run_batch(b1, model=model, seconds=3.0, interval=0.5, fused=True,
          mesh=mesh, intervene=iv)

assert np.array_equal(np.asarray(b0.state.window_pages),
                      np.asarray(b1.state.window_pages))
assert np.array_equal(np.asarray(b0.state.rpcs_in_flight),
                      np.asarray(b1.state.rpcs_in_flight))
np.testing.assert_allclose(np.asarray(b0.state.ctr_bytes_done),
                           np.asarray(b1.state.ctr_bytes_done),
                           rtol=1e-6, atol=1e-6)
print("NEUTRAL-MESH-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "NEUTRAL-MESH-OK" in out.stdout


# ---------------------------------------------------------------------- #
# each intervention kind bends the trajectory it claims to bend
# ---------------------------------------------------------------------- #
def test_pin_forces_theta_everywhere(dial_model):
    """pin(θ*) overrides every interval's write-back: final knobs are
    the pin, and the θ trajectory departs from the factual run."""
    spec = get_scenario("filebench_mix")          # starts at (64, 2)
    tcfg = TraceConfig(timeline=False)
    b0, r0 = _run([spec], dial_model, trace=tcfg)
    n = b0.n_osc
    pin = (256, 8)
    b1, r1 = _run([spec], dial_model, trace=tcfg,
                  iv=Intervention.pin(n, pin, batch=1))
    wp, rf = _knobs(b1)
    assert (wp == pin[0]).all() and (rf == pin[1]).all()
    t0 = RunTrace.from_fused(r0, tcfg, b0.params.tick)
    t1 = RunTrace.from_fused(r1, tcfg, b1.params.tick)
    assert not np.array_equal(t0.decisions["theta"], t1.decisions["theta"])


def test_freeze_holds_theta_at_initial(dial_model):
    """freeze computes decisions but never applies them — the recovery
    scenario's factual run changes θ, the frozen run cannot."""
    spec = get_scenario("filebench_mix")          # pathological start
    tcfg = TraceConfig(timeline=False)
    b0, r0 = _run([spec], dial_model, trace=tcfg)
    t0 = RunTrace.from_fused(r0, tcfg, b0.params.tick)
    assert t0.decisions["changed"].sum() > 0, \
        "scenario no longer triggers factual θ changes"
    n = b0.n_osc
    b1, _ = _run([spec], dial_model,
                 iv=Intervention.freeze_theta(n, batch=1))
    wp, rf = _knobs(b1)
    assert (wp == spec.initial_theta[0]).all()
    assert (rf == spec.initial_theta[1]).all()


def test_gates_open_fires_blocked_decisions(dial_model):
    """On a fleet where most interfaces are idle the volume gate blocks
    their warm rows; forcing the gates open fires those decisions."""
    spec = ScenarioSpec(
        name="gate_trigger", n_clients=4, n_osts=2,
        workloads=(sequential_stream(0, WRITE, 2 * 2**20, ost=0,
                                     n_threads=2),),
        initial_theta=(64, 2))
    tcfg = TraceConfig(timeline=False)
    b0, r0 = _run([spec], dial_model, trace=tcfg)
    t0 = RunTrace.from_fused(r0, tcfg, b0.params.tick)
    d0 = t0.decisions
    blocked = (d0["warm"] & ~d0["decided"]).sum()
    assert blocked > 0, "scenario no longer gate-blocks any warm row"
    n = b0.n_osc
    b1, r1 = _run([spec], dial_model, trace=tcfg,
                  iv=Intervention.gates_open(n, batch=1))
    t1 = RunTrace.from_fused(r1, tcfg, b1.params.tick)
    d1 = t1.decisions
    assert d1["decided"].sum() > d0["decided"].sum()
    # warmup still applies: gates_open never decides a cold row
    assert not (d1["decided"] & ~d1["warm"]).any()


def test_intervene_requires_fused_and_tuned(dial_model):
    spec = get_scenario("filebench_mix")
    batch = stack_scenarios([build(spec)])
    iv = Intervention.neutral(batch.n_osc, batch=1)
    with pytest.raises(ValueError, match="fused"):
        run_batch(batch, model=dial_model, seconds=1.0, interval=0.5,
                  intervene=iv)


# ---------------------------------------------------------------------- #
# the diagnosis engine + report determinism
# ---------------------------------------------------------------------- #
def _dcfg():
    from repro.obs.diagnose import DiagnoseConfig
    return DiagnoseConfig(seconds=2.0, interval=0.5,
                          thetas=((64, 2), (256, 8)), max_evidence=4)


def test_diagnose_structure_and_taxonomy(dial_model):
    from repro.obs.diagnose import ARMS, CAUSES, DIAGNOSIS_SCHEMA, diagnose

    d = diagnose(get_scenario("filebench_mix"), dial_model, _dcfg())
    assert d["schema"] == DIAGNOSIS_SCHEMA
    assert d["cause"] in CAUSES
    assert set(d["arms"]) == set(ARMS)
    assert set(d["signals"]) >= {"blocked_share", "nocand_share",
                                 "converged_interval",
                                 "theta_star_in_grid"}
    assert d["n_intervals"] == 4
    if d["losing"]:
        assert d["cause"] != "none" and d["evidence"]
        assert d["n_evidence_total"] >= len(d["evidence"])
    else:
        assert d["cause"] == "none"
    assert "gap_mbs" in d["recovery"]


def test_diagnosis_report_byte_identical(dial_model, tmp_path):
    """Same (spec, model, config) -> byte-identical diagnosis.json and
    diagnosis.md — the fuzz-report cmp pattern."""
    from repro.obs.diagnose import diagnose, write_diagnosis_report

    spec = get_scenario("filebench_mix")
    outs = []
    for rep in ("a", "b"):
        d = diagnose(spec, dial_model, _dcfg())
        outs.append(write_diagnosis_report([d], str(tmp_path / rep)))
    (j1, m1), (j2, m2) = outs
    assert filecmp.cmp(j1, j2, shallow=False)
    assert filecmp.cmp(m1, m2, shallow=False)


def test_fuzz_stamps_diagnoses(dial_model):
    """A diagnosing sweep stamps every triaged loser with a diagnosis
    whose cause lands in the summary's per-cause counts."""
    import dataclasses as dc

    from repro.lab.fuzz import SMOKE, run_sweep

    cfg = dc.replace(SMOKE, n_scenarios=8, seconds=2.0,
                     loss_threshold=0.01)
    report = run_sweep(cfg, dial_model, diagnose=True, max_diagnoses=4)
    losses = report["triage"]["losses"]
    if not losses:
        pytest.skip("sweep produced no triaged losers at 1%")
    n_diag = min(len(losses), 4)
    assert report["summary"]["n_diagnosed"] == n_diag
    for r in losses[:n_diag]:
        d = r["diagnosis"]
        assert d["cause"] != "none" and d["losing"]
        assert d["evidence"]
        # the stamped race figures are the sweep's own, not re-raced
        assert d["race"]["dial_mbs"] == r["dial_mbs"]
    counts = report["summary"]["loss_causes"]
    assert sum(counts.values()) == n_diag


def test_curriculum_buckets_by_cause(dial_model, tmp_path):
    """The hard-case curriculum reports a before/after loss rate per
    diagnosed cause bucket (and replays weighted by cause)."""
    import dataclasses as dc
    import json

    from repro.lab.continual import (CAUSE_WEIGHTS,
                                     run_hard_case_curriculum,
                                     write_curriculum_report)
    from repro.lab.fuzz import SMOKE, run_sweep, write_fuzz_report

    cfg = dc.replace(SMOKE, n_scenarios=8, seconds=2.0,
                     loss_threshold=0.01)
    report = run_sweep(cfg, dial_model, diagnose=True, max_diagnoses=4)
    if not report["triage"]["losses"]:
        pytest.skip("sweep produced no triaged losers at 1%")
    jpath, _ = write_fuzz_report(report, str(tmp_path / "fuzz"))

    model = dataclasses.replace(dial_model)       # curriculum mutates it
    cur = run_hard_case_curriculum(jpath, model, seconds=2.0,
                                   interval=0.5, max_cases=2)
    assert cur["schema"] == "dial-curriculum-v1"
    assert cur["n_losers"] == min(2, len(report["triage"]["losses"]))
    assert cur["n_replays"] == sum(
        CAUSE_WEIGHTS.get(c["cause"], 1) for c in cur["cases"])
    assert set(cur["overall"]) == {"before_loss_rate", "after_loss_rate",
                                   "delta"}
    for cause, b in cur["buckets"].items():
        assert b["n"] >= 1
        assert 0.0 <= b["before_loss_rate"] <= 1.0
        assert 0.0 <= b["after_loss_rate"] <= 1.0
    assert sum(b["n"] for b in cur["buckets"].values()) == cur["n_losers"]
    path = write_curriculum_report(cur, str(tmp_path / "cur"))
    assert json.load(open(path))["schema"] == "dial-curriculum-v1"


def test_trace_sinks_carry_diagnosis(dial_model, tmp_path):
    """write_trace(diagnosis=...) stamps the verdict into all three
    sinks; the Chrome instants land on decision-interval timestamps."""
    import json

    from repro.lab.trace import trace_scenario, write_trace
    from repro.obs.diagnose import diagnose
    from repro.obs.sinks import read_jsonl, read_jsonl_diagnosis

    spec = get_scenario("filebench_mix")
    trace = trace_scenario(spec, dial_model, seconds=2.0,
                           config=TraceConfig(timeline=False))
    d = diagnose(spec, dial_model, _dcfg())
    paths = write_trace(trace, str(tmp_path), diagnosis=d)

    back = read_jsonl(paths["jsonl"])
    back.validate()
    stamped = read_jsonl_diagnosis(paths["jsonl"])
    assert stamped is not None and stamped["cause"] == d["cause"]

    doc = json.load(open(paths["chrome"]))
    diag = [e for e in doc["traceEvents"] if e.get("pid") == 3]
    assert any(e.get("ph") == "i" for e in diag)
    dec_ts = {e["ts"] for e in doc["traceEvents"]
              if e.get("pid") == 2 and e.get("ph") == "i"}
    for e in diag:
        if e.get("ph") == "i" and e["ts"] > 0:
            assert e["ts"] in dec_ts
    assert "## Diagnosis" in open(paths["md"]).read()


def test_read_jsonl_ignores_unknown_kinds(dial_model, tmp_path):
    """Explicit kind dispatch: a diagnosis (or unknown) record must
    never be misfiled as a timeline row, and v1 files still read."""
    import json

    from repro.lab.trace import trace_scenario
    from repro.obs.sinks import read_jsonl, write_jsonl

    trace = trace_scenario(get_scenario("filebench_mix"), dial_model,
                           seconds=2.0,
                           config=TraceConfig(timeline=False))
    p = str(tmp_path / "t.jsonl")
    write_jsonl(trace, p, diagnosis={"cause": "inherent", "evidence": []})
    with open(p) as f:
        lines = f.read().splitlines()
    # downgrade the header to v1 and append an unknown kind
    meta = json.loads(lines[0])
    meta["schema"] = "dial-trace-v1"
    lines[0] = json.dumps(meta)
    lines.append(json.dumps({"kind": "someday", "x": 1}))
    with open(p, "w") as f:
        f.write("\n".join(lines) + "\n")
    back = read_jsonl(p)
    back.validate()
    assert back.timeline is None
    np.testing.assert_array_equal(back.decisions["theta"],
                                  trace.decisions["theta"])
