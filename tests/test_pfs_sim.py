"""PFS simulator: invariants (hypothesis) + calibration regressions."""

import numpy as np
import pytest

try:  # property-based fuzzing when available; seeded sweep otherwise
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.pfs import PFSSim, SimParams
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import random_stream, sequential_stream


def run_stream(op, wl_fn, req, window, inflight, n_threads=1, seconds=6.0,
               seed=0):
    sim = PFSSim(n_clients=1, n_osts=4, seed=seed)
    wl = wl_fn(0, op, req, ost=0, n_threads=n_threads)
    sim.attach(wl)
    sim.set_knobs(sim.client_oscs(0), window_pages=window,
                  rpcs_in_flight=inflight)
    sim.run(seconds)
    return wl.done_bytes(sim) / seconds / 1e6, sim


# ---------------------------------------------------------------------- #
# physics invariants
# ---------------------------------------------------------------------- #
def _check_throughput_never_exceeds_physics(window, inflight, req, rand, op):
    """Delivered bytes can never exceed OST bandwidth (+ write-cache slack)."""
    fn = random_stream if rand else sequential_stream
    tput, sim = run_stream(op, fn, req, window, inflight, n_threads=4)
    cap = sim.params.ost_bandwidth / 1e6
    slack = (sim.params.max_dirty_bytes + sim.params.grant_bytes) / 6.0 / 1e6 \
        if op == WRITE else 1.0
    assert tput <= cap + slack + 1.0


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(window=st.sampled_from([16, 64, 256, 1024]),
           inflight=st.sampled_from([1, 2, 4, 8, 16, 32]),
           req=st.sampled_from([8 * 1024, 1 * 2**20, 16 * 2**20]),
           rand=st.booleans(), op=st.sampled_from([READ, WRITE]))
    def test_throughput_never_exceeds_physics(window, inflight, req, rand, op):
        _check_throughput_never_exceeds_physics(window, inflight, req, rand, op)
else:
    _PHYSICS_CASES = [
        (16, 1, 8 * 1024, False, READ),
        (16, 32, 16 * 2**20, True, WRITE),
        (64, 4, 1 * 2**20, True, READ),
        (64, 16, 8 * 1024, False, WRITE),
        (256, 8, 16 * 2**20, False, READ),
        (256, 2, 1 * 2**20, True, WRITE),
        (1024, 32, 16 * 2**20, False, WRITE),
        (1024, 8, 8 * 1024, True, READ),
    ]

    @pytest.mark.parametrize("window,inflight,req,rand,op", _PHYSICS_CASES)
    def test_throughput_never_exceeds_physics(window, inflight, req, rand, op):
        _check_throughput_never_exceeds_physics(window, inflight, req, rand, op)


def _check_counters_monotonic_nonnegative(window, inflight):
    sim = PFSSim(n_clients=2, n_osts=4, seed=1)
    sim.attach(sequential_stream(0, READ, 2**20, ost=0))
    sim.attach(random_stream(1, WRITE, 8192, ost=0, n_threads=4))
    sim.set_knobs(sim.client_oscs(0), window_pages=window,
                  rpcs_in_flight=inflight)
    prev = None
    for _ in range(10):
        sim.run(0.25)
        cur = (sim.ctr_bytes_done.copy(), sim.ctr_rpcs_sent.copy(),
               sim.ctr_latency_sum.copy())
        for arr in cur:
            assert (arr >= -1e-9).all()
        if prev is not None:
            for a, b in zip(prev, cur):
                assert (b - a >= -1e-6).all(), "counters must be monotonic"
        prev = cur
    # fluid state sanity
    assert (sim.dirty_bytes >= -1e-6).all()
    assert (sim.active_rpcs >= -1e-6).all()


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(window=st.sampled_from([16, 64, 256, 1024]),
           inflight=st.sampled_from([1, 4, 16]))
    def test_counters_monotonic_nonnegative(window, inflight):
        _check_counters_monotonic_nonnegative(window, inflight)
else:
    @pytest.mark.parametrize("window,inflight",
                             [(16, 1), (64, 16), (256, 4), (1024, 16)])
    def test_counters_monotonic_nonnegative(window, inflight):
        _check_counters_monotonic_nonnegative(window, inflight)


def test_determinism():
    t1, _ = run_stream(READ, sequential_stream, 2**20, 256, 8, seed=5)
    t2, _ = run_stream(READ, sequential_stream, 2**20, 256, 8, seed=5)
    assert t1 == t2


# ---------------------------------------------------------------------- #
# calibration regressions (the regimes DIAL exploits)
# ---------------------------------------------------------------------- #
def test_seq_big_window_wins():
    lo, _ = run_stream(READ, sequential_stream, 16 * 2**20, 16, 4)
    hi, _ = run_stream(READ, sequential_stream, 16 * 2**20, 1024, 4)
    assert hi > 2 * lo


def test_random_small_oversized_window_hurts():
    """The paper's SII-B motivation: huge windows idle the RPC channels
    under sparse random demand."""
    good, _ = run_stream(READ, random_stream, 8192, 64, 8, n_threads=32)
    bad, _ = run_stream(READ, random_stream, 8192, 1024, 8, n_threads=32)
    assert good > 2 * bad


def test_inflight_scales_seq_reads():
    lo, _ = run_stream(READ, sequential_stream, 2**20, 256, 1)
    hi, _ = run_stream(READ, sequential_stream, 2**20, 256, 8)
    assert hi > 1.5 * lo


def test_contention_shares_bandwidth():
    sim = PFSSim(n_clients=4, n_osts=4, seed=0)
    wls = []
    for c in range(4):
        w = sequential_stream(c, READ, 2**20, ost=0)
        sim.attach(w)
        wls.append(w)
    sim.run(6.0)
    rates = [w.done_bytes(sim) / 6.0 for w in wls]
    cap = sim.params.ost_bandwidth
    assert sum(rates) <= cap * 1.05
    assert max(rates) / max(min(rates), 1.0) < 1.5  # fair-ish


def test_write_cache_absorbs_then_throttles():
    sim = PFSSim(n_clients=1, n_osts=4, seed=0)
    w = sequential_stream(0, WRITE, 2**20, ost=0)
    sim.attach(w)
    sim.set_knobs(sim.client_oscs(0), window_pages=256, rpcs_in_flight=8)
    sim.run(0.5)
    early = w.done_bytes(sim) / 0.5
    sim.run(10.0)
    late = (w.done_bytes(sim) - early * 0.5) / 10.0
    assert early > late  # initial burst into the dirty cache
    assert late <= sim.params.ost_bandwidth * 1.05
