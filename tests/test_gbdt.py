"""GBDT trainer + dense forest: correctness, calibration, persistence."""

import numpy as np
import pytest

try:  # property-based fuzzing when available; seeded sweep otherwise
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.gbdt import DenseForest, GBDTClassifier, GBDTParams


def _toy(n=4000, d=8, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    y = ((X[:, 0] > 0.3) & (X[:, 1] < 0.5) | (X[:, 2] * X[:, 3] > 1.0)).astype(float)
    return X, y


def test_fits_nonlinear_rule():
    X, y = _toy()
    clf = GBDTClassifier(GBDTParams(n_trees=60, max_depth=4)).fit(X[:3000], y[:3000])
    acc = ((clf.predict_proba(X[3000:]) > 0.5) == y[3000:]).mean()
    assert acc > 0.9


def test_dense_layout_roundtrip(tmp_path):
    X, y = _toy(n=1500)
    clf = GBDTClassifier(GBDTParams(n_trees=20, max_depth=4)).fit(X, y)
    f = clf.forest
    path = str(tmp_path / "forest.npz")
    f.save(path)
    g = DenseForest.load(path)
    np.testing.assert_allclose(f.predict_margin(X[:64]),
                               g.predict_margin(X[:64]))


def test_monotone_loss_improvement():
    """More trees should not make training loss worse."""
    X, y = _toy(n=2000)
    margins = []
    for t in (10, 40, 120):
        clf = GBDTClassifier(GBDTParams(n_trees=t, max_depth=4,
                                        subsample=1.0)).fit(X, y)
        p = np.clip(clf.predict_proba(X), 1e-6, 1 - 1e-6)
        margins.append(-(y * np.log(p) + (1 - y) * np.log(1 - p)).mean())
    assert margins[0] >= margins[1] >= margins[2]


def _check_predictions_in_unit_interval(seed):
    X, y = _toy(n=800, seed=seed)
    clf = GBDTClassifier(GBDTParams(n_trees=15, max_depth=3)).fit(X, y)
    p = clf.predict_proba(X[:100])
    assert ((p >= 0) & (p <= 1)).all()


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100))
    def test_predictions_in_unit_interval(seed):
        _check_predictions_in_unit_interval(seed)
else:
    @pytest.mark.parametrize("seed", range(0, 101, 10))
    def test_predictions_in_unit_interval(seed):
        _check_predictions_in_unit_interval(seed)


def test_pass_through_padding_semantics():
    """Every tree is padded to full depth; traversal of a constant
    dataset must reproduce the base rate exactly."""
    X = np.zeros((512, 4))
    y = np.concatenate([np.ones(256), np.zeros(256)])
    clf = GBDTClassifier(GBDTParams(n_trees=10, max_depth=4)).fit(X, y)
    p = clf.predict_proba(X[:4])
    np.testing.assert_allclose(p, 0.5, atol=0.05)


# ---------------------------------------------------------------------- #
# edge cases, each pinned through a DenseForest save/load round trip and
# mirrored against the jitted trainer (repro.learn.boost)
# ---------------------------------------------------------------------- #
def _roundtrip(forest, tmp_path, tag):
    path = str(tmp_path / f"{tag}.npz")
    forest.save(path)
    loaded = DenseForest.load(path)
    np.testing.assert_array_equal(loaded.feature, forest.feature)
    np.testing.assert_array_equal(loaded.threshold, forest.threshold)
    np.testing.assert_array_equal(loaded.leaf, forest.leaf)
    return loaded


def _both_trainers(X, y, params):
    from repro.learn.boost import fit_forest

    f_np = GBDTClassifier(params).fit(X, y).forest
    f_jx = fit_forest(X, y, params)
    np.testing.assert_array_equal(f_np.feature, f_jx.feature)
    np.testing.assert_allclose(f_np.leaf, f_jx.leaf, atol=1e-5)
    return f_np


def test_constant_features_never_split(tmp_path):
    """Constant columns have no valid split bin; trees must fall back to
    pass-through spines without touching them."""
    rng = np.random.default_rng(0)
    X = np.column_stack([np.full(400, 3.25), rng.normal(size=400),
                         np.full(400, -1.0)])
    y = (X[:, 1] > 0).astype(float)
    p = GBDTParams(n_trees=8, max_depth=3)
    f = _both_trainers(X, y, p)
    assert not ((f.feature == 0) & np.isfinite(f.threshold)).any()
    assert not ((f.feature == 2) & np.isfinite(f.threshold)).any()
    g = _roundtrip(f, tmp_path, "const")
    acc = ((g.predict_proba(X) > 0.5) == y).mean()
    assert acc > 0.9


def test_single_class_labels(tmp_path):
    """All-positive labels: no split has gain; prediction saturates at
    the (clamped) base rate."""
    rng = np.random.default_rng(1)
    X = rng.normal(size=(300, 5))
    y = np.ones(300)
    p = GBDTParams(n_trees=6, max_depth=4)
    f = _both_trainers(X, y, p)
    g = _roundtrip(f, tmp_path, "oneclass")
    assert (g.predict_proba(X[:32]) > 0.99).all()


def test_fewer_samples_than_bins(tmp_path):
    """n_samples < n_bins collapses quantile edges via dedup; both
    trainers must agree and the forest must still fit the data."""
    rng = np.random.default_rng(2)
    X = rng.normal(size=(20, 3))
    y = (X[:, 0] > 0).astype(float)
    p = GBDTParams(n_trees=10, max_depth=3, n_bins=48, subsample=1.0,
                   min_child_hess=0.1)
    f = _both_trainers(X, y, p)
    g = _roundtrip(f, tmp_path, "tiny")
    acc = ((g.predict_proba(X) > 0.5) == y).mean()
    assert acc == 1.0


def test_depth_padding_pass_through_nodes(tmp_path):
    """A rule needing only one split leaves deep levels as pass-through
    (threshold=+inf descends left, spine carries the leaf value); the
    dense traversal must still be exact after a save/load round trip."""
    rng = np.random.default_rng(3)
    X = rng.normal(size=(600, 4))
    y = (X[:, 2] > 0.1).astype(float)
    p = GBDTParams(n_trees=5, max_depth=5, subsample=1.0)
    f = _both_trainers(X, y, p)
    assert np.isinf(f.threshold).any()          # real pass-through nodes
    g = _roundtrip(f, tmp_path, "passthrough")
    np.testing.assert_array_equal(g.predict_margin(X),
                                  f.predict_margin(X))
    acc = ((g.predict_proba(X) > 0.5) == y).mean()
    assert acc > 0.97
