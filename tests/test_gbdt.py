"""GBDT trainer + dense forest: correctness, calibration, persistence."""

import numpy as np
import pytest

try:  # property-based fuzzing when available; seeded sweep otherwise
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.gbdt import DenseForest, GBDTClassifier, GBDTParams


def _toy(n=4000, d=8, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    y = ((X[:, 0] > 0.3) & (X[:, 1] < 0.5) | (X[:, 2] * X[:, 3] > 1.0)).astype(float)
    return X, y


def test_fits_nonlinear_rule():
    X, y = _toy()
    clf = GBDTClassifier(GBDTParams(n_trees=60, max_depth=4)).fit(X[:3000], y[:3000])
    acc = ((clf.predict_proba(X[3000:]) > 0.5) == y[3000:]).mean()
    assert acc > 0.9


def test_dense_layout_roundtrip(tmp_path):
    X, y = _toy(n=1500)
    clf = GBDTClassifier(GBDTParams(n_trees=20, max_depth=4)).fit(X, y)
    f = clf.forest
    path = str(tmp_path / "forest.npz")
    f.save(path)
    g = DenseForest.load(path)
    np.testing.assert_allclose(f.predict_margin(X[:64]),
                               g.predict_margin(X[:64]))


def test_monotone_loss_improvement():
    """More trees should not make training loss worse."""
    X, y = _toy(n=2000)
    margins = []
    for t in (10, 40, 120):
        clf = GBDTClassifier(GBDTParams(n_trees=t, max_depth=4,
                                        subsample=1.0)).fit(X, y)
        p = np.clip(clf.predict_proba(X), 1e-6, 1 - 1e-6)
        margins.append(-(y * np.log(p) + (1 - y) * np.log(1 - p)).mean())
    assert margins[0] >= margins[1] >= margins[2]


def _check_predictions_in_unit_interval(seed):
    X, y = _toy(n=800, seed=seed)
    clf = GBDTClassifier(GBDTParams(n_trees=15, max_depth=3)).fit(X, y)
    p = clf.predict_proba(X[:100])
    assert ((p >= 0) & (p <= 1)).all()


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100))
    def test_predictions_in_unit_interval(seed):
        _check_predictions_in_unit_interval(seed)
else:
    @pytest.mark.parametrize("seed", range(0, 101, 10))
    def test_predictions_in_unit_interval(seed):
        _check_predictions_in_unit_interval(seed)


def test_pass_through_padding_semantics():
    """Every tree is padded to full depth; traversal of a constant
    dataset must reproduce the base rate exactly."""
    X = np.zeros((512, 4))
    y = np.concatenate([np.ones(256), np.zeros(256)])
    clf = GBDTClassifier(GBDTParams(n_trees=10, max_depth=4)).fit(X, y)
    p = clf.predict_proba(X[:4])
    np.testing.assert_allclose(p, 0.5, atol=0.05)
