"""Per-arch smoke tests (reduced configs): fwd/train step, shapes, no NaNs,
prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import lm

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    if cfg.num_codebooks:
        tokens = jax.random.randint(KEY, (B, S, cfg.num_codebooks), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.img_tokens:
        batch["img_embeds"] = jax.random.normal(
            KEY, (B, cfg.img_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """One forward/backward on the reduced config: finite loss + grads."""
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, batch, cfg))(params)
    assert np.isfinite(float(loss)), arch
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, KEY)
    batch = _batch(cfg)
    x, aux = lm.forward_train(params, batch["tokens"], cfg,
                              img_embeds=batch.get("img_embeds"))
    exp_s = S + (cfg.img_tokens or 0)
    assert x.shape == (B, exp_s, cfg.d_model), arch
    logits = lm.logits_for(params, x[:, -1:], cfg)
    if cfg.num_codebooks:
        assert logits.shape == (B, 1, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch


@pytest.mark.parametrize("arch", ["stablelm-12b", "recurrentgemma-9b",
                                  "falcon-mamba-7b", "gemma2-2b",
                                  "musicgen-large"])
def test_prefill_decode_consistency(arch):
    """Token-by-token decode reproduces the prefill logits."""
    cfg = get_smoke_config(arch)
    params = lm.init_params(cfg, KEY)
    tshape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    tokens = jax.random.randint(KEY, tshape, 0, cfg.vocab_size)
    plog, _ = lm.prefill(params, tokens, cfg, max_len=S + 8)
    cache = lm.init_cache(cfg, B, max_len=S + 8)
    for t in range(S):
        dlog, cache = lm.decode_step(params, tokens[:, t:t + 1], cache,
                                     jnp.int32(t), cfg)
    err = float(jnp.abs(plog - dlog).max())
    assert err < 5e-2, (arch, err)


def test_full_configs_construct_abstractly():
    """Full published configs build abstract params without allocation,
    and the analytic parameter counts are in the right ballpark."""
    expected_b = {
        "gemma2-2b": (2.0, 3.5), "stablelm-12b": (11, 14),
        "starcoder2-15b": (14, 17), "qwen1.5-32b": (30, 36),
        "falcon-mamba-7b": (6.5, 8.5), "olmoe-1b-7b": (6, 8),
        "recurrentgemma-9b": (8, 11), "llava-next-34b": (32, 36),
        "qwen2-moe-a2.7b": (13, 16), "musicgen-large": (2, 3.5),
    }
    for arch in ARCHS:
        cfg = get_config(arch)
        ap = lm.abstract_params(cfg)
        n = sum(np.prod(l.shape) for l in jax.tree.leaves(ap))
        lo, hi = expected_b[arch]
        assert lo * 1e9 <= n <= hi * 1e9, (arch, n / 1e9)
        # analytic count agrees with the real pytree within 2%
        assert abs(cfg.param_count() - n) / n < 0.02, (
            arch, cfg.param_count() / 1e9, n / 1e9)


def test_gemma2_softcap_and_pattern():
    cfg = get_config("gemma2-2b")
    types = cfg.layer_types()
    assert len(types) == 26
    assert types[0] == "attn_local" and types[1] == "attn"
    assert cfg.final_softcap == 30.0 and cfg.attn_softcap == 50.0


def test_recurrentgemma_pattern_with_tail():
    cfg = get_config("recurrentgemma-9b")
    types = cfg.layer_types()
    assert len(types) == 38
    assert types.count("attn_local") == 12
    assert types.count("recurrent") == 26
    assert cfg.tail_types == ("recurrent", "recurrent")
