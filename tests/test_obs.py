"""Device-resident telemetry: tracing must never change a decision,
the host mirror must produce the same rows as the fused trace, and the
sinks (JSONL, Chrome trace_event, markdown) must round-trip / validate.
Also covers the perf-ledger tooling (compare.py, provenance, timers).
"""

import copy
import json
import os
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.fleet import run_fleet  # noqa: E402
from repro.obs.schema import (DECISION_FIELDS, TIMELINE_FIELDS, RunTrace,
                              TraceConfig)  # noqa: E402
from repro.pfs import PFSSim  # noqa: E402
from repro.pfs.engine import READ, WRITE  # noqa: E402
from repro.pfs.workloads import random_stream, sequential_stream  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_EXACT = ("decided", "ops", "theta", "changed", "n_candidates", "active",
          "steady", "warm")
_CLOSE = ("t", "score", "probs", "vol_r", "vol_w", "ratio")


def _mixed_sim(seed=5):
    sim = PFSSim(n_clients=4, n_osts=2, seed=seed)
    sim.attach(sequential_stream(0, READ, 4 * 2**20, ost=0))
    sim.attach(random_stream(1, WRITE, 64 * 1024, ost=1, n_threads=2))
    sim.attach(sequential_stream(2, WRITE, 2 * 2**20, ost=0, n_threads=2))
    sim.attach(random_stream(3, READ, 256 * 1024, ost=1))
    sim.set_knobs(np.arange(sim.n_osc), window_pages=64, rpcs_in_flight=2)
    return sim


def _traj(decisions):
    return [(r.oscs.tolist(), r.ops.tolist(), r.decisions.theta.tolist(),
             r.decisions.changed.tolist()) for r in decisions]


def _counters_close(state_a, state_b, rtol=1e-6):
    for f in ("ctr_bytes_done", "ctr_rpcs_sent", "ctr_latency_sum",
              "ctr_pending_integral", "ctr_block_time"):
        np.testing.assert_allclose(
            np.asarray(getattr(state_a, f), dtype=np.float64),
            np.asarray(getattr(state_b, f), dtype=np.float64),
            rtol=rtol, atol=1e-6, err_msg=f)


@pytest.fixture(scope="module")
def fused_traced(dial_model):
    cfg = TraceConfig(stride=5)
    sim = _mixed_sim()
    fleet = run_fleet(sim, dial_model, seconds=4.0, interval=0.5,
                      backend="jax-fused", trace=cfg)
    return fleet, sim, cfg


# ---------------------------------------------------------------------- #
# schema guards
# ---------------------------------------------------------------------- #
def test_trace_config_stride_validates():
    with pytest.raises(ValueError, match="stride"):
        TraceConfig(stride=0)
    assert TraceConfig().stride >= 1


# ---------------------------------------------------------------------- #
# tentpole: tracing is decision-neutral on every path
# ---------------------------------------------------------------------- #
def test_traced_fused_is_decision_neutral(dial_model, fused_traced):
    """Trace records are *additional* scan outputs: the traced fused
    dispatch produces bit-identical θ and ≤1e-6 counters vs untraced."""
    f_tr, sim_tr, _ = fused_traced
    sim = _mixed_sim()
    f = run_fleet(sim, dial_model, seconds=4.0, interval=0.5,
                  backend="jax-fused")
    assert _traj(f_tr.decisions) == _traj(f.decisions)
    assert any(len(r) for r in f_tr.decisions), "run never decided"
    np.testing.assert_array_equal(sim_tr.window_pages, sim.window_pages)
    np.testing.assert_array_equal(sim_tr.rpcs_in_flight,
                                  sim.rpcs_in_flight)
    _counters_close(sim_tr.state, sim.state)
    trace = f_tr.trace
    assert isinstance(trace, RunTrace)
    trace.validate()
    assert trace.decisions["decided"].any()


def test_traced_numpy_is_decision_neutral(dial_model):
    sim_a, sim_b = _mixed_sim(), _mixed_sim()
    fa = run_fleet(sim_a, dial_model, seconds=3.0, interval=0.5,
                   backend="numpy")
    fb = run_fleet(sim_b, dial_model, seconds=3.0, interval=0.5,
                   backend="numpy", trace=TraceConfig(stride=5))
    assert _traj(fa.decisions) == _traj(fb.decisions)
    np.testing.assert_array_equal(sim_a.window_pages, sim_b.window_pages)
    _counters_close(sim_a.state, sim_b.state)
    fb.trace.validate()


def test_host_trace_mirrors_fused_trace(dial_model, fused_traced):
    """The host tick loop with the HostTracer produces the same rows —
    every decision field and every timeline track — as the in-dispatch
    fused trace (the host model scores through the same fused float32
    predictor, so probabilities match bitwise)."""
    f_fused, _, cfg = fused_traced
    model_jax = copy.copy(dial_model)
    model_jax.backend = "jax"
    model_jax.__post_init__()
    sim = _mixed_sim()
    f_host = run_fleet(sim, model_jax, seconds=4.0, interval=0.5,
                       backend="numpy", trace=cfg)
    th, tf = f_host.trace, f_fused.trace
    th.validate()
    assert th.n_intervals == tf.n_intervals
    assert th.n_interfaces == tf.n_interfaces
    for f in _EXACT:
        np.testing.assert_array_equal(th.decisions[f], tf.decisions[f],
                                      err_msg=f)
    for f in _CLOSE:
        np.testing.assert_allclose(th.decisions[f], tf.decisions[f],
                                   rtol=1e-5, atol=1e-8, err_msg=f)
    assert set(th.decisions) == set(DECISION_FIELDS)
    assert th.timeline is not None and tf.timeline is not None
    assert set(th.timeline) == set(TIMELINE_FIELDS)
    for f in TIMELINE_FIELDS:
        np.testing.assert_allclose(th.timeline[f], tf.timeline[f],
                                   rtol=1e-5, atol=1e-6, err_msg=f)


def test_split_batch_trace_covers_untuned_elements(dial_model):
    """Mixed tuned/untuned batch: tracing stays decision-neutral, the
    merged trace covers every element's timeline, and never-tuned
    elements carry the inert placeholder decision record (decided
    false, θ = applied knobs)."""
    from repro.lab.batch import run_batch, stack_scenarios
    from repro.lab.scenarios import SCENARIOS, build, variants

    cfg = TraceConfig(stride=10)
    spec = SCENARIOS["degraded_ost"]

    def batch():
        return stack_scenarios([build(s) for s in variants(spec, 3,
                                                           seed=4)])
    ba, bb = batch(), batch()
    n = ba.n_osc
    # tune only elements 0 and 2; element 1 runs the lean program
    cols = np.concatenate([np.arange(n), 2 * n + np.arange(n)])
    ra = run_batch(ba, dial_model, seconds=3.0, interval=0.5, fused=True,
                   tune_cols=cols)
    rb = run_batch(bb, dial_model, seconds=3.0, interval=0.5, fused=True,
                   tune_cols=cols, trace=cfg)
    assert _traj(ra.decisions) == _traj(rb.decisions)
    trace = RunTrace.from_fused(rb, cfg, bb.params.tick)
    trace.validate()
    assert trace.n_interfaces == 3 * n
    decided = trace.decisions["decided"]
    assert not decided[:, n:2 * n].any(), "lean program cannot decide"
    assert decided[:, :n].any() or decided[:, 2 * n:].any()
    # untuned columns: θ is the element's applied (never-changed) knobs
    theta_u = trace.decisions["theta"][:, n:2 * n]
    want = np.stack([np.asarray(bb.state.window_pages)[1],
                     np.asarray(bb.state.rpcs_in_flight)[1]], axis=-1)
    np.testing.assert_array_equal(
        theta_u, np.broadcast_to(want, theta_u.shape))
    assert not trace.decisions["changed"][:, n:2 * n].any()
    # the timeline merged from both programs: finite, all elements hot
    tl = trace.timeline
    assert tl["read_bytes"].shape[1] == 3 * bb.topo.n_osts
    assert np.isfinite(tl["read_bytes"]).all()
    assert (tl["read_bytes"] + tl["write_bytes"]).sum() > 0


def test_sharded_traced_matches_untraced_8dev(dial_model):
    """Traced sharded dispatch on 8 forced host devices: θ identical to
    the untraced single-device run, trace validates at full batch."""
    code = """
import numpy as np
from repro.core.gbdt import GBDTClassifier, GBDTParams
from repro.core.metrics import feature_dim
from repro.core.model import DIALModel
from repro.pfs.state import READ, WRITE

rng = np.random.default_rng(0)
def _forest(dim):
    x = rng.normal(size=(400, dim)).astype(np.float32)
    y = (x[:, 0] + x[:, -1] > -1.0).astype(np.int64)
    return GBDTClassifier(GBDTParams(n_trees=8, max_depth=3)).fit(x, y).forest
k = 1
model = DIALModel(read_forest=_forest(feature_dim(READ, k)),
                  write_forest=_forest(feature_dim(WRITE, k)),
                  backend="jax", k=k)

import jax
from repro.distributed.sharding import fleet_mesh
from repro.lab.batch import run_batch, stack_scenarios
from repro.lab.scenarios import SCENARIOS, build, variants
from repro.obs.schema import RunTrace, TraceConfig

assert jax.device_count() == 8
cfg = TraceConfig(stride=10)
spec = SCENARIOS["failing_ost"]
ba = stack_scenarios([build(s) for s in variants(spec, 8, seed=2)])
bb = stack_scenarios([build(s) for s in variants(spec, 8, seed=2)])
ra = run_batch(ba, model, seconds=3.0, interval=0.5, fused=True)
rb = run_batch(bb, model, seconds=3.0, interval=0.5, fused=True,
               mesh=fleet_mesh(8), trace=cfg)
ta = [(i, int(o), int(op), int(t[0]), int(t[1]))
      for i, r in enumerate(ra.decisions)
      for o, op, t in zip(r.oscs, r.ops, r.decisions.theta)]
tb = [(i, int(o), int(op), int(t[0]), int(t[1]))
      for i, r in enumerate(rb.decisions)
      for o, op, t in zip(r.oscs, r.ops, r.decisions.theta)]
assert ta == tb and len(tb) > 0
trace = RunTrace.from_fused(rb, cfg, bb.params.tick)
trace.validate()
assert trace.n_interfaces == 8 * ba.n_osc
assert trace.decisions["decided"].any()
print("OK", len(tb))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------- #
# sinks
# ---------------------------------------------------------------------- #
def test_jsonl_roundtrip(tmp_path, fused_traced):
    from repro.obs.sinks import read_jsonl, write_jsonl

    fleet, _, _ = fused_traced
    path = write_jsonl(fleet.trace, str(tmp_path / "trace.jsonl"))
    back = read_jsonl(path)
    a, b = fleet.trace, back
    assert a.n_intervals == b.n_intervals
    assert a.n_interfaces == b.n_interfaces
    assert a.config == b.config
    np.testing.assert_array_equal(a.oscs, b.oscs)
    # the sink rounds floats to 9 decimals: lossless for flags/θ,
    # absolute 1e-9 for probabilities and gate metrics
    for f in DECISION_FIELDS:
        np.testing.assert_allclose(a.decisions[f], b.decisions[f],
                                   rtol=1e-6, atol=1e-9, err_msg=f)
    for f in TIMELINE_FIELDS:
        np.testing.assert_allclose(a.timeline[f], b.timeline[f],
                                   rtol=1e-6, atol=1e-9, err_msg=f)
    back.validate()


def test_chrome_trace_valid_and_monotone(tmp_path, fused_traced):
    from repro.obs.sinks import write_chrome

    fleet, _, _ = fused_traced
    path = write_chrome(fleet.trace, str(tmp_path / "trace.chrome.json"))
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert events, "empty chrome trace"
    assert {e["ph"] for e in events} <= {"C", "i", "M"}
    timed = [e["ts"] for e in events if e["ph"] != "M"]
    assert all(t >= 0 for t in timed)
    assert timed == sorted(timed), "timestamps not monotone"
    # counter tracks exist for every OST and decisions made it in
    assert any(e["ph"] == "i" for e in events)
    assert any(e["ph"] == "C" for e in events)


def test_render_summary(fused_traced):
    from repro.obs.sinks import render_summary

    fleet, _, _ = fused_traced
    md = render_summary(fleet.trace, title="mixed")
    assert "mixed" in md
    assert "decided" in md
    assert "OST" in md


# ---------------------------------------------------------------------- #
# fuzz triage replay recipes
# ---------------------------------------------------------------------- #
def test_trace_recipe_roundtrip(tmp_path):
    from repro.lab.fuzz import fingerprint, spec_to_dict, trace_recipe
    from repro.lab.scenarios import SCENARIOS
    from repro.lab.trace import load_spec_from_report

    spec = SCENARIOS["degraded_ost"]
    fp = fingerprint(spec)
    report = {"triage": {"losses": [
        {"name": spec.name, "fingerprint": fp,
         "spec": spec_to_dict(spec)}]}}
    path = str(tmp_path / "report.json")
    with open(path, "w") as f:
        json.dump(report, f)
    recipe = trace_recipe(path, fp)
    assert "--from-report" in recipe and fp in recipe
    back = load_spec_from_report(path, fp)
    assert back.n_clients == spec.n_clients
    assert back.n_osts == spec.n_osts
    with pytest.raises(KeyError, match="not in"):
        load_spec_from_report(path, "no-such-fp")


# ---------------------------------------------------------------------- #
# perf ledger: timers, provenance, compare gate
# ---------------------------------------------------------------------- #
def test_phase_timers():
    from repro.obs.timers import PhaseTimers

    t = PhaseTimers()
    with t.phase("dispatch"):
        pass
    t.add("dispatch", 0.5)
    t.add("to_host", 0.25)
    s = t.summary()
    assert s["dispatch"]["calls"] == 2
    assert s["dispatch"]["seconds"] >= 0.5
    assert s["to_host"]["seconds"] == 0.25
    t.reset()
    assert t.summary() == {}


def test_collect_provenance():
    from repro.obs.timers import collect_provenance

    p = collect_provenance()
    for key in ("git_sha", "platform", "python", "jax_version",
                "device_count", "device_kind", "default_backend"):
        assert key in p, key
    assert p["device_count"] >= 1
    assert isinstance(p["git_sha"], str)


def test_compare_direction_and_gate(tmp_path):
    sys.path.insert(0, REPO)
    try:
        from benchmarks.compare import compare, direction, main
    finally:
        sys.path.remove(REPO)

    assert direction("speedup") == +1
    assert direction("read_e2e_ms") == -1
    assert direction("default_overhead_pct") == -1
    assert direction("us_per_call") == 0

    base = {"schema": "dial-bench-v1", "benchmarks": [
        {"name": "x", "us_per_call": 100,
         "derived": {"speedup": 10.0, "exec_ms": 5.0}}]}
    good = {"schema": "dial-bench-v1", "benchmarks": [
        {"name": "x", "us_per_call": 900,
         "derived": {"speedup": 10.5, "exec_ms": 4.9}}]}
    bad = {"schema": "dial-bench-v1", "benchmarks": [
        {"name": "x", "us_per_call": 100,
         "derived": {"speedup": 5.0, "exec_ms": 9.0}}]}
    assert compare(base, good)["regressions"] == []
    r = compare(base, bad)
    assert {x["metric"] for x in r["regressions"]} == \
        {"x.speedup", "x.exec_ms"}
    # a looser threshold passes what the default flags
    assert compare(base, bad, threshold=1.0)["regressions"] == []

    pb, pc = str(tmp_path / "b.json"), str(tmp_path / "c.json")
    with open(pb, "w") as f:
        json.dump(base, f)
    with open(pc, "w") as f:
        json.dump(bad, f)
    assert main([pb, pb]) == 0
    assert main([pb, pc]) == 1
    assert main([pb, pc, "--report-only"]) == 0


def test_compare_asymmetric_records(tmp_path):
    """Metrics on only one side: first-class new/removed rows in the
    table (and render), never a gate failure."""
    sys.path.insert(0, REPO)
    try:
        from benchmarks.compare import compare, main, render
    finally:
        sys.path.remove(REPO)

    base = {"schema": "dial-bench-v1", "benchmarks": [
        {"name": "x", "us_per_call": 100, "derived": {"speedup": 10.0}},
        {"name": "old", "us_per_call": 50, "derived": {"exec_ms": 3.0}}]}
    cand = {"schema": "dial-bench-v1", "benchmarks": [
        {"name": "x", "us_per_call": 110, "derived": {"speedup": 10.1}},
        {"name": "fresh", "us_per_call": 70, "derived": {"gain": 2.0}}]}
    r = compare(base, cand)
    verdicts = {row["metric"]: row["verdict"] for row in r["rows"]}
    assert verdicts["old.exec_ms"] == "removed"
    assert verdicts["old.us_per_call"] == "removed"
    assert verdicts["fresh.gain"] == "new"
    assert verdicts["fresh.us_per_call"] == "new"
    # removed rows keep their baseline value, new rows their candidate
    by_metric = {row["metric"]: row for row in r["rows"]}
    assert by_metric["old.exec_ms"]["baseline"] == 3.0
    assert by_metric["old.exec_ms"]["candidate"] is None
    assert by_metric["fresh.gain"]["candidate"] == 2.0
    assert by_metric["fresh.gain"]["baseline"] is None
    assert r["regressions"] == []          # asymmetry never fails
    out = render(r)
    assert "removed" in out and "new" in out
    assert "old.exec_ms" in out and "fresh.gain" in out

    pb, pc = str(tmp_path / "b.json"), str(tmp_path / "c.json")
    with open(pb, "w") as f:
        json.dump(base, f)
    with open(pc, "w") as f:
        json.dump(cand, f)
    assert main([pb, pc]) == 0
