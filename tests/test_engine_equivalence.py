"""Engine layering equivalence: legacy loop == vectorized table == fused
JAX scan, plus byte-conservation properties on every backend.

The numpy tick loop (legacy ``Workload`` objects driving ``sim.step()``)
is the oracle; the vectorized ``WorkloadTable`` demand path and the
jitted ``lax.scan`` interval path must reproduce its per-OSC counters to
tight tolerance, and ``FleetAgent`` tuning on top of either engine
backend must produce identical knob trajectories.
"""

import numpy as np
import pytest

from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import (WorkloadTable, bdcats_read, dlio_reader,
                                 random_stream, run_interval,
                                 sequential_stream, table_from_sim,
                                 vpic_write)

TICKS_PER_INTERVAL = 100   # 0.5 s tuning interval at the 5 ms tick
N_INTERVALS = 3

PROBE_COUNTERS = (
    "ctr_bytes_done", "ctr_rpcs_sent", "ctr_rpc_bytes", "ctr_partial_rpcs",
    "ctr_latency_sum", "ctr_rpcs_done", "ctr_req_count", "ctr_req_bytes",
    "ctr_cache_hit_bytes", "ctr_block_time", "ctr_pending_integral",
    "ctr_active_integral", "ctr_dirty_integral", "ctr_grant_integral",
    "randomness",
)
FLUID_FIELDS = (
    "pending", "queue_rpcs", "queue_bytes", "active_rpcs", "setup_work",
    "unready_bytes", "ready_bytes", "dirty_bytes", "grant_used",
    "write_blocked",
)


def mixed_workloads():
    """The paper's evaluation mix (vpic + bdcats + dlio + filebench),
    including overlapping stripes that force multi-wave demand."""
    wls = []
    for c in range(0, 4):
        wls.append(vpic_write(c, dims=1 + c % 3, osts=(0, 1, 2, 3)))
    for c, mode in zip(range(4, 8), ("partial", "strided", "full", "partial")):
        wls.append(bdcats_read(c, mode, osts=(0, 1, 2, 3)))
    for c in range(8, 12):
        wls.append(dlio_reader(c, "bert" if c % 2 else "megatron",
                               n_threads=2 + c % 3, osts=(c % 4,)))
    for c in range(12, 16):
        if c % 2:
            wls.append(sequential_stream(c, READ, 4 * 2**20, ost=c % 4))
        else:
            wls.append(random_stream(c, WRITE, 256 * 1024, ost=c % 4,
                                     n_threads=2))
    # overlapping same-client stripes -> multi-wave table
    wls.append(bdcats_read(4, "full", osts=(2, 3)))
    wls.append(vpic_write(0, dims=1, osts=(2, 3)))
    return wls


def build_sim(seed=0):
    sim = PFSSim(n_clients=16, n_osts=4, seed=seed)   # 64 OSC interfaces
    for w in mixed_workloads():
        sim.attach(w)
    return sim


def run_oracle(n_ticks):
    sim = build_sim()
    for _ in range(n_ticks):
        sim.step()
    return sim


def assert_states_close(oracle_state, state, fields, rtol):
    for f in fields:
        a = np.asarray(getattr(oracle_state, f), dtype=float)
        b = np.asarray(getattr(state, f), dtype=float)
        err = np.max(np.abs(a - b) / np.maximum(np.abs(a), 1.0))
        assert err <= rtol, (f, err)


# ---------------------------------------------------------------------- #
# layer equivalence
# ---------------------------------------------------------------------- #
def test_workload_table_matches_legacy_loop():
    """Vectorized demand (numpy backend) == per-object Workload.tick."""
    n = TICKS_PER_INTERVAL * N_INTERVALS
    oracle = run_oracle(n)
    sim = build_sim()
    table, wstate = table_from_sim(sim)
    assert table.n_waves >= 2   # the overlap rows exercise wave sequencing
    state, wstate = run_interval(sim.params, sim.topo, table, sim.state,
                                 wstate, n)
    assert_states_close(oracle.state, state, PROBE_COUNTERS, 1e-9)
    assert_states_close(oracle.state, state, FLUID_FIELDS, 1e-9)
    # per-row delivered bytes and closed-loop issued state match the
    # legacy objects too (the handoff sync_workloads_from_table relies on)
    done = table.done_bytes(state, wstate)
    for i, w in enumerate(oracle._workloads):
        assert done[i] == pytest.approx(w.done_bytes(oracle), rel=1e-9)
        assert wstate.issued[i] == pytest.approx(w._issued, rel=1e-9,
                                                 abs=1e-3)


def test_jax_scan_matches_numpy_oracle():
    """Acceptance: mixed vpic/bdcats/dlio, 64 OSCs, 3 fused intervals ->
    per-OSC ctr_bytes_done and every probe counter within 1e-6 relative
    of the numpy oracle."""
    jax = pytest.importorskip("jax")
    from repro.pfs.engine_jax import FusedEngine

    oracle = run_oracle(TICKS_PER_INTERVAL * N_INTERVALS)
    sim = build_sim()
    table, wstate = table_from_sim(sim)
    engine = FusedEngine(sim.params, sim.topo, table, TICKS_PER_INTERVAL,
                         seg_backend="jax")
    state = sim.state
    for _ in range(N_INTERVALS):
        state, wstate = engine.run_interval(state, wstate)
    assert state.tick_index == oracle.state.tick_index
    assert_states_close(oracle.state, state, PROBE_COUNTERS, 1e-6)
    assert_states_close(oracle.state, state, FLUID_FIELDS, 1e-6)


def test_fleet_agent_trajectories_identical_across_backends(dial_model):
    """FleetAgent tuning on the fused scan == on the Python tick loop:
    same decisions, same knob trajectory, interval for interval."""
    pytest.importorskip("jax")
    from repro.core.fleet import run_fleet

    def run(backend):
        sim = PFSSim(n_clients=8, n_osts=2, seed=3)
        for c in range(8):
            if c % 2:
                sim.attach(sequential_stream(c, READ, 4 * 2**20, ost=c % 2))
            else:
                sim.attach(random_stream(c, WRITE, 256 * 1024, ost=c % 2,
                                         n_threads=2))
        sim.set_knobs(np.arange(sim.n_osc), window_pages=64, rpcs_in_flight=2)
        fleet = run_fleet(sim, dial_model, seconds=3.0, interval=0.5,
                          backend=backend)
        traj = [(r.oscs.tolist(), r.ops.tolist(), r.decisions.theta.tolist(),
                 r.decisions.changed.tolist()) for r in fleet.decisions]
        return traj, sim.window_pages.copy(), sim.rpcs_in_flight.copy()

    traj_np, win_np, rif_np = run("numpy")
    traj_jax, win_jax, rif_jax = run("jax")
    assert traj_np == traj_jax
    np.testing.assert_array_equal(win_np, win_jax)
    np.testing.assert_array_equal(rif_np, rif_jax)


# ---------------------------------------------------------------------- #
# conservation properties
# ---------------------------------------------------------------------- #
def check_conservation(state):
    """Over any workload mix: per-op submitted bytes == completed +
    in-pipeline bytes, and all state arrays stay non-negative."""
    s = state
    atol = 1e-3   # bytes; counters reach ~1e10
    # reads: everything submitted is either done or still in the pipeline
    read_pipe = (s.pending[READ] + s.queue_bytes[READ]
                 + s.unready_bytes[READ] + s.ready_bytes[READ])
    np.testing.assert_allclose(
        np.asarray(s.ctr_req_bytes[READ]),
        np.asarray(s.ctr_bytes_done[READ] + read_pipe),
        rtol=1e-9, atol=atol, err_msg="read byte conservation")
    # writes: app-visible completion == acceptance into the dirty cache,
    # and the write pipeline mirrors the dirty cache exactly
    np.testing.assert_allclose(
        np.asarray(s.ctr_req_bytes[WRITE]),
        np.asarray(s.ctr_bytes_done[WRITE]),
        rtol=1e-9, atol=atol, err_msg="write acceptance accounting")
    write_pipe = (s.pending[WRITE] + s.queue_bytes[WRITE]
                  + s.unready_bytes[WRITE] + s.ready_bytes[WRITE])
    np.testing.assert_allclose(
        np.asarray(s.dirty_bytes), np.asarray(write_pipe),
        rtol=1e-9, atol=atol, err_msg="dirty cache vs write pipeline")
    for f in FLUID_FIELDS + PROBE_COUNTERS:
        assert (np.asarray(getattr(s, f), dtype=float) >= -1e-6).all(), f


@pytest.mark.parametrize("seed", [0, 7])
def test_conservation_numpy_backend(seed):
    sim = build_sim(seed=seed)
    for i in range(6):
        for _ in range(50):
            sim.step()
        check_conservation(sim.state)


def test_conservation_jax_backend():
    pytest.importorskip("jax")
    from repro.pfs.engine_jax import FusedEngine

    sim = build_sim()
    table, wstate = table_from_sim(sim)
    engine = FusedEngine(sim.params, sim.topo, table, 50, seg_backend="jax")
    state = sim.state
    for _ in range(6):
        state, wstate = engine.run_interval(state, wstate)
        check_conservation(state)


# ---------------------------------------------------------------------- #
# segment_reduce kernel
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("e,s,block", [(37, 4, 64), (1024, 8, 256),
                                       (5000, 33, 1024)])
def test_segment_sum_kernel_matches_refs(e, s, block):
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.segment_reduce.kernel import segment_sum as pallas_ss
    from repro.kernels.segment_reduce.ops import segment_sum_np
    from repro.kernels.segment_reduce.ref import segment_sum_ref

    rng = np.random.default_rng(e)
    x = rng.normal(size=e).astype(np.float32)
    seg = rng.integers(0, s, size=e)
    want = segment_sum_np(x, seg, s)
    got_ref = np.asarray(segment_sum_ref(jnp.asarray(x), jnp.asarray(seg), s))
    got_pal = np.asarray(pallas_ss(jnp.asarray(x), jnp.asarray(seg), s,
                                   block_e=block, interpret=True))
    np.testing.assert_allclose(got_ref, want, rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(got_pal, want, rtol=1e-5, atol=1e-4)
