"""Model artifact persistence: campaigns must be able to save and reload
forests with bit-identical inference.

Covers the three layers the lab leans on: ``DenseForest.save/load``,
``DIALModel.save/load`` (forests + space + k), and the versioned
campaign artifact directory (``save_versioned`` / ``load_versioned`` /
``LATEST`` resolution).
"""

import numpy as np
import pytest

from repro.core.config_space import SPACE
from repro.core.gbdt import DenseForest, GBDTClassifier, GBDTParams
from repro.core.model import DIALModel


@pytest.fixture(scope="module")
def forests():
    rng = np.random.default_rng(42)
    n, dim = 400, 12
    X = rng.normal(size=(n, dim)).astype(np.float32)
    out = []
    for seed in (0, 1):
        y = (X[:, seed] + 0.3 * rng.normal(size=n) > 0).astype(float)
        clf = GBDTClassifier(GBDTParams(n_trees=25, max_depth=4, seed=seed))
        out.append(clf.fit(X, y).forest)
    return out[0], out[1], X


def test_dense_forest_roundtrip_bit_identical(tmp_path, forests):
    f, _, X = forests
    path = str(tmp_path / "forest.npz")
    f.save(path)
    g = DenseForest.load(path)
    assert g.depth == f.depth and g.n_features == f.n_features
    assert g.base_score == f.base_score
    np.testing.assert_array_equal(g.feature, f.feature)
    np.testing.assert_array_equal(g.threshold, f.threshold)
    np.testing.assert_array_equal(g.leaf, f.leaf)
    np.testing.assert_array_equal(g.predict_proba(X), f.predict_proba(X))


def test_dial_model_roundtrip_bit_identical(tmp_path, forests):
    fr, fw, X = forests
    model = DIALModel(read_forest=fr, write_forest=fw, space=SPACE, k=1)
    prefix = str(tmp_path / "dial")
    model.save(prefix)
    loaded = DIALModel.load(prefix)
    assert loaded.k == model.k
    assert len(loaded.space) == len(model.space)
    for op in (0, 1):
        np.testing.assert_array_equal(loaded.predict_proba(op, X),
                                      model.predict_proba(op, X))


def test_versioned_artifacts_roundtrip_and_latest(tmp_path, forests):
    from repro.lab.campaign import (latest_version, load_versioned,
                                    save_versioned)

    fr, fw, X = forests
    root = str(tmp_path / "models")
    m1 = DIALModel(read_forest=fr, write_forest=fw)
    m2 = DIALModel(read_forest=fw, write_forest=fr)   # distinct payload
    d1 = save_versioned(m1, root, meta={"note": "first"})
    d2 = save_versioned(m2, root, meta={"note": "second"})
    assert d1.endswith("v001") and d2.endswith("v002")
    assert latest_version(root) == "v002"

    latest = load_versioned(root)
    np.testing.assert_array_equal(latest.predict_proba(0, X),
                                  m2.predict_proba(0, X))
    pinned = load_versioned(root, version="v001")
    np.testing.assert_array_equal(pinned.predict_proba(1, X),
                                  m1.predict_proba(1, X))
    import json
    import os
    with open(os.path.join(d2, "manifest.json")) as f:
        assert json.load(f)["version"] == "v002"


def test_load_versioned_missing_raises(tmp_path):
    from repro.lab.campaign import load_versioned

    with pytest.raises(FileNotFoundError):
        load_versioned(str(tmp_path / "nothing"))


def test_train_meta_roundtrip_and_backend_parity(tmp_path, forests):
    """train_models records provenance (backend + dataset fingerprint)
    for both training paths, and DIALModel.save/load round-trips it."""
    from repro.core.dataset import train_models

    fr, fw, X = forests
    rng = np.random.default_rng(7)
    n, dim = 300, 12
    Xd = rng.normal(size=(n, dim)).astype(np.float32)
    data = {"read": (Xd, (Xd[:, 0] > 0).astype(float)),
            "write": (Xd, (Xd[:, 1] > 0).astype(float))}
    params = GBDTParams(n_trees=10, max_depth=3)
    m_np = train_models(data, params, backend="numpy")
    m_jx = train_models(data, params, backend="jax")
    assert m_np.train_meta["trainer_backend"] == "numpy"
    assert m_jx.train_meta["trainer_backend"] == "jax"
    # same data -> same fingerprint; parity-grade training -> same forests
    assert m_np.train_meta["dataset"] == m_jx.train_meta["dataset"]
    assert m_np.train_meta["dataset"]["rows"] == {"read": n, "write": n}
    np.testing.assert_array_equal(m_np.read_forest.feature,
                                  m_jx.read_forest.feature)
    np.testing.assert_allclose(m_np.read_forest.leaf,
                               m_jx.read_forest.leaf, atol=1e-5)

    prefix = str(tmp_path / "dial")
    m_jx.save(prefix)
    loaded = DIALModel.load(prefix)
    assert loaded.train_meta == m_jx.train_meta


def test_versioned_artifact_refuses_mismatched_forests(tmp_path, forests):
    """The strict loader cross-checks manifest vs model provenance, so
    forests swapped underneath a campaign manifest are refused."""
    import json
    import os

    from repro.lab.campaign import load_versioned, save_versioned

    fr, fw, X = forests
    meta = {"trainer_backend": "jax",
            "dataset": {"rows": {"read": 10, "write": 10}, "sha256": "aa"}}
    model = DIALModel(read_forest=fr, write_forest=fw, train_meta=meta)
    root = str(tmp_path / "models")
    d = save_versioned(model, root, meta={"train_meta": meta})
    assert load_versioned(root) is not None      # consistent -> loads

    # tamper: rewrite the model meta as if trained on other data
    with open(os.path.join(d, "dial.meta.json"), "w") as f:
        json.dump({"trainer_backend": "numpy",
                   "dataset": {"rows": {"read": 99, "write": 1},
                               "sha256": "bb"}}, f)
    with pytest.raises(ValueError, match="inconsistent"):
        load_versioned(root)
    assert load_versioned(root, strict=False) is not None


def test_versioned_artifact_refuses_missing_or_corrupt_meta(tmp_path,
                                                            forests):
    """Deleting or truncating dial.meta.json must not bypass the strict
    guard when the manifest still carries train_meta (the partial-copy /
    tamper case the guard exists for)."""
    import os

    from repro.lab.campaign import load_versioned, save_versioned

    fr, fw, X = forests
    meta = {"trainer_backend": "jax",
            "dataset": {"rows": {"read": 10, "write": 10}, "sha256": "aa"}}
    model = DIALModel(read_forest=fr, write_forest=fw, train_meta=meta)
    root = str(tmp_path / "models")
    d = save_versioned(model, root, meta={"train_meta": meta})
    meta_path = os.path.join(d, "dial.meta.json")

    # truncated/corrupt meta -> refused
    with open(meta_path, "w") as f:
        f.write('{"trainer_backend":')
    with pytest.raises(ValueError, match="missing or unreadable"):
        load_versioned(root)

    # missing meta -> refused
    os.remove(meta_path)
    with pytest.raises(ValueError, match="missing or unreadable"):
        load_versioned(root)
    assert load_versioned(root, strict=False) is not None


def test_versioned_artifact_refuses_missing_or_corrupt_manifest(tmp_path,
                                                                forests):
    """The manifest side of the same contract: a model carrying
    provenance whose manifest.json is gone or truncated is refused."""
    import os

    from repro.lab.campaign import load_versioned, save_versioned

    fr, fw, X = forests
    meta = {"trainer_backend": "jax",
            "dataset": {"rows": {"read": 10, "write": 10}, "sha256": "aa"}}
    model = DIALModel(read_forest=fr, write_forest=fw, train_meta=meta)
    root = str(tmp_path / "models")
    d = save_versioned(model, root, meta={"train_meta": meta})
    man_path = os.path.join(d, "manifest.json")

    with open(man_path, "w") as f:
        f.write('{"version":')
    with pytest.raises(ValueError, match="manifest.json is missing"):
        load_versioned(root)

    os.remove(man_path)
    with pytest.raises(ValueError, match="manifest.json is missing"):
        load_versioned(root)
    assert load_versioned(root, strict=False) is not None
