"""Fuzz layer: event validation, schedule-compiler properties, the
Lustre-grounded fault kinds on both backends, seed-pinned differential
numpy-vs-fused equivalence on *generated* scenarios, and sweep
determinism.  Property tests run under hypothesis when available and as
seeded parametrized sweeps otherwise (the test_gbdt.py convention)."""

import dataclasses
import json
import math

import numpy as np
import pytest

try:  # property-based fuzzing when available; seeded sweep otherwise
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.lab.fuzz import (SMOKE, _draw_event, fingerprint,
                            generate_spec, generate_specs, load_hard_specs,
                            run_sweep, spec_from_dict, spec_to_dict,
                            write_fuzz_report)
from repro.lab.scenarios import (EVENT_KINDS, FAULT_KINDS, DisturbanceEvent,
                                 ScenarioSpec, build, make_schedule,
                                 validate_events)
from repro.pfs.engine import READ
from repro.pfs.state import Disturbance, SimParams, SimTopo, _neutral_cached
from repro.pfs.workloads import run_interval, sequential_stream

PARAMS = SimParams()
FIELDS = ("bw_scale", "iops_scale", "bg_bytes", "nic_scale")


def _sched_leaves(s):
    return [np.asarray(getattr(s, f)) for f in FIELDS]


def _gen_events(seed, n_clients=4, n_osts=2, horizon=6.0, n=3):
    """Deterministic arbitrary events covering every kind (reuses the
    sweep's own generator so properties hold on exactly what it draws)."""
    rng = np.random.default_rng(seed)
    kinds = [EVENT_KINDS[(seed + i) % len(EVENT_KINDS)] for i in range(n)]
    return [_draw_event(rng, k, n_clients, n_osts, horizon) for k in kinds]


# ---------------------------------------------------------------------- #
# construction-time validation (satellite: malformed events fail loudly)
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("kwargs,match", [
    (dict(kind="ost_melt", targets=(0,), magnitude=0.5), "unknown"),
    (dict(kind="ost_slow", targets=(), magnitude=0.5), "empty targets"),
    (dict(kind="ost_slow", targets=(0.5,), magnitude=0.5), "integer ids"),
    (dict(kind="ost_slow", targets=(-1,), magnitude=0.5), "integer ids"),
    (dict(kind="ost_slow", targets=(0,), magnitude=-0.5), "magnitude"),
    (dict(kind="ost_slow", targets=(0,), magnitude=math.inf), "magnitude"),
    (dict(kind="ost_slow", targets=(0,), magnitude=0.0), "magnitude"),
    (dict(kind="nic_slow", targets=(0,), magnitude=0.0), "magnitude"),
    (dict(kind="ost_fail", targets=(0,), magnitude=1.0), "residual"),
    (dict(kind="client_evict", targets=(0,), end=3.0, magnitude=1.5),
     "residual"),
    (dict(kind="ost_slow", targets=(0,), magnitude=0.5, start=-1.0),
     "start"),
    (dict(kind="ost_slow", targets=(0,), magnitude=0.5, start=math.nan),
     "start"),
    (dict(kind="ost_slow", targets=(0,), magnitude=0.5, start=2.0,
          end=2.0), "end"),
    (dict(kind="ost_slow", targets=(0,), magnitude=0.5, period=-1.0),
     "period"),
    (dict(kind="ost_slow", targets=(0,), magnitude=0.5, period=math.inf),
     "period"),
    (dict(kind="ost_slow", targets=(0,), magnitude=0.5, period=1.0,
          duty=0.0), "duty"),
    (dict(kind="ost_slow", targets=(0,), magnitude=0.5, period=1.0,
          duty=1.5), "duty"),
    (dict(kind="ost_slow", targets=(0,), magnitude=0.5, recovery=1.0),
     "recovery"),
    (dict(kind="ost_failover", targets=(0,), end=3.0), "recovery"),
    (dict(kind="ost_failover", targets=(0,), recovery=2.0), "finite"),
    (dict(kind="ost_failover", targets=(0,), end=3.0, recovery=-1.0),
     "recovery"),
    (dict(kind="ost_failover", targets=(0,), end=3.0, recovery=2.0,
          period=1.0), "period"),
])
def test_event_construction_rejects(kwargs, match):
    with pytest.raises(ValueError, match=match):
        DisturbanceEvent(**kwargs)


def test_event_valid_constructions_pass():
    DisturbanceEvent("ost_slow", targets=(0, 1), magnitude=0.3,
                     period=1.0, duty=1.0)            # duty = 1 is legal
    DisturbanceEvent("ost_fail", targets=(0,), start=1.0, end=2.0)
    DisturbanceEvent("ost_failover", targets=(1,), start=1.0, end=2.0,
                     recovery=0.5)
    DisturbanceEvent("client_evict", targets=(2,), start=1.0, end=2.0,
                     magnitude=0.1)


def test_out_of_topology_targets_rejected():
    topo = SimTopo.dense(4, 2)
    ost_ev = DisturbanceEvent("ost_slow", targets=(2,), magnitude=0.5)
    cli_ev = DisturbanceEvent("client_evict", targets=(4,), start=1.0,
                              end=2.0)
    for ev in (ost_ev, cli_ev):
        with pytest.raises(ValueError, match="out of range"):
            validate_events([ev], topo)
        with pytest.raises(ValueError, match="out of range"):
            make_schedule([ev], topo, PARAMS, 0, 10)
    spec = ScenarioSpec(name="bad", n_clients=4, n_osts=2,
                        workloads=(sequential_stream(0, READ, 2**20),),
                        events=(ost_ev,))
    with pytest.raises(ValueError, match="out of range"):
        build(spec)


# ---------------------------------------------------------------------- #
# satellite regression: the cached neutral disturbance is immutable
# ---------------------------------------------------------------------- #
def test_cached_neutral_is_frozen():
    """lru_cached identity arrays are shared by every undisturbed tick;
    an in-place edit must raise instead of corrupting later ticks."""
    d = _neutral_cached(2, 4)
    with pytest.raises((ValueError, RuntimeError)):
        d.bw_scale[0] = 0.5
    with pytest.raises((ValueError, RuntimeError)):
        d.bg_bytes += 1.0
    again = _neutral_cached(2, 4)
    assert again is d                       # still the shared instance
    np.testing.assert_array_equal(again.bw_scale, np.ones(2))
    np.testing.assert_array_equal(again.bg_bytes, np.zeros(2))
    np.testing.assert_array_equal(again.nic_scale, np.ones(4))


def test_neutral_schedules_stay_writable():
    """make_schedule composes events into a *fresh* neutral schedule in
    place — freezing the cache must not freeze those."""
    topo = SimTopo.dense(2, 2)
    s = Disturbance.neutral(topo, n_ticks=4)
    s.bw_scale[:] = 0.5                      # fresh array: fine
    t = Disturbance.neutral(topo, n_ticks=4)
    np.testing.assert_array_equal(t.bw_scale, np.ones((4, 2)))


# ---------------------------------------------------------------------- #
# schedule-compiler properties (hypothesis / seeded fallback)
# ---------------------------------------------------------------------- #
def _check_composition_order_independent(seed):
    topo = SimTopo.dense(4, 2)
    events = _gen_events(seed, 4, 2, n=3)
    a = make_schedule(events, topo, PARAMS, 0, 200)
    b = make_schedule(list(reversed(events)), topo, PARAMS, 0, 200)
    for x, y, f in zip(_sched_leaves(a), _sched_leaves(b), FIELDS):
        np.testing.assert_allclose(x, y, rtol=1e-12, atol=0, err_msg=f)


def _check_tiling_across_intervals(seed):
    """Absolute-tick purity: one 240-tick compile bit-equals any
    partition into consecutive intervals."""
    topo = SimTopo.dense(4, 2)
    events = _gen_events(seed, 4, 2, n=2)
    whole = make_schedule(events, topo, PARAMS, 0, 240)
    rng = np.random.default_rng(seed + 1)
    cuts = sorted(rng.choice(np.arange(1, 240), size=3, replace=False))
    bounds = [0, *map(int, cuts), 240]
    parts = [make_schedule(events, topo, PARAMS, lo, hi - lo)
             for lo, hi in zip(bounds[:-1], bounds[1:])]
    for f in FIELDS:
        tiled = np.concatenate([np.asarray(getattr(p, f)) for p in parts])
        np.testing.assert_array_equal(tiled, np.asarray(getattr(whole, f)),
                                      err_msg=f)


def _check_no_events_is_exact_identity(seed):
    topo = SimTopo.dense(2 + seed % 3, 1 + seed % 2)
    s = make_schedule([], topo, PARAMS, seed * 7, 50)
    assert (np.asarray(s.bw_scale) == 1.0).all()
    assert (np.asarray(s.iops_scale) == 1.0).all()
    assert (np.asarray(s.bg_bytes) == 0.0).all()
    assert (np.asarray(s.nic_scale) == 1.0).all()


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 500))
    def test_composition_order_independent(seed):
        _check_composition_order_independent(seed)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 500))
    def test_tiling_across_intervals(seed):
        _check_tiling_across_intervals(seed)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100))
    def test_no_events_is_exact_identity(seed):
        _check_no_events_is_exact_identity(seed)
else:
    @pytest.mark.parametrize("seed", range(12))
    def test_composition_order_independent(seed):
        _check_composition_order_independent(seed)

    @pytest.mark.parametrize("seed", range(12))
    def test_tiling_across_intervals(seed):
        _check_tiling_across_intervals(seed)

    @pytest.mark.parametrize("seed", range(6))
    def test_no_events_is_exact_identity(seed):
        _check_no_events_is_exact_identity(seed)


# ---------------------------------------------------------------------- #
# active() / capacity_scale edges
# ---------------------------------------------------------------------- #
def test_active_window_boundaries():
    ev = DisturbanceEvent("ost_slow", targets=(0,), magnitude=0.5,
                          start=1.0, end=3.0)
    t = np.array([0.0, 1.0 - 1e-9, 1.0, 2.0, 3.0 - 1e-9, 3.0, 4.0])
    np.testing.assert_array_equal(
        ev.active(t), [False, False, True, True, True, False, False])


def test_active_duty_edge_is_strict():
    """(t - start) mod period < duty * period is strict: the tick landing
    exactly on the duty boundary is OFF."""
    ev = DisturbanceEvent("ost_slow", targets=(0,), magnitude=0.5,
                          start=0.0, period=1.0, duty=0.5)
    t = np.array([0.0, 0.25, 0.5, 0.75, 1.0, 1.25, 1.5])
    np.testing.assert_array_equal(
        ev.active(t), [True, True, False, False, True, True, False])


def test_active_duty_one_equals_plain_window():
    t = np.linspace(0.0, 6.0, 601)
    plain = DisturbanceEvent("ost_slow", targets=(0,), magnitude=0.5,
                             start=1.0, end=4.0)
    duty1 = DisturbanceEvent("ost_slow", targets=(0,), magnitude=0.5,
                             start=1.0, end=4.0, period=0.7, duty=1.0)
    np.testing.assert_array_equal(duty1.active(t), plain.active(t))


def test_failover_capacity_ramp_exact():
    """0 during the outage, linear from `end`, exactly 1 at
    end + recovery and beyond (exact binary arithmetic on this grid)."""
    ev = DisturbanceEvent("ost_failover", targets=(0,), start=1.0,
                          end=2.0, recovery=2.0)
    t = np.array([0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 3.5, 4.0, 4.5])
    np.testing.assert_array_equal(
        ev.capacity_scale(t),
        [1.0, 0.0, 0.0, 0.0, 0.25, 0.5, 0.75, 1.0, 1.0])


def test_fail_capacity_snaps_back():
    ev = DisturbanceEvent("ost_fail", targets=(0,), start=1.0, end=2.0)
    t = np.array([0.5, 1.0, 2.0 - 1e-9, 2.0, 3.0])
    np.testing.assert_array_equal(ev.capacity_scale(t),
                                  [1.0, 0.0, 0.0, 1.0, 1.0])


def test_fault_kinds_compile_into_disturbance_fields():
    """ost_fail/ost_failover hit bw+iops; client_evict hits nic only."""
    topo = SimTopo.dense(3, 2)
    n_ticks = int(round(6.0 / PARAMS.tick))
    s = make_schedule([
        DisturbanceEvent("ost_failover", targets=(0,), start=1.0, end=2.0,
                         recovery=2.0),
        DisturbanceEvent("client_evict", targets=(1,), start=1.0, end=2.0),
    ], topo, PARAMS, 0, n_ticks)
    t = np.arange(n_ticks) * PARAMS.tick
    out = (t >= 1.0) & (t < 2.0)
    assert (np.asarray(s.bw_scale)[out, 0] == 0.0).all()
    assert (np.asarray(s.iops_scale)[out, 0] == 0.0).all()
    assert (np.asarray(s.bw_scale)[:, 1] == 1.0).all()   # other OST spared
    assert (np.asarray(s.nic_scale)[out, 1] == 0.0).all()
    assert (np.asarray(s.nic_scale)[:, 0] == 1.0).all()
    assert (np.asarray(s.nic_scale)[:, 2] == 1.0).all()
    recovered = t >= 4.0
    assert (np.asarray(s.bw_scale)[recovered, 0] == 1.0).all()
    ramp = (t > 2.0) & (t < 4.0)                 # at t=end scale is still
    bw = np.asarray(s.bw_scale)[ramp, 0]         # magnitude (= 0 here)
    assert (bw > 0.0).all() and (bw < 1.0).all()
    assert (np.diff(bw) > 0).all()                       # strictly rising


# ---------------------------------------------------------------------- #
# fault kinds bite, on both backends (acceptance: failover ramp)
# ---------------------------------------------------------------------- #
def _interval_bytes(spec, backend, n_intervals=8, interval=0.5):
    """Per-interval total bytes on one backend, plus the final state."""
    b = build(spec)
    steps = int(round(interval / b.params.tick))
    if backend == "jax":
        jax = pytest.importorskip("jax")
        from repro.pfs.engine_jax import FusedEngine
        engine = FusedEngine(b.params, b.topo, b.table, steps,
                             seg_backend="jax")
    st, ws = b.state, b.wstate
    done, out = 0.0, []
    for i in range(n_intervals):
        sched = b.schedule(i * steps, steps)
        if backend == "numpy":
            st, ws = run_interval(b.params, b.topo, b.table, st, ws, steps,
                                  schedule=sched)
        else:
            st, ws = engine.run_interval(st, ws, schedule=sched)
        total = float(np.asarray(st.ctr_bytes_done).sum())
        out.append(total - done)
        done = total
    return np.array(out), st


_FAILOVER_SPEC = ScenarioSpec(
    name="fuzz_failover_probe", n_clients=2, n_osts=1,
    workloads=tuple(sequential_stream(c, READ, 4 * 2**20, ost=0,
                                      n_threads=2) for c in range(2)),
    events=(DisturbanceEvent("ost_failover", targets=(0,), start=1.0,
                             end=2.0, recovery=1.5),),
)


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_ost_failover_bites_with_recovery_ramp(backend):
    """Throughput collapses during the outage and climbs back along the
    ramp instead of snapping — on the numpy oracle AND the fused scan.
    Intervals: [0,1) healthy, [1,2) outage, [2,3.5) ramp, [3.5,4) full."""
    deltas, _ = _interval_bytes(_FAILOVER_SPEC, backend)
    healthy = deltas[:2].mean()
    outage = deltas[2:4]
    ramp_lo, ramp_hi = deltas[4], deltas[6]      # [2,2.5) vs [3,3.5)
    assert healthy > 0
    assert (outage < 0.05 * healthy).all(), "outage did not bite"
    assert ramp_lo > outage.max(), "no recovery along the ramp"
    assert ramp_hi > 1.5 * max(ramp_lo, 1.0), "ramp is not ramping"
    assert deltas[7] > 0.6 * healthy, "never recovered to near-full"


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_ost_fail_hard_outage_and_snap_back(backend):
    spec = dataclasses.replace(
        _FAILOVER_SPEC, name="fuzz_fail_probe",
        events=(DisturbanceEvent("ost_fail", targets=(0,), start=1.0,
                                 end=2.0),))
    deltas, _ = _interval_bytes(spec, backend)
    healthy = deltas[:2].mean()
    assert (deltas[2:4] < 0.05 * healthy).all()
    assert deltas[4] > 0.5 * healthy            # immediate snap back


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_client_evict_stalls_victim_only(backend):
    spec = dataclasses.replace(
        _FAILOVER_SPEC, name="fuzz_evict_probe", n_clients=2, n_osts=1,
        events=(DisturbanceEvent("client_evict", targets=(0,), start=1.0,
                                 end=3.0),))
    b = build(spec)
    steps = int(round(0.5 / b.params.tick))
    if backend == "jax":
        pytest.importorskip("jax")
        from repro.pfs.engine_jax import FusedEngine
        engine = FusedEngine(b.params, b.topo, b.table, steps,
                             seg_backend="jax")
    st, ws = b.state, b.wstate
    per_osc = []
    for i in range(8):
        sched = b.schedule(i * steps, steps)
        if backend == "numpy":
            st, ws = run_interval(b.params, b.topo, b.table, st, ws, steps,
                                  schedule=sched)
        else:
            st, ws = engine.run_interval(st, ws, schedule=sched)
        per_osc.append(np.asarray(st.ctr_bytes_done).sum(axis=0).copy())
    per_osc = np.array(per_osc)                 # (8, n_osc) cumulative
    deltas = np.diff(per_osc, axis=0, prepend=0.0)
    victim, survivor = deltas[:, 0], deltas[:, 1]
    stalled = victim[2:6]                       # [1,3): evicted
    assert victim[0] > 0 and survivor[0] > 0
    assert (stalled < 0.05 * victim[:2].mean()).all(), "victim not stalled"
    assert (survivor[2:6] > 0.5 * survivor[:2].mean()).all(), \
        "survivor should keep flowing"
    assert victim[7] > 0.3 * victim[:2].mean(), "victim never reconnected"


def test_fault_backends_agree_on_counters():
    """The same fault schedule produces ≤1e-6-relative counters on the
    numpy oracle and the fused scan (zero scales are NaN-safe on both)."""
    pytest.importorskip("jax")
    for events in [
        (DisturbanceEvent("ost_failover", targets=(0,), start=1.0,
                          end=2.0, recovery=1.5),),
        (DisturbanceEvent("ost_fail", targets=(0,), start=1.0, end=2.0),),
        (DisturbanceEvent("client_evict", targets=(0,), start=1.0,
                          end=3.0),),
    ]:
        spec = dataclasses.replace(_FAILOVER_SPEC, events=events)
        _, st_np = _interval_bytes(spec, "numpy", n_intervals=6)
        _, st_jx = _interval_bytes(spec, "jax", n_intervals=6)
        for f in ("ctr_bytes_done", "ctr_rpcs_sent", "ctr_latency_sum",
                  "ctr_block_time", "ctr_pending_integral",
                  "ctr_dirty_integral"):
            np.testing.assert_allclose(
                np.asarray(getattr(st_jx, f), dtype=np.float64),
                np.asarray(getattr(st_np, f), dtype=np.float64),
                rtol=1e-6, atol=1e-6, err_msg=f"{events[0].kind}:{f}")


# ---------------------------------------------------------------------- #
# the generator: determinism, validity, coverage
# ---------------------------------------------------------------------- #
def test_generation_is_deterministic_and_valid():
    a = generate_specs(SMOKE)
    b = generate_specs(SMOKE)
    assert len(a) == SMOKE.n_scenarios >= 64
    assert [fingerprint(s) for s in a] == [fingerprint(s) for s in b]
    for s in a[:16]:
        build(s)                              # construct + validate
    drawn = {ev.kind for s in a for ev in s.events}
    assert set(FAULT_KINDS) <= drawn          # fault vocabulary exercised
    assert drawn <= set(EVENT_KINDS)


def test_fingerprint_ignores_labels_but_not_physics():
    s = generate_spec(SMOKE, 3)
    relabeled = dataclasses.replace(s, name="x", seed=99,
                                    description="y", tags=("z",))
    assert fingerprint(relabeled) == fingerprint(s)
    changed = dataclasses.replace(s, initial_theta=(16, 1)
                                  if s.initial_theta != (16, 1)
                                  else (64, 2))
    assert fingerprint(changed) != fingerprint(s)


def test_spec_dict_round_trip():
    for i in (0, 5, 11):
        s = generate_spec(SMOKE, i)
        rt = spec_from_dict(json.loads(json.dumps(spec_to_dict(s))))
        assert fingerprint(rt) == fingerprint(s)
        build(rt)


# ---------------------------------------------------------------------- #
# differential: generated scenarios, numpy host oracle vs fused loop
# ---------------------------------------------------------------------- #
def _diff_specs():
    """Seed-pinned generated scenarios covering all three fault kinds.

    Indices are pinned on the stable side of demand-gate knife-edges: a
    duty-cycled closed loop can amplify segment-sum reduction-order ulp
    drift into one flipped issue burst (a few requests out of thousands
    — θ decisions still identical), so like every cross-backend pin in
    this suite the counter comparison fixes its inputs.  A sweep of the
    full 32-spec stream showed exact θ-trajectory equality on all 32 and
    ≤1e-13-relative counters on 30.
    """
    cfg = dataclasses.replace(SMOKE, n_scenarios=32, min_events=1)
    specs = generate_specs(cfg)
    picked = [specs[i] for i in (0, 1, 10, 19, 25)]
    covered = {ev.kind for s in picked for ev in s.events}
    assert set(FAULT_KINDS) <= covered, "pinned set lost fault coverage"
    return picked


def test_differential_generated_numpy_vs_fused(dial_model):
    """θ trajectories exact and counters ≤1e-6 rel between the host
    numpy oracle (FleetAgent + run_interval) and run_batch(fused=True)
    on generated scenarios including the new fault kinds."""
    pytest.importorskip("jax")
    from repro.core.fleet import FleetAgent, SimFleetPort
    from repro.lab.batch import run_batch, stack_scenarios
    from repro.pfs import PFSSim

    interval, seconds = 0.5, 3.0
    n_intervals = int(round(seconds / interval))
    for spec in _diff_specs():
        # --- host numpy oracle ---
        b = build(spec)
        steps = int(round(interval / b.params.tick))
        sim = PFSSim(spec.n_clients, spec.n_osts)
        sim.state = b.state
        ws = b.wstate
        fleet = FleetAgent(SimFleetPort(sim), dial_model)
        for i in range(n_intervals):
            sched = b.schedule(i * steps, steps)
            sim.state, ws = run_interval(b.params, b.topo, b.table,
                                         sim.state, ws, steps,
                                         schedule=sched)
            fleet.tick()

        # --- fused device loop (single-element batch, all cols tuned) ---
        bf = stack_scenarios([build(spec)])
        result = run_batch(bf, model=dial_model, seconds=seconds,
                           interval=interval, fused=True)

        traj = lambda recs: [(r.oscs.tolist(), r.ops.tolist(),
                              r.decisions.theta.tolist(),
                              r.decisions.changed.tolist()) for r in recs]
        assert traj(result.decisions) == traj(fleet.decisions), spec.name
        np.testing.assert_array_equal(
            np.asarray(bf.state.window_pages)[0], sim.state.window_pages,
            err_msg=spec.name)
        np.testing.assert_array_equal(
            np.asarray(bf.state.rpcs_in_flight)[0],
            sim.state.rpcs_in_flight, err_msg=spec.name)
        for f in ("ctr_bytes_done", "ctr_rpcs_sent", "ctr_rpc_bytes",
                  "ctr_partial_rpcs", "ctr_latency_sum", "ctr_rpcs_done",
                  "ctr_req_count", "ctr_req_bytes", "ctr_cache_hit_bytes",
                  "ctr_block_time", "ctr_pending_integral",
                  "ctr_active_integral", "ctr_dirty_integral",
                  "ctr_grant_integral"):
            np.testing.assert_allclose(
                np.asarray(getattr(bf.state, f))[0].astype(np.float64),
                np.asarray(getattr(sim.state, f), dtype=np.float64),
                rtol=1e-6, atol=1e-6, err_msg=f"{spec.name}:{f}")


# ---------------------------------------------------------------------- #
# the sweep harness: determinism, triage, hard-case feed
# ---------------------------------------------------------------------- #
def test_sweep_deterministic_and_triaged(dial_model, tmp_path):
    """A tiny in-process sweep twice: byte-identical reports, coherent
    triage (losses are exactly the under-threshold rows, deduplicated),
    and the hard-case feed round-trips through report.json."""
    pytest.importorskip("jax")
    cfg = dataclasses.replace(
        SMOKE, n_scenarios=6, seconds=2.0,
        thetas=((64, 2), (1024, 16)), topologies=((4, 2),),
        loss_threshold=0.02)
    r1 = run_sweep(cfg, dial_model)
    r2 = run_sweep(cfg, dial_model)
    blob1 = json.dumps(r1, sort_keys=True)
    assert blob1 == json.dumps(r2, sort_keys=True)

    assert r1["summary"]["n_scenarios"] == 6
    assert len(r1["scenarios"]) == 6
    assert [s["index"] for s in r1["scenarios"]] == list(range(6))
    fps = {s["fingerprint"] for s in r1["scenarios"]}
    for row in r1["scenarios"]:
        assert row["dial_mbs"] >= 0 and row["best_static_mbs"] >= 0
    expect_losses = {
        row["fingerprint"] for row in r1["scenarios"]
        if row["best_static_mbs"] >= cfg.min_best_static_mbs
        and row["dial_mbs"] < (1 - cfg.loss_threshold)
        * row["best_static_mbs"]}
    got = [l["fingerprint"] for l in r1["triage"]["losses"]]
    assert set(got) == expect_losses and len(got) == len(set(got))
    assert fps >= expect_losses

    jpath, mpath = write_fuzz_report(r1, str(tmp_path))
    hard = load_hard_specs(jpath)
    assert len(hard) == len(got)
    for spec, l in zip(hard, r1["triage"]["losses"]):
        assert fingerprint(spec) == l["fingerprint"]
        build(spec)                           # replayable
    md = open(mpath).read()
    assert "Fuzz sweep triage" in md
