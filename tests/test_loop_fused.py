"""Device-resident loop: the single-dispatch FusedLoop must reproduce
the (bug-fixed) host FleetAgent oracle decision for decision, plus the
fleet/tuner correctness sweep that pins the oracle itself."""

import inspect

import numpy as np
import pytest

from repro.core.config_space import SPACE
from repro.core.tuner import (TunerParams, conditional_score_greedy,
                              conditional_score_greedy_batch)
from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import random_stream, sequential_stream

jax = pytest.importorskip("jax")


def _traj(decisions):
    return [(r.oscs.tolist(), r.ops.tolist(), r.decisions.theta.tolist(),
             r.decisions.changed.tolist()) for r in decisions]


def _assert_counters_close(state_a, state_b, rtol=1e-6):
    for f in ("ctr_bytes_done", "ctr_rpcs_sent", "ctr_rpc_bytes",
              "ctr_partial_rpcs", "ctr_latency_sum", "ctr_rpcs_done",
              "ctr_req_count", "ctr_req_bytes", "ctr_cache_hit_bytes",
              "ctr_block_time", "ctr_pending_integral",
              "ctr_active_integral", "ctr_dirty_integral",
              "ctr_grant_integral"):
        np.testing.assert_allclose(
            np.asarray(getattr(state_a, f), dtype=np.float64),
            np.asarray(getattr(state_b, f), dtype=np.float64),
            rtol=rtol, atol=1e-6, err_msg=f)


def _mixed_sim(seed=5):
    sim = PFSSim(n_clients=4, n_osts=2, seed=seed)
    sim.attach(sequential_stream(0, READ, 4 * 2**20, ost=0))
    sim.attach(random_stream(1, WRITE, 64 * 1024, ost=1, n_threads=2))
    sim.attach(sequential_stream(2, WRITE, 2 * 2**20, ost=0, n_threads=2))
    sim.attach(random_stream(3, READ, 256 * 1024, ost=1))
    sim.set_knobs(np.arange(sim.n_osc), window_pages=64, rpcs_in_flight=2)
    return sim


def _readheavy_sim(seed=9):
    sim = PFSSim(n_clients=3, n_osts=2, seed=seed)
    sim.attach(sequential_stream(0, READ, 8 * 2**20, ost=0, n_threads=2))
    sim.attach(random_stream(1, READ, 256 * 1024, ost=1, n_threads=2))
    sim.attach(sequential_stream(2, WRITE, 1 * 2**20, ost=1))
    sim.set_knobs(np.arange(sim.n_osc), window_pages=16, rpcs_in_flight=1)
    return sim


# ---------------------------------------------------------------------- #
# tentpole: one jitted dispatch == the per-interval host loop
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("build", [_mixed_sim, _readheavy_sim],
                         ids=["mixed", "readheavy"])
def test_fused_loop_matches_host_oracle(dial_model, build):
    """θ trajectories exact and probe counters ≤1e-6 relative against the
    bug-fixed FleetAgent on the host jax backend AND the numpy engine."""
    import copy

    from repro.core.fleet import run_fleet

    # host "jax" run scores through the same fused float32 paired
    # predictor the device loop embeds, so probabilities match bitwise;
    # the numpy run keeps the float64 oracle forests (θ must still agree)
    model_jax = copy.copy(dial_model)
    model_jax.backend = "jax"
    model_jax.__post_init__()

    def run(backend, model):
        sim = build()
        fleet = run_fleet(sim, model, seconds=4.0, interval=0.5,
                          backend=backend)
        return fleet, sim

    f_np, sim_np = run("numpy", dial_model)
    f_jax, sim_jax = run("jax", model_jax)
    f_fused, sim_fused = run("jax-fused", dial_model)

    # one decision record per interval on every backend (bug-fixed
    # alignment), and the run must actually decide something
    assert len(f_np.decisions) == len(f_jax.decisions) \
        == len(f_fused.decisions) == 8
    assert any(len(r) for r in f_fused.decisions)
    assert any(r.decisions.changed.any() for r in f_fused.decisions
               if len(r))

    assert _traj(f_fused.decisions) == _traj(f_jax.decisions)
    assert _traj(f_fused.decisions) == _traj(f_np.decisions)
    for sim in (sim_jax, sim_np):
        np.testing.assert_array_equal(sim_fused.window_pages,
                                      sim.window_pages)
        np.testing.assert_array_equal(sim_fused.rpcs_in_flight,
                                      sim.rpcs_in_flight)
        _assert_counters_close(sim_fused.state, sim.state)

    # probabilities the decisions were made from match the host float32
    # scoring path exactly (same featurize-cast, same forest traversal)
    for rf, rh in zip(f_fused.decisions, f_jax.decisions):
        np.testing.assert_array_equal(rf.decisions.probs, rh.decisions.probs)


def test_fused_loop_k2_history_matches_host():
    """k>1 history: the fused ring buffer must reproduce the host deque
    (k+1 stacked snapshots, oldest-first feature order, k-deep
    steadiness guards).  Tiny synthetic forests with the k=2 feature
    dimensionality keep this fast — equivalence is about the loop
    mechanics, not model quality."""
    from repro.core.fleet import FleetAgent, SimFleetPort
    from repro.core.gbdt import GBDTClassifier, GBDTParams
    from repro.core.metrics import feature_dim
    from repro.core.model import DIALModel
    from repro.pfs.engine_jax import FusedEngine
    from repro.pfs.loop_jax import FusedLoop
    from repro.pfs.workloads import table_from_sim

    rng = np.random.default_rng(0)

    def forest(dim):
        x = rng.normal(size=(400, dim)).astype(np.float32)
        y = (x[:, 0] + x[:, -1] > -1.0).astype(float)   # mostly positive
        return GBDTClassifier(GBDTParams(n_trees=8, max_depth=3)).fit(
            x, y).forest

    model2 = DIALModel(read_forest=forest(feature_dim(READ, 2)),
                       write_forest=forest(feature_dim(WRITE, 2)),
                       backend="jax", k=2)

    steps = 100
    sim_h = _mixed_sim(seed=11)
    table, wstate = table_from_sim(sim_h)
    engine = FusedEngine(sim_h.params, sim_h.topo, table, steps,
                         seg_backend="jax")
    fleet = FleetAgent(SimFleetPort(sim_h), model2, k=2)
    for _ in range(8):
        sim_h.state, wstate = engine.run_interval(sim_h.state, wstate)
        fleet.tick()

    sim_f = _mixed_sim(seed=11)
    table_f, wstate_f = table_from_sim(sim_f)
    loop = FusedLoop(sim_f.params, sim_f.topo, steps, model2, k=2,
                     seg_backend="jax")
    result = loop.run(table_f, sim_f.state, wstate_f, 8)

    assert _traj(result.decisions) == _traj(fleet.decisions)
    np.testing.assert_array_equal(result.state.window_pages,
                                  sim_h.window_pages)
    _assert_counters_close(result.state, sim_h.state)


def test_fused_batch_matches_host_run_batch(dial_model):
    """run_batch(fused=True) — the vmapped whole-run dispatch — must
    reproduce the host per-interval batch loop on a disturbed scenario,
    including the per-element precompiled schedules."""
    from repro.lab.batch import run_batch, stack_scenarios
    from repro.lab.scenarios import build, get_scenario, variants

    spec = get_scenario("degraded_ost")
    specs = [spec] + variants(spec, 1, seed=3)

    b_h = stack_scenarios([build(s) for s in specs])
    f_h = run_batch(b_h, model=dial_model, seconds=3.0, interval=0.5)
    b_f = stack_scenarios([build(s) for s in specs])
    f_f = run_batch(b_f, model=dial_model, seconds=3.0, interval=0.5,
                    fused=True)

    assert _traj(f_f.decisions) == _traj(f_h.decisions)
    np.testing.assert_array_equal(np.asarray(b_f.state.window_pages),
                                  np.asarray(b_h.state.window_pages))
    np.testing.assert_array_equal(np.asarray(b_f.state.rpcs_in_flight),
                                  np.asarray(b_h.state.rpcs_in_flight))
    _assert_counters_close(b_f.state, b_h.state)


def test_host_ticks_continue_seamlessly_after_fused_run(dial_model):
    """A fused run followed by host ticks must equal an uninterrupted
    host run: ingest_fused restores the probe, the applied-θ view, AND
    the snapshot history, so the first post-fused tick still decides."""
    from repro.core.fleet import run_fleet
    from repro.pfs.engine_jax import FusedEngine
    from repro.pfs.workloads import table_from_sim

    sim_h = _mixed_sim(seed=21)
    f_h = run_fleet(sim_h, dial_model, seconds=4.0, interval=0.5,
                    backend="jax")

    sim_m = _mixed_sim(seed=21)
    f_m = run_fleet(sim_m, dial_model, seconds=2.0, interval=0.5,
                    backend="jax-fused")
    table, wstate = table_from_sim(sim_m)
    engine = FusedEngine(sim_m.params, sim_m.topo, table, 100,
                         seg_backend="auto")
    for _ in range(4):                       # continue on the host
        sim_m.state, wstate = engine.run_interval(sim_m.state, wstate)
        f_m.tick()

    assert _traj(f_m.decisions) == _traj(f_h.decisions)
    np.testing.assert_array_equal(sim_m.window_pages, sim_h.window_pages)
    _assert_counters_close(sim_m.state, sim_h.state)


def test_fused_batch_split_tuned_untuned_matches_host(dial_model):
    """An evaluate-style batch (one tuned element among static arms)
    exercises the split path: tuned elements through the decision loop,
    the rest through the engine-only fused run, states scattered back
    in element order and decision columns remapped."""
    from repro.lab.batch import run_batch, stack_scenarios
    from repro.lab.scenarios import build, get_scenario, variants

    spec = get_scenario("degraded_ost")
    specs = [spec] + variants(spec, 2, seed=5)
    n = spec.n_clients * spec.n_osts
    tune_cols = 1 * n + np.arange(n)          # tune only element 1

    b_h = stack_scenarios([build(s) for s in specs])
    f_h = run_batch(b_h, model=dial_model, seconds=3.0, interval=0.5,
                    tune_cols=tune_cols)
    b_f = stack_scenarios([build(s) for s in specs])
    f_f = run_batch(b_f, model=dial_model, seconds=3.0, interval=0.5,
                    tune_cols=tune_cols, fused=True)

    assert _traj(f_f.decisions) == _traj(f_h.decisions)
    # every decision column must belong to the tuned element
    for r in f_f.decisions:
        if len(r):
            assert ((r.oscs >= n) & (r.oscs < 2 * n)).all()
    np.testing.assert_array_equal(np.asarray(b_f.state.window_pages),
                                  np.asarray(b_h.state.window_pages))
    _assert_counters_close(b_f.state, b_h.state)


def test_fused_tune_mask_restricts_decisions(dial_model):
    """A tune mask must behave exactly like a FleetAgent over the same
    interface subset: untouched interfaces keep their knobs."""
    from repro.core.fleet import run_fleet

    oscs = np.array([0, 1, 2])
    sim_h = _mixed_sim(seed=7)
    f_h = run_fleet(sim_h, dial_model, oscs=oscs, seconds=3.0,
                    interval=0.5, backend="jax")
    sim_f = _mixed_sim(seed=7)
    f_f = run_fleet(sim_f, dial_model, oscs=oscs, seconds=3.0,
                    interval=0.5, backend="jax-fused")

    assert _traj(f_f.decisions) == _traj(f_h.decisions)
    np.testing.assert_array_equal(sim_f.window_pages, sim_h.window_pages)
    # everything outside the subset stayed at the initial setting
    assert (sim_f.window_pages[3:] == 64).all()
    assert (sim_f.rpcs_in_flight[3:] == 2).all()


# ---------------------------------------------------------------------- #
# Algorithm 1 property sweep: scalar == batch == in-jit JAX, row for row
# ---------------------------------------------------------------------- #
def _adversarial_rows():
    m = len(SPACE)
    tau = TunerParams().tau
    rows = [
        np.full(m, tau),                      # all exactly at tau: strict >
        np.full(m, 0.95),                     # all-keep
        np.full(m, 0.5),                      # none-keep
        np.full(m, 0.81),                     # all-keep exact ties
    ]
    r = np.zeros(m)
    r[7] = 0.9                                # single survivor: degenerate
    rows.append(r)                            # MinMax span in both dims
    r = np.zeros(m)
    r[[3, 17]] = 0.9                          # exact tie, first-max break
    rows.append(r)
    r = np.full(m, tau)
    r[::2] = np.nextafter(tau, 1.0)           # straddling tau by 1 ulp
    rows.append(r)
    r = np.zeros(m)
    r[-1] = np.nextafter(tau, 1.0)            # lone marginal survivor
    rows.append(r)
    rng = np.random.default_rng(0)
    for _ in range(6):                        # randomized fill
        rows.append(rng.uniform(0.0, 1.0, size=m))
    return rows


def test_alg1_scalar_batch_jnp_agree_on_adversarial_rows():
    from repro.pfs.loop_jax import conditional_score_greedy_jnp

    rows = _adversarial_rows()
    configs = SPACE.configs()
    currents = [configs[(3 * i) % len(configs)] for i in range(len(rows))]
    for op in (READ, WRITE):
        probs = np.stack(rows)
        ops = np.full(len(rows), op)
        current = np.asarray(currents)
        batch = conditional_score_greedy_batch(probs, ops, current)
        theta_j, changed_j, ncand_j, score_j = conditional_score_greedy_jnp(
            probs, ops, current)
        for i, row in enumerate(rows):
            scalar = conditional_score_greedy(row, op, currents[i])
            got = batch.one(i)
            assert got.theta == scalar.theta, (op, i)
            assert got.changed == scalar.changed, (op, i)
            assert got.n_candidates == scalar.n_candidates, (op, i)
            assert got.score == pytest.approx(scalar.score, abs=0), (op, i)
            assert tuple(theta_j[i]) == scalar.theta, (op, i)
            assert bool(changed_j[i]) == scalar.changed, (op, i)
            assert int(ncand_j[i]) == scalar.n_candidates, (op, i)
            np.testing.assert_allclose(score_j[i], scalar.score,
                                       rtol=1e-12, err_msg=str((op, i)))


def test_alg1_tau_is_strict_and_keeps_current():
    """Probabilities exactly at τ must not survive (paper line 4 uses
    strict >): the tuner keeps the current θ and reports 0 candidates."""
    from repro.pfs.loop_jax import conditional_score_greedy_jnp

    m = len(SPACE)
    tau = TunerParams().tau
    probs = np.full((1, m), tau)
    current = np.array([[64, 4]])
    for op in (READ, WRITE):
        d = conditional_score_greedy_batch(probs, [op], current).one(0)
        assert d.theta == (64, 4) and not d.changed and d.n_candidates == 0
        theta_j, changed_j, ncand_j, _ = conditional_score_greedy_jnp(
            probs, np.array([op]), current)
        assert tuple(theta_j[0]) == (64, 4)
        assert not changed_j[0] and ncand_j[0] == 0


# ---------------------------------------------------------------------- #
# bugfix regressions
# ---------------------------------------------------------------------- #
def test_no_tunerparams_instance_evaluated_at_import_time():
    """PR-4 review convention: no call site may bake a shared TunerParams
    instance into its signature — defaults must be None-then-instantiate."""
    import repro.core.agent as agent
    import repro.core.fleet as fleet
    import repro.core.tuner as tuner
    import repro.lab.batch as batch
    import repro.lab.evaluate as evaluate

    fns = [agent.DIALAgent.__init__, agent.ReferenceLoopAgent.__init__,
           agent.run_with_agents, agent.run_with_loop_agents,
           fleet.FleetAgent.__init__, fleet.run_fleet,
           tuner.conditional_score_greedy,
           tuner.conditional_score_greedy_batch,
           batch.run_batch, evaluate.evaluate_scenario]
    for fn in fns:
        for p in inspect.signature(fn).parameters.values():
            assert not isinstance(p.default, TunerParams), fn.__qualname__


def test_agents_do_not_share_default_tuner_params(dial_model):
    from repro.core.fleet import FleetAgent, SimFleetPort

    a = FleetAgent(SimFleetPort(_mixed_sim()), dial_model)
    b = FleetAgent(SimFleetPort(_mixed_sim()), dial_model)
    assert a.tuner_params == b.tuner_params          # same frozen values
    assert a.tuner_params is not b.tuner_params      # never one instance


def test_gated_ticks_return_fresh_results_and_align_decisions(dial_model):
    """Every tick appends exactly one (fresh) record, so decisions[i]
    is interval i — and no two agents can alias one mutable empty."""
    from repro.core.fleet import FleetAgent, SimFleetPort

    a = FleetAgent(SimFleetPort(_mixed_sim(seed=1)), dial_model)
    b = FleetAgent(SimFleetPort(_mixed_sim(seed=1)), dial_model)
    ra, rb = a.tick(), b.tick()          # warmup ticks: gated, empty
    assert len(ra) == len(rb) == 0
    assert ra is not rb
    assert ra.oscs is not rb.oscs
    assert ra.decisions.theta is not rb.decisions.theta

    sim = _mixed_sim(seed=2)
    fleet = FleetAgent(SimFleetPort(sim), dial_model)
    steps = int(round(0.5 / sim.params.tick))
    for _ in range(6):
        for _ in range(steps):
            sim.step()
        fleet.tick()
    assert len(fleet.decisions) == fleet._ticks == 6
    # warmup intervals (ticks 1..3 for warmup=2, k=1) recorded as empty
    assert all(len(r) == 0 for r in fleet.decisions[:3])
    assert any(len(r) for r in fleet.decisions[3:])


class _BelowTauModel:
    """Stub model: no configuration ever clears τ, so Algorithm 1 always
    keeps `current` — which makes the decision record an exact witness
    of what the agent believes is applied."""

    backend = "numpy"

    def score_fleet(self, x_read, x_write):
        return np.zeros(len(x_read)), np.zeros(len(x_write))


class _NullScalarModel(_BelowTauModel):
    """Per-interface surface of the stub (for ReferenceLoopAgent)."""

    def score_space(self, history, op):
        from repro.core.config_space import SPACE
        return np.zeros(len(SPACE))


@pytest.mark.parametrize("kind", ["fleet", "loop"])
def test_decision_sees_out_of_band_knob_change(kind):
    """Flipping knobs behind the agent's back (ε-greedy exploration,
    campaign alternation) must be visible to the next decision's
    `current` — both agents derive it from the probe, not a shadow."""
    from repro.core.agent import ReferenceLoopAgent, SimClientPort
    from repro.core.fleet import FleetAgent, SimFleetPort

    sim = _mixed_sim(seed=3)
    if kind == "fleet":
        agents = [FleetAgent(SimFleetPort(sim), _BelowTauModel())]
        results = lambda r: [r.decisions.one(i) for i in range(len(r))]
    else:
        agents = [ReferenceLoopAgent(SimClientPort(sim, c),
                                     _NullScalarModel())
                  for c in range(sim.n_clients)]
        results = lambda r: [d for _, _, d in r]
    steps = int(round(0.5 / sim.params.tick))
    for _ in range(4):                       # through warmup + history
        for _ in range(steps):
            sim.step()
        for a in agents:
            a.tick()

    # out-of-band flip, as lab/continual.py exploration does
    sim.set_knobs(np.arange(sim.n_osc), window_pages=256, rpcs_in_flight=8)
    seen = 0
    for _ in range(6):
        for _ in range(steps):
            sim.step()
        for a in agents:
            for d in results(a.tick()):
                assert d.theta == (256, 8), "stale current θ"
                assert not d.changed
                seen += 1
    assert seen > 0, "no decidable rows after the flip; test is vacuous"
