"""Fleet path: batched probing/metrics/tuning must match the per-agent
loop exactly — same simulator trace, same seeds, same knob trajectory."""

import numpy as np
import pytest

from repro.core.config_space import SPACE
from repro.core.metrics import snapshot, snapshot_all
from repro.core.tuner import (TunerParams, conditional_score_greedy,
                              conditional_score_greedy_batch)
from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.stats import probe, probe_all, stack_stats
from repro.pfs.workloads import random_stream, sequential_stream


def _busy_sim(seed=11):
    sim = PFSSim(n_clients=2, n_osts=2, seed=seed)
    sim.attach(sequential_stream(0, READ, 4 * 2**20, ost=0))
    sim.attach(random_stream(0, WRITE, 64 * 1024, ost=1, n_threads=2))
    sim.attach(sequential_stream(1, WRITE, 2 * 2**20, ost=0, n_threads=2))
    sim.attach(random_stream(1, READ, 256 * 1024, ost=1))
    return sim


# ---------------------------------------------------------------------- #
# probing + metrics: stacked arrays == per-interface scalars, bit for bit
# ---------------------------------------------------------------------- #
def test_probe_all_matches_probe():
    sim = _busy_sim()
    sim.run(0.5)
    fleet = probe_all(sim)
    for i in range(sim.n_osc):
        one = probe(sim, i)
        col = fleet.one(i)
        for field in ("bytes_done", "rpcs_sent", "rpc_bytes", "latency_sum",
                      "req_bytes", "pending_integral", "active_integral",
                      "randomness"):
            np.testing.assert_array_equal(getattr(col, field),
                                          getattr(one, field), err_msg=field)
        assert (col.cache_hit_bytes, col.block_time, col.window_pages,
                col.rpcs_in_flight) == (one.cache_hit_bytes, one.block_time,
                                        one.window_pages, one.rpcs_in_flight)


def test_snapshot_all_matches_snapshot_bitwise():
    sim = _busy_sim()
    prev_f = probe_all(sim)
    prev_s = [probe(sim, i) for i in range(sim.n_osc)]
    sim.run(0.5)
    cur_f = probe_all(sim)
    fleet = snapshot_all(prev_f, cur_f)
    for i in range(sim.n_osc):
        s = snapshot(prev_s[i], probe(sim, i))
        np.testing.assert_array_equal(fleet.read[i], s.read)
        np.testing.assert_array_equal(fleet.write[i], s.write)
        assert fleet.read_volume[i] == s.read_volume
        assert fleet.write_volume[i] == s.write_volume


def test_stack_stats_round_trips_probe_all():
    sim = _busy_sim()
    sim.run(0.3)
    ids = np.arange(sim.n_osc)
    stacked = stack_stats([probe(sim, int(i)) for i in ids], ids)
    direct = probe_all(sim, ids)
    np.testing.assert_array_equal(stacked.bytes_done, direct.bytes_done)
    np.testing.assert_array_equal(stacked.window_pages, direct.window_pages)
    np.testing.assert_array_equal(stacked.dirty_integral,
                                  direct.dirty_integral)


# ---------------------------------------------------------------------- #
# Algorithm 1, batched == scalar per row
# ---------------------------------------------------------------------- #
def test_batch_tuner_matches_scalar_rows():
    rng = np.random.default_rng(3)
    m = 64
    configs = SPACE.configs()
    probs = rng.uniform(0.0, 1.0, size=(m, len(SPACE)))
    probs[:8] = 0.5                      # rows where nothing clears tau
    ops = rng.integers(0, 2, size=m)
    current = np.array([configs[j] for j in
                        rng.integers(0, len(configs), size=m)])
    params = TunerParams()
    batch = conditional_score_greedy_batch(probs, ops, current,
                                           SPACE, params)
    for i in range(m):
        want = conditional_score_greedy(probs[i], int(ops[i]),
                                        (int(current[i, 0]),
                                         int(current[i, 1])),
                                        SPACE, params)
        got = batch.one(i)
        assert got.theta == want.theta, i
        assert got.changed == want.changed, i
        assert got.n_candidates == want.n_candidates, i
        assert got.score == pytest.approx(want.score, abs=0), i


def test_batch_tuner_tie_break_matches_scalar():
    """Exact ties must resolve to the same (first-max) config."""
    probs = np.full((1, len(SPACE)), 0.9)
    for op in (READ, WRITE):
        got = conditional_score_greedy_batch(
            probs, np.array([op]), np.array([[256, 8]])).one(0)
        want = conditional_score_greedy(probs[0], op, (256, 8))
        assert got.theta == want.theta


# ---------------------------------------------------------------------- #
# end-to-end: fleet trajectory == per-agent loop trajectory
# ---------------------------------------------------------------------- #
def test_fleet_matches_loop_agents_trajectory(dial_model):
    """Same seeds, same workloads: the batched fleet and the per-agent
    Python loop must produce the identical decision sequence and knob
    trajectory (the tentpole equivalence guarantee)."""
    from repro.core.agent import ReferenceLoopAgent, SimClientPort
    from repro.core.fleet import FleetAgent, SimFleetPort

    def build():
        sim = _busy_sim(seed=5)
        sim.set_knobs(np.arange(sim.n_osc), window_pages=64,
                      rpcs_in_flight=2)
        return sim

    sim_l = build()
    loop = [ReferenceLoopAgent(SimClientPort(sim_l, c), dial_model)
            for c in range(2)]
    sim_f = build()
    fleet = FleetAgent(SimFleetPort(sim_f), dial_model)

    steps = int(round(0.5 / sim_l.params.tick))
    for _ in range(10):
        for _ in range(steps):
            sim_l.step()
            sim_f.step()
        loop_tick = []
        for a in loop:
            loop_tick.extend(a.tick())
        fleet_tick = fleet.tick().as_list()
        assert len(loop_tick) == len(fleet_tick)
        for (lo, lop, ld), (fo, fop, fd) in zip(loop_tick, fleet_tick):
            assert (lo, lop) == (fo, fop)
            assert ld.theta == fd.theta
            assert ld.changed == fd.changed
            assert ld.n_candidates == fd.n_candidates
            np.testing.assert_array_equal(ld.probs, fd.probs)
        # knobs applied identically -> identical traces going forward
        np.testing.assert_array_equal(sim_l.window_pages, sim_f.window_pages)
        np.testing.assert_array_equal(sim_l.rpcs_in_flight,
                                      sim_f.rpcs_in_flight)


def test_dial_agent_adapter_matches_loop(dial_model):
    """DIALAgent (now a fleet adapter) must still equal the reference
    loop for a single client, through the generic ClientPort surface."""
    from repro.core.agent import DIALAgent, ReferenceLoopAgent, SimClientPort

    def run(cls):
        sim = PFSSim(n_clients=1, n_osts=2, seed=9)
        sim.attach(sequential_stream(0, READ, 8 * 2**20, ost=0))
        sim.set_knobs(sim.client_oscs(0), window_pages=16, rpcs_in_flight=1)
        agent = cls(SimClientPort(sim, 0), dial_model)
        steps = int(round(0.5 / sim.params.tick))
        out = []
        for _ in range(8):
            for _ in range(steps):
                sim.step()
            out.extend((o, op, d.theta, d.changed) for o, op, d in
                       agent.tick())
        return out, sim.window_pages.copy(), sim.rpcs_in_flight.copy()

    dec_l, win_l, rif_l = run(ReferenceLoopAgent)
    dec_f, win_f, rif_f = run(DIALAgent)
    assert dec_l == dec_f
    np.testing.assert_array_equal(win_l, win_f)
    np.testing.assert_array_equal(rif_l, rif_f)


def test_fleet_jax_backend_matches_numpy_decisions(dial_model):
    """The fused single-launch predictor must not change any decision."""
    import copy

    from repro.core.fleet import FleetAgent, SimFleetPort

    def run(backend):
        model = copy.copy(dial_model)
        model.backend = backend
        model.__post_init__()
        sim = _busy_sim(seed=13)
        fleet = FleetAgent(SimFleetPort(sim), model)
        steps = int(round(0.5 / sim.params.tick))
        out = []
        for _ in range(6):
            for _ in range(steps):
                sim.step()
            r = fleet.tick()
            out.append((r.oscs.tolist(), r.ops.tolist(),
                        r.decisions.theta.tolist()))
        return out

    assert run("numpy") == run("jax")


# ---------------------------------------------------------------------- #
# paired-forest kernel vs refs
# ---------------------------------------------------------------------- #
def test_paired_forest_kernel_matches_split_forests():
    import jax.numpy as jnp

    from repro.core.gbdt import GBDTClassifier, GBDTParams
    from repro.kernels.gbdt_forest.kernel import paired_forest_margin
    from repro.kernels.gbdt_forest.ops import pair_forests
    from repro.kernels.gbdt_forest.ref import paired_forest_margin_ref

    rng = np.random.default_rng(0)
    Xr = rng.normal(size=(1500, 10))
    fr = GBDTClassifier(GBDTParams(n_trees=12, max_depth=3)).fit(
        Xr, (Xr[:, 0] > 0).astype(float)).forest
    Xw = rng.normal(size=(1500, 14))
    fw = GBDTClassifier(GBDTParams(n_trees=20, max_depth=5)).fit(
        Xw, (Xw[:, 1] * Xw[:, 2] > 0).astype(float)).forest

    feature, threshold, leaf, base, depth, n_feat = pair_forests(fr, fw)
    n = 100
    x = np.zeros((n, n_feat), dtype=np.float32)
    op = rng.integers(0, 2, size=n).astype(np.int32)
    xr = rng.normal(size=(n, 10)).astype(np.float32)
    xw = rng.normal(size=(n, 14)).astype(np.float32)
    x[op == 0, :10] = xr[op == 0]
    x[op == 1, :14] = xw[op == 1]

    args = (jnp.asarray(x), jnp.asarray(op), jnp.asarray(feature),
            jnp.asarray(threshold), jnp.asarray(leaf), jnp.asarray(base))
    ref = np.asarray(paired_forest_margin_ref(*args, depth))
    pal = np.asarray(paired_forest_margin(*args, depth, block_n=64))
    np.testing.assert_allclose(ref, pal, rtol=1e-5, atol=1e-5)
    # and against the unpadded numpy oracles
    want = np.where(op == 0, fr.predict_margin(x[:, :10]),
                    fw.predict_margin(x[:, :14]))
    np.testing.assert_allclose(ref, want, rtol=1e-4, atol=1e-4)
