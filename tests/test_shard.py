"""Sharded fused loop: mesh helpers, padding, donation, and the
sharded-vs-single-device equivalence (subprocess with 8 forced host
devices — conftest keeps the in-process tests on the real device set).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# the tiny-synthetic-model + scenario-batch prelude every subprocess
# shares: fast to fit, exercises both forests, decisions still fire
PRELUDE = """
import numpy as np
from repro.core.gbdt import GBDTClassifier, GBDTParams
from repro.core.metrics import feature_dim
from repro.core.model import DIALModel
from repro.pfs.state import READ, WRITE

rng = np.random.default_rng(0)
def _forest(dim):
    x = rng.normal(size=(400, dim)).astype(np.float32)
    y = (x[:, 0] + x[:, -1] > -1.0).astype(np.int64)
    return GBDTClassifier(GBDTParams(n_trees=8, max_depth=3)).fit(x, y).forest
k = 1
model = DIALModel(read_forest=_forest(feature_dim(READ, k)),
                  write_forest=_forest(feature_dim(WRITE, k)),
                  backend="jax", k=k)

def traj(decisions):
    return [(i, int(o), int(op), int(t[0]), int(t[1]))
            for i, r in enumerate(decisions)
            for o, op, t in zip(r.oscs, r.ops, r.decisions.theta)]
"""


# ---------------------------------------------------------------------- #
# helpers: mesh construction + pad/unpad (single device, in process)
# ---------------------------------------------------------------------- #
def test_fleet_mesh_single_device():
    from repro.distributed.sharding import FLEET_AXIS, fleet_mesh
    from repro.launch.mesh import make_fleet_mesh

    m = fleet_mesh()
    assert m.axis_names == (FLEET_AXIS,)
    assert m.devices.size >= 1
    assert make_fleet_mesh(1).devices.size == 1


def test_fleet_mesh_too_many_devices_raises():
    import jax

    from repro.distributed.sharding import fleet_mesh

    with pytest.raises(ValueError, match="force host devices"):
        fleet_mesh(jax.device_count() + 1)


def test_pad_unpad_roundtrip():
    from repro.distributed.sharding import (fleet_batch_size, pad_fleet,
                                            unpad_fleet)

    tree = {"a": np.arange(30.0).reshape(5, 3, 2), "b": np.arange(5)}
    assert fleet_batch_size(tree) == 5
    padded, n_pad = pad_fleet(tree, 4)
    assert n_pad == 3
    assert padded["a"].shape == (8, 3, 2)
    # phantom rows replicate element 0
    np.testing.assert_array_equal(padded["a"][5:],
                                  np.repeat(tree["a"][:1], 3, axis=0))
    back = unpad_fleet(padded, n_pad)
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"], tree["b"])
    # already divisible: no copy-shaped change
    same, n0 = pad_fleet(tree, 5)
    assert n0 == 0 and same["a"].shape == (5, 3, 2)


def test_fused_loop_mesh_requires_batched():
    from repro.distributed.sharding import fleet_mesh
    from repro.lab.scenarios import SCENARIOS, build
    from repro.pfs.loop_jax import FusedLoop

    b = build(SCENARIOS["degraded_ost"])
    with pytest.raises(ValueError, match="batched=True"):
        FusedLoop(b.params, b.topo, 10, None, tuned=False,
                  mesh=fleet_mesh(1))


def test_run_batch_mesh_requires_fused():
    from repro.distributed.sharding import fleet_mesh
    from repro.lab.batch import run_batch, stack_scenarios
    from repro.lab.scenarios import SCENARIOS, build

    batch = stack_scenarios([build(SCENARIOS["degraded_ost"])])
    with pytest.raises(ValueError, match="fused=True"):
        run_batch(batch, None, seconds=1.0, mesh=fleet_mesh(1))


def test_run_fleet_mesh_needs_sharded_backend():
    from repro.core.fleet import run_fleet
    from repro.distributed.sharding import fleet_mesh
    from repro.pfs import PFSSim

    sim = PFSSim(n_clients=2, n_osts=2, seed=0)
    with pytest.raises(ValueError, match="jax-sharded"):
        run_fleet(sim, None, seconds=1.0, backend="numpy",
                  mesh=fleet_mesh(1))


# ---------------------------------------------------------------------- #
# 8 forced host devices: equivalence, padding, donation (subprocess)
# ---------------------------------------------------------------------- #
def test_sharded_matches_single_device_8dev():
    """Mixed disturbed batch on an 8-device mesh: θ trajectories exactly
    equal to the single-device fused dispatch, probe counters ≤1e-6."""
    out = run_py(PRELUDE + """
import jax
from repro.distributed.sharding import fleet_mesh
from repro.lab.batch import run_batch, stack_scenarios
from repro.lab.scenarios import SCENARIOS, build, variants

assert jax.device_count() == 8
spec = SCENARIOS["failing_ost"]
ba = stack_scenarios([build(s) for s in variants(spec, 8, seed=2)])
bb = stack_scenarios([build(s) for s in variants(spec, 8, seed=2)])
ra = run_batch(ba, model, seconds=4.0, interval=0.5, fused=True)
rb = run_batch(bb, model, seconds=4.0, interval=0.5, fused=True,
               mesh=fleet_mesh(8))
ta, tb = traj(ra.decisions), traj(rb.decisions)
assert ta == tb, (len(ta), len(tb))
assert len(tb) > 0, "batch never decided — test is vacuous"
for f in ("ctr_bytes_done", "ctr_rpcs_sent", "ctr_latency_sum",
          "ctr_pending_integral", "ctr_block_time"):
    np.testing.assert_allclose(np.asarray(getattr(ba.state, f)),
                               np.asarray(getattr(bb.state, f)),
                               rtol=1e-6, err_msg=f)
print("OK", len(tb))
""")
    assert "OK" in out


def test_sharded_padding_non_divisible_8dev():
    """B=5 on a 4-device mesh: padded to 8, phantom elements masked out,
    outputs sliced back — results equal the unsharded run."""
    out = run_py(PRELUDE + """
from repro.distributed.sharding import fleet_mesh
from repro.lab.batch import run_batch, stack_scenarios
from repro.lab.scenarios import SCENARIOS, build, variants

spec = SCENARIOS["noisy_neighbor"]
ba = stack_scenarios([build(s) for s in variants(spec, 5, seed=3)])
bb = stack_scenarios([build(s) for s in variants(spec, 5, seed=3)])
ra = run_batch(ba, model, seconds=4.0, interval=0.5, fused=True)
rb = run_batch(bb, model, seconds=4.0, interval=0.5, fused=True,
               mesh=fleet_mesh(4))
assert traj(ra.decisions) == traj(rb.decisions)
# every output came back at the caller's batch size, not the padded one
for tree in (rb.state, rb.wstate, rb.trace, rb.hist):
    import jax
    for leaf in jax.tree.leaves(tree):
        assert np.asarray(leaf).shape[0] == 5, np.asarray(leaf).shape
np.testing.assert_allclose(np.asarray(ba.state.ctr_bytes_done),
                           np.asarray(bb.state.ctr_bytes_done), rtol=1e-6)
# no decision ever references a phantom element's fleet column
n = ba.n_osc
assert all(int(o) < 5 * n for r in rb.decisions for o in r.oscs)
print("OK")
""")
    assert "OK" in out


def test_run_fleet_jax_sharded_matches_fused_8dev():
    """run_fleet(backend='jax-sharded') pins to jax-fused: same θ
    trajectory, same counters, and host ticks continue seamlessly after
    the fused run (history ring adopted)."""
    out = run_py(PRELUDE + """
import sys
sys.path.insert(0, "tests")
import test_loop_fused as tlf
from repro.core.fleet import run_fleet

sim_a, sim_b = tlf._mixed_sim(0), tlf._mixed_sim(0)
fa = run_fleet(sim_a, model, seconds=4.0, interval=0.5,
               backend="jax-fused", seg_backend="jax")
fb = run_fleet(sim_b, model, seconds=4.0, interval=0.5,
               backend="jax-sharded", seg_backend="jax")
assert tlf._traj(fa.decisions) == tlf._traj(fb.decisions)
assert sum(len(r.oscs) for r in fb.decisions) > 0
tlf._assert_counters_close(sim_a.state, sim_b.state, rtol=1e-6)
for _ in range(100):
    sim_a.step()
for _ in range(100):
    sim_b.step()
fa.tick(); fb.tick()
assert tlf._traj(fa.decisions) == tlf._traj(fb.decisions)
print("OK")
""")
    assert "OK" in out


def test_donation_consumes_state_buffers_8dev():
    """donate_argnums really donates: pre-sharded state/wstate buffers
    are consumed by the dispatch (no silent resharding copy doubling
    peak memory); the un-donated table stays alive."""
    out = run_py(PRELUDE + """
import jax
from jax.experimental import enable_x64
from repro.distributed.sharding import fleet_mesh, fleet_sharding
from repro.lab.batch import stack_scenarios
from repro.lab.scenarios import SCENARIOS, build, variants
from repro.pfs.loop_jax import FusedLoop

mesh = fleet_mesh(8)
batch = stack_scenarios(
    [build(s) for s in variants(SCENARIOS["degraded_ost"], 8, seed=1)])
loop = FusedLoop(batch.params, batch.topo, 20, model, seg_backend="jax",
                 batched=True, mesh=mesh)
sched = loop._shape_schedule(batch.schedule(0, 2 * 20), 2)
with enable_x64():
    sh = fleet_sharding(mesh)
    jargs = jax.tree.map(lambda a: jax.device_put(np.asarray(a), sh),
                         (batch.table, batch.state, batch.wstate, sched,
                          np.ones((8, batch.n_osc), dtype=bool)))
    out = loop._run(*jargs)
    jax.block_until_ready(out)
    assert all(x.is_deleted() for x in jax.tree.leaves(jargs[1])), \\
        "SimState inputs survived the dispatch — donation didn't happen"
    assert all(x.is_deleted() for x in jax.tree.leaves(jargs[2])), \\
        "WorkloadState inputs survived the dispatch"
    assert not any(x.is_deleted() for x in jax.tree.leaves(jargs[0])), \\
        "table was donated but is not in donate_argnums"
print("OK")
""")
    assert "OK" in out


def test_donation_consumes_state_buffers_single_device():
    """The unsharded jit path donates too (default device placement)."""
    out = run_py(PRELUDE + """
import jax
from jax.experimental import enable_x64
import jax.numpy as jnp
from repro.lab.batch import stack_scenarios
from repro.lab.scenarios import SCENARIOS, build, variants
from repro.pfs.loop_jax import FusedLoop

batch = stack_scenarios(
    [build(s) for s in variants(SCENARIOS["degraded_ost"], 4, seed=1)])
loop = FusedLoop(batch.params, batch.topo, 20, model, seg_backend="jax",
                 batched=True)
sched = loop._shape_schedule(batch.schedule(0, 2 * 20), 2)
with enable_x64():
    jargs = jax.tree.map(jnp.asarray,
                         (batch.table, batch.state, batch.wstate, sched,
                          np.ones((4, batch.n_osc), dtype=bool)))
    out = loop._run(*jargs)
    jax.block_until_ready(out)
    assert all(x.is_deleted() for x in jax.tree.leaves(jargs[1]))
    assert all(x.is_deleted() for x in jax.tree.leaves(jargs[2]))
print("OK")
""", devices=1)
    assert "OK" in out


def test_fuzz_mesh_matches_unmeshed_report_8dev():
    """A smoke fuzz sweep through --mesh produces the same triage as the
    single-device sweep on the same seed and model (same mesh caveat as
    PR 6: comparisons hold within one mesh shape; this pins 8-dev vs
    1-dev on the smoke config's tame scenario population)."""
    out = run_py(PRELUDE + """
import dataclasses
from repro.distributed.sharding import fleet_mesh
from repro.lab.fuzz import SMOKE, run_sweep

cfg = dataclasses.replace(SMOKE, n_scenarios=8, seconds=2.0)
ra = run_sweep(cfg, model)
rb = run_sweep(cfg, model, mesh=fleet_mesh(8))
sa, sb = ra["summary"], rb["summary"]
assert [r["fingerprint"] for r in ra["scenarios"]] == \\
       [r["fingerprint"] for r in rb["scenarios"]]
# counts match exactly; throughput fractions to float tolerance (XLA
# may fuse the per-shard program differently than the full-batch one)
for key in ("n_scenarios", "n_buckets", "n_unique_specs", "n_losses"):
    assert sa[key] == sb[key], (key, sa, sb)
import numpy as _np
_np.testing.assert_allclose(
    [r["dial_frac_of_best_static"] for r in ra["scenarios"]],
    [r["dial_frac_of_best_static"] for r in rb["scenarios"]], rtol=1e-6)
print("OK", sa["n_scenarios"])
""")
    assert "OK" in out


def test_weak_scaling_benchmark_smoke_8dev():
    """The headline benchmark runs end to end (quick mode) and reports a
    parsable weak-scaling curve."""
    import json

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "benchmarks", "fleet_weak_scaling.py"),
         "--quick", "--json", "--max-fleet", "512"],
        capture_output=True, text=True, env=env, timeout=600, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["schema"] == "dial-weak-scaling-v1"
    assert [p["devices"] for p in r["points"]] == [1, 2]
    assert all(p["if_intervals_per_s"] > 0 for p in r["points"])
    assert r["max_fleet"]["interfaces"] >= 512
