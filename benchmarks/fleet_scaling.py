"""Fleet scaling: amortized per-interface tuning cost, 16 -> 512 clients.

The paper's Table III prices one tuning round at ~10-13.5 ms *per OSC
interface* — fine for one client, but a fleet of hundreds of clients
re-pays the Python/probe/model-entry overhead per interface every
interval.  This sweep drives identical simulator traces with

    loop   one :class:`ReferenceLoopAgent` per client (the paper's
           measured implementation: probe + model launch per interface);
    fleet  one :class:`FleetAgent` over every interface (one stacked
           probe, one fused model launch, one batched Algorithm 1).

and reports wall-clock per interface per tuning tick.  Decisions are
identical (tests/test_fleet.py); only the execution schedule differs, so
the gap is pure overhead amortization — and it must widen with scale.

Run:  PYTHONPATH=src python benchmarks/fleet_scaling.py [--quick]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core.agent import ReferenceLoopAgent, SimClientPort
from repro.core.fleet import FleetAgent, SimFleetPort
from repro.core.model import DIALModel
from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import random_stream, sequential_stream

WARMUP_TICKS = 3   # agent warmup (2) + history fill (k=1)
TIMED_TICKS = 4
INTERVAL = 0.5     # paper probe interval [s]


def get_model(backend: str) -> DIALModel:
    try:
        model = DIALModel.load("models/dial", backend=backend)
        print("loaded pretrained forests from models/dial.*")
    except FileNotFoundError:
        from repro.core.dataset import CollectConfig, collect, train_models
        from repro.core.gbdt import GBDTParams

        print("training a quick model (no models/dial.* found)...")
        data = collect(CollectConfig(seconds=25.0, reps=1))
        model = train_models(data, GBDTParams(n_trees=40, max_depth=5))
        model.backend = backend
    return model


def build_sim(n_clients: int, n_osts: int, seed: int = 1) -> PFSSim:
    sim = PFSSim(n_clients=n_clients, n_osts=n_osts, seed=seed)
    for c in range(n_clients):
        # alternate op so both models stay hot; stripe over the OSTs
        if c % 2 == 0:
            sim.attach(sequential_stream(c, READ, 4 * 2**20, ost=c % n_osts))
        else:
            sim.attach(random_stream(c, WRITE, 256 * 1024, ost=c % n_osts,
                                     n_threads=2))
    sim.set_knobs(np.arange(sim.n_osc), window_pages=64, rpcs_in_flight=2)
    return sim


def _drive(sim, tick_fns, steps: int) -> float:
    """Advance ``WARMUP_TICKS + TIMED_TICKS`` intervals; return the total
    wall-clock seconds spent inside agent ticks after warmup."""
    spent = 0.0
    for interval in range(WARMUP_TICKS + TIMED_TICKS):
        for _ in range(steps):
            sim.step()
        t0 = time.perf_counter()
        for fn in tick_fns:
            fn()
        dt = time.perf_counter() - t0
        if interval >= WARMUP_TICKS:
            spent += dt
    return spent


def bench(n_clients: int, n_osts: int, model: DIALModel) -> dict:
    n_osc = n_clients * n_osts

    sim_l = build_sim(n_clients, n_osts)
    steps = int(round(INTERVAL / sim_l.params.tick))
    loop = [ReferenceLoopAgent(SimClientPort(sim_l, c), model)
            for c in range(n_clients)]
    t_loop = _drive(sim_l, [a.tick for a in loop], steps)

    sim_f = build_sim(n_clients, n_osts)
    fleet = FleetAgent(SimFleetPort(sim_f), model)
    t_fleet = _drive(sim_f, [fleet.tick], steps)

    per = lambda t: t / TIMED_TICKS / n_osc * 1e3
    return {"n_clients": n_clients, "n_osc": n_osc,
            "loop_ms": per(t_loop), "fleet_ms": per(t_fleet),
            "speedup": t_loop / max(t_fleet, 1e-12)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, nargs="*",
                    default=[16, 64, 128, 256, 512])
    ap.add_argument("--osts", type=int, default=2,
                    help="OSTs (= OSC interfaces per client)")
    ap.add_argument("--backend", default="numpy",
                    choices=("numpy", "jax", "pallas"),
                    help="model backend (pallas = interpret mode on CPU)")
    ap.add_argument("--quick", action="store_true",
                    help="sweep 16..128 clients only")
    args = ap.parse_args()
    clients = [c for c in args.clients if c <= 128] if args.quick \
        else args.clients

    model = get_model(args.backend)
    print(f"\nbackend={model.backend}  interval={INTERVAL}s  "
          f"timed ticks={TIMED_TICKS}  (ms per interface per tuning tick)")
    print(f"{'clients':>8} {'oscs':>6} {'loop':>10} {'fleet':>10} "
          f"{'speedup':>8}")
    for c in clients:
        r = bench(c, args.osts, model)
        print(f"{r['n_clients']:>8} {r['n_osc']:>6} {r['loop_ms']:>9.3f}ms "
              f"{r['fleet_ms']:>9.3f}ms {r['speedup']:>7.1f}x")
    print("\npaper Table III prices the loop at 10-13.5 ms/interface on a "
          "16-core host;\nthe fleet path amortizes probe + launch overhead "
          "across the whole batch.")


if __name__ == "__main__":
    main()
