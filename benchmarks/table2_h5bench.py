"""Paper Table II: H5bench-style scientific workloads — DIAL vs optimal.

VPIC-IO (1/2/3-D contiguous array writes) and BDCATS-IO
(partial/strided/full reads).  'Optimal' is an exhaustive grid search over
the configuration space per workload (what the paper measured offline);
DIAL starts from Lustre defaults and tunes online.  The paper's claim:
DIAL lands within a few percent of optimal.
"""

from __future__ import annotations

from repro.core.agent import run_with_agents
from repro.core.config_space import SPACE
from repro.core.model import DIALModel
from repro.pfs import PFSSim
from repro.pfs.workloads import bdcats_read, vpic_write

SECONDS = 20.0


def _run(make_wl, window, inflight, tuned_model=None, seconds=SECONDS,
         seed=11):
    sim = PFSSim(n_clients=1, n_osts=8, seed=seed)
    wl = make_wl()
    sim.attach(wl)
    sim.set_knobs(sim.client_oscs(0), window_pages=window,
                  rpcs_in_flight=inflight)
    if tuned_model is not None:
        run_with_agents(sim, tuned_model, [0], seconds)
    else:
        sim.run(seconds)
    return wl.done_bytes(sim) / seconds / 1e6


def optimal(make_wl) -> tuple[float, tuple]:
    best, best_cfg = -1.0, None
    for w, f in SPACE.configs():
        t = _run(make_wl, w, f)
        if t > best:
            best, best_cfg = t, (w, f)
    return best, best_cfg


WORKLOADS = [
    ("VPIC-IO (1D array write)", lambda: vpic_write(0, 1)),
    ("VPIC-IO (2D array write)", lambda: vpic_write(0, 2)),
    ("VPIC-IO (3D array write)", lambda: vpic_write(0, 3)),
    ("BDCATS-IO (partial read)", lambda: bdcats_read(0, "partial")),
    ("BDCATS-IO (strided read)", lambda: bdcats_read(0, "strided")),
    ("BDCATS-IO (full read)", lambda: bdcats_read(0, "full")),
]


def run(model_path: str = "models/dial") -> list[dict]:
    model = DIALModel.load(model_path)
    rows = []
    for name, mk in WORKLOADS:
        opt, opt_cfg = optimal(mk)
        dial = _run(mk, 256, 8, tuned_model=model)   # from Lustre defaults
        rows.append({"workload": name, "optimal_mbs": round(opt, 1),
                     "optimal_cfg": opt_cfg, "dial_mbs": round(dial, 1),
                     "dial_frac_of_optimal": round(dial / opt, 3)})
    return rows


def main():
    for r in run():
        print(f"{r['workload']:28s} optimal={r['optimal_mbs']:8.1f} MB/s "
              f"(w={r['optimal_cfg'][0]},f={r['optimal_cfg'][1]})  "
              f"DIAL={r['dial_mbs']:8.1f} MB/s "
              f"({100 * r['dial_frac_of_optimal']:.1f}% of optimal)")


if __name__ == "__main__":
    main()
