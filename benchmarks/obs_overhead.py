"""Tracing overhead on the device-resident loop.

The telemetry contract is "free when off, cheap when on": the untraced
program is literally unchanged (trace records are additional scan
outputs, added only when a ``TraceConfig`` is passed at construction),
and at the default timeline stride the traced dispatch must stay within
a few percent of wall clock.  This benchmark pins the "cheap when on"
half: identical fused runs, untraced vs traced (decision provenance
only, and decisions + timeline at the default stride), compiled-program
execute time via double dispatch, compile time reported separately.

``overhead_pct`` at the default stride is the figure the perf ledger
guards (<= 10%); it rides ``benchmarks/run.py --json`` into
``BENCH_*.json`` and ``benchmarks/compare.py``.
"""

from __future__ import annotations

import time

import numpy as np

from repro.obs.schema import TraceConfig
from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import (random_stream, sequential_stream,
                                 table_from_sim)


def _sim(n_clients: int = 8, n_osts: int = 4):
    sim = PFSSim(n_clients=n_clients, n_osts=n_osts, seed=3)
    for c in range(n_clients):
        if c % 2 == 0:
            sim.attach(sequential_stream(c, READ, 2**20,
                                         ost=c % n_osts, n_threads=4))
        else:
            sim.attach(random_stream(c, WRITE, 64 * 1024,
                                     ost=c % n_osts, n_threads=4))
    return sim


def _time_loop(model, trace, seconds: float, interval: float,
               reps: int = 3) -> dict:
    """Best-of-``reps`` execute wall for one loop variant (first extra
    dispatch pays compilation, reported as ``compile_s``)."""
    from repro.pfs.loop_jax import FusedLoop

    proto = _sim()
    steps = max(int(round(interval / proto.params.tick)), 1)
    n_intervals = int(round(seconds / interval))
    loop = FusedLoop(proto.params, proto.topo, steps, model,
                     seg_backend="jax", trace=trace)
    walls = []
    for _ in range(reps + 1):
        s = _sim()
        table, wstate = table_from_sim(s)
        t0 = time.perf_counter()
        loop.run(table, s.state, wstate, n_intervals)
        walls.append(time.perf_counter() - t0)
    return {"execute_s": min(walls[1:]),
            "compile_s": walls[0] - min(walls[1:]),
            "n_intervals": n_intervals,
            "n_interfaces": proto.n_osc}


def bench(model=None, seconds: float = 20.0, interval: float = 0.5,
          stride: int = 20) -> dict:
    """Untraced vs traced fused runs; ``overhead_pct`` per variant."""
    if model is None:
        from repro.core.model import DIALModel
        model = DIALModel.load("models/dial")
        model.backend = "jax"

    base = _time_loop(model, None, seconds, interval)
    variants = {
        "decisions_only": TraceConfig(stride=stride, timeline=False),
        "default": TraceConfig(stride=stride, timeline=True),
    }
    out = {"untraced": base, "stride": stride}
    for name, cfg in variants.items():
        r = _time_loop(model, cfg, seconds, interval)
        r["overhead_pct"] = round(
            100.0 * (r["execute_s"] - base["execute_s"])
            / max(base["execute_s"], 1e-9), 2)
        out[name] = r
    return out


def main():
    res = bench()
    b = res["untraced"]
    print(f"untraced       : execute={b['execute_s']*1e3:8.1f} ms  "
          f"compile={b['compile_s']:.2f} s  "
          f"({b['n_intervals']} intervals x {b['n_interfaces']} interfaces)")
    for name in ("decisions_only", "default"):
        r = res[name]
        print(f"{name:15s}: execute={r['execute_s']*1e3:8.1f} ms  "
              f"compile={r['compile_s']:.2f} s  "
              f"overhead={r['overhead_pct']:+.1f}%")
    print(f"(timeline stride {res['stride']}; ledger guard: default "
          f"<= 10%)")


if __name__ == "__main__":
    main()
