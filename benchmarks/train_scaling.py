"""GBDT training throughput: vmapped jitted trainer vs the numpy loop.

The learn layer's claim is that model fitting no longer has to leave
the array program: a whole forest grows under ``jit`` (scan over trees,
unrolled level-synchronous depth loop, one-hot-matmul histograms) and a
``vmap`` trains a *batch* of forests — the read+write pair, or a whole
campaign hyperparameter sweep — in one launch.

This sweep builds B campaign-shaped datasets (smoke-campaign scale:
~384 rows x 32 designed-metric features per cell dataset, 32 quantile
bins — ample at ~12 rows/bin; both trainers bin identically) and times

    numpy     one ``GBDTClassifier.fit`` per dataset (the sequential
              oracle loop: Python over trees x depths x features);
    vmap      one ``fit_forest_batch`` launch for all B
              (``precision="fast"``: float32, the production online-
              refit configuration; compile excluded);
    vmap-x64  the same launch in ``precision="exact"`` (float64,
              split-for-split parity with the numpy loop),

reporting forests trained per wall-clock second (best of three timed
repetitions per path — the host is shared) and the speedup at each B.

Run:  PYTHONPATH=src python benchmarks/train_scaling.py [--quick] [--json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.gbdt import GBDTClassifier, GBDTParams
from repro.learn.boost import fit_forest_batch

N_ROWS = 384          # smoke-campaign-sized cell dataset
N_FEATURES = 32       # the read model's designed-metric dimension
PARAMS = GBDTParams(n_trees=40, max_depth=5, n_bins=32)
NUMPY_CAP = 8         # numpy forests actually fitted (cost extrapolated)
REPS = 3              # timed repetitions; best is reported


def _datasets(batch: int, n: int = N_ROWS, n_feat: int = N_FEATURES):
    """B synthetic campaign-shaped datasets (distinct nonlinear rules)."""
    out = []
    for i in range(batch):
        rng = np.random.default_rng(1000 + i)
        X = rng.normal(size=(n, n_feat))
        y = ((X[:, i % n_feat] + 0.5 * X[:, (i + 3) % n_feat] > 0.2)
             | (X[:, (i + 5) % n_feat] * X[:, (i + 7) % n_feat] > 0.9)
             ).astype(float)
        out.append((X, y))
    return out


def _best_of(fn, reps: int = REPS) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench(batch: int, params: GBDTParams = PARAMS) -> dict:
    datasets = _datasets(batch)

    # numpy loop (cap the measured forests; the loop is embarrassingly
    # linear in B, so the per-forest cost extrapolates exactly)
    n_np = min(batch, NUMPY_CAP)

    def numpy_loop():
        for X, y in datasets[:n_np]:
            GBDTClassifier(params).fit(X, y)

    t_numpy = _best_of(numpy_loop, reps=2) * batch / n_np

    # jitted vmap launches (compile excluded via one warm call each)
    fit_forest_batch(datasets, params, precision="fast")
    t_fast = _best_of(
        lambda: fit_forest_batch(datasets, params, precision="fast"))

    fit_forest_batch(datasets, params, precision="exact")
    t_exact = _best_of(
        lambda: fit_forest_batch(datasets, params, precision="exact"))

    return {
        "batch_size": batch,
        "n_rows": N_ROWS,
        "n_features": N_FEATURES,
        "numpy_forests_per_s": batch / t_numpy,
        "fast_forests_per_s": batch / t_fast,
        "exact_forests_per_s": batch / t_exact,
        "fast_speedup": t_numpy / t_fast,
        "exact_speedup": t_numpy / t_exact,
    }


def run(scales=(8, 16, 32)) -> list[dict]:
    return [bench(b) for b in scales]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batches", type=int, nargs="*", default=[8, 16, 32])
    ap.add_argument("--quick", action="store_true",
                    help="sweep 8..16 forests only")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    scales = ([b for b in args.batches if b <= 16] if args.quick
              else args.batches)

    print(f"forests/s, {PARAMS.n_trees} trees x depth {PARAMS.max_depth}, "
          f"{N_ROWS} rows x {N_FEATURES} features per dataset "
          f"(compile excluded)")
    print(f"{'B':>4} {'numpy f/s':>10} {'fast f/s':>9} {'exact f/s':>10} "
          f"{'fast x':>7} {'exact x':>8}")
    rows = []
    for b in scales:
        r = bench(b)
        rows.append(r)
        print(f"{r['batch_size']:>4} {r['numpy_forests_per_s']:>10.2f} "
              f"{r['fast_forests_per_s']:>9.2f} "
              f"{r['exact_forests_per_s']:>10.2f} "
              f"{r['fast_speedup']:>6.1f}x {r['exact_speedup']:>7.1f}x")
    if args.json:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
