"""Decision-loop scaling: per-interval host loop vs one jit per run.

The tuned simulator has three execution schedules for the same
algorithm (decisions are identical on all of them —
tests/test_loop_fused.py):

    host-numpy  the run_fleet default: Python tick loop for the engine,
                host probe/snapshot/featurize/Algorithm 1 per interval;
    host-jax    jitted engine interval scan, but the decision path still
                surfaces per interval (one device round trip + host
                numpy tuning every 0.5 s of simulated time);
    fused       repro.pfs.loop_jax.FusedLoop — N intervals of engine
                *and* tuning as a single jitted dispatch.

This sweep reports **tuned intervals per second** at 64 / 256 / 1024
OSC interfaces.  The headline number is fused vs the per-interval host
loop (run_fleet's default backend); fused vs host-jax isolates what
fusing just the decision path buys on top of the already-fused engine.
Compile time is excluded (one warmup run per path).

Run:  PYTHONPATH=src python benchmarks/loop_scaling.py [--quick] [--json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.fleet import FleetAgent, SimFleetPort
from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import random_stream, sequential_stream, table_from_sim

TICKS_PER_INTERVAL = 100   # 0.5 s tuning interval at the 5 ms tick
N_INTERVALS = 6            # timed tuned intervals per path
N_OSTS = 2


def build_sim(n_clients: int, n_osts: int = N_OSTS, seed: int = 1) -> PFSSim:
    sim = PFSSim(n_clients=n_clients, n_osts=n_osts, seed=seed)
    for c in range(n_clients):
        if c % 2 == 0:
            sim.attach(sequential_stream(c, READ, 4 * 2**20, ost=c % n_osts))
        else:
            sim.attach(random_stream(c, WRITE, 256 * 1024, ost=c % n_osts,
                                     n_threads=2))
    sim.set_knobs(np.arange(sim.n_osc), window_pages=64, rpcs_in_flight=2)
    return sim


def get_model(backend: str = "jax"):
    try:                                    # as benchmarks.loop_scaling
        from benchmarks.fleet_scaling import get_model as _get
    except ModuleNotFoundError:             # as a standalone script
        from fleet_scaling import get_model as _get
    return _get(backend)


def _bench_host_numpy(n_clients: int, model) -> float:
    sim = build_sim(n_clients)
    fleet = FleetAgent(SimFleetPort(sim), model)
    for _ in range(TICKS_PER_INTERVAL):     # warmup interval: compiles
        sim.step()                          # the model predictor
    fleet.tick()
    t0 = time.perf_counter()
    for _ in range(N_INTERVALS):
        for _ in range(TICKS_PER_INTERVAL):
            sim.step()
        fleet.tick()
    return time.perf_counter() - t0


def _bench_host_jax(n_clients: int, model, seg_backend: str) -> float:
    from repro.pfs.engine_jax import FusedEngine

    sim = build_sim(n_clients)
    table, wstate = table_from_sim(sim)
    engine = FusedEngine(sim.params, sim.topo, table, TICKS_PER_INTERVAL,
                         seg_backend=seg_backend)
    fleet = FleetAgent(SimFleetPort(sim), model)
    sim.state, wstate = engine.run_interval(sim.state, wstate)  # compile
    fleet.tick()
    t0 = time.perf_counter()
    for _ in range(N_INTERVALS):
        sim.state, wstate = engine.run_interval(sim.state, wstate)
        fleet.tick()
    return time.perf_counter() - t0


def _bench_fused(n_clients: int, model, seg_backend: str) -> float:
    from repro.pfs.loop_jax import FusedLoop

    sim = build_sim(n_clients)
    table, wstate = table_from_sim(sim)
    loop = FusedLoop(sim.params, sim.topo, TICKS_PER_INTERVAL, model,
                     seg_backend=seg_backend)
    state = sim.state
    loop.run(table, state, wstate, N_INTERVALS)     # compile + warm
    t0 = time.perf_counter()
    loop.run(table, state, wstate, N_INTERVALS)
    return time.perf_counter() - t0


def bench(n_osc: int, seg_backend: str = "jax", model=None) -> dict:
    model = model if model is not None else get_model("jax")
    n_clients = n_osc // N_OSTS
    t_np = _bench_host_numpy(n_clients, model)
    t_jax = _bench_host_jax(n_clients, model, seg_backend)
    t_fused = _bench_fused(n_clients, model, seg_backend)
    ips = lambda t: N_INTERVALS / t
    return {
        "n_osc": n_osc,
        "host_numpy_ips": ips(t_np),
        "host_jax_ips": ips(t_jax),
        "fused_ips": ips(t_fused),
        "speedup_vs_host_numpy": t_np / max(t_fused, 1e-12),
        "speedup_vs_host_jax": t_jax / max(t_fused, 1e-12),
    }


def run(scales=(64, 256, 1024), seg_backend: str = "jax") -> list[dict]:
    model = get_model("jax")
    return [bench(n, seg_backend, model) for n in scales]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--oscs", type=int, nargs="*", default=[64, 256, 1024])
    ap.add_argument("--seg-backend", default="jax")
    ap.add_argument("--quick", action="store_true",
                    help="sweep 64..256 interfaces only")
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON row per scale")
    args = ap.parse_args()
    scales = [n for n in args.oscs if n <= 256] if args.quick else args.oscs

    model = get_model("jax")
    print(f"tuned intervals/sec over {N_INTERVALS} x {TICKS_PER_INTERVAL}"
          f"-tick intervals (compile excluded)")
    print(f"{'oscs':>6} {'host-numpy':>11} {'host-jax':>10} {'fused':>10} "
          f"{'vs numpy':>9} {'vs jax':>8}")
    rows = []
    for n in scales:
        r = bench(n, args.seg_backend, model)
        rows.append(r)
        print(f"{r['n_osc']:>6} {r['host_numpy_ips']:>10.2f} "
              f"{r['host_jax_ips']:>9.2f} {r['fused_ips']:>9.2f} "
              f"{r['speedup_vs_host_numpy']:>8.1f}x "
              f"{r['speedup_vs_host_jax']:>7.1f}x")
    if args.json:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
