"""Render the EXPERIMENTS.md roofline table from results/dryrun/*.json."""

from __future__ import annotations

import glob
import json
import os


def load(out_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict], mesh: str = "pod") -> str:
    rows = ["| arch | shape | dominant | compute s | memory s | collective s "
            "| MODEL_FLOPs/HLO | MFU bound |",
            "|---|---|---|---|---|---|---|---|"]
    want = 2 if mesh == "pod" else 3
    for r in recs:
        if len(r["mesh"]) != want:
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | **{ro['dominant']}** "
            f"| {ro['compute_s']:.4f} | {ro['memory_s']:.4f} "
            f"| {ro['collective_s']:.4f} | {ro['useful_flops_frac']:.2f} "
            f"| {ro['mfu_bound']:.3f} |")
    return "\n".join(rows)


def main():
    import sys
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_v2"
    recs = load(out_dir)
    print(f"{len(recs)} dry-run records")
    print("\n## single-pod (16x16 = 256 chips)\n")
    print(table(recs, "pod"))
    print("\n## multi-pod (2x16x16 = 512 chips)\n")
    print(table(recs, "multipod"))


if __name__ == "__main__":
    main()
