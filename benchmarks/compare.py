"""Regression gate over two ``dial-bench-v1`` perf records.

    python benchmarks/compare.py BASELINE.json CANDIDATE.json
        [--threshold 0.10] [--report-only]

Diffs every shared metric, classifies each by a direction heuristic
(``speedup`` up is good, ``*_ms`` down is good, ...), and exits
nonzero when any metric moved the wrong way by more than the
threshold — the teeth behind ``make bench-compare``.  Benchmarks that
exist on only one side are reported but never fail the gate (new
benchmarks land all the time; removed ones are a review question, not
a perf regression).  ``--report-only`` prints the same table but
always exits 0 (CI uses it where the runner's wall clock is too noisy
to block on).
"""

from __future__ import annotations

import argparse
import json
import sys

# metric-name fragments -> which direction is an improvement.  Checked
# in order; first hit wins.  Names matching neither are informational.
_INFORMATIONAL = ("us_per_call",)   # harness wall incl. compile: noisy
_HIGHER_IS_BETTER = ("speedup", "per_s", "frac", "mfu", "gain", "tps",
                     "ips", "devices", "interfaces", "cores")
_LOWER_IS_BETTER = ("overhead", "_ms", "_s", "_pct", "seconds")


def direction(metric: str) -> int:
    """+1 higher is better, -1 lower is better, 0 informational."""
    low = metric.lower()
    for frag in _INFORMATIONAL:
        if frag in low:
            return 0
    for frag in _HIGHER_IS_BETTER:
        if frag in low:
            return +1
    for frag in _LOWER_IS_BETTER:
        if frag in low:
            return -1
    return 0


def _metrics(payload: dict) -> dict:
    """Flatten a dial-bench-v1 payload to ``{bench.metric: value}``
    (numeric derived values plus each benchmark's ``us_per_call``)."""
    if payload.get("schema") != "dial-bench-v1":
        raise ValueError(f"not a dial-bench-v1 record: "
                         f"schema={payload.get('schema')!r}")
    out = {}
    for rec in payload.get("benchmarks", []):
        name = rec["name"]
        out[f"{name}.us_per_call"] = rec.get("us_per_call")
        for k, v in rec.get("derived", {}).items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                out[f"{name}.{k}"] = v
    return out


def compare(baseline: dict, candidate: dict,
            threshold: float = 0.10) -> dict:
    """Diff two payloads; returns rows plus the regression verdict.

    A row regresses when the candidate moved against its metric's
    direction by more than ``threshold`` (relative).  Zero-valued
    baselines can't express a relative move and are reported as
    informational.  Metrics present on only one side get first-class
    rows with verdict ``new`` (candidate only) or ``removed``
    (baseline only) — visible in the table, never a gate failure.
    """
    base, cand = _metrics(baseline), _metrics(candidate)
    rows, regressions = [], []
    for key in sorted(set(base) | set(cand)):
        if key not in cand:
            rows.append({"metric": key, "baseline": base[key],
                         "candidate": None, "delta_pct": None,
                         "verdict": "removed"})
            continue
        if key not in base:
            rows.append({"metric": key, "baseline": None,
                         "candidate": cand[key], "delta_pct": None,
                         "verdict": "new"})
            continue
        b, c = base[key], cand[key]
        d = direction(key.split(".", 1)[1])
        if b == 0 or d == 0:
            rows.append({"metric": key, "baseline": b, "candidate": c,
                         "delta_pct": None, "verdict": "info"})
            continue
        delta = (c - b) / abs(b)
        improved = delta * d
        verdict = ("regression" if improved < -threshold
                   else "improved" if improved > threshold else "ok")
        row = {"metric": key, "baseline": b, "candidate": c,
               "delta_pct": round(100.0 * delta, 1), "verdict": verdict}
        rows.append(row)
        if verdict == "regression":
            regressions.append(row)
    only_base = sorted(set(base) - set(cand))
    only_cand = sorted(set(cand) - set(base))
    return {"rows": rows, "regressions": regressions,
            "only_baseline": only_base, "only_candidate": only_cand,
            "threshold": threshold}


def render(result: dict) -> str:
    lines = [f"{'metric':<48} {'baseline':>12} {'candidate':>12} "
             f"{'delta':>8}  verdict"]
    for r in result["rows"]:
        delta = ("" if r["delta_pct"] is None
                 else f"{r['delta_pct']:+.1f}%")
        b = "—" if r["baseline"] is None else r["baseline"]
        c = "—" if r["candidate"] is None else r["candidate"]
        lines.append(f"{r['metric']:<48} {b:>12} "
                     f"{c:>12} {delta:>8}  {r['verdict']}")
    if result["only_candidate"]:
        lines.append(f"new (candidate only): "
                     f"{', '.join(result['only_candidate'])}")
    if result["only_baseline"]:
        lines.append(f"dropped (baseline only): "
                     f"{', '.join(result['only_baseline'])}")
    n = len(result["regressions"])
    lines.append(f"{n} regression(s) beyond "
                 f"{100 * result['threshold']:.0f}%")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="dial-bench-v1 JSON (reference)")
    ap.add_argument("candidate", help="dial-bench-v1 JSON (under test)")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative move against a metric's direction "
                         "that counts as a regression (default 0.10)")
    ap.add_argument("--report-only", action="store_true",
                    help="print the diff but always exit 0")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.candidate) as f:
        candidate = json.load(f)
    result = compare(baseline, candidate, threshold=args.threshold)
    print(render(result))
    if result["regressions"] and not args.report_only:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
