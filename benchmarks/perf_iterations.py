"""SPerf hillclimb measurements: re-lower the three selected cells with
the optimization variants and print before/after roofline terms.

A. qwen2-moe train_4k  — expert padding 60->64 => EP shards the 16-way
   model axis (baseline: replicated expert compute).
B. llava-next train_4k — q-head padding 56->64 => head-sharded attention
   (baseline: replicated-attention fallback).
C. gemma2-2b long_500k — sliding-window cache slice on decode for the 13
   local layers (baseline: every layer streams the full 524k cache).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402

import repro.configs as cfgs                      # noqa: E402
from repro.launch import dryrun as dr             # noqa: E402


def measure(arch, shape, override=None, window_cache=False, tag=""):
    orig = cfgs.get_config
    if override:
        cfg0 = orig(arch)
        patched = dataclasses.replace(cfg0, **override)
        cfgs.get_config = lambda a: patched if a == arch else orig(a)
        dr.get_config = cfgs.get_config
    try:
        cfg, sh, mesh, lowered, extra = dr.lower_cell(arch, shape, False)
        if window_cache:
            extra["window_cache"] = True
        rec = dr.analyze(cfg, sh, mesh, lowered, extra)
    finally:
        cfgs.get_config = orig
        dr.get_config = orig
    ro = rec["roofline"]
    print(f"[{tag}] {arch} x {shape}: dominant={ro['dominant']} "
          f"compute={ro['compute_s']:.4f} memory={ro['memory_s']:.4f} "
          f"collective={ro['collective_s']:.4f} mfu_bound={ro['mfu_bound']:.3f}")
    os.makedirs("results/perf", exist_ok=True)
    with open(f"results/perf/{arch}__{shape}__{tag}.json", "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="one optimization pair (A baseline vs padded EP) "
                         "instead of the full three-cell sweep")
    ap.add_argument("--json", action="store_true",
                    help="print a final machine-readable summary line "
                         "(for benchmarks/run.py)")
    args = ap.parse_args(argv)

    recs = {}
    # A: expert padding
    recs["A_baseline"] = measure("qwen2-moe-a2.7b", "train_4k",
                                 tag="A_baseline")
    recs["A_padded_ep"] = measure("qwen2-moe-a2.7b", "train_4k",
                                  override={"n_experts_pad": 64},
                                  tag="A_padded_ep")
    if not args.quick:
        # B: head padding
        recs["B_baseline"] = measure("llava-next-34b", "train_4k",
                                     tag="B_baseline")
        recs["B_padded_heads"] = measure("llava-next-34b", "train_4k",
                                         override={"n_heads_pad": 64},
                                         tag="B_padded_heads")
        # C: window cache (code change is live; compare against the
        # analytic full-cache memory term recorded by the v2 sweep
        # baseline)
        recs["C_window_cache"] = measure("gemma2-2b", "long_500k",
                                         window_cache=True,
                                         tag="C_window_cache")
        recs["C_window_cache_32k"] = measure("gemma2-2b", "decode_32k",
                                             window_cache=True,
                                             tag="C_window_cache_32k")
    if args.json:
        summary = {
            "schema": "dial-perf-iterations-v1",
            "quick": args.quick,
            "measures": {tag: {k: rec["roofline"][k]
                               for k in ("dominant", "compute_s",
                                         "memory_s", "collective_s",
                                         "mfu_bound")}
                         for tag, rec in recs.items()},
        }
        print(json.dumps(summary))
    return recs


if __name__ == "__main__":
    main()
