"""Engine scaling: Python tick loop vs fused JAX interval scan.

PR 1 removed the per-interface Python overhead from the *tuning* tick;
the remaining hot path is the simulator itself, stepped tick-by-tick
from Python.  This sweep drives identical workload mixes through

    loop    the numpy oracle: legacy ``Workload`` objects + one
            ``sim.step()`` Python call per 5 ms tick;
    fused   the execution layer: the same workloads frozen into a
            ``WorkloadTable`` and a whole 100-tick tuning interval run
            as one jitted ``lax.scan`` (``repro.pfs.engine_jax``).

and reports simulated ticks/second at 16 -> 1024 OSC interfaces.  Rows
mirror the ``fleet_scaling.py`` JSON shape (one dict per scale with a
``speedup`` key); compile time is excluded (one warmup interval).

Run:  PYTHONPATH=src python benchmarks/sim_scaling.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.pfs import PFSSim
from repro.pfs.engine import READ, WRITE
from repro.pfs.workloads import random_stream, sequential_stream, table_from_sim

TICKS_PER_INTERVAL = 100   # 0.5 s tuning interval at the 5 ms tick
TIMED_INTERVALS = 4
N_OSTS = 2


def build_sim(n_clients: int, n_osts: int = N_OSTS, seed: int = 1) -> PFSSim:
    sim = PFSSim(n_clients=n_clients, n_osts=n_osts, seed=seed)
    for c in range(n_clients):
        if c % 2 == 0:
            sim.attach(sequential_stream(c, READ, 4 * 2**20, ost=c % n_osts))
        else:
            sim.attach(random_stream(c, WRITE, 256 * 1024, ost=c % n_osts,
                                     n_threads=2))
    sim.set_knobs(np.arange(sim.n_osc), window_pages=64, rpcs_in_flight=2)
    return sim


def bench(n_osc: int, seg_backend: str = "auto") -> dict:
    from repro.pfs.engine_jax import FusedEngine

    n_clients = n_osc // N_OSTS

    # numpy loop: warmup one interval, then time
    sim_l = build_sim(n_clients)
    for _ in range(TICKS_PER_INTERVAL):
        sim_l.step()
    t0 = time.perf_counter()
    for _ in range(TIMED_INTERVALS * TICKS_PER_INTERVAL):
        sim_l.step()
    t_loop = time.perf_counter() - t0

    # fused scan: warmup interval covers compile, then time
    sim_f = build_sim(n_clients)
    table, wstate = table_from_sim(sim_f)
    engine = FusedEngine(sim_f.params, sim_f.topo, table, TICKS_PER_INTERVAL,
                         seg_backend=seg_backend)
    state = sim_f.state
    state, wstate = engine.run_interval(state, wstate)
    t0 = time.perf_counter()
    for _ in range(TIMED_INTERVALS):
        state, wstate = engine.run_interval(state, wstate)
    t_fused = time.perf_counter() - t0

    ticks = TIMED_INTERVALS * TICKS_PER_INTERVAL
    return {"n_clients": n_clients, "n_osc": n_osc,
            "loop_ticks_per_s": ticks / t_loop,
            "fused_ticks_per_s": ticks / t_fused,
            "speedup": t_loop / max(t_fused, 1e-12)}


def run(scales=(16, 64, 256, 1024), seg_backend: str = "auto") -> list[dict]:
    return [bench(n, seg_backend) for n in scales]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--oscs", type=int, nargs="*",
                    default=[16, 64, 256, 1024])
    ap.add_argument("--seg-backend", default="auto",
                    choices=("auto", "jax", "pallas", "pallas_interpret"),
                    help="segment-reduce backend for the fused path")
    ap.add_argument("--quick", action="store_true",
                    help="sweep 16..256 OSCs only")
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON row per scale")
    args = ap.parse_args()
    scales = [n for n in args.oscs if n <= 256] if args.quick else args.oscs

    print(f"ticks/sec over {TIMED_INTERVALS} x {TICKS_PER_INTERVAL}-tick "
          f"intervals (compile excluded)")
    print(f"{'oscs':>6} {'loop t/s':>12} {'fused t/s':>12} {'speedup':>8}")
    rows = []
    for n in scales:
        r = bench(n, args.seg_backend)
        rows.append(r)
        print(f"{r['n_osc']:>6} {r['loop_ticks_per_s']:>11.0f} "
              f"{r['fused_ticks_per_s']:>11.0f} {r['speedup']:>7.1f}x")
    if args.json:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
