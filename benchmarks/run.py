"""Benchmark orchestrator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus the detailed tables.
``--json PATH`` additionally writes the rows as a machine-readable
``BENCH_*.json`` (one object per benchmark: name / us_per_call /
derived key-values) so the perf trajectory can be tracked across
commits (``make bench-json``).
"""

from __future__ import annotations

import argparse
import json
import platform
import time


def _record(records: list, name: str, us_per_call: float,
            derived: dict) -> None:
    pairs = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.0f},{pairs}")
    records.append({"name": name, "us_per_call": round(us_per_call),
                    "derived": derived})


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the summary rows as JSON "
                         "(e.g. reports/BENCH_latest.json)")
    args = ap.parse_args(argv)

    import benchmarks.fig3_dlio as fig3
    import benchmarks.fleet_scaling as fleet
    import benchmarks.lab_scaling as labsc
    import benchmarks.loop_scaling as loopsc
    import benchmarks.obs_overhead as obsov
    import benchmarks.ragged_scaling as raggedsc
    import benchmarks.sim_scaling as simsc
    import benchmarks.table2_h5bench as t2
    import benchmarks.table3_overhead as t3
    import benchmarks.train_scaling as trainsc

    records: list[dict] = []
    print("name,us_per_call,derived")

    t0 = time.time()
    rows2 = t2.run()
    el = (time.time() - t0) * 1e6 / max(len(rows2), 1)
    worst = min(r["dial_frac_of_optimal"] for r in rows2)
    _record(records, "table2_h5bench", el,
            {"min_frac_of_optimal": round(worst, 3)})

    t0 = time.time()
    rows3 = fig3.run()
    el = (time.time() - t0) * 1e6 / max(len(rows3), 1)
    best = max(r["speedup"] for r in rows3)
    _record(records, "fig3_dlio", el,
            {"max_speedup_vs_default": round(best, 2)})

    t0 = time.time()
    res = t3.run(backend="numpy")
    el = (time.time() - t0) * 1e6
    _record(records, "table3_overhead", el,
            {"read_e2e_ms": round(res["read"]["end_to_end_ms"], 2),
             "write_e2e_ms": round(res["write"]["end_to_end_ms"], 2)})

    for sharded, tag in ((False, "table3_fused"), (True, "table3_sharded")):
        t0 = time.time()
        rfu = t3.run_fused(sharded=sharded, seconds=10.0)
        el = (time.time() - t0) * 1e6
        _record(records, tag, el,
                {"tuning_ms_per_if_interval":
                     rfu["tuning_ms_per_interface_interval"],
                 "tuned_execute_s": rfu["tuned"]["execute_s"],
                 "tuned_compile_s": rfu["tuned"]["compile_s"],
                 "engine_only_execute_s": rfu["engine_only"]["execute_s"]})

    t0 = time.time()
    ro = obsov.bench(seconds=10.0)
    el = (time.time() - t0) * 1e6
    _record(records, "obs_overhead", el,
            {"stride": ro["stride"],
             "untraced_execute_ms":
                 round(ro["untraced"]["execute_s"] * 1e3, 1),
             "decisions_only_overhead_pct":
                 ro["decisions_only"]["overhead_pct"],
             "default_overhead_pct": ro["default"]["overhead_pct"]})

    t0 = time.time()
    fm = fleet.get_model("numpy")
    rf = fleet.bench(128, 2, fm)
    el = (time.time() - t0) * 1e6
    _record(records, "fleet_scaling", el,
            {"fleet_ms_per_osc": round(rf["fleet_ms"], 3),
             "loop_ms_per_osc": round(rf["loop_ms"], 3),
             "speedup": round(rf["speedup"], 1)})

    t0 = time.time()
    rs = simsc.bench(256)
    el = (time.time() - t0) * 1e6
    _record(records, "sim_scaling", el,
            {"loop_tps": round(rs["loop_ticks_per_s"]),
             "fused_tps": round(rs["fused_ticks_per_s"]),
             "speedup": round(rs["speedup"], 1)})

    t0 = time.time()
    rl = labsc.bench(32)
    el = (time.time() - t0) * 1e6
    _record(records, "lab_scaling", el,
            {"seq_sim_s_per_s": round(rl["seq_scenario_s_per_s"], 1),
             "batch_sim_s_per_s": round(rl["batch_scenario_s_per_s"], 1),
             "speedup": round(rl["speedup"], 1)})

    t0 = time.time()
    rr = raggedsc.bench(16)
    el = (time.time() - t0) * 1e6
    _record(records, "ragged_scaling", el,
            {"n_scenarios": rr["n_scenarios"],
             "seq_dispatches": rr["sequential_dispatches"],
             "structure_dispatches": rr["structure_dispatches"],
             "ragged_dispatches": rr["ragged_dispatches"],
             "ragged_loop_misses": rr["ragged_loop_misses"],
             "ragged_sim_s_per_s": round(rr["ragged_sim_s_per_s"], 1),
             "speedup_vs_seq": round(rr["ragged_speedup_vs_seq"], 1),
             "speedup_vs_structure":
                 round(rr["ragged_speedup_vs_structure"], 2)})

    t0 = time.time()
    rlp = loopsc.bench(256)
    el = (time.time() - t0) * 1e6
    _record(records, "loop_scaling", el,
            {"host_loop_ips": round(rlp["host_numpy_ips"], 2),
             "fused_ips": round(rlp["fused_ips"], 2),
             "speedup_vs_host_loop": round(rlp["speedup_vs_host_numpy"], 1)})

    t0 = time.time()
    rt = trainsc.bench(16)
    el = (time.time() - t0) * 1e6
    _record(records, "train_scaling", el,
            {"numpy_forests_per_s": round(rt["numpy_forests_per_s"], 2),
             "fast_forests_per_s": round(rt["fast_forests_per_s"], 2),
             "exact_forests_per_s": round(rt["exact_forests_per_s"], 2),
             "fast_speedup": round(rt["fast_speedup"], 1)})

    # a fresh process: the weak-scaling sweep forces host devices via
    # XLA_FLAGS, which only takes effect before jax initializes — and
    # the benchmarks above already initialized it here
    import os
    import subprocess
    import sys

    t0 = time.time()
    out = subprocess.run(
        [sys.executable,
         os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "fleet_weak_scaling.py"), "--json"],
        capture_output=True, text=True, check=True)
    el = time.time() - t0
    wk = json.loads(out.stdout.strip().splitlines()[-1])
    last, probe = wk["points"][-1], wk["max_fleet"]
    _record(records, "fleet_weak_scaling", el * 1e6,
            {"max_devices": last["devices"],
             "if_intervals_per_s": last["if_intervals_per_s"],
             "speedup_vs_1dev": last["speedup_vs_1dev"],
             "host_cores": wk["host_cores"],
             "max_fleet_interfaces": probe["interfaces"],
             "max_fleet_seconds": probe["seconds"]})

    # same fresh-process constraint: perf_iterations forces 512 host
    # devices at import
    t0 = time.time()
    out = subprocess.run(
        [sys.executable,
         os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "perf_iterations.py"), "--quick", "--json"],
        capture_output=True, text=True, check=True)
    el = time.time() - t0
    pi = json.loads(out.stdout.strip().splitlines()[-1])
    base = pi["measures"]["A_baseline"]
    pad = pi["measures"]["A_padded_ep"]
    _record(records, "perf_iterations", el * 1e6,
            {"a_baseline_dominant": base["dominant"],
             "a_baseline_mfu_bound": round(base["mfu_bound"], 3),
             "a_padded_ep_mfu_bound": round(pad["mfu_bound"], 3),
             "a_mfu_gain": round(pad["mfu_bound"]
                                 / max(base["mfu_bound"], 1e-9), 2)})

    if args.json:
        from repro.obs.timers import collect_provenance

        payload = {
            "schema": "dial-bench-v1",
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "platform": platform.platform(),
            "python": platform.python_version(),
            "provenance": collect_provenance(),
            "benchmarks": records,
        }
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"\nwrote {args.json}")

    print("\n--- Table II detail ---")
    for r in rows2:
        print(f"{r['workload']:28s} optimal={r['optimal_mbs']:8.1f} "
              f"DIAL={r['dial_mbs']:8.1f} ({100*r['dial_frac_of_optimal']:.1f}%)")
    print("\n--- Fig. 3 detail ---")
    for r in rows3:
        print(f"DLIO-{r['kernel']:9s} t={r['threads']:2d} osts={r['osts']}: "
              f"default={r['default_mbs']:7.1f} DIAL={r['dial_mbs']:7.1f} "
              f"({r['speedup']:.2f}x)")
    print("\n--- Table III detail (numpy backend) ---")
    for op in ("read", "write"):
        r = res[op]
        print(f"{op:5s}: snapshot={r['snapshot_ms']:.2f} ms "
              f"inference={r['inference_ms']:.2f} ms "
              f"end_to_end={r['end_to_end_ms']:.2f} ms")


if __name__ == "__main__":
    main()
