"""Benchmark orchestrator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows plus the detailed tables.
"""

from __future__ import annotations

import time


def main() -> None:
    import benchmarks.fig3_dlio as fig3
    import benchmarks.fleet_scaling as fleet
    import benchmarks.lab_scaling as labsc
    import benchmarks.sim_scaling as simsc
    import benchmarks.table2_h5bench as t2
    import benchmarks.table3_overhead as t3

    print("name,us_per_call,derived")

    t0 = time.time()
    rows2 = t2.run()
    el = (time.time() - t0) * 1e6 / max(len(rows2), 1)
    worst = min(r["dial_frac_of_optimal"] for r in rows2)
    print(f"table2_h5bench,{el:.0f},min_frac_of_optimal={worst:.3f}")

    t0 = time.time()
    rows3 = fig3.run()
    el = (time.time() - t0) * 1e6 / max(len(rows3), 1)
    best = max(r["speedup"] for r in rows3)
    print(f"fig3_dlio,{el:.0f},max_speedup_vs_default={best:.2f}x")

    t0 = time.time()
    res = t3.run(backend="numpy")
    el = (time.time() - t0) * 1e6
    print(f"table3_overhead,{el:.0f},"
          f"read_e2e_ms={res['read']['end_to_end_ms']:.2f};"
          f"write_e2e_ms={res['write']['end_to_end_ms']:.2f}")

    t0 = time.time()
    fm = fleet.get_model("numpy")
    rf = fleet.bench(128, 2, fm)
    el = (time.time() - t0) * 1e6
    print(f"fleet_scaling,{el:.0f},"
          f"fleet_ms_per_osc={rf['fleet_ms']:.3f};"
          f"loop_ms_per_osc={rf['loop_ms']:.3f};"
          f"speedup={rf['speedup']:.1f}x")

    t0 = time.time()
    rs = simsc.bench(256)
    el = (time.time() - t0) * 1e6
    print(f"sim_scaling,{el:.0f},"
          f"loop_tps={rs['loop_ticks_per_s']:.0f};"
          f"fused_tps={rs['fused_ticks_per_s']:.0f};"
          f"speedup={rs['speedup']:.1f}x")

    t0 = time.time()
    rl = labsc.bench(32)
    el = (time.time() - t0) * 1e6
    print(f"lab_scaling,{el:.0f},"
          f"seq_sim_s_per_s={rl['seq_scenario_s_per_s']:.1f};"
          f"batch_sim_s_per_s={rl['batch_scenario_s_per_s']:.1f};"
          f"speedup={rl['speedup']:.1f}x")

    print("\n--- Table II detail ---")
    for r in rows2:
        print(f"{r['workload']:28s} optimal={r['optimal_mbs']:8.1f} "
              f"DIAL={r['dial_mbs']:8.1f} ({100*r['dial_frac_of_optimal']:.1f}%)")
    print("\n--- Fig. 3 detail ---")
    for r in rows3:
        print(f"DLIO-{r['kernel']:9s} t={r['threads']:2d} osts={r['osts']}: "
              f"default={r['default_mbs']:7.1f} DIAL={r['dial_mbs']:7.1f} "
              f"({r['speedup']:.2f}x)")
    print("\n--- Table III detail (numpy backend) ---")
    for op in ("read", "write"):
        r = res[op]
        print(f"{op:5s}: snapshot={r['snapshot_ms']:.2f} ms "
              f"inference={r['inference_ms']:.2f} ms "
              f"end_to_end={r['end_to_end_ms']:.2f} ms")


if __name__ == "__main__":
    main()
