"""Scenario throughput: vmapped batch path vs sequential per-scenario loop.

The lab's claim is that scenario *count* is free: B structurally-
identical scenarios advance one tuning interval in a single vmapped
jitted launch, where the historical approach runs one Python interval
loop per scenario (the schedule ``core/dataset.collect`` and every
per-scenario experiment used to pay).

This sweep builds B jittered variants of one disturbed scenario
(``noisy_neighbor``: mixed reads under background contention bursts)
and drives the identical physics through

    sequential   one numpy ``run_interval`` per scenario per interval
                 (demand_step + engine_step, the oracle path);
    batched      one ``BatchEngine.run_interval`` for all B scenarios
                 (vmap of the fused lax.scan; compile excluded).

reporting completed scenario-seconds of simulation per wall-clock
second and the batch/sequential speedup at each B.

Run:  PYTHONPATH=src python benchmarks/lab_scaling.py [--quick] [--json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.lab.batch import BatchEngine, stack_scenarios
from repro.lab.scenarios import build, get_scenario, variants
from repro.pfs.workloads import run_interval as np_run_interval

TICKS_PER_INTERVAL = 100   # 0.5 s tuning interval at the 5 ms tick
TIMED_INTERVALS = 2
BASE_SCENARIO = "noisy_neighbor"


def bench(batch_size: int, seg_backend: str = "jax",
          base: str = BASE_SCENARIO) -> dict:
    specs = variants(get_scenario(base), batch_size, seed=11)
    interval_s = TICKS_PER_INTERVAL * 0.005

    # sequential numpy loop over per-scenario intervals
    built = [build(s) for s in specs]
    t0 = time.perf_counter()
    for b in built:
        st, ws = b.state, b.wstate
        for i in range(TIMED_INTERVALS):
            sched = b.schedule(i * TICKS_PER_INTERVAL, TICKS_PER_INTERVAL)
            st, ws = np_run_interval(b.params, b.topo, b.table, st, ws,
                                     TICKS_PER_INTERVAL, schedule=sched)
    t_seq = time.perf_counter() - t0

    # vmapped batch (compile excluded via one warmup interval)
    batch = stack_scenarios([build(s) for s in specs])
    engine = BatchEngine(batch.params, batch.topo, TICKS_PER_INTERVAL,
                         seg_backend=seg_backend)
    sched = batch.schedule(0, TICKS_PER_INTERVAL)
    engine.run_interval(batch.table, batch.state, batch.wstate, sched)
    batch = stack_scenarios([build(s) for s in specs])
    t0 = time.perf_counter()
    for i in range(TIMED_INTERVALS):
        sched = batch.schedule(i * TICKS_PER_INTERVAL, TICKS_PER_INTERVAL)
        batch.state, batch.wstate = engine.run_interval(
            batch.table, batch.state, batch.wstate, sched)
    t_batch = time.perf_counter() - t0

    sim_seconds = batch_size * TIMED_INTERVALS * interval_s
    return {
        "batch_size": batch_size,
        "seq_scenario_s_per_s": sim_seconds / t_seq,
        "batch_scenario_s_per_s": sim_seconds / t_batch,
        "speedup": t_seq / max(t_batch, 1e-12),
    }


def run(scales=(8, 32, 128), seg_backend: str = "jax") -> list[dict]:
    return [bench(b, seg_backend) for b in scales]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--batches", type=int, nargs="*", default=[8, 32, 128])
    ap.add_argument("--seg-backend", default="jax")
    ap.add_argument("--quick", action="store_true",
                    help="sweep 8..32 scenarios only")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    scales = [b for b in args.batches if b <= 32] if args.quick else args.batches

    print(f"scenario-seconds simulated per wall second over "
          f"{TIMED_INTERVALS} x {TICKS_PER_INTERVAL}-tick intervals "
          f"({BASE_SCENARIO} variants; compile excluded)")
    print(f"{'B':>5} {'seq sim-s/s':>12} {'batch sim-s/s':>14} {'speedup':>8}")
    rows = []
    for b in scales:
        r = bench(b, args.seg_backend)
        rows.append(r)
        print(f"{r['batch_size']:>5} {r['seq_scenario_s_per_s']:>11.1f} "
              f"{r['batch_scenario_s_per_s']:>13.1f} {r['speedup']:>7.1f}x")
    if args.json:
        for r in rows:
            print(json.dumps(r))


if __name__ == "__main__":
    main()
